"""Generate EXPERIMENTS.md from dry-run artifacts + benchmark CSVs.

Run AFTER: the full dry-run sweep (results/dryrun_final) and
`python -m benchmarks.run > bench_output.txt`.
"""
import json
import subprocess
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from benchmarks.roofline import load_cells, PEAK_FLOPS, HBM_BW, ICI_BW  # noqa: E402

BASE = "results/dryrun"        # paper-faithful baseline sweep
FINAL = "results/dryrun_final"  # post-hillclimb sweep


def fmt_cells(cells):
    lines = ["| arch | shape | mesh | compute s | memory s | collective s "
             "| bottleneck | useful | roofline frac | HBM GiB/chip |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        if "skip" in c:
            lines.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | — | — "
                         f"| — | SKIP | — | — | — |")
            continue
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} "
            f"| {c['compute_s']:.4f} | {c['memory_s']:.4f} "
            f"| {c['collective_s']:.4f} | {c['bottleneck']} "
            f"| {c['useful_ratio']:.3f} | {c['roofline_fraction']:.3f} "
            f"| {c['hbm_gib_per_chip']:.2f} |")
    return "\n".join(lines)


def dryrun_stats(d):
    import glob
    ok = skip = fail = 0
    compile_s = []
    for f in glob.glob(d + "/*.json"):
        r = json.load(open(f))
        if r.get("ok"):
            ok += 1
            compile_s.append(r.get("compile_s", 0))
        elif "skipped" in r:
            skip += 1
        else:
            fail += 1
    return ok, skip, fail, (sum(compile_s) / max(len(compile_s), 1))


def main():
    base_cells = {(c.get("arch"), c.get("shape"), c.get("mesh")): c
                  for c in load_cells(BASE)}
    final_cells = load_cells(FINAL)
    ok, skip, fail, avg_c = dryrun_stats(FINAL)
    b_ok, b_skip, b_fail, _ = dryrun_stats(BASE)

    # before/after deltas for the 3 hillclimbed cells
    picks = [("yi_6b", "train_4k", "16x16"),
             ("kimi_k2_1t_a32b", "train_4k", "16x16"),
             ("mixtral_8x22b", "prefill_32k", "16x16")]
    delta_rows = ["| cell | metric | baseline | optimized | Δ |",
                  "|---|---|---|---|---|"]
    fin = {(c.get("arch"), c.get("shape"), c.get("mesh")): c
           for c in final_cells}
    for key in picks:
        b, f = base_cells.get(key), fin.get(key)
        if not b or not f or "skip" in b or "skip" in f:
            continue
        for metric in ("collective_s", "memory_s", "roofline_fraction"):
            bb, ff = b[metric], f[metric]
            delta = (ff / bb - 1) * 100 if bb else 0
            delta_rows.append(
                f"| {key[0]}×{key[1]} | {metric} | {bb:.4f} | {ff:.4f} "
                f"| {delta:+.0f}% |")

    with open("EXPERIMENTS_TABLES.md", "w") as f:
        f.write("## Generated tables\n\n")
        f.write(f"### Dry-run summary\nfinal sweep: OK={ok} SKIP={skip} "
                f"FAIL={fail} (avg compile {avg_c:.1f}s); baseline sweep: "
                f"OK={b_ok} SKIP={b_skip} FAIL={b_fail}\n\n")
        f.write("### §Roofline — optimized (post-hillclimb), all cells\n\n")
        f.write(fmt_cells(final_cells))
        f.write("\n\n### Hillclimb before/after\n\n")
        f.write("\n".join(delta_rows))
        f.write("\n\n### §Roofline — paper-faithful baseline, all cells\n\n")
        f.write(fmt_cells(load_cells(BASE)))
        f.write("\n")
    print("wrote EXPERIMENTS_TABLES.md")


if __name__ == "__main__":
    main()
