"""Capture golden scheduler metrics + engine byte accounting.

Run once against the pre-refactor monolithic schedulers (PR 3) to freeze
their simulate-mode `ScheduleMetrics` on the fig6 configurations, and the
serving engine's `BatchReport` byte accounting on the quickstart scenario.
`tests/test_pipeline.py` asserts the plan-builder + cost-interpreter stack
reproduces these to float equality, and the execute interpreter reproduces
the byte accounting exactly — ISSUE 4's acceptance criterion.

Usage:  PYTHONPATH=src python scripts/capture_golden_pipeline.py
Writes: tests/data/golden_pipeline.json
"""
from __future__ import annotations

import json
import os

import numpy as np


def metrics_record(m) -> dict:
    return {
        "scheduler": m.scheduler,
        "makespan_s": m.makespan_s,
        "io_modeled_s": m.io_modeled_s,
        "compute_modeled_s": m.compute_modeled_s,
        "host_preprocess_s": m.host_preprocess_s,
        "bytes_by_path": m.bytes_by_path,
        "seconds_by_path": m.seconds_by_path,
        "total_transfer_bytes": m.total_transfer_bytes,
        "cache_hit_bytes": m.cache_hit_bytes,
        "merge_events": m.merge_events,
        "merge_io_s": m.merge_io_s,
        "segments": m.segments,
        "oom": m.oom,
    }


def report_record(r) -> dict:
    return {
        "uploaded_bytes": r.uploaded_bytes,
        "cache_hit_bytes": r.cache_hit_bytes,
        "promoted_bytes": r.promoted_bytes,
        "segments_streamed": r.segments_streamed,
        "aggregation_passes": r.aggregation_passes,
        "ici_bytes": r.ici_bytes,
        "directory_hit_bytes": r.directory_hit_bytes,
        "duplicate_avoided_bytes": r.duplicate_avoided_bytes,
    }


def fig6_golden() -> dict:
    from benchmarks.common import (
        FEATURE_DIM, budget_for, dataset, feature_spec,
    )
    from repro.core import SCHEDULERS
    from repro.io.tiers import PAPER_GPU_SYSTEM

    out = {}
    for name in ["rUSA", "kV2a", "kU1a", "socLJ1", "kP1a"]:
        a = dataset(name)
        feat = feature_spec(a)
        budget = budget_for(name, a, feat)
        for sched in ["maxmemory", "ucg", "etc", "aires"]:
            res = SCHEDULERS[sched](
                PAPER_GPU_SYSTEM, device_budget=budget).run(
                    a, feat, mode="simulate", dataset=name)
            out[f"{name}/{sched}"] = metrics_record(res.metrics)
    return out


def cached_sim_golden() -> dict:
    """AIRES simulate mode with a shared segment cache: cold + warm."""
    from benchmarks.common import budget_for, dataset, feature_spec
    from repro.core import SCHEDULERS
    from repro.io import TieredSegmentCache
    from repro.io.tiers import PAPER_GPU_SYSTEM

    a = dataset("kV2a")
    feat = feature_spec(a, 64)
    budget = budget_for("kV2a", a, feat)
    cache = TieredSegmentCache(device_budget_bytes=budget)
    sched = SCHEDULERS["aires"](PAPER_GPU_SYSTEM, device_budget=budget,
                                segment_cache=cache)
    cold = sched.run(a, feat, dataset="kV2a").metrics
    warm = sched.run(a, feat, dataset="kV2a").metrics
    return {"cold": metrics_record(cold), "warm": metrics_record(warm)}


def engine_golden() -> dict:
    from repro.core import plan_memory_dense_features
    from repro.data import (
        SUITESPARSE_SPECS, generate_graph, normalized_adjacency, scaled_spec,
    )
    from repro.io import CacheDirectory
    from repro.runtime import EngineConfig, InferenceRequest, ServingEngine

    a = normalized_adjacency(generate_graph(
        scaled_spec(SUITESPARSE_SPECS["socLJ1"], 1e-4), seed=0))
    est = plan_memory_dense_features(a, a.n_rows, 64, float("inf"))
    budget = int(est.m_b + est.m_c + 0.6 * a.nbytes())
    rng = np.random.default_rng(1)
    h = rng.standard_normal((a.n_rows, 32)).astype(np.float32)
    w = [rng.standard_normal((32, 16)).astype(np.float32)]

    out = {}
    for label, kw, nworkers, shards in [
        ("cache_on", {}, 1, 1),
        ("cache_off", {"cache_enabled": False}, 1, 1),
        ("shard4", {"cache_shards": 4}, 2, 4),
    ]:
        directory = CacheDirectory() if nworkers > 1 else None
        workers = [
            ServingEngine(EngineConfig(device_budget_bytes=budget,
                                       max_batch_features=64,
                                       worker_id=wid, **kw),
                          directory=directory)
            for wid in range(nworkers)
        ]
        for eng in workers:
            eng.register_graph("lj", a)
        reports = []
        for _epoch in range(2):
            for eng in workers:
                eng.submit(InferenceRequest("lj", h, w))
                reports.append(report_record(eng.run_batch()))
        out[label] = reports
    return out


def main() -> None:
    golden = {
        "fig6": fig6_golden(),
        "cached_sim": cached_sim_golden(),
        "engine": engine_golden(),
    }
    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "tests", "data", "golden_pipeline.json")
    path = os.path.abspath(path)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(golden, f, indent=1, sort_keys=True)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
