"""Lint every benchmark-built pipeline plan with the static analyzer.

CI gate (the `lint` job): builds all 20 fig6 configurations (5 datasets ×
4 schedulers, paper budgets at AIRES_BENCH_SCALE) plus the cached and
sharded engine stream plans, runs `repro.core.analysis.analyze_plan` over
each raw plan, and re-analyzes under `PassPipeline(strict=True)` with the
three production passes — so a pass or builder change that oversubscribes
a tier, drops bytes, or leaves a hazard fails CI before any golden drifts.

Exit status: nonzero if any plan yields an error-severity finding.
Warnings are printed but do not fail the gate — except in the
partition-aware section, where a `lint/shard-imbalance` warning means
the cluster->shard balance cap regressed and does fail it.

Usage:  PYTHONPATH=src python scripts/lint_plans.py
"""
from __future__ import annotations

import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)   # benchmarks.* lives at the repo root

from benchmarks.common import (           # noqa: E402
    SCALE, budget_for, dataset, feature_spec,
)
from repro.core import (                  # noqa: E402
    AiresConfig,
    AiresSpGEMM,
    EDFOrderingPass,
    PassPipeline,
    PlanAnalysisError,
    SCHEDULERS,
    ShardPlacementPass,
    TransferCoalescingPass,
    analyze_plan,
    plan_memory_dense_features,
)
from repro.data import generate_sbm_graph, normalized_adjacency  # noqa: E402
from repro.io import (                    # noqa: E402
    ShardedSegmentCache, TieredSegmentCache,
)
from repro.io.tiers import ICI_RING, PAPER_GPU_SYSTEM  # noqa: E402
from repro.sparse.partition import partition_graph  # noqa: E402

DATASETS = ["rUSA", "kV2a", "kU1a", "socLJ1", "kP1a"]   # fig6 configs
SPEC = PAPER_GPU_SYSTEM


def _lint(label, plan, cache=None):
    """Analyze one plan; returns its findings (printed as we go)."""
    report = analyze_plan(plan, spec=SPEC, segment_cache=cache)
    status = "clean" if not report.findings else (
        f"{len(report.errors)} error(s), {len(report.warnings)} warning(s)")
    print(f"  {label:<44s} {status}")
    for f in report.findings:
        print(f"    {f}")
    return report


def _strict_rewrite(label, plan, cache):
    """Run the production passes under strict mode; analyzer findings on
    any pass output raise (and fail the gate) right here."""
    pipeline = PassPipeline(
        [ShardPlacementPass(), TransferCoalescingPass(min_bytes=1 << 12),
         EDFOrderingPass()],
        spec=SPEC, strict=True)
    try:
        out, reports = pipeline.apply(plan, segment_cache=cache)
    except PlanAnalysisError as err:
        print(f"  {label:<44s} FAILED strict rewrite")
        print(f"    {err}")
        return False
    n = sum(len(r.findings) for r in reports)
    print(f"  {label:<44s} strict rewrite clean "
          f"({len(reports)} passes, {n} findings)")
    return n == 0


def main() -> int:
    errors = 0
    print(f"fig6 builder plans (scale={SCALE:g}):")
    for name in DATASETS:
        a = dataset(name)
        feat = feature_spec(a)
        budget = budget_for(name, a, feat)
        for sched_name, cls in SCHEDULERS.items():
            plan = cls(SPEC, device_budget=budget).build_plan(
                a, feat, dataset=name)
            report = _lint(f"{name}/{sched_name}"
                           + (" (oom)" if plan.oom else ""), plan)
            errors += len(report.errors)

    print("cached + sharded engine plans:")
    small = dataset(DATASETS[0])
    # The engine needs a feasible (M_B + M_C + working-set) budget at the
    # serving width — the fig6 paper ratios deliberately starve it.
    est = plan_memory_dense_features(small, small.n_rows, 16, float("inf"))
    budget = int(est.m_b + est.m_c + 0.6 * small.nbytes())
    for label, cache in (
            ("tiered cache", TieredSegmentCache(device_budget_bytes=budget)),
            ("sharded cache (4)", ShardedSegmentCache(
                device_budget_bytes=budget, n_shards=4))):
        eng = AiresSpGEMM(
            AiresConfig(device_budget_bytes=budget, bm=8, bk=8),
            segment_cache=cache)
        plan = eng.stream_plan(small, (small.n_rows, 16), spec=SPEC)
        report = _lint(f"stream plan / {label}", plan, cache=cache)
        errors += len(report.errors)
        if not _strict_rewrite(f"strict passes / {label}", plan, cache):
            errors += 1

    print("partition-aware sharded plan (lint/shard-imbalance gate):")
    # Connectivity-clustered owner maps concentrate bricks on near shards
    # — by design. The balance cap in `map_clusters_to_shards` keeps the
    # heaviest shard under the analyzer's 2x-mean wire-byte threshold, so
    # a partitioned plan must lint clean; regressing the cap (or the LDG
    # clustering) trips `lint/shard-imbalance` here and fails the gate.
    sbm = normalized_adjacency(generate_sbm_graph(
        small.n_rows, 8 * small.n_rows, n_blocks=8, seed=0))
    est = plan_memory_dense_features(sbm, sbm.n_rows, 16, float("inf"))
    budget = int(est.m_b + est.m_c + 0.6 * sbm.nbytes())
    cache = ShardedSegmentCache(device_budget_bytes=budget, n_shards=4,
                                topology=ICI_RING)
    part = partition_graph(sbm, 8, n_shards=4, topology=ICI_RING,
                           local_shard=cache.local_shard)
    eng = AiresSpGEMM(
        AiresConfig(device_budget_bytes=budget, bm=8, bk=8),
        segment_cache=cache, partition=part)
    plan = eng.stream_plan(sbm, (sbm.n_rows, 16), spec=SPEC)
    report = _lint("stream plan / partitioned shards (4)", plan, cache=cache)
    errors += len(report.errors) + len(report.warnings)
    if not _strict_rewrite("strict passes / partitioned shards (4)",
                           plan, cache):
        errors += 1

    if errors:
        print(f"FAIL: {errors} error-severity finding(s)")
        return 1
    print("OK: every plan analyzed clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
