"""Out-of-core MoE expert streaming — the AIRES engine applied to weights.

The RoBW invariant ('never split a row') becomes 'never split an expert':
expert blocks stream host->device double-buffered while the router and
attention weights stay resident (dual-way placement). This is how kimi-k2's
384-expert FFN bank exceeds HBM without stalling compute (DESIGN §6).

Run:  PYTHONPATH=src python examples/ooc_expert_streaming.py
"""
import numpy as np

from repro.io.weights import ExpertBank, StreamedWeightProvider

rng = np.random.default_rng(0)
E, D, F = 64, 32, 16
banks = [ExpertBank(layer=l, arrays={
    "w_gate": rng.standard_normal((E, D, F)).astype(np.float32),
    "w_up": rng.standard_normal((E, D, F)).astype(np.float32),
    "w_down": rng.standard_normal((E, F, D)).astype(np.float32),
}) for l in range(4)]

per_expert = banks[0].expert_bytes()
provider = StreamedWeightProvider(banks, hbm_budget_bytes=per_expert * 12,
                                  align=4, depth=2)
total_blocks = 0
for bank in banks:
    for (s, e), arrays in provider.stream_layer(bank):
        # a real layer would run the expert matmuls for experts [s, e) here
        assert arrays["w_gate"].shape[0] == e - s
        total_blocks += 1
print(f"streamed {total_blocks} aligned expert blocks across "
      f"{len(banks)} layers (block_size={provider.block_size} experts)")
assert provider.block_size % 4 == 0
print("OK")
