"""Quickstart: the paper's technique in six lines.

Out-of-core SpGEMM of a graph adjacency against dense features through the
AIRES pipeline (Eq.5-7 planning -> RoBW partitioning -> double-buffered
streaming -> Pallas block-ELL kernel), verified against the oracle.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import AiresConfig, AiresSpGEMM, plan_memory_dense_features
from repro.data import SUITESPARSE_SPECS, generate_graph, normalized_adjacency, scaled_spec
from repro.sparse.ref_spgemm import spgemm_csr_dense

# A socLJ1-like power-law graph, scaled for the CPU container.
a = normalized_adjacency(generate_graph(scaled_spec(SUITESPARSE_SPECS["socLJ1"], 1e-4), seed=0))
h = np.random.default_rng(0).standard_normal((a.n_rows, 32)).astype(np.float32)

# Budget: the Eq. 5-7 resident set (M_B + M_C) must fit; granting only a
# fraction of A's bytes on top forces out-of-core streaming.
est = plan_memory_dense_features(a, a.n_rows, h.shape[1], float("inf"))
budget = int(est.m_b + est.m_c + 0.5 * a.nbytes())
engine = AiresSpGEMM(AiresConfig(device_budget_bytes=budget, bm=8, bk=8))
x = engine(a, jnp.asarray(h))

err = np.abs(np.asarray(x) - spgemm_csr_dense(a, h)).max()
print(f"graph: {a.n_rows} nodes, {a.nnz} edges; "
      f"streamed {engine.last_stream_stats.segments} RoBW segments; "
      f"max err vs oracle = {err:.2e}")
assert err < 1e-4
print("OK")
