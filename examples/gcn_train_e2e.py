"""End-to-end driver: train a GCN with out-of-core AIRES aggregation.

A ~100k-parameter GCN (256-dim features, 2 hidden layers) trains for a few
hundred steps on a synthetic kmer-style graph; the aggregation X = A~ H runs
through the full AIRES streaming engine each epoch when out_of_core=True.

Run:  PYTHONPATH=src python examples/gcn_train_e2e.py [--steps 200]
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import AiresConfig, AiresSpGEMM
from repro.data import SUITESPARSE_SPECS, generate_graph, normalized_adjacency, scaled_spec
from repro.models import GCNConfig, gcn_init, gcn_loss
from repro.sparse import csr_to_dense
from repro.train import make_optimizer

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--out-of-core-every", type=int, default=50,
                help="validate the streamed path every N steps")
args = ap.parse_args()

# Graph + features + labels.
a = normalized_adjacency(generate_graph(scaled_spec(SUITESPARSE_SPECS["kV2a"], 5e-6), seed=0))
n = a.n_rows
rng = np.random.default_rng(0)
cfg = GCNConfig(feature_dim=64, hidden_dims=(64, 64), n_classes=8,
                out_of_core=True,
                device_budget_bytes=int((a.nbytes() + n * 64 * 4 * 3) * 0.6))
h0 = jnp.asarray(rng.standard_normal((n, cfg.feature_dim)).astype(np.float32))
labels = jnp.asarray(rng.integers(0, cfg.n_classes, size=(n,)))

params = gcn_init(cfg, jax.random.PRNGKey(0))
init_opt, opt_update = make_optimizer("adamw", lr=2e-3)
opt = init_opt(params)

a_dense = jnp.asarray(csr_to_dense(a))      # in-core path for the jitted loop
engine = AiresSpGEMM(AiresConfig(device_budget_bytes=cfg.device_budget_bytes,
                                 bm=8, bk=8))

@jax.jit
def step(params, opt):
    loss, grads = jax.value_and_grad(
        lambda p: gcn_loss(cfg, p, a_dense, h0, labels))(params)
    params, opt = opt_update(params, grads, opt)
    return loss, params, opt

t0 = time.perf_counter()
for s in range(args.steps):
    loss, params, opt = step(params, opt)
    if s % 25 == 0:
        print(f"step {s:>4d} loss {float(loss):.4f}")
    if s % args.out_of_core_every == 0:
        # The AIRES streamed aggregation must agree with the in-core path —
        # forward AND backward (the custom VJP streams Aᵀ for real).
        x_stream = engine(a, h0)
        x_ref = a_dense @ h0
        assert float(jnp.abs(x_stream - x_ref).max()) < 1e-3
        g_stream = jax.grad(lambda h: jnp.sum(engine(a, h) ** 2))(h0)
        g_ref = jax.grad(lambda h: jnp.sum((a_dense @ h) ** 2))(h0)
        assert float(jnp.abs(g_stream - g_ref).max()) < 1e-2
        assert engine.last_backward_stream_stats.segments >= 1
print(f"final loss {float(loss):.4f} in {time.perf_counter()-t0:.1f}s "
      f"({args.steps} steps, out-of-core checks passed)")
