"""Serve a small LM with batched requests through the decode path.

Uses the recurrentgemma smoke config (hybrid RG-LRU + local attention) —
the same serve_step the multi-pod dry-run lowers at decode_32k/long_500k.

Run:  PYTHONPATH=src python examples/lm_serve.py
"""
import numpy as np
import jax

from repro.configs import get_config
from repro.launch.serve import serve
from repro.models import init_params

cfg = get_config("recurrentgemma_2b", smoke=True)
params = init_params(cfg, jax.random.PRNGKey(0))
prompts = np.random.default_rng(0).integers(0, cfg.vocab, size=(4, 6),
                                            dtype=np.int32)
tokens = serve(cfg, params, prompts, steps=10)
print("served batch of 4 requests, 10 tokens each:")
print(tokens)
assert tokens.shape == (4, 10)
print("OK")
