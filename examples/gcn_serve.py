"""Serving-engine demo: the quickstart graph, twice, through the cache.

Epoch 1 streams every BlockELL segment host→device; epoch 2 finds them in
the tiered segment cache and uploads (almost) nothing — the redundant
re-transfer AIRES Phase III leaves on the table, closed. A second graph
shares the same engine and cache budget to show multi-graph serving.

Run:  PYTHONPATH=src python examples/gcn_serve.py
"""
import numpy as np

from repro.core import plan_memory_dense_features
from repro.data import (
    SUITESPARSE_SPECS, generate_graph, normalized_adjacency, scaled_spec,
)
from repro.runtime import EngineConfig, InferenceRequest, ServingEngine
from repro.sparse.ref_spgemm import spgemm_csr_dense

# The quickstart graph plus a road-network graph, multi-graph style.
lj = normalized_adjacency(generate_graph(
    scaled_spec(SUITESPARSE_SPECS["socLJ1"], 1e-4), seed=0))
road = normalized_adjacency(generate_graph(
    scaled_spec(SUITESPARSE_SPECS["rUSA"], 2e-5), seed=1))

rng = np.random.default_rng(0)
# Feasible for the engine's pinned plan width (64) on both graphs, with
# enough slack that each graph still streams in several segments.
budget = max(
    int(est.m_b + est.m_c + 0.6 * a.nbytes())
    for a in (lj, road)
    for est in [plan_memory_dense_features(a, a.n_rows, 64, float("inf"))])
engine = ServingEngine(EngineConfig(device_budget_bytes=budget))
engine.register_graph("socLJ1", lj)
engine.register_graph("rUSA", road)

h = rng.standard_normal((lj.n_rows, 32)).astype(np.float32)
w = rng.standard_normal((32, 8)).astype(np.float32)
h_road = rng.standard_normal((road.n_rows, 16)).astype(np.float32)

reports = []
for epoch in range(2):
    engine.submit(InferenceRequest("socLJ1", h, [w]))
    engine.submit(InferenceRequest("rUSA", h_road))
    rep = engine.run_batch()
    reports.append(rep)
    print(f"epoch {epoch}: uploaded {rep.uploaded_bytes} B, "
          f"cache-hit {rep.cache_hit_bytes} B "
          f"(promoted {rep.promoted_bytes} B, hit rate {rep.hit_rate:.0%})")

out = next(r.output for r in reports[0].results if r.graph == "socLJ1")
err = np.abs(out - spgemm_csr_dense(lj, h) @ w).max()
print(f"max err vs oracle = {err:.2e}")
assert err < 1e-3
assert reports[1].uploaded_bytes <= reports[0].uploaded_bytes // 2, \
    "second epoch should reuse cached segments"
print("OK")
