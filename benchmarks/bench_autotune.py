"""Autotune bench — online cost calibration + schedule search, measured.

Two sections, one JSON artifact (BENCH_autotune.json):

  * calibration — a ServingEngine whose `CostCalibrator` watches traffic
    against a *drifted* ground-truth system (every path's bandwidth at
    0.7x and setup latency at 3x the static spec; HBM untouched). Each
    window predicts per-(graph, width) request costs, measures the true
    makespan under the drifted spec, then feeds the window's transfer
    records back into the calibrator. The on-arm's mean |error| must
    shrink strictly window over window (trust-blended fits converge
    geometrically); the off-arm (static spec) stays at its initial error.

  * autotune — `ServingEngine.autotune` per (graph, system), recording
    the default vs tuned predicted makespan (tuned <= default by
    construction: the default arm is always a candidate) plus a roofline
    cross-check: the default plan's makespan can never beat
    max_path(path_bytes / path_bw), the same per-resource bound
    benchmarks/roofline.py computes from the shared TierSpec constants.

  * bitexact — a calibrator with zero observations prices and serves
    byte-identically to no calibrator at all (the off-by-default
    guarantee the golden pipeline tests pin).

Deterministic: every "actual" is a modeled estimate under the drifted
spec, never wall clock, so CI can assert the monotone properties at
AIRES_BENCH_SCALE=1e-4.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Dict, List

import numpy as np

from benchmarks.bench_serve import _jsonable, build_graphs, serving_budget
from benchmarks.common import SCALE
from repro.core import CostCalibrator
from repro.core.analysis import path_byte_totals
from repro.core.pipeline import CacheProbeOp, TransferOp
from repro.io.tiers import (
    PAPER_GPU_SYSTEM,
    Path,
    TieredMemorySystem,
    TierSpec,
    TPU_V5E_SYSTEM,
)
from repro.runtime import (
    EngineConfig,
    InferenceRequest,
    ServingEngine,
    VirtualClock,
)

WIDTHS = (16, 32, 48)
HIDDEN = 16
WINDOWS = 5
BW_DRIFT = 0.7      # ground-truth bandwidth = 0.7x the static spec
LAT_DRIFT = 3.0     # ground-truth setup latency = 3x the static spec
SYSTEMS: Dict[str, TierSpec] = {
    "tpu_v5e": TPU_V5E_SYSTEM,
    "paper_gpu": PAPER_GPU_SYSTEM,
}


def drifted_spec(base: TierSpec) -> TierSpec:
    """The ground-truth system the static spec has drifted away from.
    Only per-path bw/latency move — `hbm_bw` and the host constants stay,
    so every modeled discrepancy is observable from transfer records."""
    return dataclasses.replace(
        base,
        bw={p: b * BW_DRIFT for p, b in base.bw.items()},
        latency_s={p: l * LAT_DRIFT for p, l in base.latency_s.items()},
    )


def make_engine(graphs, budget: int, spec: TierSpec = TPU_V5E_SYSTEM,
                calibrator: CostCalibrator = None) -> ServingEngine:
    eng = ServingEngine(EngineConfig(
        device_budget_bytes=budget, clock=VirtualClock(), tier_spec=spec,
        calibrator=calibrator))
    for name, a in graphs.items():
        eng.register_graph(name, a)
    return eng


def template_request(name: str, a, width: int) -> InferenceRequest:
    h = np.zeros((a.n_rows, width), np.float32)
    w = [np.zeros((width, HIDDEN), np.float32)]
    return InferenceRequest(name, h, w)


def replay_plan_transfers(plan, tms: TieredMemorySystem) -> None:
    """Charge every transfer the plan declares (cold reading: cache
    probes charge their miss) through `tms` — the observation stream a
    real deployment's TieredMemorySystem would have recorded."""
    for bound in plan.ops:
        op = bound.op
        t = op if isinstance(op, TransferOp) else (
            op.miss if isinstance(op, CacheProbeOp) else None)
        if t is not None and t.nbytes > 0:
            tms.transfer(t.path, t.src, t.dst, t.nbytes, tag=t.tag)


def run_calibration(graphs, budget: int) -> Dict[str, object]:
    base = TPU_V5E_SYSTEM
    true_spec = drifted_spec(base)
    cal = CostCalibrator()
    eng = make_engine(graphs, budget, calibrator=cal)
    windows: List[Dict[str, object]] = []
    for w in range(WINDOWS):
        true_tms = TieredMemorySystem(true_spec)
        errs, off_errs = [], []
        for name, a in graphs.items():
            for width in WIDTHS:
                req = template_request(name, a, width)
                predicted = eng.estimate_request_cost(req)
                off_predicted = eng.estimate_request_cost(req, spec=base)
                plan = eng._engines[name].stream_plan(
                    a, (a.n_rows, width), spec=true_spec)
                actual = plan.estimate(true_spec).makespan_s
                errs.append(abs(predicted - actual))
                off_errs.append(abs(off_predicted - actual))
                replay_plan_transfers(plan, true_tms)
        records = cal.observe_records(true_tms.transfers)
        windows.append({
            "window": w,
            "calibrated_mean_abs_error_s": float(np.mean(errs)),
            "uncalibrated_mean_abs_error_s": float(np.mean(off_errs)),
            "records_observed": records,
            "generation": cal.generation,
        })
    return {
        "bw_drift": BW_DRIFT, "latency_drift": LAT_DRIFT,
        "windows": windows,
        "path_estimates": [
            {"path": e.path.value, "n_obs": e.n_obs, "rounds": e.rounds,
             "bw": e.bw, "latency_s": e.latency_s, "trust": e.trust}
            for e in cal.estimates(base)],
    }


def run_autotune(graphs, budget: int) -> List[Dict[str, object]]:
    rows = []
    for sys_name, spec in SYSTEMS.items():
        eng = make_engine(graphs, budget, spec=spec)
        for name, a in graphs.items():
            tuned = eng.autotune(name)
            # Roofline cross-check on the default plan: its modeled
            # makespan cannot beat the busiest path's bytes/bw bound
            # (the same per-resource reading benchmarks/roofline.py
            # derives from this very TierSpec).
            plan = eng._engines[name].stream_plan(
                a, (a.n_rows, eng.config.max_batch_features), spec=spec)
            totals = path_byte_totals(plan)
            bound = max((nbytes / spec.bw[Path(p)]
                         for p, nbytes in totals.items()), default=0.0)
            rows.append({
                "system": sys_name, "graph": name,
                "default_makespan_s": tuned.default_makespan_s,
                "tuned_makespan_s": tuned.predicted_makespan_s,
                "predicted_speedup": tuned.predicted_speedup,
                "min_bytes": tuned.min_bytes,
                "pass_order": list(tuned.pass_order),
                "ell_buckets": (list(tuned.ell_buckets)
                                if tuned.ell_buckets else None),
                "ell_bytes": tuned.ell_bytes,
                "default_ell_bytes": tuned.default_ell_bytes,
                "roofline_bound_s": bound,
                "is_default": tuned.is_default,
            })
    return rows


def run_bitexact(graphs, budget: int) -> Dict[str, object]:
    def one_batch(calibrator):
        rng = np.random.default_rng(7)
        eng = make_engine(graphs, budget, calibrator=calibrator)
        for name, a in graphs.items():
            h = rng.standard_normal((a.n_rows, HIDDEN)).astype(np.float32)
            w = [rng.standard_normal((HIDDEN, HIDDEN)).astype(np.float32)]
            eng.submit(InferenceRequest(name, h, w))
        return eng.run_batch()

    off = one_batch(None)
    on = one_batch(CostCalibrator())   # zero observations = identity
    predictions_equal = (
        [l.predicted_s for l in off.request_latency]
        == [l.predicted_s for l in on.request_latency])
    outputs_equal = all(
        np.array_equal(r0.output, r1.output)
        for r0, r1 in zip(off.results, on.results))
    return {
        "predictions_equal": bool(predictions_equal),
        "outputs_equal": bool(outputs_equal),
        "uploaded_bytes_equal": off.uploaded_bytes == on.uploaded_bytes,
    }


def validate_report(report: Dict[str, object]) -> None:
    """Schema + property check for BENCH_autotune.json (CI smoke job)."""
    for key in ("scale", "calibration", "autotune", "bitexact"):
        assert key in report, f"missing top-level key {key!r}"
    windows = report["calibration"]["windows"]
    assert len(windows) >= 3, "need >= 3 calibration windows"
    errs = [w["calibrated_mean_abs_error_s"] for w in windows]
    for i in range(1, len(errs)):
        assert errs[i] < errs[i - 1], (
            f"calibrated error not strictly decreasing at window {i}: "
            f"{errs[i - 1]:.3e} -> {errs[i]:.3e}")
    off = [w["uncalibrated_mean_abs_error_s"] for w in windows]
    assert errs[-1] < off[-1], "calibration never beat the static spec"
    assert report["autotune"], "no autotune rows"
    for row in report["autotune"]:
        assert row["tuned_makespan_s"] <= row["default_makespan_s"] + 1e-12, (
            f"tuned arm worse than default on {row['system']}/{row['graph']}")
        assert row["default_makespan_s"] >= row["roofline_bound_s"] - 1e-12, (
            f"makespan beats the roofline bound on "
            f"{row['system']}/{row['graph']}")
        assert row["ell_bytes"] <= row["default_ell_bytes"]
    for key, ok in report["bitexact"].items():
        assert ok, f"calibration-off bit-exactness violated: {key}"


def run() -> Dict[str, object]:
    graphs = build_graphs()
    budget = serving_budget(graphs)
    report = {
        "scale": SCALE,
        "widths": list(WIDTHS),
        "calibration": run_calibration(graphs, budget),
        "autotune": run_autotune(graphs, budget),
        "bitexact": run_bitexact(graphs, budget),
    }
    return _jsonable(report)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", default="BENCH_autotune.json")
    args = ap.parse_args(argv)

    report = run()
    validate_report(report)
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
        fh.write("\n")

    for w in report["calibration"]["windows"]:
        print(f"window {w['window']}: calibrated |err| "
              f"{w['calibrated_mean_abs_error_s']:.3e}s vs static "
              f"{w['uncalibrated_mean_abs_error_s']:.3e}s "
              f"({w['records_observed']} records)")
    for row in report["autotune"]:
        print(f"{row['system']:9s} {row['graph']:8s} default "
              f"{row['default_makespan_s']:.3e}s -> tuned "
              f"{row['tuned_makespan_s']:.3e}s "
              f"(x{row['predicted_speedup']:.3f}, "
              f"min_bytes={row['min_bytes']}, "
              f"order={'>'.join(row['pass_order'])}, "
              f"buckets={row['ell_buckets']})")
    print(f"bitexact: {report['bitexact']}")
    print(f"wrote {args.out} (scale={SCALE})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
