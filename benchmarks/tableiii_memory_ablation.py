"""Table III — per-epoch latency under shrinking GPU memory constraints.

Paper claim: baselines OOM as the budget drops below their minimum
footprint (MaxMemory/UCG first, then ETC) while AIRES keeps running with
gracefully increasing latency.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import (
    SCALE, budget_for, csv_row, dataset, feature_spec, run_sched,
)

# (dataset, budgets GB) straight from Table III.
CASES = [
    ("kV1r", [24, 21, 19]),
    ("kP1a", [16, 14, 12]),
    ("socLJ1", [11, 10, 8]),
]
SCHEDS = ["maxmemory", "ucg", "etc", "aires"]


def run() -> List[str]:
    rows = [f"# tableIII memory-constraint ablation (scale={SCALE})"]
    for name, budgets in CASES:
        a = dataset(name)
        feat = feature_spec(a)
        for gb in budgets:
            budget = budget_for(name, a, feat, budget_gb=gb)
            cells = []
            for sched in SCHEDS:
                m = run_sched(sched, a, feat, budget, name).metrics
                cells.append("-" if m.oom else f"{m.makespan_s*1e3:.2f}ms")
            rows.append(csv_row(
                f"tableIII/{name}/{gb}GB", 0.0,
                ";".join(f"{s}={c}" for s, c in zip(SCHEDS, cells))))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
