"""Partition-aware sharding bench — connectivity-clustered vs CRC owners.

Serves GCN epochs against a stochastic-block-model graph (the clustered
community structure `repro.sparse.partition` exploits) on a 4-shard ring
cache through two arms built on identical engines, budgets and passes
(ShardPlacementPass enabled in both):

  * crc       — the default owner map: `shard_of` CRC-hashes every
                segment key, spreading bricks uniformly over the mesh.
                A warm epoch ships ~(S-1)/S of the working set over ICI
                at ring-average hop distance.
  * partition — `EngineConfig.partition_shards` clusters the CSR
                adjacency (LDG, 2x-shards clusters), RoBW tiles over the
                cluster boundaries, and the cluster->shard map packs
                nnz-heavy clusters onto the nearest shards first under a
                1.5x balance cap. Warm-epoch ICI bytes drop from
                *topology*: co-clustered bricks live local or one hop
                away instead of uniformly spread.

Outputs must be bit-identical across arms (cluster-aligned RoBW segments
still hold complete rows), and the partitioned arm's warm-epoch
`ici_bytes` must come out strictly below CRC's — the ISSUE acceptance
metric. Writes BENCH_partition.json.
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List

import numpy as np

from benchmarks.common import SCALE
from repro.core import ShardPlacementPass, plan_memory_dense_features
from repro.data import generate_sbm_graph, normalized_adjacency
from repro.io.tiers import ICI_RING
from repro.runtime import EngineConfig, InferenceRequest, ServingEngine

N_VERTICES = max(2_048, int(4_000_000 * SCALE))
N_EDGES = max(16_384, int(60_000_000 * SCALE))
N_BLOCKS = 8               # SBM communities = cluster count below
P_IN = 0.9                 # fraction of edges confined to their block
SHARDS = 4                 # ring: hops from shard 0 are [0, 1, 2, 1]
CLUSTERS = 2 * SHARDS      # >shards so the nnz-balanced packing can skew
WIDTH = 32                 # request feature width
HIDDEN = 16                # single GCN layer, WIDTH -> HIDDEN
EPOCHS = 4                 # epoch 1 fills the cache; report the last
SEG_FRAC = 24              # stream budget sized for ~SEG_FRAC segments

EPOCH_KEYS = ("uploaded_bytes", "cache_hit_bytes", "promoted_bytes",
              "ici_bytes", "segments_streamed")


def sbm_graph():
    return normalized_adjacency(generate_sbm_graph(
        N_VERTICES, N_EDGES, n_blocks=N_BLOCKS, p_in=P_IN, seed=0))


def stream_budget(a) -> int:
    est = plan_memory_dense_features(a, a.n_rows, WIDTH, float("inf"))
    return int(est.m_b + est.m_c + a.nbytes() / SEG_FRAC)


def build_workload(a, seed: int):
    rng = np.random.default_rng(seed)
    h = rng.standard_normal((a.n_rows, WIDTH)).astype(np.float32)
    w = [rng.standard_normal((WIDTH, HIDDEN)).astype(np.float32)]
    return h, w


def make_engine(a, budget: int, cache_bytes: int,
                partitioned: bool) -> ServingEngine:
    eng = ServingEngine(EngineConfig(
        device_budget_bytes=budget,
        cache_device_bytes=cache_bytes,
        cache_shards=SHARDS,
        ici_topology=ICI_RING,
        plan_passes=[ShardPlacementPass()],
        max_batch_features=WIDTH,
        partition_shards=CLUSTERS if partitioned else 0))
    eng.register_graph("g", a)
    return eng


def epoch(eng: ServingEngine, h, w):
    eng.submit(InferenceRequest("g", h, w))
    return eng.run_batch()


def measure_wire_bytes(a, budget: int) -> Dict[str, int]:
    """One unsharded cold epoch: the graph's total brick bytes W (what
    both arms' aggregate cache budget is sized to, so each shard holds
    ~W/SHARDS and neither arm can simply pin the whole plan locally)."""
    probe = ServingEngine(EngineConfig(device_budget_bytes=budget,
                                       max_batch_features=WIDTH))
    probe.register_graph("g", a)
    h, w = build_workload(a, seed=0)
    cold = epoch(probe, h, w)
    return {
        "wire_total_bytes": int(cold.uploaded_bytes),
        "segments": int(cold.segments_streamed
                        // max(1, cold.aggregation_passes)),
    }


def run_arm(a, budget: int, cache_bytes: int, h, w,
            partitioned: bool):
    eng = make_engine(a, budget, cache_bytes, partitioned)
    epochs: List[Dict[str, int]] = []
    outputs: List[np.ndarray] = []
    for _ in range(EPOCHS):
        rep = epoch(eng, h, w)
        outputs.append(np.asarray(rep.results[0].output))
        epochs.append({
            "uploaded_bytes": rep.uploaded_bytes,
            "cache_hit_bytes": rep.cache_hit_bytes,
            "promoted_bytes": rep.promoted_bytes,
            "ici_bytes": rep.ici_bytes,
            "segments_streamed": rep.segments_streamed,
        })
    summary = {"epochs": epochs, "warm": epochs[-1],
               "cold_uploaded_bytes": epochs[0]["uploaded_bytes"]}
    if partitioned:
        part = eng._engines["g"].partition
        summary["partition"] = {
            "n_clusters": part.n_clusters,
            "shard_nnz": [int(x) for x in part.shard_nnz],
        }
    return summary, outputs


def validate_report(report: Dict[str, object]) -> None:
    """Schema + acceptance check for BENCH_partition.json (CI smoke)."""
    for key in ("scale", "graph", "seed", "shards", "clusters", "arms",
                "outputs_bitwise_equal"):
        assert key in report, f"missing top-level key {key!r}"
    for key in ("n_rows", "nnz", "n_blocks", "segments",
                "wire_total_bytes"):
        assert key in report["graph"], f"graph missing {key!r}"
    assert set(report["arms"]) == {"crc", "partition"}
    for arm, summary in report["arms"].items():
        assert len(summary["epochs"]) == EPOCHS, arm
        for entry in summary["epochs"]:
            for k in EPOCH_KEYS:
                assert isinstance(entry.get(k), int), (arm, k)
        assert summary["cold_uploaded_bytes"] > 0, arm
    part = report["arms"]["partition"]
    assert part["partition"]["n_clusters"] == report["clusters"]
    # Same math, different owners: outputs are bit-identical per epoch.
    assert report["outputs_bitwise_equal"] is True
    # The headline acceptance: clustering the owner map cuts warm-epoch
    # ICI bytes strictly, from topology alone (same passes, same cache
    # budget, same graph — only who owns each brick changed).
    crc_ici = report["arms"]["crc"]["warm"]["ici_bytes"]
    part_ici = part["warm"]["ici_bytes"]
    assert crc_ici > 0, "CRC arm shipped nothing over ICI — cache too big?"
    assert part_ici < crc_ici, (
        f"partitioned owners must beat CRC: {part_ici} >= {crc_ici}")


def _jsonable(obj):
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    return obj


def run(seed: int) -> Dict[str, object]:
    a = sbm_graph()
    budget = stream_budget(a)
    h, w = build_workload(a, seed)
    probe = measure_wire_bytes(a, budget)
    # Aggregate cache budget = the plan's wire bytes: each of the 4
    # shards holds ~W/4, so placement cannot pin the whole working set
    # on the local shard and the owner map decides who pays ICI.
    cache_bytes = probe["wire_total_bytes"]

    crc, crc_out = run_arm(a, budget, cache_bytes, h, w, partitioned=False)
    part, part_out = run_arm(a, budget, cache_bytes, h, w, partitioned=True)
    identical = all(np.array_equal(x, y)
                    for x, y in zip(crc_out, part_out))

    report = {
        "scale": SCALE,
        "seed": seed,
        "shards": SHARDS,
        "clusters": CLUSTERS,
        "graph": {
            "name": "sbm", "n_rows": a.n_rows, "nnz": a.nnz,
            "n_blocks": N_BLOCKS, "p_in": P_IN,
            "segments": probe["segments"],
            "wire_total_bytes": probe["wire_total_bytes"],
        },
        "cache_device_bytes": cache_bytes,
        "arms": {"crc": crc, "partition": part},
        "outputs_bitwise_equal": bool(identical),
    }
    return _jsonable(report)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--out", default="BENCH_partition.json")
    args = ap.parse_args(argv)

    report = run(args.seed)
    validate_report(report)
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
        fh.write("\n")

    g = report["graph"]
    print(f"sbm graph: {g['n_rows']} rows, {g['nnz']} nnz, "
          f"{g['n_blocks']} blocks, {g['segments']} segments, "
          f"wire={g['wire_total_bytes']}")
    for arm in ("crc", "partition"):
        warm = report["arms"][arm]["warm"]
        print(f"{arm:9s} warm epoch: ici={warm['ici_bytes']} "
              f"hits={warm['cache_hit_bytes']} "
              f"promoted={warm['promoted_bytes']} "
              f"uploaded={warm['uploaded_bytes']}")
    crc_ici = report["arms"]["crc"]["warm"]["ici_bytes"]
    part_ici = report["arms"]["partition"]["warm"]["ici_bytes"]
    print(f"warm ICI bytes: crc={crc_ici} partition={part_ici} "
          f"({100 * (1 - part_ici / crc_ici):.1f}% lower; "
          f"outputs identical={report['outputs_bitwise_equal']})")
    print(f"wrote {args.out} (scale={SCALE})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
