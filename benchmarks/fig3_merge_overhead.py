"""Fig. 3 — merging overhead of naive (MaxMemory) segmentation.

Paper claim: merge+staging latency is 10–50 % of computation latency and
grows as the memory budget shrinks (kP1a < kU1a < kV2a at their Table II
constraints). We reproduce the metric exactly as captioned: (host merge +
merge DtoH/HtoD transfer time) / computation latency, under the naive
scheduler; AIRES's RoBW brings it to 0 (no merge events).
"""
from __future__ import annotations

from typing import List

from benchmarks.common import (
    budget_for, csv_row, dataset, feature_spec, run_sched, SCALE,
)

DATASETS = ["kP1a", "kU1a", "kV2a"]


def run() -> List[str]:
    """Fig. 3 setup: tight budget (0.45× requirement — 'the smaller the
    allocated GPU memory, the higher the overheads') and the paper's own
    baseline kernel efficiency (hypersparse cuSPARSE-class SpGEMM reaches
    ~2 % of HBM bandwidth; the overhead ratio is measured against that
    computation latency, as in the figure's caption)."""
    from repro.core import SCHEDULERS
    from repro.io.tiers import PAPER_GPU_SYSTEM
    from repro.core.memory_model import required_bytes

    rows = [f"# fig3 merge overhead (scale={SCALE})"]
    for name in DATASETS:
        a = dataset(name)
        feat = feature_spec(a)
        budget = int(0.55 * required_bytes(a, feat))
        naive_sched = SCHEDULERS["maxmemory"](
            PAPER_GPU_SYSTEM, device_budget=budget, compute_efficiency=0.02)
        # Fig. 3 instruments the naive system *while it runs*: disable the
        # Table III feasibility policy for this diagnostic.
        naive_sched.oom_fraction = 0.0
        naive = naive_sched.run(a, feat, dataset=name).metrics
        # AIRES at its Table II constraint budget (Fig. 3 is a naive-system
        # diagnostic; the AIRES row demonstrates zero merge events).
        from benchmarks.common import budget_for
        aires = SCHEDULERS["aires"](
            PAPER_GPU_SYSTEM, device_budget=budget_for(name, a, feat),
            compute_efficiency=0.02).run(a, feat, dataset=name).metrics
        frac = naive.merge_overhead_frac()
        rows.append(csv_row(
            f"fig3/{name}/maxmemory", naive.makespan_s * 1e6,
            f"merge_overhead_frac={frac:.3f};merge_events={naive.merge_events}"))
        rows.append(csv_row(
            f"fig3/{name}/aires", aires.makespan_s * 1e6,
            f"merge_overhead_frac={aires.merge_overhead_frac():.3f};"
            f"merge_events={aires.merge_events}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
