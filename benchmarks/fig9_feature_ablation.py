"""Fig. 9 — per-epoch latency across GCN feature sizes 16..256.

Paper claim: AIRES's speedup is consistent across model configurations.
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import SCALE, budget_for, csv_row, dataset, feature_spec
from repro.core import FeatureSpec, gcn_epoch
from repro.io.tiers import PAPER_GPU_SYSTEM

DATASET = "kV2a"
FEATURE_SIZES = [16, 32, 64, 128, 256]


def run() -> List[str]:
    rows = [f"# fig9 feature-size ablation on {DATASET} (scale={SCALE})"]
    a = dataset(DATASET)
    for f in FEATURE_SIZES:
        feat = feature_spec(a, f)
        budget = budget_for(DATASET, a, feat)
        spans = {}
        for sched in ("maxmemory", "etc", "aires"):
            em = gcn_epoch(a, feat, [np.zeros((f, f))] * 2, sched,
                           PAPER_GPU_SYSTEM, budget, dataset=DATASET)
            spans[sched] = em.epoch_makespan_s
        rows.append(csv_row(
            f"fig9/F{f}/aires", spans["aires"] * 1e6,
            f"speedup_vs_maxmem={spans['maxmemory']/spans['aires']:.2f}"
            f";vs_etc={spans['etc']/spans['aires']:.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
