"""Fig. 9 — per-epoch latency across GCN feature sizes 16..256.

Paper claim: AIRES's speedup is consistent across model configurations.

`--cache` adds the tiered-segment-cache ablation arm: two consecutive
epochs of the AIRES scheduler sharing one cache — the second epoch's
Phase II DMA drops to cache promotions only, and the row reports its
makespan plus the wire bytes the cache kept off the bus.
"""
from __future__ import annotations

import argparse
from typing import List

import numpy as np

from benchmarks.common import SCALE, budget_for, csv_row, dataset, feature_spec
from repro.core import FeatureSpec, SCHEDULERS, gcn_epoch
from repro.io import TieredSegmentCache
from repro.io.tiers import PAPER_GPU_SYSTEM

DATASET = "kV2a"
FEATURE_SIZES = [16, 32, 64, 128, 256]


def run(cache: bool = False) -> List[str]:
    rows = [f"# fig9 feature-size ablation on {DATASET} (scale={SCALE})"]
    a = dataset(DATASET)
    for f in FEATURE_SIZES:
        feat = feature_spec(a, f)
        budget = budget_for(DATASET, a, feat)
        spans = {}
        for sched in ("maxmemory", "etc", "aires"):
            em = gcn_epoch(a, feat, [np.zeros((f, f))] * 2, sched,
                           PAPER_GPU_SYSTEM, budget, dataset=DATASET)
            spans[sched] = em.epoch_makespan_s
        rows.append(csv_row(
            f"fig9/F{f}/aires", spans["aires"] * 1e6,
            f"speedup_vs_maxmem={spans['maxmemory']/spans['aires']:.2f}"
            f";vs_etc={spans['etc']/spans['aires']:.2f}"))
        if cache:
            # Cache device tier sized at the streaming budget — i.e. the
            # ablation models an operator dedicating as much spare HBM
            # again to brick retention (see TieredSegmentCache docstring:
            # the tier is spare memory beyond the Eq. 5-7 working set).
            seg_cache = TieredSegmentCache(device_budget_bytes=budget)
            sched = SCHEDULERS["aires"](PAPER_GPU_SYSTEM,
                                        device_budget=budget,
                                        segment_cache=seg_cache)
            warm = cold = None
            for _ in range(2):  # epoch 1 fills, epoch 2 hits
                cold, warm = warm, sched.run(a, feat, dataset=DATASET).metrics
            rows.append(csv_row(
                f"fig9/F{f}/aires+cache", warm.makespan_s * 1e6,
                f"hit_bytes={warm.cache_hit_bytes}"
                f";dma_bytes={warm.bytes_by_path.get('dma', 0)}"
                f";speedup_vs_cold={cold.makespan_s/warm.makespan_s:.2f}"))
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cache", action="store_true",
                    help="add the tiered-segment-cache warm-epoch arm")
    args = ap.parse_args(argv)
    print("\n".join(run(cache=args.cache)))


if __name__ == "__main__":
    main()
