"""Fig. 9 — per-epoch latency across GCN feature sizes 16..256.

Paper claim: AIRES's speedup is consistent across model configurations.

`--cache` adds the tiered-segment-cache ablation arm: two consecutive
epochs of the AIRES scheduler sharing one cache — the second epoch's
Phase II DMA drops to cache promotions only, and the row reports its
makespan plus the wire bytes the cache kept off the bus.

`--passes` adds the plan-rewrite ablation arm (repro.core.passes): the
same warm-epoch runs routed through a PassPipeline — shard-aware RoBW
placement (with `--shards`: warm ici_bytes must come out strictly lower
than the pass-free shard arm, the ISSUE 5 acceptance metric) plus
transfer coalescing.

`--partition` (with `--shards`) adds a partition-aware owner-map arm:
the scheduler tiles RoBW over LDG cluster boundaries and installs a
cluster->shard owner map, so warm-epoch remote hits concentrate on
near shards instead of the CRC-uniform spread (repro.sparse.partition).
"""
from __future__ import annotations

import argparse
from typing import List

import numpy as np

from benchmarks.common import SCALE, budget_for, csv_row, dataset, feature_spec
from repro.core import (
    PassPipeline,
    SCHEDULERS,
    ShardPlacementPass,
    TransferCoalescingPass,
    gcn_epoch,
)
from repro.io import ShardedSegmentCache, TieredSegmentCache
from repro.io.tiers import PAPER_GPU_SYSTEM
from repro.sparse.partition import partition_graph

DATASET = "kV2a"
FEATURE_SIZES = [16, 32, 64, 128, 256]


def _pass_pipeline() -> PassPipeline:
    return PassPipeline([ShardPlacementPass(), TransferCoalescingPass()],
                        spec=PAPER_GPU_SYSTEM)


def run(cache: bool = False, shards: int = 0,
        passes: bool = False, partition: bool = False) -> List[str]:
    rows = [f"# fig9 feature-size ablation on {DATASET} (scale={SCALE})"]
    a = dataset(DATASET)
    part = (partition_graph(a, 2 * shards, n_shards=shards)
            if partition and shards else None)
    for f in FEATURE_SIZES:
        feat = feature_spec(a, f)
        budget = budget_for(DATASET, a, feat)
        spans = {}
        for sched in ("maxmemory", "etc", "aires"):
            em = gcn_epoch(a, feat, [np.zeros((f, f))] * 2, sched,
                           PAPER_GPU_SYSTEM, budget, dataset=DATASET)
            spans[sched] = em.epoch_makespan_s
        rows.append(csv_row(
            f"fig9/F{f}/aires", spans["aires"] * 1e6,
            f"speedup_vs_maxmem={spans['maxmemory']/spans['aires']:.2f}"
            f";vs_etc={spans['etc']/spans['aires']:.2f}"))
        if cache:
            # Cache device tier sized at the streaming budget — i.e. the
            # ablation models an operator dedicating as much spare HBM
            # again to brick retention (see TieredSegmentCache docstring:
            # the tier is spare memory beyond the Eq. 5-7 working set).
            rows.append(_warm_epoch_row(
                a, feat, budget, TieredSegmentCache(device_budget_bytes=budget),
                f"fig9/F{f}/aires+cache"))
            if passes:
                rows.append(_warm_epoch_row(
                    a, feat, budget,
                    TieredSegmentCache(device_budget_bytes=budget),
                    f"fig9/F{f}/aires+cache+passes",
                    passes=_pass_pipeline()))
        if shards:
            # Mesh-sharded device tier: each shard retains 1/shards of the
            # plan; warm-epoch remote hits ride ICI (cheap) instead of the
            # PCIe-class DMA re-upload — the fig9 scale-out arm.
            rows.append(_warm_epoch_row(
                a, feat, budget,
                ShardedSegmentCache(device_budget_bytes=budget,
                                    n_shards=shards),
                f"fig9/F{f}/aires+cache{shards}shard", ici=True))
            if passes:
                # Placement pass: the plan's bricks are pinned to the shard
                # that streams them — warm ici_bytes strictly below the
                # pass-free row above (the acceptance comparison).
                rows.append(_warm_epoch_row(
                    a, feat, budget,
                    ShardedSegmentCache(device_budget_bytes=budget,
                                        n_shards=shards),
                    f"fig9/F{f}/aires+cache{shards}shard+passes", ici=True,
                    passes=_pass_pipeline()))
            if part is not None:
                # Partition-aware owners: connectivity-clustered bricks
                # co-located on their cluster's shard — warm ici_bytes
                # drop from topology (vs the CRC shard row above).
                rows.append(_warm_epoch_row(
                    a, feat, budget,
                    ShardedSegmentCache(device_budget_bytes=budget,
                                        n_shards=shards),
                    f"fig9/F{f}/aires+cache{shards}shard+partition",
                    ici=True, partition=part))
    return rows


def _warm_epoch_row(a, feat, budget, seg_cache, label, ici=False,
                    passes=None, partition=None) -> str:
    """Two consecutive AIRES epochs sharing `seg_cache`; report the warm one."""
    sched = SCHEDULERS["aires"](PAPER_GPU_SYSTEM, device_budget=budget,
                                segment_cache=seg_cache, passes=passes,
                                partition=partition)
    warm = cold = None
    for _ in range(2):  # epoch 1 fills, epoch 2 hits
        cold, warm = warm, sched.run(a, feat, dataset=DATASET).metrics
    derived = (f"hit_bytes={warm.cache_hit_bytes}"
               f";dma_bytes={warm.bytes_by_path.get('dma', 0)}")
    if ici:
        derived += f";ici_bytes={warm.bytes_by_path.get('ici', 0)}"
    derived += f";speedup_vs_cold={cold.makespan_s/warm.makespan_s:.2f}"
    return csv_row(label, warm.makespan_s * 1e6, derived)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cache", action="store_true",
                    help="add the tiered-segment-cache warm-epoch arm")
    ap.add_argument("--shards", type=int, default=0,
                    help="add a mesh-sharded cache arm with this many shards")
    ap.add_argument("--passes", action="store_true",
                    help="add plan-rewrite-pass arms (shard placement + "
                         "transfer coalescing) next to the cache/shard arms")
    ap.add_argument("--partition", action="store_true",
                    help="add a partition-aware owner-map arm next to the "
                         "shard arm (requires --shards)")
    args = ap.parse_args(argv)
    print("\n".join(run(cache=args.cache, shards=args.shards,
                        passes=args.passes, partition=args.partition)))


if __name__ == "__main__":
    main()
