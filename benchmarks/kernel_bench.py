"""Kernel microbench: bcsr_spmm wall time (interpret mode — correctness
path only; on CPU this measures the streaming pipeline, not MXU perf) plus
the derived arithmetic-intensity numbers the TPU roofline uses.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np
import jax.numpy as jnp

from repro.kernels import bcsr_spmm
from repro.sparse import csr_from_dense, tile_csr_to_block_ell


def run() -> List[str]:
    rows = ["# kernel microbench (interpret mode on CPU)"]
    rng = np.random.default_rng(0)
    for n, f, dens in [(256, 64, 0.05), (512, 128, 0.02)]:
        dense = ((rng.random((n, n)) < dens)
                 * rng.standard_normal((n, n))).astype(np.float32)
        a = csr_from_dense(dense)
        ell = tile_csr_to_block_ell(a, bm=32, bk=32)
        h = rng.standard_normal((n, f)).astype(np.float32)
        hj = jnp.asarray(h)
        out = bcsr_spmm(ell, hj, bn=32)           # compile + warm
        out.block_until_ready()
        t0 = time.perf_counter()
        reps = 3
        for _ in range(reps):
            out = bcsr_spmm(ell, hj, bn=32)
        out.block_until_ready()
        us = (time.perf_counter() - t0) / reps * 1e6
        # TPU-side derived numbers: bytes moved vs MACs per segment
        flops = 2 * a.nnz * f
        bytes_moved = ell.nbytes() + h.nbytes + n * f * 4
        rows.append(
            f"kernel/bcsr_spmm/n{n}_f{f},{us:.1f},"
            f"flops={flops};bytes={bytes_moved};"
            f"intensity={flops/bytes_moved:.3f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
