"""Shared benchmark infrastructure.

All reproduction benches run the paper's datasets scaled by SCALE (CPU
container; printed in every CSV) with budgets expressed as the paper's
budget:requirement *ratios*, which preserves the out-of-core stress level
exactly. The I/O model uses the paper's hardware constants
(PAPER_GPU_SYSTEM); the roofline bench uses TPU v5e constants.
"""
from __future__ import annotations

import functools
import os
from typing import Dict, List

import numpy as np

from repro.core import FeatureSpec, SCHEDULERS, required_bytes
from repro.data import (
    SUITESPARSE_SPECS, generate_graph, normalized_adjacency, scaled_spec,
)
from repro.io.tiers import PAPER_GPU_SYSTEM
from repro.sparse.formats import CSR

# Dataset scale relative to the paper's full graphs. Overridable so the CI
# smoke job can run the full benchmark drivers on tiny configs
# (AIRES_BENCH_SCALE=1e-4) without a separate code path.
SCALE = float(os.environ.get("AIRES_BENCH_SCALE", "1e-3"))
FEATURE_DIM = 256          # paper §V-A
FEATURE_SPARSITY = 99.0    # paper §V-A


@functools.lru_cache(maxsize=None)
def dataset(name: str) -> CSR:
    spec = scaled_spec(SUITESPARSE_SPECS[name], SCALE)
    return normalized_adjacency(generate_graph(spec, seed=0))


def feature_spec(a: CSR, f: int = FEATURE_DIM) -> FeatureSpec:
    return FeatureSpec(a.n_rows, f, 4, sparsity_pct=FEATURE_SPARSITY)


def budget_for(name: str, a: CSR, feat: FeatureSpec,
               budget_gb: float = None) -> int:
    """Paper budget (GB) → scaled bytes via the budget:req ratio."""
    spec = SUITESPARSE_SPECS[name]
    gb = budget_gb if budget_gb is not None else spec.mem_constraint_gb
    return int(gb / spec.mem_req_gb * required_bytes(a, feat))


def run_sched(name: str, a: CSR, feat, budget: int, dataset_name: str = ""):
    return SCHEDULERS[name](PAPER_GPU_SYSTEM, device_budget=budget).run(
        a, feat, dataset=dataset_name)


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"
