"""§Roofline — three-term roofline per (arch × shape × mesh) from the
dry-run artifacts (results/dryrun/*.json).

  compute_s    = HLO_FLOPs_per_device / peak_FLOPs          (197 TF bf16)
  memory_s     = HLO_bytes_per_device / HBM_bw              (819 GB/s)
  collective_s = collective_bytes_per_device / link_bw      (~50 GB/s/link)

cost_analysis of the SPMD-partitioned module is per-device, so dividing by
per-chip peaks directly gives the per-step time lower bound each resource
imposes; the max of the three is the roofline bound and its argmax the
bottleneck. MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference), N = active
params for MoE; the MODEL/HLO ratio exposes remat/redundant compute.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import SHAPES, get_config
from repro.io.tiers import Path, TPU_V5E_SYSTEM

# Per-chip peaks sourced from the one TierSpec the whole repo prices
# against (repro.io.tiers.TPU_V5E_SYSTEM) — the same constants the
# autotuner's roofline cross-check reads, so the two can never drift.
PEAK_FLOPS = TPU_V5E_SYSTEM.peak_flops    # bf16 per chip
HBM_BW = TPU_V5E_SYSTEM.hbm_bw            # bytes/s per chip
ICI_BW = TPU_V5E_SYSTEM.bw[Path.ICI]      # bytes/s per link

def _default_results_dir() -> str:
    if os.environ.get("DRYRUN_DIR"):
        return os.environ["DRYRUN_DIR"]
    # prefer the optimized sweep; fall back to the baseline sweep
    return ("results/dryrun_final" if os.path.isdir("results/dryrun_final")
            else "results/dryrun")


RESULTS_DIR = _default_results_dir()


def _expert_params(cfg) -> int:
    if not cfg.is_moe:
        return 0
    return cfg.n_layers * 3 * cfg.d_model * (cfg.expert_d_ff or cfg.d_ff) \
        * cfg.n_experts


def model_flops(arch: str, shape_name: str, n_params: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    exp = _expert_params(cfg)
    n_active = n_params - exp + (exp * cfg.top_k // max(cfg.n_experts, 1)
                                 if cfg.is_moe else 0)
    if shape["kind"] == "train":
        tokens = shape["global_batch"] * shape["seq_len"]
        return 6.0 * n_active * tokens
    if shape["kind"] == "prefill":
        tokens = shape["global_batch"] * shape["seq_len"]
        return 2.0 * n_active * tokens
    tokens = shape["global_batch"]  # one new token per sequence
    return 2.0 * n_active * tokens


def analyze_cell(d: Dict) -> Optional[Dict]:
    if not d.get("ok"):
        return None
    chips = 512 if d["mesh"] == "2x16x16" else 256
    flops_dev = d.get("total_flops", d["cost"]["flops"])
    bytes_dev = d.get("total_bytes_accessed", d["cost"]["bytes_accessed"])
    coll_dev = d.get("total_collective_bytes", d["collectives"]["bytes"])
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(d["arch"], d["shape"], d["params"])
    hlo_global = flops_dev * chips
    return {
        "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
        "chips": chips,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "bottleneck": bottleneck,
        "bound_s": terms[bottleneck],
        "model_flops": mf, "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "roofline_fraction": (mf / chips / PEAK_FLOPS) / terms[bottleneck]
        if terms[bottleneck] else 0.0,
        "hbm_gib_per_chip": (d["memory"]["argument_bytes"]
                             + d["memory"]["temp_bytes"]) / 2**30,
    }


def load_cells(results_dir: str = RESULTS_DIR) -> List[Dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        row = analyze_cell(d)
        if row is None:
            cells.append({"arch": d.get("arch"), "shape": d.get("shape"),
                          "mesh": d.get("mesh"),
                          "skip": d.get("skipped", d.get("error", "?"))})
        else:
            cells.append(row)
    return cells


def run() -> List[str]:
    rows = ["# roofline terms per (arch x shape x mesh); seconds per step"]
    for c in load_cells():
        if "skip" in c:
            rows.append(f"roofline/{c['arch']}/{c['shape']}/{c['mesh']},0.0,"
                        f"SKIP:{str(c['skip'])[:60]}")
            continue
        rows.append(
            f"roofline/{c['arch']}/{c['shape']}/{c['mesh']},"
            f"{c['bound_s']*1e6:.1f},"
            f"compute={c['compute_s']:.4f}s;memory={c['memory_s']:.4f}s;"
            f"collective={c['collective_s']:.4f}s;bottleneck={c['bottleneck']};"
            f"useful_ratio={c['useful_ratio']:.3f};"
            f"roofline_frac={c['roofline_fraction']:.3f};"
            f"hbm_gib={c['hbm_gib_per_chip']:.2f}")
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
