"""Serving bench — round-based drains vs the continuous step loop.

Replays the same arrival traces (Poisson and Gamma-modulated bursty) through
two serving arms built on identical engines, budgets and modeled costs:

  * round       — ``replay_round``: arrivals are admitted only between full
                  ``run_batch`` drains, the engine's native cadence.
  * continuous  — ``replay_continuous``: a ``ContinuousServer`` admits between
                  every column-concat group and re-prioritizes per step.

Both arms share one virtual timeline whose unit is the modeled cost of a
single mid-width pass (``unit_cost_s``), so arrival rates and deadlines are
expressed in load units and the comparison is scale-invariant: the CI smoke
job runs the same driver at AIRES_BENCH_SCALE=1e-4.

Writes BENCH_serve.json: per-arm p50/p99 latency, goodput, deadline-miss
rate, and uploaded/cache-hit byte accounting.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Callable, Dict, List, Tuple

import numpy as np

from benchmarks.common import SCALE
from repro.core import EDFOrderingPass, plan_memory_dense_features
from repro.data import (
    SUITESPARSE_SPECS, generate_graph, normalized_adjacency, scaled_spec,
)
from repro.runtime import (
    ContinuousServer, EngineConfig, InferenceRequest, ServingEngine,
    VirtualClock, bursty_trace, poisson_trace, replay_continuous,
    replay_round, summarize,
)

# Two graphs with different stream profiles (power-law social vs near-planar
# road) so EDF group ordering has real choices to make. rUSA is held at 0.2×
# the socLJ1 scale to keep per-pass costs comparable.
GRAPHS: Dict[str, float] = {"socLJ1": 1.0, "rUSA": 0.2}
WIDTHS: Tuple[int, ...] = (16, 32, 48)   # heterogeneous request widths
HIDDEN = 16                              # single GCN layer, w -> HIDDEN
DEADLINE_UNITS = 3.0                     # deadline = 3x one mid-width pass
POISSON_RHO = 0.8                        # offered load, passes per unit time
BURSTY_RHO = 3.5
BURST_SHAPE = 0.25                       # Gamma shape: smaller = burstier
EPISODE = 16                             # arrivals per rate-modulation draw

ARM_KEYS = (
    "offered", "served", "on_time", "expired", "rejected", "deadline_misses",
    "deadline_miss_rate", "p50_latency_s", "p99_latency_s", "mean_latency_s",
    "goodput_rps", "makespan_s", "groups_served", "uploaded_bytes",
    "cache_hit_bytes", "promoted_bytes", "ici_bytes", "aggregation_passes",
)


def build_graphs():
    graphs = {}
    for name, mult in GRAPHS.items():
        spec = scaled_spec(SUITESPARSE_SPECS[name], SCALE * mult)
        graphs[name] = normalized_adjacency(generate_graph(spec, seed=0))
    return graphs


def serving_budget(graphs) -> int:
    """Big enough for any single graph's stream plan, small enough that the
    segment cache keeps mattering across graph switches."""
    budget = 0
    for a in graphs.values():
        est = plan_memory_dense_features(a, a.n_rows, 64, float("inf"))
        budget = max(budget, int(est.m_b + est.m_c + 0.6 * a.nbytes()))
    return budget


def make_engine(graphs, budget: int, clock: VirtualClock) -> ServingEngine:
    eng = ServingEngine(EngineConfig(
        device_budget_bytes=budget, clock=clock,
        plan_passes=[EDFOrderingPass(clock=clock)]))
    for name, a in graphs.items():
        eng.register_graph(name, a)
    return eng


def build_workload(graphs, seed: int):
    """Per-(graph, width) feature matrices + shared weights, and the
    Arrival -> InferenceRequest factory both arms use."""
    rng = np.random.default_rng(seed)
    feats = {(n, w): rng.standard_normal((a.n_rows, w)).astype(np.float32)
             for n, a in graphs.items() for w in WIDTHS}
    weights = {w: rng.standard_normal((w, HIDDEN)).astype(np.float32)
               for w in WIDTHS}

    def make_request(arr) -> InferenceRequest:
        return InferenceRequest(
            arr.graph, feats[(arr.graph, arr.feature_dim)],
            [weights[arr.feature_dim]], deadline_s=arr.deadline_s)

    return feats, weights, make_request


def probe_unit_cost(graphs, budget: int, feats, weights) -> float:
    """Modeled cost of one mid-width pass on the largest graph: the virtual
    time unit that rates and deadlines are quoted in."""
    probe = make_engine(graphs, budget, VirtualClock())
    mid = WIDTHS[len(WIDTHS) // 2]
    name = max(graphs, key=lambda n: graphs[n].n_rows)
    return probe.estimate_request_cost(
        InferenceRequest(name, feats[(name, mid)], [weights[mid]]))


def make_trace(kind: str, n: int, unit: float, graphs, seed: int):
    deadline = DEADLINE_UNITS * unit
    if kind == "poisson":
        return poisson_trace(
            n=n, rate_hz=POISSON_RHO / unit, graphs=sorted(graphs),
            seed=seed, feature_dim=WIDTHS, deadline_s=deadline)
    if kind == "bursty":
        return bursty_trace(
            n=n, base_rate_hz=BURSTY_RHO / unit, graphs=sorted(graphs),
            seed=seed, feature_dim=WIDTHS, deadline_s=deadline,
            burst_shape=BURST_SHAPE, episode=EPISODE)
    raise ValueError(f"unknown trace kind {kind!r}")


def run_trace(kind: str, n: int, seed: int, graphs, budget: int,
              make_request: Callable, unit: float) -> Dict[str, object]:
    trace = make_trace(kind, n, unit, graphs, seed)
    round_report = replay_round(
        make_engine(graphs, budget, VirtualClock()), trace, make_request)
    cont_report = replay_continuous(
        ContinuousServer(make_engine(graphs, budget, VirtualClock())),
        trace, make_request)
    rho = POISSON_RHO if kind == "poisson" else BURSTY_RHO
    return {
        "trace": {
            "kind": kind, "requests": n, "seed": seed,
            "offered_load_rho": rho,
            "deadline_units": DEADLINE_UNITS,
            "widths": list(WIDTHS),
            "burst_shape": BURST_SHAPE if kind == "bursty" else None,
            "episode": EPISODE if kind == "bursty" else None,
        },
        "arms": {
            "round": summarize(round_report),
            "continuous": summarize(cont_report),
        },
    }


def validate_report(report: Dict[str, object]) -> None:
    """Schema check for BENCH_serve.json (used by the CI smoke job)."""
    for key in ("scale", "unit_cost_s", "requests", "seed", "traces"):
        assert key in report, f"missing top-level key {key!r}"
    assert report["traces"], "no traces recorded"
    for entry in report["traces"]:
        assert set(entry) == {"trace", "arms"}, sorted(entry)
        assert entry["trace"]["kind"] in ("poisson", "bursty")
        assert set(entry["arms"]) == {"round", "continuous"}
        for arm, summary in entry["arms"].items():
            missing = [k for k in ARM_KEYS if k not in summary]
            assert not missing, f"{arm} arm missing {missing}"
            for k in ARM_KEYS:
                assert isinstance(summary[k], (int, float)), (arm, k)
            assert summary["offered"] == entry["trace"]["requests"]


def _jsonable(obj):
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    return obj


def run(traces: List[str], n: int, seed: int) -> Dict[str, object]:
    graphs = build_graphs()
    budget = serving_budget(graphs)
    feats, weights, make_request = build_workload(graphs, seed)
    unit = probe_unit_cost(graphs, budget, feats, weights)
    report = {
        "scale": SCALE,
        "unit_cost_s": unit,
        "requests": n,
        "seed": seed,
        "traces": [run_trace(kind, n, seed, graphs, budget, make_request, unit)
                   for kind in traces],
    }
    return _jsonable(report)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--traces", default="poisson,bursty",
                    help="comma-separated subset of {poisson,bursty}")
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    kinds = [k.strip() for k in args.traces.split(",") if k.strip()]
    report = run(kinds, args.requests, args.seed)
    validate_report(report)
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
        fh.write("\n")

    for entry in report["traces"]:
        kind = entry["trace"]["kind"]
        for arm in ("round", "continuous"):
            s = entry["arms"][arm]
            print(f"{kind:8s} {arm:10s} p50={s['p50_latency_s']:.3e}s "
                  f"p99={s['p99_latency_s']:.3e}s "
                  f"miss={s['deadline_misses']}/{s['offered']} "
                  f"goodput={s['goodput_rps']:.1f}rps "
                  f"uploaded={s['uploaded_bytes']} "
                  f"cache_hit={s['cache_hit_bytes']}")
    print(f"wrote {args.out} (scale={SCALE})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
