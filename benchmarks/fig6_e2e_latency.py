"""Fig. 6 — end-to-end per-epoch latency, AIRES vs baselines, 5 datasets.

Paper claim: AIRES averages 1.8× / 1.7× / 1.5× over MaxMemory / UCG / ETC.
Per-epoch = forward + backward streaming cycles of the layer chain
(gcn_epoch with 2 hidden layers, backward_factor=2).
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import (
    FEATURE_DIM, SCALE, budget_for, csv_row, dataset, feature_spec,
)
from repro.core import gcn_epoch
from repro.io.tiers import PAPER_GPU_SYSTEM

DATASETS = ["rUSA", "kV2a", "kU1a", "socLJ1", "kP1a"]
SCHEDS = ["maxmemory", "ucg", "etc", "aires"]


def run() -> List[str]:
    rows = [f"# fig6 per-epoch latency (scale={SCALE})"]
    speedups = {s: [] for s in SCHEDS if s != "aires"}
    for name in DATASETS:
        a = dataset(name)
        feat = feature_spec(a)
        budget = budget_for(name, a, feat)
        spans = {}
        for sched in SCHEDS:
            em = gcn_epoch(a, feat, [np.zeros((FEATURE_DIM, FEATURE_DIM))] * 2,
                           sched, PAPER_GPU_SYSTEM, budget, dataset=name)
            spans[sched] = em.epoch_makespan_s
        for sched in SCHEDS:
            sp = spans[sched] / spans["aires"]
            if sched != "aires":
                speedups[sched].append(sp)
            rows.append(csv_row(
                f"fig6/{name}/{sched}", spans[sched] * 1e6,
                f"speedup_vs_aires_inverse={sp:.2f}"))
    for sched, v in speedups.items():
        rows.append(csv_row(f"fig6/avg/{sched}", 0.0,
                            f"aires_speedup={np.mean(v):.2f}"
                            f";paper={'1.8' if sched=='maxmemory' else '1.7' if sched=='ucg' else '1.5'}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
