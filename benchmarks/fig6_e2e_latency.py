"""Fig. 6 — end-to-end per-epoch latency, AIRES vs baselines, 5 datasets.

Paper claim: AIRES averages 1.8× / 1.7× / 1.5× over MaxMemory / UCG / ETC.
Per-epoch = forward + backward streaming cycles of the layer chain.

Two accountings share `gcn_epoch`:
  * simulate (this file's sweep): backward modeled as backward_factor=2×
    the forward stream — the paper's §V-A accounting at full dataset scale.
  * execute (--execute): a real forward+backward pass through the
    differentiable AiresSpGEMM engine on a further-scaled graph — the
    backward genuinely streams the transposed RoBW plan; the CSV reports
    streamed segments and wire bytes per phase.
"""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import (
    FEATURE_DIM, SCALE, budget_for, csv_row, dataset, feature_spec,
)
from repro.core import gcn_epoch
from repro.io.tiers import PAPER_GPU_SYSTEM

DATASETS = ["rUSA", "kV2a", "kU1a", "socLJ1", "kP1a"]
SCHEDS = ["maxmemory", "ucg", "etc", "aires"]


def run() -> List[str]:
    rows = [f"# fig6 per-epoch latency (scale={SCALE})"]
    speedups = {s: [] for s in SCHEDS if s != "aires"}
    for name in DATASETS:
        a = dataset(name)
        feat = feature_spec(a)
        budget = budget_for(name, a, feat)
        spans = {}
        for sched in SCHEDS:
            em = gcn_epoch(a, feat, [np.zeros((FEATURE_DIM, FEATURE_DIM))] * 2,
                           sched, PAPER_GPU_SYSTEM, budget, dataset=name,
                           mode="simulate", backward_factor=2.0)
            spans[sched] = em.epoch_makespan_s
        for sched in SCHEDS:
            sp = spans[sched] / spans["aires"]
            if sched != "aires":
                speedups[sched].append(sp)
            rows.append(csv_row(
                f"fig6/{name}/{sched}", spans[sched] * 1e6,
                f"speedup_vs_aires_inverse={sp:.2f}"))
    for sched, v in speedups.items():
        rows.append(csv_row(f"fig6/avg/{sched}", 0.0,
                            f"aires_speedup={np.mean(v):.2f}"
                            f";paper={'1.8' if sched=='maxmemory' else '1.7' if sched=='ucg' else '1.5'}"))
    return rows


def run_execute(scale_down: float = 0.05) -> List[str]:
    """Real fwd+bwd epoch on a reduced graph: per-phase streamed accounting.

    The graphs are scaled a further `scale_down` below SCALE: execute mode
    runs the Pallas kernel in interpret mode on CPU, so this is a
    correctness/accounting artifact, not a latency measurement.
    """
    from repro.core import AiresConfig
    from repro.data import (
        SUITESPARSE_SPECS, generate_graph, normalized_adjacency, scaled_spec,
    )

    rows = ["# fig6 execute-mode epoch (real forward+backward streaming)"]
    for name in DATASETS[:2]:
        a = normalized_adjacency(generate_graph(
            scaled_spec(SUITESPARSE_SPECS[name], SCALE * scale_down), seed=0))
        n = a.n_rows
        rng = np.random.default_rng(0)
        f = 32
        h0 = rng.standard_normal((n, f)).astype(np.float32)
        ws = [rng.standard_normal((f, f)).astype(np.float32)] * 2
        budget = int((a.nbytes() + 3 * h0.nbytes) * 0.7) + (1 << 16)
        em = gcn_epoch(
            a, h0, ws, "aires", PAPER_GPU_SYSTEM, budget, mode="execute",
            dataset=name,
            engine_config=AiresConfig(device_budget_bytes=budget, bm=8, bk=8))
        fwd_segs = sum(s.segments for s in em.forward_stream)
        bwd_segs = sum(s.segments for s in em.backward_stream)
        fwd_bytes = sum(s.uploaded_bytes for s in em.forward_stream)
        bwd_bytes = sum(s.uploaded_bytes for s in em.backward_stream)
        rows.append(csv_row(
            f"fig6exec/{name}/aires", em.wall_seconds * 1e3,
            f"fwd_segments={fwd_segs};bwd_segments={bwd_segs};"
            f"fwd_bytes={fwd_bytes};bwd_bytes={bwd_bytes}"))
    return rows


if __name__ == "__main__":
    import sys
    out = run_execute() if "--execute" in sys.argv else run()
    print("\n".join(out))
