"""Evolving-graph bench — delta updates vs evict-and-reregister.

After warming a serving engine's segment cache on one graph, applies edge
deltas of growing size k through two arms built on identical engines,
budgets and plans:

  * delta — ``ServingEngine.update_graph``: prepared plans migrate
            incrementally (only touched row blocks re-tile), and exactly
            the stale segment keys are invalidated. The post-update epoch
            re-streams precisely ``retiled_bytes``.
  * full  — the pre-ISSUE-7 recipe: ``evict_graph`` + ``register_graph``
            with the updated CSR. Every brick re-tiles and the post-update
            epoch re-streams the whole wire footprint.

Edge lists nest (delta k uses the first k edges of one shuffled pool), so
the delta arm's touched-row set — and its re-tiled byte count — grows
monotonically with k while the full arm stays flat at the graph's total
wire bytes: update cost scales with the delta, not the graph.

Writes BENCH_update.json: per-k segments re-tiled/reused, re-tiled bytes,
post-update and warm-epoch uploads, and update wall time for both arms.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import SCALE, dataset
from repro.core import plan_memory_dense_features
from repro.runtime import EngineConfig, InferenceRequest, ServingEngine
from repro.sparse import apply_edge_updates

GRAPH = "socLJ1"
WIDTH = 32                 # request feature width
HIDDEN = 16                # single GCN layer, WIDTH -> HIDDEN
DELTA_SIZES = (1, 4, 16, 64)
A_FRAC = 0.15              # graph fraction resident -> several segments

ARM_KEYS = (
    "edges_changed", "rows_touched", "segments_total", "segments_retiled",
    "segments_reused", "retiled_bytes", "uploaded_after_bytes",
    "cache_hit_after_bytes", "warm_after_bytes", "update_seconds",
)


def serving_budget(a) -> int:
    est = plan_memory_dense_features(a, a.n_rows, WIDTH, float("inf"))
    return int(est.m_b + est.m_c + A_FRAC * a.nbytes())


def make_engine(a, budget: int) -> ServingEngine:
    eng = ServingEngine(EngineConfig(device_budget_bytes=budget,
                                     max_batch_features=WIDTH))
    eng.register_graph("g", a)
    return eng


def build_workload(a, seed: int):
    rng = np.random.default_rng(seed)
    h = rng.standard_normal((a.n_rows, WIDTH)).astype(np.float32)
    w = [rng.standard_normal((WIDTH, HIDDEN)).astype(np.float32)]
    return h, w


def edge_pool(a, seed: int, n: int) -> List[tuple]:
    """One shuffled pool of distinct (row, col, value) edges; delta k uses
    the first k, so touched-row sets nest as k grows."""
    rng = np.random.default_rng(seed + 1)
    seen, pool = set(), []
    while len(pool) < n:
        r = int(rng.integers(a.n_rows))
        c = int(rng.integers(a.shape[1]))
        if (r, c) in seen:
            continue
        seen.add((r, c))
        pool.append((r, c, float(rng.standard_normal())))
    return pool


def epoch(eng: ServingEngine, h, w):
    eng.submit(InferenceRequest("g", h, w))
    return eng.run_batch()


def run_delta_arm(a, budget: int, h, w, edges) -> Dict[str, object]:
    eng = make_engine(a, budget)
    epoch(eng, h, w)                       # cold: tile + upload everything
    epoch(eng, h, w)                       # warm: cache fully resident
    rep = eng.update_graph("g", inserts=edges)
    after = epoch(eng, h, w)
    warm = epoch(eng, h, w)
    return {
        "edges_changed": rep.delta.n_changed,
        "rows_touched": int(rep.delta.touched_rows.size),
        "segments_total": rep.segments_retiled + rep.segments_reused,
        "segments_retiled": rep.segments_retiled,
        "segments_reused": rep.segments_reused,
        "retiled_bytes": rep.retiled_bytes,
        "uploaded_after_bytes": after.uploaded_bytes,
        "cache_hit_after_bytes": after.cache_hit_bytes,
        "warm_after_bytes": warm.uploaded_bytes,
        "update_seconds": rep.wall_seconds,
    }


def run_full_arm(a, budget: int, h, w, edges) -> Dict[str, object]:
    eng = make_engine(a, budget)
    epoch(eng, h, w)
    epoch(eng, h, w)
    t0 = time.perf_counter()
    new, delta = apply_edge_updates(a, inserts=edges)
    eng.evict_graph("g")
    eng.register_graph("g", new)
    update_s = time.perf_counter() - t0
    after = epoch(eng, h, w)               # re-tiles + re-uploads everything
    warm = epoch(eng, h, w)
    n_segments = after.segments_streamed // max(1, after.aggregation_passes)
    return {
        "edges_changed": delta.n_changed,
        "rows_touched": int(delta.touched_rows.size),
        "segments_total": n_segments,
        "segments_retiled": n_segments,
        "segments_reused": 0,
        "retiled_bytes": after.uploaded_bytes,
        "uploaded_after_bytes": after.uploaded_bytes,
        "cache_hit_after_bytes": after.cache_hit_bytes,
        "warm_after_bytes": warm.uploaded_bytes,
        "update_seconds": update_s,
    }


def validate_report(report: Dict[str, object]) -> None:
    """Schema + acceptance check for BENCH_update.json (CI smoke job)."""
    for key in ("scale", "graph", "seed", "deltas"):
        assert key in report, f"missing top-level key {key!r}"
    for key in ("name", "n_rows", "nnz", "segments", "wire_total_bytes"):
        assert key in report["graph"], f"graph missing {key!r}"
    deltas = report["deltas"]
    assert deltas, "no delta sizes recorded"
    prev_retiled = -1
    for i, entry in enumerate(deltas):
        assert set(entry) == {"k", "arms"}, sorted(entry)
        assert set(entry["arms"]) == {"delta", "full"}
        for arm, summary in entry["arms"].items():
            missing = [k for k in ARM_KEYS if k not in summary]
            assert not missing, f"{arm} arm missing {missing}"
            for k in ARM_KEYS:
                assert isinstance(summary[k], (int, float)), (arm, k)
        d, f = entry["arms"]["delta"], entry["arms"]["full"]
        # The post-update epoch re-streams exactly the re-tiled bricks,
        # untouched bricks keep hitting, and the next epoch is free.
        assert d["uploaded_after_bytes"] == d["retiled_bytes"], entry["k"]
        assert d["warm_after_bytes"] == 0, entry["k"]
        assert f["warm_after_bytes"] == 0, entry["k"]
        # Delta cost never exceeds the full re-register, and is strictly
        # below it at the smallest k (the headline acceptance criterion).
        assert d["uploaded_after_bytes"] <= f["uploaded_after_bytes"], \
            entry["k"]
        if i == 0:
            assert d["uploaded_after_bytes"] < f["uploaded_after_bytes"], (
                "delta arm must beat evict-and-reregister at small k")
            assert d["segments_reused"] > 0
        # Nested edge pools: re-tiled bytes grow monotonically with k —
        # cost tracks the delta, not the graph.
        assert d["retiled_bytes"] >= prev_retiled, entry["k"]
        prev_retiled = d["retiled_bytes"]


def _jsonable(obj):
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    return obj


def run(delta_sizes, seed: int) -> Dict[str, object]:
    a = dataset(GRAPH)
    budget = serving_budget(a)
    h, w = build_workload(a, seed)
    pool = edge_pool(a, seed, max(delta_sizes))

    probe = make_engine(a, budget)
    cold = epoch(probe, h, w)
    n_segments = cold.segments_streamed // max(1, cold.aggregation_passes)

    report = {
        "scale": SCALE,
        "graph": {
            "name": GRAPH, "n_rows": a.n_rows, "nnz": a.nnz,
            "segments": n_segments, "wire_total_bytes": cold.uploaded_bytes,
        },
        "seed": seed,
        "deltas": [
            {"k": k, "arms": {
                "delta": run_delta_arm(a, budget, h, w, pool[:k]),
                "full": run_full_arm(a, budget, h, w, pool[:k]),
            }}
            for k in delta_sizes
        ],
    }
    return _jsonable(report)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--deltas", default=",".join(map(str, DELTA_SIZES)),
                    help="comma-separated edge-delta sizes")
    ap.add_argument("--seed", type=int, default=5)
    ap.add_argument("--out", default="BENCH_update.json")
    args = ap.parse_args(argv)

    sizes = sorted({int(k) for k in args.deltas.split(",") if k.strip()})
    report = run(sizes, args.seed)
    validate_report(report)
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
        fh.write("\n")

    g = report["graph"]
    print(f"graph {g['name']}: {g['n_rows']} rows, {g['nnz']} nnz, "
          f"{g['segments']} segments, wire={g['wire_total_bytes']}")
    for entry in report["deltas"]:
        d, f = entry["arms"]["delta"], entry["arms"]["full"]
        print(f"k={entry['k']:4d} delta: retiled={d['segments_retiled']}"
              f"/{d['segments_total']} segs "
              f"uploaded={d['uploaded_after_bytes']} "
              f"({d['update_seconds']*1e3:.1f}ms)  "
              f"full: uploaded={f['uploaded_after_bytes']} "
              f"({f['update_seconds']*1e3:.1f}ms)")
    print(f"wrote {args.out} (scale={SCALE})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
