"""Fig. 7 — GPU↔CPU I/O breakdown (DMA + UM traffic only, as the paper
counts CUDA memcpy/UM ops; GDS traffic is *not* GPU-CPU and is excluded).

Paper claim: AIRES cuts transferred bytes by up to 84.2 % (kA2a, vs
MaxMemory) and both bytes and latency by ~70–75 % vs ETC on kV1r.
"""
from __future__ import annotations

from typing import List

from benchmarks.common import (
    SCALE, budget_for, csv_row, dataset, feature_spec, run_sched,
)

DATASETS = ["rUSA", "kV2a", "kU1a", "socLJ1", "kP1a", "kA2a", "kV1r"]
SCHEDS = ["maxmemory", "ucg", "etc", "aires"]


def _dma_um(metrics) -> tuple:
    b = sum(v for k, v in metrics.bytes_by_path.items() if k in ("dma", "um"))
    s = sum(v for k, v in metrics.seconds_by_path.items() if k in ("dma", "um"))
    return b, s


def run() -> List[str]:
    rows = [f"# fig7 GPU-CPU I/O breakdown (scale={SCALE})"]
    for name in DATASETS:
        a = dataset(name)
        feat = feature_spec(a)
        budget = budget_for(name, a, feat)
        base_bytes = None
        for sched in SCHEDS:
            m = run_sched(sched, a, feat, budget, name).metrics
            if m.oom:
                rows.append(csv_row(f"fig7/{name}/{sched}", 0.0, "OOM"))
                continue
            b, s = _dma_um(m)
            if sched == "maxmemory":
                base_bytes = b
            red = (f";reduction_vs_maxmem={100 * (1 - b / base_bytes):.1f}%"
                   if base_bytes and sched != "maxmemory" else "")
            rows.append(csv_row(
                f"fig7/{name}/{sched}", s * 1e6,
                f"dma_um_bytes={b}{red}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
