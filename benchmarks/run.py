"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows for:
  fig3  merge overhead            (paper Fig. 3)
  fig6  per-epoch e2e latency     (paper Fig. 6)
  fig7  GPU-CPU I/O breakdown     (paper Fig. 7)
  fig8  storage-tier bandwidth    (paper Fig. 8)
  fig9  feature-size ablation     (paper Fig. 9)
  tableIII memory ablation        (paper Table III)
  roofline (§Roofline, from dry-run artifacts when present)
  kernel microbench
"""
from __future__ import annotations

import traceback

from benchmarks import (
    fig3_merge_overhead,
    fig6_e2e_latency,
    fig7_io_breakdown,
    fig8_bandwidth,
    fig9_feature_ablation,
    tableiii_memory_ablation,
    roofline,
    kernel_bench,
)

MODULES = [
    fig3_merge_overhead,
    fig6_e2e_latency,
    fig7_io_breakdown,
    fig8_bandwidth,
    fig9_feature_ablation,
    tableiii_memory_ablation,
    roofline,
    kernel_bench,
]


def main() -> None:
    print("name,us_per_call,derived")
    for mod in MODULES:
        try:
            for row in mod.run():
                print(row)
        except Exception as err:  # noqa: BLE001
            print(f"{mod.__name__},0.0,ERROR:{type(err).__name__}:{err}")
            traceback.print_exc()


if __name__ == "__main__":
    main()
