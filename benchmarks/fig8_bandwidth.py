"""Fig. 8 — GPU/CPU↔SSD bandwidth utilization: dual-way (GDS + PCIe) vs
single-path baselines.

Paper claim: the dual-way path strategy raises storage-tier bandwidth
utilization across all datasets because GDS and PCIe channels run
concurrently (Fig. 5 Phase I).
"""
from __future__ import annotations

from typing import List

from benchmarks.common import (
    SCALE, budget_for, csv_row, dataset, feature_spec, run_sched,
)

DATASETS = ["rUSA", "kV2a", "kU1a", "socLJ1", "kP1a", "kA2a", "kV1r"]


def run() -> List[str]:
    rows = [f"# fig8 storage-tier bandwidth (scale={SCALE})"]
    for name in DATASETS:
        a = dataset(name)
        feat = feature_spec(a)
        budget = budget_for(name, a, feat)
        for sched in ("etc", "aires"):
            m = run_sched(sched, a, feat, budget, name).metrics
            if m.oom:
                rows.append(csv_row(f"fig8/{name}/{sched}", 0.0, "OOM"))
                continue
            storage_bytes = sum(
                v for k, v in m.bytes_by_path.items() if k in ("gds", "sio"))
            storage_secs = max(
                (v for k, v in m.seconds_by_path.items()
                 if k in ("gds", "sio")), default=0.0)  # channels overlap
            eff_bw = storage_bytes / max(storage_secs, 1e-12) / 1e9
            rows.append(csv_row(
                f"fig8/{name}/{sched}", storage_secs * 1e6,
                f"effective_storage_bw_gbps={eff_bw:.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
