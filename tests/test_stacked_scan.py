"""Scan-over-layers path: exact equivalence with the unrolled reference and
decode-state round trips for every arch."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import arch_ids, get_config
from repro.models import init_params, forward
from repro.models.stacked import (
    decode_step_scan, forward_scan, group_split, init_decode_state_stacked,
    init_params_stacked, lm_loss_scan,
)

KEY = jax.random.PRNGKey(0)
B, S = 2, 8


def _inputs(cfg):
    kw = {}
    if cfg.n_vision_tokens:
        kw["vision_embeds"] = jax.random.normal(
            KEY, (B, cfg.n_vision_tokens, cfg.d_model))
    if cfg.is_enc_dec:
        kw["audio_embeds"] = jax.random.normal(
            KEY, (B, cfg.audio_frames, cfg.d_model))
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    return tokens, kw


@pytest.mark.parametrize("arch", arch_ids())
def test_scan_equals_unrolled(arch):
    cfg = get_config(arch, smoke=True)
    tokens, kw = _inputs(cfg)
    l1, _ = forward(cfg, init_params(cfg, KEY), tokens, **kw)
    l2, _ = forward_scan(cfg, init_params_stacked(cfg, KEY), tokens, **kw)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               atol=2e-4, rtol=1e-4)


@pytest.mark.parametrize("arch", arch_ids())
def test_scan_decode_jits(arch):
    cfg = get_config(arch, smoke=True)
    sparams = init_params_stacked(cfg, KEY)
    state = init_decode_state_stacked(cfg, B, 16)
    enc_out = (jnp.zeros((B, cfg.audio_frames, cfg.d_model))
               if cfg.is_enc_dec else None)
    step = jax.jit(lambda p, t, st: decode_step_scan(cfg, p, t, st,
                                                     enc_out=enc_out))
    tok = jnp.zeros((B, 1), jnp.int32)
    for _ in range(2):
        logits, state = step(sparams, tok, state)
        assert not np.isnan(np.asarray(logits)).any()
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["gemma2_27b", "recurrentgemma_2b",
                                  "xlstm_125m"])
def test_scan_loss_grads_finite(arch):
    cfg = get_config(arch, smoke=True)
    sparams = init_params_stacked(cfg, KEY)
    tokens, kw = _inputs(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: lm_loss_scan(cfg, p, tokens, tokens, **kw))(sparams)
    assert np.isfinite(float(loss))
    for g in jax.tree_util.tree_leaves(grads):
        assert np.isfinite(np.asarray(g)).all()


def test_group_split_covers_all_layers():
    for arch in arch_ids():
        cfg = get_config(arch)
        from repro.models.stacked import unit_kinds
        r, rem = group_split(cfg)
        assert r * len(unit_kinds(cfg)) + rem == cfg.n_layers
