"""GCN model: in-core vs out-of-core equivalence and training."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.gcn_paper import SMOKE
from repro.core import AiresConfig, AiresSpGEMM
from repro.data import SUITESPARSE_SPECS, generate_graph, normalized_adjacency, scaled_spec
from repro.models import gcn_forward, gcn_init, gcn_loss
from repro.sparse import csr_to_dense
from repro.train import make_optimizer


def _setup():
    a = normalized_adjacency(
        generate_graph(scaled_spec(SUITESPARSE_SPECS["rUSA"], 1e-5), seed=2))
    rng = np.random.default_rng(0)
    h0 = jnp.asarray(rng.standard_normal(
        (a.n_rows, SMOKE.feature_dim)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, SMOKE.n_classes, size=(a.n_rows,)))
    return a, h0, labels


def test_out_of_core_matches_in_core():
    a, h0, labels = _setup()
    params = gcn_init(SMOKE, jax.random.PRNGKey(0))
    a_dense = jnp.asarray(csr_to_dense(a))
    budget = int((a.nbytes() + 3 * h0.nbytes) * 0.6)
    engine = AiresSpGEMM(AiresConfig(device_budget_bytes=budget, bm=8, bk=8))
    y_ic = gcn_forward(SMOKE, params, a_dense, h0)
    import dataclasses
    cfg_ooc = dataclasses.replace(SMOKE, out_of_core=True)
    y_ooc = gcn_forward(cfg_ooc, params, a, h0, engine=engine)
    np.testing.assert_allclose(np.asarray(y_ic), np.asarray(y_ooc),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.slow
def test_gcn_training_converges():
    a, h0, labels = _setup()
    params = gcn_init(SMOKE, jax.random.PRNGKey(0))
    a_dense = jnp.asarray(csr_to_dense(a))
    init_opt, opt_update = make_optimizer("adamw", lr=1e-2)
    opt = init_opt(params)

    @jax.jit
    def step(params, opt):
        loss, grads = jax.value_and_grad(
            lambda p: gcn_loss(SMOKE, p, a_dense, h0, labels))(params)
        params, opt = opt_update(params, grads, opt)
        return loss, params, opt

    l0 = None
    for s in range(150):
        loss, params, opt = step(params, opt)
        if l0 is None:
            l0 = float(loss)
    assert float(loss) < 0.5 * l0
