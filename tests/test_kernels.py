"""Per-kernel correctness sweeps: Pallas (interpret=True) vs ref.py oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import bcsr_spmm, decode_attention, fused_gcn_layer
from repro.kernels.ref import decode_attention_ref
from repro.sparse import csr_from_dense, tile_csr_to_block_ell


def _rand_sparse(n, m, density, dtype, seed):
    rng = np.random.default_rng(seed)
    dense = ((rng.random((n, m)) < density)
             * rng.standard_normal((n, m))).astype(dtype)
    return dense


@pytest.mark.parametrize("n,m,f", [(16, 16, 8), (40, 24, 16), (64, 64, 32),
                                   (33, 57, 24)])
@pytest.mark.parametrize("density", [0.05, 0.3])
def test_bcsr_spmm_shapes(n, m, f, density):
    dense = _rand_sparse(n, m, density, np.float32, seed=n * m + f)
    a = csr_from_dense(dense)
    ell = tile_csr_to_block_ell(a, bm=8, bk=8)
    h = np.random.default_rng(1).standard_normal((m, f)).astype(np.float32)
    out = np.asarray(bcsr_spmm(ell, jnp.asarray(h), bn=8))
    np.testing.assert_allclose(out, dense @ h, atol=1e-4)


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_bcsr_spmm_dtypes(dtype):
    dense = _rand_sparse(32, 32, 0.2, np.float32, seed=7).astype(dtype)
    a = csr_from_dense(dense)
    ell = tile_csr_to_block_ell(a, bm=8, bk=8, dtype=dtype)
    h = np.random.default_rng(2).standard_normal((32, 16)).astype(dtype)
    out = np.asarray(bcsr_spmm(ell, jnp.asarray(h), bn=8))
    np.testing.assert_allclose(
        out, dense.astype(np.float32) @ h.astype(np.float32),
        atol=1e-2 if dtype == np.float16 else 1e-4)


def test_bcsr_spmm_empty_rows():
    dense = np.zeros((24, 24), np.float32)
    dense[3, 5] = 2.0  # single nonzero
    a = csr_from_dense(dense)
    ell = tile_csr_to_block_ell(a, bm=8, bk=8)
    h = np.ones((24, 8), np.float32)
    out = np.asarray(bcsr_spmm(ell, jnp.asarray(h), bn=8))
    np.testing.assert_allclose(out, dense @ h, atol=1e-5)


@pytest.mark.parametrize("n,f,fo", [(24, 16, 8), (40, 24, 16)])
def test_fused_gcn_layer(n, f, fo):
    dense = _rand_sparse(n, n, 0.2, np.float32, seed=n)
    a = csr_from_dense(dense)
    ell = tile_csr_to_block_ell(a, bm=8, bk=8)
    rng = np.random.default_rng(5)
    h = rng.standard_normal((n, f)).astype(np.float32)
    w = rng.standard_normal((f, fo)).astype(np.float32)
    b = rng.standard_normal((fo,)).astype(np.float32)
    out = np.asarray(fused_gcn_layer(ell, jnp.asarray(h), jnp.asarray(w),
                                     jnp.asarray(b)))
    ref = np.maximum(dense @ h @ w + b, 0)
    np.testing.assert_allclose(out, ref, atol=1e-3)


@pytest.mark.parametrize("b,nq,nkv,s,d", [
    (2, 8, 2, 64, 16), (1, 4, 4, 32, 8), (3, 16, 4, 48, 32),
])
def test_decode_attention(b, nq, nkv, s, d):
    rng = np.random.default_rng(b * s)
    q = rng.standard_normal((b, nq, d)).astype(np.float32)
    k = rng.standard_normal((b, nkv, s, d)).astype(np.float32)
    v = rng.standard_normal((b, nkv, s, d)).astype(np.float32)
    lens = rng.integers(1, s + 1, size=(b,)).astype(np.int32)
    out = np.asarray(decode_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(lens),
        block_s=16))
    ref = np.asarray(decode_attention_ref(
        q.reshape(b, nkv, nq // nkv, d), k, v, lens)).reshape(b, nq, d)
    np.testing.assert_allclose(out, ref, atol=1e-4)


def test_decode_attention_full_vs_short_lens():
    """Padding KV past `lens` must not change the result."""
    rng = np.random.default_rng(0)
    b, nq, nkv, s, d = 2, 4, 2, 32, 16
    q = rng.standard_normal((b, nq, d)).astype(np.float32)
    k = rng.standard_normal((b, nkv, s, d)).astype(np.float32)
    v = rng.standard_normal((b, nkv, s, d)).astype(np.float32)
    lens = np.array([10, 20], np.int32)
    out1 = np.asarray(decode_attention(jnp.asarray(q), jnp.asarray(k),
                                       jnp.asarray(v), jnp.asarray(lens),
                                       block_s=8))
    k2 = k.copy(); v2 = v.copy()
    k2[:, :, 25:] = 999.0; v2[:, :, 25:] = -999.0  # poison beyond lens
    out2 = np.asarray(decode_attention(jnp.asarray(q), jnp.asarray(k2),
                                       jnp.asarray(v2), jnp.asarray(lens),
                                       block_s=8))
    np.testing.assert_allclose(out1, out2, atol=1e-5)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 24), (False, 0)])
@pytest.mark.parametrize("b,h,s,d", [(2, 3, 64, 16), (1, 2, 48, 32)])
def test_flash_attention(b, h, s, d, causal, window):
    from repro.kernels import flash_attention
    from repro.kernels.ref import flash_attention_ref
    rng = np.random.default_rng(b * s + d)
    q = rng.standard_normal((b, h, s, d)).astype(np.float32)
    k = rng.standard_normal((b, h, s, d)).astype(np.float32)
    v = rng.standard_normal((b, h, s, d)).astype(np.float32)
    out = np.asarray(flash_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        causal=causal, window=window, block_q=16, block_k=16))
    ref = np.asarray(flash_attention_ref(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        causal=causal, window=window))
    np.testing.assert_allclose(out, ref, atol=1e-4)


def test_flash_attention_dtype_bf16():
    from repro.kernels import flash_attention
    from repro.kernels.ref import flash_attention_ref
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 2, 32, 16)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 2, 32, 16)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 2, 32, 16)), jnp.bfloat16)
    out = flash_attention(q, k, v, block_q=16, block_k=16)
    ref = flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2)
