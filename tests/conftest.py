"""Shared fixtures + markers for the AIRES test suite.

Tier split (see README "Testing"):
  * fast tier — `pytest -m "not slow"`: runs on every PR.
  * full tier — `pytest`: runs on main; adds the long streaming/training sweeps.

The `slow` marker is registered here (and in pyproject.toml) so the fast
subset never warns on unknown markers.
"""
import os
import sys

import numpy as np
import pytest

# The golden-equality tests reuse the benchmark configs (benchmarks.common
# builds the fig6 datasets/budgets); the benchmarks package lives at the
# repo root, which is not on sys.path when only PYTHONPATH=src is set.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running test (excluded from the PR-tier fast subset)")


@pytest.fixture(autouse=True, scope="session")
def _analyze_plans_by_default():
    """Static plan analysis is on for the whole suite: every plan any test
    interprets or streams is checked by `repro.core.analysis` first, and an
    error-severity finding raises `PlanAnalysisError`. Production keeps the
    default off; tests that deliberately interpret a broken plan opt out
    with `analyze=False`."""
    from repro.core import analysis

    previous = analysis.set_default_analyze(True)
    yield
    analysis.set_default_analyze(previous)


@pytest.fixture(scope="session")
def make_sparse():
    """Factory for small random sparse matrices: (CSR, dense) pairs.

    Deterministic per (n, m, density, seed) so session-scoped reuse is safe.
    """
    from repro.sparse import csr_from_dense

    def _make(n, m, density=0.2, seed=0, dtype=np.float32):
        rng = np.random.default_rng(seed)
        dense = ((rng.random((n, m)) < density)
                 * rng.standard_normal((n, m))).astype(dtype)
        return csr_from_dense(dense), dense

    return _make


@pytest.fixture(scope="session")
def paper_graph():
    """A scaled paper dataset adjacency (normalized), shared across modules."""
    from repro.data import (
        SUITESPARSE_SPECS, generate_graph, normalized_adjacency, scaled_spec,
    )

    spec = scaled_spec(SUITESPARSE_SPECS["kV2a"], 2e-4)
    a = normalized_adjacency(generate_graph(spec, seed=3))
    a.validate()
    return a


@pytest.fixture(scope="session")
def paper_feats(paper_graph):
    rng = np.random.default_rng(0)
    return rng.standard_normal((paper_graph.n_rows, 16)).astype(np.float32)
