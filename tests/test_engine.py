"""Serving-engine behaviour: multi-graph batching, epoch-over-epoch cache
reuse, the cache-off ablation, and the simulate↔execute byte cross-check.

The headline assertions mirror ISSUE 2's acceptance criteria:
  * batched multi-graph inference is exact vs the dense reference chain;
  * on the quickstart graph, epoch 2 uploads ≤ 50 % of epoch 1's wire bytes
    with the cache on (in fact: zero), and strictly fewer bytes generally;
  * cache_enabled=False reproduces the PR-1 AiresSpGEMM behavior exactly —
    same outputs, same uploaded_bytes, no epoch-2 improvement;
  * AiresScheduler(mode="simulate") Phase II DMA in `bytes_by_path` agrees
    with AiresSpGEMM execute-mode `uploaded_bytes` once both plan with the
    same per-segment budget — the model is locked to reality.
"""
import json
import os
import time

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import (
    AiresConfig, AiresSpGEMM, SCHEDULERS, plan_memory_dense_features,
)
from repro.io import CacheDirectory, ShardedSegmentCache, TieredSegmentCache
from repro.io.tiers import PAPER_GPU_SYSTEM
from repro.runtime import (
    AdmissionError, EngineConfig, InferenceRequest, ServingEngine,
)
from repro.sparse.ref_spgemm import spgemm_csr_dense


@pytest.fixture(scope="module")
def quickstart_graph():
    """The examples/quickstart.py graph (socLJ1 scaled for CPU)."""
    from repro.data import (
        SUITESPARSE_SPECS, generate_graph, normalized_adjacency, scaled_spec,
    )

    a = normalized_adjacency(generate_graph(
        scaled_spec(SUITESPARSE_SPECS["socLJ1"], 1e-4), seed=0))
    a.validate()
    return a


@pytest.fixture(scope="module")
def road_graph():
    from repro.data import (
        SUITESPARSE_SPECS, generate_graph, normalized_adjacency, scaled_spec,
    )

    return normalized_adjacency(generate_graph(
        scaled_spec(SUITESPARSE_SPECS["rUSA"], 2e-5), seed=1))


def _budget(a, width=64, a_frac=0.6):
    """Feasible for the serving engine's pinned plan width, but small enough
    to force ≥2 streamed segments."""
    est = plan_memory_dense_features(a, a.n_rows, width, float("inf"))
    return int(est.m_b + est.m_c + a_frac * a.nbytes())


def _engine(a, **overrides):
    kw = dict(device_budget_bytes=_budget(a), max_batch_features=64)
    kw.update(overrides)
    return ServingEngine(EngineConfig(**kw))


def _reference_chain(a, h, weights):
    h = np.asarray(h, dtype=np.float32)
    if not weights:
        return spgemm_csr_dense(a, h)
    for layer, w in enumerate(weights):
        x = spgemm_csr_dense(a, h)
        h = x @ np.asarray(w, dtype=np.float32)
        if layer < len(weights) - 1:
            h = np.maximum(h, 0.0)
    return h


# ---- multi-graph batching correctness ------------------------------------

def test_multi_graph_batch_matches_dense_reference(quickstart_graph,
                                                   road_graph):
    rng = np.random.default_rng(0)
    g1, g2 = quickstart_graph, road_graph
    eng = _engine(g1, device_budget_bytes=max(_budget(g1), _budget(g2)))
    eng.register_graph("lj", g1)
    eng.register_graph("road", g2)

    cases = [
        ("lj", rng.standard_normal((g1.n_rows, 16)).astype(np.float32),
         [rng.standard_normal((16, 8)).astype(np.float32),
          rng.standard_normal((8, 4)).astype(np.float32)]),
        ("lj", rng.standard_normal((g1.n_rows, 24)).astype(np.float32), []),
        ("road", rng.standard_normal((g2.n_rows, 32)).astype(np.float32),
         [rng.standard_normal((32, 8)).astype(np.float32)]),
    ]
    rids = [eng.submit(InferenceRequest(g, h, ws)) for g, h, ws in cases]
    report = eng.run_batch()
    assert len(report.results) == len(cases)
    # the two same-width-round "lj" requests share one streamed pass
    assert report.aggregation_passes < sum(max(len(ws), 1)
                                           for _, _, ws in cases)
    outs = {r.request_id: r.output for r in report.results}
    graphs = {"lj": g1, "road": g2}
    for rid, (gname, h, ws) in zip(rids, cases):
        np.testing.assert_allclose(
            outs[rid], _reference_chain(graphs[gname], h, ws),
            atol=1e-3, rtol=1e-3)


def test_submit_validates_graph_and_shape(quickstart_graph):
    eng = _engine(quickstart_graph)
    eng.register_graph("g", quickstart_graph)
    with pytest.raises(KeyError):
        eng.submit(InferenceRequest("nope", np.zeros((4, 4), np.float32)))
    with pytest.raises(ValueError):
        eng.submit(InferenceRequest("g", np.zeros((3, 4), np.float32)))
    with pytest.raises(ValueError):
        eng.register_graph("g", quickstart_graph)


def test_infer_does_not_drain_other_queued_requests(quickstart_graph):
    rng = np.random.default_rng(7)
    a = quickstart_graph
    eng = _engine(a)
    eng.register_graph("g", a)
    h_queued = rng.standard_normal((a.n_rows, 8)).astype(np.float32)
    rid = eng.submit(InferenceRequest("g", h_queued))
    h_now = rng.standard_normal((a.n_rows, 8)).astype(np.float32)
    out_now = eng.infer("g", h_now)
    np.testing.assert_allclose(out_now, _reference_chain(a, h_now, []),
                               atol=1e-4)
    # the queued request survived infer() and still runs
    report = eng.run_batch()
    assert [r.request_id for r in report.results] == [rid]
    np.testing.assert_allclose(report.results[0].output,
                               _reference_chain(a, h_queued, []), atol=1e-4)


def test_evict_graph_returns_orphans_and_drops_cache(quickstart_graph):
    rng = np.random.default_rng(8)
    a = quickstart_graph
    eng = _engine(a)
    eng.register_graph("g", a)
    eng.infer("g", rng.standard_normal((a.n_rows, 8)).astype(np.float32))
    assert len(eng.cache) > 0
    rid = eng.submit(InferenceRequest(
        "g", rng.standard_normal((a.n_rows, 8)).astype(np.float32)))
    orphans = eng.evict_graph("g")
    assert [r.request_id for r in orphans] == [rid]
    assert len(eng.cache) == 0, "eviction must drop every cached namespace"
    assert eng.run_batch().results == []  # queue is clean, nothing dropped


def test_promoted_bytes_surface_in_stream_stats(quickstart_graph):
    """A warm epoch served by host-tier promotions must not read as free:
    StreamStats.promoted_bytes carries the re-crossing bytes."""
    rng = np.random.default_rng(9)
    a = quickstart_graph
    h = rng.standard_normal((a.n_rows, 16)).astype(np.float32)
    budget = _budget(a, width=16)
    tiny = TieredSegmentCache(device_budget_bytes=1)  # everything spills
    eng = AiresSpGEMM(AiresConfig(device_budget_bytes=budget, bm=8, bk=8),
                      segment_cache=tiny)
    eng(a, jnp.asarray(h))
    cold = eng.last_stream_stats
    eng(a, jnp.asarray(h))
    warm = eng.last_stream_stats
    assert cold.promoted_bytes == 0
    assert warm.uploaded_bytes == 0
    assert warm.promoted_bytes == warm.cache_hit_bytes == cold.uploaded_bytes


# ---- the acceptance criterion: epoch 2 uploads ≤ 50 % --------------------

def test_second_epoch_uploads_drop_on_quickstart_graph(quickstart_graph):
    rng = np.random.default_rng(1)
    a = quickstart_graph
    eng = _engine(a)
    eng.register_graph("lj", a)
    h = rng.standard_normal((a.n_rows, 32)).astype(np.float32)
    w = [rng.standard_normal((32, 16)).astype(np.float32)]

    reports = []
    for _ in range(2):
        eng.submit(InferenceRequest("lj", h, w))
        reports.append(eng.run_batch())
    first, second = reports
    assert first.uploaded_bytes > 0
    assert second.uploaded_bytes < first.uploaded_bytes
    assert second.uploaded_bytes <= first.uploaded_bytes // 2, (
        "epoch 2 must upload at most half of epoch 1's wire bytes")
    assert second.cache_hit_bytes == first.uploaded_bytes
    # same answer both times
    np.testing.assert_allclose(first.results[0].output,
                               second.results[0].output, atol=1e-6)


def test_epoch2_exact_under_cache_demotion_pressure(quickstart_graph):
    """A device tier too small for the whole plan forces demote/promote
    round-trips mid-stream; outputs must stay exact."""
    rng = np.random.default_rng(2)
    a = quickstart_graph
    h = rng.standard_normal((a.n_rows, 16)).astype(np.float32)

    probe = _engine(a)
    probe.register_graph("lj", a)
    ref = probe.infer("lj", h)
    wire_total = (probe.cache_stats().hit_bytes
                  + probe.cache_stats().miss_bytes)

    eng = _engine(a, cache_device_bytes=max(1, wire_total // 3))
    eng.register_graph("lj", a)
    out1 = eng.infer("lj", h)
    out2 = eng.infer("lj", h)
    np.testing.assert_allclose(out1, ref, atol=1e-6)
    np.testing.assert_allclose(out2, ref, atol=1e-6)
    stats = eng.cache_stats()
    assert stats.demoted_bytes > 0, "pressure test must actually demote"
    assert stats.host_hits > 0, "epoch 2 should be served by promotions"


# ---- cache-off ablation reproduces PR-1 ----------------------------------

def test_cache_off_reproduces_pr1_engine_exactly(quickstart_graph):
    rng = np.random.default_rng(3)
    a = quickstart_graph
    f = 32
    h = rng.standard_normal((a.n_rows, f)).astype(np.float32)
    budget = _budget(a, width=f)

    # PR-1 path: bare AiresSpGEMM, no cache, plan at the actual width.
    pr1 = AiresSpGEMM(AiresConfig(device_budget_bytes=budget, bm=8, bk=8))
    x_pr1 = np.asarray(pr1(a, jnp.asarray(h)))
    pr1_bytes = pr1.last_stream_stats.uploaded_bytes

    # Serving engine, cache off, pinned width == actual width.
    eng = ServingEngine(EngineConfig(device_budget_bytes=budget,
                                     cache_enabled=False,
                                     max_batch_features=f))
    eng.register_graph("lj", a)
    assert eng.cache is None and eng.cache_stats() is None
    reports = []
    for _ in range(2):
        eng.submit(InferenceRequest("lj", h))
        reports.append(eng.run_batch())
    np.testing.assert_array_equal(reports[0].results[0].output, x_pr1)
    for rep in reports:
        assert rep.uploaded_bytes == pr1_bytes
        assert rep.cache_hit_bytes == 0
    assert reports[1].uploaded_bytes == reports[0].uploaded_bytes, (
        "without the cache, every epoch re-streams every byte — PR-1")


# ---- simulate ↔ execute cross-check (locks the model to reality) ---------

def test_simulate_bytes_by_path_matches_execute_uploaded_bytes(
        quickstart_graph):
    """Same graph, same per-segment budget, same wire format: the modeled
    Phase II DMA bytes must equal the real streamed upload bytes."""
    rng = np.random.default_rng(4)
    a = quickstart_graph
    f = 32
    h = rng.standard_normal((a.n_rows, f)).astype(np.float32)
    budget = _budget(a, width=f)

    engine = AiresSpGEMM(AiresConfig(device_budget_bytes=budget, bm=8, bk=8))
    engine(a, jnp.asarray(h))
    real = engine.last_stream_stats

    # Same budget on both sides: the unified Eq. 5 planner gives the
    # scheduler and the engine identical MemoryEstimates for dense features,
    # hence identical RoBW partitions — the pre-unification equal-m_a
    # scaffolding is gone.
    sched_budget = budget
    sched = SCHEDULERS["aires"](PAPER_GPU_SYSTEM, device_budget=sched_budget,
                                wire_format="bricks", bm=8, bk=8)
    res = sched.run(a, h, mode="simulate")
    assert not res.metrics.oom
    assert res.metrics.segments == real.segments
    modeled_dma = res.metrics.bytes_by_path.get("dma", 0)
    assert modeled_dma == pytest.approx(real.uploaded_bytes, rel=0.02), (
        "simulate-mode DMA bytes diverged from executed upload bytes")

    # ...and the agreement holds warm: with a shared cache large enough to
    # hold the whole plan device-side, both sides drop their epoch-2 wire
    # traffic to zero. (An undersized device tier would instead show the
    # demote/promote DMA churn in bytes_by_path — also honest, not tested
    # here.)
    cache = TieredSegmentCache(device_budget_bytes=4 * modeled_dma)
    cached_sched = SCHEDULERS["aires"](
        PAPER_GPU_SYSTEM, device_budget=sched_budget,
        wire_format="bricks", bm=8, bk=8, segment_cache=cache)
    cold = cached_sched.run(a, h, mode="simulate").metrics
    warm = cached_sched.run(a, h, mode="simulate").metrics
    assert cold.bytes_by_path.get("dma", 0) == modeled_dma
    assert warm.bytes_by_path.get("dma", 0) == 0
    assert warm.cache_hit_bytes == modeled_dma

    cached_engine = AiresSpGEMM(
        AiresConfig(device_budget_bytes=budget, bm=8, bk=8),
        segment_cache=TieredSegmentCache(device_budget_bytes=4 * modeled_dma))
    cached_engine(a, jnp.asarray(h))
    cached_engine(a, jnp.asarray(h))
    assert cached_engine.last_stream_stats.uploaded_bytes == 0
    assert (cached_engine.last_stream_stats.cache_hit_bytes
            == real.uploaded_bytes)


# ---- sharded serving (ISSUE 3 tentpole) ----------------------------------

def _wire_total(a, h):
    """Total wire bytes of one streamed pass at h's width (probe run)."""
    probe = _engine(a)
    probe.register_graph("lj", a)
    probe.infer("lj", h)
    return probe.cache_stats().hit_bytes + probe.cache_stats().miss_bytes


def test_sharded_two_worker_warm_epoch_acceptance(quickstart_graph):
    """The ISSUE acceptance scenario: 4 cache shards, two replicated
    workers sharing a CacheDirectory, device tier too small for the plan.
    Warm epoch: zero wire uploads, promoted/remote bytes ride ICI, and the
    directory spares at least one duplicate demotion copy."""
    rng = np.random.default_rng(11)
    a = quickstart_graph
    h = rng.standard_normal((a.n_rows, 32)).astype(np.float32)
    w = [rng.standard_normal((32, 16)).astype(np.float32)]
    ref = _reference_chain(a, h, w)
    wire_total = _wire_total(a, h)

    directory = CacheDirectory()
    workers = [
        ServingEngine(
            EngineConfig(device_budget_bytes=_budget(a),
                         cache_device_bytes=max(4, wire_total // 2),
                         cache_shards=4, worker_id=wid),
            directory=directory)
        for wid in (0, 1)
    ]
    for eng in workers:
        assert isinstance(eng.cache, ShardedSegmentCache)
        assert eng.cache.n_shards == 4
        eng.register_graph("lj", a)

    cold, warm = [], []
    for epoch_reports in (cold, warm):
        for eng in workers:
            eng.submit(InferenceRequest("lj", h, w))
            epoch_reports.append(eng.run_batch())
    for rep in cold + warm:
        np.testing.assert_allclose(rep.results[0].output, ref,
                                   atol=1e-3, rtol=1e-3)

    assert cold[0].uploaded_bytes > 0
    # Worker 1's cold epoch already benefits from worker 0's demotions: its
    # own demotions find the directory populated.
    assert sum(r.duplicate_avoided_bytes for r in cold + warm) > 0, \
        "directory must spare at least one duplicate demotion copy"
    for rep in warm:
        assert rep.uploaded_bytes == 0, \
            "warm epoch must not re-stream any wire bytes"
        assert rep.cache_hit_bytes == wire_total
        assert rep.ici_bytes > 0, \
            "remote-shard traffic must ride the ICI path"
    stats = workers[0].cache_stats()
    assert stats.remote_hits > 0 and stats.ici_bytes > 0


def test_evict_graph_unpublishes_directory_holdings(quickstart_graph):
    """Regression (ISSUE 7 satellite): `evict_graph` dropped the local
    cache but left the evicting worker's CacheDirectory records behind —
    peers could be routed a peer-promote for host copies the worker no
    longer backs. Eviction now drops exactly that worker's holdings under
    the graph prefix; a peer's own records survive."""
    from repro.core import AiresSpGEMM
    from repro.io import prefix_matches

    rng = np.random.default_rng(13)
    a = quickstart_graph
    h = rng.standard_normal((a.n_rows, 32)).astype(np.float32)
    wire_total = _wire_total(a, h)

    directory = CacheDirectory()
    workers = [
        ServingEngine(
            EngineConfig(device_budget_bytes=_budget(a),
                         cache_device_bytes=max(4, wire_total // 2),
                         cache_shards=4, worker_id=wid),
            directory=directory)
        for wid in (0, 1)
    ]
    for eng in workers:
        eng.register_graph("lj", a)
        eng.submit(InferenceRequest("lj", h))
        eng.run_batch()

    prefix = AiresSpGEMM.graph_cache_prefix(a)
    held_by_0 = [k for k in directory._entries
                 if prefix_matches(k.graph_id, prefix)
                 and directory.holder(k) == 0]
    assert held_by_0, "demotion pressure must have published host copies"

    workers[0].evict_graph("lj")
    for key in held_by_0:
        assert directory.holder(key) is None, (
            "evicting worker's directory records must be unpublished")
    leftovers = [k for k in directory._entries
                 if prefix_matches(k.graph_id, prefix)]
    assert all(directory.holder(k) == 1 for k in leftovers), (
        "only the peer's own holdings may survive worker 0's evict")
    # Worker 1 keeps serving correctly: the bricks it deduplicated against
    # worker 0's now-gone host copies re-upload (no dangling peer-promote),
    # and the answer is still exact.
    workers[1].submit(InferenceRequest("lj", h))
    rep = workers[1].run_batch()
    assert rep.directory_hit_bytes == 0, (
        "no peer-promote may be served from the evicted worker's records")
    np.testing.assert_allclose(rep.results[0].output,
                               _reference_chain(a, h, []), atol=1e-3,
                               rtol=1e-3)


def test_one_shard_directory_off_matches_pr2_bitexactly(quickstart_graph):
    """A 1-shard ShardedSegmentCache with no directory must reproduce the
    PR-2 TieredSegmentCache BatchReport byte accounting bit-exactly —
    including under demotion pressure."""
    rng = np.random.default_rng(12)
    a = quickstart_graph
    h = rng.standard_normal((a.n_rows, 16)).astype(np.float32)
    wire_total = _wire_total(a, h)
    pressure = max(4, wire_total // 3)

    reports = {}
    for flavor in ("tiered", "sharded1"):
        eng = _engine(a, cache_device_bytes=pressure)
        if flavor == "sharded1":
            # swap in the 1-shard sharded tier before any graph binds to it
            eng.cache = ShardedSegmentCache(
                device_budget_bytes=pressure, n_shards=1)
        eng.register_graph("lj", a)
        reps = []
        for _ in range(2):
            eng.submit(InferenceRequest("lj", h))
            reps.append(eng.run_batch())
        reports[flavor] = reps
    for pr2, one in zip(reports["tiered"], reports["sharded1"]):
        assert one.uploaded_bytes == pr2.uploaded_bytes
        assert one.cache_hit_bytes == pr2.cache_hit_bytes
        assert one.promoted_bytes == pr2.promoted_bytes
        assert one.bus_bytes == pr2.bus_bytes
        assert one.segments_streamed == pr2.segments_streamed
        assert one.aggregation_passes == pr2.aggregation_passes
        assert one.ici_bytes == 0
        assert one.directory_hit_bytes == pr2.directory_hit_bytes == 0
        assert one.duplicate_avoided_bytes == 0
        np.testing.assert_array_equal(pr2.results[0].output,
                                      one.results[0].output)


def test_engine_rejects_contradictory_sharding_config():
    budget = 1 << 20
    # cache features demanded while the cache is off -> error, not silence
    with pytest.raises(ValueError, match="cache_enabled=False"):
        ServingEngine(EngineConfig(device_budget_bytes=budget,
                                   cache_enabled=False),
                      directory=CacheDirectory())
    # two replicas on one directory must carry distinct worker ids
    directory = CacheDirectory()
    ServingEngine(EngineConfig(device_budget_bytes=budget, worker_id=0),
                  directory=directory)
    with pytest.raises(ValueError, match="worker_id"):
        ServingEngine(EngineConfig(device_budget_bytes=budget, worker_id=0),
                      directory=directory)


def test_serving_engine_over_real_mesh(quickstart_graph):
    """ServingEngine(mesh=...) builds the sharded cache from a real device
    mesh; exercised with >1 devices in the CI sharded job."""
    import jax

    if jax.device_count() < 4:
        pytest.skip("needs >=4 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    from repro.launch.mesh import make_cache_mesh

    rng = np.random.default_rng(13)
    a = quickstart_graph
    h = rng.standard_normal((a.n_rows, 16)).astype(np.float32)
    mesh = make_cache_mesh(4)
    eng = ServingEngine(EngineConfig(device_budget_bytes=_budget(a)),
                        mesh=mesh)
    assert isinstance(eng.cache, ShardedSegmentCache)
    assert eng.cache.devices is not None
    eng.register_graph("lj", a)
    out1 = eng.infer("lj", h)
    out2 = eng.infer("lj", h)
    ref = _reference_chain(a, h, [])
    np.testing.assert_allclose(out1, ref, atol=1e-4)
    np.testing.assert_allclose(out2, ref, atol=1e-4)
    stats = eng.cache_stats()
    assert stats.remote_hits > 0, \
        "second pass must hit bricks owned by remote chips"


# ---- execute interpreter bit-exact with the PR-3 BatchReports --------------

def _report_fields(rep):
    return {
        "uploaded_bytes": rep.uploaded_bytes,
        "cache_hit_bytes": rep.cache_hit_bytes,
        "promoted_bytes": rep.promoted_bytes,
        "segments_streamed": rep.segments_streamed,
        "aggregation_passes": rep.aggregation_passes,
        "ici_bytes": rep.ici_bytes,
        "directory_hit_bytes": rep.directory_hit_bytes,
        "duplicate_avoided_bytes": rep.duplicate_avoided_bytes,
    }


def test_batch_reports_bitexact_with_prerefactor_golden(quickstart_graph):
    """ISSUE 4 acceptance: the execute-interpreter serving path reproduces
    the pre-refactor (PR 3) BatchReport byte accounting exactly — cache on,
    cache off, and 4-shard × 2 workers, two epochs each (frozen in
    tests/data/golden_pipeline.json)."""
    with open(os.path.join(os.path.dirname(__file__), "data",
                           "golden_pipeline.json")) as f:
        golden = json.load(f)["engine"]
    a = quickstart_graph
    rng = np.random.default_rng(1)
    h = rng.standard_normal((a.n_rows, 32)).astype(np.float32)
    w = [rng.standard_normal((32, 16)).astype(np.float32)]
    budget = _budget(a)

    for label, kw, nworkers in [("cache_on", {}, 1),
                                ("cache_off", {"cache_enabled": False}, 1),
                                ("shard4", {"cache_shards": 4}, 2)]:
        directory = CacheDirectory() if nworkers > 1 else None
        workers = [
            ServingEngine(EngineConfig(device_budget_bytes=budget,
                                       max_batch_features=64,
                                       worker_id=wid, **kw),
                          directory=directory)
            for wid in range(nworkers)
        ]
        for eng in workers:
            eng.register_graph("lj", a)
        reports = []
        for _epoch in range(2):
            for eng in workers:
                eng.submit(InferenceRequest("lj", h, w))
                reports.append(eng.run_batch())
        for i, (got, want) in enumerate(zip(reports, golden[label])):
            assert _report_fields(got) == want, (label, i)


# ---- admission control (ISSUE 4 satellite) ---------------------------------

def test_submit_estimates_request_cost(quickstart_graph):
    a = quickstart_graph
    eng = _engine(a, max_queue_cost_s=1e9)
    eng.register_graph("g", a)
    h = np.zeros((a.n_rows, 16), np.float32)
    one = eng.estimate_request_cost(InferenceRequest("g", h))
    two = eng.estimate_request_cost(InferenceRequest(
        "g", h, weights=[np.zeros((16, 16), np.float32)] * 2))
    assert one > 0
    # a 2-layer request costs two streamed passes
    assert two == pytest.approx(2 * one)
    rid = eng.submit(InferenceRequest("g", h))
    assert eng._queue[0].request_id == rid
    assert eng._queue[0].estimated_cost_s == pytest.approx(one)
    assert eng.queued_cost_s() == pytest.approx(one)


def test_submit_skips_pricing_without_admission_policy(quickstart_graph):
    """No deadline and no queue cap → submit() must not pay for plan
    preparation (the pre-admission submit latency)."""
    a = quickstart_graph
    eng = _engine(a)
    eng.register_graph("g", a)
    eng.submit(InferenceRequest("g", np.zeros((a.n_rows, 16), np.float32)))
    assert eng._queue[0].estimated_cost_s == 0.0
    assert eng._pass_costs == {}, "no estimate should have been memoized"


def test_infeasible_deadline_rejected_at_submit(quickstart_graph):
    a = quickstart_graph
    eng = _engine(a)
    eng.register_graph("g", a)
    h = np.zeros((a.n_rows, 16), np.float32)
    with pytest.raises(AdmissionError) as exc:
        eng.submit(InferenceRequest("g", h, deadline_s=1e-15))
    assert exc.value.decision.reason == "deadline-infeasible"
    assert eng.run_batch().rejected[0].reason == "deadline-infeasible"
    # a realistic deadline is admitted and served
    rid = eng.submit(InferenceRequest("g", h, deadline_s=60.0))
    rep = eng.run_batch()
    assert [r.request_id for r in rep.results] == [rid]
    assert rep.rejected == [] and rep.expired == []


def test_queue_cost_cap_rejects_overflow(quickstart_graph):
    a = quickstart_graph
    probe = _engine(a)
    probe.register_graph("g", a)
    h = np.zeros((a.n_rows, 16), np.float32)
    unit = probe.estimate_request_cost(InferenceRequest("g", h))

    eng = _engine(a, max_queue_cost_s=1.5 * unit)
    eng.register_graph("g", a)
    eng.submit(InferenceRequest("g", h))
    with pytest.raises(AdmissionError) as exc:
        eng.submit(InferenceRequest("g", h))
    assert exc.value.decision.reason == "queue-full"
    rep = eng.run_batch()
    assert len(rep.results) == 1
    assert [d.reason for d in rep.rejected] == ["queue-full"]
    # the drain freed the queue budget: the next submit is admitted
    eng.submit(InferenceRequest("g", h))
    assert len(eng.run_batch().results) == 1


def test_expired_requests_dropped_not_run(quickstart_graph):
    a = quickstart_graph
    eng = _engine(a)
    eng.register_graph("g", a)
    h = np.zeros((a.n_rows, 16), np.float32)
    rid_expired = eng.submit(InferenceRequest("g", h, deadline_s=0.03))
    rid_live = eng.submit(InferenceRequest("g", h))
    time.sleep(0.08)
    rep = eng.run_batch()
    assert [r.request_id for r in rep.results] == [rid_live]
    assert [d.request_id for d in rep.expired] == [rid_expired]
    assert rep.expired[0].reason == "deadline-expired"


# ---- warm start from checkpointed bricks (ISSUE 4 satellite) ---------------

def test_warm_start_restores_cache_and_charges_tms(quickstart_graph,
                                                   tmp_path):
    a = quickstart_graph
    rng = np.random.default_rng(21)
    h = rng.standard_normal((a.n_rows, 32)).astype(np.float32)
    w = [rng.standard_normal((32, 16)).astype(np.float32)]

    donor = _engine(a)
    donor.register_graph("lj", a)
    donor.submit(InferenceRequest("lj", h, w))
    cold = donor.run_batch()
    assert cold.uploaded_bytes > 0
    donor.checkpoint_cache(str(tmp_path))

    # A *fresh* engine (fresh cache, fresh process in production — keys are
    # content-addressed so they survive): warm-start, then first batch.
    fresh = _engine(a)
    fresh.register_graph("lj", a)
    ws = fresh.warm_start(str(tmp_path))
    assert ws.bricks > 0
    assert ws.wire_bytes == cold.uploaded_bytes
    assert ws.modeled_seconds > 0
    # honesty: the warm-start load shows up on the engine's tms paths
    by_path = {p.value: b for p, b in fresh.tms.bytes_by_path().items()}
    assert by_path.get("sio", 0) >= ws.wire_bytes   # storage → host
    assert by_path.get("dma", 0) >= ws.wire_bytes   # host → device

    fresh.submit(InferenceRequest("lj", h, w))
    first = fresh.run_batch()
    assert first.uploaded_bytes == 0, \
        "warm-started first epoch must not re-stream wire bytes"
    assert first.cache_hit_bytes == cold.uploaded_bytes
    np.testing.assert_array_equal(first.results[0].output,
                                  cold.results[0].output)


def test_warm_start_requires_cache(quickstart_graph, tmp_path):
    eng = _engine(quickstart_graph, cache_enabled=False)
    with pytest.raises(ValueError, match="cache_enabled"):
        eng.warm_start(str(tmp_path))
    with pytest.raises(ValueError, match="cache_enabled"):
        eng.checkpoint_cache(str(tmp_path))


def test_warm_start_empty_directory_is_noop(quickstart_graph, tmp_path):
    eng = _engine(quickstart_graph)
    eng.register_graph("g", quickstart_graph)
    ws = eng.warm_start(str(tmp_path))
    assert (ws.bricks, ws.wire_bytes) == (0, 0)


def test_checkpoint_cache_coexists_with_training_checkpoints(
        quickstart_graph, tmp_path):
    """Brick checkpoints live in their own subdirectory: pointing
    checkpoint_cache at a directory holding training checkpoints must
    neither prune them nor let warm_start misread them."""
    import os

    from repro.checkpoint import Checkpointer

    a = quickstart_graph
    ckpt = Checkpointer(str(tmp_path))
    ckpt.save(100, params={"layer0": {"w": np.ones((2, 2))}},
              opt_state={"m": np.zeros(2)})

    eng = _engine(a)
    eng.register_graph("g", a)
    eng.infer("g", np.zeros((a.n_rows, 16), np.float32))
    eng.checkpoint_cache(str(tmp_path))  # default step=0 < training step

    # the training checkpoint survived the brick save's keep_last=1 prune
    assert os.path.isdir(tmp_path / "step_100")
    restored, step = ckpt.restore({"params": {"layer0": {"w": None}},
                                  "opt_state": {"m": None}})
    assert step == 100
    np.testing.assert_array_equal(restored["params"]["layer0"]["w"],
                                  np.ones((2, 2)))

    fresh = _engine(a)
    fresh.register_graph("g", a)
    assert fresh.warm_start(str(tmp_path)).bricks > 0


def test_load_segment_bricks_ignores_foreign_checkpoints(tmp_path):
    """A directory that only holds a training checkpoint yields no bricks
    (not a crash on its nested param keys)."""
    from repro.checkpoint import Checkpointer, load_segment_bricks

    Checkpointer(str(tmp_path)).save(
        3, params={"layer0": {"w": np.ones((2, 2))}}, opt_state={})
    assert load_segment_bricks(str(tmp_path)) == []


# ---- gcn_epoch passthrough -----------------------------------------------

def test_gcn_epoch_simulate_accepts_segment_cache(quickstart_graph):
    from repro.core import FeatureSpec, gcn_epoch, required_bytes

    a = quickstart_graph
    feat = FeatureSpec(a.n_rows, 64, 4, sparsity_pct=99.0)
    budget = int(0.9 * required_bytes(a, feat))
    cache = TieredSegmentCache(device_budget_bytes=budget)
    weights = [np.zeros((64, 64))] * 2
    base = gcn_epoch(a, feat, weights, "aires", PAPER_GPU_SYSTEM, budget)
    assert sum(m.cache_hit_bytes for m in base.per_layer) == 0
    # Same-width layers share a plan, so even the cold epoch's second layer
    # hits; the warm epoch hits everywhere.
    cold = gcn_epoch(a, feat, weights, "aires", PAPER_GPU_SYSTEM, budget,
                     segment_cache=cache)
    warm = gcn_epoch(a, feat, weights, "aires", PAPER_GPU_SYSTEM, budget,
                     segment_cache=cache)
    assert warm.epoch_makespan_s < base.epoch_makespan_s
    assert warm.epoch_makespan_s <= cold.epoch_makespan_s
    assert sum(m.cache_hit_bytes for m in warm.per_layer) > 0


# ---- admission/report-accounting bugfixes (ISSUE 6 satellites) -----------

def test_infer_after_queue_expiry_raises_admission_error(quickstart_graph):
    """infer() whose own request expires before the internal batch runs
    must raise an AdmissionError naming the expiry — not leak a bare
    StopIteration out of a result search."""
    a = quickstart_graph
    calls = {"n": 0}

    def clock():
        # First read stamps submit(); every later read (run_batch's
        # prepare_queue) lands far past the 60 s relative deadline.
        calls["n"] += 1
        return 0.0 if calls["n"] == 1 else 1e9

    eng = _engine(a, clock=clock)
    eng.register_graph("g", a)
    h = np.random.default_rng(0).standard_normal(
        (a.n_rows, 8)).astype(np.float32)
    with pytest.raises(AdmissionError) as ei:
        eng.infer("g", h, deadline_s=60.0)
    assert ei.value.decision.reason == "deadline-expired"
    assert eng._queue == [] and eng._rejected == []


def test_infer_preserves_foreign_admission_verdicts(quickstart_graph):
    """Rejection verdicts from *other* callers' submits must survive an
    interleaved infer() and surface in the next real BatchReport instead
    of vanishing into the private report infer() discards."""
    rng = np.random.default_rng(1)
    a = quickstart_graph
    probe = _engine(a)
    probe.register_graph("g", a)
    h = [rng.standard_normal((a.n_rows, 8)).astype(np.float32)
         for _ in range(3)]
    est = probe.estimate_request_cost(InferenceRequest("g", h[0]))
    # Room for one queued request (est <= cap) but not two (2*est > cap).
    eng = _engine(a, max_queue_cost_s=1.5 * est)
    eng.register_graph("g", a)
    rid = eng.submit(InferenceRequest("g", h[0]))
    with pytest.raises(AdmissionError):
        eng.submit(InferenceRequest("g", h[1]))      # queue-full verdict
    out = eng.infer("g", h[2])                       # interleaved caller
    np.testing.assert_allclose(out, _reference_chain(a, h[2], []), atol=1e-4)
    report = eng.run_batch()
    assert [r.request_id for r in report.results] == [rid]
    assert [v.reason for v in report.rejected] == ["queue-full"]


def test_run_batch_leaves_caller_requests_unmutated(quickstart_graph):
    """Queue preparation prices/stamps engine-side copies; the caller's
    own InferenceRequest objects stay untouched."""
    rng = np.random.default_rng(2)
    a = quickstart_graph
    eng = _engine(a)
    eng.register_graph("g", a)
    submitted = InferenceRequest(
        "g", rng.standard_normal((a.n_rows, 8)).astype(np.float32),
        deadline_s=120.0)
    eng.submit(submitted)
    direct = InferenceRequest(
        "g", rng.standard_normal((a.n_rows, 8)).astype(np.float32))
    eng._queue.append(direct)                        # e.g. an orphan re-queue
    report = eng.run_batch()
    assert len(report.results) == 2
    assert submitted.estimated_cost_s == 0.0
    assert submitted.submitted_s == -1.0
    assert submitted.request_id == -1
    assert direct.estimated_cost_s == 0.0
    assert direct.submitted_s == -1.0


def test_direct_requeue_deadline_not_instantly_expired(quickstart_graph):
    """A deadline-bearing request that reaches the queue without passing
    submit() (submitted_s still the -1.0 sentinel) is stamped on first
    sight, not expired against the monotonic epoch."""
    rng = np.random.default_rng(3)
    a = quickstart_graph
    eng = _engine(a, clock=lambda: 1e6)   # epoch far beyond any deadline
    eng.register_graph("g", a)
    eng._queue.append(InferenceRequest(
        "g", rng.standard_normal((a.n_rows, 8)).astype(np.float32),
        deadline_s=60.0))
    report = eng.run_batch()
    assert report.expired == []
    assert len(report.results) == 1


# ---- partition-aware sharding (connectivity-clustered owner maps) --------

@pytest.fixture(scope="module")
def sbm_graph():
    from repro.data import generate_sbm_graph, normalized_adjacency

    a = normalized_adjacency(generate_sbm_graph(
        512, 4096, n_blocks=4, p_in=0.95, seed=0))
    a.validate()
    return a


def _partitioned_engine(a, clusters=8, **overrides):
    from repro.io.tiers import ICI_RING

    kw = dict(device_budget_bytes=_budget(a, width=32),
              cache_device_bytes=_budget(a, width=32),
              cache_shards=4, ici_topology=ICI_RING,
              partition_shards=clusters, max_batch_features=32)
    kw.update(overrides)
    eng = ServingEngine(EngineConfig(**kw))
    eng.register_graph("g", a)
    return eng


def _workload(a, seed=5, width=32, hidden=16):
    rng = np.random.default_rng(seed)
    h = rng.standard_normal((a.n_rows, width)).astype(np.float32)
    w = [rng.standard_normal((width, hidden)).astype(np.float32)]
    return h, w


def test_partition_shards_end_to_end_outputs_bitexact(sbm_graph):
    """partition_shards only moves brick ownership — every epoch's output
    must be bit-identical to the CRC-owner default."""
    a = sbm_graph
    h, w = _workload(a)
    crc = _partitioned_engine(a, clusters=0)
    part = _partitioned_engine(a, clusters=8)
    spg = part._engines["g"]
    assert spg.partition is not None and spg.partition.n_clusters == 8
    assert part.cache._owner_maps, \
        "register_graph must install the owner map eagerly"
    for _ in range(2):
        crc.submit(InferenceRequest("g", h, w))
        part.submit(InferenceRequest("g", h, w))
        ref = crc.run_batch().results[0].output
        got = part.run_batch().results[0].output
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_partition_off_without_sharded_cache(quickstart_graph):
    """partition_shards on an unsharded cache is a no-op: CRC owners are
    already correct and an all-zeros owner map would only add overhead."""
    eng = _engine(quickstart_graph, partition_shards=8)
    eng.register_graph("g", quickstart_graph)
    assert eng._engines["g"].partition is None


def test_partition_owner_map_survives_warm_start(sbm_graph, tmp_path):
    a = sbm_graph
    h, w = _workload(a)
    donor = _partitioned_engine(a)
    donor.submit(InferenceRequest("g", h, w))
    cold = donor.run_batch()
    donor.checkpoint_cache(str(tmp_path))

    fresh = _partitioned_engine(a)
    assert fresh.cache._owner_maps, \
        "owner map must be installed before warm_start puts route bricks"
    ws = fresh.warm_start(str(tmp_path))
    assert ws.bricks > 0
    # Every restored brick sits on the shard its owner map dictates.
    for s, shard in enumerate(fresh.cache.shards):
        for key in list(shard._device) + list(shard._host):
            assert fresh.cache.owner_of(key) == s
    fresh.submit(InferenceRequest("g", h, w))
    first = fresh.run_batch()
    assert first.uploaded_bytes == 0, \
        "warm-started partitioned epoch must not re-stream wire bytes"
    np.testing.assert_array_equal(np.asarray(first.results[0].output),
                                  np.asarray(cold.results[0].output))


def test_update_graph_keeps_partition_owner_maps(sbm_graph):
    a = sbm_graph
    h, w = _workload(a)
    eng = _partitioned_engine(a)
    eng.submit(InferenceRequest("g", h, w))
    eng.run_batch()
    part_before = eng._engines["g"].partition
    rep = eng.update_graph("g", inserts=[(5, 300, 0.5), (6, 301, 0.25)])
    assert rep.plans_updated >= 1
    part_after = eng._engines["g"].partition
    assert part_after is not None, "partition must survive edge deltas"
    np.testing.assert_array_equal(part_after.cluster_to_shard,
                                  part_before.cluster_to_shard)
    assert eng.cache._owner_maps, \
        "owner maps must be re-installed for the migrated plan"
    # Exactness on the updated graph, vs a CRC engine serving it fresh.
    ref_eng = _partitioned_engine(eng._graphs["g"], clusters=0)
    eng.submit(InferenceRequest("g", h, w))
    ref_eng.submit(InferenceRequest("g", h, w))
    got = eng.run_batch().results[0].output
    ref = ref_eng.run_batch().results[0].output
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_install_schedule_swaps_partition(sbm_graph):
    from repro.core import TunedSchedule
    from repro.core.autotune import DEFAULT_MIN_BYTES, DEFAULT_PASS_ORDER

    a = sbm_graph
    eng = _partitioned_engine(a, clusters=0)
    assert eng._engines["g"].partition is None

    def tuned(clusters):
        return TunedSchedule(
            graph="g", min_bytes=DEFAULT_MIN_BYTES,
            pass_order=DEFAULT_PASS_ORDER, ell_buckets=None,
            predicted_makespan_s=1.0, default_makespan_s=1.0,
            partition_clusters=clusters)

    eng.install_schedule(tuned(8))
    spg = eng._engines["g"]
    assert spg.partition is not None and spg.partition.n_clusters == 8
    assert not spg._prepared, "cluster change must drop prepared plans"
    h, w = _workload(a)
    eng.submit(InferenceRequest("g", h, w))
    out_part = eng.run_batch().results[0].output
    eng.install_schedule(tuned(None))
    assert eng._engines["g"].partition is None
    eng.submit(InferenceRequest("g", h, w))
    out_crc = eng.run_batch().results[0].output
    np.testing.assert_array_equal(np.asarray(out_part), np.asarray(out_crc))
