"""Gradient correctness of the differentiable streaming SpGEMM.

`AiresSpGEMM.__call__` carries a custom VJP whose backward streams the
transposed RoBW plan (dH = Aᵀ dX). Every test here checks `jax.grad`
through the *streamed* path against the dense `(A @ H)` reference gradient:
if they match, the transposed plan covers each nonzero exactly once and the
block-ELL backward kernel is exact.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import AiresConfig, AiresSpGEMM, FeatureSpec, gcn_epoch
from repro.io.tiers import PAPER_GPU_SYSTEM


def _engine(a, h_nbytes, frac=0.8, **kw):
    budget = int((a.nbytes() + 3 * h_nbytes) * frac) + 4096
    return AiresSpGEMM(AiresConfig(device_budget_bytes=budget,
                                   bm=8, bk=8, **kw))


def _case(make_sparse, n, m, f, density=0.25, seed=0, dtype=np.float32):
    # matrices come from the shared conftest factory; features are drawn
    # separately so the case is fully determined by (n, m, f, density, seed)
    a, dense = make_sparse(n, m, density=density, seed=seed)
    h = np.random.default_rng(seed + 1).standard_normal((m, f)).astype(dtype)
    return a, dense, h


# ≥3 shapes; (33, 57, 24) and (41, 23, 12) are ragged (n % bm != 0).
SHAPES = [(16, 16, 8), (40, 24, 16), (33, 57, 24), (41, 23, 12)]


@pytest.mark.parametrize("n,m,f", SHAPES)
def test_grad_matches_dense_f32(n, m, f, make_sparse):
    a, dense, h = _case(make_sparse, n, m, f, seed=n * m + f)
    eng = _engine(a, h.nbytes)

    def loss(h_):
        return jnp.sum(jnp.sin(eng(a, h_)))

    def loss_ref(h_):
        return jnp.sum(jnp.sin(jnp.asarray(dense) @ h_))

    g = jax.grad(loss)(jnp.asarray(h))
    g_ref = jax.grad(loss_ref)(jnp.asarray(h))
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), atol=1e-4)
    assert eng.last_backward_stream_stats is not None
    assert eng.last_backward_stream_stats.segments >= 1
    assert eng.last_backward_stream_stats.uploaded_bytes > 0


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grad_dtypes(dtype, make_sparse):
    a, dense, h_np = _case(make_sparse, 40, 40, 16, seed=7)
    eng = _engine(a, h_np.nbytes)
    h = jnp.asarray(h_np, dtype)

    g = jax.grad(lambda h_: jnp.sum(eng(a, h_)))(h)
    g_ref = jax.grad(
        lambda h_: jnp.sum(jnp.asarray(dense, dtype) @ h_))(h)
    assert g.dtype == dtype  # custom VJP must return the primal dtype
    atol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(g, np.float32),
                               np.asarray(g_ref, np.float32), atol=atol)


def test_grad_streams_multiple_transposed_segments(make_sparse):
    """A tight budget must force the backward pass to stream ≥2 segments of
    the transposed plan — the out-of-core regime, not a degenerate single
    upload."""
    a, dense, h = _case(make_sparse, 64, 64, 16, density=0.3, seed=3)
    eng = _engine(a, h.nbytes, frac=0.35)

    g = jax.grad(lambda h_: jnp.sum(eng(a, h_) ** 2))(jnp.asarray(h))
    g_ref = jax.grad(
        lambda h_: jnp.sum((jnp.asarray(dense) @ h_) ** 2))(jnp.asarray(h))
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), atol=1e-3)
    assert eng.last_stream_stats.segments >= 2, "forward should stream"
    assert eng.last_backward_stream_stats.segments >= 2, \
        "backward should stream the transposed plan"


def test_fused_layer_param_grads(make_sparse):
    """dH, dW, db through the fused σ((A H) W + b) streamed layer."""
    a, dense, h = _case(make_sparse, 41, 41, 12, seed=11)
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.standard_normal((12, 6)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((6,)).astype(np.float32))
    eng = _engine(a, h.nbytes)

    def loss(h_, w_, b_):
        return jnp.sum(jnp.tanh(eng.gcn_layer(a, h_, w_, b_)))

    def loss_ref(h_, w_, b_):
        return jnp.sum(jnp.tanh(
            jax.nn.relu(jnp.asarray(dense) @ h_ @ w_ + b_)))

    args = (jnp.asarray(h), w, b)
    grads = jax.grad(loss, argnums=(0, 1, 2))(*args)
    refs = jax.grad(loss_ref, argnums=(0, 1, 2))(*args)
    for g, r in zip(grads, refs):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r), atol=2e-3)


def test_gcn_model_grads_out_of_core(make_sparse):
    """Full GCN param grads via gcn_loss with the streamed engine vs the
    dense in-core path — covers W and bias grads of every layer."""
    import dataclasses
    from repro.models import GCNConfig, gcn_init, gcn_loss
    from repro.sparse import csr_to_dense

    a, dense, h = _case(make_sparse, 40, 40, 16, seed=2)
    cfg = GCNConfig(feature_dim=16, hidden_dims=(16,), n_classes=4,
                    out_of_core=True)
    params = gcn_init(cfg, jax.random.PRNGKey(0))
    labels = jnp.asarray(
        np.random.default_rng(1).integers(0, 4, size=(a.n_rows,)))
    eng = _engine(a, h.nbytes)
    h0 = jnp.asarray(h)

    g_ooc = jax.grad(lambda p: gcn_loss(cfg, p, a, h0, labels,
                                        engine=eng))(params)
    cfg_ic = dataclasses.replace(cfg, out_of_core=False)
    g_ic = jax.grad(lambda p: gcn_loss(cfg_ic, p, jnp.asarray(dense), h0,
                                       labels))(params)
    for k in params:
        np.testing.assert_allclose(np.asarray(g_ooc[k]), np.asarray(g_ic[k]),
                                   atol=1e-4, err_msg=k)
    # one backward stream per layer boundary that needs dH
    assert len(eng.backward_stats_log) >= 1


def test_gcn_epoch_execute_reports_phase_stats(make_sparse):
    """Execute-mode epochs must report separate forward/backward
    StreamStats, with the backward really streaming transposed segments."""
    a, dense, h0 = _case(make_sparse, 48, 48, 16, density=0.3, seed=9)
    rng = np.random.default_rng(0)
    ws = [rng.standard_normal((16, 16)).astype(np.float32),
          rng.standard_normal((16, 8)).astype(np.float32)]
    budget = int((a.nbytes() + 3 * h0.nbytes) * 0.5) + 4096
    em = gcn_epoch(
        a, h0, ws, "aires", PAPER_GPU_SYSTEM, budget, mode="execute",
        engine_config=AiresConfig(device_budget_bytes=budget, bm=8, bk=8))
    assert len(em.forward_stream) == len(ws)
    assert len(em.backward_stream) == len(ws)
    for s in em.forward_stream + em.backward_stream:
        assert s.segments >= 1
        assert s.uploaded_bytes > 0
    assert em.wall_seconds > 0
    assert len(em.per_layer) == len(ws)
    assert len(em.per_layer_backward) == len(ws)
    assert em.epoch_makespan_s > 0 and np.isfinite(em.epoch_makespan_s)


def test_gcn_epoch_simulate_keeps_backward_factor(make_sparse):
    """Simulate mode still uses the paper's modeled backward multiplier."""
    a, _, _ = _case(make_sparse, 48, 48, 16, density=0.3, seed=9)
    feat = FeatureSpec(a.n_rows, 16, 4, 0.0)
    ws = [np.zeros((16, 16), np.float32)] * 2
    budget = int(2.5 * a.nbytes()) + (1 << 16)
    em1 = gcn_epoch(a, feat, ws, "aires", PAPER_GPU_SYSTEM, budget,
                    mode="simulate", backward_factor=1.0)
    em2 = gcn_epoch(a, feat, ws, "aires", PAPER_GPU_SYSTEM, budget,
                    mode="simulate", backward_factor=3.0)
    np.testing.assert_allclose(em2.epoch_makespan_s / em1.epoch_makespan_s,
                               2.0, rtol=1e-6)
    assert not em1.forward_stream and not em1.backward_stream


@pytest.mark.slow
def test_out_of_core_training_descends(make_sparse):
    """A few real out-of-core optimizer steps: loss must go down with every
    gradient coming through the streamed custom VJP."""
    from repro.models import GCNConfig, gcn_init
    from repro.train import gcn_train_loop

    a, dense, h = _case(make_sparse, 40, 40, 16, seed=4)
    cfg = GCNConfig(feature_dim=16, hidden_dims=(16,), n_classes=4,
                    out_of_core=True)
    params = gcn_init(cfg, jax.random.PRNGKey(0))
    labels = jnp.asarray(
        np.random.default_rng(1).integers(0, 4, size=(a.n_rows,)))
    eng = _engine(a, h.nbytes)
    params, info = gcn_train_loop(cfg, eng, a, jnp.asarray(h), labels,
                                  params, n_epochs=8, lr=5e-2)
    losses = [l for _, l in info["history"]]
    assert losses[-1] < 0.8 * losses[0]
    # every epoch recorded both phases
    for ep in info["epochs"]:
        assert len(ep["forward_stream"]) == 2   # two layers
        assert all(s.segments >= 1 for s in ep["backward_stream"])
