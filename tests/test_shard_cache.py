"""Mesh-sharded segment cache (io/shard_cache.py) + cross-worker directory.

Covers the ISSUE-3 tentpole invariants:
  * 1-shard equivalence — a ShardedSegmentCache over a 1-axis mesh with one
    shard is byte-identical to a bare TieredSegmentCache under any op mix
    (hypothesis-optional seeded sweep, the test_segment_cache pattern);
  * deterministic placement — every key has one stable owner shard, and
    per-shard budgets/LRU are independent (pressure on one shard never
    evicts another shard's bricks);
  * ICI accounting — remote-shard hits and shard placements are charged
    through TieredMemorySystem on Path.ICI, local hits stay free, so
    simulate-mode bytes_by_path stays honest;
  * directory semantics — a peer's demoted host copy serves a local miss
    (``cache/peer-promote``), a demotion whose brick a peer already holds
    is dropped without a DtoH copy (duplicate_avoided), holders unpublish
    when their copy leaves the host tier;
  * real mesh placement — with >1 actual devices (CI runs the suite under
    XLA_FLAGS=--xla_force_host_platform_device_count=8) bricks genuinely
    live on their owner chip and remote hits come back on the local chip.
"""
import dataclasses
import importlib.util

import numpy as np
import pytest

from repro.io import (
    CacheDirectory,
    CacheStats,
    SegmentKey,
    ShardedSegmentCache,
    TieredSegmentCache,
    shard_of,
)
from repro.io.tiers import (
    MemoryTier,
    PAPER_GPU_SYSTEM,
    Path,
    TieredMemorySystem,
)

HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None


def _key(i, graph="g0"):
    return SegmentKey(graph, i, "bricks", (i, 8, 8))


def _key_for_shard(shard, n_shards, graph="g0", start=0):
    """First segment id >= start whose owner is `shard`."""
    i = start
    while shard_of(_key(i, graph), n_shards) != shard:
        i += 1
    return _key(i, graph)


# ---- deterministic placement & independence ------------------------------

def test_shard_of_is_deterministic_and_in_range():
    for n in (1, 2, 4, 7):
        for i in range(50):
            s = shard_of(_key(i), n)
            assert 0 <= s < n
            assert s == shard_of(_key(i), n)  # stable
    assert shard_of(_key(0), 1) == 0


def test_shard_of_spreads_keys_across_shards():
    owners = {shard_of(_key(i), 4) for i in range(64)}
    assert owners == {0, 1, 2, 3}, "CRC placement should reach every shard"


def test_entries_land_on_owner_shard_and_budgets_are_independent():
    cache = ShardedSegmentCache(device_budget_bytes=8, n_shards=4)
    k_s0 = _key_for_shard(0, 4)
    k_s1 = _key_for_shard(1, 4)
    cache.put(k_s0, "a", 1)
    cache.put(k_s1, "b", 1)
    assert cache.shards[cache.shard_index_of(k_s0)].tier_of(k_s0) \
        == MemoryTier.DEVICE
    assert cache.shards[cache.shard_index_of(k_s1)].tier_of(k_s1) \
        == MemoryTier.DEVICE
    # Fill shard 1's slice (2 bytes) until it demotes; shard 0 is untouched.
    start = 0
    for _ in range(3):
        k = _key_for_shard(1, 4, start=start)
        start = k.segment_id + 1
        cache.put(k, "x", 1)
    assert cache.tier_of(k_s1) == MemoryTier.HOST, "shard 1 under pressure"
    assert cache.tier_of(k_s0) == MemoryTier.DEVICE, \
        "pressure on shard 1 must not evict shard 0's bricks"


def test_invalid_construction():
    with pytest.raises(ValueError):
        ShardedSegmentCache(device_budget_bytes=8, n_shards=0)
    with pytest.raises(ValueError):
        ShardedSegmentCache(device_budget_bytes=8, n_shards=2, local_shard=2)
    with pytest.raises(ValueError):
        ShardedSegmentCache(device_budget_bytes=3, n_shards=4)
    with pytest.raises(ValueError):
        ShardedSegmentCache(device_budget_bytes=8, n_shards=2, devices=[1])


def test_shard_blob_is_pinned_and_matches_tuple_repr():
    """`_shard_blob` is an explicit field serialization whose bytes are
    frozen: a SegmentKey dataclass change (new field, renamed field) must
    not silently reshuffle every CRC owner. The blob deliberately excludes
    `fingerprint` so edge deltas keep a segment's owner."""
    from repro.io.shard_cache import _shard_blob
    import zlib

    k = SegmentKey("g0", 3, "bricks", (3, 8, 8))
    assert _shard_blob(k) == b"('g0', 3, 'bricks', (3, 8, 8))"
    assert zlib.crc32(_shard_blob(k)) == 1050362079
    assert shard_of(k, 4) == 3
    # 1-tuple shape keeps the trailing comma (the repr convention).
    k1 = SegmentKey("g0", 1, "bricks", (7,))
    assert _shard_blob(k1) == b"('g0', 1, 'bricks', (7,))"
    # Equivalent to the tuple repr for canonical keys...
    for key in (k, k1):
        ident = (key.graph_id, key.segment_id, key.wire_format, key.shape)
        assert _shard_blob(key) == repr(ident).encode()
    # ...and fingerprint-blind: same owner across content changes.
    kf = dataclasses.replace(k, fingerprint="deadbeef")
    assert _shard_blob(kf) == _shard_blob(k)
    assert shard_of(kf, 4) == shard_of(k, 4)


# ---- partition-derived owner maps ----------------------------------------

def test_owner_map_overrides_crc_and_drops_with_namespace():
    cache = ShardedSegmentCache(device_budget_bytes=64, n_shards=4)
    keys = [_key(i) for i in range(4)]
    crc_owners = [cache.owner_of(k) for k in keys]
    cache.install_owner_map("g0", [1, 1, 2, 2], clusters=[0, 0, 1, 1])
    assert [cache.owner_of(k) for k in keys] == [1, 1, 2, 2]
    assert [cache.cluster_of_key(k) for k in keys] == [0, 0, 1, 1]
    # Keys outside the map (and other namespaces) stay on CRC owners.
    far = _key(9)
    assert cache.owner_of(far) == shard_of(far, 4)
    other = _key(0, graph="gB")
    assert cache.owner_of(other) == shard_of(other, 4)
    assert cache.cluster_of_key(other) is None
    # Dropping the namespace restores the CRC default.
    assert cache.drop_owner_map("g0") is True
    assert [cache.owner_of(k) for k in keys] == crc_owners
    assert cache.drop_owner_map("g0") is False


def test_owner_map_validates_and_reinstall_replaces():
    cache = ShardedSegmentCache(device_budget_bytes=64, n_shards=2)
    with pytest.raises(ValueError, match="outside"):
        cache.install_owner_map("g0", [0, 2])
    with pytest.raises(ValueError, match="length"):
        cache.install_owner_map("g0", [0, 1], clusters=[0])
    cache.install_owner_map("g0", [1, 1], clusters=[0, 0])
    cache.install_owner_map("g0", [0, 1])          # reinstall, no clusters
    assert cache.owner_map("g0") == [0, 1]
    assert cache.cluster_of_key(_key(0)) is None, \
        "reinstall without clusters must drop the stale cluster map"


def test_owner_map_routes_puts_and_gets_with_ici_accounting():
    tms = TieredMemorySystem(PAPER_GPU_SYSTEM)
    cache = ShardedSegmentCache(device_budget_bytes=64, n_shards=4,
                                local_shard=1, tms=tms)
    cache.install_owner_map("g0", [1, 3])
    k_local, k_remote = _key(0), _key(1)
    cache.put(k_local, "a", 8)
    assert tms.bytes_by_path().get(Path.ICI, 0) == 0, \
        "put at the mapped local owner is free"
    cache.put(k_remote, "b", 8)
    assert cache.shards[3].tier_of(k_remote) == MemoryTier.DEVICE
    assert tms.bytes_by_path()[Path.ICI] == 8
    # A put landing exactly on the mapped owner records no per-key
    # override — a later reinstall must still be able to move it.
    assert cache._locations == {}
    _, cost = cache.get_with_cost(k_local, nbytes=8)
    assert cost == 0.0
    value, cost = cache.get_with_cost(k_remote, nbytes=8)
    assert value == "b" and cost > 0.0


def test_owner_map_survives_clear_but_not_prefix_invalidation():
    cache = ShardedSegmentCache(device_budget_bytes=64, n_shards=4)
    cache.install_owner_map("g0", [2, 2], clusters=[0, 0])
    cache.clear()
    assert cache.owner_map("g0") == [2, 2], \
        "clear() drops content, not placement policy"
    cache.invalidate_keys([_key(0)])
    assert cache.owner_map("g0") == [2, 2]
    cache.invalidate_prefix("g0")
    assert cache.owner_map("g0") is None, \
        "namespace invalidation drops the namespace's owner map"


def test_put_override_wins_over_owner_map():
    cache = ShardedSegmentCache(device_budget_bytes=64, n_shards=4)
    cache.install_owner_map("g0", [2])
    k = _key(0)
    cache.put(k, "v", 4, shard=3)       # placement pass pins elsewhere
    assert cache.owner_of(k) == 3
    assert cache.shards[3].tier_of(k) == MemoryTier.DEVICE
    # A plain re-put keeps the overridden location (put resolves through
    # `owner_of`); explicitly placing back on the mapped owner clears the
    # per-key override so the owner map governs again.
    cache.put(k, "v", 4)
    assert cache.owner_of(k) == 3
    cache.put(k, "v", 4, shard=2)
    assert cache.owner_of(k) == 2
    assert cache._locations == {}


# ---- ICI accounting ------------------------------------------------------

def test_remote_hit_charged_on_ici_path_local_hit_free():
    tms = TieredMemorySystem(PAPER_GPU_SYSTEM)
    cache = ShardedSegmentCache(device_budget_bytes=64, n_shards=4,
                                local_shard=0, tms=tms)
    k_local = _key_for_shard(0, 4)
    k_remote = _key_for_shard(2, 4)
    cache.put(k_local, "l", 8)
    assert tms.bytes_by_path().get(Path.ICI, 0) == 0, "local put is free"
    cache.put(k_remote, "r", 8)     # fresh brick ships to its owner chip
    assert tms.bytes_by_path()[Path.ICI] == 8
    tags = [t.tag for t in tms.transfers]
    assert tags == ["cache/shard-place"]

    _, cost = cache.get_with_cost(k_local, nbytes=8)
    assert cost == 0.0
    assert tms.bytes_by_path()[Path.ICI] == 8, "local hit adds no ICI"
    value, cost = cache.get_with_cost(k_remote, nbytes=8)
    assert value == "r" and cost > 0.0
    assert tms.bytes_by_path()[Path.ICI] == 16
    assert tms.transfers[-1].tag == "cache/ici"
    st = cache.stats
    assert st.remote_hits == 1 and st.ici_bytes == 16
    assert st.device_hits == 2 and st.hit_bytes == 16


def test_ici_is_cheaper_than_dma_reupload():
    """The point of the shard tier: an ICI hop beats re-crossing the host
    bus, on both modeled systems."""
    nbytes = 1 << 20
    for spec in (PAPER_GPU_SYSTEM,):
        tms = TieredMemorySystem(spec)
        ici_s = tms.transfer(Path.ICI, MemoryTier.DEVICE, MemoryTier.DEVICE,
                             nbytes)
        dma_s = tms.transfer(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE,
                             nbytes)
        assert ici_s < dma_s
        # ...but dearer than staying in local HBM (no transfer at all).
        assert ici_s > nbytes / spec.hbm_bw


def test_remote_host_hit_promotes_then_ships_over_ici():
    tms = TieredMemorySystem(PAPER_GPU_SYSTEM)
    cache = ShardedSegmentCache(device_budget_bytes=4, n_shards=2,
                                local_shard=0, tms=tms)
    k = _key_for_shard(1, 2)
    cache.put(k, "v", 2)
    # Overflow shard 1 (budget 2) so k demotes to its host slice.
    start = k.segment_id + 1
    for _ in range(2):
        nk = _key_for_shard(1, 2, start=start)
        start = nk.segment_id + 1
        cache.put(nk, "w", 1)
    assert cache.tier_of(k) == MemoryTier.HOST
    tms.reset_accounting()
    value, cost = cache.get_with_cost(k, nbytes=2)
    assert value == "v"
    assert sum(t.nbytes for t in tms.transfers
               if t.tag == "cache/promote") == 2, "host->owner promotion"
    assert sum(t.nbytes for t in tms.transfers
               if t.tag == "cache/ici") == 2, "owner->local ship"
    promote_s = next(t.seconds for t in tms.transfers
                     if t.tag == "cache/promote")
    ici_s = next(t.seconds for t in tms.transfers if t.tag == "cache/ici")
    assert cost == pytest.approx(promote_s + ici_s)


# ---- cross-worker cache directory ----------------------------------------

def _pressured_pair(directory, budget=2):
    """Two workers' caches over the same keys, demotion pressure on both."""
    return [TieredSegmentCache(device_budget_bytes=budget,
                               directory=directory, worker_id=w)
            for w in (0, 1)]


def test_directory_dedups_demotion_copies():
    directory = CacheDirectory()
    w0, w1 = _pressured_pair(directory)
    for i in range(4):          # worker 0 demotes keys 0,1 and publishes
        w0.put(_key(i), f"v{i}", 1)
    assert directory.holder(_key(0)) == 0
    for i in range(4):          # worker 1 demotes the same keys
        w1.put(_key(i), f"v{i}", 1)
    assert w1.stats.duplicate_avoided_bytes == 2, \
        "worker 1 must skip host copies worker 0 already holds"
    assert w1.stats.demoted_bytes == 0
    assert directory.duplicates_avoided == 2
    # worker 0 paid its demotions normally
    assert w0.stats.demoted_bytes == 2


def test_directory_serves_peer_miss_and_counts_hit_bytes():
    tms = TieredMemorySystem(PAPER_GPU_SYSTEM)
    directory = CacheDirectory()
    w0 = TieredSegmentCache(device_budget_bytes=2, tms=tms,
                            directory=directory, worker_id=0)
    w1 = TieredSegmentCache(device_budget_bytes=2, tms=tms,
                            directory=directory, worker_id=1)
    for i in range(3):
        w0.put(_key(i), f"v{i}", 1)     # k0 demoted -> published
    assert w1.tier_of(_key(0)) is None
    tms.reset_accounting()
    value = w1.get(_key(0), nbytes=1)
    assert value == "v0", "miss served from the peer's host copy"
    assert tms.transfers[-1].tag == "cache/peer-promote"
    st = w1.stats
    assert st.directory_hits == 1 and st.directory_hit_bytes == 1
    assert st.hit_bytes == 1 and st.misses == 0
    assert st.promoted_bytes == 1, "peer promotion crossed the bus"
    # the peer keeps its copy and the directory record
    assert w0.tier_of(_key(0)) == MemoryTier.HOST
    assert directory.holder(_key(0)) == 0
    # worker 1 now holds a device copy; its later demotion is deduped
    w1.put(_key(10), "x", 1)
    w1.put(_key(11), "y", 1)          # evicts _key(0): peer holds it -> drop
    assert w1.stats.duplicate_avoided_bytes == 1


def test_directory_unpublishes_when_host_copy_leaves():
    directory = CacheDirectory()
    w0, w1 = _pressured_pair(directory)
    for i in range(3):
        w0.put(_key(i), f"v{i}", 1)
    assert directory.holder(_key(0)) == 0
    assert w0.get(_key(0), nbytes=1) == "v0"       # promotion consumes copy
    assert directory.holder(_key(0)) is None
    for i in range(3):
        w1.put(_key(i, "gB"), f"b{i}", 1)
    assert directory.holder(_key(0, "gB")) == 1
    w1.invalidate_graph("gB")
    assert directory.holder(_key(0, "gB")) is None


def test_directory_rejects_duplicate_worker_claim():
    directory = CacheDirectory()
    directory.claim_worker(0)
    directory.claim_worker(1)
    with pytest.raises(ValueError, match="already claimed"):
        directory.claim_worker(0)


def test_directory_off_is_bitexact_noop():
    plain = TieredSegmentCache(device_budget_bytes=2)
    for i in range(4):
        plain.put(_key(i), f"v{i}", 1)
        plain.get(_key(i % 2), nbytes=1)
    st = plain.stats
    assert st.directory_hits == st.directory_hit_bytes == 0
    assert st.duplicate_avoided_bytes == 0


# ---- 1-shard equivalence property (the acceptance criterion) -------------

_STAT_FIELDS = [f.name for f in dataclasses.fields(CacheStats)]


def check_one_shard_matches_tiered(seed):
    """Same op sequence through a bare TieredSegmentCache and a 1-shard
    ShardedSegmentCache: every stat field, tier placement and used-byte
    counter must agree exactly — and no ICI traffic may appear."""
    rng = np.random.default_rng(seed)
    dev_budget = int(rng.integers(4, 64))
    host_budget = int(rng.integers(4, 64)) if rng.random() < 0.5 else None
    tms_a = TieredMemorySystem(PAPER_GPU_SYSTEM)
    tms_b = TieredMemorySystem(PAPER_GPU_SYSTEM)
    ref = TieredSegmentCache(dev_budget, host_budget, tms=tms_a)
    one = ShardedSegmentCache(dev_budget, host_budget, tms=tms_b, n_shards=1)
    keys = [_key(j, graph=f"g{j % 3}") for j in range(12)]
    for _ in range(100):
        k = keys[int(rng.integers(0, len(keys)))]
        nb = int(rng.integers(1, dev_budget + 8))
        op = rng.random()
        if op < 0.45:
            assert ref.get(k, nbytes=nb) == one.get(k, nbytes=nb)
        elif op < 0.9:
            payload = ("payload", k.segment_id, nb)
            ref.put(k, payload, nb)
            one.put(k, payload, nb)
        else:
            assert ref.invalidate_graph(k.graph_id) \
                == one.invalidate_graph(k.graph_id)
    for f in _STAT_FIELDS:
        assert getattr(ref.stats, f) == getattr(one.stats, f), f
    assert one.stats.ici_bytes == 0 and one.stats.remote_hits == 0
    assert ref.device_used_bytes == one.device_used_bytes
    assert ref.host_used_bytes == one.host_used_bytes
    for k in keys:
        assert ref.tier_of(k) == one.tier_of(k)
    assert tms_a.bytes_by_path() == tms_b.bytes_by_path()
    assert tms_a.seconds_by_path() == tms_b.seconds_by_path()


if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_one_shard_matches_tiered(seed):
        check_one_shard_matches_tiered(seed)
else:
    @pytest.mark.parametrize("seed", range(25))
    def test_one_shard_matches_tiered(seed):
        check_one_shard_matches_tiered(seed)


# ---- sharded capacity/accounting sweep -----------------------------------

def check_sharded_capacity_and_accounting(seed):
    rng = np.random.default_rng(seed)
    n_shards = int(rng.integers(2, 6))
    dev_budget = int(rng.integers(n_shards * 4, 128))
    cache = ShardedSegmentCache(dev_budget, n_shards=n_shards,
                                local_shard=int(rng.integers(0, n_shards)))
    per_shard = dev_budget // n_shards
    keys = [_key(j, graph=f"g{j % 3}") for j in range(16)]
    requested = 0
    for _ in range(90):
        k = keys[int(rng.integers(0, len(keys)))]
        nb = int(rng.integers(1, per_shard + 8))
        if rng.random() < 0.5:
            requested += nb
            cache.get(k, nbytes=nb)
        else:
            cache.put(k, ("p", k.segment_id, nb), nb)
        for shard in cache.shards:
            assert shard.device_used_bytes <= per_shard
    st_ = cache.stats
    assert st_.hit_bytes + st_.miss_bytes == requested


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_sharded_capacity_and_accounting(seed):
        check_sharded_capacity_and_accounting(seed)
else:
    @pytest.mark.parametrize("seed", range(25))
    def test_sharded_capacity_and_accounting(seed):
        check_sharded_capacity_and_accounting(seed)


# ---- real multi-device mesh placement (CI sharded job) -------------------

def _device_of(arr):
    devs = arr.devices() if callable(getattr(arr, "devices", None)) \
        else {arr.device()}
    assert len(devs) == 1
    return next(iter(devs))


@pytest.fixture
def four_device_mesh():
    import jax

    if jax.device_count() < 4:
        pytest.skip("needs >=4 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    from repro.launch.mesh import make_cache_mesh

    return make_cache_mesh(4)


def test_from_mesh_places_bricks_on_owner_chips(four_device_mesh):
    import jax
    import jax.numpy as jnp

    mesh = four_device_mesh
    cache = ShardedSegmentCache.from_mesh(mesh, device_budget_bytes=1 << 20)
    assert cache.n_shards == 4
    local_dev = jax.devices()[0]
    arrays = {}
    for shard in range(4):
        k = _key_for_shard(shard, 4, start=100 * shard)
        arr = jnp.arange(16, dtype=jnp.float32) + shard
        cache.put(k, arr, int(arr.nbytes))
        arrays[shard] = (k, np.asarray(arr))
    for shard, (k, ref) in arrays.items():
        stored = cache.shards[shard]._device[k].value
        assert _device_of(stored) == cache.devices[shard], \
            "brick must live on its owner chip"
        got = cache.get(k, nbytes=int(ref.nbytes))
        assert _device_of(got) == local_dev, \
            "remote hit must come back on the local chip (the ICI hop)"
        np.testing.assert_array_equal(np.asarray(got), ref)
    assert cache.stats.remote_hits == 3
    assert cache.stats.ici_bytes > 0


def test_make_cache_mesh_rejects_oversubscription():
    import jax

    from repro.launch.mesh import make_cache_mesh

    with pytest.raises(ValueError):
        make_cache_mesh(jax.device_count() + 1)
