"""runtime/supervisor.py coverage: restart/backoff, straggler EWMA, the
streamer-deadline feedback loop, and elastic mesh shaping."""
import pytest

from repro.runtime import ElasticMesh, RunState, Supervisor, SupervisorConfig


# ---- Supervisor.run: crash recovery ---------------------------------------

def test_run_completes_without_failures():
    sup = Supervisor(SupervisorConfig(backoff_s=0.0))
    state = sup.run(lambda start: start + 10)
    assert state.step == 10
    assert state.restarts == 0


def test_run_restarts_on_recoverable_and_restores():
    calls = []

    def body(start):
        calls.append(start)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return start + 1

    restores = []

    def restore():
        restores.append(True)
        return 7

    sup = Supervisor(SupervisorConfig(max_restarts=3, backoff_s=0.0))
    state = sup.run(body, restore=restore)
    assert state.restarts == 2
    assert len(restores) == 2
    # after the first failure every retry starts from the restored step
    assert calls == [0, 7, 7]
    assert state.step == 8


def test_run_without_restore_retries_from_same_step():
    attempts = []

    def body(start):
        attempts.append(start)
        if len(attempts) == 1:
            raise RuntimeError("once")
        return start + 5

    sup = Supervisor(SupervisorConfig(backoff_s=0.0))
    state = sup.run(body)
    assert attempts == [0, 0]
    assert state.step == 5


def test_run_exceeding_max_restarts_raises():
    sup = Supervisor(SupervisorConfig(max_restarts=2, backoff_s=0.0))

    def body(start):
        raise RuntimeError("always")

    with pytest.raises(RuntimeError, match="max_restarts"):
        sup.run(body)
    assert sup.state.restarts == 3  # counted before the give-up check


def test_unrecoverable_exception_propagates_immediately():
    sup = Supervisor(SupervisorConfig(backoff_s=0.0),
                     recoverable=(ValueError,))

    def body(start):
        raise KeyError("not recoverable")

    with pytest.raises(KeyError):
        sup.run(body)
    assert sup.state.restarts == 0


# ---- straggler tracking ----------------------------------------------------

def test_observe_step_first_sample_seeds_ewma():
    sup = Supervisor(SupervisorConfig())
    assert sup.observe_step(1.0) is False
    assert sup.state.step_time_ewma == 1.0


def test_observe_step_flags_stragglers_and_clamps_ewma():
    cfg = SupervisorConfig(straggler_factor=3.0, ewma_alpha=0.5)
    sup = Supervisor(cfg)
    sup.observe_step(1.0)
    assert sup.observe_step(10.0) is True        # > 3 × ewma
    assert sup.state.straggler_events == 1
    # the straggler was clamped to factor×ewma before entering the average,
    # so one hiccup cannot triple the bar for the next step
    assert sup.state.step_time_ewma == pytest.approx(
        0.5 * 1.0 + 0.5 * 3.0)
    assert sup.observe_step(2.1) is False        # normal step again


def test_observe_step_normal_steps_track_average():
    sup = Supervisor(SupervisorConfig(ewma_alpha=0.2))
    sup.observe_step(1.0)
    assert sup.observe_step(1.5) is False
    assert sup.state.step_time_ewma == pytest.approx(0.8 * 1.0 + 0.2 * 1.5)


def test_stream_deadline_feeds_back_from_ewma():
    sup = Supervisor(SupervisorConfig(straggler_factor=2.5))
    assert sup.stream_deadline() is None         # no samples yet
    sup.observe_step(0.4)
    assert sup.stream_deadline() == pytest.approx(1.0)


# ---- elastic mesh ----------------------------------------------------------

def test_elastic_mesh_shape_for_divides_model_parallel():
    em = ElasticMesh(model_parallel=4)
    assert em.shape_for(8) == (2, 4)
    # a lost node: gcd degrades model parallelism instead of failing
    assert em.shape_for(6) == (3, 2)
    assert em.shape_for(5) == (5, 1)


def test_elastic_mesh_local_batch_ramps():
    em = ElasticMesh(model_parallel=2)
    assert em.local_batch(32, 8) == 8   # dp=4
    assert em.local_batch(32, 4) == 16  # dp=2
    assert em.local_batch(1, 8) == 1    # floor at 1


def test_elastic_mesh_make_uses_live_devices():
    import jax

    em = ElasticMesh(model_parallel=1)
    mesh = em.make()
    assert mesh.devices.size == len(jax.devices())
    assert mesh.axis_names == ("data", "model")


def test_run_state_defaults():
    st = RunState()
    assert (st.step, st.restarts, st.straggler_events) == (0, 0, 0)
