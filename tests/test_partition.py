"""Connectivity clustering + cluster->shard mapping (sparse/partition.py).

Covers the partition-aware-sharding tentpole invariants:
  * LDG clustering — deterministic, near-uniform cluster sizes, and
    community recovery on SBM graphs (the locality prior keeps contiguous
    blocks together instead of round-robining seed rows);
  * `map_clusters_to_shards` — nearest-first packing under the bounded-
    imbalance cap, least-loaded fallback, validation errors;
  * plan projection — `boundaries()` aligns RoBW segments to cluster
    edges; `clusters_for_plan`/`owners_for_plan` majority votes;
  * `refine` — delta re-clustering keeps untouched labels and the
    cluster->shard map verbatim, validates shapes, changes the token.
"""
import numpy as np
import pytest

from repro.data import generate_sbm_graph, normalized_adjacency
from repro.io.tiers import ICI_ALL_TO_ALL, ICI_RING
from repro.sparse.formats import CSR
from repro.sparse.partition import (
    Partition,
    map_clusters_to_shards,
    partition_graph,
)
from repro.core.robw import robw_partition


def _chain(n, dtype=np.float32):
    """Path graph: row i links i-1 and i+1 — maximally bandable."""
    rows, cols = [], []
    for i in range(n):
        for j in (i - 1, i + 1):
            if 0 <= j < n:
                rows.append(i)
                cols.append(j)
    indptr = np.zeros(n + 1, np.int64)
    np.add.at(indptr, np.asarray(rows) + 1, 1)
    order = np.lexsort((cols, rows))
    return CSR(indptr=np.cumsum(indptr),
               indices=np.asarray(cols, np.int64)[order],
               data=np.ones(len(rows), dtype)[order], shape=(n, n))


def _sbm(n=512, m=4096, blocks=4, seed=0):
    return normalized_adjacency(
        generate_sbm_graph(n, m, n_blocks=blocks, p_in=0.95, seed=seed))


# ---- LDG clustering ------------------------------------------------------

def test_partition_is_deterministic():
    a = _sbm()
    p1 = partition_graph(a, 8, n_shards=4)
    p2 = partition_graph(a, 8, n_shards=4)
    np.testing.assert_array_equal(p1.cluster_of, p2.cluster_of)
    np.testing.assert_array_equal(p1.cluster_to_shard, p2.cluster_to_shard)
    assert p1.token == p2.token != 0


def test_cluster_sizes_near_uniform():
    a = _sbm()
    p = partition_graph(a, 8)
    sizes = np.bincount(p.cluster_of, minlength=8)
    capacity = -(-a.n_rows // 8)
    assert sizes.max() <= capacity
    assert sizes.min() >= 1


def test_sbm_blocks_stay_pure():
    """Each LDG cluster should be dominated by one SBM block — the
    community-recovery property the warm-epoch ICI win rests on."""
    n, blocks = 512, 4
    a = _sbm(n=n, blocks=blocks)
    p = partition_graph(a, blocks)
    block_of = np.arange(n) // (n // blocks)
    for c in range(p.n_clusters):
        members = block_of[p.cluster_of == c]
        if members.size == 0:
            continue
        purity = np.bincount(members).max() / members.size
        assert purity >= 0.9, f"cluster {c} purity {purity:.2f}"


def test_locality_prior_keeps_chain_contiguous():
    """On a path graph the first rows have no labeled neighbors ahead of
    them; the locality prior must keep runs together (few boundaries)
    instead of round-robin seeding the first k rows into k clusters."""
    a = _chain(64)
    p = partition_graph(a, 4)
    # Contiguous clustering => exactly k-1 label changes along the rows.
    assert p.boundaries().size == 3
    sizes = np.bincount(p.cluster_of, minlength=4)
    assert sizes.max() - sizes.min() <= 1


def test_partition_validates_and_clamps():
    a = _chain(8)
    with pytest.raises(ValueError, match="n_clusters"):
        partition_graph(a, 0)
    p = partition_graph(a, 100)         # clamped to n_rows
    assert p.n_clusters == 8
    assert p.n_shards == 1


def test_empty_graph():
    a = CSR(indptr=np.zeros(1, np.int64), indices=np.empty(0, np.int64),
            data=np.empty(0, np.float32), shape=(0, 0))
    p = partition_graph(a, 4)
    assert p.n_rows == 0
    assert p.boundaries().size == 0
    assert "0 rows" in p.describe()


# ---- cluster -> shard mapping --------------------------------------------

def test_map_nearest_first_under_cap():
    # Ring of 4, local shard 0: distance order is [0, 1, 3, 2] (hops
    # [0, 1, 2, 1], ties toward the lower index). Four equal clusters at
    # balance 1.75 (cap = 1.75 * total/4): shard 0 takes the first pair
    # (2 <= 1.75? no — 2 units > 1.75 units cap), so one each lands on
    # 0 and 1 first, then 3, then 2.
    out = map_clusters_to_shards([10, 10, 10, 10], 4, topology=ICI_RING,
                                 local_shard=0)
    assert out.tolist() == [0, 1, 3, 2]


def test_map_packs_local_surplus():
    # Cap = 1.75 * 40/4 = 17.5: the local shard takes 10 + 7 = 17, the
    # next cluster (10) must hop out — bounded imbalance, not winner-
    # takes-all.
    out = map_clusters_to_shards([10, 10, 7, 7, 3, 3], 4,
                                 topology=ICI_RING, local_shard=0)
    load = np.bincount(out, weights=np.array([10, 10, 7, 7, 3, 3]),
                       minlength=4)
    assert load[0] <= 1.75 * 40 / 4
    assert load[0] == load.max(), "local shard fills first"
    # Under the analyzer's 2x-mean lint threshold by construction.
    assert load.max() <= 2 * load.sum() / 4


def test_map_fallback_when_no_shard_fits():
    # One giant cluster exceeds every cap: least-loaded fallback takes it.
    out = map_clusters_to_shards([100, 1, 1], 2, balance=1.0)
    assert set(out.tolist()) == {0, 1}


def test_map_single_shard_and_validation():
    assert map_clusters_to_shards([5, 5], 1).tolist() == [0, 0]
    with pytest.raises(ValueError, match="local_shard"):
        map_clusters_to_shards([5], 2, local_shard=2)
    with pytest.raises(ValueError, match="balance"):
        map_clusters_to_shards([5], 2, balance=0.5)


# ---- plan projection -----------------------------------------------------

def test_boundaries_align_robw_segments():
    a = _sbm(n=256, m=2048, blocks=4)
    p = partition_graph(a, 4)
    bounds = set(p.boundaries().tolist())
    plan = robw_partition(a, a.nbytes() // 6, align=1,
                          boundaries=p.boundaries())
    labels = p.cluster_of
    for seg in plan.segments:
        segment_labels = set(labels[seg.row_start:seg.row_end].tolist())
        assert len(segment_labels) == 1 or not bounds, \
            f"segment [{seg.row_start},{seg.row_end}) straddles a boundary"


def test_owners_for_plan_majority_vote():
    labels = np.array([0, 0, 1, 1], np.int64)
    p = Partition(cluster_of=labels,
                  cluster_to_shard=np.array([2, 3], np.int64),
                  n_shards=4, row_nnz=np.array([5, 5, 1, 1], np.int64))

    class _Seg:
        def __init__(self, lo, hi):
            self.row_start, self.row_end = lo, hi

    class _Plan:
        segments = [_Seg(0, 3), _Seg(3, 4)]

    # Segment 0 spans both clusters; cluster 0 wins on nnz weight.
    assert p.clusters_for_plan(_Plan) == [0, 1]
    assert p.owners_for_plan(_Plan) == [2, 3]
    # All-empty rows fall back to the row-count vote.
    p0 = Partition(cluster_of=labels,
                   cluster_to_shard=np.array([2, 3], np.int64),
                   n_shards=4, row_nnz=np.zeros(4, np.int64))
    assert p0.clusters_for_plan(_Plan) == [0, 1]


def test_row_permutation_sorts_by_cluster():
    labels = np.array([1, 0, 1, 0], np.int64)
    p = Partition(cluster_of=labels,
                  cluster_to_shard=np.array([0, 0], np.int64),
                  n_shards=1, row_nnz=np.ones(4, np.int64))
    perm = p.row_permutation()
    assert np.all(np.diff(labels[perm]) >= 0)
    assert perm.tolist() == [1, 3, 0, 2], "stable within clusters"


# ---- refine (evolving graphs) --------------------------------------------

def test_refine_keeps_untouched_labels_and_shard_map():
    a = _sbm(n=256, m=2048, blocks=4)
    p = partition_graph(a, 4, n_shards=4)
    refined = p.refine(a, touched_rows=[0, 1, 2])
    untouched = np.ones(256, bool)
    untouched[:3] = False
    np.testing.assert_array_equal(refined.cluster_of[untouched],
                                  p.cluster_of[untouched])
    np.testing.assert_array_equal(refined.cluster_to_shard,
                                  p.cluster_to_shard)
    assert refined.n_shards == p.n_shards


def test_refine_relabels_touched_rows_to_neighbor_majority():
    a = _chain(32)
    p = partition_graph(a, 2)           # rows 0..15 -> c0, 16..31 -> c1
    labels = p.cluster_of.copy()
    # Force row 0 into the wrong cluster, then refine it back: its
    # neighbor (row 1) holds the majority label.
    wrong = Partition(cluster_of=np.where(np.arange(32) == 0,
                                          labels[31], labels),
                      cluster_to_shard=p.cluster_to_shard,
                      n_shards=p.n_shards, row_nnz=p.row_nnz)
    fixed = wrong.refine(a, touched_rows=[0])
    assert fixed.cluster_of[0] == labels[1]
    # Isolated touched rows (no neighbors) keep their current label.
    iso = CSR(indptr=np.zeros(33, np.int64),
              indices=np.empty(0, np.int64),
              data=np.empty(0, np.float32), shape=(32, 32))
    kept = wrong.refine(iso, touched_rows=[0])
    assert kept.cluster_of[0] == wrong.cluster_of[0]


def test_refine_validates_shapes_and_token_tracks_labels():
    a = _chain(32)
    b = _chain(16)
    p = partition_graph(a, 2)
    with pytest.raises(ValueError, match="rows"):
        p.refine(b, touched_rows=[0])
    with pytest.raises(IndexError, match="touched"):
        p.refine(a, touched_rows=[99])
    with pytest.raises(IndexError, match="touched"):
        p.refine(a, touched_rows=[-1])
    same = p.refine(a, touched_rows=[5])    # relabels to its own majority
    assert (same.token == p.token) == bool(
        np.array_equal(same.cluster_of, p.cluster_of))


def test_shard_nnz_and_describe():
    a = _sbm(n=256, m=2048, blocks=4)
    p = partition_graph(a, 8, n_shards=4, topology=ICI_ALL_TO_ALL)
    assert int(p.shard_nnz.sum()) == int(p.cluster_nnz.sum()) == a.nnz
    d = p.describe()
    assert "256 rows" in d and "8 clusters" in d and "4 shards" in d
