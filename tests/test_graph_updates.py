"""Evolving graphs: edge deltas, incremental re-tiling and segment-level
cache keys (ISSUE 7).

The headline assertions mirror the ISSUE's acceptance criteria:
  * `apply_edge_updates` is exact vs a dense oracle and strict about
    malformed updates (bounds, duplicates, delete-of-absent);
  * CSR arrays are frozen at construction — mutate-in-place fails loudly
    instead of silently serving a stale fingerprint memo;
  * `robw_delta_partition` re-partitions only touched row blocks; reused
    segments keep their boundaries, bricks and fingerprints verbatim,
    and delta-updated bricks are bit-identical to a from-scratch
    `densify_segment` of the same rows (property-tested, hypothesis-
    optional);
  * `ServingEngine.update_graph` invalidates exactly the touched segment
    keys: the post-update epoch uploads precisely `retiled_bytes`, the
    epoch after uploads zero, and outputs track the updated graph;
  * `ContinuousServer.update_graph` applies a delta between steps without
    draining the queue.
"""
import importlib.util

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import (
    AiresConfig, AiresSpGEMM, densify_segment, plan_memory_dense_features,
    robw_delta_partition, robw_partition,
)
from repro.io import SegmentKey, TieredSegmentCache
from repro.runtime import (
    ContinuousServer, EngineConfig, InferenceRequest, ServingEngine,
    VirtualClock,
)
from repro.sparse import (
    EdgeDelta, apply_edge_updates, csr_fingerprint, csr_from_dense,
    csr_to_dense, graph_cache_prefix, segment_fingerprint,
)
from repro.sparse.ref_spgemm import spgemm_csr_dense

HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None


@pytest.fixture(scope="module")
def quickstart_graph():
    from repro.data import (
        SUITESPARSE_SPECS, generate_graph, normalized_adjacency, scaled_spec,
    )

    a = normalized_adjacency(generate_graph(
        scaled_spec(SUITESPARSE_SPECS["socLJ1"], 1e-4), seed=0))
    a.validate()
    return a


def _budget(a, width=64, a_frac=0.15):
    """Small enough that the plan holds several segments — deltas must be
    able to leave most of them untouched. Sized by the larger matrix
    dimension so both orientations (forward H: n_cols×F, backward dX:
    n_rows×F) stay feasible for rectangular property-test matrices."""
    est = plan_memory_dense_features(a, max(a.shape), width, float("inf"))
    return int(est.m_b + est.m_c + a_frac * a.nbytes())


def _random_sparse(rng):
    """Mirrors tests/test_robw_property.py's case distribution."""
    n = int(rng.integers(8, 65))
    m = int(rng.integers(8, 65))
    density = float(rng.uniform(0.01, 0.4))
    dense = ((rng.random((n, m)) < density)
             * rng.standard_normal((n, m))).astype(np.float32)
    return csr_from_dense(dense), dense


def _random_delta(rng, a, dense, max_edges=6):
    """Draw a valid (inserts, deletes) pair against `dense`'s occupancy."""
    n, m = dense.shape
    inserts, deletes, used = [], [], set()
    for _ in range(int(rng.integers(1, max_edges))):
        r, c = int(rng.integers(n)), int(rng.integers(m))
        if (r, c) in used:
            continue
        used.add((r, c))
        if dense[r, c] != 0 and rng.random() < 0.5:
            deletes.append((r, c))
        else:
            inserts.append((r, c, float(rng.standard_normal())))
    return inserts, deletes


# ---- apply_edge_updates: dense-oracle exactness --------------------------

def test_apply_edge_updates_matches_dense_oracle():
    rng = np.random.default_rng(0)
    a, dense = _random_sparse(rng)
    inserts, deletes = _random_delta(rng, a, dense, max_edges=10)
    new, delta = apply_edge_updates(a, inserts=inserts, deletes=deletes)

    ref = dense.copy()
    n_ins = n_upd = 0
    for r, c, v in inserts:
        if ref[r, c] != 0:
            n_upd += 1
        else:
            n_ins += 1
        ref[r, c] = v
    for r, c in deletes:
        ref[r, c] = 0.0
    np.testing.assert_array_equal(csr_to_dense(new), ref)
    new.validate()
    assert delta.n_inserted == n_ins
    assert delta.n_updated == n_upd
    assert delta.n_deleted == len(deletes)
    assert delta.n_changed == n_ins + n_upd + len(deletes)
    touched = sorted({r for r, _, _ in inserts} | {r for r, _ in deletes})
    assert delta.touched_rows.tolist() == touched
    touched_c = sorted({c for _, c, _ in inserts} | {c for _, c in deletes})
    assert delta.touched_cols.tolist() == touched_c


def test_apply_edge_updates_splices_untouched_rows_verbatim():
    """Untouched rows must be bit-exact — that is what keeps their segment
    fingerprints (and cached bricks) valid."""
    rng = np.random.default_rng(1)
    a, _ = _random_sparse(rng)
    r = a.n_rows // 2
    new, delta = apply_edge_updates(a, inserts=[(r, 0, 2.5)])
    assert delta.touched_rows.tolist() == [r]
    for row in range(a.n_rows):
        if row == r:
            continue
        lo_o, hi_o = int(a.indptr[row]), int(a.indptr[row + 1])
        lo_n, hi_n = int(new.indptr[row]), int(new.indptr[row + 1])
        np.testing.assert_array_equal(a.indices[lo_o:hi_o],
                                      new.indices[lo_n:hi_n])
        np.testing.assert_array_equal(a.data[lo_o:hi_o],
                                      new.data[lo_n:hi_n])


def test_apply_edge_updates_strictness():
    a = csr_from_dense(np.array([[1.0, 0.0], [0.0, 2.0]], np.float32))
    with pytest.raises(IndexError):
        apply_edge_updates(a, inserts=[(2, 0, 1.0)])
    with pytest.raises(IndexError):
        apply_edge_updates(a, deletes=[(0, 5)])
    with pytest.raises(ValueError, match="duplicate insert"):
        apply_edge_updates(a, inserts=[(0, 1, 1.0), (0, 1, 2.0)])
    with pytest.raises(ValueError, match="duplicate delete"):
        apply_edge_updates(a, deletes=[(0, 0), (0, 0)])
    with pytest.raises(ValueError, match="both inserted and deleted"):
        apply_edge_updates(a, inserts=[(0, 0, 3.0)], deletes=[(0, 0)])
    with pytest.raises(KeyError):
        apply_edge_updates(a, deletes=[(0, 1)])


def test_empty_update_is_identity():
    a = csr_from_dense(np.eye(4, dtype=np.float32))
    new, delta = apply_edge_updates(a)
    assert new is a
    assert delta.n_changed == 0
    assert delta.touched_rows.size == 0 and delta.touched_cols.size == 0


def test_updated_graph_keeps_cache_lineage():
    """graph_cache_prefix must survive chained deltas (CSR.graph_key) so
    untouched segment keys keep hitting; a fresh equal-content CSR without
    the lineage gets the ancestor-free prefix."""
    a = csr_from_dense(np.eye(6, dtype=np.float32))
    prefix = graph_cache_prefix(a)
    assert prefix == (f"g{csr_fingerprint(a)}:{a.nnz}"
                      f":{a.shape[0]}x{a.shape[1]}")
    b, _ = apply_edge_updates(a, inserts=[(0, 3, 1.0)])
    c, _ = apply_edge_updates(b, deletes=[(0, 3)])
    assert b.graph_key == prefix and c.graph_key == prefix
    assert graph_cache_prefix(b) == prefix
    assert graph_cache_prefix(c) == prefix
    assert csr_fingerprint(b) != csr_fingerprint(a)
    # same content as `a`, but rebuilt without lineage → same prefix again
    fresh = csr_from_dense(csr_to_dense(c))
    assert graph_cache_prefix(fresh) == prefix


# ---- the stale-fingerprint bugfix: frozen CSR arrays ---------------------

def test_csr_arrays_are_frozen_against_inplace_mutation():
    """Regression (ISSUE 7 satellite): `csr_fingerprint` memoizes on the
    instance, so in-place mutation used to serve stale fingerprints — and
    stale cached bricks. Arrays are now frozen at construction: the
    mutation itself raises instead of corrupting silently."""
    a = csr_from_dense(np.array([[1.0, 2.0], [0.0, 3.0]], np.float32))
    fp = csr_fingerprint(a)
    with pytest.raises(ValueError, match="read-only"):
        a.data[0] = 99.0
    with pytest.raises(ValueError, match="read-only"):
        a.indices[0] = 1
    with pytest.raises(ValueError, match="read-only"):
        a.indptr[0] = 1
    assert csr_fingerprint(a) == fp        # memo never went stale
    np.testing.assert_array_equal(csr_to_dense(a),
                                  [[1.0, 2.0], [0.0, 3.0]])


def test_edge_delta_index_arrays_are_frozen():
    a = csr_from_dense(np.eye(4, dtype=np.float32))
    _, delta = apply_edge_updates(a, inserts=[(1, 2, 1.0)])
    with pytest.raises(ValueError, match="read-only"):
        delta.touched_rows[0] = 3


# ---- robw_delta_partition ------------------------------------------------

def check_delta_partition(a, dense, budget, rng):
    inserts, deletes = _random_delta(rng, a, dense)
    new, delta = apply_edge_updates(a, inserts=inserts, deletes=deletes)
    old_plan = robw_partition(a, budget)
    new_plan, reuse = robw_delta_partition(new, old_plan,
                                           delta.touched_rows)
    segs = new_plan.segments
    # 1. Complete cover, in order, no overlap.
    assert segs[0].row_start == 0 and segs[-1].row_end == new.n_rows
    for s1, s2 in zip(segs, segs[1:]):
        assert s1.row_end == s2.row_start
    # 2. Budget respected unless a single row alone exceeds it.
    for seg in segs:
        if seg.n_rows > 1:
            assert seg.nbytes <= budget
    # 3. Reused segments are verbatim copies of untouched old segments,
    #    and no touched row falls inside a reused segment.
    touched = set(delta.touched_rows.tolist())
    for seg, src in zip(segs, reuse):
        if src is None:
            continue
        old_seg = old_plan.segments[src]
        assert (seg.row_start, seg.row_end) == (old_seg.row_start,
                                                old_seg.row_end)
        assert not touched & set(range(seg.row_start, seg.row_end))
        assert segment_fingerprint(new, seg.row_start, seg.row_end) == \
            segment_fingerprint(a, seg.row_start, seg.row_end)
    # 4. Bricks: every segment — reused or re-tiled — densifies to exactly
    #    densify_segment of the *new* matrix's rows (bit-identical), so a
    #    delta plan's bricks are interchangeable with a from-scratch
    #    re-tile of the same rows.
    for seg, src in zip(segs, reuse):
        fresh = densify_segment(new, seg, bm=8, bk=8)
        if src is not None:
            old_brick = densify_segment(a, old_plan.segments[src],
                                        bm=8, bk=8)
            np.testing.assert_array_equal(old_brick.blocks, fresh.blocks)
            np.testing.assert_array_equal(old_brick.col_tile,
                                          fresh.col_tile)


def test_delta_partition_rejects_out_of_range_rows():
    a = csr_from_dense(np.eye(8, dtype=np.float32))
    plan = robw_partition(a, 64)
    with pytest.raises(IndexError):
        robw_delta_partition(a, plan, [8])
    with pytest.raises(IndexError):
        robw_delta_partition(a, plan, [-1])


def test_delta_partition_no_touched_rows_is_plan_copy():
    a = csr_from_dense(np.eye(16, dtype=np.float32))
    plan = robw_partition(a, 48)
    new_plan, reuse = robw_delta_partition(a, plan, [])
    assert reuse == list(range(len(plan.segments)))
    assert [(s.row_start, s.row_end) for s in new_plan.segments] == \
        [(s.row_start, s.row_end) for s in plan.segments]


# ---- property: delta bricks ≡ from-scratch, cache hits survive -----------

def check_delta_update_end_to_end(seed):
    """After a random delta: SpGEMM output is exact on the new graph,
    untouched segment keys are preserved (their cache entries keep
    hitting), and changed segments carry fresh fingerprints."""
    rng = np.random.default_rng(seed)
    a, dense = _random_sparse(rng)
    h = rng.standard_normal((a.shape[1], 8)).astype(np.float32)
    budget = _budget(a, width=8, a_frac=0.3)
    cache = TieredSegmentCache(device_budget_bytes=1 << 24)
    spg = AiresSpGEMM(AiresConfig(device_budget_bytes=budget, bm=8, bk=8),
                      segment_cache=cache)
    np.testing.assert_allclose(np.asarray(spg(a, jnp.asarray(h))),
                               dense @ h, atol=1e-4, rtol=1e-4)
    (old_key,) = list(spg._prepared)
    old_keys = spg._segment_keys(spg._prepared[old_key])

    inserts, deletes = _random_delta(rng, a, dense)
    new, delta = apply_edge_updates(a, inserts=inserts, deletes=deletes)
    stats = spg.apply_edge_update(a, new, delta)
    assert stats.plans_updated == 1
    assert stats.segments_retiled >= 1

    (new_key,) = list(spg._prepared)
    prep = spg._prepared[new_key]
    new_keys = spg._segment_keys(prep)
    # Untouched keys survive verbatim (same namespace, id, fingerprint):
    # those are exactly the cache entries that keep hitting.
    surviving = set(old_keys) & set(new_keys)
    assert len(surviving) == stats.segments_reused
    assert set(stats.stale_keys) == set(old_keys) - set(new_keys)
    # Every brick — reused or re-tiled — matches a from-scratch densify of
    # the updated matrix, and every fingerprint matches its rows' content.
    for seg, ell, fp in zip(prep.plan.segments, prep.ells, prep.fps):
        fresh = densify_segment(new, seg, bm=8, bk=8)
        np.testing.assert_array_equal(ell.blocks, fresh.blocks)
        np.testing.assert_array_equal(ell.col_tile, fresh.col_tile)
        assert fp == segment_fingerprint(new, seg.row_start, seg.row_end)
    # The updated engine computes the updated graph exactly.
    ref = csr_to_dense(new) @ h
    np.testing.assert_allclose(np.asarray(spg(new, jnp.asarray(h))),
                               ref, atol=1e-4, rtol=1e-4)


if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.integers(0, 10_000))
    def test_delta_partition_properties(seed):
        rng = np.random.default_rng(seed)
        a, dense = _random_sparse(rng)
        budget = int(rng.integers(64, 4097))
        check_delta_partition(a, dense, budget, rng)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 10_000))
    def test_delta_update_end_to_end(seed):
        check_delta_update_end_to_end(seed)
else:
    @pytest.mark.parametrize("seed", range(25))
    def test_delta_partition_properties(seed):
        rng = np.random.default_rng(seed)
        a, dense = _random_sparse(rng)
        budget = int(rng.integers(64, 4097))
        check_delta_partition(a, dense, budget, rng)

    @pytest.mark.parametrize("seed", range(10))
    def test_delta_update_end_to_end(seed):
        check_delta_update_end_to_end(seed)


def test_delta_update_migrates_backward_plan_too():
    """A prepared transposed (backward) plan re-tiles by touched *columns*
    and stays exact under jax.grad after the delta."""
    import jax

    rng = np.random.default_rng(7)
    a, dense = _random_sparse(rng)
    h = rng.standard_normal((a.shape[1], 8)).astype(np.float32)
    budget = _budget(a, width=8, a_frac=0.3)
    spg = AiresSpGEMM(AiresConfig(device_budget_bytes=budget, bm=8, bk=8))
    # d/dH sum(A @ H) = Aᵀ @ 1 broadcast across feature columns
    def grad_ref(d):
        return np.repeat(d.sum(axis=0)[:, None], 8, axis=1)

    g = jax.grad(lambda h_: jnp.sum(spg(a, h_)))(jnp.asarray(h))
    np.testing.assert_allclose(np.asarray(g), grad_ref(dense),
                               atol=1e-4, rtol=1e-4)
    assert len(spg._prepared) == 2           # forward + backward plans

    inserts, deletes = _random_delta(rng, a, dense)
    new, delta = apply_edge_updates(a, inserts=inserts, deletes=deletes)
    stats = spg.apply_edge_update(a, new, delta)
    assert stats.plans_updated == 2
    g2 = jax.grad(lambda h_: jnp.sum(spg(new, h_)))(jnp.asarray(h))
    np.testing.assert_allclose(np.asarray(g2), grad_ref(csr_to_dense(new)),
                               atol=1e-4, rtol=1e-4)
    # Transposed bricks match a from-scratch densify of the new transpose.
    for key, prep in spg._prepared.items():
        if not key[-1]:
            continue
        a_t = spg.transpose_of(new)
        for seg, ell in zip(prep.plan.segments, prep.ells):
            fresh = densify_segment(a_t, seg, bm=8, bk=8)
            np.testing.assert_array_equal(ell.blocks, fresh.blocks)


# ---- ServingEngine.update_graph: upload exactly the delta ----------------

def test_update_graph_uploads_only_retiled_bytes(quickstart_graph):
    """The ISSUE acceptance scenario: after a small edge delta the next
    epoch re-streams exactly `retiled_bytes` (untouched bricks keep
    hitting), and the epoch after uploads zero."""
    rng = np.random.default_rng(3)
    a = quickstart_graph
    h = rng.standard_normal((a.n_rows, 32)).astype(np.float32)
    w = [rng.standard_normal((32, 16)).astype(np.float32)]
    eng = ServingEngine(EngineConfig(device_budget_bytes=_budget(a),
                                     max_batch_features=64))
    eng.register_graph("g", a)

    def epoch():
        eng.submit(InferenceRequest("g", h, w))
        return eng.run_batch()

    cold, warm = epoch(), epoch()
    assert cold.uploaded_bytes > 0 and warm.uploaded_bytes == 0

    rep = eng.update_graph("g", inserts=[(5, 100, 0.5)])
    assert rep.delta.n_changed == 1
    assert rep.plans_updated >= 1
    assert rep.segments_retiled >= 1
    assert rep.segments_reused >= 1, "delta must not re-tile the graph"
    assert rep.segments_reused > rep.segments_retiled
    assert rep.stale_keys >= 1
    assert rep.cache_entries_dropped >= 1

    after = epoch()
    assert after.uploaded_bytes == rep.retiled_bytes, (
        "post-update epoch must re-stream exactly the re-tiled bricks")
    assert after.cache_hit_bytes > 0, "untouched bricks must keep hitting"
    assert epoch().uploaded_bytes == 0

    new = eng._graphs["g"]
    ref = spgemm_csr_dense(new, h) @ w[0]
    np.testing.assert_allclose(after.results[0].output, ref, atol=1e-4,
                               rtol=1e-4)


def test_update_graph_requires_registration(quickstart_graph):
    eng = ServingEngine(EngineConfig(
        device_budget_bytes=_budget(quickstart_graph)))
    with pytest.raises(KeyError):
        eng.update_graph("nope", inserts=[(0, 0, 1.0)])


# ---- ContinuousServer: deltas between steps, queue intact ----------------

def test_continuous_server_update_between_steps(quickstart_graph):
    """A delta lands between steps without draining the queue: the request
    admitted before the update is served against the updated graph."""
    rng = np.random.default_rng(8)
    a = quickstart_graph
    eng = ServingEngine(EngineConfig(device_budget_bytes=_budget(a),
                                     max_batch_features=64,
                                     clock=VirtualClock()))
    eng.register_graph("g", a)
    server = ContinuousServer(eng)

    h1, h2 = (rng.standard_normal((a.n_rows, 40)).astype(np.float32)
              for _ in range(2))
    r1 = int(server.submit(InferenceRequest("g", h1)))
    r2 = int(server.submit(InferenceRequest("g", h2)))
    s1 = server.step()                      # serves r1 against the old graph
    assert [e.request_id for e in s1.events] == [r1]
    np.testing.assert_allclose(s1.results[0].output,
                               spgemm_csr_dense(a, h1), atol=1e-4)

    rep = server.update_graph("g", inserts=[(3, 50, 0.25)])
    assert rep.segments_reused >= 1
    assert server.pending == 1              # queue survived the delta

    s2 = server.step()                      # r2 now sees the updated graph
    assert [e.request_id for e in s2.events] == [r2]
    new = eng._graphs["g"]
    np.testing.assert_allclose(s2.results[0].output,
                               spgemm_csr_dense(new, h2), atol=1e-4)
    assert server.step() is None
