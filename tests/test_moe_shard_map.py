"""shard_map expert-parallel MoE: equivalence with the GSPMD path.

Needs >1 device, so it runs in a subprocess with
--xla_force_host_platform_device_count=8 (the main test process locked
jax to 1 CPU device at import).

Triage note (2026-07): this test's "numeric assertion failure" was API
drift, not a routing bug — the subprocess crashed with AttributeError
(`jax.sharding.set_mesh` / `jax.lax.axis_size` are absent on JAX 0.4.x)
before computing anything, and the returncode assertion surfaced it as a
failure. With the compat shims the shard_map path matches the GSPMD
reference to <1e-4 unchanged.
"""
import os
import subprocess
import sys
import textwrap

import pytest


@pytest.mark.slow
def test_shard_map_moe_matches_reference():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, %r)
        import numpy as np, jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_config
        from repro.models.moe_shard_map import moe_ffn_shard_map
        from repro.models.layers import moe_ffn
        from repro.models.transformer import _init_moe

        from repro.kernels.compat import use_mesh

        cfg = get_config("kimi_k2_1t_a32b", smoke=True)
        cfg = dataclasses.replace(cfg, n_experts=8, top_k=2,
                                  capacity_factor=8.0)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        p = _init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
        with use_mesh(mesh):
            out_sm, _ = jax.jit(lambda p_, x_: moe_ffn_shard_map(
                cfg, p_, x_, mesh, ("data",), "model"))(p, x)
        out_ref, _ = moe_ffn(cfg, p, x)
        err = float(jnp.abs(out_sm - out_ref).max())
        assert err < 1e-4, err
        print("OK", err)
    """) % (os.path.join(os.path.dirname(__file__), "..", "src"),)
    res = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=300)
    assert res.returncode == 0, res.stderr[-2000:]
    assert "OK" in res.stdout
