"""Fault-tolerance substrate: checkpoint atomicity/restore, supervisor
restart, straggler detection, elastic mesh."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import Checkpointer, latest_step
from repro.data import TokenPipeline
from repro.runtime import ElasticMesh, RunState, Supervisor, SupervisorConfig


def _tree():
    return {
        "params": {"w": jnp.arange(6.0).reshape(2, 3),
                   "layers": [{"a": jnp.ones((2,))}, {"a": jnp.zeros((2,))}]},
        "opt_state": {"m": {"w": jnp.zeros((2, 3)),
                            "layers": [{"a": jnp.zeros((2,))}] * 2},
                      "step": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    ck.save(10, tree["params"], tree["opt_state"])
    restored, step = ck.restore(tree)
    assert step == 10
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomicity_tmp_never_visible(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    ck.save(5, tree["params"], tree["opt_state"])
    # a stale tmp dir (crashed writer) must not be picked up
    os.makedirs(tmp_path / "step_9.tmp")
    assert latest_step(str(tmp_path)) == 5


def test_prune_keeps_last(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_last=2)
    tree = _tree()
    for s in (1, 2, 3, 4):
        ck.save(s, tree["params"], tree["opt_state"])
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path))
    assert steps == [3, 4]


def test_restart_reproduces_batches():
    """The seekable pipeline guarantees batch k is identical after restart."""
    p1 = TokenPipeline(1000, 16, 8, seed=3)
    p2 = TokenPipeline(1000, 16, 8, seed=3)
    t1, l1 = p1.batch_at(41)
    t2, l2 = p2.batch_at(41)
    np.testing.assert_array_equal(t1, t2)
    # sharded pipelines partition the batch deterministically
    shards = [TokenPipeline(1000, 16, 8, seed=3, shard_index=i, shard_count=4)
              for i in range(4)]
    batches = [s.batch_at(7)[0] for s in shards]
    assert all(b.shape == (2, 16) for b in batches)


def test_supervisor_restarts_until_success(tmp_path):
    calls = {"n": 0}

    def body(start):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("simulated node failure")
        return start + 100

    sup = Supervisor(SupervisorConfig(max_restarts=5, backoff_s=0.0))
    state = sup.run(body, restore=lambda: 0)
    assert state.restarts == 2 and state.step == 100


def test_supervisor_gives_up():
    sup = Supervisor(SupervisorConfig(max_restarts=1, backoff_s=0.0))
    with pytest.raises(RuntimeError, match="max_restarts"):
        sup.run(lambda s: (_ for _ in ()).throw(RuntimeError("x")))


def test_straggler_detection():
    sup = Supervisor(SupervisorConfig(straggler_factor=3.0))
    assert not sup.observe_step(1.0)
    for _ in range(5):
        assert not sup.observe_step(1.1)
    assert sup.observe_step(10.0)           # 10x the EWMA
    assert sup.state.straggler_events == 1
    assert sup.stream_deadline() is not None


def test_elastic_mesh_resize():
    em = ElasticMesh(model_parallel=4)
    assert em.shape_for(16) == (4, 4)
    assert em.shape_for(12) == (3, 4)
    assert em.shape_for(7) == (7, 1)   # degraded but functional
    assert em.local_batch(256, 16) == 64
