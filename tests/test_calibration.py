"""Cost calibration + schedule autotuning (repro.core.calibration/autotune).

Covers the observe -> fit -> reprice -> search loop end to end:

  * calibrator recovery: the per-path least-squares fit recovers known
    (bandwidth, latency) coefficients exactly from noiseless transfers,
    and falls back to bandwidth-only on degenerate designs;
  * convergence: the trust-blended calibrated spec's prediction error
    against a drifted ground-truth system shrinks strictly round over
    round (the property BENCH_autotune.json persists);
  * identity: zero observations => `calibrated(base) is base`, and an
    engine with a fresh calibrator prices and serves byte-identically to
    one without (calibration off by default stays bit-exact);
  * engine wiring: a calibrator generation move invalidates the
    `_pass_costs` memo and reprices queued requests — both the live
    queue and a detached `prepare_queue` list;
  * autotuner: never predicted worse than default, tuned bucket sets
    stream fewer bytes, `install_schedule` swaps the pipeline and keeps
    serving outputs identical;
  * spec-derived coalescing threshold (`min_bytes=None`).
"""
import dataclasses
from types import SimpleNamespace

import numpy as np
import pytest

from benchmarks.bench_autotune import drifted_spec, replay_plan_transfers
from repro.core import (
    CostCalibrator,
    TransferCoalescingPass,
    TunedSchedule,
    autotune_schedule,
    bucket_set_bytes,
    candidate_bucket_sets,
    plan_memory_dense_features,
)
from repro.core.autotune import DEFAULT_MIN_BYTES, DEFAULT_PASS_ORDER
from repro.io.tiers import Path, TieredMemorySystem, TPU_V5E_SYSTEM
from repro.runtime import (
    EngineConfig,
    InferenceRequest,
    ServingEngine,
    VirtualClock,
)


@pytest.fixture(scope="module")
def graph():
    from repro.data import (
        SUITESPARSE_SPECS, generate_graph, normalized_adjacency, scaled_spec,
    )

    a = normalized_adjacency(generate_graph(
        scaled_spec(SUITESPARSE_SPECS["socLJ1"], 1e-4), seed=0))
    a.validate()
    return a


@pytest.fixture(scope="module")
def budget(graph):
    est = plan_memory_dense_features(graph, graph.n_rows, 64, float("inf"))
    return int(est.m_b + est.m_c + 0.6 * graph.nbytes())


def make_engine(graph, budget, **cfg) -> ServingEngine:
    eng = ServingEngine(EngineConfig(
        device_budget_bytes=budget, clock=VirtualClock(), **cfg))
    eng.register_graph("g", graph)
    return eng


def request(graph, width=16, hidden=16, seed=1):
    rng = np.random.default_rng(seed)
    h = rng.standard_normal((graph.n_rows, width)).astype(np.float32)
    w = [rng.standard_normal((width, hidden)).astype(np.float32)]
    return InferenceRequest("g", h, w)


# ---- calibrator fits -------------------------------------------------------


def test_fit_recovers_coefficients_exactly():
    true_bw, true_lat = 5e9, 1e-5
    cal = CostCalibrator()
    rng = np.random.default_rng(0)
    for _ in range(32):
        b = int(rng.integers(1 << 10, 1 << 22))
        h = int(rng.integers(1, 4))
        cal.observe_transfer(Path.DMA, b, true_lat * h + b / true_bw, hops=h)
    bw, lat = cal.fitted(Path.DMA)
    assert bw == pytest.approx(true_bw, rel=1e-6)
    assert lat == pytest.approx(true_lat, rel=1e-6)


def test_degenerate_design_falls_back_to_bandwidth_only():
    """Every sample at the same (bytes, hops) cannot separate setup from
    bandwidth: the fit keeps the base latency and still reproduces the
    observed seconds at the observed size."""
    spec = TPU_V5E_SYSTEM
    base_lat = spec.latency_s[Path.GDS]
    cal = CostCalibrator()
    nbytes, seconds = 4096, 3.3e-5
    for _ in range(5):
        cal.observe_transfer(Path.GDS, nbytes, seconds)
    bw, lat = cal.fitted(Path.GDS, base=spec)
    assert lat == base_lat
    assert lat + nbytes / bw == pytest.approx(seconds, rel=1e-9)


def test_observe_records_recovers_payload_from_wire_bytes():
    """TransferRecords store wire bytes (payload x hops); the fit must be
    over payload, so a multi-hop record round-trips the model."""
    true_bw, true_lat = 40e9, 2e-6
    tms = TieredMemorySystem(dataclasses.replace(
        TPU_V5E_SYSTEM, bw={**TPU_V5E_SYSTEM.bw, Path.ICI: true_bw},
        latency_s={**TPU_V5E_SYSTEM.latency_s, Path.ICI: true_lat}))
    from repro.io.tiers import MemoryTier
    rng = np.random.default_rng(1)
    for _ in range(16):
        tms.transfer(Path.ICI, MemoryTier.DEVICE, MemoryTier.DEVICE,
                     int(rng.integers(1 << 12, 1 << 20)),
                     hops=int(rng.integers(1, 4)))
    cal = CostCalibrator()
    assert cal.observe_records(tms.transfers) == 16
    bw, lat = cal.fitted(Path.ICI)
    assert bw == pytest.approx(true_bw, rel=1e-6)
    assert lat == pytest.approx(true_lat, rel=1e-6)


def test_zero_observations_is_identity():
    cal = CostCalibrator()
    assert cal.calibrated(TPU_V5E_SYSTEM) is TPU_V5E_SYSTEM
    assert cal.generation == 0
    assert cal.fitted(Path.DMA) is None
    assert cal.estimates(TPU_V5E_SYSTEM) == []


def test_trust_blend_converges_geometrically():
    """Each observation round moves the calibrated bandwidth a `blend`
    fraction of the remaining gap (in inverse-bandwidth space)."""
    spec = TPU_V5E_SYSTEM
    true_bw = spec.bw[Path.DMA] * 0.5
    cal = CostCalibrator(blend=0.5)
    gaps = []
    for _ in range(6):
        cal.observe_transfer(Path.DMA, 1 << 20,
                             spec.latency_s[Path.DMA]
                             + (1 << 20) / true_bw)
        calib = cal.calibrated(spec)
        gaps.append(abs(1.0 / calib.bw[Path.DMA] - 1.0 / true_bw))
    for prev, cur in zip(gaps, gaps[1:]):
        assert cur < prev
        assert cur == pytest.approx(prev * 0.5, rel=1e-6)


def test_error_channel_scales_only_unfitted_paths():
    spec = TPU_V5E_SYSTEM
    cal = CostCalibrator()
    # DMA gets a direct fit at exactly the base coefficients.
    cal.observe_transfer(Path.DMA, 1 << 16,
                         spec.latency_s[Path.DMA]
                         + (1 << 16) / spec.bw[Path.DMA])
    # Requests ran 2x slower than predicted.
    assert cal.observe_batch(
        [SimpleNamespace(predicted_s=1.0, processing_s=2.0)]) == 1
    assert cal.error_scale > 1.0
    calib = cal.calibrated(spec)
    # Unfitted paths slow down by the error scale...
    assert calib.bw[Path.GDS] < spec.bw[Path.GDS]
    assert calib.latency_s[Path.GDS] > spec.latency_s[Path.GDS]
    # ...fitted paths follow their own fit, and HBM never moves.
    assert calib.bw[Path.DMA] == pytest.approx(spec.bw[Path.DMA], rel=1e-9)
    assert calib.hbm_bw == spec.hbm_bw
    assert calib.device_capacity == spec.device_capacity
    # Samples without a usable prediction are skipped.
    assert not cal.observe_error(
        SimpleNamespace(predicted_s=0.0, processing_s=1.0))


# ---- convergence against a drifted ground truth ----------------------------


def test_prediction_error_strictly_decreases(graph, budget):
    true_spec = drifted_spec(TPU_V5E_SYSTEM)
    cal = CostCalibrator()
    eng = make_engine(graph, budget, calibrator=cal)
    req = request(graph)
    errs = []
    for _ in range(4):
        predicted = eng.estimate_request_cost(req)
        plan = eng._engines["g"].stream_plan(
            graph, (graph.n_rows, 16), spec=true_spec)
        actual = plan.estimate(true_spec).makespan_s
        errs.append(abs(predicted - actual))
        tms = TieredMemorySystem(true_spec)
        replay_plan_transfers(plan, tms)
        cal.observe_records(tms.transfers)
    assert all(b < a for a, b in zip(errs, errs[1:])), errs
    assert errs[-1] < 0.2 * errs[0]


# ---- engine wiring ---------------------------------------------------------


def test_calibration_off_is_bit_exact(graph, budget):
    """A calibrator with zero observations must not perturb anything:
    same predictions, byte-identical outputs, same byte accounting."""
    def one(calibrator):
        eng = make_engine(graph, budget, calibrator=calibrator)
        eng.submit(request(graph, seed=7))
        return eng.run_batch()

    off, on = one(None), one(CostCalibrator())
    assert ([l.predicted_s for l in off.request_latency]
            == [l.predicted_s for l in on.request_latency])
    assert off.uploaded_bytes == on.uploaded_bytes
    assert off.cache_hit_bytes == on.cache_hit_bytes
    for r0, r1 in zip(off.results, on.results):
        assert np.array_equal(r0.output, r1.output)


def test_generation_move_invalidates_memo_and_reprices_queue(graph, budget):
    cal = CostCalibrator()
    eng = make_engine(graph, budget, calibrator=cal,
                      max_queue_cost_s=1e9)   # forces pricing at submit
    receipt = eng.submit(request(graph))
    c0 = receipt.estimated_cost_s
    assert c0 > 0.0
    assert eng._pass_costs
    # Traffic shows DMA running 10x slower than spec.
    slow_bw = TPU_V5E_SYSTEM.bw[Path.DMA] / 10.0
    for nbytes in (1 << 16, 1 << 18, 1 << 20):
        cal.observe_transfer(Path.DMA, nbytes,
                             TPU_V5E_SYSTEM.latency_s[Path.DMA]
                             + nbytes / slow_bw)
    c1 = eng.estimate_request_cost(request(graph))
    assert c1 > c0           # slower bandwidth => dearer pass
    # The queued request was repriced by the generation sweep.
    assert eng._queue[0].estimated_cost_s == pytest.approx(c1)
    assert eng.queued_cost_s() == pytest.approx(c1)


def test_prepare_queue_reprices_detached_queue(graph, budget):
    """`run_batch` detaches the queue before `prepare_queue`; staleness
    must still reprice it there (the cost_spec sweep can't reach it)."""
    cal = CostCalibrator()
    eng = make_engine(graph, budget, calibrator=cal, max_queue_cost_s=1e9)
    eng.submit(request(graph))
    queue, eng._queue = eng._queue, []
    c0 = queue[0].estimated_cost_s
    slow_bw = TPU_V5E_SYSTEM.bw[Path.DMA] / 10.0
    cal.observe_transfer(Path.DMA, 1 << 20,
                         TPU_V5E_SYSTEM.latency_s[Path.DMA]
                         + (1 << 20) / slow_bw)
    ready, expired = eng.prepare_queue(queue, eng.clock())
    assert not expired
    assert ready[0].estimated_cost_s > c0


def test_run_batch_feeds_calibrator(graph, budget):
    cal = CostCalibrator()
    eng = make_engine(graph, budget, calibrator=cal, max_queue_cost_s=1e9)
    eng.submit(request(graph))
    assert cal.generation == 0
    eng.run_batch()
    assert cal.generation > 0          # error channel observed the batch
    assert cal.error_scale != 1.0 or cal._error_n > 0


# ---- autotuner -------------------------------------------------------------


def test_autotune_never_predicted_worse_than_default(graph, budget):
    eng = make_engine(graph, budget)
    tuned = eng.autotune("g")
    assert isinstance(tuned, TunedSchedule)
    assert tuned.predicted_makespan_s <= tuned.default_makespan_s
    assert tuned.predicted_speedup >= 1.0
    assert tuned.ell_bytes <= tuned.default_ell_bytes
    # Building the tuned passes round-trips the order.
    names = []
    for p in tuned.build_passes():
        names.append("transfer-coalescing"
                     if isinstance(p, TransferCoalescingPass)
                     else "shard-placement")
    assert tuple(names) == tuned.pass_order


def test_autotune_respects_custom_grid(graph, budget):
    eng = make_engine(graph, budget)
    tuned = autotune_schedule(
        eng._engines["g"], graph, graph="g", width=16,
        spec=TPU_V5E_SYSTEM, min_bytes_grid=(DEFAULT_MIN_BYTES,),
        bucket_sets=[None])
    assert tuned.min_bytes == DEFAULT_MIN_BYTES
    assert tuned.ell_buckets is None
    assert tuned.pass_order in (DEFAULT_PASS_ORDER,
                                tuple(reversed(DEFAULT_PASS_ORDER)))
    assert tuned.predicted_makespan_s <= tuned.default_makespan_s


def test_install_schedule_swaps_pipeline_and_keeps_outputs(graph, budget):
    base = make_engine(graph, budget)
    base.submit(request(graph, seed=11))
    expect = base.run_batch().results[0].output

    eng = make_engine(graph, budget)
    eng.estimate_request_cost(request(graph))   # warm the memo
    assert eng._pass_costs
    tuned = eng.autotune("g", install=True)
    assert eng.installed_schedules["g"] == tuned
    assert not eng._pass_costs                  # memo invalidated
    spg = eng._engines["g"]
    assert spg.plan_passes is not None
    if tuned.ell_buckets is not None:
        assert spg.config.ell_buckets == list(tuned.ell_buckets)
    # A tuned schedule reshapes transfers, never the math.
    eng.submit(request(graph, seed=11))
    got = eng.run_batch().results[0].output
    np.testing.assert_allclose(got, expect, rtol=1e-5, atol=1e-5)


def test_bucket_set_bytes_and_candidates():
    widths, rows = [3, 5, 9], [128, 256, 128]
    pow2 = bucket_set_bytes(widths, rows, None, bm=128, bk=128)
    exact = bucket_set_bytes(widths, rows, (3, 5, 9), bm=128, bk=128)
    assert exact < pow2        # pow2 pads 3->4, 5->8, 9->16
    with pytest.raises(ValueError):
        bucket_set_bytes(widths, rows, (3, 5), bm=128, bk=128)  # 9 can't fit
    cands = candidate_bucket_sets(widths)
    assert cands[0] is None
    assert (3, 5, 9) in cands
    many = candidate_bucket_sets(list(range(1, 20)), max_buckets=4)
    ladder = [c for c in many if c is not None][0]
    assert len(ladder) <= 4 and max(ladder) == 19


# ---- spec-derived coalescing threshold -------------------------------------


def test_coalescing_threshold_derivation():
    spec = TPU_V5E_SYSTEM
    derived = TransferCoalescingPass(min_bytes=None)
    assert derived.threshold(spec, Path.DMA) == max(
        1, int(spec.bw[Path.DMA] * spec.latency_s[Path.DMA]))
    # No spec to derive from => the documented static default.
    assert (derived.threshold(None, Path.DMA)
            == TransferCoalescingPass.DEFAULT_MIN_BYTES)
    # Explicit min_bytes wins regardless of spec.
    fixed = TransferCoalescingPass(min_bytes=4096)
    assert fixed.threshold(spec, Path.DMA) == 4096
    assert TransferCoalescingPass.DEFAULT_MIN_BYTES == 1 << 18
    with pytest.raises(ValueError):
        TransferCoalescingPass(min_bytes=0)


# ---- partition-aware autotune arm ------------------------------------------


def test_autotune_partition_arm_prices_warm_ici():
    """On a sharded cache the autotuner prices connectivity-clustered
    owner maps by modeled warm-epoch ICI bytes and only keeps a cluster
    count that strictly beats the CRC default."""
    from repro.data import generate_sbm_graph, normalized_adjacency
    from repro.io.tiers import ICI_RING

    a = normalized_adjacency(generate_sbm_graph(
        512, 4096, n_blocks=4, p_in=0.95, seed=0))
    est = plan_memory_dense_features(a, a.n_rows, 32, float("inf"))
    b = int(est.m_b + est.m_c + 0.6 * a.nbytes())
    eng = ServingEngine(EngineConfig(
        device_budget_bytes=b, cache_device_bytes=b, cache_shards=4,
        ici_topology=ICI_RING, max_batch_features=32, clock=VirtualClock()))
    eng.register_graph("g", a)
    tuned = eng.autotune("g", width=32)
    assert tuned.default_warm_ici_bytes > 0, \
        "CRC owners on 4 ring shards must model some warm ICI traffic"
    assert tuned.warm_ici_bytes <= tuned.default_warm_ici_bytes
    if tuned.partition_clusters is not None:
        assert tuned.partition_clusters > 1
        assert tuned.warm_ici_bytes < tuned.default_warm_ici_bytes
    # Installing round-trips the cluster count onto the graph's engine.
    eng.install_schedule(tuned)
    spg = eng._engines["g"]
    if tuned.partition_clusters is None:
        assert spg.partition is None
    else:
        assert spg.partition.n_clusters == tuned.partition_clusters


def test_autotune_skips_partition_arm_without_sharded_cache(graph, budget):
    tuned = make_engine(graph, budget).autotune("g")
    assert tuned.partition_clusters is None
    assert tuned.warm_ici_bytes == 0
    assert tuned.default_warm_ici_bytes == 0
