"""Plan-rewrite pass framework (ISSUE 5): identity-pipeline behavior
preservation, per-pass properties, and the sharded-placement acceptance run.

Acceptance criteria covered here:
  * with an empty/identity `PassPipeline`, simulate metrics are float-equal
    to tests/data/golden_pipeline.json and execute outputs + BatchReports
    are bit-exact with PR-4 behavior (cache on/off, 1- and 4-shard);
  * coalescing conserves total bytes per path; placement never increases
    `ici_bytes`; EDF-with-tardy-demotion never increases deadline misses
    (hypothesis-driven when installed, deterministic sweep otherwise);
  * a 4-shard × 2-worker warm epoch streams strictly fewer ICI bytes with
    the placement pass enabled, with bit-identical outputs.
"""
import importlib.util
import json
import os

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import (
    AiresConfig,
    AiresSpGEMM,
    CacheProbeOp,
    ComputeOp,
    CostInterpreter,
    EDFOrderingPass,
    FeatureSpec,
    PassPipeline,
    PhaseSpec,
    PipelinePlan,
    PlanValidationError,
    SCHEDULERS,
    ShardPlacementPass,
    TransferCoalescingPass,
    TransferOp,
    deadline_order,
    edf_sort,
    plan_memory_dense_features,
)
from repro.core.analysis import diff_path_totals, path_byte_totals
from repro.core.pipeline import LANE_COMPUTE, LANE_DMA
from repro.io import (
    CacheDirectory,
    ICI_RING,
    ICI_ALL_TO_ALL,
    ShardedSegmentCache,
    TieredSegmentCache,
)
from repro.io.segment_cache import SegmentKey
from repro.io.shard_cache import shard_of
from repro.io.tiers import MemoryTier, PAPER_GPU_SYSTEM, Path
from repro.runtime import EngineConfig, InferenceRequest, ServingEngine
from repro.sparse.ref_spgemm import spgemm_csr_dense

HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None
GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "data",
                           "golden_pipeline.json")
METRIC_FIELDS = [
    "makespan_s", "io_modeled_s", "compute_modeled_s", "host_preprocess_s",
    "bytes_by_path", "seconds_by_path", "total_transfer_bytes",
    "cache_hit_bytes", "merge_events", "merge_io_s", "segments", "oom",
]


@pytest.fixture(scope="module")
def small_graph():
    from repro.data import (
        SUITESPARSE_SPECS, generate_graph, normalized_adjacency, scaled_spec,
    )

    a = normalized_adjacency(generate_graph(
        scaled_spec(SUITESPARSE_SPECS["socLJ1"], 1e-4), seed=0))
    a.validate()
    return a


def _budget(a, width=64, a_frac=0.6):
    est = plan_memory_dense_features(a, a.n_rows, width, float("inf"))
    return int(est.m_b + est.m_c + a_frac * a.nbytes())


# ---- satellite bugfix: plan validation -------------------------------------


def _tiny_plan():
    p = PipelinePlan(scheduler="t")
    p.phases = [PhaseSpec("p")]
    return p


def test_validate_rejects_dangling_dep():
    p = _tiny_plan()
    p.add(TransferOp(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE, 8),
          "p", LANE_DMA, deps=(3,))
    with pytest.raises(PlanValidationError, match="dangling"):
        p.validate()
    q = _tiny_plan()
    q.add(ComputeOp(1e-6), "p", LANE_COMPUTE, deps=(-1,))
    with pytest.raises(PlanValidationError, match="dangling"):
        q.validate()


def test_validate_rejects_cycles_and_forward_refs():
    p = _tiny_plan()
    i0 = p.add(TransferOp(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE, 8),
               "p", LANE_DMA, deps=(1,))   # forward edge of a 2-cycle
    p.add(ComputeOp(1e-6), "p", LANE_COMPUTE, deps=(i0,))
    with pytest.raises(PlanValidationError, match="topological"):
        p.validate()
    q = _tiny_plan()
    q.add(ComputeOp(1e-6), "p", LANE_COMPUTE, deps=(0,))  # self-cycle
    with pytest.raises(PlanValidationError, match="cycle"):
        q.validate()


def test_validate_rejects_op_bearing_plan_without_phases():
    """The `if declared and ...` loophole is closed: an op-bearing plan
    with an empty phase list used to pass validation, and every op then
    sat in an undeclared phase whose span never entered the makespan."""
    p = PipelinePlan(scheduler="t")
    p.add(ComputeOp(1e-6), "p", LANE_COMPUTE)
    with pytest.raises(PlanValidationError, match="declares no phases"):
        p.validate()
    # Empty plans stay valid — builders return one (oom=True) for
    # infeasible budgets before declaring any phase.
    PipelinePlan(scheduler="t").validate()
    PipelinePlan(scheduler="t", oom=True).validate()


def test_validate_rejects_undeclared_and_duplicate_phases():
    p = _tiny_plan()
    p.add(ComputeOp(1e-6), "nope", LANE_COMPUTE)
    with pytest.raises(PlanValidationError, match="undeclared"):
        p.validate()
    q = PipelinePlan(scheduler="t")
    q.phases = [PhaseSpec("p"), PhaseSpec("p")]
    with pytest.raises(PlanValidationError, match="duplicate"):
        q.validate()


def test_interpreter_refuses_malformed_plan():
    """The silent mis-order is gone: interpreting a plan with a dangling
    dep raises instead of reading completion time 0.0."""
    p = _tiny_plan()
    p.add(TransferOp(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE, 8),
          "p", LANE_DMA, deps=(7,))
    with pytest.raises(PlanValidationError):
        CostInterpreter(PAPER_GPU_SYSTEM).run(p)


def test_valid_builder_plans_pass_validation(small_graph):
    a = small_graph
    h = FeatureSpec(a.n_rows, 32, 4, 0.0)
    for name in SCHEDULERS:
        plan = SCHEDULERS[name](PAPER_GPU_SYSTEM,
                                device_budget=_budget(a)).build_plan(a, h)
        assert plan.validate() is plan


# ---- acceptance: identity pipeline is behavior-preserving ------------------


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN_PATH) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def fig6_setup():
    from benchmarks.common import SCALE, budget_for, dataset, feature_spec

    if SCALE != 1e-3:
        pytest.skip("golden metrics were frozen at SCALE=1e-3 "
                    "(AIRES_BENCH_SCALE overrides the benchmark scale)")
    out = {}
    for name in ["rUSA", "kV2a", "kU1a", "socLJ1", "kP1a"]:
        a = dataset(name)
        feat = feature_spec(a)
        out[name] = (a, feat, budget_for(name, a, feat))
    return out


@pytest.mark.parametrize("sched", ["maxmemory", "ucg", "etc", "aires"])
@pytest.mark.parametrize("name", ["rUSA", "kV2a", "kU1a", "socLJ1", "kP1a"])
def test_identity_pipeline_simulate_matches_golden(golden, fig6_setup,
                                                   name, sched):
    """run() = build → (identity rewrite) → interpret must be float-equal
    to the pre-refactor goldens on every fig6 configuration."""
    a, feat, budget = fig6_setup[name]
    res = SCHEDULERS[sched](PAPER_GPU_SYSTEM, device_budget=budget,
                            passes=PassPipeline([])).run(
        a, feat, mode="simulate", dataset=name)
    assert res.pass_reports == []
    want = golden["fig6"][f"{name}/{sched}"]
    for field in METRIC_FIELDS:
        got = getattr(res.metrics, field)
        assert got == want[field], (
            f"{name}/{sched}.{field}: {got!r} != golden {want[field]!r}")


def test_identity_pipeline_execute_bit_exact(small_graph):
    a = small_graph
    rng = np.random.default_rng(5)
    h = rng.standard_normal((a.n_rows, 16)).astype(np.float32)
    kw = dict(device_budget=_budget(a, width=16), bm=8, bk=8)
    x0 = SCHEDULERS["aires"](PAPER_GPU_SYSTEM, **kw).run(
        a, h, mode="execute").x
    x1 = SCHEDULERS["aires"](PAPER_GPU_SYSTEM, passes=PassPipeline([]),
                             **kw).run(a, h, mode="execute").x
    np.testing.assert_array_equal(x0, x1)


def _report_fields(rep):
    return {
        "uploaded_bytes": rep.uploaded_bytes,
        "cache_hit_bytes": rep.cache_hit_bytes,
        "promoted_bytes": rep.promoted_bytes,
        "segments_streamed": rep.segments_streamed,
        "aggregation_passes": rep.aggregation_passes,
        "ici_bytes": rep.ici_bytes,
        "directory_hit_bytes": rep.directory_hit_bytes,
        "duplicate_avoided_bytes": rep.duplicate_avoided_bytes,
    }


def test_identity_pipeline_engine_reports_bitexact(golden, small_graph):
    """The PR-4 golden BatchReport scenarios — cache on, cache off, and
    4-shard × 2 workers — reproduce bit-exactly with an (empty) engine
    PassPipeline configured."""
    a = small_graph
    rng = np.random.default_rng(1)
    h = rng.standard_normal((a.n_rows, 32)).astype(np.float32)
    w = [rng.standard_normal((32, 16)).astype(np.float32)]
    budget = _budget(a)
    engine_golden = golden["engine"]

    for label, kw, nworkers in [("cache_on", {}, 1),
                                ("cache_off", {"cache_enabled": False}, 1),
                                ("shard4", {"cache_shards": 4}, 2)]:
        directory = CacheDirectory() if nworkers > 1 else None
        workers = [
            ServingEngine(EngineConfig(device_budget_bytes=budget,
                                       max_batch_features=64,
                                       worker_id=wid, plan_passes=(), **kw),
                          directory=directory)
            for wid in range(nworkers)
        ]
        for eng in workers:
            eng.register_graph("lj", a)
        reports = []
        for _epoch in range(2):
            for eng in workers:
                eng.submit(InferenceRequest("lj", h, w))
                reports.append(eng.run_batch())
        for i, (got, want) in enumerate(zip(reports, engine_golden[label])):
            assert _report_fields(got) == want, (label, i)


# ---- transfer coalescing ---------------------------------------------------


def _random_plan(rng):
    """A random (valid) multi-lane, multi-phase plan of small transfers,
    computes and host ops — the coalescing property-test input."""
    plan = PipelinePlan(scheduler="prop")
    plan.phases = [PhaseSpec("a"), PhaseSpec("b", overlap="serial")]
    paths = [Path.DMA, Path.GDS, Path.STORAGE_HOST]
    lanes = [LANE_DMA, "gds", ""]
    last = None
    for _ in range(int(rng.integers(2, 40))):
        kind = rng.integers(0, 4)
        phase = "a" if rng.integers(0, 2) else "b"
        if kind < 2:
            p = paths[int(rng.integers(0, len(paths)))]
            deps = (last,) if (last is not None and rng.integers(0, 3) == 0) \
                else ()
            last = plan.add(
                TransferOp(p, MemoryTier.HOST, MemoryTier.DEVICE,
                           int(rng.integers(1, 1 << 20)),
                           merge=bool(rng.integers(0, 2))),
                phase, lanes[int(rng.integers(0, len(lanes)))], deps=deps)
        elif kind == 2:
            deps = (last,) if last is not None else ()
            last = plan.add(ComputeOp(float(rng.random()) * 1e-4),
                            phase, LANE_COMPUTE, deps=deps)
        else:
            from repro.core import HostPreprocessOp
            last = plan.add(HostPreprocessOp(1e-6), phase, "host")
    return plan


def _assert_coalescing_invariants(plan, min_bytes):
    # strict=True: the shared analyzer enforces per-path byte conservation
    # inside apply() — the same diff CI's scripts/lint_plans.py runs, so
    # this test and the lint gate cannot drift.
    pipeline = PassPipeline([TransferCoalescingPass(min_bytes=min_bytes)],
                            spec=PAPER_GPU_SYSTEM, strict=True)
    before = plan.estimate(PAPER_GPU_SYSTEM)
    out, reports = pipeline.apply(plan)
    out.validate()
    after = out.estimate(PAPER_GPU_SYSTEM)
    # bytes per path conserved exactly (analyzer diff helper: {} = no delta)
    assert diff_path_totals(path_byte_totals(plan),
                            path_byte_totals(out)) == {}
    # fewer (or equal) transfer ops, never more setup latency
    n_before = sum(isinstance(b.op, TransferOp) for b in plan.ops)
    n_after = sum(isinstance(b.op, TransferOp) for b in out.ops)
    assert n_after <= n_before
    assert after.io_modeled_s <= before.io_modeled_s + 1e-15
    assert reports and reports[0].pass_name == "transfer-coalescing"
    assert not any(f.severity == "error" for f in reports[0].findings)


def test_coalescing_conserves_bytes_per_path_property():
    if HAVE_HYPOTHESIS:
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=40, deadline=None)
        @given(st.integers(0, 2**32 - 1), st.sampled_from([1 << 12, 1 << 20]))
        def prop(seed, min_bytes):
            _assert_coalescing_invariants(
                _random_plan(np.random.default_rng(seed)), min_bytes)

        prop()
    else:
        for seed in range(40):
            for min_bytes in (1 << 12, 1 << 20):
                _assert_coalescing_invariants(
                    _random_plan(np.random.default_rng(seed)), min_bytes)


def test_coalescing_merges_small_serial_transfers():
    """Three small same-path serial transfers become one DMA: same bytes,
    two setup latencies saved."""
    plan = PipelinePlan(scheduler="t")
    plan.phases = [PhaseSpec("p", overlap="serial")]
    for _ in range(3):
        plan.add(TransferOp(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE,
                            1 << 10), "p")
    out, _ = PassPipeline([TransferCoalescingPass(min_bytes=1 << 12)]).apply(
        plan)
    assert len(out.ops) == 1
    assert out.ops[0].op.nbytes == 3 * (1 << 10)
    spec = PAPER_GPU_SYSTEM
    m, _ = CostInterpreter(spec).run(out)
    assert m.makespan_s == pytest.approx(
        spec.latency_s[Path.DMA] + 3 * (1 << 10) / spec.bw[Path.DMA])


def test_coalescing_respects_threshold_and_lane_order():
    plan = PipelinePlan(scheduler="t")
    plan.phases = [PhaseSpec("p")]
    # big op between two small ones on the same lane closes the run
    plan.add(TransferOp(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE,
                        1 << 10), "p", LANE_DMA)
    plan.add(TransferOp(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE,
                        1 << 24), "p", LANE_DMA)
    plan.add(TransferOp(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE,
                        1 << 10), "p", LANE_DMA)
    out, _ = PassPipeline([TransferCoalescingPass(min_bytes=1 << 12)]).apply(
        plan)
    assert len(out.ops) == 3, "interleaved big transfer must break the run"


def test_coalescing_remaps_compute_deps(small_graph):
    """AIRES stream phase: computes dep on their segment's transfer; after
    coalescing they dep on the merged DMA — plan still validates and
    total bytes are unchanged."""
    a = small_graph
    h = FeatureSpec(a.n_rows, 16, 4, 0.0)
    sched = SCHEDULERS["aires"](PAPER_GPU_SYSTEM, device_budget=_budget(a))
    plan = sched.build_plan(a, h)
    before = plan.estimate(PAPER_GPU_SYSTEM)
    out, _ = PassPipeline([TransferCoalescingPass(min_bytes=1 << 30)]).apply(
        plan)
    out.validate()
    after = out.estimate(PAPER_GPU_SYSTEM)
    assert after.bytes_by_path == before.bytes_by_path
    assert diff_path_totals(path_byte_totals(plan),
                            path_byte_totals(out)) == {}
    n_cmp = sum(isinstance(b.op, ComputeOp) for b in out.ops)
    assert n_cmp == plan.segments


def test_coalesced_stream_executes_bit_exact(small_graph):
    """The real streamer path: a cache-off engine with coalescing uploads
    the same bytes in fewer issues and produces the identical output."""
    a = small_graph
    rng = np.random.default_rng(7)
    h = rng.standard_normal((a.n_rows, 16)).astype(np.float32)
    budget = _budget(a, width=16)

    plain = AiresSpGEMM(AiresConfig(device_budget_bytes=budget, bm=8, bk=8))
    x0 = np.asarray(plain(a, jnp.asarray(h)))
    s0 = plain.last_stream_stats
    assert s0.segments >= 2, "need >=2 segments for coalescing to act"

    co = AiresSpGEMM(
        AiresConfig(device_budget_bytes=budget, bm=8, bk=8),
        plan_passes=PassPipeline([TransferCoalescingPass(min_bytes=1 << 30)]))
    x1 = np.asarray(co(a, jnp.asarray(h)))
    s1 = co.last_stream_stats
    np.testing.assert_array_equal(x0, x1)
    assert s1.uploaded_bytes == s0.uploaded_bytes
    assert s1.segments < s0.segments, \
        "coalescing must reduce real streamer issues"


# ---- shard-aware placement -------------------------------------------------


def _probe_plan(keys, nbytes):
    plan = PipelinePlan(scheduler="t")
    plan.phases = [PhaseSpec("p")]
    for k in keys:
        miss = TransferOp(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE,
                          nbytes, tag="phaseII/seg")
        plan.add(CacheProbeOp(k, nbytes, miss, value=True), "p", LANE_DMA)
    return plan


def _placement_never_increases_ici(seed):
    rng = np.random.default_rng(seed)
    n_shards = int(rng.integers(2, 6))
    nbytes = int(rng.integers(1, 4096))
    n_keys = int(rng.integers(1, 24))
    budget = int(rng.integers(n_shards, n_shards * n_keys * 4096 + 1))
    topology = ICI_RING if rng.integers(0, 2) else ICI_ALL_TO_ALL
    keys = [SegmentKey(f"g{seed}", i, "bricks", (i,)) for i in range(n_keys)]

    def warm_ici(passes):
        cache = ShardedSegmentCache(device_budget_bytes=budget,
                                    n_shards=n_shards, topology=topology)
        sched_passes = (PassPipeline([ShardPlacementPass()])
                        if passes else PassPipeline([]))
        plan = _probe_plan(keys, nbytes)
        plan, _ = sched_passes.apply(plan, segment_cache=cache)
        # cold fill then warm reread, both interpreted for real
        CostInterpreter(PAPER_GPU_SYSTEM, segment_cache=cache).run(plan)
        m, _ = CostInterpreter(PAPER_GPU_SYSTEM, segment_cache=cache).run(plan)
        return m.bytes_by_path.get("ici", 0)

    assert warm_ici(True) <= warm_ici(False)


def test_placement_never_increases_ici_property():
    if HAVE_HYPOTHESIS:
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=40, deadline=None)
        @given(st.integers(0, 2**32 - 1))
        def prop(seed):
            _placement_never_increases_ici(seed)

        prop()
    else:
        for seed in range(60):
            _placement_never_increases_ici(seed)


def test_placement_pins_bricks_to_local_shard():
    cache = ShardedSegmentCache(device_budget_bytes=1 << 20, n_shards=4)
    keys = [SegmentKey("g", i, "bricks", (i,)) for i in range(8)]
    remote = [k for k in keys if shard_of(k, 4) != 0]
    assert remote, "CRC should scatter at least one key off shard 0"
    plan = _probe_plan(keys, 256)
    plan, _ = PassPipeline([ShardPlacementPass()]).apply(
        plan, segment_cache=cache)
    probes = [b.op for b in plan.ops if isinstance(b.op, CacheProbeOp)]
    for op in probes:
        if shard_of(op.key, 4) != 0:
            assert op.place_shard == 0, "remote key must be pinned locally"
        else:
            assert op.place_shard is None
    # interpreting the rewritten plan records the placements in the owner
    # map: warm hits are local, zero ICI
    CostInterpreter(PAPER_GPU_SYSTEM, segment_cache=cache).run(plan)
    assert all(cache.owner_of(k) == 0 for k in keys)
    m, _ = CostInterpreter(PAPER_GPU_SYSTEM, segment_cache=cache).run(plan)
    assert m.bytes_by_path.get("ici", 0) == 0
    assert m.cache_hit_bytes == 8 * 256


def test_placement_prefers_device_tiers_and_falls_back_near():
    """The tier-aware decision rules: local device first; a brick the
    owner can keep device-resident stays there (a remote device hit's ICI
    is cheaper than converting it into a local PCIe promotion); overflow
    goes to the nearest shard with device room at no more hops than the
    owner. 512 B device + 512 B host per shard, 400 B bricks, ring."""
    n = 8
    cache = ShardedSegmentCache(device_budget_bytes=n * 512,
                                host_budget_bytes=n * 512, n_shards=n,
                                topology=ICI_RING)
    assert cache.shard_headroom(0) == 512
    assert cache.shard_host_headroom(0) == 512
    # four keys sharing one far CRC owner (>= 2 hops from shard 0)
    pool = [SegmentKey("g", i, "bricks", (i,)) for i in range(512)]
    owners = {}
    for k in pool:
        owners.setdefault(shard_of(k, n), []).append(k)
    owner = next(s for s in owners
                 if cache.ici_hops(s) >= 2 and len(owners[s]) >= 4)
    plan = _probe_plan(owners[owner][:4], 400)
    plan, _ = PassPipeline([ShardPlacementPass()]).apply(
        plan, segment_cache=cache)
    placed = [b.op.place_shard for b in plan.ops
              if isinstance(b.op, CacheProbeOp)]
    assert placed[0] == 0, "first brick takes the local device headroom"
    assert placed[1] is None, \
        "the owner still has device room — keep the cheap remote-device hit"
    for p in placed[2:]:
        assert p is not None and p != 0, \
            "local and owner device tiers are full"
        assert cache.ici_hops(p) <= cache.ici_hops(owner)
    # deterministic nearest-first fill: both 1-hop neighbors of shard 0
    assert {placed[2], placed[3]} == {1, 7}


def test_placement_uses_local_host_only_under_global_device_pressure():
    """No shard's device tier can hold the brick → it will be a host-tier
    hit wherever it lands, so the pass pins it locally (promotion without
    the ICI add-on). With an unbounded host this is always capacity-safe."""
    n = 4
    cache = ShardedSegmentCache(device_budget_bytes=n * 64, n_shards=n)
    key = next(k for k in (SegmentKey("g", i, "bricks", (i,))
                           for i in range(64)) if shard_of(k, n) != 0)
    plan = _probe_plan([key], 4096)      # 4096 B >> 64 B per-shard device
    plan, _ = PassPipeline([ShardPlacementPass()]).apply(
        plan, segment_cache=cache)
    assert plan.ops[0].op.place_shard == 0


def test_placement_leaves_resident_bricks_alone():
    cache = ShardedSegmentCache(device_budget_bytes=1 << 20, n_shards=4)
    key = next(SegmentKey("g", i, "bricks", (i,)) for i in range(64)
               if shard_of(SegmentKey("g", i, "bricks", (i,)), 4) != 0)
    cache.put(key, "brick", 256)       # resident at its CRC owner
    plan = _probe_plan([key], 256)
    plan, _ = PassPipeline([ShardPlacementPass()]).apply(
        plan, segment_cache=cache)
    op = plan.ops[0].op
    assert op.place_shard is None, "warm bricks must not be migrated"


def test_placement_estimate_prices_rewritten_plan():
    """peek_cost honors the placement override: a cold estimate of the
    rewritten plan predicts no shard-place ICI for locally pinned keys."""
    cache = ShardedSegmentCache(device_budget_bytes=1 << 20, n_shards=4)
    keys = [SegmentKey("g", i, "bricks", (i,)) for i in range(8)]
    plan = _probe_plan(keys, 256)
    est_before = plan.estimate(PAPER_GPU_SYSTEM, segment_cache=cache)
    assert est_before.bytes_by_path.get("ici", 0) > 0
    plan, _ = PassPipeline([ShardPlacementPass()]).apply(
        plan, segment_cache=cache)
    est_after = plan.estimate(PAPER_GPU_SYSTEM, segment_cache=cache)
    assert est_after.bytes_by_path.get("ici", 0) == 0
    assert len(cache) == 0, "estimating must not touch the cache"


def test_warm_epoch_ici_strictly_lower_with_placement(small_graph):
    """Scheduler-level acceptance (the fig9 --shards arm in miniature):
    warm-epoch ici_bytes strictly lower with the pass, simulate metrics
    otherwise coherent."""
    a = small_graph
    budget = _budget(a)
    feat = np.zeros((a.n_rows, 16), np.float32)

    def warm(passes):
        cache = ShardedSegmentCache(device_budget_bytes=budget, n_shards=4)
        sched = SCHEDULERS["aires"](PAPER_GPU_SYSTEM, device_budget=budget,
                                    segment_cache=cache, passes=passes)
        sched.run(a, feat)
        return sched.run(a, feat).metrics

    w0 = warm(None)
    w1 = warm(PassPipeline([ShardPlacementPass()], spec=PAPER_GPU_SYSTEM))
    assert w0.bytes_by_path.get("ici", 0) > 0, \
        "without placement, warm hits must ride ICI"
    assert (w1.bytes_by_path.get("ici", 0)
            < w0.bytes_by_path.get("ici", 0))
    assert w1.cache_hit_bytes == w0.cache_hit_bytes


# ---- the 4-shard × 2-worker engine acceptance run --------------------------


def test_sharded_two_worker_placement_acceptance(small_graph):
    """ISSUE 5 acceptance: 4 cache shards × 2 replicated workers, warm
    epoch — the placement pass strictly reduces BatchReport.ici_bytes and
    every numerical output stays bit-identical to the pass-free run."""
    rng = np.random.default_rng(11)
    a = small_graph
    h = rng.standard_normal((a.n_rows, 32)).astype(np.float32)
    w = [rng.standard_normal((32, 16)).astype(np.float32)]
    budget = _budget(a)

    def run_epochs(plan_passes):
        directory = CacheDirectory()
        workers = [
            ServingEngine(
                EngineConfig(device_budget_bytes=budget, cache_shards=4,
                             worker_id=wid, plan_passes=plan_passes),
                directory=directory)
            for wid in (0, 1)
        ]
        for eng in workers:
            eng.register_graph("lj", a)
        epochs = []
        for _ in range(2):
            for eng in workers:
                eng.submit(InferenceRequest("lj", h, w))
                epochs.append(eng.run_batch())
        return epochs

    base = run_epochs(None)
    placed = run_epochs([ShardPlacementPass()])

    # bit-identical outputs, epoch by epoch, worker by worker
    for b, p in zip(base, placed):
        np.testing.assert_array_equal(b.results[0].output,
                                      p.results[0].output)
    # warm epoch (last two reports, one per worker): strictly lower ICI
    base_warm = sum(r.ici_bytes for r in base[2:])
    placed_warm = sum(r.ici_bytes for r in placed[2:])
    assert base_warm > 0, "pass-free warm epoch must cross shards"
    assert placed_warm < base_warm
    # and nothing got re-uploaded either way
    for r in base[2:] + placed[2:]:
        assert r.uploaded_bytes == 0


# ---- EDF / deadline-aware ordering -----------------------------------------


def _misses(items, order):
    t = 0.0
    missed = 0
    for cost, dl in order:
        t += cost
        if dl is not None and t > dl:
            missed += 1
    return missed


def _max_lateness(order):
    t = 0.0
    worst = 0.0
    for cost, dl in order:
        t += cost
        if dl is not None:
            worst = max(worst, t - dl)
    return worst


def _check_deadline_order(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 12))
    items = [(float(rng.random() * 10),
              None if rng.integers(0, 4) == 0 else float(rng.random() * 20))
             for _ in range(n)]
    cost_of = lambda it: it[0]
    deadline_of = lambda it: it[1]
    ordered = deadline_order(items, cost_of, deadline_of)
    assert sorted(map(id, ordered)) == sorted(map(id, items)), "permutation"
    # Moore–Hodgson optimality: never more misses than submission order
    assert _misses(items, ordered) <= _misses(items, items)
    # pure EDF: optimal max lateness (Jackson's rule)
    edf = edf_sort(items, deadline_of)
    assert _max_lateness(edf) <= _max_lateness(items) + 1e-12


def test_deadline_order_never_increases_misses_property():
    if HAVE_HYPOTHESIS:
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=100, deadline=None)
        @given(st.integers(0, 2**32 - 1))
        def prop(seed):
            _check_deadline_order(seed)

        prop()
    else:
        for seed in range(200):
            _check_deadline_order(seed)


def test_deadline_order_demotes_tardy_job():
    """The Moore–Hodgson move pure EDF misses: dropping the long job saves
    the two short ones (EDF alone would miss two deadlines here)."""
    items = [("long", 10.0, 10.0), ("s1", 2.0, 11.0), ("s2", 2.0, 13.0)]
    ordered = deadline_order(items, lambda it: it[1], lambda it: it[2])
    assert [it[0] for it in ordered] == ["s1", "s2", "long"]
    assert _misses(None, [(c, d) for _, c, d in ordered]) == 1
    # pure EDF keeps the long job first and misses both short deadlines
    edf = edf_sort(items, lambda it: it[2])
    assert [it[0] for it in edf] == ["long", "s1", "s2"]


def test_deadline_free_requests_keep_fifo_order():
    items = [(i, None) for i in range(5)]
    ordered = deadline_order(items, lambda it: 1.0, lambda it: it[1])
    assert ordered == items


def test_engine_edf_orders_earliest_deadline_first(small_graph):
    """Two graphs, the later-registered one holding the earlier deadline:
    with the EDF pass its group completes first (smaller actual_s); the
    outputs match the dense reference either way."""
    from repro.data import (
        SUITESPARSE_SPECS, generate_graph, normalized_adjacency, scaled_spec,
    )

    rng = np.random.default_rng(3)
    g1 = small_graph
    g2 = normalized_adjacency(generate_graph(
        scaled_spec(SUITESPARSE_SPECS["rUSA"], 2e-5), seed=1))
    eng = ServingEngine(EngineConfig(
        device_budget_bytes=max(_budget(g1), _budget(g2)),
        plan_passes=[EDFOrderingPass()]))
    eng.register_graph("first", g1)
    eng.register_graph("second", g2)
    h1 = rng.standard_normal((g1.n_rows, 16)).astype(np.float32)
    h2 = rng.standard_normal((g2.n_rows, 16)).astype(np.float32)
    rid_late = eng.submit(InferenceRequest("first", h1, deadline_s=120.0))
    rid_urgent = eng.submit(InferenceRequest("second", h2, deadline_s=30.0))
    rep = eng.run_batch()
    lat = {l.request_id: l for l in rep.request_latency}
    assert lat[int(rid_urgent)].actual_s < lat[int(rid_late)].actual_s, \
        "the earlier deadline must be served first"
    outs = {r.request_id: r.output for r in rep.results}
    np.testing.assert_allclose(outs[int(rid_late)],
                               spgemm_csr_dense(g1, h1), atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(outs[int(rid_urgent)],
                               spgemm_csr_dense(g2, h2), atol=1e-3, rtol=1e-3)


def test_edf_compares_deadlines_on_one_clock(small_graph):
    """Relative deadlines are converted to remaining-time at ordering:
    a request submitted earlier with a nominally larger deadline_s can
    have LESS time remaining than a fresh request — it must run first
    (ordering by the raw relative field would invert them)."""
    import time as _time

    from repro.data import (
        SUITESPARSE_SPECS, generate_graph, normalized_adjacency, scaled_spec,
    )

    rng = np.random.default_rng(4)
    g1 = small_graph
    g2 = normalized_adjacency(generate_graph(
        scaled_spec(SUITESPARSE_SPECS["rUSA"], 2e-5), seed=1))
    eng = ServingEngine(EngineConfig(
        device_budget_bytes=max(_budget(g1), _budget(g2)),
        plan_passes=[EDFOrderingPass()]))
    eng.register_graph("old", g1)
    eng.register_graph("fresh", g2)
    h1 = rng.standard_normal((g1.n_rows, 16)).astype(np.float32)
    h2 = rng.standard_normal((g2.n_rows, 16)).astype(np.float32)
    # submitted first, deadline_s 60.0 -> ~59.6 s remaining at batch time
    rid_old = eng.submit(InferenceRequest("old", h1, deadline_s=60.0))
    _time.sleep(0.4)
    # submitted later, deadline_s 59.9 -> ~59.9 s remaining (MORE time)
    rid_fresh = eng.submit(InferenceRequest("fresh", h2, deadline_s=59.9))
    rep = eng.run_batch()
    lat = {l.request_id: l for l in rep.request_latency}
    assert lat[int(rid_old)].actual_s < lat[int(rid_fresh)].actual_s, \
        "less time remaining must mean served first, regardless of the " \
        "raw relative deadline_s fields"


# ---- per-request latency predictions (satellite) ---------------------------


def test_submit_receipt_carries_prediction(small_graph):
    a = small_graph
    eng = ServingEngine(EngineConfig(device_budget_bytes=_budget(a),
                                     max_queue_cost_s=1e9))
    eng.register_graph("g", a)
    h = np.zeros((a.n_rows, 16), np.float32)
    receipt = eng.submit(InferenceRequest("g", h))
    assert isinstance(receipt, int)          # backward-compatible id
    assert receipt.estimated_cost_s > 0
    assert receipt.estimated_cost_s == pytest.approx(
        eng.estimate_request_cost(InferenceRequest("g", h)))


def test_batch_report_records_predicted_vs_actual(small_graph):
    a = small_graph
    eng = ServingEngine(EngineConfig(device_budget_bytes=_budget(a)))
    eng.register_graph("g", a)
    h = np.zeros((a.n_rows, 16), np.float32)
    w = [np.zeros((16, 8), np.float32)]
    rid0 = eng.submit(InferenceRequest("g", h))
    rid1 = eng.submit(InferenceRequest("g", h, w))
    rep = eng.run_batch()
    assert [l.request_id for l in rep.request_latency] == [rid0, rid1]
    for l in rep.request_latency:
        assert l.predicted_s > 0, "run_batch must fill unpriced predictions"
        assert l.actual_s >= l.processing_s > 0, \
            "batch-relative latency includes the group-relative one"
        assert l.error_s == l.processing_s - l.predicted_s, \
            "calibration error compares group-relative processing time"
    # the single-pass request is predicted cheaper than the 1-layer one?
    # both are one aggregation pass at width 16 — equal predictions.
    assert (rep.request_latency[0].predicted_s
            == pytest.approx(rep.request_latency[1].predicted_s))


# ---- multi-hop ICI topology ------------------------------------------------


def test_ici_topology_hops():
    assert ICI_ALL_TO_ALL.hops(0, 5, 8) == 1
    assert ICI_ALL_TO_ALL.hops(2, 2, 8) == 0
    assert ICI_RING.hops(0, 1, 8) == 1
    assert ICI_RING.hops(0, 4, 8) == 4
    assert ICI_RING.hops(0, 5, 8) == 3     # wraps the short way
    assert ICI_RING.hops(7, 0, 8) == 1
    with pytest.raises(ValueError):
        from repro.io import ICITopology
        ICITopology("mesh3d")


def test_ring_topology_charges_hop_scaled_ici():
    """A 3-hop remote put/get must charge 3× the bytes on the ICI path and
    3 per-hop latencies — the all-to-all flat link stays 1×."""
    from repro.io import TieredMemorySystem

    n = 8
    key = next(SegmentKey("g", i, "bricks", (i,)) for i in range(256)
               if ICI_RING.hops(shard_of(SegmentKey("g", i, "bricks", (i,)),
                                         n), 0, n) == 3)
    for topology, hops in ((ICI_ALL_TO_ALL, 1), (ICI_RING, 3)):
        tms = TieredMemorySystem(PAPER_GPU_SYSTEM)
        cache = ShardedSegmentCache(device_budget_bytes=1 << 20, n_shards=n,
                                    tms=tms, topology=topology)
        cache.put(key, "v", 1000)
        assert tms.bytes_by_path()[Path.ICI] == 1000 * hops
        spec = PAPER_GPU_SYSTEM
        want = spec.latency_s[Path.ICI] * hops + 1000 / spec.bw[Path.ICI]
        assert tms.seconds_by_path()[Path.ICI] == pytest.approx(want)
        cache.get(key, nbytes=1000)
        assert tms.bytes_by_path()[Path.ICI] == 2 * 1000 * hops
        assert cache.stats.ici_bytes == 2 * 1000 * hops


def test_pass_reports_expose_cost_deltas(small_graph):
    """ScheduleResult.pass_reports carries one before/after reading per
    pass, and coalescing's delta is non-positive on a serial baseline."""
    a = small_graph
    h = FeatureSpec(a.n_rows, 16, 4, 0.0)
    pipeline = PassPipeline([TransferCoalescingPass(min_bytes=1 << 30),
                             ShardPlacementPass()], spec=PAPER_GPU_SYSTEM)
    res = SCHEDULERS["maxmemory"](PAPER_GPU_SYSTEM,
                                  device_budget=4 * _budget(a),
                                  passes=pipeline).run(a, h)
    assert [r.pass_name for r in res.pass_reports] == [
        "transfer-coalescing", "shard-placement"]
    assert res.pass_reports[0].makespan_delta_s <= 0
    assert res.pass_reports[0].bytes_delta("dma") == 0
    # placement is a no-op without a sharded cache
    assert res.pass_reports[1].makespan_delta_s == 0
