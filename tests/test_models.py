"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and no NaNs (assignment
requirement §f)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import arch_ids, get_config
from repro.models import (
    init_params, forward, lm_loss, init_decode_state, decode_step, encode,
    param_count,
)
from repro.train import make_optimizer

KEY = jax.random.PRNGKey(0)
B, S = 2, 12


def _batch(cfg):
    kw = {}
    if cfg.n_vision_tokens:
        kw["vision_embeds"] = jax.random.normal(
            KEY, (B, cfg.n_vision_tokens, cfg.d_model))
    if cfg.is_enc_dec:
        kw["audio_embeds"] = jax.random.normal(
            KEY, (B, cfg.audio_frames, cfg.d_model))
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    return tokens, kw


@pytest.mark.parametrize("arch", arch_ids())
def test_smoke_forward(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, KEY)
    tokens, kw = _batch(cfg)
    logits, aux = forward(cfg, params, tokens, **kw)
    assert logits.shape == (B, S, cfg.vocab)
    assert not np.isnan(np.asarray(logits)).any()
    assert param_count(params) > 0


@pytest.mark.parametrize("arch", arch_ids())
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, KEY)
    tokens, kw = _batch(cfg)
    init_opt, opt_update = make_optimizer("adamw", lr=1e-3)
    opt = init_opt(params)

    def loss_fn(p):
        return lm_loss(cfg, p, tokens, tokens, **kw)

    l0, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(l0))
    gnorm = sum(float(jnp.sum(jnp.square(g)))
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0
    params2, _ = opt_update(params, grads, opt)
    l1 = loss_fn(params2)
    assert float(l1) < float(l0), f"{arch}: one step should reduce loss"


@pytest.mark.parametrize("arch", arch_ids())
def test_smoke_decode(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, KEY)
    state = init_decode_state(cfg, B, max_len=16)
    enc_out = None
    if cfg.is_enc_dec:
        audio = jax.random.normal(KEY, (B, cfg.audio_frames, cfg.d_model))
        enc_out = encode(cfg, params, audio)
    tok = jnp.zeros((B, 1), jnp.int32)
    for _ in range(3):
        logits, state = decode_step(cfg, params, tok, state, enc_out=enc_out)
        assert logits.shape == (B, 1, cfg.vocab)
        assert not np.isnan(np.asarray(logits)).any()
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["yi_6b", "gemma2_27b", "recurrentgemma_2b",
                                  "xlstm_125m", "mixtral_8x22b"])
def test_prefill_decode_consistency(arch):
    """Teacher-forced decode must reproduce full-sequence forward logits."""
    cfg = get_config(arch, smoke=True)
    params = init_params(cfg, KEY)
    tokens = jax.random.randint(jax.random.PRNGKey(7), (B, 6), 0, cfg.vocab)
    full_logits, _ = forward(cfg, params, tokens)

    state = init_decode_state(cfg, B, max_len=8)
    outs = []
    for t in range(6):
        logits, state = decode_step(cfg, params, tokens[:, t:t+1], state)
        outs.append(np.asarray(logits[:, 0]))
    dec = np.stack(outs, axis=1)
    np.testing.assert_allclose(dec, np.asarray(full_logits), atol=2e-3,
                               rtol=1e-3)
