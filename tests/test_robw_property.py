"""Property-based tests (hypothesis) for the RoBW invariants — the
algorithmic heart of the paper (Alg. 1)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import robw_partition, naive_partition, calc_mem
from repro.core.robw import segments_to_block_ell
from repro.sparse import csr_from_dense, csr_row_slice, block_ell_to_dense


@st.composite
def sparse_matrices(draw):
    n = draw(st.integers(8, 64))
    m = draw(st.integers(8, 64))
    density = draw(st.floats(0.01, 0.4))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    dense = (rng.random((n, m)) < density) * rng.standard_normal((n, m))
    return csr_from_dense(dense.astype(np.float32)), dense.astype(np.float32)


@settings(max_examples=30, deadline=None)
@given(sparse_matrices(), st.integers(64, 4096))
def test_robw_invariants(am, budget):
    a, dense = am
    plan = robw_partition(a, budget)
    segs = plan.segments
    # 1. Complete cover, in order, no overlap (no row ever split).
    assert segs[0].row_start == 0 and segs[-1].row_end == a.n_rows
    for s1, s2 in zip(segs, segs[1:]):
        assert s1.row_end == s2.row_start
    # 2. Budget respected unless a single row alone exceeds it.
    for seg in segs:
        if seg.n_rows > 1:
            assert seg.nbytes <= budget
    # 3. Concatenating segments reproduces A exactly.
    parts = [csr_row_slice(a, s.row_start, s.row_end) for s in segs]
    rebuilt_nnz = sum(p.nnz for p in parts)
    assert rebuilt_nnz == a.nnz
    rebuilt = np.concatenate([
        np.concatenate([p.data[p.indptr[i]:p.indptr[i+1]]
                        for i in range(p.n_rows)]) if p.nnz else np.empty(0, np.float32)
        for p in parts]) if a.nnz else np.empty(0, np.float32)
    np.testing.assert_array_equal(rebuilt, a.data)


@settings(max_examples=30, deadline=None)
@given(sparse_matrices(), st.integers(2, 16), st.integers(64, 4096))
def test_robw_alignment(am, align, budget):
    a, _ = am
    plan = robw_partition(a, budget, align=align)
    for seg in plan.segments[:-1]:
        # aligned unless the budget forced a sub-align block
        assert seg.n_rows % align == 0 or seg.nbytes >= budget // 2 or seg.n_rows == 1


@settings(max_examples=20, deadline=None)
@given(sparse_matrices(), st.integers(128, 2048))
def test_naive_partition_covers_and_flags(am, budget):
    a, _ = am
    cuts = naive_partition(a, budget)
    assert cuts[0][0] == 0 and cuts[-1][1] == a.nnz
    for (lo, hi, *_), (lo2, *_rest) in zip(cuts, cuts[1:]):
        assert hi == lo2
    # any interior cut not on a row boundary must be flagged partial
    boundaries = set(a.indptr.tolist())
    for i, (lo, hi, first_partial, last_partial) in enumerate(cuts[:-1]):
        if hi not in boundaries:
            assert last_partial


@settings(max_examples=15, deadline=None)
@given(sparse_matrices())
def test_block_ell_roundtrip(am):
    a, dense = am
    plan = robw_partition(a, max(256, a.nbytes() // 3), align=8)
    rows = 0
    out = np.zeros_like(dense)
    for seg, ell in zip(plan.segments,
                        segments_to_block_ell(a, plan, bm=8, bk=8)):
        block_dense = block_ell_to_dense(ell)
        out[seg.row_start:seg.row_end] = block_dense[: seg.n_rows]
        rows += seg.n_rows
    assert rows == a.n_rows
    np.testing.assert_allclose(out, dense, atol=1e-6)
