"""Property-based tests for the RoBW invariants — the algorithmic heart of
the paper (Alg. 1) plus the transposed backward plan (dH = Aᵀ dX).

Runs under `hypothesis` when installed (declared in requirements-test.txt);
without it, each property falls back to a deterministic seeded sweep over
the same case distribution, so the invariants stay covered in minimal
environments instead of silently skipping.
"""
import importlib.util

import numpy as np
import pytest

from repro.core import robw_partition, robw_transpose_plan, naive_partition
from repro.core.robw import segments_to_block_ell
from repro.sparse import (
    block_ell_to_dense, csr_from_dense, csr_row_slice, csr_to_dense,
    csr_transpose,
)

HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None


def _random_sparse(rng):
    """One case from the shared distribution (mirrors the hypothesis
    strategy below so both drivers exercise identical shapes)."""
    n = int(rng.integers(8, 65))
    m = int(rng.integers(8, 65))
    density = float(rng.uniform(0.01, 0.4))
    dense = ((rng.random((n, m)) < density)
             * rng.standard_normal((n, m))).astype(np.float32)
    return csr_from_dense(dense), dense


def _fallback_cases(n_cases):
    """Deterministic generator: seed i → (matrix, budget, align) tuple."""
    for seed in range(n_cases):
        rng = np.random.default_rng(seed)
        a, dense = _random_sparse(rng)
        budget = int(rng.integers(64, 4097))
        align = int(rng.integers(2, 17))
        yield a, dense, budget, align


# ---- the properties (plain functions — both drivers call these) ----------

def check_robw_invariants(a, dense, budget):
    plan = robw_partition(a, budget)
    segs = plan.segments
    # 1. Complete cover, in order, no overlap (no row ever split).
    assert segs[0].row_start == 0 and segs[-1].row_end == a.n_rows
    for s1, s2 in zip(segs, segs[1:]):
        assert s1.row_end == s2.row_start
    # 2. Budget respected unless a single row alone exceeds it.
    for seg in segs:
        if seg.n_rows > 1:
            assert seg.nbytes <= budget
    # 3. Concatenating segments reproduces A exactly.
    parts = [csr_row_slice(a, s.row_start, s.row_end) for s in segs]
    rebuilt_nnz = sum(p.nnz for p in parts)
    assert rebuilt_nnz == a.nnz
    rebuilt = np.concatenate([
        np.concatenate([p.data[p.indptr[i]:p.indptr[i + 1]]
                        for i in range(p.n_rows)]) if p.nnz
        else np.empty(0, np.float32)
        for p in parts]) if a.nnz else np.empty(0, np.float32)
    np.testing.assert_array_equal(rebuilt, a.data)


def check_robw_alignment(a, align, budget):
    plan = robw_partition(a, budget, align=align)
    for seg in plan.segments[:-1]:
        # aligned unless the budget forced a sub-align block
        assert (seg.n_rows % align == 0 or seg.nbytes >= budget // 2
                or seg.n_rows == 1)


def check_naive_partition_covers_and_flags(a, budget):
    cuts = naive_partition(a, budget)
    assert cuts[0][0] == 0 and cuts[-1][1] == a.nnz
    for (lo, hi, *_), (lo2, *_rest) in zip(cuts, cuts[1:]):
        assert hi == lo2
    # any interior cut not on a row boundary must be flagged partial
    boundaries = set(a.indptr.tolist())
    for lo, hi, first_partial, last_partial in cuts[:-1]:
        if hi not in boundaries:
            assert last_partial


def check_block_ell_roundtrip(a, dense):
    plan = robw_partition(a, max(256, a.nbytes() // 3), align=8)
    rows = 0
    out = np.zeros_like(dense)
    for seg, ell in zip(plan.segments,
                        segments_to_block_ell(a, plan, bm=8, bk=8)):
        block_dense = block_ell_to_dense(ell)
        out[seg.row_start:seg.row_end] = block_dense[: seg.n_rows]
        rows += seg.n_rows
    assert rows == a.n_rows
    np.testing.assert_allclose(out, dense, atol=1e-6)


def check_transpose_involution(a):
    """Transpose of transpose reproduces A exactly (canonical CSR arrays)."""
    att = csr_transpose(csr_transpose(a))
    assert att.shape == a.shape
    np.testing.assert_array_equal(att.indptr, a.indptr)
    np.testing.assert_array_equal(att.indices, a.indices)
    np.testing.assert_array_equal(att.data, a.data)


def check_transpose_plan_covers_nnz_once(a, dense, budget):
    """The backward plan's densified segments cover every nnz of Aᵀ exactly
    once: reassembling them reproduces denseᵀ, and segment nnz sums to
    nnz(A) — the invariant that makes the streamed dH = Aᵀ dX exact."""
    a_t, plan = robw_transpose_plan(a, max(256, budget), align=8)
    assert a_t.shape == (a.shape[1], a.shape[0])
    assert a_t.nnz == a.nnz
    assert sum(s.nnz for s in plan.segments) == a.nnz
    out = np.zeros((a.shape[1], a.shape[0]), dtype=np.float32)
    for seg, ell in zip(plan.segments,
                        segments_to_block_ell(a_t, plan, bm=8, bk=8)):
        out[seg.row_start:seg.row_end] = block_ell_to_dense(ell)[: seg.n_rows]
    np.testing.assert_allclose(out, dense.T, atol=1e-6)
    # ... and the transposed stream against ones recovers column sums of A:
    # every A-nonzero contributes to exactly one backward segment product.
    col_sums = out @ np.ones((a.shape[0],), np.float32)
    np.testing.assert_allclose(col_sums, csr_to_dense(a).T.sum(axis=1),
                               atol=1e-5)


# ---- hypothesis driver ---------------------------------------------------

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @st.composite
    def sparse_matrices(draw):
        n = draw(st.integers(8, 64))
        m = draw(st.integers(8, 64))
        density = draw(st.floats(0.01, 0.4))
        seed = draw(st.integers(0, 2**31 - 1))
        rng = np.random.default_rng(seed)
        dense = ((rng.random((n, m)) < density)
                 * rng.standard_normal((n, m))).astype(np.float32)
        return csr_from_dense(dense), dense

    @settings(max_examples=30, deadline=None)
    @given(sparse_matrices(), st.integers(64, 4096))
    def test_robw_invariants(am, budget):
        check_robw_invariants(*am, budget)

    @settings(max_examples=30, deadline=None)
    @given(sparse_matrices(), st.integers(2, 16), st.integers(64, 4096))
    def test_robw_alignment(am, align, budget):
        check_robw_alignment(am[0], align, budget)

    @settings(max_examples=20, deadline=None)
    @given(sparse_matrices(), st.integers(128, 2048))
    def test_naive_partition_covers_and_flags(am, budget):
        check_naive_partition_covers_and_flags(am[0], budget)

    @settings(max_examples=15, deadline=None)
    @given(sparse_matrices())
    def test_block_ell_roundtrip(am):
        check_block_ell_roundtrip(*am)

    @settings(max_examples=25, deadline=None)
    @given(sparse_matrices())
    def test_transpose_involution(am):
        check_transpose_involution(am[0])

    @settings(max_examples=15, deadline=None)
    @given(sparse_matrices(), st.integers(256, 4096))
    def test_transpose_plan_covers_nnz_once(am, budget):
        check_transpose_plan_covers_nnz_once(*am, budget)


# ---- deterministic fallback driver (no hypothesis installed) -------------

else:
    CASES = list(_fallback_cases(15))

    @pytest.mark.parametrize("case", range(len(CASES)))
    def test_robw_invariants(case):
        a, dense, budget, _ = CASES[case]
        check_robw_invariants(a, dense, budget)

    @pytest.mark.parametrize("case", range(len(CASES)))
    def test_robw_alignment(case):
        a, _, budget, align = CASES[case]
        check_robw_alignment(a, align, budget)

    @pytest.mark.parametrize("case", range(0, len(CASES), 2))
    def test_naive_partition_covers_and_flags(case):
        a, _, budget, _ = CASES[case]
        check_naive_partition_covers_and_flags(a, max(128, budget // 2))

    @pytest.mark.parametrize("case", range(0, len(CASES), 2))
    def test_block_ell_roundtrip(case):
        a, dense, _, _ = CASES[case]
        check_block_ell_roundtrip(a, dense)

    @pytest.mark.parametrize("case", range(len(CASES)))
    def test_transpose_involution(case):
        a, _, _, _ = CASES[case]
        check_transpose_involution(a)

    @pytest.mark.parametrize("case", range(0, len(CASES), 2))
    def test_transpose_plan_covers_nnz_once(case):
        a, dense, budget, _ = CASES[case]
        check_transpose_plan_covers_nnz_once(a, dense, budget)
