"""Static plan analyzer (ISSUE 8): clean plans stay clean, planted bugs fire
their exact rule codes, and strict pass pipelines refuse broken rewrites.

Acceptance criteria covered here:
  * all four schedulers' built plans (cache-off, tiered-cache and sharded
    variants) analyze with zero findings;
  * all three production passes analyze clean under
    `PassPipeline(strict=True)`, with (empty) findings attached to the
    `PassReport`s;
  * adversarial plans — a planted tier oversubscription, an unordered
    same-`SegmentKey` probe pair, and a byte-dropping mutation of
    `TransferCoalescingPass` — fire exactly `mem/oversubscription`,
    `race/segment-key` and `bytes/path-delta`;
  * property (hypothesis when installed): a plan whose alloc replay
    analyzes clean interprets without `OutOfMemory` at the analyzed
    capacities, and vice versa.
"""
import dataclasses
import importlib.util

import numpy as np
import pytest

from repro.core import (
    AiresConfig,
    AiresSpGEMM,
    AllocOp,
    CacheProbeOp,
    ComputeOp,
    CostInterpreter,
    EDFOrderingPass,
    FeatureSpec,
    PassPipeline,
    PhaseSpec,
    PipelinePlan,
    PlanAnalysisError,
    RULES,
    SCHEDULERS,
    ShardPlacementPass,
    TransferCoalescingPass,
    TransferOp,
    analyze_plan,
    diff_path_totals,
    path_byte_totals,
    plan_memory_dense_features,
)
from repro.core.pipeline import (
    HostPreprocessOp, LANE_COMPUTE, LANE_DMA, LANE_GDS,
)
from repro.io import ShardedSegmentCache, TieredSegmentCache
from repro.io.segment_cache import SegmentKey
from repro.io.tiers import MemoryTier, PAPER_GPU_SYSTEM, Path

HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None
SPEC = PAPER_GPU_SYSTEM


@pytest.fixture(scope="module")
def small_graph():
    from repro.data import (
        SUITESPARSE_SPECS, generate_graph, normalized_adjacency, scaled_spec,
    )

    a = normalized_adjacency(generate_graph(
        scaled_spec(SUITESPARSE_SPECS["socLJ1"], 1e-4), seed=0))
    a.validate()
    return a


def _budget(a, width=64, a_frac=0.6):
    est = plan_memory_dense_features(a, a.n_rows, width, float("inf"))
    return int(est.m_b + est.m_c + a_frac * a.nbytes())


def _plan(*phases):
    p = PipelinePlan(scheduler="t")
    p.phases = [ph if isinstance(ph, PhaseSpec) else PhaseSpec(ph)
                for ph in phases]
    return p


def _transfer(nbytes=1 << 10, path=Path.DMA, src=MemoryTier.HOST,
              dst=MemoryTier.DEVICE, **kw):
    return TransferOp(path, src, dst, nbytes, **kw)


def _probe(key, nbytes=1 << 10, **kw):
    return CacheProbeOp(key, nbytes, _transfer(nbytes, tag="phaseII/seg"),
                        **kw)


def _key(i=0, fp=""):
    return SegmentKey("g", i, "bricks", (i,), fingerprint=fp)


# ---- clean plans stay clean ------------------------------------------------


def test_all_scheduler_plans_analyze_clean(small_graph):
    a = small_graph
    feat = FeatureSpec(a.n_rows, 64, 4, 0.0)
    budget = _budget(a)
    for name, cls in SCHEDULERS.items():
        plan = cls(SPEC, device_budget=budget).build_plan(a, feat)
        report = analyze_plan(plan, spec=SPEC)
        assert report.findings == [], \
            f"{name}: {[str(f) for f in report.findings]}"


def test_cached_and_sharded_scheduler_plans_analyze_clean(small_graph):
    a = small_graph
    feat = FeatureSpec(a.n_rows, 64, 4, 0.0)
    budget = _budget(a)
    for cache in (TieredSegmentCache(device_budget_bytes=budget),
                  ShardedSegmentCache(device_budget_bytes=budget,
                                      n_shards=4)):
        sched = SCHEDULERS["aires"](SPEC, device_budget=budget,
                                    segment_cache=cache)
        plan = sched.build_plan(a, feat)
        report = analyze_plan(plan, spec=SPEC, segment_cache=cache)
        assert report.findings == []


def test_oom_plan_analyzes_empty():
    """Builder-declared infeasibility is not a finding: the interpreters
    never touch the op list either."""
    plan = PipelinePlan(scheduler="t", oom=True)
    assert analyze_plan(plan, spec=SPEC).findings == []


def test_production_passes_analyze_clean_strict(small_graph):
    """All three production passes under strict mode, against a sharded
    cache: no raise, and every PassReport carries empty findings."""
    a = small_graph
    feat = FeatureSpec(a.n_rows, 64, 4, 0.0)
    budget = _budget(a)
    cache = ShardedSegmentCache(device_budget_bytes=budget, n_shards=4)
    sched = SCHEDULERS["aires"](SPEC, device_budget=budget,
                                segment_cache=cache)
    plan = sched.build_plan(a, feat)
    pipeline = PassPipeline(
        [ShardPlacementPass(), TransferCoalescingPass(min_bytes=1 << 12),
         EDFOrderingPass()],
        spec=SPEC, strict=True)
    out, reports = pipeline.apply(plan, segment_cache=cache)
    out.validate()
    assert len(reports) == 3
    assert all(r.findings == () for r in reports)
    assert diff_path_totals(path_byte_totals(plan),
                            path_byte_totals(out)) == {}


def test_released_scheduler_plan_has_no_dangling_pins(small_graph):
    a = small_graph
    feat = FeatureSpec(a.n_rows, 64, 4, 0.0)
    budget = _budget(a)
    cache = TieredSegmentCache(device_budget_bytes=budget)
    res = SCHEDULERS["aires"](SPEC, device_budget=budget,
                              segment_cache=cache).run(a, feat)
    report = analyze_plan(res.pipeline, spec=SPEC, released=True)
    assert report.findings == []


# ---- planted bugs fire their exact rule codes ------------------------------


def test_oversubscription_rule_fires():
    plan = _plan(PhaseSpec("p", overlap="serial"))
    plan.add(AllocOp(MemoryTier.DEVICE, "huge", SPEC.device_capacity + 1),
             "p")
    plan.add(_transfer(), "p")
    report = analyze_plan(plan, spec=SPEC)
    assert [f.rule for f in report.errors] == ["mem/oversubscription"]
    assert report.errors[0].ops == (0,)
    # ... and the interpreter refuses the plan up front under analyze=True.
    with pytest.raises(PlanAnalysisError):
        CostInterpreter(SPEC, analyze=True).run(plan)
    # Point-in-time: two allocs that only jointly oversubscribe flag the
    # second, and a same-name realloc *replaces* (TieredMemorySystem
    # semantics) so it stays clean.
    plan2 = _plan(PhaseSpec("p", overlap="serial"))
    half = SPEC.device_capacity // 2 + 1
    plan2.add(AllocOp(MemoryTier.DEVICE, "a", half), "p")
    i = plan2.add(AllocOp(MemoryTier.DEVICE, "b", half), "p")
    r2 = analyze_plan(plan2, spec=SPEC)
    assert [f.rule for f in r2.errors] == ["mem/oversubscription"]
    assert r2.errors[0].ops == (i,)
    plan3 = _plan(PhaseSpec("p", overlap="serial"))
    plan3.add(AllocOp(MemoryTier.DEVICE, "a", half), "p")
    plan3.add(AllocOp(MemoryTier.DEVICE, "a", half), "p")  # realloc
    plan3.add(_transfer(), "p")
    assert analyze_plan(plan3, spec=SPEC).findings == []


def test_without_spec_budget_rules_skip():
    plan = _plan(PhaseSpec("p", overlap="serial"))
    plan.add(AllocOp(MemoryTier.DEVICE, "huge", SPEC.device_capacity + 1),
             "p")
    plan.add(_transfer(), "p")
    assert analyze_plan(plan).findings == []


def test_race_unordered_same_segment_key():
    key = _key()
    # Different lanes, no deps: unordered — the race fires.
    plan = _plan("p")
    i = plan.add(_probe(key), "p", LANE_DMA)
    j = plan.add(_probe(key), "p", LANE_GDS)
    report = analyze_plan(plan)
    assert [f.rule for f in report.errors] == ["race/segment-key"]
    assert report.errors[0].ops == (i, j)
    # Same lane: lane serialization orders them — clean.
    ordered = _plan("p")
    ordered.add(_probe(key), "p", LANE_DMA)
    ordered.add(_probe(key), "p", LANE_DMA)
    assert analyze_plan(ordered).by_rule("race/segment-key") == []
    # Cross-lane with an explicit dep — clean.
    dep = _plan("p")
    i = dep.add(_probe(key), "p", LANE_DMA)
    dep.add(_probe(key), "p", LANE_GDS, deps=(i,))
    assert analyze_plan(dep).by_rule("race/segment-key") == []
    # Different phases: declared phase order is a barrier — clean.
    phased = _plan("p", "q")
    phased.add(_probe(key), "p", LANE_DMA)
    phased.add(_probe(key), "q", LANE_GDS)
    assert analyze_plan(phased).by_rule("race/segment-key") == []
    # A serial phase is a total order — clean.
    serial = _plan(PhaseSpec("p", overlap="serial"))
    serial.add(_probe(key), "p")
    serial.add(_probe(key), "p")
    assert analyze_plan(serial).by_rule("race/segment-key") == []


def test_race_unordered_alloc_slot():
    plan = _plan("p")
    plan.add(AllocOp(MemoryTier.DEVICE, "H", 64), "p", LANE_DMA)
    plan.add(AllocOp(MemoryTier.DEVICE, "H", 32), "p", LANE_GDS)
    report = analyze_plan(plan)
    assert [f.rule for f in report.errors] == ["race/alloc-name"]
    # Distinct names on unordered lanes are distinct resources — clean.
    ok = _plan("p")
    ok.add(AllocOp(MemoryTier.DEVICE, "H", 64), "p", LANE_DMA)
    ok.add(AllocOp(MemoryTier.DEVICE, "C", 32), "p", LANE_GDS)
    assert analyze_plan(ok).by_rule("race/alloc-name") == []


def test_race_pin_and_unconsumed_payload_warn():
    key_a, key_b = _key(0), _key(1)
    plan = _plan("p")
    plan.add(_probe(key_a, pin=object()), "p", LANE_DMA)
    plan.add(_probe(key_b, pin=object()), "p", LANE_GDS)
    report = analyze_plan(plan)
    assert [f.rule for f in report.warnings] == ["race/pin"]
    assert report.ok  # warnings never fail interpretation

    stream = _plan("stream")
    stream.add(_probe(key_a, payload=(0, "ell")), "stream", LANE_DMA)
    report = analyze_plan(stream)
    assert [f.rule for f in report.warnings] == ["race/unconsumed-payload"]
    consumed = _plan("stream")
    i = consumed.add(_probe(key_a, payload=(0, "ell")), "stream", LANE_DMA)
    consumed.add(ComputeOp(1e-6), "stream", LANE_COMPUTE, deps=(i,))
    assert analyze_plan(consumed).findings == []


def test_byte_dropping_rewrite_raises_under_strict():
    class ByteDroppingPass(TransferCoalescingPass):
        """Adversarial mutation: coalesce, then halve the merged bytes."""

        name = "byte-dropper"

        def __call__(self, plan, ctx=None):
            plan = super().__call__(plan, ctx)
            for bound in plan.ops:
                if isinstance(bound.op, TransferOp):
                    bound.op.nbytes //= 2
            return plan

    def build():
        plan = _plan(PhaseSpec("p", overlap="serial"))
        for _ in range(3):
            plan.add(_transfer(1 << 10), "p")
        return plan

    with pytest.raises(PlanAnalysisError) as err:
        PassPipeline([ByteDroppingPass(min_bytes=1 << 12)],
                     strict=True).apply(build())
    assert "bytes/path-delta" in str(err.value)
    # The same rewrite sails through a non-strict pipeline — strict is
    # exactly what stands between a buggy pass and wrong output.
    out, _ = PassPipeline([ByteDroppingPass(min_bytes=1 << 12)]).apply(
        build())
    assert path_byte_totals(out) == {"dma": (3 << 10) // 2}
    # An opted-out pass (conserves_bytes=False) may change bytes.
    class ReroutingPass(ByteDroppingPass):
        conserves_bytes = False

    out, reports = PassPipeline([ReroutingPass(min_bytes=1 << 12)],
                                strict=True).apply(build())
    assert reports[-1].findings == ()


def test_strict_pipeline_attaches_findings_to_reports():
    """Warning-severity findings ride the PassReport without raising."""
    plan = _plan(PhaseSpec("p", overlap="serial"))
    plan.add(_transfer(0, tag="empty"), "p")
    plan.add(_transfer(1 << 20), "p")
    out, reports = PassPipeline(
        [TransferCoalescingPass(min_bytes=1 << 10)], spec=SPEC,
        strict=True).apply(plan)
    assert len(reports) == 1
    assert [f.rule for f in reports[0].findings] == \
        ["lint/zero-byte-transfer"]
    assert reports[0].before is not None  # cost tracking still on


# ---- semantic lints --------------------------------------------------------


def test_lint_negative_and_zero_bytes():
    plan = _plan(PhaseSpec("p", overlap="serial"))
    plan.add(_transfer(-4, tag="neg"), "p")
    plan.add(_transfer(0, tag="zero"), "p")
    report = analyze_plan(plan)
    assert [f.rule for f in report.errors] == ["lint/negative-bytes"]
    assert [f.rule for f in report.warnings] == ["lint/zero-byte-transfer"]


def test_lint_miss_dst_tier():
    plan = _plan("p")
    miss = _transfer(64, dst=MemoryTier.HOST)
    plan.add(CacheProbeOp(_key(), 64, miss), "p", LANE_DMA)
    report = analyze_plan(plan)
    assert [f.rule for f in report.errors] == ["lint/miss-dst-tier"]


def test_lint_alloc_unreferenced():
    plan = _plan(PhaseSpec("p", overlap="serial"))
    plan.add(AllocOp(MemoryTier.HOST, "staging", 1 << 10), "p")
    plan.add(ComputeOp(1e-6), "p")  # touches DEVICE only
    report = analyze_plan(plan)
    assert [f.rule for f in report.warnings] == ["lint/alloc-unreferenced"]
    # A host preprocess op is host-tier work — the alloc is referenced.
    plan.add(HostPreprocessOp(1e-6), "p")
    assert analyze_plan(plan).findings == []


def test_lint_bad_placement():
    cache = ShardedSegmentCache(device_budget_bytes=1 << 20, n_shards=4)
    plan = _plan("p")
    plan.add(_probe(_key(), place_shard=7), "p", LANE_DMA)
    report = analyze_plan(plan, segment_cache=cache)
    assert [f.rule for f in report.errors] == ["lint/bad-placement"]
    # Without a cache, only negative shards are provably wrong.
    neg = _plan("p")
    neg.add(_probe(_key(), place_shard=-1), "p", LANE_DMA)
    assert [f.rule for f in analyze_plan(neg).errors] == \
        ["lint/bad-placement"]
    assert analyze_plan(plan).findings == []


def test_lint_duplicate_key_conflicting_fingerprints():
    plan = _plan("p")
    i = plan.add(_probe(_key(0, fp="aaaa")), "p", LANE_DMA)
    j = plan.add(_probe(_key(0, fp="bbbb")), "p", LANE_DMA)
    report = analyze_plan(plan)
    assert [f.rule for f in report.errors] == ["lint/duplicate-key-conflict"]
    assert report.errors[0].ops == (i, j)
    # Same fingerprint twice is a re-probe, not a conflict.
    ok = _plan("p")
    ok.add(_probe(_key(0, fp="aaaa")), "p", LANE_DMA)
    ok.add(_probe(_key(0, fp="aaaa")), "p", LANE_DMA)
    assert analyze_plan(ok).by_rule("lint/duplicate-key-conflict") == []


def test_lint_shard_imbalance():
    cache = ShardedSegmentCache(device_budget_bytes=1 << 20, n_shards=4)
    # 32 probes (the 8-per-shard floor) all owned by shard 0: its bytes
    # are 4x the per-shard mean — a property of the owner map, not size.
    plan = _plan("p")
    for i in range(32):
        plan.add(_probe(_key(i), place_shard=0), "p", LANE_DMA)
    report = analyze_plan(plan, segment_cache=cache)
    assert [f.rule for f in report.warnings] == ["lint/shard-imbalance"]
    # Evenly spread owners stay clean at the same probe count...
    even = _plan("p")
    for i in range(32):
        even.add(_probe(_key(i), place_shard=i % 4), "p", LANE_DMA)
    assert analyze_plan(even, segment_cache=cache).findings == []
    # ...and below the probe-count gate the same skew is granularity, not
    # an owner-map bug (one big segment trips 2x by pigeonhole).
    small = _plan("p")
    for i in range(31):
        small.add(_probe(_key(i), place_shard=0), "p", LANE_DMA)
    assert analyze_plan(small, segment_cache=cache).findings == []


def test_lint_dangling_pin_after_release():
    plan = _plan("p")
    i = plan.add(_probe(_key(), pin=object(), payload=(0, "ell")), "p",
                 LANE_DMA)
    plan.add(ComputeOp(1e-6), "p", LANE_COMPUTE, deps=(i,))
    # Pre-release, pins are expected: the released contract is opt-in.
    assert analyze_plan(plan).findings == []
    assert analyze_plan(plan, released=True).by_rule("lint/dangling-pin")
    plan.release_payloads()
    assert analyze_plan(plan, released=True).findings == []


def test_every_finding_rule_is_cataloged():
    """Rule codes are stable API: every code the analyzer can emit is in
    RULES, so the README table and CI lint output can't drift."""
    emitted = {
        "mem/oversubscription", "race/segment-key", "race/alloc-name",
        "race/pin", "race/unconsumed-payload", "bytes/path-delta",
        "lint/negative-bytes", "lint/zero-byte-transfer",
        "lint/miss-dst-tier", "lint/alloc-unreferenced",
        "lint/bad-placement", "lint/dangling-pin",
        "lint/duplicate-key-conflict", "lint/shard-imbalance",
    }
    assert emitted == set(RULES)


# ---- interpreters under analyze=True ---------------------------------------


def test_interpreter_analyze_default_on_under_tests():
    """tests/conftest.py flips the module default on: a broken plan dies
    in analysis, not at the runtime alloc."""
    plan = _plan(PhaseSpec("p", overlap="serial"))
    plan.add(AllocOp(MemoryTier.DEVICE, "huge", SPEC.device_capacity + 1),
             "p")
    with pytest.raises(PlanAnalysisError):
        CostInterpreter(SPEC).run(plan)
    m, _ = CostInterpreter(SPEC, analyze=False).run(plan)
    assert m.oom
    # estimate() never analyzes: admission control prices plans constantly.
    assert plan.estimate(SPEC).oom


def test_engine_analyze_plans_flag(small_graph):
    """EngineConfig.analyze_plans=True streams a real batch through the
    execute interpreter's analysis gate."""
    import jax.numpy as jnp
    from repro.runtime import EngineConfig, InferenceRequest, ServingEngine

    a = small_graph
    rng = np.random.default_rng(0)
    h = rng.standard_normal((a.n_rows, 8)).astype(np.float32)
    engine = ServingEngine(EngineConfig(
        device_budget_bytes=_budget(a, width=8), bm=8, bk=8,
        max_batch_features=8, analyze_plans=True))
    engine.register_graph("g", a)
    engine.submit(InferenceRequest("g", jnp.asarray(h)))
    report = engine.run_batch()
    assert len(report.results) == 1
    assert report.results[0].output is not None


def test_spgemm_stream_plan_analyzes_clean(small_graph):
    a = small_graph
    budget = _budget(a, width=8)
    cache = TieredSegmentCache(device_budget_bytes=budget)
    eng = AiresSpGEMM(AiresConfig(device_budget_bytes=budget, bm=8, bk=8),
                      segment_cache=cache)
    plan = eng.stream_plan(a, (a.n_rows, 8), spec=SPEC)
    assert analyze_plan(plan, spec=SPEC, segment_cache=cache).findings == []


# ---- property: clean alloc replay <=> no runtime OutOfMemory ---------------


def _random_alloc_plan(rng, spec):
    plan = _plan(PhaseSpec("p", overlap="serial"))
    names = ["H", "C", "A", "S"]
    tiers = [MemoryTier.DEVICE, MemoryTier.HOST]
    caps = {MemoryTier.DEVICE: spec.device_capacity,
            MemoryTier.HOST: spec.host_capacity}
    for _ in range(int(rng.integers(1, 12))):
        tier = tiers[int(rng.integers(0, len(tiers)))]
        plan.add(AllocOp(tier, names[int(rng.integers(0, len(names)))],
                         int(rng.integers(0, caps[tier] // 2 + 2))), "p")
    plan.add(_transfer(1 << 10), "p")
    return plan


def _assert_liveness_matches_interpreter(seed):
    spec = dataclasses.replace(SPEC, device_capacity=1 << 12,
                               host_capacity=1 << 13)
    plan = _random_alloc_plan(np.random.default_rng(seed), spec)
    clean = not analyze_plan(plan, spec=spec).by_rule("mem/oversubscription")
    m, _ = CostInterpreter(spec, analyze=False).run(plan)
    assert clean == (not m.oom)


def test_clean_liveness_implies_no_runtime_oom_property():
    if HAVE_HYPOTHESIS:
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=60, deadline=None)
        @given(st.integers(0, 2**32 - 1))
        def prop(seed):
            _assert_liveness_matches_interpreter(seed)

        prop()
    else:
        for seed in range(80):
            _assert_liveness_matches_interpreter(seed)
