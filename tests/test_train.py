"""Optimizers, gradient compression, accumulation, and the train loop."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.train import (
    adamw_init, adamw_update, adafactor_init, adafactor_update,
    compress_grads, decompress_grads, ef_init,
    TrainLoopConfig, train_loop, make_optimizer,
)
from repro.configs import get_config
from repro.models import init_params
from repro.data import TokenPipeline


def _quadratic_problem(seed=0):
    rng = np.random.default_rng(seed)
    target = jnp.asarray(rng.standard_normal((8, 8)).astype(np.float32))
    params = {"w": jnp.zeros((8, 8), jnp.float32)}

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2)

    return params, loss, target


@pytest.mark.parametrize("name,lr", [("adamw", 0.05), ("adafactor", 0.1)])
def test_optimizer_converges(name, lr):
    params, loss, target = _quadratic_problem()
    init_fn, update = make_optimizer(name, lr=lr, weight_decay=0.0)
    state = init_fn(params)
    l0 = float(loss(params))
    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, state = update(params, grads, state)
    assert float(loss(params)) < 0.05 * l0


def test_adafactor_memory_is_factored():
    params = {"w": jnp.zeros((64, 32))}
    state = adafactor_init(params)
    stats = state["stats"]["w"]
    assert stats["vr"].shape == (64,) and stats["vc"].shape == (32,)


def test_compression_error_feedback_unbiased():
    """EF compensates quantization: accumulated updates converge to the
    accumulated true gradient (the telescoping-sum property)."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal((256,)).astype(np.float32))
    ef = ef_init({"g": g_true})
    acc = jnp.zeros_like(g_true)
    for _ in range(50):
        q, s, ef_new = compress_grads({"g": g_true}, ef)
        recon = decompress_grads(q, s)["g"]
        acc = acc + recon
        ef = ef_new
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g_true),
                               atol=1e-2)


def test_compression_wire_is_int8():
    g = {"g": jnp.linspace(-3, 3, 128)}
    q, s, ef = compress_grads(g, ef_init(g))
    assert q["g"].dtype == jnp.int8


def test_grad_accum_equivalence():
    """grad_accum=2 over split microbatches == single big batch step."""
    cfg = get_config("yi_6b", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    pipe = TokenPipeline(cfg.vocab, 8, 4)
    t, lbl = pipe.batch_at(0)
    big = {"tokens": jnp.asarray(t), "labels": jnp.asarray(lbl)}
    micro = {"tokens": jnp.asarray(t).reshape(2, 2, 8),
             "labels": jnp.asarray(lbl).reshape(2, 2, 8)}

    from repro.train.loop import make_train_step
    init_opt, _ = make_optimizer("adamw", lr=1e-3)
    opt = init_opt(params)

    s1 = make_train_step(cfg, TrainLoopConfig(grad_accum=1, lr=1e-3))
    s2 = make_train_step(cfg, TrainLoopConfig(grad_accum=2, lr=1e-3))
    l1, p1, _, _ = s1(params, opt, big)
    l2, p2, _, _ = s2(params, opt, micro)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    # AdamW normalizes by sqrt(v); near-zero grads amplify fp noise — the
    # update-direction agreement is what matters.
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)


def test_train_loop_loss_decreases():
    cfg = get_config("xlstm_125m", smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    init_opt, _ = make_optimizer("adamw", lr=2e-3)
    opt = init_opt(params)
    pipe = TokenPipeline(cfg.vocab, 8, 4, seed=1)

    def batches():
        s = 0
        while True:
            t, lbl = pipe.batch_at(0)  # overfit one batch
            yield {"tokens": jnp.asarray(t), "labels": jnp.asarray(lbl)}
            s += 1

    lc = TrainLoopConfig(max_steps=20, lr=2e-3)
    _, _, info = train_loop(cfg, lc, params, opt, batches(), log_every=19)
    losses = [l for _, l in info["history"]]
    assert losses[-1] < losses[0]
