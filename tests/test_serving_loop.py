"""Continuous-batching serving loop (repro.runtime.serving_loop).

The headline assertions mirror ISSUE 6's acceptance criteria:
  * continuous outputs are exact vs the dense reference chain;
  * a mid-stream submit joins the next *forming* group instead of
    waiting for a full drain;
  * backpressure prices the *remaining* queue — a rejected submit is
    admitted again after one step drains a group;
  * queue-position EDF serves an urgent late arrival before earlier
    loose-deadline groups;
  * on seeded bursty traces the continuous arm never serves fewer
    requests on time than the round-based engine;
  * on a single-burst uniform-width trace both arms stream identical
    uploaded/cache-hit byte totals (same groups, same passes).
"""
import numpy as np
import pytest

from repro.core import EDFOrderingPass, plan_memory_dense_features
from repro.runtime import (
    AdmissionError, ContinuousServer, EngineConfig, InferenceRequest,
    ServingEngine, VirtualClock, bursty_trace, poisson_trace,
    replay_continuous, replay_round, summarize,
)
from repro.sparse.ref_spgemm import spgemm_csr_dense


@pytest.fixture(scope="module")
def quickstart_graph():
    from repro.data import (
        SUITESPARSE_SPECS, generate_graph, normalized_adjacency, scaled_spec,
    )

    return normalized_adjacency(generate_graph(
        scaled_spec(SUITESPARSE_SPECS["socLJ1"], 1e-4), seed=0))


@pytest.fixture(scope="module")
def road_graph():
    from repro.data import (
        SUITESPARSE_SPECS, generate_graph, normalized_adjacency, scaled_spec,
    )

    return normalized_adjacency(generate_graph(
        scaled_spec(SUITESPARSE_SPECS["rUSA"], 2e-5), seed=1))


def _budget(graphs):
    return max(
        int(est.m_b + est.m_c + 0.6 * a.nbytes())
        for a in graphs.values()
        for est in [plan_memory_dense_features(a, a.n_rows, 64,
                                               float("inf"))])


def _engine(graphs, clock, **overrides):
    kw = dict(device_budget_bytes=_budget(graphs), clock=clock,
              plan_passes=[EDFOrderingPass(clock=clock)])
    kw.update(overrides)
    eng = ServingEngine(EngineConfig(**kw))
    for name, a in graphs.items():
        eng.register_graph(name, a)
    return eng


def _feats(rng, a, width):
    return rng.standard_normal((a.n_rows, width)).astype(np.float32)


# ---- clock + step mechanics ----------------------------------------------

def test_virtual_clock_is_monotonic():
    clock = VirtualClock(1.0)
    assert clock() == 1.0
    clock.advance(0.5)
    assert clock() == 1.5
    clock.advance_to(1.5)            # no-op advance is fine
    with pytest.raises(ValueError):
        clock.advance_to(1.0)
    with pytest.raises(ValueError):
        clock.advance(-0.1)
    assert clock() == 1.5


def test_attach_requires_clean_queue_on_foreign_clock(quickstart_graph):
    """An engine that already queued work on a different clock holds
    stamps the loop's virtual timeline can't interpret."""
    a = quickstart_graph
    eng = ServingEngine(EngineConfig(device_budget_bytes=_budget({"g": a})))
    eng.register_graph("g", a)
    eng.submit(InferenceRequest(
        "g", _feats(np.random.default_rng(0), a, 8)))
    with pytest.raises(ValueError, match="different.*clock"):
        ContinuousServer(eng)


def test_continuous_outputs_match_dense_reference(quickstart_graph):
    rng = np.random.default_rng(4)
    a = quickstart_graph
    server = ContinuousServer(_engine({"g": a}, VirtualClock()))
    assert server.step() is None                 # idle loop is a no-op
    hs = [_feats(rng, a, 16) for _ in range(3)]
    w = rng.standard_normal((16, 8)).astype(np.float32)
    rids = [int(server.submit(InferenceRequest("g", h, [w]))) for h in hs]
    steps = server.drain()
    outs = {r.request_id: r.output for s in steps for r in s.results}
    assert sorted(outs) == sorted(rids)
    for rid, h in zip(rids, hs):
        np.testing.assert_allclose(
            outs[rid], spgemm_csr_dense(a, h) @ w, atol=1e-4)
    report = server.report()
    assert report.served == 3 and report.on_time == 3
    assert report.makespan_s > 0.0               # modeled costs moved time


def test_midstream_submit_joins_next_forming_group(quickstart_graph):
    """Cap 64: two width-40 requests form separate groups; a width-16
    request submitted after the first step rides the second group."""
    rng = np.random.default_rng(5)
    a = quickstart_graph
    server = ContinuousServer(_engine({"g": a}, VirtualClock()))
    r1 = int(server.submit(InferenceRequest("g", _feats(rng, a, 40))))
    r2 = int(server.submit(InferenceRequest("g", _feats(rng, a, 40))))
    s1 = server.step()
    assert [e.request_id for e in s1.events] == [r1]
    r3 = int(server.submit(InferenceRequest("g", _feats(rng, a, 16))))
    s2 = server.step()
    assert sorted(e.request_id for e in s2.events) == sorted([r2, r3])
    assert server.step() is None


def test_backpressure_prices_remaining_queue(quickstart_graph):
    """max_queue_cost_s admits again as soon as a step drains a group —
    the continuous loop's whole point vs round-snapshot pricing."""
    rng = np.random.default_rng(6)
    a = quickstart_graph
    probe = ContinuousServer(_engine({"g": a}, VirtualClock()))
    est = probe.engine.estimate_request_cost(
        InferenceRequest("g", _feats(rng, a, 48)))
    server = ContinuousServer(_engine(
        {"g": a}, VirtualClock(), max_queue_cost_s=2.5 * est))
    server.submit(InferenceRequest("g", _feats(rng, a, 48)))
    server.submit(InferenceRequest("g", _feats(rng, a, 48)))
    with pytest.raises(AdmissionError):          # 3*est > 2.5*est
        server.submit(InferenceRequest("g", _feats(rng, a, 48)))
    assert server.step() is not None             # one width-48 group leaves
    rid = server.submit(InferenceRequest("g", _feats(rng, a, 48)))
    assert int(rid) >= 0
    server.drain()
    report = server.report()
    assert report.served == 3
    assert [v.reason for v in report.rejected] == ["queue-full"]


def test_edf_serves_urgent_group_before_loose_backlog(quickstart_graph,
                                                      road_graph):
    """Queue-position EDF at group granularity: a tight-deadline arrival
    on one graph overtakes an earlier loose-deadline backlog on another."""
    rng = np.random.default_rng(7)
    graphs = {"g": quickstart_graph, "road": road_graph}
    clock = VirtualClock()
    server = ContinuousServer(_engine(graphs, clock))
    est = server.engine.estimate_request_cost(
        InferenceRequest("road", _feats(rng, road_graph, 16)))
    server.submit(InferenceRequest(
        "g", _feats(rng, quickstart_graph, 16), deadline_s=100.0))
    server.submit(InferenceRequest(
        "g", _feats(rng, quickstart_graph, 16), deadline_s=100.0))
    server.submit(InferenceRequest(
        "road", _feats(rng, road_graph, 16), deadline_s=5.0 * est))
    step = server.step()
    assert step.graph == "road"
    server.drain()
    assert server.report().on_time == 3


# ---- round vs continuous on shared traces --------------------------------

def _make_workload(rng, graphs, widths, hidden=8):
    feats = {(n, w): _feats(rng, a, w)
             for n, a in graphs.items() for w in widths}
    weights = {w: rng.standard_normal((w, hidden)).astype(np.float32)
               for w in widths}

    def make_request(arr):
        return InferenceRequest(
            arr.graph, feats[(arr.graph, arr.feature_dim)],
            [weights[arr.feature_dim]], deadline_s=arr.deadline_s)

    return make_request


def _unit(graphs, make_request):
    from repro.runtime.serving_loop import Arrival

    probe = _engine(graphs, VirtualClock())
    name = max(graphs, key=lambda n: graphs[n].n_rows)
    return probe.estimate_request_cost(
        make_request(Arrival(0.0, name, 32)))


@pytest.mark.parametrize("seed", [0, 1])
def test_continuous_on_time_never_below_round(quickstart_graph, road_graph,
                                              seed):
    """On the same bursty trace, admitting between every group must not
    serve fewer requests on time than admitting between full drains."""
    graphs = {"g": quickstart_graph, "road": road_graph}
    rng = np.random.default_rng(10)
    widths = (16, 32, 48)
    make_request = _make_workload(rng, graphs, widths)
    unit = _unit(graphs, make_request)
    trace = bursty_trace(n=36, base_rate_hz=3.5 / unit,
                         graphs=sorted(graphs), seed=seed,
                         feature_dim=widths, deadline_s=3.0 * unit,
                         burst_shape=0.25, episode=12)
    r_round = replay_round(_engine(graphs, VirtualClock()),
                           trace, make_request)
    r_cont = replay_continuous(
        ContinuousServer(_engine(graphs, VirtualClock())),
        trace, make_request)
    s_round, s_cont = summarize(r_round), summarize(r_cont)
    assert s_round["offered"] == s_cont["offered"] == 36
    assert s_cont["on_time"] >= s_round["on_time"]


def test_single_burst_byte_accounting_matches_round(quickstart_graph,
                                                    road_graph):
    """One tight burst of uniform-width no-deadline requests: both arms
    form the same column-concat groups in the same order, so uploaded and
    cache-hit wire bytes must agree exactly."""
    graphs = {"g": quickstart_graph, "road": road_graph}
    rng = np.random.default_rng(11)
    make_request = _make_workload(rng, graphs, widths=(16,))
    trace = poisson_trace(n=12, rate_hz=1e9, graphs=sorted(graphs),
                          seed=2, feature_dim=16)
    r_round = replay_round(_engine(graphs, VirtualClock()),
                           trace, make_request)
    r_cont = replay_continuous(
        ContinuousServer(_engine(graphs, VirtualClock())),
        trace, make_request)
    assert r_round.served == r_cont.served == 12
    assert r_cont.stats.uploaded_bytes == r_round.stats.uploaded_bytes
    assert r_cont.stats.cache_hit_bytes == r_round.stats.cache_hit_bytes
    assert r_cont.stats.aggregation_passes == r_round.stats.aggregation_passes
