"""DoubleBufferedStreamer edge cases (Phase II hardening sweep, ISSUE 2).

Covers the corners the serving engine leans on: deep pipelines (depth>2),
straggler re-issue accounting, empty payload iterables, in-order delivery
under a slow consumer, and the segment-cache hooks.
"""
import time

import pytest

from repro.io import DoubleBufferedStreamer


def _mk(depth=2, uploads=None, consumed=None, **kw):
    uploads = uploads if uploads is not None else []
    consumed = consumed if consumed is not None else []
    return DoubleBufferedStreamer(
        upload=lambda p: (uploads.append(p), p)[1],
        consume=lambda p, i: (consumed.append((p, i)), p * 10)[1],
        depth=depth, **kw)


def test_depth_must_be_positive():
    with pytest.raises(ValueError):
        _mk(depth=0)


@pytest.mark.parametrize("depth", [3, 4, 7, 100])
def test_deeper_pipelines_preserve_order(depth):
    uploads, consumed = [], []
    streamer = _mk(depth=depth, uploads=uploads, consumed=consumed)
    out = streamer.run_all(list(range(10)))
    assert out == [i * 10 for i in range(10)]
    assert [c[1] for c in consumed] == list(range(10))
    assert uploads == list(range(10))
    assert streamer.stats.segments == 10


def test_prefetch_depth_bounds_inflight_uploads():
    """With depth=d, at most d uploads run ahead of the consumer."""
    uploaded, consumed = [], []
    lead = []

    streamer = DoubleBufferedStreamer(
        upload=lambda p: (uploaded.append(p), p)[1],
        consume=lambda p, i: (consumed.append(p),
                              lead.append(len(uploaded) - len(consumed)),
                              p)[2],
        depth=3)
    streamer.run_all(list(range(12)))
    # when consume(k) runs, uploads may lead it by at most depth
    assert max(lead) <= 3
    assert consumed == list(range(12))


def test_empty_payload_iterable():
    streamer = _mk()
    assert streamer.run_all([]) == []
    assert streamer.run_all(iter(())) == []
    st = streamer.stats
    assert (st.segments, st.uploaded_bytes, st.reissues) == (0, 0, 0)


def test_in_order_yields_under_slow_consume():
    """Regression: a consumer slower than the producer must not reorder or
    drop results (the pipeline refills while the consumer lags)."""
    order = []

    def slow_consume(p, i):
        time.sleep(0.002 if i % 2 else 0.006)  # jittered slowness
        order.append(i)
        return p

    streamer = DoubleBufferedStreamer(
        upload=lambda p: p, consume=slow_consume, depth=3)
    got = list(streamer.run(list(range(8))))
    assert got == list(range(8))
    assert order == list(range(8))


def test_deadline_reissue_counts_bytes_and_events():
    def slow_upload(p):
        time.sleep(0.02)
        return p

    streamer = DoubleBufferedStreamer(
        upload=slow_upload, consume=lambda p, i: p,
        depth=1, deadline_s=0.001, max_reissue=2,
        payload_nbytes=lambda p: 100)
    streamer.run_all([1, 2])
    st = streamer.stats
    assert st.reissues >= 2            # both segments blow the deadline
    assert st.reissues <= 4            # bounded by max_reissue per segment
    # every re-issue is real retransmitted wire traffic
    assert st.uploaded_bytes == 100 * (2 + st.reissues)


def test_no_deadline_means_no_reissue():
    streamer = DoubleBufferedStreamer(
        upload=lambda p: p, consume=lambda p, i: p, depth=2,
        payload_nbytes=lambda p: 7)
    streamer.run_all(list(range(5)))
    assert streamer.stats.reissues == 0
    assert streamer.stats.uploaded_bytes == 35


def test_cache_hooks_split_hit_and_miss_bytes():
    store = {}
    uploads = []

    streamer = DoubleBufferedStreamer(
        upload=lambda p: (uploads.append(p), p * 2)[1],
        consume=lambda p, i: p,
        depth=2,
        payload_nbytes=lambda p: 10,
        cache_lookup=store.get,
        cache_store=lambda p, dev: store.__setitem__(p, dev))
    out1 = streamer.run_all([1, 2, 3])
    assert out1 == [2, 4, 6]
    assert streamer.stats.uploaded_bytes == 30
    assert streamer.stats.cache_hit_bytes == 0

    out2 = streamer.run_all([1, 2, 3])   # warm: everything served from store
    assert out2 == [2, 4, 6]
    assert uploads == [1, 2, 3]          # no second upload
    assert streamer.stats.uploaded_bytes == 30
    assert streamer.stats.cache_hits == 3
    assert streamer.stats.cache_hit_bytes == 30


def test_cache_miss_none_falls_through_to_upload():
    calls = []
    streamer = DoubleBufferedStreamer(
        upload=lambda p: p,
        consume=lambda p, i: p,
        cache_lookup=lambda p: (calls.append(p), None)[1])
    assert streamer.run_all([5]) == [5]
    assert calls == [5]
    assert streamer.stats.cache_hits == 0
