"""Graph generators, token pipeline, and sharding-rule unit tests."""
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import arch_ids, get_config
from repro.kernels.compat import make_abstract_mesh
from repro.data import (
    SUITESPARSE_SPECS, generate_graph, normalized_adjacency, scaled_spec,
)
from repro.launch.sharding import batch_pspec, param_pspec


def test_generate_graph_counts():
    spec = scaled_spec(SUITESPARSE_SPECS["rUSA"], 1e-4)
    a = generate_graph(spec, seed=0)
    a.validate()
    assert a.n_rows == spec.n_vertices
    # dedup may remove a few parallel edges
    assert 0.5 * spec.n_edges <= a.nnz <= spec.n_edges


def test_powerlaw_has_skew():
    spec = scaled_spec(SUITESPARSE_SPECS["socLJ1"], 5e-4)
    a = generate_graph(spec, seed=0)
    deg = a.row_nnz()
    assert deg.max() > 10 * max(np.median(deg), 1)


def test_normalized_adjacency_spectral(tmp_path):
    spec = scaled_spec(SUITESPARSE_SPECS["rUSA"], 5e-5)
    a = normalized_adjacency(generate_graph(spec, seed=1))
    # Ã of an undirected-ish graph has rows bounded by 1 in L1 after
    # symmetric normalization; self loops guarantee nonzero diagonal.
    from repro.sparse import csr_to_dense
    d = csr_to_dense(a)
    assert (np.diag(d) > 0).all()
    # degree normalization keeps entries and spectrum bounded (A here is
    # directed, so the radius can exceed 1 slightly — bound loosely)
    assert d.max() <= 1.0 + 1e-6
    eig = np.max(np.abs(np.linalg.eigvals(d + d.T) / 2))
    assert eig < 2.5


def test_token_pipeline_sharding_partition():
    from repro.data import TokenPipeline
    full = TokenPipeline(100, 8, 8, seed=5)
    t_full, _ = full.batch_at(3)
    assert t_full.shape == (8, 8)
    shard = TokenPipeline(100, 8, 8, seed=5, shard_index=1, shard_count=4)
    t_s, _ = shard.batch_at(3)
    assert t_s.shape == (2, 8)


MESHES = [
    make_abstract_mesh((16, 16), ("data", "model")),
    make_abstract_mesh((2, 16, 16), ("pod", "data", "model")),
]


@pytest.mark.parametrize("mesh", MESHES, ids=["single", "multi"])
@pytest.mark.parametrize("arch", arch_ids())
def test_param_rules_divide(arch, mesh):
    """Every rule-produced spec must divide the dims it shards — for every
    full-size arch on both production meshes."""
    import jax
    from repro.models.stacked import init_params_stacked
    cfg = get_config(arch)
    abs_params = jax.eval_shape(
        lambda k: init_params_stacked(cfg, k), jax.random.PRNGKey(0))

    def check(path, leaf):
        spec = param_pspec(jax.tree_util.keystr(path), leaf.shape, mesh)
        for dim, axis in zip(leaf.shape, spec):
            if axis is None:
                continue
            size = 1
            for ax in (axis if isinstance(axis, tuple) else (axis,)):
                size *= mesh.shape[ax]
            assert dim % size == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(check, abs_params)


@pytest.mark.parametrize("mesh", MESHES, ids=["single", "multi"])
def test_batch_pspec_divisibility(mesh):
    assert batch_pspec((256, 4096), mesh)[0] is not None
    assert batch_pspec((1, 4096), mesh)[0] is None  # batch=1 replicates
