"""End-to-end behaviour tests for the AIRES system (paper-level claims).

Each test maps to a paper artifact:
  * RoBW removes merge events entirely (Fig. 3 mechanism)
  * AIRES executes out-of-core SpGEMM exactly (correctness under streaming)
  * scheduler ranking AIRES < ETC < UCG/MaxMemory at constraint budgets (Fig. 6)
  * OOM ladder matches Table III
  * transferred DMA+UM bytes drop vs MaxMemory (Fig. 7)
"""
import numpy as np
import pytest

from repro.core import (
    SCHEDULERS, FeatureSpec, required_bytes, AiresSpGEMM, AiresConfig,
    plan_memory_spec,
)
from repro.io.tiers import PAPER_GPU_SYSTEM
from repro.sparse.ref_spgemm import spgemm_csr_dense


@pytest.fixture(scope="module")
def graph(paper_graph):
    # shared session graph from conftest (same spec as the paper artifacts)
    return paper_graph


@pytest.fixture(scope="module")
def feats(paper_feats):
    return paper_feats


def _streaming_budget(graph, feats, a_frac=0.6):
    """Budget that is feasible but forces ≥2 streamed segments."""
    est = plan_memory_spec(graph, FeatureSpec.of(feats), float("inf"))
    return int(est.m_b + est.m_c + a_frac * graph.nbytes())


def test_aires_execute_exact(graph, feats):
    budget = _streaming_budget(graph, feats)
    res = SCHEDULERS["aires"](PAPER_GPU_SYSTEM, device_budget=budget).run(
        graph, feats, mode="execute")
    assert not res.metrics.oom
    assert res.metrics.segments >= 2, "budget should force streaming"
    ref = spgemm_csr_dense(graph, feats)
    np.testing.assert_allclose(res.x, ref, atol=1e-4)


def test_aires_no_merge_events(graph, feats):
    budget = _streaming_budget(graph, feats)
    res = SCHEDULERS["aires"](PAPER_GPU_SYSTEM, device_budget=budget).run(
        graph, feats)
    assert res.metrics.merge_events == 0
    # The naive mechanism needs a budget whose static half is below |A|
    # (policy off: this probes the mechanism, not Table III feasibility).
    feat = FeatureSpec(graph.n_rows, 256, 4, sparsity_pct=99.0)
    mm_sched = SCHEDULERS["maxmemory"](
        PAPER_GPU_SYSTEM,
        device_budget=int(required_bytes(graph, feat) * 0.55))
    mm_sched.oom_fraction = 0.0
    mm = mm_sched.run(graph, feat)
    assert mm.metrics.merge_events > 0, "naive cuts must split rows"


def test_fig6_ranking(graph):
    feat = FeatureSpec(graph.n_rows, 256, 4, sparsity_pct=99.0)
    req = required_bytes(graph, feat)
    budget = int(0.9 * req)
    spans = {}
    for name in SCHEDULERS:
        r = SCHEDULERS[name](PAPER_GPU_SYSTEM, device_budget=budget).run(
            graph, feat, dataset="kV2a")
        assert not r.metrics.oom, name
        spans[name] = r.metrics.makespan_s
    assert spans["aires"] < spans["etc"] < spans["maxmemory"]
    assert spans["aires"] < spans["ucg"]
    # paper: 1.5–1.8x class speedups
    assert spans["maxmemory"] / spans["aires"] > 1.3


def test_tableiii_oom_ladder(graph):
    feat = FeatureSpec(graph.n_rows, 256, 4, sparsity_pct=99.0)
    req = required_bytes(graph, feat)
    est = plan_memory_spec(graph, feat, req)
    aires_floor = (est.m_b + est.m_c) / req

    def ooms(name, frac):
        r = SCHEDULERS[name](PAPER_GPU_SYSTEM,
                             device_budget=int(frac * req)).run(graph, feat)
        return r.metrics.oom

    # AIRES's Eq.7 floor must undercut ETC's 0.72 threshold.
    assert aires_floor < 0.72
    low = (aires_floor + 0.72) / 2
    # ~0.9: everyone runs; ~0.8: only ETC+AIRES; low rung: only AIRES.
    assert not any(ooms(n, 0.9) for n in SCHEDULERS)
    assert ooms("maxmemory", 0.8) and ooms("ucg", 0.8)
    assert not ooms("etc", 0.8) and not ooms("aires", 0.8)
    assert ooms("etc", low) and not ooms("aires", low)


def test_fig7_byte_reduction(graph):
    feat = FeatureSpec(graph.n_rows, 256, 4, sparsity_pct=99.0)
    req = required_bytes(graph, feat)
    budget = int(0.9 * req)

    def dma_um(name):
        r = SCHEDULERS[name](PAPER_GPU_SYSTEM, device_budget=budget).run(
            graph, feat)
        return sum(v for k, v in r.metrics.bytes_by_path.items()
                   if k in ("dma", "um"))

    reduction = 1 - dma_um("aires") / dma_um("maxmemory")
    assert reduction > 0.5, f"expected large DMA+UM reduction, got {reduction:.2f}"


@pytest.mark.slow
def test_streaming_engine_matches_oracle(graph, feats):
    import jax.numpy as jnp
    budget = _streaming_budget(graph, feats)
    eng = AiresSpGEMM(AiresConfig(device_budget_bytes=budget, bm=8, bk=8))
    x = np.asarray(eng(graph, jnp.asarray(feats)))
    np.testing.assert_allclose(x, spgemm_csr_dense(graph, feats), atol=1e-4)
