"""Eq. (5)-(7) analytical memory model."""
import importlib.util

import numpy as np
import pytest

from repro.core import (
    FeatureSpec, calc_mem, ell_bucket_capacity, estimate_output_bytes,
    estimate_resident_bytes, plan_memory_dense_features, plan_memory_spec,
    plan_memory_unified, required_bytes, segment_budget,
)
from repro.sparse import csr_from_dense

HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None


@pytest.fixture
def a():
    rng = np.random.default_rng(0)
    dense = (rng.random((200, 200)) < 0.05) * np.ones((200, 200), np.float32)
    return csr_from_dense(dense)


def test_eq6_resident():
    assert estimate_resident_bytes(100, 50, 25) == 175


def test_eq7_budget():
    assert segment_budget(300, 60, 90) == 50.0


def test_eq5_monotonic_in_density():
    lo = estimate_output_bytes(1000_000, 1000_000, 99.0, 99.0)
    hi = estimate_output_bytes(1000_000, 1000_000, 95.0, 99.0)
    assert hi > lo > 0


def test_calc_mem_matches_alg1():
    # (k+1) row pointers + q (col ids + values)
    assert calc_mem(10, 100, value_bytes=4, index_bytes=4) == 11 * 4 + 100 * 8


def test_plan_memory_raw_alpha_entry_point(a):
    """The raw Eq. 5-7 entry point (explicit α/β/θ) stays consistent with
    its building blocks."""
    from repro.core import plan_memory

    est = plan_memory(a, 1000.0, 400.0, 100.0, m_total=1 << 22)
    assert est.m_b == estimate_resident_bytes(1000.0, 400.0, 100.0)
    assert est.p == segment_budget(1 << 22, est.m_c, est.m_b)
    assert est.m_a == pytest.approx(3.0 * est.p)
    assert est.feasible == (est.p > 0)
    assert not plan_memory(a, 1000.0, 400.0, 100.0, m_total=1).feasible


def test_plan_feasibility_threshold(a):
    feat = FeatureSpec(a.n_rows, 64, 4, sparsity_pct=99.0)
    req = required_bytes(a, feat)
    assert plan_memory_spec(a, feat, req).feasible
    est = plan_memory_spec(a, feat, req * 0.01)
    assert not est.feasible


def test_plan_segment_budget_shrinks_with_memory(a):
    feat = FeatureSpec(a.n_rows, 64, 4, sparsity_pct=99.0)
    req = required_bytes(a, feat)
    p_big = plan_memory_spec(a, feat, req).p
    p_small = plan_memory_spec(a, feat, req * 0.7).p
    assert p_big > p_small


def test_feature_spec_compressed_vs_dense():
    dense = FeatureSpec(1000, 256, 4, sparsity_pct=0.0)
    sparse = FeatureSpec(1000, 256, 4, sparsity_pct=99.0)
    assert dense.compressed_bytes == 1000 * 256 * 4
    assert sparse.compressed_bytes < dense.compressed_bytes / 10


def test_ell_bucket_capacity():
    assert ell_bucket_capacity(0) == 1
    assert ell_bucket_capacity(5) == 8
    assert ell_bucket_capacity(8) == 8
    assert ell_bucket_capacity(9) == 16
    assert ell_bucket_capacity(5, buckets=[4, 12, 20]) == 12


def test_ell_bucket_capacity_rejects_undersized_bucket_list():
    """Regression (ISSUE 3): `true_width` beyond every explicit bucket used
    to return max(buckets) — a capacity *smaller* than the true tile width,
    silently truncating nonzeros on pad."""
    assert ell_bucket_capacity(20, buckets=[4, 12, 20]) == 20  # boundary ok
    with pytest.raises(ValueError, match="exceeds every explicit bucket"):
        ell_bucket_capacity(21, buckets=[4, 12, 20])
    with pytest.raises(ValueError, match="truncate"):
        ell_bucket_capacity(1000, buckets=[8])
    # the implicit power-of-two path keeps covering any width
    assert ell_bucket_capacity(1000) == 1024


# ---- planner unification (ISSUE 3 satellite) ------------------------------
#
# Property: the unified planner matches both pre-unification readings on
# their home turf — the compressed-feature Eq. 5 reading (old
# plan_memory_spec, reference-implemented below) for sparse feature
# matrices, and the dense-resident invariants (M_B = N·F·bytes, M_C capped
# at the dense X footprint) for sparsity_pct=0 — and the two surviving
# entry points return *identical* MemoryEstimates for dense features (the
# divergence that used to force equal-m_a scaffolding in test_engine.py).

def _old_spec_reading(a, feat, m_total):
    """Pre-unification plan_memory_spec, verbatim (the paper-faithful
    reading the unified planner adopted)."""
    itemsize = float(a.data.dtype.itemsize)
    n_total = float(a.shape[0]) * float(a.shape[1])
    alpha_a_dense = n_total * itemsize
    alpha_b_dense = float(feat.dense_bytes)
    sparsity_a_pct = 100.0 * (1.0 - a.nnz / max(n_total, 1.0))
    m_c = estimate_output_bytes(alpha_a_dense, alpha_b_dense,
                                sparsity_a_pct, feat.sparsity_pct)
    if feat.sparsity_pct <= 0.0:
        m_c = min(m_c, float(a.shape[0]) * feat.n_cols * feat.dtype_bytes)
    m_b = float(feat.compressed_bytes)
    return m_b, m_c, segment_budget(m_total, m_c, m_b)


def _random_case(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 120))
    m = int(rng.integers(8, 120))
    density = float(rng.uniform(0.005, 0.2))
    dense = (rng.random((n, m)) < density).astype(np.float32)
    dense[0, 0] = 1.0  # never empty
    a = csr_from_dense(dense)
    f = int(rng.integers(1, 300))
    m_total = float(rng.integers(1, 1 << 22))
    return rng, a, m, f, m_total


def check_unified_matches_compressed_reading(seed):
    rng, a, m, f, m_total = _random_case(seed)
    feat = FeatureSpec(m, f, 4, sparsity_pct=float(rng.uniform(50.0, 99.9)))
    est = plan_memory_unified(a, feat, m_total)
    m_b, m_c, p = _old_spec_reading(a, feat, m_total)
    assert est.m_b == m_b and est.m_c == m_c and est.p == p
    assert est.feasible == (p > 0.0)
    # plan_memory_spec is the same planner under its historical name
    assert plan_memory_spec(a, feat, m_total) == est


def check_unified_matches_dense_reading(seed):
    rng, a, m, f, m_total = _random_case(seed)
    feat = FeatureSpec(m, f, 4, sparsity_pct=0.0)
    via_spec = plan_memory_spec(a, feat, m_total)
    via_dense = plan_memory_dense_features(a, m, f, m_total)
    # identical MemoryEstimates from both former entry points (frozen
    # dataclass equality covers m_b, m_c, p, m_total, feasible)
    assert via_spec == via_dense == plan_memory_unified(a, feat, m_total)
    # dense home-turf invariants of the old dense reading
    assert via_dense.m_b == m * f * 4
    assert via_dense.m_c <= a.shape[0] * f * 4
    assert via_dense.p == segment_budget(m_total, via_dense.m_c,
                                         via_dense.m_b)


if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_unified_matches_compressed_reading(seed):
        check_unified_matches_compressed_reading(seed)

    @settings(max_examples=30, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_unified_matches_dense_reading(seed):
        check_unified_matches_dense_reading(seed)
else:
    @pytest.mark.parametrize("seed", range(20))
    def test_unified_matches_compressed_reading(seed):
        check_unified_matches_compressed_reading(seed)

    @pytest.mark.parametrize("seed", range(20))
    def test_unified_matches_dense_reading(seed):
        check_unified_matches_dense_reading(seed)
