"""Eq. (5)-(7) analytical memory model."""
import numpy as np
import pytest

from repro.core import (
    FeatureSpec, calc_mem, ell_bucket_capacity, estimate_output_bytes,
    estimate_resident_bytes, plan_memory_spec, required_bytes, segment_budget,
)
from repro.sparse import csr_from_dense


@pytest.fixture
def a():
    rng = np.random.default_rng(0)
    dense = (rng.random((200, 200)) < 0.05) * np.ones((200, 200), np.float32)
    return csr_from_dense(dense)


def test_eq6_resident():
    assert estimate_resident_bytes(100, 50, 25) == 175


def test_eq7_budget():
    assert segment_budget(300, 60, 90) == 50.0


def test_eq5_monotonic_in_density():
    lo = estimate_output_bytes(1000_000, 1000_000, 99.0, 99.0)
    hi = estimate_output_bytes(1000_000, 1000_000, 95.0, 99.0)
    assert hi > lo > 0


def test_calc_mem_matches_alg1():
    # (k+1) row pointers + q (col ids + values)
    assert calc_mem(10, 100, value_bytes=4, index_bytes=4) == 11 * 4 + 100 * 8


def test_plan_feasibility_threshold(a):
    feat = FeatureSpec(a.n_rows, 64, 4, sparsity_pct=99.0)
    req = required_bytes(a, feat)
    assert plan_memory_spec(a, feat, req).feasible
    est = plan_memory_spec(a, feat, req * 0.01)
    assert not est.feasible


def test_plan_segment_budget_shrinks_with_memory(a):
    feat = FeatureSpec(a.n_rows, 64, 4, sparsity_pct=99.0)
    req = required_bytes(a, feat)
    p_big = plan_memory_spec(a, feat, req).p
    p_small = plan_memory_spec(a, feat, req * 0.7).p
    assert p_big > p_small


def test_feature_spec_compressed_vs_dense():
    dense = FeatureSpec(1000, 256, 4, sparsity_pct=0.0)
    sparse = FeatureSpec(1000, 256, 4, sparsity_pct=99.0)
    assert dense.compressed_bytes == 1000 * 256 * 4
    assert sparse.compressed_bytes < dense.compressed_bytes / 10


def test_ell_bucket_capacity():
    assert ell_bucket_capacity(0) == 1
    assert ell_bucket_capacity(5) == 8
    assert ell_bucket_capacity(8) == 8
    assert ell_bucket_capacity(9) == 16
    assert ell_bucket_capacity(5, buckets=[4, 12, 20]) == 12
