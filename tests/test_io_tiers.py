"""Tiered memory accounting + double-buffered streamer."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.io import (
    DoubleBufferedStreamer, MemoryTier, TieredMemorySystem,
    PAPER_GPU_SYSTEM, TPU_V5E_SYSTEM,
)
from repro.io.tiers import OutOfMemory, Path
from repro.io.weights import ExpertBank, StreamedWeightProvider


def test_alloc_accounting_and_oom():
    tms = TieredMemorySystem(PAPER_GPU_SYSTEM)
    tms.alloc(MemoryTier.DEVICE, "a", 10 << 30)
    tms.alloc(MemoryTier.DEVICE, "b", 10 << 30)
    assert tms.headroom(MemoryTier.DEVICE) == 4 << 30
    with pytest.raises(OutOfMemory):
        tms.alloc(MemoryTier.DEVICE, "c", 5 << 30)
    tms.free(MemoryTier.DEVICE, "a")
    tms.alloc(MemoryTier.DEVICE, "c", 5 << 30)  # now fits


def test_realloc_same_name_replaces():
    tms = TieredMemorySystem(PAPER_GPU_SYSTEM)
    tms.alloc(MemoryTier.HOST, "x", 1 << 30)
    tms.alloc(MemoryTier.HOST, "x", 2 << 30)
    assert tms.used[MemoryTier.HOST] == 2 << 30


def test_transfer_latency_model():
    tms = TieredMemorySystem(PAPER_GPU_SYSTEM)
    s = tms.transfer(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE, 22_000_000_000)
    assert s == pytest.approx(1.0 + 8e-6, rel=1e-3)  # 22 GB at 22 GB/s


def test_dualway_makespan_overlaps():
    tms = TieredMemorySystem(PAPER_GPU_SYSTEM)
    tms.transfer(Path.GDS, MemoryTier.STORAGE, MemoryTier.DEVICE, 6_000_000_000)
    tms.transfer(Path.STORAGE_HOST, MemoryTier.STORAGE, MemoryTier.HOST, 6_500_000_000)
    assert tms.makespan_overlapped() < tms.makespan_serial()
    assert tms.makespan_overlapped() == pytest.approx(1.0, rel=1e-2)


def test_streamer_order_and_depth():
    uploaded, consumed = [], []
    streamer = DoubleBufferedStreamer(
        upload=lambda p: (uploaded.append(p), p)[1],
        consume=lambda p, i: (consumed.append((p, i)), p * 10)[1],
        depth=2)
    out = streamer.run_all(range(5))
    assert out == [0, 10, 20, 30, 40]
    assert [c[1] for c in consumed] == list(range(5))
    assert streamer.stats.segments == 5


def test_streamer_deadline_reissues():
    import time

    def slow_upload(p):
        time.sleep(0.02)
        return p

    streamer = DoubleBufferedStreamer(
        upload=slow_upload, consume=lambda p, i: p,
        depth=1, deadline_s=0.001, max_reissue=1)
    streamer.run_all([1, 2])
    assert streamer.stats.reissues >= 1


def test_expert_streaming_complete_blocks():
    """RoBW-for-experts: blocks are complete, aligned, and cover the bank."""
    e, d, f = 32, 16, 8
    rng = np.random.default_rng(0)
    bank = ExpertBank(layer=0, arrays={
        "w_gate": rng.standard_normal((e, d, f)).astype(np.float32),
        "w_down": rng.standard_normal((e, f, d)).astype(np.float32),
    })
    per_expert = bank.expert_bytes()
    provider = StreamedWeightProvider([bank], hbm_budget_bytes=per_expert * 10,
                                      align=4)
    blocks = provider.blocks_for(bank)
    assert blocks[0][0] == 0 and blocks[-1][1] == e
    for (s0, e0), (s1, e1) in zip(blocks, blocks[1:]):
        assert e0 == s1
    for (s0, e0) in blocks[:-1]:
        assert (e0 - s0) % 4 == 0      # aligned, complete expert blocks
    # streamed payloads reproduce the bank exactly
    got = {}
    for (rng_blk, arrays) in provider.stream_layer(bank):
        got[rng_blk] = arrays
    rebuilt = np.concatenate([np.asarray(got[k]["w_gate"]) for k in sorted(got)])
    np.testing.assert_array_equal(rebuilt, bank.arrays["w_gate"])


def test_tms_aggregates_match_records_and_bound_memory():
    """bytes/seconds_by_path come from running aggregates identical to a
    record-list fold; keep_records=False (a ServingEngine's lifetime tms)
    keeps the aggregates but never grows the per-transfer log."""
    from repro.io.tiers import (
        MemoryTier, PAPER_GPU_SYSTEM, Path, TieredMemorySystem,
    )

    full = TieredMemorySystem(PAPER_GPU_SYSTEM)
    lean = TieredMemorySystem(PAPER_GPU_SYSTEM, keep_records=False)
    for tms in (full, lean):
        for i in range(5):
            tms.transfer(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE,
                         1000 + i, tag="t")
        tms.transfer(Path.GDS, MemoryTier.STORAGE, MemoryTier.DEVICE, 77)
    assert len(full.transfers) == 6 and len(lean.transfers) == 0
    assert full.bytes_by_path() == lean.bytes_by_path()
    assert full.seconds_by_path() == lean.seconds_by_path()
    assert full.total_bytes() == lean.total_bytes() == sum(
        t.nbytes for t in full.transfers)
    # aggregates are the record fold, float-for-float
    import collections
    by = collections.defaultdict(float)
    for t in full.transfers:
        by[t.path] += t.seconds
    assert dict(by) == full.seconds_by_path()
    lean.reset_accounting()
    assert lean.total_bytes() == 0 and lean.bytes_by_path() == {}
