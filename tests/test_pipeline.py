"""Pipeline-plan IR: plan builders, the two interpreters, and the ISSUE 4
acceptance criterion — on the fig6 configurations, cost-interpreter
`ScheduleMetrics` match the pre-refactor monolithic schedulers to float
equality (frozen in tests/data/golden_pipeline.json), and the execute
interpreter's outputs agree exactly with the reference computation.
"""
import json
import os

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import (
    AiresConfig,
    AiresSpGEMM,
    CacheProbeOp,
    ComputeOp,
    CostInterpreter,
    ExecuteInterpreter,
    FeatureSpec,
    HostPreprocessOp,
    PhaseSpec,
    PipelinePlan,
    SCHEDULERS,
    TransferOp,
    plan_memory_dense_features,
)
from repro.core.pipeline import LANE_COMPUTE, LANE_DMA, LANE_GDS, AllocOp
from repro.io import TieredSegmentCache
from repro.io.tiers import (
    MemoryTier,
    PAPER_GPU_SYSTEM,
    Path,
)
from repro.sparse.formats import csr_fingerprint
from repro.sparse.ref_spgemm import spgemm_csr_dense

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "data",
                           "golden_pipeline.json")
METRIC_FIELDS = [
    "makespan_s", "io_modeled_s", "compute_modeled_s", "host_preprocess_s",
    "bytes_by_path", "seconds_by_path", "total_transfer_bytes",
    "cache_hit_bytes", "merge_events", "merge_io_s", "segments", "oom",
]


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN_PATH) as f:
        return json.load(f)


@pytest.fixture(scope="module")
def fig6_setup():
    from benchmarks.common import SCALE, budget_for, dataset, feature_spec

    if SCALE != 1e-3:
        pytest.skip("golden metrics were frozen at SCALE=1e-3 "
                    "(AIRES_BENCH_SCALE overrides the benchmark scale)")
    out = {}
    for name in ["rUSA", "kV2a", "kU1a", "socLJ1", "kP1a"]:
        a = dataset(name)
        feat = feature_spec(a)
        out[name] = (a, feat, budget_for(name, a, feat))
    return out


@pytest.fixture(scope="module")
def small_graph():
    from repro.data import (
        SUITESPARSE_SPECS, generate_graph, normalized_adjacency, scaled_spec,
    )

    a = normalized_adjacency(generate_graph(
        scaled_spec(SUITESPARSE_SPECS["socLJ1"], 1e-4), seed=0))
    a.validate()
    return a


# ---- acceptance: cost interpreter == pre-refactor simulate, float-equal ----

@pytest.mark.parametrize("sched", ["maxmemory", "ucg", "etc", "aires"])
@pytest.mark.parametrize("name", ["rUSA", "kV2a", "kU1a", "socLJ1", "kP1a"])
def test_cost_interpreter_matches_prerefactor_fig6(golden, fig6_setup,
                                                   name, sched):
    a, feat, budget = fig6_setup[name]
    res = SCHEDULERS[sched](PAPER_GPU_SYSTEM, device_budget=budget).run(
        a, feat, mode="simulate", dataset=name)
    want = golden["fig6"][f"{name}/{sched}"]
    for field in METRIC_FIELDS:
        got = getattr(res.metrics, field)
        assert got == want[field], (
            f"{name}/{sched}.{field}: {got!r} != pre-refactor {want[field]!r}")


def test_cached_simulate_matches_prerefactor(golden, fig6_setup):
    """AIRES + shared segment cache: cold epoch fills, warm epoch hits —
    both float-equal to the pre-refactor monolith."""
    from benchmarks.common import budget_for, dataset, feature_spec

    a = dataset("kV2a")
    feat = feature_spec(a, 64)
    budget = budget_for("kV2a", a, feat)
    cache = TieredSegmentCache(device_budget_bytes=budget)
    sched = SCHEDULERS["aires"](PAPER_GPU_SYSTEM, device_budget=budget,
                                segment_cache=cache)
    for label in ("cold", "warm"):
        m = sched.run(a, feat, dataset="kV2a").metrics
        want = golden["cached_sim"][label]
        for field in METRIC_FIELDS:
            assert getattr(m, field) == want[field], (label, field)


# ---- one plan, two interpreters -------------------------------------------

@pytest.mark.parametrize("sched", ["maxmemory", "ucg", "etc", "aires"])
def test_execute_and_cost_interpret_same_plan_same_metrics(small_graph,
                                                           sched):
    """Simulate-vs-execute agreement is true by construction: interpreting
    one plan with both interpreters yields identical metrics, and the
    execute pass adds the exact output."""
    a = small_graph
    rng = np.random.default_rng(0)
    h = rng.standard_normal((a.n_rows, 16)).astype(np.float32)
    # Above every scheduler's Table III feasibility floor (MaxMemory/UCG
    # need ≥84 % of required_bytes), still small enough to stream.
    from repro.core import FeatureSpec, required_bytes
    budget = int(1.1 * required_bytes(a, FeatureSpec.of(h)))
    kw = dict(bm=8, bk=8) if sched == "aires" else {}
    scheduler = SCHEDULERS[sched](PAPER_GPU_SYSTEM, device_budget=budget, **kw)

    plan = scheduler.build_plan(a, h, mode="execute")
    m_cost, x_cost = CostInterpreter(PAPER_GPU_SYSTEM).run(plan)
    m_exec, x_exec = ExecuteInterpreter(PAPER_GPU_SYSTEM).run(plan)
    assert x_cost is None
    assert x_exec is not None
    for field in METRIC_FIELDS:
        assert getattr(m_cost, field) == getattr(m_exec, field), field
    ref = spgemm_csr_dense(a, h)
    np.testing.assert_allclose(x_exec, ref, atol=1e-3, rtol=1e-3)


def test_scheduler_run_is_build_plus_interpret(small_graph):
    """run() must be nothing more than build_plan() + interpreter."""
    a = small_graph
    feat = FeatureSpec(a.n_rows, 32, 4, 0.0)
    est = plan_memory_dense_features(a, a.n_rows, 32, float("inf"))
    budget = int(est.m_b + est.m_c + 0.6 * a.nbytes())
    sched = SCHEDULERS["aires"](PAPER_GPU_SYSTEM, device_budget=budget)
    res = sched.run(a, feat)
    plan = sched.build_plan(a, feat)
    m, _ = CostInterpreter(PAPER_GPU_SYSTEM).run(plan)
    for field in METRIC_FIELDS:
        assert getattr(res.metrics, field) == getattr(m, field), field
    assert res.pipeline is not None
    assert res.pipeline.segments == res.metrics.segments


# ---- lane/overlap semantics of the makespan --------------------------------

def _plan(phases):
    p = PipelinePlan(scheduler="test")
    p.phases = phases
    return p


def test_lanes_phase_overlaps_independent_lanes():
    """Two transfers on different lanes overlap; same lane serializes."""
    spec = PAPER_GPU_SYSTEM
    plan = _plan([PhaseSpec("p")])
    plan.add(TransferOp(Path.GDS, MemoryTier.STORAGE, MemoryTier.DEVICE,
                        1 << 20), "p", LANE_GDS)
    plan.add(TransferOp(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE,
                        1 << 20), "p", LANE_DMA)
    m, _ = CostInterpreter(spec).run(plan)
    t_gds = spec.latency_s[Path.GDS] + (1 << 20) / spec.bw[Path.GDS]
    t_dma = spec.latency_s[Path.DMA] + (1 << 20) / spec.bw[Path.DMA]
    assert m.makespan_s == max(t_gds, t_dma)
    assert m.io_modeled_s == t_gds + t_dma

    serial = _plan([PhaseSpec("p")])
    for _ in range(2):
        serial.add(TransferOp(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE,
                              1 << 20), "p", LANE_DMA)
    m2, _ = CostInterpreter(spec).run(serial)
    assert m2.makespan_s == pytest.approx(2 * t_dma)


def test_deps_gate_compute_behind_transfer():
    """A compute op with a transfer dep starts at the transfer's completion
    — the double-buffer recurrence in miniature."""
    spec = PAPER_GPU_SYSTEM
    plan = _plan([PhaseSpec("p")])
    ios, cmps = [], []
    for _ in range(3):
        i = plan.add(TransferOp(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE,
                                1 << 20), "p", LANE_DMA)
        plan.add(ComputeOp(1e-4), "p", LANE_COMPUTE, deps=(i,))
    m, _ = CostInterpreter(spec).run(plan)
    t_dma = spec.latency_s[Path.DMA] + (1 << 20) / spec.bw[Path.DMA]
    # manual recurrence: io chain on its lane, compute waits on io + itself
    pipeline = io_free = 0.0
    for _ in range(3):
        io_done = io_free + t_dma
        pipeline = max(pipeline, io_done) + 1e-4
        io_free = io_done
    assert m.makespan_s == pytest.approx(pipeline)
    assert m.compute_modeled_s == pytest.approx(3e-4)


def test_serial_phase_sums_categories():
    spec = PAPER_GPU_SYSTEM
    plan = _plan([PhaseSpec("p", overlap="serial")])
    plan.add(TransferOp(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE,
                        1 << 20), "p")
    plan.add(HostPreprocessOp(2e-3), "p")
    plan.add(ComputeOp(5e-3), "p")
    m, _ = CostInterpreter(spec).run(plan)
    t_dma = spec.latency_s[Path.DMA] + (1 << 20) / spec.bw[Path.DMA]
    assert m.makespan_s == pytest.approx(t_dma + 2e-3 + 5e-3)
    assert m.host_preprocess_s == 2e-3


def test_alloc_op_oom_aborts_interpretation():
    spec = PAPER_GPU_SYSTEM
    plan = _plan([PhaseSpec("p", overlap="serial")])
    plan.add(AllocOp(MemoryTier.DEVICE, "huge",
                     spec.device_capacity + 1), "p")
    plan.add(TransferOp(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE,
                        1 << 20), "p")
    # analyze=False: the *runtime* OOM path is under test here — the static
    # analyzer (on by default under tests) refuses this plan up front, which
    # tests/test_analysis.py asserts separately.
    m, x = CostInterpreter(spec, analyze=False).run(plan)
    assert m.oom and x is None
    assert m.bytes_by_path == {}  # nothing charged after the failed alloc


def test_oom_plan_short_circuits():
    plan = PipelinePlan(scheduler="t", oom=True)
    m, x = CostInterpreter(PAPER_GPU_SYSTEM).run(plan)
    assert m.oom and x is None


# ---- cache probes: interpret vs estimate (peek) ----------------------------

def _probe_plan(key, nbytes):
    plan = _plan([PhaseSpec("p")])
    miss = TransferOp(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE, nbytes,
                      tag="phaseII/seg")
    plan.add(CacheProbeOp(key, nbytes, miss, value=True), "p", LANE_DMA)
    return plan


def test_estimate_peeks_without_mutating_cache():
    from repro.io.segment_cache import SegmentKey

    cache = TieredSegmentCache(device_budget_bytes=1 << 20)
    key = SegmentKey("g", 0, "bricks", (1,))
    plan = _probe_plan(key, 4096)

    # estimate on a cold cache: miss modeled, nothing inserted
    est = plan.estimate(PAPER_GPU_SYSTEM, segment_cache=cache)
    assert est.cache_hit_bytes == 0
    assert len(cache) == 0 and cache.stats.misses == 0

    # real interpretation inserts; estimate then sees a device hit — still
    # without touching LRU state or stats
    CostInterpreter(PAPER_GPU_SYSTEM, segment_cache=cache).run(plan)
    assert len(cache) == 1
    stats_before = (cache.stats.device_hits, cache.stats.misses)
    est2 = plan.estimate(PAPER_GPU_SYSTEM, segment_cache=cache)
    assert est2.cache_hit_bytes == 4096
    assert est2.bytes_by_path.get("dma", 0) == 0
    assert (cache.stats.device_hits, cache.stats.misses) == stats_before


def test_estimate_models_host_tier_promotion():
    from repro.io.segment_cache import SegmentKey

    cache = TieredSegmentCache(device_budget_bytes=1)  # everything spills
    key = SegmentKey("g", 0, "bricks", (1,))
    plan = _probe_plan(key, 4096)
    CostInterpreter(PAPER_GPU_SYSTEM, segment_cache=cache).run(plan)
    assert cache.tier_of(key) is MemoryTier.HOST
    est = plan.estimate(PAPER_GPU_SYSTEM, segment_cache=cache)
    assert est.cache_hit_bytes == 4096
    # the modeled promotion crosses the bus but is cheaper than a miss +
    # demotion; key point: the brick stays on the host tier (no mutation)
    assert est.bytes_by_path.get("dma", 0) == 4096
    assert cache.tier_of(key) is MemoryTier.HOST


def test_estimate_prices_remote_shard_hits_over_ici():
    """A peeked device hit owned by a remote shard must carry the ICI hop
    the real interpreter charges — estimate and execute agree on sharded
    caches too."""
    from repro.io import ShardedSegmentCache
    from repro.io.segment_cache import SegmentKey
    from repro.io.shard_cache import shard_of

    cache = ShardedSegmentCache(device_budget_bytes=1 << 20, n_shards=4)
    # find a key owned by a remote shard (local is 0)
    key = next(SegmentKey("g", i, "bricks", (1,)) for i in range(64)
               if shard_of(SegmentKey("g", i, "bricks", (1,)), 4) != 0)
    cache.put(key, "brick", 4096)
    plan = _probe_plan(key, 4096)
    est = plan.estimate(PAPER_GPU_SYSTEM, segment_cache=cache)
    assert est.cache_hit_bytes == 4096
    assert est.bytes_by_path.get("ici", 0) == 4096

    # and the real probe charges the same ICI bytes
    m, _ = CostInterpreter(PAPER_GPU_SYSTEM, segment_cache=cache).run(plan)
    assert m.bytes_by_path.get("ici", 0) == 4096


def test_run_releases_payloads_but_stays_estimable(small_graph):
    """Execute-mode results must not pin the densified bricks (this is an
    out-of-core library); the returned plan still cost-interprets."""
    a = small_graph
    rng = np.random.default_rng(3)
    h = rng.standard_normal((a.n_rows, 16)).astype(np.float32)
    est_mem = plan_memory_dense_features(a, a.n_rows, 16, float("inf"))
    budget = int(est_mem.m_b + est_mem.m_c + 0.6 * a.nbytes())
    sched = SCHEDULERS["aires"](PAPER_GPU_SYSTEM, device_budget=budget,
                                bm=8, bk=8)
    res = sched.run(a, h, mode="execute")
    assert res.x is not None
    for bound in res.pipeline.ops:
        op = bound.op
        assert getattr(op, "payload", None) is None
        assert getattr(op, "kernel", None) is None
        assert getattr(op, "pin", None) is None
        assert not hasattr(op, "value") or op.value is True
    assert res.pipeline.reference_kernel is None
    again = res.pipeline.estimate(PAPER_GPU_SYSTEM)
    assert again.makespan_s == res.metrics.makespan_s


# ---- the engine-side plan: stream_plan + estimate --------------------------

def test_stream_plan_estimate_prices_a_pass(small_graph):
    a = small_graph
    est_mem = plan_memory_dense_features(a, a.n_rows, 32, float("inf"))
    budget = int(est_mem.m_b + est_mem.m_c + 0.6 * a.nbytes())
    # Device tier large enough to retain the whole plan: warm hits are then
    # genuinely free (an undersized tier would model promote DMA instead).
    cache = TieredSegmentCache(device_budget_bytes=64 << 20)
    eng = AiresSpGEMM(AiresConfig(device_budget_bytes=budget, bm=8, bk=8,
                                  plan_features=32),
                      segment_cache=cache)
    plan = eng.stream_plan(a, (a.n_rows, 32), spec=PAPER_GPU_SYSTEM)
    assert plan.segments >= 2
    cold = plan.estimate(PAPER_GPU_SYSTEM, segment_cache=cache)
    assert cold.makespan_s > 0
    assert cold.bytes_by_path.get("dma", 0) == plan.wire_bytes()

    # run the pass for real; the warm estimate must now price ~free
    eng(a, jnp.asarray(np.zeros((a.n_rows, 32), np.float32)))
    warm = plan.estimate(PAPER_GPU_SYSTEM, segment_cache=cache)
    assert warm.cache_hit_bytes == plan.wire_bytes()
    assert warm.makespan_s < cold.makespan_s

    # estimating never disturbed the cache: a second real pass is all hits
    eng(a, jnp.asarray(np.zeros((a.n_rows, 32), np.float32)))
    assert eng.last_stream_stats.uploaded_bytes == 0


def test_stream_payloads_follow_plan_order(small_graph):
    a = small_graph
    est_mem = plan_memory_dense_features(a, a.n_rows, 16, float("inf"))
    budget = int(est_mem.m_b + est_mem.m_c + 0.6 * a.nbytes())
    eng = AiresSpGEMM(AiresConfig(device_budget_bytes=budget, bm=8, bk=8))
    plan = eng.stream_plan(a, (a.n_rows, 16))
    payloads = plan.stream_payloads()
    assert [i for i, _ in payloads] == list(range(plan.segments))


# ---- fingerprint namespaces (the id()-reuse bugfix) ------------------------

def test_graph_namespace_is_content_addressed(small_graph):
    """Same structure → same namespace, regardless of object identity;
    different structure → different namespace. id(a) gave neither."""
    import copy

    a = small_graph
    b = copy.deepcopy(a)
    assert a is not b
    assert csr_fingerprint(a) == csr_fingerprint(b)
    assert (AiresSpGEMM.graph_cache_prefix(a)
            == AiresSpGEMM.graph_cache_prefix(b))

    c = copy.deepcopy(a)
    c.indptr = c.indptr.copy()
    # move one nonzero between rows: same nnz/shape, different structure.
    # (The memo rides along with deepcopy — correct for immutable CSRs;
    # this test builds a *new* structure, so drop it.)
    if hasattr(c, "_fingerprint"):
        del c._fingerprint
    row = int(np.argmax(np.diff(c.indptr)))
    c.indptr[row + 1] -= 1
    assert csr_fingerprint(a) != csr_fingerprint(c)


def test_reweighted_graph_gets_its_own_namespace(small_graph):
    """Cached bricks embed edge VALUES, so a re-weighted graph with the
    identical sparsity pattern must not hit the old graph's bricks."""
    import copy

    a = small_graph
    b = copy.deepcopy(a)
    del b._fingerprint
    b.data = b.data * 2.0
    assert csr_fingerprint(a) != csr_fingerprint(b)
    assert (AiresSpGEMM.graph_cache_prefix(a)
            != AiresSpGEMM.graph_cache_prefix(b))

    est = plan_memory_dense_features(a, a.n_rows, 16, float("inf"))
    budget = int(est.m_b + est.m_c + 0.6 * a.nbytes())
    cache = TieredSegmentCache(device_budget_bytes=64 << 20)
    eng = AiresSpGEMM(AiresConfig(device_budget_bytes=budget, bm=8, bk=8),
                      segment_cache=cache)
    h = np.ones((a.n_rows, 16), np.float32)
    xa = np.asarray(eng(a, jnp.asarray(h)))
    assert eng.last_stream_stats.uploaded_bytes > 0
    xb = np.asarray(eng(b, jnp.asarray(h)))
    assert eng.last_stream_stats.cache_hit_bytes == 0, \
        "re-weighted graph must miss the old graph's bricks"
    np.testing.assert_allclose(xb, 2.0 * xa, rtol=1e-5, atol=1e-5)


def test_simulate_cache_hits_across_equal_content_graphs():
    """The scenario the id() bug corrupted: a graph object is GC'd, an
    equal-content graph reappears at (possibly) the same id. Content
    namespaces make the cached segments legitimately reusable."""
    import copy

    from repro.data import (
        SUITESPARSE_SPECS, generate_graph, normalized_adjacency, scaled_spec,
    )

    a1 = normalized_adjacency(generate_graph(
        scaled_spec(SUITESPARSE_SPECS["rUSA"], 2e-5), seed=1))
    a2 = copy.deepcopy(a1)
    feat = FeatureSpec(a1.n_rows, 32, 4, 0.0)
    est = plan_memory_dense_features(a1, a1.n_rows, 32, float("inf"))
    budget = int(est.m_b + est.m_c + 0.6 * a1.nbytes())
    cache = TieredSegmentCache(device_budget_bytes=budget)
    sched = SCHEDULERS["aires"](PAPER_GPU_SYSTEM, device_budget=budget,
                                segment_cache=cache)
    cold = sched.run(a1, feat).metrics
    warm = sched.run(a2, feat).metrics   # different object, same content
    assert cold.cache_hit_bytes == 0
    assert warm.cache_hit_bytes == cold.bytes_by_path.get("dma", 0)


# ---- execute interpreter drives the real streamer --------------------------

def test_execute_stream_counts_match_cost_model(small_graph):
    """The same plan's wire bytes appear identically in the cost reading
    and the real stream's StreamStats — one plan, no drift."""
    a = small_graph
    est_mem = plan_memory_dense_features(a, a.n_rows, 16, float("inf"))
    budget = int(est_mem.m_b + est_mem.m_c + 0.6 * a.nbytes())
    eng = AiresSpGEMM(AiresConfig(device_budget_bytes=budget, bm=8, bk=8))
    h = np.zeros((a.n_rows, 16), np.float32)
    plan = eng.stream_plan(a, (a.n_rows, 16), spec=PAPER_GPU_SYSTEM)
    modeled = plan.estimate(PAPER_GPU_SYSTEM)
    eng(a, jnp.asarray(h))
    real = eng.last_stream_stats
    assert real.uploaded_bytes == modeled.bytes_by_path.get("dma", 0)
    assert real.segments == plan.segments
