"""Property tests for the tiered segment cache (io/segment_cache.py).

Invariants, each driven by hypothesis when installed and by a deterministic
seeded sweep otherwise (the conftest/test_robw_property pattern — fallback,
never skip):
  * LRU order: device eviction is strictly least-recently-used, and a get()
    refreshes recency.
  * capacity: neither tier ever exceeds its byte budget, under any op mix.
  * demote/promote round-trip: a brick that falls to the host tier and is
    promoted back is bit-identical.
  * byte accounting: hit_bytes + miss_bytes equals exactly the wire bytes
    requested through get() — the invariant the serving metrics rely on.
"""
import importlib.util

import numpy as np
import pytest

from repro.io import SegmentKey, TieredSegmentCache
from repro.io.tiers import MemoryTier, PAPER_GPU_SYSTEM, TieredMemorySystem

HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None


def _key(i, graph="g0"):
    return SegmentKey(graph, i, "bricks", (i, 8, 8))


# ---- deterministic unit behaviour ----------------------------------------

def test_lru_eviction_demotes_in_order():
    cache = TieredSegmentCache(device_budget_bytes=3)
    for i in range(3):
        cache.put(_key(i), f"seg{i}", 1)
    cache.put(_key(3), "seg3", 1)           # evicts k0 (oldest)
    assert cache.tier_of(_key(0)) == MemoryTier.HOST
    assert cache.tier_of(_key(3)) == MemoryTier.DEVICE
    cache.get(_key(1), nbytes=1)            # refresh k1
    cache.put(_key(4), "seg4", 1)           # now k2 is LRU, not k1
    assert cache.tier_of(_key(2)) == MemoryTier.HOST
    assert cache.tier_of(_key(1)) == MemoryTier.DEVICE
    assert cache.stats.demoted_bytes == 2


def test_host_tier_hit_promotes_back_to_device():
    cache = TieredSegmentCache(device_budget_bytes=2)
    cache.put(_key(0), "a", 1)
    cache.put(_key(1), "b", 1)
    cache.put(_key(2), "c", 1)              # k0 demoted
    assert cache.tier_of(_key(0)) == MemoryTier.HOST
    assert cache.get(_key(0), nbytes=1) == "a"
    assert cache.tier_of(_key(0)) == MemoryTier.DEVICE
    assert cache.stats.host_hits == 1
    assert cache.stats.promoted_bytes == 1


def test_host_budget_drops_overflow_for_good():
    cache = TieredSegmentCache(device_budget_bytes=1, host_budget_bytes=1)
    cache.put(_key(0), "a", 1)
    cache.put(_key(1), "b", 1)              # k0 -> host
    cache.put(_key(2), "c", 1)              # k1 -> host, k0 dropped
    assert _key(0) not in cache
    assert cache.stats.evicted_bytes == 1
    assert cache.get(_key(0), nbytes=1) is None


def test_oversized_entry_spills_straight_to_host():
    cache = TieredSegmentCache(device_budget_bytes=4)
    cache.put(_key(0), "big", 9)
    assert cache.tier_of(_key(0)) == MemoryTier.HOST
    assert cache.device_used_bytes == 0
    assert cache.get(_key(0), nbytes=9) == "big"  # served, promoted-or-held
    assert cache.device_used_bytes <= 4


def test_transfers_charged_through_tiered_memory_system():
    tms = TieredMemorySystem(PAPER_GPU_SYSTEM)
    cache = TieredSegmentCache(device_budget_bytes=2, tms=tms)
    cache.put(_key(0), "a", 1)
    cache.put(_key(1), "b", 1)
    cache.put(_key(2), "c", 1)              # one demotion
    cache.get(_key(0), nbytes=1)            # promotion (+ a demotion: full)
    tags = [t.tag for t in tms.transfers]
    assert tags == ["cache/demote", "cache/promote", "cache/demote"]
    assert cache.last_get_transfer_s > 0.0
    n_before = len(tms.transfers)
    cache.get(_key(0), nbytes=1)            # device hit: free
    assert cache.last_get_transfer_s == 0.0
    assert len(tms.transfers) == n_before


def test_invalidate_graph_drops_both_tiers():
    cache = TieredSegmentCache(device_budget_bytes=2)
    cache.put(_key(0, "gA"), "a", 1, pin="graph-object-A")
    cache.put(_key(1, "gA"), "b", 1)
    cache.put(_key(2, "gB"), "c", 1)        # demotes k0
    assert cache.invalidate_graph("gA") == 2
    assert len(cache) == 1
    assert cache.tier_of(_key(2, "gB")) is not None


def test_invalidate_prefix_is_delimiter_aware():
    """Regression (ISSUE 7 satellite): raw-string prefix matching let
    invalidating `g12` take out an innocent `g123` bystander. Matching is
    now `:`-boundary aware — only the graph itself and its namespace
    extensions fall."""
    cache = TieredSegmentCache(device_budget_bytes=8)
    cache.put(_key(0, "g12"), "a", 1)
    cache.put(_key(1, "g12:fwd:w64"), "b", 1)
    cache.put(_key(2, "g123"), "c", 1)
    cache.put(_key(3, "g123:fwd:w64"), "d", 1)
    assert cache.invalidate_prefix("g12") == 2
    assert cache.tier_of(_key(0, "g12")) is None
    assert cache.tier_of(_key(1, "g12:fwd:w64")) is None
    assert cache.tier_of(_key(2, "g123")) is not None, \
        "sibling graph sharing leading characters must survive"
    assert cache.tier_of(_key(3, "g123:fwd:w64")) is not None


def test_prefix_matches_semantics():
    from repro.io import prefix_matches

    assert prefix_matches("g12", "g12")
    assert prefix_matches("g12:fwd:w64", "g12")
    assert not prefix_matches("g123", "g12")
    assert not prefix_matches("g123:fwd", "g12")
    assert not prefix_matches("g1", "g12")
    assert prefix_matches(1234, "x", exact=1234)   # non-string graph ids
    assert not prefix_matches(1234, "12")


def test_invalidate_keys_drops_exact_keys_both_tiers():
    """The delta-update path: exactly the stale keys fall, nothing else —
    including a host-tier (demoted) entry."""
    cache = TieredSegmentCache(device_budget_bytes=2)
    cache.put(_key(0), "a", 1)
    cache.put(_key(1), "b", 1)
    cache.put(_key(2), "c", 1)              # k0 demoted to host
    assert cache.tier_of(_key(0)) == MemoryTier.HOST
    assert cache.invalidate_keys([_key(0), _key(2), _key(9)]) == 2
    assert cache.tier_of(_key(0)) is None
    assert cache.tier_of(_key(2)) is None
    assert cache.tier_of(_key(1)) == MemoryTier.DEVICE


def test_invalidate_keys_unpublishes_directory_holdings():
    from repro.io import CacheDirectory

    directory = CacheDirectory()
    directory.claim_worker("w0")
    cache = TieredSegmentCache(device_budget_bytes=1, directory=directory,
                               worker_id="w0")
    cache.put(_key(0), "a", 1)
    cache.put(_key(1), "b", 1)              # k0 demoted → published
    assert directory.holder(_key(0)) == "w0"
    cache.invalidate_keys([_key(0)])
    assert directory.holder(_key(0)) is None


def test_directory_drop_reaches_any_holder():
    """`drop` removes a record regardless of holder (unlike the
    holder-checked `unpublish`) — a graph delta makes peers' copies stale
    too."""
    from repro.io import CacheDirectory

    directory = CacheDirectory()
    directory.publish(_key(0), "peer", "v", 4)
    directory.unpublish(_key(0), "me")      # holder-checked: no-op
    assert directory.holder(_key(0)) == "peer"
    assert directory.drop(_key(0)) is True
    assert directory.holder(_key(0)) is None
    assert directory.drop(_key(0)) is False


def test_directory_drop_prefix_delimiter_aware_and_holder_filtered():
    from repro.io import CacheDirectory

    directory = CacheDirectory()
    directory.publish(_key(0, "g12:fwd"), "w0", "a", 1)
    directory.publish(_key(1, "g12:bwd"), "w1", "b", 1)
    directory.publish(_key(2, "g123:fwd"), "w0", "c", 1)
    assert directory.drop_prefix("g12", worker_id="w0") == 1
    assert directory.holder(_key(1, "g12:bwd")) == "w1"
    assert directory.holder(_key(2, "g123:fwd")) == "w0"
    assert directory.drop_prefix("g12") == 1    # any holder
    assert len(directory) == 1


def test_fingerprint_distinguishes_segment_generations():
    """Same (graph, segment, format, shape) but different content
    fingerprints are different cache keys — the stale generation cannot
    shadow the fresh one."""
    cache = TieredSegmentCache(device_budget_bytes=4)
    stale = SegmentKey("g0", 0, "bricks", (1, 8, 8), fingerprint="s8n4caaaa")
    fresh = SegmentKey("g0", 0, "bricks", (1, 8, 8), fingerprint="s8n5cbbbb")
    cache.put(stale, "old", 1)
    assert cache.get(fresh, nbytes=1) is None
    cache.put(fresh, "new", 1)
    assert cache.get(fresh, nbytes=1) == "new"
    assert cache.get(stale, nbytes=1) == "old"


# ---- the properties (plain functions — both drivers call these) ----------

def check_capacity_and_accounting(seed):
    """No op sequence may overrun a tier budget, and requested wire bytes
    split exactly into hit_bytes + miss_bytes."""
    rng = np.random.default_rng(seed)
    dev_budget = int(rng.integers(4, 64))
    host_budget = (int(rng.integers(4, 64))
                   if rng.random() < 0.7 else None)
    cache = TieredSegmentCache(dev_budget, host_budget)
    keys = [_key(j, graph=f"g{j % 3}") for j in range(10)]
    requested = 0
    for _ in range(80):
        k = keys[int(rng.integers(0, len(keys)))]
        nb = int(rng.integers(1, dev_budget + 16))
        if rng.random() < 0.5:
            requested += nb
            cache.get(k, nbytes=nb)
        else:
            cache.put(k, ("payload", k.segment_id, nb), nb)
        assert cache.device_used_bytes <= dev_budget
        if host_budget is not None:
            assert cache.host_used_bytes <= host_budget
    st = cache.stats
    assert st.hit_bytes + st.miss_bytes == requested


def check_lru_keeps_newest(seed):
    """After n distinct 1-byte puts into a k-slot device tier, exactly the
    last k live on device and the earlier ones were demoted oldest-first."""
    rng = np.random.default_rng(seed)
    k = int(rng.integers(2, 8))
    n = int(rng.integers(k + 1, 20))
    cache = TieredSegmentCache(device_budget_bytes=k)
    for i in range(n):
        cache.put(_key(i), i, 1)
    for i in range(n - k):
        assert cache.tier_of(_key(i)) == MemoryTier.HOST
    for i in range(n - k, n):
        assert cache.tier_of(_key(i)) == MemoryTier.DEVICE
    # host tier preserves demotion (FIFO) order
    host_keys = [key.segment_id for key in cache._host]
    assert host_keys == sorted(host_keys)


def check_demote_promote_bit_identical(seed):
    """Bricks that bounce device->host->device come back bit-identical."""
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    n_bricks = int(rng.integers(3, 7))
    arrays = [rng.standard_normal((int(rng.integers(1, 5)), 8, 8))
              .astype(np.float32) for _ in range(n_bricks)]
    nbytes = [a.nbytes for a in arrays]
    # device tier holds barely one brick: every put demotes the previous
    cache = TieredSegmentCache(device_budget_bytes=max(nbytes))
    for i, arr in enumerate(arrays):
        cache.put(_key(i), (jnp.asarray(arr), f"meta{i}"), nbytes[i])
    for i, arr in enumerate(arrays):
        value = cache.get(_key(i), nbytes=nbytes[i])
        assert value is not None, "demoted bricks must remain servable"
        got, meta = value
        assert meta == f"meta{i}"
        np.testing.assert_array_equal(np.asarray(got), arr)
    assert cache.stats.demoted_bytes > 0
    assert cache.stats.promoted_bytes > 0


# ---- hypothesis driver ---------------------------------------------------

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_capacity_and_accounting(seed):
        check_capacity_and_accounting(seed)

    @settings(max_examples=40, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_lru_keeps_newest(seed):
        check_lru_keeps_newest(seed)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_demote_promote_bit_identical(seed):
        check_demote_promote_bit_identical(seed)


# ---- deterministic fallback driver (no hypothesis installed) -------------

else:
    @pytest.mark.parametrize("seed", range(25))
    def test_capacity_and_accounting(seed):
        check_capacity_and_accounting(seed)

    @pytest.mark.parametrize("seed", range(25))
    def test_lru_keeps_newest(seed):
        check_lru_keeps_newest(seed)

    @pytest.mark.parametrize("seed", range(10))
    def test_demote_promote_bit_identical(seed):
        check_demote_promote_bit_identical(seed)
