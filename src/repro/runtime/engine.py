"""Out-of-core GCN serving engine: multi-graph batching over AiresSpGEMM.

The ROADMAP's serving target meets the paper's Phase III: requests against
many resident graphs are queued, grouped by graph, and served through ONE
`AiresSpGEMM` per graph — all engines sharing one tiered segment cache
(`repro.io.segment_cache`), so the expensive part of a request (streaming
BlockELL bricks host→device) amortizes across requests, layers and epochs.

Three mechanisms do the work:

  * one prepared plan per graph — every engine plans at the pinned width
    `EngineConfig.max_batch_features` (`AiresConfig.plan_features`), so all
    layer widths and all batch widths up to the pin share a single RoBW plan
    and its cached bricks. This replaces leaning on `AiresSpGEMM`'s flat
    `PREPARED_CACHE_MAX=8` LRU, which cycles when widths multiply.
  * column-concat batching — X = A·[H₁|H₂|…] computes every queued
    request's aggregation for a graph in a single streamed pass; outputs
    split per request and the cheap dense transforms run per request.
  * Phase III chaining — activations stay jax device arrays between layers
    (relu((A H) W) chains), never round-tripping through host numpy until
    the final result is handed back.

Request semantics: a request with L weight matrices computes
    h ← relu((A h) Wₗ) for l < L-1;  output = (A h) W_{L-1}
(final layer linear); L = 0 returns the bare aggregation A·H.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

from repro.checkpoint.checkpointer import (
    load_segment_bricks,
    save_segment_bricks,
)
from repro.core.autotune import TunedSchedule, autotune_schedule
from repro.core.calibration import CostCalibrator
from repro.core.passes import PassPipeline, PlanPass
from repro.core.spgemm import AiresConfig, AiresSpGEMM
from repro.io.segment_cache import (
    CacheDirectory,
    CacheStats,
    SegmentKey,
    TieredSegmentCache,
)
from repro.io.shard_cache import ShardedSegmentCache
from repro.io.tiers import (
    ICI_ALL_TO_ALL,
    ICITopology,
    MemoryTier,
    Path,
    TieredMemorySystem,
    TierSpec,
    TPU_V5E_SYSTEM,
)
from repro.sparse.formats import CSR, BlockELL
from repro.sparse.partition import Partition, partition_graph
from repro.sparse.updates import EdgeDelta, apply_edge_updates


@dataclasses.dataclass
class EngineConfig:
    """Knobs for the serving engine (see README "Serving engine")."""

    device_budget_bytes: int
    cache_enabled: bool = True
    # Segment-cache tiers: device defaults to the streaming budget (the
    # bricks the plan streams are exactly what is worth keeping resident),
    # host to 8× that; None host budget = unbounded spill.
    cache_device_bytes: Optional[int] = None
    cache_host_bytes: Optional[int] = None
    # Sharded device tier (io/shard_cache.py): >1 partitions the cache's
    # device budget over `cache_shards` independent LRU shards, remote hits
    # riding the ICI path. 1 (default) keeps the PR-2 single-chip cache —
    # byte-identical accounting. A mesh passed to ServingEngine overrides
    # this with the size of `cache_shard_axis`.
    cache_shards: int = 1
    cache_shard_axis: str = "cache"
    # Identity of this replicated worker in a shared CacheDirectory.
    worker_id: int = 0
    # Planning width: one plan serves all request/layer widths up to this,
    # and batches are chunked so concatenated width never exceeds it.
    max_batch_features: int = 64
    bm: int = 8
    bk: int = 8
    align: int = 8
    stream_depth: int = 2
    straggler_deadline_s: Optional[float] = None
    interpret: Optional[bool] = None
    # Cost model used for admission control and warm-start accounting: the
    # engine prices each request with `PipelinePlan.estimate()` under this
    # TierSpec before it is allowed onto the queue.
    tier_spec: TierSpec = TPU_V5E_SYSTEM
    # Admission control: reject a submit() once the estimated cost of the
    # already-queued requests plus the new one exceeds this many modeled
    # seconds (None = unbounded queue, the pre-admission behavior).
    max_queue_cost_s: Optional[float] = None
    # Plan-rewrite passes (repro.core.passes): a PassPipeline — or a
    # sequence of PlanPass instances — applied to every stream plan before
    # it is estimated or executed; an EDFOrderingPass in the pipeline
    # additionally reorders run_batch() work earliest-deadline-first.
    # None (default) and the empty pipeline reproduce pass-free behavior
    # bit-exactly.
    plan_passes: Optional["PassPipeline | Sequence[PlanPass]"] = None
    # Inter-chip link topology for the sharded cache's ICI charges (ring
    # vs all-to-all); all-to-all reproduces the former flat-link costing.
    ici_topology: ICITopology = ICI_ALL_TO_ALL
    # Static plan analysis (repro.core.analysis) before every real stream:
    # True forces it on, False off, None (default) defers to the module
    # default — off in production, on under tests via tests/conftest.py.
    # An error-severity finding raises PlanAnalysisError instead of
    # streaming a semantically broken plan.
    analyze_plans: Optional[bool] = None
    # Clock used for submit stamps, deadline expiry and EDF remaining-time
    # math. None (default) = `time.monotonic`. The continuous serving loop
    # (`repro.runtime.serving_loop`) injects a `VirtualClock` here so trace
    # replays and admission control run on one deterministic timeline.
    clock: Optional[Callable[[], float]] = None
    # Online cost-model calibration (repro.core.calibration): when set,
    # every admission/EDF/backpressure estimate prices against
    # `calibrator.calibrated(tier_spec)` instead of the raw spec, the
    # engine feeds each batch's RequestLatency stream back into it, and a
    # generation bump invalidates the memoized `_pass_costs` (and
    # reprices queued requests). None (default) = static costs, bit-exact
    # to the pre-calibration engine.
    calibrator: Optional[CostCalibrator] = None
    # Explicit ELL bucket ladder for every registered graph's bricks
    # (AiresConfig.ell_buckets); None keeps power-of-two buckets. Usually
    # installed per graph by `install_schedule` rather than set here.
    ell_buckets: Optional[Sequence[int]] = None
    # Partition-aware sharding (repro.sparse.partition): cluster count for
    # the connectivity clustering run over every registered graph when the
    # segment cache is sharded (`cache_shards > 1`). The partition's owner
    # map replaces CRC owners for that graph's bricks, cutting warm-epoch
    # ICI bytes from topology. 0 (default) = off, byte-identical to CRC
    # sharding; ignored on unsharded caches. Per-graph overrides: pass
    # `partition=` to register_graph, or install an autotuned schedule
    # whose `partition_clusters` is set.
    partition_shards: int = 0


@dataclasses.dataclass
class InferenceRequest:
    """One GCN inference against a registered graph.

    `deadline_s` is a relative deadline: the request must *finish* within
    that many wall seconds of submit(). Submission rejects requests whose
    modeled cost alone already exceeds the deadline (infeasible), and
    run_batch() expires requests whose deadline passed while queued.
    """

    graph: str
    features: np.ndarray                  # (n_nodes, F)
    weights: Sequence[np.ndarray] = ()    # per-layer (F_in, F_out) chain
    request_id: int = -1                  # assigned by submit()
    deadline_s: Optional[float] = None
    submitted_s: float = -1.0             # monotonic stamp set by submit()
    estimated_cost_s: float = 0.0         # modeled cost set by submit()


@dataclasses.dataclass
class InferenceResult:
    request_id: int
    graph: str
    output: np.ndarray


@dataclasses.dataclass
class RejectedRequest:
    """Admission-control verdict for a request that never joined the queue
    (or expired on it). Reported in the next BatchReport."""

    graph: str
    reason: str                    # "deadline-infeasible" | "queue-full"
    estimated_cost_s: float
    deadline_s: Optional[float] = None
    request_id: int = -1           # -1: rejected before an id was assigned


class AdmissionError(RuntimeError):
    """submit() refused a request; `.decision` carries the verdict."""

    def __init__(self, decision: RejectedRequest):
        self.decision = decision
        super().__init__(
            f"request on graph {decision.graph!r} rejected "
            f"({decision.reason}): estimated cost "
            f"{decision.estimated_cost_s:.3g}s"
            + (f" vs deadline {decision.deadline_s:.3g}s"
               if decision.deadline_s is not None else ""))


class SubmitReceipt(int):
    """What `submit()` returns: the request id (an int — fully
    backward-compatible everywhere an id was expected) carrying the
    `PipelinePlan.estimate()` cost admission control priced the request
    with. 0.0 when no admission policy (deadline / queue cap) was in
    force — submit() does not pay for plan preparation in that case; use
    `ServingEngine.estimate_request_cost` for an on-demand prediction."""

    estimated_cost_s: float

    def __new__(cls, request_id: int, estimated_cost_s: float = 0.0):
        obj = super().__new__(cls, request_id)
        obj.estimated_cost_s = float(estimated_cost_s)
        return obj


@dataclasses.dataclass
class RequestLatency:
    """Predicted-vs-actual story of one served request.

    `predicted_s` is the request's `PipelinePlan.estimate()` cost (one
    streamed pass per layer — the number admission control uses).
    `actual_s` is the wall-clock from the batch's start until this
    request's output materialized — the user-visible in-batch latency,
    which includes waiting for earlier graph groups (exactly what EDF
    ordering shrinks for urgent requests). `processing_s` is the same
    stamp measured from this request's *own graph group's* start — the
    number comparable to `predicted_s` for cost-model calibration, since
    the prediction prices only this request's streamed work."""

    request_id: int
    graph: str
    predicted_s: float
    actual_s: float
    processing_s: float = 0.0

    @property
    def error_s(self) -> float:
        """Calibration error: group-relative completion vs prediction."""
        return self.processing_s - self.predicted_s


@dataclasses.dataclass
class GraphUpdateReport:
    """What one `update_graph` edge delta changed, end to end."""

    graph: str
    delta: EdgeDelta
    plans_updated: int            # prepared plans migrated (direction×width)
    segments_retiled: int         # bricks re-densified (touched rows only)
    segments_reused: int          # bricks carried over verbatim
    retiled_bytes: int            # wire bytes of the re-densified bricks
    stale_keys: int               # segment keys made stale by the delta
    cache_entries_dropped: int    # of those, entries actually evicted
    wall_seconds: float = 0.0


@dataclasses.dataclass
class WarmStartReport:
    """What warm_start() restored into the segment cache."""

    bricks: int = 0
    wire_bytes: int = 0
    modeled_seconds: float = 0.0   # storage→host + host→device, via the tms


@dataclasses.dataclass
class GroupStats:
    """I/O story of one served column-concat group (the per-group slice of
    a BatchReport's byte accounting) — what `serve_group` returns to both
    `run_batch` and the continuous serving loop."""

    uploaded_bytes: int = 0
    cache_hit_bytes: int = 0
    promoted_bytes: int = 0
    ici_bytes: int = 0
    directory_hit_bytes: int = 0
    segments_streamed: int = 0
    aggregation_passes: int = 0

    def accumulate(self, stats) -> None:
        """Fold one stream's `StreamStats` into the group totals."""
        self.uploaded_bytes += stats.uploaded_bytes
        self.cache_hit_bytes += stats.cache_hit_bytes
        self.promoted_bytes += stats.promoted_bytes
        self.ici_bytes += stats.ici_bytes
        self.directory_hit_bytes += stats.directory_hit_bytes
        self.segments_streamed += stats.segments
        self.aggregation_passes += 1

    def merge(self, other: "GroupStats") -> None:
        """Fold another group's totals into these (batch-level rollup)."""
        for f in dataclasses.fields(self):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))


@dataclasses.dataclass
class BatchReport:
    """One run_batch() drain: results + the I/O story of the batch."""

    results: List[InferenceResult]
    uploaded_bytes: int       # wire bytes freshly streamed host->device
    cache_hit_bytes: int      # wire bytes served from the segment cache
    promoted_bytes: int       # of those, host-tier hits re-crossing the bus
    segments_streamed: int    # consume() invocations (incl. cache hits)
    aggregation_passes: int   # streamed SpGEMM passes (batching merges these)
    wall_seconds: float = 0.0
    # Sharded cache: bytes that crossed the inter-chip path this batch
    # (remote-shard hits + shard placements). 0 for a 1-shard cache.
    ici_bytes: int = 0
    # Cross-worker directory: wire bytes served from a peer worker's host
    # copy, and demotion copies this worker skipped because a peer already
    # holds the brick. 0 with no directory attached.
    directory_hit_bytes: int = 0
    duplicate_avoided_bytes: int = 0
    # Admission control: requests rejected at submit() since the previous
    # report, and queued requests whose deadline expired before this batch
    # ran them.
    rejected: List[RejectedRequest] = dataclasses.field(default_factory=list)
    expired: List[RejectedRequest] = dataclasses.field(default_factory=list)
    # Predicted-vs-actual latency per served request (request_id order).
    request_latency: List[RequestLatency] = dataclasses.field(
        default_factory=list)

    @property
    def bus_bytes(self) -> int:
        """Everything that actually crossed host->device this batch."""
        return self.uploaded_bytes + self.promoted_bytes

    @property
    def hit_rate(self) -> float:
        total = self.uploaded_bytes + self.cache_hit_bytes
        return self.cache_hit_bytes / total if total else 0.0


class ServingEngine:
    """Multi-graph out-of-core GCN inference with a shared segment cache.

    Usage:
        eng = ServingEngine(EngineConfig(device_budget_bytes=...))
        eng.register_graph("socLJ1", adjacency_csr)
        rid = eng.submit(InferenceRequest("socLJ1", h, weights=[w0, w1]))
        report = eng.run_batch()          # drains the queue, grouped by graph

    With `cache_enabled=False` every batch re-streams every segment — bit
    for bit the PR-1 `AiresSpGEMM` behavior (the ablation baseline).

    Scale-out: `config.cache_shards > 1` (or a `mesh` argument) partitions
    the cache's device tier across a mesh axis (`ShardedSegmentCache`), and
    a shared `CacheDirectory` lets replicated workers serve each other's
    demoted bricks instead of duplicating them — see README "Sharded
    serving". Both default off, reproducing PR-2 byte accounting exactly.
    """

    def __init__(self, config: EngineConfig,
                 directory: Optional[CacheDirectory] = None,
                 mesh=None):
        self.config = config
        self.directory = directory
        # Submit stamps, expiry and queue-position math all read this one
        # clock; a VirtualClock here puts the whole admission story on a
        # deterministic replay timeline.
        self.clock: Callable[[], float] = config.clock or time.monotonic
        # Plan-rewrite pipeline every batch's stream plans route through
        # (build → rewrite → interpret). A bare sequence of passes is
        # wrapped here; track_costs=False keeps per-stream estimates off
        # the serving hot path (scheduler runs still report deltas).
        pp = config.plan_passes
        if pp is None:
            self.plan_pipeline: Optional[PassPipeline] = None
        elif isinstance(pp, PassPipeline):
            self.plan_pipeline = pp
        else:
            self.plan_pipeline = PassPipeline(
                list(pp), spec=config.tier_spec, track_costs=False)
        # All modeled I/O this engine performs outside a stream's own
        # accounting window — cache demote/promote churn, warm-start loads —
        # lands here, so `tms.bytes_by_path()` stays honest from the first
        # epoch (the warm-start bricks did cross sio+dma once).
        # keep_records=False: a serving process lives for days; only the
        # bounded per-path aggregates may grow, never a per-transfer log.
        self.tms = TieredMemorySystem(config.tier_spec, keep_records=False)
        self.cache: Optional["TieredSegmentCache | ShardedSegmentCache"] = None
        if not config.cache_enabled and (directory is not None
                                         or mesh is not None):
            raise ValueError(
                "cache_enabled=False contradicts an explicit "
                f"{'directory' if directory is not None else 'mesh'}: "
                "the sharded tier and the cross-worker directory are "
                "cache features")
        if directory is not None:
            # Distinct replica identities, or the directory silently no-ops.
            directory.claim_worker(config.worker_id)
        if config.cache_enabled:
            device_bytes = (config.cache_device_bytes
                            or config.device_budget_bytes)
            if mesh is not None:
                self.cache = ShardedSegmentCache.from_mesh(
                    mesh, device_bytes, axis=config.cache_shard_axis,
                    host_budget_bytes=config.cache_host_bytes, tms=self.tms,
                    directory=directory, worker_id=config.worker_id,
                    topology=config.ici_topology)
            elif config.cache_shards > 1:
                self.cache = ShardedSegmentCache(
                    device_budget_bytes=device_bytes,
                    host_budget_bytes=config.cache_host_bytes,
                    n_shards=config.cache_shards, tms=self.tms,
                    directory=directory, worker_id=config.worker_id,
                    topology=config.ici_topology)
            else:
                self.cache = TieredSegmentCache(
                    device_budget_bytes=device_bytes,
                    host_budget_bytes=config.cache_host_bytes, tms=self.tms,
                    directory=directory, worker_id=config.worker_id)
        self._graphs: "OrderedDict[str, CSR]" = OrderedDict()
        self._engines: Dict[str, AiresSpGEMM] = {}
        self._queue: List[InferenceRequest] = []
        self._next_id = 0
        # Admission-control state: memoized per-(graph, width) pass cost
        # estimates, and the verdicts awaiting their BatchReport.
        self._pass_costs: Dict[tuple, float] = {}
        self._rejected: List[RejectedRequest] = []
        # Calibration generation the memos were priced under; when the
        # calibrator moves past it, cost_spec() clears the memos and
        # reprices the queue. Installed autotuned schedules, per graph.
        self._cost_generation = (config.calibrator.generation
                                 if config.calibrator is not None else 0)
        self._installed_schedules: Dict[str, TunedSchedule] = {}

    # ---- graph registry --------------------------------------------------

    def register_graph(self, name: str, a: CSR,
                       partition: Optional[Partition] = None) -> None:
        """Make a graph servable. CSRs are immutable once registered (the
        cache keys on identity + structure, like AiresSpGEMM's plan cache).

        `partition` installs a connectivity-clustered owner map for this
        graph's bricks (see `repro.sparse.partition`); when omitted and
        `EngineConfig.partition_shards > 0` on a sharded cache, one is
        clustered here from the graph's CSR adjacency. Partitioned graphs
        prepare their forward plan eagerly so the owner map is installed
        on the cache before any `warm_start` puts route bricks to owners.
        """
        if name in self._graphs:
            raise ValueError(f"graph {name!r} already registered")
        a.validate()
        cfg = self.config
        if partition is None:
            partition = self._auto_partition(a)
        self._graphs[name] = a
        eng = AiresSpGEMM(
            AiresConfig(
                device_budget_bytes=cfg.device_budget_bytes,
                bm=cfg.bm, bk=cfg.bk, align=cfg.align,
                stream_depth=cfg.stream_depth,
                straggler_deadline_s=cfg.straggler_deadline_s,
                interpret=cfg.interpret,
                plan_features=cfg.max_batch_features,
                ell_buckets=(list(cfg.ell_buckets)
                             if cfg.ell_buckets else None),
            ),
            segment_cache=self.cache,
            plan_passes=self.plan_pipeline,
            analyze=cfg.analyze_plans,
            partition=partition)
        self._engines[name] = eng
        if partition is not None and self.cache is not None:
            eng._prepare(a, (a.n_rows, cfg.max_batch_features),
                         transpose=False)

    def _auto_partition(self, a: CSR) -> Optional[Partition]:
        """Cluster `a` per `EngineConfig.partition_shards` — None when the
        knob is off or the cache is not sharded (CRC owners are already
        correct, and an owner map of all-zeros would only add overhead)."""
        k = int(self.config.partition_shards or 0)
        n_shards = int(getattr(self.cache, "n_shards", 1) or 1)
        if k <= 0 or n_shards <= 1:
            return None
        return partition_graph(
            a, k, n_shards=n_shards,
            topology=self.config.ici_topology,
            local_shard=int(getattr(self.cache, "local_shard", 0)))

    def evict_graph(self, name: str) -> List[InferenceRequest]:
        """Drop a graph, its engine, its cached segments (every namespace,
        not just plans still in the prepared LRU), and any queued requests
        against it — which are returned so the caller can re-route them."""
        a = self._graphs.pop(name, None)
        self._engines.pop(name, None)
        self._installed_schedules.pop(name, None)
        self._pass_costs = {k: v for k, v in self._pass_costs.items()
                            if k[0] != name}
        if a is not None:
            prefix = AiresSpGEMM.graph_cache_prefix(a)
            if self.cache is not None:
                self.cache.invalidate_prefix(prefix)
            if self.directory is not None:
                # Unpublish this worker's holdings: peers must not be
                # routed a peer-promote for entries we no longer back.
                self.directory.drop_prefix(prefix,
                                           worker_id=self.config.worker_id)
        orphaned = [r for r in self._queue if r.graph == name]
        self._queue = [r for r in self._queue if r.graph != name]
        return orphaned

    def update_graph(self, name: str, inserts=None,
                     deletes=None) -> GraphUpdateReport:
        """Apply an edge delta to a registered graph, in place of the
        evict-and-reregister cycle: prepared plans migrate incrementally
        (`AiresSpGEMM.apply_edge_update` re-tiles only touched row blocks),
        and exactly the stale segment keys are invalidated — device, host,
        sharded tiers, and every `CacheDirectory` holder, peers included.
        Untouched bricks stay resident, so the next epoch re-uploads only
        what the delta touched. Queued requests keep working: the node
        count is unchanged and they resolve the graph by name at serve
        time."""
        a = self._graphs.get(name)
        if a is None:
            raise KeyError(f"graph {name!r} not registered")
        t0 = time.perf_counter()
        new, delta = apply_edge_updates(a, inserts=inserts, deletes=deletes)
        stats = self._engines[name].apply_edge_update(a, new, delta)
        self._graphs[name] = new
        dropped = 0
        if stats.stale_keys:
            if self.cache is not None:
                dropped = self.cache.invalidate_keys(stats.stale_keys)
            if self.directory is not None:
                for key in stats.stale_keys:
                    self.directory.drop(key)
        # Cost memos price segment count and nnz — both may have changed.
        self._pass_costs = {k: v for k, v in self._pass_costs.items()
                            if k[0] != name}
        return GraphUpdateReport(
            graph=name, delta=delta, plans_updated=stats.plans_updated,
            segments_retiled=stats.segments_retiled,
            segments_reused=stats.segments_reused,
            retiled_bytes=stats.retiled_bytes,
            stale_keys=len(stats.stale_keys),
            cache_entries_dropped=dropped,
            wall_seconds=time.perf_counter() - t0)

    @property
    def graphs(self) -> List[str]:
        return list(self._graphs)

    def cache_stats(self) -> Optional[CacheStats]:
        return self.cache.stats if self.cache is not None else None

    # ---- brick checkpointing + warm start --------------------------------
    #
    # Cache keys are content-addressed (csr_fingerprint namespaces), so the
    # bricks one serving process checkpoints are the bricks the next
    # process's streams will look up — warm start survives restarts.

    def checkpoint_cache(self, directory: str, step: int = 0) -> str:
        """Persist the segment cache's bricks (both tiers) for warm_start.

        Only engine-format entries — the `(blocks, col_tile, n_tiles, ell)`
        device payload `AiresSpGEMM` streams — are checkpointed; anything
        else sharing the cache is skipped.
        """
        if self.cache is None:
            raise ValueError("cache_enabled=False: nothing to checkpoint")
        bricks = []
        for key, value, nbytes in self.cache.export_entries():
            if not (isinstance(value, tuple) and len(value) == 4
                    and isinstance(value[3], BlockELL)):
                continue
            ell = value[3]
            meta = {
                "graph_id": key.graph_id,
                "segment_id": key.segment_id,
                "wire_format": key.wire_format,
                "shape": list(key.shape),
                "fingerprint": key.fingerprint,
                "nbytes": int(nbytes),
                "bm": ell.bm, "bk": ell.bk,
                "n_rows": ell.n_rows, "n_cols": ell.n_cols,
            }
            bricks.append((meta, {"blocks": np.asarray(ell.blocks),
                                  "col_tile": np.asarray(ell.col_tile),
                                  "n_tiles": np.asarray(ell.n_tiles)}))
        return save_segment_bricks(directory, bricks, step=step)

    def warm_start(self, checkpoint_dir: str) -> WarmStartReport:
        """Pre-populate the segment cache from checkpointed bricks.

        Every restored brick is charged through the engine's
        `TieredMemorySystem` — one storage→host read plus one host→device
        upload — so the first epoch's `tms.bytes_by_path()` stays honest:
        warm-started bricks were not free, they crossed the bus before the
        first request arrived (just not inside any request's latency).
        """
        if self.cache is None:
            raise ValueError("cache_enabled=False contradicts warm_start")
        report = WarmStartReport()
        for meta, arrays in load_segment_bricks(checkpoint_dir):
            ell = BlockELL(
                blocks=arrays["blocks"], col_tile=arrays["col_tile"],
                n_tiles=arrays["n_tiles"], bm=int(meta["bm"]),
                bk=int(meta["bk"]), n_rows=int(meta["n_rows"]),
                n_cols=int(meta["n_cols"]))
            # `fingerprint` absent in pre-delta checkpoints: restore with ""
            # — such keys simply miss (and re-stream) under the
            # fingerprint-bearing keys current plans emit.
            key = SegmentKey(meta["graph_id"], meta["segment_id"],
                             meta["wire_format"], tuple(meta["shape"]),
                             fingerprint=meta.get("fingerprint", ""))
            nbytes = int(meta["nbytes"])
            report.modeled_seconds += self.tms.transfer(
                Path.STORAGE_HOST, MemoryTier.STORAGE, MemoryTier.HOST,
                nbytes, tag="warmstart/load")
            report.modeled_seconds += self.tms.transfer(
                Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE,
                nbytes, tag="warmstart/promote")
            self.cache.put(key, AiresSpGEMM.device_payload(ell), nbytes,
                           tms=self.tms)
            report.bricks += 1
            report.wire_bytes += nbytes
        return report

    # ---- admission control (satellite of the pipeline-IR tentpole) -------

    def cost_spec(self) -> TierSpec:
        """The `TierSpec` every cost estimate prices against. Without a
        calibrator this is the configured spec, bit-exactly. With one,
        it is `calibrator.calibrated(tier_spec)`; and whenever the
        calibrator's generation has moved since the memos were priced,
        the `_pass_costs` memo is dropped and every queued request whose
        estimate an admission policy already filled is repriced — EDF
        order and `max_queue_cost_s` backpressure see the new costs on
        the very next decision."""
        cal = self.config.calibrator
        if cal is None:
            return self.config.tier_spec
        if cal.generation != self._cost_generation:
            # Mark current *first*: repricing below re-enters cost_spec()
            # via estimate_request_cost, which must not recurse.
            self._cost_generation = cal.generation
            self._pass_costs.clear()
            self._queue = [
                dataclasses.replace(
                    r, estimated_cost_s=self.estimate_request_cost(r))
                if r.estimated_cost_s > 0.0 else r
                for r in self._queue]
        return cal.calibrated(self.config.tier_spec)

    def _pass_cost(self, name: str, width: int,
                   spec: Optional[TierSpec] = None) -> float:
        """Modeled makespan of one streamed aggregation pass at `width`,
        via the engine's own `PipelinePlan.estimate()` (cold-cache reading:
        admission must hold even if the cache is evicted underneath the
        queue). Memoized under the current `cost_spec()` — the plan is
        pinned per graph, so the estimate only varies with the feature
        width (and the calibration generation, which clears the memo).
        An explicit `spec` bypasses the memo entirely — that is how
        callers compare calibrated vs uncalibrated pricing."""
        if spec is not None:
            a = self._graphs[name]
            plan = self._engines[name].stream_plan(
                a, (a.n_rows, int(width)), spec=spec)
            return plan.estimate(spec).makespan_s
        # cost_spec() first: a generation move clears the memo below.
        sp = self.cost_spec()
        key = (name, int(width))
        if key not in self._pass_costs:
            a = self._graphs[name]
            plan = self._engines[name].stream_plan(
                a, (a.n_rows, int(width)), spec=sp)
            self._pass_costs[key] = plan.estimate(sp).makespan_s
        return self._pass_costs[key]

    def estimate_request_cost(self, request: InferenceRequest,
                              spec: Optional[TierSpec] = None) -> float:
        """Modeled seconds to serve `request`: one streamed pass per layer,
        each at that layer's activation width. `spec` pins the pricing
        spec (unmemoized); default is the calibrated `cost_spec()`."""
        widths = [int(request.features.shape[1])]
        for w in list(request.weights)[:-1]:
            widths.append(int(w.shape[1]))
        return sum(self._pass_cost(request.graph, wd, spec=spec)
                   for wd in widths)

    def estimate_group_cost(self, name: str, group: Sequence[InferenceRequest]
                            ) -> float:
        """Modeled seconds for one column-concat group of requests against
        `name`: mirrors `_batched_aggregate`'s greedy chunking exactly —
        per layer level, live request widths pack into passes capped at
        `max_batch_features`, each pass priced by the memoized
        `PipelinePlan.estimate()` cost at its concatenated width. This is
        the per-group cost the continuous loop's queue-position EDF
        accumulates into time-to-front."""
        cap = self.config.max_batch_features
        per_req: List[List[int]] = []
        for r in group:
            ws = list(r.weights)
            per_req.append([int(r.features.shape[1])]
                           + [int(np.asarray(w).shape[1]) for w in ws[:-1]])
        total = 0.0
        for layer in range(max((len(lv) for lv in per_req), default=0)):
            width = 0
            for lv in per_req:
                if layer >= len(lv):
                    continue
                f = lv[layer]
                if width and width + f > cap:
                    total += self._pass_cost(name, width)
                    width = 0
                width += f
            if width:
                total += self._pass_cost(name, width)
        return total

    def queued_cost_s(self) -> float:
        """Estimated cost of everything still awaiting service. In the
        round engine the queue empties only at a drain; under the
        continuous loop served groups leave it step by step, so the
        `max_queue_cost_s` backpressure prices the *remaining* queue, not
        a round snapshot."""
        if self.config.calibrator is not None:
            self.cost_spec()  # reprice stale entries before summing
        return sum(r.estimated_cost_s for r in self._queue)

    def feed_latencies(self, latencies: Sequence[RequestLatency]) -> int:
        """Feed one batch's `RequestLatency` stream into the configured
        calibrator (no-op without one). `run_batch` calls this after every
        drain; the continuous loop (`ContinuousServer.step`) calls it per
        served group. Returns the number of samples folded in."""
        cal = self.config.calibrator
        if cal is None or not latencies:
            return 0
        return cal.observe_batch(latencies)

    # ---- autotuned schedules (repro.core.autotune) ------------------------

    def autotune(self, name: str, width: Optional[int] = None,
                 install: bool = False) -> TunedSchedule:
        """Search (coalescing min_bytes × pass order × ELL bucket set) for
        one registered graph, priced under the calibrated `cost_spec()`;
        optionally install the winner. Never predicted worse than default
        (the default arm is always a candidate)."""
        if name not in self._graphs:
            raise KeyError(f"graph {name!r} not registered")
        tuned = autotune_schedule(
            self._engines[name], self._graphs[name], graph=name,
            width=int(width or self.config.max_batch_features),
            spec=self.cost_spec(), segment_cache=self.cache)
        if install:
            self.install_schedule(tuned)
        return tuned

    def install_schedule(self, tuned: TunedSchedule) -> None:
        """Install an autotuned schedule for `tuned.graph`: that graph's
        `AiresSpGEMM` gets its own `PassPipeline` in tuned order (other
        graphs keep the shared engine pipeline), a changed ELL bucket set
        drops the graph's prepared plans and cached bricks (its cache
        namespaces carry a bucket tag, so stale default-bucket entries
        are reclaimed, not shadowed), and the graph's cost memos are
        invalidated so admission prices the tuned plans."""
        name = tuned.graph
        if name not in self._graphs:
            raise KeyError(f"graph {name!r} not registered")
        eng = self._engines[name]
        eng.plan_passes = PassPipeline(
            tuned.build_passes(), spec=self.config.tier_spec,
            track_costs=False)
        changed = False
        new_buckets = (list(tuned.ell_buckets)
                       if tuned.ell_buckets is not None else None)
        if new_buckets != (eng.config.ell_buckets or None):
            eng.config = dataclasses.replace(eng.config,
                                             ell_buckets=new_buckets)
            changed = True
        # A changed cluster count re-partitions the graph (same clustering
        # the autotuner's trial arm priced); like a bucket change, the old
        # namespaces (different `:p` tag) are reclaimed, not shadowed.
        old_clusters = (eng.partition.n_clusters
                        if eng.partition is not None else None)
        if tuned.partition_clusters != old_clusters:
            if tuned.partition_clusters is None:
                eng.partition = None
            else:
                eng.partition = partition_graph(
                    self._graphs[name], int(tuned.partition_clusters),
                    n_shards=int(getattr(self.cache, "n_shards", 1) or 1),
                    topology=self.config.ici_topology,
                    local_shard=int(getattr(self.cache, "local_shard", 0)))
            changed = True
        if changed:
            eng.clear_cache()
            if self.cache is not None:
                self.cache.invalidate_prefix(
                    AiresSpGEMM.graph_cache_prefix(self._graphs[name]))
        self._pass_costs = {k: v for k, v in self._pass_costs.items()
                            if k[0] != name}
        self._installed_schedules[name] = tuned

    @property
    def installed_schedules(self) -> Dict[str, TunedSchedule]:
        return dict(self._installed_schedules)

    def _reject(self, request: InferenceRequest, reason: str,
                est: float) -> None:
        decision = RejectedRequest(
            graph=request.graph, reason=reason, estimated_cost_s=est,
            deadline_s=request.deadline_s, request_id=request.request_id)
        self._rejected.append(decision)
        raise AdmissionError(decision)

    # ---- request queue ---------------------------------------------------

    def submit(self, request: InferenceRequest) -> SubmitReceipt:
        """Queue a request; returns its id as a `SubmitReceipt` (an int)
        carrying the admission-control cost prediction, so callers see the
        latency estimate the engine already computed for them."""
        if request.graph not in self._graphs:
            raise KeyError(f"graph {request.graph!r} not registered")
        n = self._graphs[request.graph].n_rows
        if request.features.shape[0] != n:
            raise ValueError(
                f"features rows {request.features.shape[0]} != graph nodes {n}")
        cap = self.config.max_queue_cost_s
        est = 0.0
        if request.deadline_s is not None or cap is not None:
            # Price the request only when an admission policy can act on
            # it: the estimate's first call per (graph, width) runs RoBW +
            # densification, which must not tax submit() latency for
            # deployments that never set a deadline or a queue cap.
            est = self.estimate_request_cost(request)
        if request.deadline_s is not None and est > request.deadline_s:
            self._reject(request, "deadline-infeasible", est)
        if cap is not None and self.queued_cost_s() + est > cap:
            self._reject(request, "queue-full", est)
        request = dataclasses.replace(
            request, request_id=self._next_id, estimated_cost_s=est,
            submitted_s=self.clock())
        self._next_id += 1
        self._queue.append(request)
        return SubmitReceipt(request.request_id, est)

    def infer(self, graph: str, features: np.ndarray,
              weights: Sequence[np.ndarray] = (),
              deadline_s: Optional[float] = None) -> np.ndarray:
        """Convenience: run one request immediately, without draining (or
        disturbing) other callers' queued requests.

        Admission verdicts accumulated from *other* callers' submits since
        the last batch are stashed across the internal drain and restored
        for the next real `run_batch` report — they must not vanish into
        the private report this method discards. If this request itself
        cannot produce a result (its own deadline expired before the
        internal batch ran), an `AdmissionError` naming the expiry is
        raised instead of an opaque `StopIteration`.
        """
        pending, self._queue = self._queue, []
        foreign, self._rejected = self._rejected, []
        try:
            rid = self.submit(InferenceRequest(graph, features, weights,
                                               deadline_s=deadline_s))
            report = self.run_batch()
        finally:
            # Restore other callers' state: their queued requests, and the
            # verdicts whose BatchReport has not happened yet (plus this
            # call's own submit-rejection, if submit() raised above — that
            # verdict surfaces in the next real report, as usual).
            self._queue = pending + self._queue
            self._rejected = foreign + self._rejected
        for r in report.results:
            if r.request_id == rid:
                return r.output
        for verdict in report.expired:
            if verdict.request_id == rid:
                raise AdmissionError(verdict)
        raise RuntimeError(
            f"infer request {int(rid)} on graph {graph!r} produced no "
            f"result and no expiry verdict — the internal batch returned "
            f"{len(report.results)} result(s) for other ids")

    # ---- batched execution -----------------------------------------------
    #
    # run_batch() is a composition of three reusable pieces — group-form
    # (`prepare_queue` + `order_queue`), group-run (`serve_group`) — which
    # the continuous serving loop (repro.runtime.serving_loop) drives one
    # group at a time instead of as a full drain.

    def prepare_queue(self, queue: List[InferenceRequest], now: float
                      ) -> Tuple[List[InferenceRequest],
                                 List[RejectedRequest]]:
        """Group-form step 1: stamp, expire, price. Returns the serve-ready
        queue (new `InferenceRequest` copies — caller-held objects are
        never mutated) and the expiry verdicts.

          * a request that reached the queue without passing ``submit()``
            (e.g. an `evict_graph` orphan re-queued directly) still holds
            the ``submitted_s = -1.0`` sentinel; it is stamped `now` on
            first sight so its relative deadline starts counting here
            instead of instantly expiring against the monotonic epoch;
          * a request whose relative deadline passed while it waited is
            dropped, not run — it could only waste the batch's budget
            producing an answer nobody can use;
          * requests no admission policy already priced get their
            `estimated_cost_s` filled via `dataclasses.replace` — the
            estimate shares the plan preparation the stream needs anyway
            (memoized per graph × width). If the calibrator moved since
            the queue was priced, *every* entry is repriced — `queue`
            was detached from `self._queue` by the caller, so the
            generation sweep in `cost_spec()` cannot reach it.
        """
        stale = False
        cal = self.config.calibrator
        if cal is not None and cal.generation != self._cost_generation:
            self.cost_spec()
            stale = True
        ready: List[InferenceRequest] = []
        expired: List[RejectedRequest] = []
        for r in queue:
            if r.submitted_s < 0.0:
                r = dataclasses.replace(r, submitted_s=now)
            if r.deadline_s is not None and now - r.submitted_s > r.deadline_s:
                expired.append(RejectedRequest(
                    graph=r.graph, reason="deadline-expired",
                    estimated_cost_s=r.estimated_cost_s,
                    deadline_s=r.deadline_s, request_id=r.request_id))
                continue
            if r.estimated_cost_s <= 0.0 or stale:
                r = dataclasses.replace(
                    r, estimated_cost_s=self.estimate_request_cost(r))
            ready.append(r)
        return ready, expired

    def order_queue(self, queue: List[InferenceRequest]
                    ) -> Tuple[List[InferenceRequest], List[str]]:
        """Group-form step 2: deadline-aware ordering. An EDFOrderingPass
        in the configured pipeline reorders the queue (earliest deadline
        first, Moore–Hodgson tardy demotion over `estimated_cost_s`), and
        graph groups then run in first-appearance order of that queue.
        Without an ordering pass, registration order — byte-identical to
        the pre-pass engine."""
        if (self.plan_pipeline is not None
                and self.plan_pipeline.orders_requests):
            queue = self.plan_pipeline.order_requests(queue)
            return queue, list(dict.fromkeys(r.graph for r in queue))
        return queue, list(self._graphs)  # registration order

    def run_batch(self) -> BatchReport:
        """Drain the queue: group by graph, batch aggregations per layer."""
        queue, self._queue = self._queue, []
        results: List[InferenceResult] = []
        t0 = time.perf_counter()
        unknown = sorted({r.graph for r in queue} - set(self._graphs))
        if unknown:
            self._queue = queue + self._queue  # nothing consumed
            raise KeyError(
                f"queued requests reference unregistered graphs {unknown}")
        queue, expired = self.prepare_queue(queue, self.clock())
        queue, graph_order = self.order_queue(queue)
        totals = GroupStats()
        latency: List[RequestLatency] = []
        # Duplicate-avoided demotions happen inside put()/evictions, outside
        # any stream's stats window — diff the cache's cumulative counter.
        dup0 = (self.cache.stats.duplicate_avoided_bytes
                if self.cache is not None else 0)
        for name in graph_order:
            group = [r for r in queue if r.graph == name]
            if not group:
                continue
            group_results, done_s, stats = self.serve_group(name, group, t0)
            results.extend(group_results)
            latency.extend(
                RequestLatency(r.request_id, name, r.estimated_cost_s,
                               *done_s[r.request_id])
                for r in group)
            totals.merge(stats)
        results.sort(key=lambda r: r.request_id)
        latency.sort(key=lambda l: l.request_id)
        self.feed_latencies(latency)
        dup = ((self.cache.stats.duplicate_avoided_bytes - dup0)
               if self.cache is not None else 0)
        rejected, self._rejected = self._rejected, []
        return BatchReport(
            results=results, uploaded_bytes=totals.uploaded_bytes,
            cache_hit_bytes=totals.cache_hit_bytes,
            promoted_bytes=totals.promoted_bytes,
            segments_streamed=totals.segments_streamed,
            aggregation_passes=totals.aggregation_passes,
            wall_seconds=time.perf_counter() - t0,
            ici_bytes=totals.ici_bytes,
            directory_hit_bytes=totals.directory_hit_bytes,
            duplicate_avoided_bytes=dup,
            rejected=rejected, expired=expired, request_latency=latency)

    def serve_group(self, name: str, group: List[InferenceRequest],
                    t0: float) -> tuple:
        """Group-run: serve one graph's requests through the column-concat
        streamed passes; returns (results, completion stamps keyed by
        request id — `(since_batch_t0, since_group_start)` wall seconds,
        taken when each request's output materializes on host — and the
        group's `GroupStats` byte accounting)."""
        a = self._graphs[name]
        eng = self._engines[name]
        mark = len(eng.forward_stats_log)
        g0 = time.perf_counter()
        # Per-request device-resident state: (request, activation, next layer).
        acts = [jnp.asarray(np.asarray(r.features, dtype=np.float32))
                for r in group]
        wss = [[jnp.asarray(np.asarray(w, dtype=np.float32)) for w in r.weights]
               for r in group]
        n_aggs = [max(len(ws), 1) for ws in wss]
        outputs: Dict[int, np.ndarray] = {}
        done_s: Dict[int, tuple] = {}
        for layer in range(max(n_aggs)):
            live = [i for i in range(len(group)) if layer < n_aggs[i]]
            aggregated = self._batched_aggregate(
                eng, a, [acts[i] for i in live])
            for i, x in zip(live, aggregated):
                ws = wss[i]
                if layer < len(ws):
                    h = x @ ws[layer]
                    if layer < len(ws) - 1:
                        h = jnp.maximum(h, 0.0)   # relu between layers
                else:                             # bare aggregation request
                    h = x
                acts[i] = h
                if layer == n_aggs[i] - 1:
                    outputs[i] = np.asarray(h)
                    now = time.perf_counter()
                    done_s[group[i].request_id] = (now - t0, now - g0)
        results = [InferenceResult(group[i].request_id, name, outputs[i])
                   for i in range(len(group))]
        stats = GroupStats()
        for s in eng.forward_stats_log[mark:]:
            stats.accumulate(s)
        return results, done_s, stats

    def _batched_aggregate(self, eng: AiresSpGEMM, a: CSR,
                           hs: List[jnp.ndarray]) -> List[jnp.ndarray]:
        """A @ each h, merging requests into column-concat streamed passes.

        Greedy chunking: pack requests into passes while the concatenated
        width stays within max_batch_features; a single over-wide request
        streams alone (AiresSpGEMM re-plans conservatively for it).
        """
        cap = self.config.max_batch_features
        out: List[Optional[jnp.ndarray]] = [None] * len(hs)
        chunk: List[int] = []
        width = 0
        for i, h in enumerate(hs):
            f = int(h.shape[1])
            if chunk and width + f > cap:
                self._aggregate_chunk(eng, a, hs, chunk, out)
                chunk, width = [], 0
            chunk.append(i)
            width += f
        if chunk:
            self._aggregate_chunk(eng, a, hs, chunk, out)
        return out

    @staticmethod
    def _aggregate_chunk(eng, a, hs, chunk, out) -> None:
        if len(chunk) == 1:
            out[chunk[0]] = eng(a, hs[chunk[0]])
            return
        h_cat = jnp.concatenate([hs[i] for i in chunk], axis=1)
        x_cat = eng(a, h_cat)
        col = 0
        for i in chunk:
            f = int(hs[i].shape[1])
            out[i] = x_cat[:, col:col + f]
            col += f
