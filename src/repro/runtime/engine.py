"""Out-of-core GCN serving engine: multi-graph batching over AiresSpGEMM.

The ROADMAP's serving target meets the paper's Phase III: requests against
many resident graphs are queued, grouped by graph, and served through ONE
`AiresSpGEMM` per graph — all engines sharing one tiered segment cache
(`repro.io.segment_cache`), so the expensive part of a request (streaming
BlockELL bricks host→device) amortizes across requests, layers and epochs.

Three mechanisms do the work:

  * one prepared plan per graph — every engine plans at the pinned width
    `EngineConfig.max_batch_features` (`AiresConfig.plan_features`), so all
    layer widths and all batch widths up to the pin share a single RoBW plan
    and its cached bricks. This replaces leaning on `AiresSpGEMM`'s flat
    `PREPARED_CACHE_MAX=8` LRU, which cycles when widths multiply.
  * column-concat batching — X = A·[H₁|H₂|…] computes every queued
    request's aggregation for a graph in a single streamed pass; outputs
    split per request and the cheap dense transforms run per request.
  * Phase III chaining — activations stay jax device arrays between layers
    (relu((A H) W) chains), never round-tripping through host numpy until
    the final result is handed back.

Request semantics: a request with L weight matrices computes
    h ← relu((A h) Wₗ) for l < L-1;  output = (A h) W_{L-1}
(final layer linear); L = 0 returns the bare aggregation A·H.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence

import numpy as np
import jax.numpy as jnp

from repro.core.spgemm import AiresConfig, AiresSpGEMM
from repro.io.segment_cache import (
    CacheDirectory,
    CacheStats,
    TieredSegmentCache,
)
from repro.io.shard_cache import ShardedSegmentCache
from repro.sparse.formats import CSR


@dataclasses.dataclass
class EngineConfig:
    """Knobs for the serving engine (see README "Serving engine")."""

    device_budget_bytes: int
    cache_enabled: bool = True
    # Segment-cache tiers: device defaults to the streaming budget (the
    # bricks the plan streams are exactly what is worth keeping resident),
    # host to 8× that; None host budget = unbounded spill.
    cache_device_bytes: Optional[int] = None
    cache_host_bytes: Optional[int] = None
    # Sharded device tier (io/shard_cache.py): >1 partitions the cache's
    # device budget over `cache_shards` independent LRU shards, remote hits
    # riding the ICI path. 1 (default) keeps the PR-2 single-chip cache —
    # byte-identical accounting. A mesh passed to ServingEngine overrides
    # this with the size of `cache_shard_axis`.
    cache_shards: int = 1
    cache_shard_axis: str = "cache"
    # Identity of this replicated worker in a shared CacheDirectory.
    worker_id: int = 0
    # Planning width: one plan serves all request/layer widths up to this,
    # and batches are chunked so concatenated width never exceeds it.
    max_batch_features: int = 64
    bm: int = 8
    bk: int = 8
    align: int = 8
    stream_depth: int = 2
    straggler_deadline_s: Optional[float] = None
    interpret: Optional[bool] = None


@dataclasses.dataclass
class InferenceRequest:
    """One GCN inference against a registered graph."""

    graph: str
    features: np.ndarray                  # (n_nodes, F)
    weights: Sequence[np.ndarray] = ()    # per-layer (F_in, F_out) chain
    request_id: int = -1                  # assigned by submit()


@dataclasses.dataclass
class InferenceResult:
    request_id: int
    graph: str
    output: np.ndarray


@dataclasses.dataclass
class BatchReport:
    """One run_batch() drain: results + the I/O story of the batch."""

    results: List[InferenceResult]
    uploaded_bytes: int       # wire bytes freshly streamed host->device
    cache_hit_bytes: int      # wire bytes served from the segment cache
    promoted_bytes: int       # of those, host-tier hits re-crossing the bus
    segments_streamed: int    # consume() invocations (incl. cache hits)
    aggregation_passes: int   # streamed SpGEMM passes (batching merges these)
    wall_seconds: float = 0.0
    # Sharded cache: bytes that crossed the inter-chip path this batch
    # (remote-shard hits + shard placements). 0 for a 1-shard cache.
    ici_bytes: int = 0
    # Cross-worker directory: wire bytes served from a peer worker's host
    # copy, and demotion copies this worker skipped because a peer already
    # holds the brick. 0 with no directory attached.
    directory_hit_bytes: int = 0
    duplicate_avoided_bytes: int = 0

    @property
    def bus_bytes(self) -> int:
        """Everything that actually crossed host->device this batch."""
        return self.uploaded_bytes + self.promoted_bytes

    @property
    def hit_rate(self) -> float:
        total = self.uploaded_bytes + self.cache_hit_bytes
        return self.cache_hit_bytes / total if total else 0.0


class ServingEngine:
    """Multi-graph out-of-core GCN inference with a shared segment cache.

    Usage:
        eng = ServingEngine(EngineConfig(device_budget_bytes=...))
        eng.register_graph("socLJ1", adjacency_csr)
        rid = eng.submit(InferenceRequest("socLJ1", h, weights=[w0, w1]))
        report = eng.run_batch()          # drains the queue, grouped by graph

    With `cache_enabled=False` every batch re-streams every segment — bit
    for bit the PR-1 `AiresSpGEMM` behavior (the ablation baseline).

    Scale-out: `config.cache_shards > 1` (or a `mesh` argument) partitions
    the cache's device tier across a mesh axis (`ShardedSegmentCache`), and
    a shared `CacheDirectory` lets replicated workers serve each other's
    demoted bricks instead of duplicating them — see README "Sharded
    serving". Both default off, reproducing PR-2 byte accounting exactly.
    """

    def __init__(self, config: EngineConfig,
                 directory: Optional[CacheDirectory] = None,
                 mesh=None):
        self.config = config
        self.directory = directory
        self.cache: Optional["TieredSegmentCache | ShardedSegmentCache"] = None
        if not config.cache_enabled and (directory is not None
                                         or mesh is not None):
            raise ValueError(
                "cache_enabled=False contradicts an explicit "
                f"{'directory' if directory is not None else 'mesh'}: "
                "the sharded tier and the cross-worker directory are "
                "cache features")
        if directory is not None:
            # Distinct replica identities, or the directory silently no-ops.
            directory.claim_worker(config.worker_id)
        if config.cache_enabled:
            device_bytes = (config.cache_device_bytes
                            or config.device_budget_bytes)
            if mesh is not None:
                self.cache = ShardedSegmentCache.from_mesh(
                    mesh, device_bytes, axis=config.cache_shard_axis,
                    host_budget_bytes=config.cache_host_bytes,
                    directory=directory, worker_id=config.worker_id)
            elif config.cache_shards > 1:
                self.cache = ShardedSegmentCache(
                    device_budget_bytes=device_bytes,
                    host_budget_bytes=config.cache_host_bytes,
                    n_shards=config.cache_shards,
                    directory=directory, worker_id=config.worker_id)
            else:
                self.cache = TieredSegmentCache(
                    device_budget_bytes=device_bytes,
                    host_budget_bytes=config.cache_host_bytes,
                    directory=directory, worker_id=config.worker_id)
        self._graphs: "OrderedDict[str, CSR]" = OrderedDict()
        self._engines: Dict[str, AiresSpGEMM] = {}
        self._queue: List[InferenceRequest] = []
        self._next_id = 0

    # ---- graph registry --------------------------------------------------

    def register_graph(self, name: str, a: CSR) -> None:
        """Make a graph servable. CSRs are immutable once registered (the
        cache keys on identity + structure, like AiresSpGEMM's plan cache)."""
        if name in self._graphs:
            raise ValueError(f"graph {name!r} already registered")
        a.validate()
        cfg = self.config
        self._graphs[name] = a
        self._engines[name] = AiresSpGEMM(
            AiresConfig(
                device_budget_bytes=cfg.device_budget_bytes,
                bm=cfg.bm, bk=cfg.bk, align=cfg.align,
                stream_depth=cfg.stream_depth,
                straggler_deadline_s=cfg.straggler_deadline_s,
                interpret=cfg.interpret,
                plan_features=cfg.max_batch_features,
            ),
            segment_cache=self.cache)

    def evict_graph(self, name: str) -> List[InferenceRequest]:
        """Drop a graph, its engine, its cached segments (every namespace,
        not just plans still in the prepared LRU), and any queued requests
        against it — which are returned so the caller can re-route them."""
        a = self._graphs.pop(name, None)
        self._engines.pop(name, None)
        if a is not None and self.cache is not None:
            self.cache.invalidate_prefix(AiresSpGEMM.graph_cache_prefix(a))
        orphaned = [r for r in self._queue if r.graph == name]
        self._queue = [r for r in self._queue if r.graph != name]
        return orphaned

    @property
    def graphs(self) -> List[str]:
        return list(self._graphs)

    def cache_stats(self) -> Optional[CacheStats]:
        return self.cache.stats if self.cache is not None else None

    # ---- request queue ---------------------------------------------------

    def submit(self, request: InferenceRequest) -> int:
        if request.graph not in self._graphs:
            raise KeyError(f"graph {request.graph!r} not registered")
        n = self._graphs[request.graph].n_rows
        if request.features.shape[0] != n:
            raise ValueError(
                f"features rows {request.features.shape[0]} != graph nodes {n}")
        request = dataclasses.replace(request, request_id=self._next_id)
        self._next_id += 1
        self._queue.append(request)
        return request.request_id

    def infer(self, graph: str, features: np.ndarray,
              weights: Sequence[np.ndarray] = ()) -> np.ndarray:
        """Convenience: run one request immediately, without draining (or
        disturbing) other callers' queued requests."""
        pending, self._queue = self._queue, []
        try:
            rid = self.submit(InferenceRequest(graph, features, weights))
            report = self.run_batch()
        finally:
            self._queue = pending + self._queue
        return next(r.output for r in report.results if r.request_id == rid)

    # ---- batched execution -----------------------------------------------

    def run_batch(self) -> BatchReport:
        """Drain the queue: group by graph, batch aggregations per layer."""
        queue, self._queue = self._queue, []
        results: List[InferenceResult] = []
        uploaded = hits = segments = passes = 0
        t0 = time.perf_counter()
        unknown = sorted({r.graph for r in queue} - set(self._graphs))
        if unknown:
            self._queue = queue + self._queue  # nothing consumed
            raise KeyError(
                f"queued requests reference unregistered graphs {unknown}")
        promoted = ici = dir_hits = 0
        # Duplicate-avoided demotions happen inside put()/evictions, outside
        # any stream's stats window — diff the cache's cumulative counter.
        dup0 = (self.cache.stats.duplicate_avoided_bytes
                if self.cache is not None else 0)
        for name in self._graphs:  # registration order, deterministic
            group = [r for r in queue if r.graph == name]
            if not group:
                continue
            eng = self._engines[name]
            mark = len(eng.forward_stats_log)
            results.extend(self._run_graph_group(name, group))
            for stats in eng.forward_stats_log[mark:]:
                uploaded += stats.uploaded_bytes
                hits += stats.cache_hit_bytes
                promoted += stats.promoted_bytes
                ici += stats.ici_bytes
                dir_hits += stats.directory_hit_bytes
                segments += stats.segments
                passes += 1
        results.sort(key=lambda r: r.request_id)
        dup = ((self.cache.stats.duplicate_avoided_bytes - dup0)
               if self.cache is not None else 0)
        return BatchReport(
            results=results, uploaded_bytes=uploaded, cache_hit_bytes=hits,
            promoted_bytes=promoted, segments_streamed=segments,
            aggregation_passes=passes,
            wall_seconds=time.perf_counter() - t0,
            ici_bytes=ici, directory_hit_bytes=dir_hits,
            duplicate_avoided_bytes=dup)

    def _run_graph_group(self, name: str,
                         group: List[InferenceRequest]) -> List[InferenceResult]:
        a = self._graphs[name]
        eng = self._engines[name]
        # Per-request device-resident state: (request, activation, next layer).
        acts = [jnp.asarray(np.asarray(r.features, dtype=np.float32))
                for r in group]
        wss = [[jnp.asarray(np.asarray(w, dtype=np.float32)) for w in r.weights]
               for r in group]
        n_aggs = [max(len(ws), 1) for ws in wss]
        outputs: Dict[int, np.ndarray] = {}
        for layer in range(max(n_aggs)):
            live = [i for i in range(len(group)) if layer < n_aggs[i]]
            aggregated = self._batched_aggregate(
                eng, a, [acts[i] for i in live])
            for i, x in zip(live, aggregated):
                ws = wss[i]
                if layer < len(ws):
                    h = x @ ws[layer]
                    if layer < len(ws) - 1:
                        h = jnp.maximum(h, 0.0)   # relu between layers
                else:                             # bare aggregation request
                    h = x
                acts[i] = h
                if layer == n_aggs[i] - 1:
                    outputs[i] = np.asarray(h)
        return [InferenceResult(group[i].request_id, name, outputs[i])
                for i in range(len(group))]

    def _batched_aggregate(self, eng: AiresSpGEMM, a: CSR,
                           hs: List[jnp.ndarray]) -> List[jnp.ndarray]:
        """A @ each h, merging requests into column-concat streamed passes.

        Greedy chunking: pack requests into passes while the concatenated
        width stays within max_batch_features; a single over-wide request
        streams alone (AiresSpGEMM re-plans conservatively for it).
        """
        cap = self.config.max_batch_features
        out: List[Optional[jnp.ndarray]] = [None] * len(hs)
        chunk: List[int] = []
        width = 0
        for i, h in enumerate(hs):
            f = int(h.shape[1])
            if chunk and width + f > cap:
                self._aggregate_chunk(eng, a, hs, chunk, out)
                chunk, width = [], 0
            chunk.append(i)
            width += f
        if chunk:
            self._aggregate_chunk(eng, a, hs, chunk, out)
        return out

    @staticmethod
    def _aggregate_chunk(eng, a, hs, chunk, out) -> None:
        if len(chunk) == 1:
            out[chunk[0]] = eng(a, hs[chunk[0]])
            return
        h_cat = jnp.concatenate([hs[i] for i in chunk], axis=1)
        x_cat = eng(a, h_cat)
        col = 0
        for i in chunk:
            f = int(hs[i].shape[1])
            out[i] = x_cat[:, col:col + f]
            col += f
