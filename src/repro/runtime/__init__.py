from repro.runtime.supervisor import (
    Supervisor, SupervisorConfig, ElasticMesh, RunState,
)
from repro.runtime.engine import (
    AdmissionError, BatchReport, EngineConfig, GraphUpdateReport, GroupStats,
    InferenceRequest, InferenceResult, RejectedRequest, RequestLatency,
    ServingEngine, SubmitReceipt, WarmStartReport,
)
from repro.runtime.serving_loop import (
    Arrival, ContinuousServer, ServeEvent, ServeReport, StepReport,
    VirtualClock, bursty_trace, poisson_trace, replay_continuous,
    replay_round, summarize,
)

__all__ = [
    "Supervisor", "SupervisorConfig", "ElasticMesh", "RunState",
    "AdmissionError", "BatchReport", "EngineConfig", "GraphUpdateReport",
    "GroupStats", "InferenceRequest", "InferenceResult", "RejectedRequest",
    "RequestLatency", "ServingEngine", "SubmitReceipt", "WarmStartReport",
    "Arrival", "ContinuousServer", "ServeEvent", "ServeReport", "StepReport",
    "VirtualClock", "bursty_trace", "poisson_trace", "replay_continuous",
    "replay_round", "summarize",
]
