from repro.runtime.supervisor import (
    Supervisor, SupervisorConfig, ElasticMesh, RunState,
)
from repro.runtime.engine import (
    BatchReport, EngineConfig, InferenceRequest, InferenceResult,
    ServingEngine,
)

__all__ = [
    "Supervisor", "SupervisorConfig", "ElasticMesh", "RunState",
    "BatchReport", "EngineConfig", "InferenceRequest", "InferenceResult",
    "ServingEngine",
]
