from repro.runtime.supervisor import (
    Supervisor, SupervisorConfig, ElasticMesh, RunState,
)
from repro.runtime.engine import (
    AdmissionError, BatchReport, EngineConfig, InferenceRequest,
    InferenceResult, RejectedRequest, RequestLatency, ServingEngine,
    SubmitReceipt, WarmStartReport,
)

__all__ = [
    "Supervisor", "SupervisorConfig", "ElasticMesh", "RunState",
    "AdmissionError", "BatchReport", "EngineConfig", "InferenceRequest",
    "InferenceResult", "RejectedRequest", "RequestLatency", "ServingEngine",
    "SubmitReceipt", "WarmStartReport",
]
