from repro.runtime.supervisor import (
    Supervisor, SupervisorConfig, ElasticMesh, RunState,
)

__all__ = ["Supervisor", "SupervisorConfig", "ElasticMesh", "RunState"]
