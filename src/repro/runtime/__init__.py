from repro.runtime.supervisor import (
    Supervisor, SupervisorConfig, ElasticMesh, RunState,
)
from repro.runtime.engine import (
    AdmissionError, BatchReport, EngineConfig, InferenceRequest,
    InferenceResult, RejectedRequest, ServingEngine, WarmStartReport,
)

__all__ = [
    "Supervisor", "SupervisorConfig", "ElasticMesh", "RunState",
    "AdmissionError", "BatchReport", "EngineConfig", "InferenceRequest",
    "InferenceResult", "RejectedRequest", "ServingEngine", "WarmStartReport",
]
