"""Fault-tolerant run supervisor: restart, straggler policy, elastic mesh.

What a 1000-node deployment needs from the driver process:
  * crash recovery — `run()` wraps the step loop; on a recoverable failure
    it restores the newest checkpoint and resumes (bounded retries with
    exponential backoff). The seekable data pipeline guarantees batch k is
    identical after restart.
  * straggler mitigation — the streaming layers (io.DoubleBufferedStreamer)
    re-issue transfers past a deadline; at the step level, the supervisor
    tracks a rolling step-time EWMA and flags steps > `straggler_factor`×
    EWMA, feeding the deadline back to the streamer.
  * elastic scaling — `ElasticMesh.resize(n_devices)` recomputes the mesh
    shape from the available device count; checkpoints are mesh-agnostic
    (repro.checkpoint), so params re-shard on restore. Batch ramping keeps
    global batch divisible by the new data-parallel degree.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, List, Optional, Tuple

import jax


@dataclasses.dataclass
class SupervisorConfig:
    max_restarts: int = 3
    backoff_s: float = 0.1
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2


@dataclasses.dataclass
class RunState:
    step: int = 0
    restarts: int = 0
    straggler_events: int = 0
    step_time_ewma: float = 0.0


class ElasticMesh:
    """Mesh factory that adapts to the live device count."""

    def __init__(self, model_parallel: int = 1, axis_names=("data", "model")):
        self.model_parallel = model_parallel
        self.axis_names = axis_names

    def shape_for(self, n_devices: int) -> Tuple[int, int]:
        mp = math.gcd(self.model_parallel, n_devices)
        return (n_devices // mp, mp)

    def make(self, devices: Optional[List] = None):
        devices = devices if devices is not None else jax.devices()
        shape = self.shape_for(len(devices))
        return jax.make_mesh(shape, self.axis_names, devices=devices)

    def local_batch(self, global_batch: int, n_devices: int) -> int:
        dp = self.shape_for(n_devices)[0]
        # Ramp global batch down to the nearest multiple if a node was lost.
        return max(1, global_batch // dp)


class Supervisor:
    def __init__(self, config: SupervisorConfig,
                 checkpointer=None,
                 recoverable: Tuple[type, ...] = (RuntimeError,)):
        self.config = config
        self.checkpointer = checkpointer
        self.recoverable = recoverable
        self.state = RunState()

    def observe_step(self, seconds: float) -> bool:
        """Track step time; returns True if this step was a straggler."""
        st = self.state
        if st.step_time_ewma == 0.0:
            st.step_time_ewma = seconds
            return False
        is_straggler = seconds > self.config.straggler_factor * st.step_time_ewma
        if is_straggler:
            st.straggler_events += 1
        # Clamp stragglers out of the EWMA so one hiccup doesn't raise the bar.
        st.step_time_ewma = (
            (1 - self.config.ewma_alpha) * st.step_time_ewma
            + self.config.ewma_alpha * min(
                seconds, self.config.straggler_factor * st.step_time_ewma))
        return is_straggler

    def stream_deadline(self) -> Optional[float]:
        """Deadline handed to DoubleBufferedStreamer for re-issue."""
        if self.state.step_time_ewma == 0.0:
            return None
        return self.config.straggler_factor * self.state.step_time_ewma

    def run(self, body: Callable[[int], int],
            restore: Optional[Callable[[], int]] = None) -> RunState:
        """body(start_step) -> last_step; restore() -> start_step.

        Restarts `body` on recoverable failures, restoring from the newest
        checkpoint each time.
        """
        start = self.state.step
        while True:
            try:
                self.state.step = body(start)
                return self.state
            except self.recoverable as err:  # noqa: PERF203
                self.state.restarts += 1
                if self.state.restarts > self.config.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.config.max_restarts}"
                    ) from err
                time.sleep(self.config.backoff_s * 2 ** (self.state.restarts - 1))
                start = restore() if restore is not None else start
