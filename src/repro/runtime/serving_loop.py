"""Continuous-batching serving loop over the ServingEngine.

`ServingEngine.run_batch` serves traffic in synchronous rounds: drain the
queue, stream every group, hand back one report. Production traffic (the
paper's recommendation/PPI workloads) arrives continuously — a request
that lands just after a drain starts waits for the *entire* round even if
its deadline is tighter than everything in it. The fix, per the batched
SpGEMM argument of arXiv:1903.11409 (and GE-SpMM's kernel-side case for
wide batched passes), is to let new requests join the column-concat
groups still *forming* while the previous group streams:

  * :class:`ContinuousServer` — a step-driven loop over an existing
    `ServingEngine`: ``submit()`` at any virtual time, ``step()`` streams
    exactly **one** group and advances the clock by its modeled cost.
    Between steps, fresh submissions join the next forming group
    (`form_groups`), so a burst never waits behind a full drain.
  * **Backpressure** rides the engine's own admission control: the loop
    shares the engine's clock, so `EngineConfig.max_queue_cost_s` prices
    each submit against the *remaining* queue (served groups leave it
    step by step), not a round snapshot.
  * **Queue-position EDF**: groups are ordered by
    `EDFOrderingPass.order_groups` — Moore–Hodgson over per-group
    `ServingEngine.estimate_group_cost` rollups, so a group's deadline is
    checked against its time-to-front (the modeled cost of every group
    ahead), not just its within-round rank.
  * :class:`VirtualClock` + the trace generators (`poisson_trace`,
    `bursty_trace`) + the replay drivers (`replay_round`,
    `replay_continuous`) make whole serving timelines deterministic:
    `benchmarks/bench_serve.py` replays identical arrival traces through
    both the round engine and this loop and persists the comparison as
    ``BENCH_serve.json``.

Byte accounting is the engine's own: every group runs through
`ServingEngine.serve_group`, the same group-run piece `run_batch` uses,
so uploaded/cache-hit/ICI bytes stay comparable across serving modes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.passes import EDFOrderingPass, edf_sort, remaining_deadline
from repro.runtime.engine import (
    AdmissionError,
    GroupStats,
    InferenceRequest,
    InferenceResult,
    RejectedRequest,
    RequestLatency,
    ServingEngine,
)

__all__ = [
    "Arrival", "ContinuousServer", "ServeEvent", "ServeReport", "StepReport",
    "VirtualClock", "bursty_trace", "poisson_trace", "replay_continuous",
    "replay_round", "summarize",
]


class VirtualClock:
    """Deterministic monotonic clock for trace replay: a callable drop-in
    for `time.monotonic` (the engine's `EngineConfig.clock` hook) whose
    time only moves when a driver advances it — by arrival stamps and by
    modeled group costs, never by wall time."""

    def __init__(self, start_s: float = 0.0):
        self.now_s = float(start_s)

    def __call__(self) -> float:
        return self.now_s

    def advance_to(self, t_s: float) -> float:
        if t_s < self.now_s:
            raise ValueError(
                f"virtual clock cannot run backwards: {t_s} < {self.now_s}")
        self.now_s = float(t_s)
        return self.now_s

    def advance(self, dt_s: float) -> float:
        if dt_s < 0:
            raise ValueError(f"negative advance {dt_s}")
        return self.advance_to(self.now_s + dt_s)


@dataclasses.dataclass
class ServeEvent:
    """One served request on the virtual timeline (all stamps in virtual
    seconds; `finished_s - started_s` is the modeled cost of the group the
    request rode — column-concat members finish together)."""

    request_id: int
    graph: str
    submitted_s: float
    started_s: float
    finished_s: float
    predicted_s: float
    deadline_s: Optional[float] = None

    @property
    def latency_s(self) -> float:
        return self.finished_s - self.submitted_s

    @property
    def on_time(self) -> bool:
        return self.deadline_s is None or self.latency_s <= self.deadline_s


@dataclasses.dataclass
class StepReport:
    """What one `ContinuousServer.step()` served: exactly one group."""

    graph: str
    started_s: float
    finished_s: float
    cost_s: float
    events: List[ServeEvent]
    results: List[InferenceResult]
    stats: GroupStats
    expired: List[RejectedRequest]


@dataclasses.dataclass
class ServeReport:
    """Cumulative story of a serving timeline (either mode)."""

    events: List[ServeEvent]
    expired: List[RejectedRequest]
    rejected: List[RejectedRequest]
    stats: GroupStats
    groups_served: int
    makespan_s: float

    @property
    def served(self) -> int:
        return len(self.events)

    @property
    def on_time(self) -> int:
        return sum(1 for e in self.events if e.on_time)

    @property
    def deadline_misses(self) -> int:
        """Requests that produced no timely answer: served late, expired
        on the queue, or refused admission."""
        return (self.served - self.on_time
                + len(self.expired) + len(self.rejected))

    @property
    def offered(self) -> int:
        return self.served + len(self.expired) + len(self.rejected)

    @property
    def goodput_rps(self) -> float:
        return self.on_time / self.makespan_s if self.makespan_s > 0 else 0.0


class ContinuousServer:
    """Step-driven continuous batching over an existing `ServingEngine`.

    Usage:
        clock = VirtualClock()
        eng = ServingEngine(EngineConfig(..., clock=clock))
        server = ContinuousServer(eng)
        server.submit(request, at=0.3)       # any virtual time
        step = server.step()                 # streams exactly one group
        report = server.report()             # cumulative ServeReport

    The loop owns no scheduling machinery of its own: admission (deadline
    feasibility + `max_queue_cost_s` against the remaining queue) is the
    engine's `submit`, group formation mirrors `_batched_aggregate`'s
    greedy width packing, execution is `serve_group` — the group-run piece
    `run_batch` itself uses — and ordering is `EDFOrderingPass` at group
    granularity. With `edf=False` groups run in formation (FIFO) order.
    """

    def __init__(self, engine: ServingEngine,
                 clock: Optional[VirtualClock] = None, edf: bool = True):
        if clock is None:
            clock = (engine.clock if isinstance(engine.clock, VirtualClock)
                     else VirtualClock())
        if engine.clock is not clock:
            if engine._queue or engine._rejected:
                raise ValueError(
                    "attach the continuous loop before queueing work: the "
                    "engine holds requests/verdicts stamped on a different "
                    "clock")
            engine.clock = clock
        self.engine = engine
        self.clock = clock
        # Group ordering shares the replay clock; the engine's own
        # configured EDF pass (if any) may sit on wall time, so the loop
        # carries its own instance.
        self._edf = EDFOrderingPass(clock=clock) if edf else None
        self._events: List[ServeEvent] = []
        self._expired: List[RejectedRequest] = []
        self._rejected: List[RejectedRequest] = []
        self._stats = GroupStats()
        self._groups_served = 0
        self._t_start = clock()

    # ---- admission (the engine's, on the shared clock) -------------------

    @property
    def pending(self) -> int:
        return len(self.engine._queue)

    def submit(self, request: InferenceRequest,
               at: Optional[float] = None):
        """Admit a request at virtual time `at` (default: now). Raises the
        engine's `AdmissionError` on rejection; the verdict is folded into
        this loop's `ServeReport.rejected` rather than a BatchReport."""
        if at is not None:
            self.clock.advance_to(at)
        try:
            return self.engine.submit(request)
        except AdmissionError:
            self._drain_verdicts()
            raise

    def _drain_verdicts(self) -> None:
        """Admission verdicts normally surface in the next BatchReport;
        the continuous loop never runs one, so collect them here."""
        if self.engine._rejected:
            self._rejected.extend(self.engine._rejected)
            self.engine._rejected.clear()

    # ---- evolving graphs -------------------------------------------------

    def update_graph(self, name: str, inserts=None, deletes=None):
        """Apply an edge delta between steps WITHOUT draining the queue.

        Delegates to `ServingEngine.update_graph`: prepared plans migrate
        incrementally and only the touched segments' cache keys are
        invalidated. Queued and mid-forming requests keep working — the
        node count is unchanged and groups resolve the graph by name at
        `serve_group` time, so requests admitted before the delta are
        served against the updated graph from the next step on. Returns
        the engine's `GraphUpdateReport`."""
        return self.engine.update_graph(name, inserts=inserts,
                                        deletes=deletes)

    # ---- group formation -------------------------------------------------

    def form_groups(self, queue: List[InferenceRequest], now: float
                    ) -> List[Tuple[str, List[InferenceRequest]]]:
        """Column-concat group formation over the pending queue: per
        graph, requests in EDF (remaining-deadline) order pack greedily
        into groups whose layer-0 concatenated width stays within
        `max_batch_features` — the unit `step()` serves. Requests admitted
        between steps land here, joining the next forming group instead
        of waiting for a full drain."""
        cap = self.engine.config.max_batch_features
        by_graph: Dict[str, List[InferenceRequest]] = {}
        for r in queue:
            by_graph.setdefault(r.graph, []).append(r)
        groups: List[Tuple[str, List[InferenceRequest]]] = []
        for name, rs in by_graph.items():
            if self._edf is not None:
                rs = edf_sort(rs, lambda r: remaining_deadline(r, now))
            chunk: List[InferenceRequest] = []
            width = 0
            for r in rs:
                f = int(r.features.shape[1])
                if chunk and width + f > cap:
                    groups.append((name, chunk))
                    chunk, width = [], 0
                chunk.append(r)
                width += f
            if chunk:
                groups.append((name, chunk))
        return groups

    def _group_cost(self, group: Tuple[str, List[InferenceRequest]]) -> float:
        name, members = group
        return self.engine.estimate_group_cost(name, members)

    # ---- the step --------------------------------------------------------

    def step(self) -> Optional[StepReport]:
        """Serve exactly one group: stamp/expire/price the pending queue
        (`prepare_queue`), form groups, pick the queue-position-EDF winner,
        stream it for real (`serve_group`), and advance the virtual clock
        by the group's modeled cost. Returns None when nothing is
        servable (idle)."""
        now = self.clock()
        self._drain_verdicts()
        queue = self.engine._queue
        unknown = sorted({r.graph for r in queue} - set(self.engine._graphs))
        if unknown:
            raise KeyError(
                f"queued requests reference unregistered graphs {unknown}")
        queue, expired = self.engine.prepare_queue(queue, now)
        self._expired.extend(expired)
        groups = self.form_groups(queue, now)
        if not groups:
            self.engine._queue = queue
            return None if not expired else StepReport(
                graph="", started_s=now, finished_s=now, cost_s=0.0,
                events=[], results=[], stats=GroupStats(), expired=expired)
        if self._edf is not None:
            groups = self._edf.order_groups(groups, self._group_cost)
        name, members = groups[0]
        taken = {id(r) for r in members}
        self.engine._queue = [r for r in queue if id(r) not in taken]
        cost = self._group_cost((name, members))
        results, done_s, stats = self.engine.serve_group(
            name, members, time.perf_counter())
        if self.engine.config.calibrator is not None:
            # Continuous mode never runs run_batch, so the per-group
            # latency stream must be fed to the calibrator here.
            self.engine.feed_latencies([
                RequestLatency(r.request_id, name, r.estimated_cost_s,
                               *done_s[r.request_id])
                for r in members])
        finished = self.clock.advance_to(now + cost)
        events = [
            ServeEvent(request_id=r.request_id, graph=name,
                       submitted_s=r.submitted_s, started_s=now,
                       finished_s=finished, predicted_s=r.estimated_cost_s,
                       deadline_s=r.deadline_s)
            for r in members
        ]
        self._events.extend(events)
        self._stats.merge(stats)
        self._groups_served += 1
        return StepReport(graph=name, started_s=now, finished_s=finished,
                          cost_s=cost, events=events, results=results,
                          stats=stats, expired=expired)

    def drain(self) -> List[StepReport]:
        """Serve until idle (no admissions in between — a synchronous
        drain, step-reported)."""
        steps = []
        while True:
            step = self.step()
            if step is None:
                return steps
            steps.append(step)

    def report(self) -> ServeReport:
        self._drain_verdicts()
        return ServeReport(
            events=list(self._events), expired=list(self._expired),
            rejected=list(self._rejected),
            stats=dataclasses.replace(self._stats),
            groups_served=self._groups_served,
            makespan_s=self.clock() - self._t_start)


# ---- arrival traces --------------------------------------------------------


@dataclasses.dataclass
class Arrival:
    """One trace entry: a request template arriving at virtual `t_s`."""

    t_s: float
    graph: str
    feature_dim: int = 16
    n_layers: int = 1
    deadline_s: Optional[float] = None


def _pick_dim(rng, feature_dim) -> int:
    """`feature_dim` may be one width or a sequence to sample uniformly —
    heterogeneous widths keep column-concat groups from absorbing a whole
    burst into one pass (the realistic serving mix)."""
    if isinstance(feature_dim, (list, tuple)):
        return int(feature_dim[int(rng.integers(len(feature_dim)))])
    return int(feature_dim)


def poisson_trace(n: int, rate_hz: float, graphs: Sequence[str],
                  seed: int = 0, feature_dim=16, n_layers: int = 1,
                  deadline_s: Optional[float] = None) -> List[Arrival]:
    """Homogeneous Poisson arrivals: i.i.d. exponential inter-arrival
    times at `rate_hz`, graphs drawn uniformly."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for _ in range(n):
        t += float(rng.exponential(1.0 / rate_hz))
        out.append(Arrival(t, graphs[int(rng.integers(len(graphs)))],
                           _pick_dim(rng, feature_dim), n_layers, deadline_s))
    return out


def bursty_trace(n: int, base_rate_hz: float, graphs: Sequence[str],
                 seed: int = 0, feature_dim=16, n_layers: int = 1,
                 deadline_s: Optional[float] = None,
                 burst_shape: float = 0.35, episode: int = 8) -> List[Arrival]:
    """Gamma-modulated (doubly-stochastic) Poisson arrivals: every
    `episode` arrivals the rate is re-drawn as ``base_rate_hz · m`` with
    ``m ~ Gamma(shape=burst_shape, scale=1/burst_shape)`` (mean 1). Small
    shapes give heavy on/off burstiness — tight request clumps separated
    by long lulls — the regime where round-based serving tails out."""
    rng = np.random.default_rng(seed)
    t = 0.0
    mult = 1.0
    out = []
    for i in range(n):
        if i % episode == 0:
            mult = max(float(rng.gamma(burst_shape, 1.0 / burst_shape)), 1e-3)
        t += float(rng.exponential(1.0 / (base_rate_hz * mult)))
        out.append(Arrival(t, graphs[int(rng.integers(len(graphs)))],
                           _pick_dim(rng, feature_dim), n_layers, deadline_s))
    return out


# ---- trace replay: round-based vs continuous -------------------------------


def replay_continuous(server: ContinuousServer, trace: Sequence[Arrival],
                      make_request: Callable[[Arrival], InferenceRequest]
                      ) -> ServeReport:
    """Replay an arrival trace through the continuous loop: arrivals due
    by the current virtual time are admitted (rejections counted, not
    raised), then one group streams; arrivals landing during that group
    join the next formation. Idle time jumps straight to the next
    arrival."""
    trace = sorted(trace, key=lambda a: a.t_s)
    i, n = 0, len(trace)
    while True:
        while i < n and trace[i].t_s <= server.clock():
            try:
                server.submit(make_request(trace[i]))
            except AdmissionError:
                pass  # verdict already folded into the report
            i += 1
        if server.step() is None:
            if i >= n:
                return server.report()
            server.clock.advance_to(trace[i].t_s)


def replay_round(engine: ServingEngine, trace: Sequence[Arrival],
                 make_request: Callable[[Arrival], InferenceRequest]
                 ) -> ServeReport:
    """Replay the same trace through the round-based `run_batch` path:
    arrivals admitted only between drains, every drain serving its whole
    queue. The virtual timeline of each round is reconstructed from the
    engine's own group-form pieces (`prepare_queue` + `order_queue` +
    `estimate_group_cost`) *before* the drain, so per-request completion
    stamps use exactly the costs the continuous arm is priced with —
    requests complete when their graph group does, and arrivals during
    the round wait for the entire drain."""
    clock = engine.clock
    if not isinstance(clock, VirtualClock):
        raise ValueError("replay_round needs an engine built with "
                         "EngineConfig(clock=VirtualClock())")
    trace = sorted(trace, key=lambda a: a.t_s)
    events: List[ServeEvent] = []
    expired: List[RejectedRequest] = []
    rejected: List[RejectedRequest] = []
    stats = GroupStats()
    groups_served = 0
    t_start = clock()
    i, n = 0, len(trace)
    while True:
        while i < n and trace[i].t_s <= clock():
            try:
                engine.submit(make_request(trace[i]))
            except AdmissionError:
                pass  # surfaces via the next BatchReport.rejected
            i += 1
        if not engine._queue:
            if i >= n:
                break
            clock.advance_to(trace[i].t_s)
            continue
        round_start = clock()
        # Peek the round's virtual timeline with the same deterministic
        # pieces run_batch composes (prepare_queue is pure; estimates are
        # memoized; the EDF pass reads the shared frozen clock), so the
        # spans below name exactly the groups the drain will serve.
        ready, _ = engine.prepare_queue(list(engine._queue), round_start)
        ordered, graph_order = engine.order_queue(ready)
        t = round_start
        spans: Dict[int, tuple] = {}
        for gname in graph_order:
            group = [r for r in ordered if r.graph == gname]
            if not group:
                continue
            cost = engine.estimate_group_cost(gname, group)
            for r in group:
                spans[r.request_id] = (t, t + cost, r)
            t += cost
            groups_served += 1
        report = engine.run_batch()
        for res in report.results:
            start, fin, r = spans[res.request_id]
            events.append(ServeEvent(
                request_id=res.request_id, graph=res.graph,
                submitted_s=r.submitted_s, started_s=start, finished_s=fin,
                predicted_s=r.estimated_cost_s, deadline_s=r.deadline_s))
        expired.extend(report.expired)
        rejected.extend(report.rejected)
        stats.merge(GroupStats(
            uploaded_bytes=report.uploaded_bytes,
            cache_hit_bytes=report.cache_hit_bytes,
            promoted_bytes=report.promoted_bytes,
            ici_bytes=report.ici_bytes,
            directory_hit_bytes=report.directory_hit_bytes,
            segments_streamed=report.segments_streamed,
            aggregation_passes=report.aggregation_passes))
        clock.advance_to(t)
    if engine._rejected:  # verdicts whose round never came
        rejected.extend(engine._rejected)
        engine._rejected.clear()
    return ServeReport(events=events, expired=expired, rejected=rejected,
                       stats=stats, groups_served=groups_served,
                       makespan_s=clock() - t_start)


def summarize(report: ServeReport) -> dict:
    """One serving arm → the flat stats dict `BENCH_serve.json` persists."""
    lat = sorted(e.latency_s for e in report.events)

    def pct(p):
        return float(np.percentile(lat, p)) if lat else None

    return {
        "offered": report.offered,
        "served": report.served,
        "on_time": report.on_time,
        "expired": len(report.expired),
        "rejected": len(report.rejected),
        "deadline_misses": report.deadline_misses,
        "deadline_miss_rate": (report.deadline_misses / report.offered
                               if report.offered else 0.0),
        "p50_latency_s": pct(50),
        "p99_latency_s": pct(99),
        "mean_latency_s": float(np.mean(lat)) if lat else None,
        "goodput_rps": report.goodput_rps,
        "makespan_s": report.makespan_s,
        "groups_served": report.groups_served,
        "uploaded_bytes": report.stats.uploaded_bytes,
        "cache_hit_bytes": report.stats.cache_hit_bytes,
        "promoted_bytes": report.stats.promoted_bytes,
        "ici_bytes": report.stats.ici_bytes,
        "aggregation_passes": report.stats.aggregation_passes,
    }
