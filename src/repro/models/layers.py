"""Transformer building blocks: norms, RoPE/M-RoPE, GQA attention, MLP, MoE.

Conventions:
  * params are dicts of jnp arrays; weights stored (in_dim, out_dim).
  * activations (B, S, D); attention internals (B, H, S, hd).
  * every function takes `cfg` first and is jit-friendly (no python state).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig


def matmul(a: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Matmul whose accumulator dtype follows the activation dtype.

    XLA upcasts bf16 dot accumulators to f32; under SPMD the cross-shard
    partial-sum all-reduce then moves f32 bytes — 2x the wire traffic of the
    Megatron-style bf16 reduction. Pinning preferred_element_type to the
    activation dtype keeps TP boundary collectives in bf16 (§Perf iter 3).
    """
    return jnp.dot(a, w, preferred_element_type=a.dtype)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * (1.0 + scale)).astype(x.dtype)


def _rope_freqs(hd: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x (B, H, S, hd), positions (B, S) int32 — standard rotary embedding."""
    hd = x.shape[-1]
    freqs = _rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[:, None, :, None].astype(jnp.float32) * freqs  # (B,1,S,hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
                sections: Tuple[int, int, int]) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.

    positions (3, B, S): temporal/height/width position ids. The hd/2
    frequency slots are split into `sections` (t, h, w); each section
    rotates by its own position stream. For text tokens the three ids are
    equal, reducing exactly to standard RoPE.
    """
    hd = x.shape[-1]
    freqs = _rope_freqs(hd, theta)                       # (hd/2,)
    sec = jnp.concatenate([
        jnp.full((sections[0],), 0, jnp.int32),
        jnp.full((sections[1],), 1, jnp.int32),
        jnp.full((sections[2],), 2, jnp.int32),
    ])
    assert sec.shape[0] == hd // 2, (sections, hd)
    # Select the position stream per frequency slot.
    pos = positions.astype(jnp.float32)                  # (3, B, S)
    pos_per_slot = pos[sec, :, :]                        # (hd/2, B, S)
    ang = jnp.transpose(pos_per_slot, (1, 2, 0))[:, None, :, :] * freqs
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def _softcap(logits: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)


# Above this query length, self-attention runs query-chunked (flash-style
# O(S·chunk) score memory instead of O(S²)). On real TPUs the Pallas kernel
# replaces this; the lax.map form keeps HLO small and per-device VMEM-safe
# for the dry-run at 32k/500k contexts.
ATTN_CHUNK_THRESHOLD = 2048
ATTN_CHUNK = 1024


def _attn_core(q, k, v, mask, softcap):
    """q (b,h,s,hd), k/v (b,h,t,hd), mask (b,s,t) → (b,h,s,hd).

    Softmax runs in f32 (stability); probs drop to the activation dtype for
    the PV matmul — halves the largest HBM operand (§Perf iteration 5; the
    Pallas flash kernel subsumes this on real TPUs).
    """
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / (q.shape[-1] ** 0.5)
    logits = _softcap(logits, softcap)
    logits = jnp.where(mask[:, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhst,bhtd->bhsd", probs, v,
                      preferred_element_type=v.dtype)


def attention(
    cfg: ArchConfig,
    p: Dict[str, jnp.ndarray],
    x: jnp.ndarray,                      # (B, S, D)
    positions: jnp.ndarray,              # (B, S) or (3, B, S) for M-RoPE
    *,
    sliding_window: Optional[int] = None,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    cross_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    cross_mask: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """GQA attention with optional sliding window, softcap, KV cache, or
    cross-attention (cross_kv = encoder K/V already projected). KV heads are
    repeated to hq so head sharding propagates cleanly (kv-head counts below
    the model-parallel degree would otherwise force GSPMD re-layouts)."""
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    group = hq // hkv

    q = matmul(x, p["wq"]).reshape(b, s, hq, hd).transpose(0, 2, 1, 3)
    if cross_kv is None:
        k = matmul(x, p["wk"]).reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
        v = matmul(x, p["wv"]).reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
        if cfg.mrope_sections is not None:
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            pos2d = positions if positions.ndim == 2 else positions[0]
            q = apply_rope(q, pos2d, cfg.rope_theta)
            k = apply_rope(k, pos2d, cfg.rope_theta)
    else:
        k, v = cross_kv

    new_cache = None
    if cache is not None and cross_kv is None:
        idx = cache["len"]
        ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, idx, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, idx, 0))
        k, v = ck, cv
        new_cache = {"k": ck, "v": cv, "len": idx + s}

    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    s_kv = k.shape[2]

    q_pos = positions if positions.ndim == 2 else positions[0]   # (B, S)
    if cache is not None and cross_kv is None:
        kv_pos = jnp.broadcast_to(jnp.arange(s_kv)[None, :], (b, s_kv))
    elif cross_kv is not None:
        kv_pos = None
    else:
        kv_pos = q_pos

    def make_mask(qp):                                           # qp (B, cs)
        if cross_kv is not None:
            m = (cross_mask[:, None, :] if cross_mask is not None
                 else jnp.ones((b, 1, s_kv), bool))
            return jnp.broadcast_to(m, (b, qp.shape[1], s_kv))
        m = kv_pos[:, None, :] <= qp[:, :, None]
        if cache is not None:
            m = m & (kv_pos[:, None, :] < cache["len"] + s)
        if sliding_window is not None:
            m = m & (kv_pos[:, None, :] > qp[:, :, None] - sliding_window)
        return m

    if s <= ATTN_CHUNK_THRESHOLD or s % ATTN_CHUNK != 0:
        out = _attn_core(q, k, v, make_mask(q_pos), cfg.attn_softcap)
    else:
        n_chunks = s // ATTN_CHUNK
        q_c = q.reshape(b, hq, n_chunks, ATTN_CHUNK, hd).transpose(2, 0, 1, 3, 4)
        pos_c = q_pos.reshape(b, n_chunks, ATTN_CHUNK).transpose(1, 0, 2)

        def chunk_fn(args):
            qc, pc = args
            return _attn_core(qc, k, v, make_mask(pc), cfg.attn_softcap)

        out_c = jax.lax.map(chunk_fn, (q_c, pos_c))              # (n,b,h,cs,hd)
        out = out_c.transpose(1, 2, 0, 3, 4).reshape(b, hq, s, hd)

    out = out.transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    return (matmul(out.astype(x.dtype), p["wo"]), new_cache)


def mlp(p: Dict[str, jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    """SwiGLU feed-forward (bf16-wire TP boundaries via matmul())."""
    return matmul(jax.nn.silu(matmul(x, p["w_gate"])) * matmul(x, p["w_up"]),
                  p["w_down"])


def moe_ffn(cfg: ArchConfig, p: Dict[str, jnp.ndarray], x: jnp.ndarray,
            mesh_axes=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k MoE with sort-based dropless-ish dispatch (capacity-bounded).

    Returns (output, aux_loss). Dispatch avoids the (T, E, C) one-hot tensor:
    position-in-expert is computed with a histogram + rank trick, then
    tokens scatter into (E, C, d) buckets, experts run as one batched
    einsum, and results scatter back. Tokens over capacity are dropped
    (standard capacity-factor semantics; cf=1.25 default).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xf = x.reshape(t, d)

    gate_logits = xf @ p["w_router"]                       # (T, E)
    probs = jax.nn.softmax(gate_logits.astype(jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                 # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # Load-balancing auxiliary loss (Switch-style).
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=1), axis=0)
    aux = e * jnp.sum(me * ce) / k

    # Flatten (token, slot) assignments.
    flat_e = top_e.reshape(-1)                             # (T·k,)
    flat_w = top_p.reshape(-1)
    tok_id = jnp.repeat(jnp.arange(t), k)

    cap = max(1, int(cfg.capacity_factor * t * k / e))
    # Round capacity to a shardable multiple so (E, C, d) dispatch buffers
    # tile over the data axes (32-way on the production mesh).
    cap = ((cap + 63) // 64) * 64
    # Rank of each assignment within its expert, via sorted order.
    order = jnp.argsort(flat_e, stable=True)
    hist = jnp.bincount(flat_e, length=e)
    starts = jnp.cumsum(hist) - hist
    ranks_sorted = jnp.arange(t * k) - starts[flat_e[order]]
    pos = jnp.zeros_like(ranks_sorted).at[order].set(ranks_sorted)
    keep = pos < cap

    # Dropped assignments scatter out-of-bounds (mode="drop") so they can
    # never clobber a kept slot.
    slot = jnp.where(keep, flat_e * cap + pos, e * cap)     # (T·k,)
    # Scatter int32 token ids into slots (MB-class), then GATHER rows —
    # scattering the (E·C, d) activations directly makes GSPMD materialize
    # the full dispatch buffer per device (506 GiB/chip on kimi-k2).
    token_for_slot = jnp.full((e * cap,), t, jnp.int32)     # t = pad sentinel
    token_for_slot = token_for_slot.at[slot].set(
        tok_id.astype(jnp.int32), mode="drop")
    xf_pad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    dispatched = xf_pad[token_for_slot].reshape(e, cap, d)
    if mesh_axes is not None:
        from jax.sharding import PartitionSpec as _P
        dispatched = jax.lax.with_sharding_constraint(
            dispatched, _P(mesh_axes["model"], mesh_axes["data"], None))

    pet = dict(preferred_element_type=dispatched.dtype)
    hidden = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", dispatched, p["w_gate"], **pet)) * \
        jnp.einsum("ecd,edf->ecf", dispatched, p["w_up"], **pet)
    expert_out = jnp.einsum("ecf,efd->ecd", hidden, p["w_down"], **pet)
    if mesh_axes is not None:
        from jax.sharding import PartitionSpec as _P
        expert_out = jax.lax.with_sharding_constraint(
            expert_out, _P(mesh_axes["model"], mesh_axes["data"], None))
    expert_out = expert_out.reshape(e * cap, d)

    gathered = expert_out[slot] * (flat_w * keep)[:, None].astype(x.dtype)
    out = jnp.zeros((t, d), dtype=x.dtype).at[tok_id].add(gathered)
    return out.reshape(b, s, d), aux
