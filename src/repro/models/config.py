"""Architecture configuration shared by every model in the zoo."""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional, Tuple


class BlockKind(str, enum.Enum):
    ATTN = "attn"          # global attention + FFN
    LOCAL_ATTN = "local"   # sliding-window attention + FFN
    MOE = "moe"            # attention + MoE FFN
    MLSTM = "mlstm"        # xLSTM matrix-memory block
    SLSTM = "slstm"        # xLSTM scalar-memory block
    RGLRU = "rglru"        # RecurrentGemma RG-LRU block


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    # Attention variants
    sliding_window: Optional[int] = None
    local_global_pattern: Optional[int] = None  # e.g. 2 → every 2nd layer global
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    rope_theta: float = 10_000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    # MoE
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    # Recurrent blocks
    block_pattern: Optional[Tuple[str, ...]] = None  # cycle of BlockKind values
    conv_width: int = 4            # recurrentgemma temporal conv
    lru_width: Optional[int] = None
    # Encoder-decoder (seamless-m4t)
    encoder_layers: int = 0        # >0 → enc-dec; n_layers = decoder layers
    # Modality frontend stubs
    n_vision_tokens: int = 0       # vlm: precomputed patch embeddings
    audio_frames: int = 0          # audio: precomputed frame embeddings
    # Numerics / training
    dtype: str = "float32"
    remat: bool = True
    tie_embeddings: bool = False
    # Paper technique hooks
    stream_weights: bool = False   # out-of-core expert/embedding streaming

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def blocks(self) -> List[BlockKind]:
        """Per-layer block kinds for the decoder stack."""
        if self.block_pattern:
            pat = [BlockKind(b) for b in self.block_pattern]
            return [pat[i % len(pat)] for i in range(self.n_layers)]
        if self.is_moe:
            return [BlockKind.MOE] * self.n_layers
        if self.local_global_pattern:
            # gemma2: alternating local/global, local first
            return [
                BlockKind.LOCAL_ATTN
                if (i % self.local_global_pattern) != self.local_global_pattern - 1
                else BlockKind.ATTN
                for i in range(self.n_layers)
            ]
        if self.sliding_window:
            return [BlockKind.LOCAL_ATTN] * self.n_layers
        return [BlockKind.ATTN] * self.n_layers

    @property
    def subquadratic(self) -> bool:
        """True if the arch can decode at 500k context (SSM/hybrid/linear)."""
        kinds = set(self.blocks())
        quad = {BlockKind.ATTN, BlockKind.MOE}
        if self.is_enc_dec:
            return False
        return not (kinds & quad) or kinds <= {
            BlockKind.MLSTM, BlockKind.SLSTM, BlockKind.RGLRU,
            BlockKind.LOCAL_ATTN}

    def scaled_down(self, **overrides) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        small = dict(
            n_layers=min(self.n_layers, 2),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads > 1 else 1,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            head_dim=16,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            expert_d_ff=64 if self.expert_d_ff else 0,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else None,
            encoder_layers=min(self.encoder_layers, 2) if self.encoder_layers else 0,
            n_vision_tokens=min(self.n_vision_tokens, 8) if self.n_vision_tokens else 0,
            audio_frames=min(self.audio_frames, 16) if self.audio_frames else 0,
            lru_width=64 if self.lru_width else None,
            remat=False,
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)
