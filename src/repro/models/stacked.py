"""Scan-over-layers execution path (production / dry-run).

Unrolled layer loops produce O(n_layers) HLO — on an 80-layer model that is
minutes of XLA compile time per (arch × shape × mesh) cell. The scanned
path stacks per-layer params along a leading axis and runs `lax.scan` over
repeats of the arch's block pattern ("unit"), giving O(unit) HLO.

Grouping: blocks() is cut into R = n_layers // len(unit) repeats plus an
unrolled remainder, e.g. recurrentgemma 26L with unit (rglru, rglru, local)
→ scan R=8 over the triple + 2 remainder layers.

Cost accounting: XLA counts a while-loop body ONCE in cost_analysis, so the
dry-run composes totals as `module_cost + (R-1) × body_cost` using
`body_fn()` compiled standalone — trip counts are known statically here.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig, BlockKind
from repro.models import layers as L
from repro.models.transformer import (
    _layer_apply, _build_positions, _shard, _init_layer, _dtype,
)


def unit_kinds(cfg: ArchConfig) -> List[BlockKind]:
    if cfg.block_pattern:
        return [BlockKind(b) for b in cfg.block_pattern]
    kinds = cfg.blocks()
    if cfg.local_global_pattern:
        return kinds[: cfg.local_global_pattern]
    return kinds[:1]


def group_split(cfg: ArchConfig) -> Tuple[int, int]:
    """(repeats R, remainder layers)."""
    u = len(unit_kinds(cfg))
    return cfg.n_layers // u, cfg.n_layers % u


def init_params_stacked(cfg: ArchConfig, key: jax.Array) -> Dict[str, Any]:
    """Same weights layout as init_params but layers stacked by unit
    position: params["scan"][j] has leaves (R, ...) for unit position j;
    params["rest"] is the unrolled remainder."""
    dt = _dtype(cfg)
    kinds = cfg.blocks()
    u_kinds = unit_kinds(cfg)
    u = len(u_kinds)
    r, rem = group_split(cfg)

    # Same split count as init_params so weights match layer-for-layer.
    keys = jax.random.split(key, cfg.n_layers + cfg.encoder_layers + 3)
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model))
                  * cfg.d_model ** -0.5).astype(dt),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(keys[1], (cfg.d_model, cfg.vocab))
            * cfg.d_model ** -0.5).astype(dt)

    per_layer = [
        _init_layer(keys[2 + i], cfg, kinds[i], dt, cross=cfg.is_enc_dec)
        for i in range(cfg.n_layers)
    ]
    params["scan"] = []
    for j in range(u):
        members = [per_layer[rep * u + j] for rep in range(r)]
        params["scan"].append(
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *members))
    params["rest"] = per_layer[r * u:]

    if cfg.is_enc_dec:
        enc_layers = [
            _init_layer(keys[2 + cfg.n_layers + i], cfg, BlockKind.ATTN, dt,
                        cross=False)
            for i in range(cfg.encoder_layers)
        ]
        params["enc_scan"] = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *enc_layers)
        params["enc_norm"] = jnp.zeros((cfg.d_model,), dt)
    if cfg.n_vision_tokens:
        params["vision_proj"] = (
            jax.random.normal(keys[-1], (cfg.d_model, cfg.d_model))
            * cfg.d_model ** -0.5).astype(dt)
    if cfg.audio_frames:
        params["audio_proj"] = (
            jax.random.normal(keys[-1], (cfg.d_model, cfg.d_model))
            * cfg.d_model ** -0.5).astype(dt)
    return params


def _unit_apply(cfg, u_kinds, unit_params, x, positions, mesh_axes,
                enc_out=None):
    aux = jnp.float32(0.0)
    for j, kind in enumerate(u_kinds):
        x, a = _layer_apply(cfg, kind, unit_params[j], x, positions,
                            mesh_axes, enc_out, None)
        aux = aux + a
    return x, aux


def encode_scan(cfg: ArchConfig, params, audio_embeds, mesh_axes=None):
    b = audio_embeds.shape[0]
    e = (audio_embeds @ params["audio_proj"]).astype(audio_embeds.dtype)
    e = _shard(e, mesh_axes, ("data", None, None))
    epos = jnp.arange(e.shape[1], dtype=jnp.int32)[None, :].repeat(b, 0)

    def body(carry, p_):
        h = L.rms_norm(carry, p_["ln1"])
        hkv, hd = cfg.n_kv_heads, cfg.hd
        ek = (h @ p_["attn"]["wk"]).reshape(b, -1, hkv, hd).transpose(0, 2, 1, 3)
        ev = (h @ p_["attn"]["wv"]).reshape(b, -1, hkv, hd).transpose(0, 2, 1, 3)
        o, _ = L.attention(cfg, p_["attn"], h, epos, cross_kv=(ek, ev))
        out = carry + o
        if "mlp" in p_:
            out = out + L.mlp(p_["mlp"], L.rms_norm(out, p_["ln2"]))
        return out, ()

    fn = jax.checkpoint(body) if cfg.remat else body
    e, _ = jax.lax.scan(fn, e, params["enc_scan"])
    return L.rms_norm(e, params["enc_norm"])


def forward_scan(cfg: ArchConfig, params, tokens,
                 vision_embeds=None, audio_embeds=None, mesh_axes=None,
                 last_only: bool = False):
    b, s = tokens.shape
    x = params["embed"][tokens]
    x = _shard(x, mesh_axes, ("data", None, None))
    if cfg.n_vision_tokens and vision_embeds is not None:
        vis = (vision_embeds @ params["vision_proj"]).astype(x.dtype)
        x = jnp.concatenate([vis, x[:, cfg.n_vision_tokens:]], axis=1)
    enc_out = None
    if cfg.is_enc_dec:
        enc_out = encode_scan(cfg, params, audio_embeds, mesh_axes)

    positions = _build_positions(cfg, b, s)
    u_kinds = unit_kinds(cfg)
    r, rem = group_split(cfg)

    def body(carry, unit_params):
        x_, aux_ = carry
        x_, a = _unit_apply(cfg, u_kinds, unit_params, x_, positions,
                            mesh_axes, enc_out)
        return (x_, aux_ + a), ()

    fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(fn, (x, jnp.float32(0.0)),
                               tuple(params["scan"]))
    kinds = cfg.blocks()
    for i, p in enumerate(params["rest"]):
        x, a = _layer_apply(cfg, kinds[r * len(u_kinds) + i], p, x,
                            positions, mesh_axes, enc_out, None)
        aux = aux + a

    if last_only:
        x = x[:, -1:, :]     # serving prefill: logits for the next token only
    x = L.rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = L.matmul(x, head)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    # vocab axis stays model-sharded (sharded softmax in the loss)
    logits = _shard(logits, mesh_axes, ("data", None, "model"))
    return logits, aux


def lm_loss_scan(cfg: ArchConfig, params, tokens, labels,
                 vision_embeds=None, audio_embeds=None, mesh_axes=None):
    """Shard-friendly CE: one-hot einsum instead of take_along_axis so the
    vocab axis stays model-sharded through the loss (no logits all-gather)."""
    logits, aux = forward_scan(cfg, params, tokens, vision_embeds,
                               audio_embeds, mesh_axes)
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    logz = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(labels, cfg.vocab, dtype=jnp.float32)
    onehot = _shard(onehot, mesh_axes, ("data", None, "model"))
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = jnp.mean(logz - gold)
    return nll + 0.01 * aux


# ---------------------------------------------------------------- decode ----

def init_decode_state_stacked(cfg: ArchConfig, batch: int, max_len: int,
                              dtype=None):
    """Decode state grouped like the params: state["scan"][j] stacked (R,...)
    for unit position j; state["rest"] unrolled."""
    from repro.models.transformer import init_decode_state
    flat = init_decode_state(cfg, batch, max_len, dtype)
    u = len(unit_kinds(cfg))
    r, rem = group_split(cfg)
    layers = flat["layers"]
    scan_states = []
    for j in range(u):
        members = [layers[rep * u + j] for rep in range(r)]
        scan_states.append(
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *members))
    return {"pos": jnp.int32(0), "scan": scan_states,
            "rest": layers[r * u:]}


def decode_step_scan(cfg: ArchConfig, params, token, state,
                     enc_out=None, mesh_axes=None):
    from repro.models.transformer import _decode_attn
    from repro.models import recurrent as R_

    b = token.shape[0]
    pos = state["pos"]
    x = params["embed"][token]
    u_kinds = unit_kinds(cfg)
    r, rem = group_split(cfg)

    def apply_one(kind, p, st, x):
        h = L.rms_norm(x, p["ln1"])
        if kind in (BlockKind.ATTN, BlockKind.MOE, BlockKind.LOCAL_ATTN):
            window = cfg.sliding_window if kind == BlockKind.LOCAL_ATTN else None
            attn_out, new_st = _decode_attn(cfg, p["attn"], h, st, pos, window,
                                            ring=kind == BlockKind.LOCAL_ATTN)
            x = x + attn_out
            if enc_out is not None and "xattn" in p:
                hx = L.rms_norm(x, p["ln_x"])
                hkv, hd = cfg.n_kv_heads, cfg.hd
                ek = (enc_out @ p["xattn"]["wk"]).reshape(
                    b, -1, hkv, hd).transpose(0, 2, 1, 3)
                ev = (enc_out @ p["xattn"]["wv"]).reshape(
                    b, -1, hkv, hd).transpose(0, 2, 1, 3)
                posb = jnp.full((b, 1), pos, jnp.int32)
                cross_out, _ = L.attention(cfg, p["xattn"], hx, posb,
                                           cross_kv=(ek, ev))
                x = x + cross_out
            h2 = L.rms_norm(x, p["ln2"])
            if kind == BlockKind.MOE:
                ffn_out, _ = L.moe_ffn(cfg, p["moe"], h2)
            elif "mlp" in p:
                ffn_out = L.mlp(p["mlp"], h2)
            else:
                ffn_out = jnp.zeros_like(x)
            x = x + ffn_out
        elif kind == BlockKind.MLSTM:
            y, new_st = R_.mlstm_step(p["mlstm"], h, st, cfg.n_heads)
            x = x + y
            if "mlp" in p:
                x = x + L.mlp(p["mlp"], L.rms_norm(x, p["ln2"]))
        elif kind == BlockKind.SLSTM:
            y, new_st = R_.slstm_step(p["slstm"], h, st)
            x = x + y
            if "mlp" in p:
                x = x + L.mlp(p["mlp"], L.rms_norm(x, p["ln2"]))
        elif kind == BlockKind.RGLRU:
            rp = p["rec"]
            gate = jax.nn.gelu(h @ rp["w_branch_gate"])
            lin = h @ rp["w_branch_lin"]
            lin, conv_st = R_.temporal_conv_step(rp, lin, st["conv"],
                                                 cfg.conv_width)
            rec, h_st = R_.rglru_step(rp, lin, st["h"])
            new_st = {"h": h_st, "conv": conv_st}
            x = x + (gate * rec) @ rp["w_out"]
            if "mlp" in p:
                x = x + L.mlp(p["mlp"], L.rms_norm(x, p["ln2"]))
        return x, new_st

    def body(x_, xs):
        unit_params, unit_states = xs
        new_states = []
        for j, kind in enumerate(u_kinds):
            x_, ns = apply_one(kind, unit_params[j], unit_states[j], x_)
            new_states.append(ns)
        return x_, tuple(new_states)

    x, new_scan_states = jax.lax.scan(
        body, x, (tuple(params["scan"]), tuple(state["scan"])))

    kinds = cfg.blocks()
    new_rest = []
    for i, p in enumerate(params["rest"]):
        kind = kinds[r * len(u_kinds) + i]
        x, ns = apply_one(kind, p, state["rest"][i], x)
        new_rest.append(ns)

    x = L.rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = L.matmul(x, head)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits, {"pos": pos + 1, "scan": list(new_scan_states),
                    "rest": new_rest}
