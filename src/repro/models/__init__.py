"""Model zoo: the paper's GCN + the 10 assigned LM-family architectures.

Pure-JAX functional models: params are pytrees of jnp arrays, every forward
is a jit-able function of (config, params, batch). One composable
transformer stack covers dense/GQA/SWA/softcap/MoE/M-RoPE variants;
recurrent blocks (mLSTM, sLSTM, RG-LRU) plug into the same block list.
"""
from repro.models.config import ArchConfig, BlockKind
from repro.models.transformer import (
    init_params,
    forward,
    encode,
    lm_loss,
    init_decode_state,
    decode_step,
    param_count,
)
from repro.models.gcn import GCNConfig, gcn_init, gcn_forward, gcn_loss

__all__ = [
    "ArchConfig", "BlockKind",
    "init_params", "forward", "encode", "lm_loss", "init_decode_state",
    "decode_step", "param_count",
    "GCNConfig", "gcn_init", "gcn_forward", "gcn_loss",
]
