"""The paper's own architecture: a GCN trained with out-of-core SpGEMM.

Two execution paths:
  * in-core (dense jnp): used by smoke tests and the training example on
    small graphs — Eq. (4) per layer: H' = σ(Ã H W).
  * out-of-core (AIRES): aggregation X = Ã H runs through AiresSpGEMM
    (RoBW streaming + Pallas kernel) when cfg.out_of_core=True.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sparse.formats import CSR


@dataclasses.dataclass(frozen=True)
class GCNConfig:
    name: str = "gcn_paper"
    feature_dim: int = 256       # paper §V-A
    hidden_dims: Tuple[int, ...] = (256, 256)
    n_classes: int = 32
    out_of_core: bool = False
    device_budget_bytes: int = 1 << 30
    dtype: str = "float32"


def gcn_init(cfg: GCNConfig, key: jax.Array) -> Dict[str, jnp.ndarray]:
    dt = jnp.dtype(cfg.dtype)
    dims = [cfg.feature_dim, *cfg.hidden_dims, cfg.n_classes]
    params = {}
    for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
        key, sub = jax.random.split(key)
        params[f"w{i}"] = (jax.random.normal(sub, (din, dout))
                           * din ** -0.5).astype(dt)
        params[f"b{i}"] = jnp.zeros((dout,), dt)
    return params


def _aggregate(a_dense: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    return jnp.dot(a_dense, h, preferred_element_type=jnp.float32).astype(h.dtype)


def gcn_forward(cfg: GCNConfig, params, a, h0: jnp.ndarray,
                engine: Optional[object] = None) -> jnp.ndarray:
    """a: dense jnp array (in-core) or CSR (out-of-core with engine)."""
    n_layers = len([k for k in params if k.startswith("w")])
    h = h0
    for i in range(n_layers):
        if cfg.out_of_core and isinstance(a, CSR):
            assert engine is not None, "out-of-core path needs AiresSpGEMM"
            x = engine(a, h)                      # streamed Ã·H
        else:
            x = _aggregate(a, h)
        h = x @ params[f"w{i}"] + params[f"b{i}"]
        if i < n_layers - 1:
            h = jax.nn.relu(h)
    return h


def gcn_loss(cfg: GCNConfig, params, a, h0, labels,
             engine: Optional[object] = None) -> jnp.ndarray:
    logits = gcn_forward(cfg, params, a, h0, engine).astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)
