"""Recurrent sequence blocks: mLSTM / sLSTM (xLSTM) and RG-LRU (Griffin).

Each block exposes a parallel `*_train` form over (B, S, D) and a
single-step `*_step` form with explicit state for decode — the state is
O(1) in sequence length, which is what makes `long_500k` runnable for
these architectures.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# mLSTM — matrix memory, parallel (stabilized quadratic form) + recurrent step
# --------------------------------------------------------------------------

def mlstm_train(p: Dict[str, jnp.ndarray], x: jnp.ndarray, n_heads: int
                ) -> jnp.ndarray:
    """x (B, S, D) → (B, S, D). Stabilized parallel form (xLSTM eq. 2x)."""
    b, s, d = x.shape
    hd = d // n_heads

    def split(w):
        return (x @ w).reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)

    q, k, v = split(p["wq"]), split(p["wk"]), split(p["wv"])
    i_pre = (x @ p["wi"]).reshape(b, s, n_heads).transpose(0, 2, 1)   # (B,H,S)
    f_pre = (x @ p["wf"]).reshape(b, s, n_heads).transpose(0, 2, 1)

    log_f = jax.nn.log_sigmoid(f_pre.astype(jnp.float32))
    csum = jnp.cumsum(log_f, axis=-1)                                  # (B,H,S)
    # D̃[t, u] = Σ_{u<j<=t} log f_j + ĩ_u  (u <= t)
    dmat = csum[..., :, None] - csum[..., None, :] + \
        i_pre.astype(jnp.float32)[..., None, :]
    causal = jnp.tril(jnp.ones((s, s), bool))
    dmat = jnp.where(causal, dmat, -jnp.inf)
    m = jnp.max(dmat, axis=-1, keepdims=True)                          # (B,H,S,1)
    m = jnp.maximum(m, -1e30)
    dexp = jnp.exp(dmat - m)

    logits = jnp.einsum("bhtd,bhud->bhtu", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / (hd ** 0.5)
    w = logits * dexp
    norm = jnp.maximum(jnp.abs(jnp.sum(w, axis=-1, keepdims=True)),
                       jnp.exp(-m))
    h = jnp.einsum("bhtu,bhud->bhtd", w / norm, v.astype(jnp.float32))
    h = h.transpose(0, 2, 1, 3).reshape(b, s, d).astype(x.dtype)
    return rms_head_norm(h, p["gn"], n_heads) @ p["wo"]


def mlstm_init_state(batch: int, n_heads: int, hd: int, dtype=jnp.float32):
    return {
        "c": jnp.zeros((batch, n_heads, hd, hd), dtype),
        "n": jnp.zeros((batch, n_heads, hd), dtype),
        "m": jnp.full((batch, n_heads), -1e30, dtype),
    }


def mlstm_step(p: Dict[str, jnp.ndarray], x: jnp.ndarray, state, n_heads: int):
    """x (B, 1, D) one token; returns (y (B,1,D), new_state)."""
    b, s, d = x.shape
    hd = d // n_heads
    xt = x[:, 0]

    def split(w):
        return (xt @ w).reshape(b, n_heads, hd)

    q, k, v = split(p["wq"]), split(p["wk"]), split(p["wv"])
    i_pre = (xt @ p["wi"]).reshape(b, n_heads).astype(jnp.float32)
    f_pre = (xt @ p["wf"]).reshape(b, n_heads).astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(f_pre)

    m_new = jnp.maximum(log_f + state["m"], i_pre)
    i_g = jnp.exp(i_pre - m_new)[..., None]
    f_g = jnp.exp(log_f + state["m"] - m_new)[..., None]

    kq_scale = 1.0 / (hd ** 0.5)
    c = f_g[..., None] * state["c"] + i_g[..., None] * \
        jnp.einsum("bhd,bhe->bhde", v.astype(jnp.float32),
                   k.astype(jnp.float32))
    n = f_g * state["n"] + i_g * k.astype(jnp.float32)
    qs = q.astype(jnp.float32) * kq_scale
    num = jnp.einsum("bhde,bhe->bhd", c, qs)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhe,bhe->bh", n, qs)),
                      jnp.exp(-m_new))[..., None]
    h = (num / den).reshape(b, 1, d).astype(x.dtype)
    y = rms_head_norm(h, p["gn"], n_heads) @ p["wo"]
    return y, {"c": c, "n": n, "m": m_new}


def rms_head_norm(h: jnp.ndarray, scale: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """Per-head RMS group norm used by xLSTM outputs."""
    shape = h.shape
    hh = h.reshape(*shape[:-1], n_heads, shape[-1] // n_heads)
    var = jnp.mean(jnp.square(hh.astype(jnp.float32)), axis=-1, keepdims=True)
    hh = hh * jax.lax.rsqrt(var + 1e-6)
    return (hh.reshape(shape) * (1.0 + scale)).astype(h.dtype)


# --------------------------------------------------------------------------
# sLSTM — scalar memory with recurrent gate mixing (sequential scan)
# --------------------------------------------------------------------------

def slstm_init_state(batch: int, d: int, dtype=jnp.float32):
    return {
        "c": jnp.zeros((batch, d), dtype),
        "n": jnp.ones((batch, d), dtype),
        "h": jnp.zeros((batch, d), dtype),
        "m": jnp.zeros((batch, d), dtype),
    }


def _slstm_cell(p, state, xt):
    """One sLSTM step; xt (B, D)."""
    h_prev = state["h"]
    zi = xt @ p["wz"] + h_prev @ p["rz"]
    ii = (xt @ p["wi_g"] + h_prev @ p["ri"]).astype(jnp.float32)
    ff = (xt @ p["wf_g"] + h_prev @ p["rf"]).astype(jnp.float32)
    oo = xt @ p["wo_g"] + h_prev @ p["ro"]

    log_f = jax.nn.log_sigmoid(ff)
    m_new = jnp.maximum(log_f + state["m"], ii)
    i_g = jnp.exp(ii - m_new)
    f_g = jnp.exp(log_f + state["m"] - m_new)

    c = f_g * state["c"] + i_g * jnp.tanh(zi).astype(jnp.float32)
    n = jnp.maximum(f_g * state["n"] + i_g, 1e-6)
    h = jax.nn.sigmoid(oo).astype(jnp.float32) * (c / n)
    h = h.astype(xt.dtype)
    return {"c": c, "n": n, "h": h, "m": m_new}, h


def slstm_train(p: Dict[str, jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    """x (B, S, D) → (B, S, D); sequential lax.scan over time."""
    b, s, d = x.shape
    state0 = slstm_init_state(b, d, jnp.float32)
    # carry "h" must match the emitted h dtype (activation dtype).
    state0["h"] = state0["h"].astype(x.dtype)

    def scan_fn(state, xt):
        new_state, h = _slstm_cell(p, state, xt)
        return new_state, h

    _, hs = jax.lax.scan(scan_fn, state0, x.transpose(1, 0, 2))
    return hs.transpose(1, 0, 2) @ p["wo"]


def slstm_step(p: Dict[str, jnp.ndarray], x: jnp.ndarray, state):
    new_state, h = _slstm_cell(p, state, x[:, 0])
    return (h @ p["wo"])[:, None], new_state


# --------------------------------------------------------------------------
# RG-LRU (RecurrentGemma / Griffin): gated linear recurrence + temporal conv
# --------------------------------------------------------------------------

_RGLRU_C = 8.0


def rglru_train(p: Dict[str, jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    """Parallel RG-LRU over (B, S, W) via associative scan."""
    r = jax.nn.sigmoid((x @ p["w_rec_gate"]).astype(jnp.float32))
    i = jax.nn.sigmoid((x @ p["w_in_gate"]).astype(jnp.float32))
    log_a = -_RGLRU_C * jax.nn.softplus(p["lambda"]) * r       # (B,S,W)
    a = jnp.exp(log_a)
    gated_x = x.astype(jnp.float32) * i
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b_term = beta * gated_x

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b_term), axis=1)
    return h.astype(x.dtype)


def rglru_init_state(batch: int, width: int, dtype=jnp.float32):
    return jnp.zeros((batch, width), jnp.float32)


def rglru_step(p: Dict[str, jnp.ndarray], x: jnp.ndarray, state):
    """x (B, 1, W); state (B, W)."""
    xt = x[:, 0]
    r = jax.nn.sigmoid((xt @ p["w_rec_gate"]).astype(jnp.float32))
    i = jax.nn.sigmoid((xt @ p["w_in_gate"]).astype(jnp.float32))
    log_a = -_RGLRU_C * jax.nn.softplus(p["lambda"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    h = a * state + beta * (xt.astype(jnp.float32) * i)
    return h[:, None].astype(x.dtype), h


def temporal_conv_train(p: Dict[str, jnp.ndarray], x: jnp.ndarray,
                        width: int) -> jnp.ndarray:
    """Causal depthwise conv1d (B, S, W), kernel (width, W)."""
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + x.shape[1]] * p["conv_w"][i] for i in range(width))
    return out + p["conv_b"]


def temporal_conv_step(p: Dict[str, jnp.ndarray], x: jnp.ndarray,
                       state: jnp.ndarray, width: int):
    """x (B, 1, W); state (B, width-1, W) holds the trailing window."""
    window = jnp.concatenate([state, x], axis=1)          # (B, width, W)
    out = jnp.einsum("bkw,kw->bw", window, p["conv_w"]) + p["conv_b"]
    return out[:, None], window[:, 1:]
