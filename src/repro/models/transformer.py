"""Composable decoder / encoder-decoder stack covering the 10 assigned archs.

One `forward` covers: dense GQA (yi, deepseek), SWA (mixtral), alternating
local/global + softcaps (gemma2), MoE (mixtral, kimi-k2), M-RoPE + vision
stub (qwen2-vl), audio enc-dec stub (seamless-m4t), xLSTM blocks
(xlstm-125m), and RG-LRU hybrid (recurrentgemma). Decode paths carry O(1)
or O(window) state for recurrent/local blocks — that is what makes
`long_500k` feasible for the sub-quadratic archs.

Sharding: when `mesh_axes` is provided (dryrun/launcher), activations get
`with_sharding_constraint` hints at layer boundaries; on a bare CPU test no
constraint is applied.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig, BlockKind
from repro.models import layers as L
from repro.models import recurrent as R


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def _shard(x, mesh_axes, spec):
    """mesh_axes: None (no constraints) or {"data": axes, "model": axis}.
    spec entries are "data"/"model"/None and resolve per-mesh, so the same
    model code runs on single-pod (data, model) and multi-pod
    (pod, data, model) meshes."""
    if mesh_axes is None:
        return x
    resolved = tuple(mesh_axes.get(a, None) if isinstance(a, str) else a
                     for a in spec)
    return jax.lax.with_sharding_constraint(x, P(*resolved))


MESH_AXES_SINGLE = {"data": ("data",), "model": "model"}
MESH_AXES_MULTI = {"data": ("pod", "data"), "model": "model"}


# --------------------------------------------------------------------------
# Parameter initialization
# --------------------------------------------------------------------------

def _init_attn(key, cfg: ArchConfig, dt):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "wq": (jax.random.normal(k1, (d, hq * hd)) * s).astype(dt),
        "wk": (jax.random.normal(k2, (d, hkv * hd)) * s).astype(dt),
        "wv": (jax.random.normal(k3, (d, hkv * hd)) * s).astype(dt),
        "wo": (jax.random.normal(k4, (hq * hd, d)) * (hq * hd) ** -0.5).astype(dt),
    }


def _init_mlp(key, d_in, d_ff, dt):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": (jax.random.normal(k1, (d_in, d_ff)) * d_in ** -0.5).astype(dt),
        "w_up": (jax.random.normal(k2, (d_in, d_ff)) * d_in ** -0.5).astype(dt),
        "w_down": (jax.random.normal(k3, (d_ff, d_in)) * d_ff ** -0.5).astype(dt),
    }


def _init_moe(key, cfg: ArchConfig, dt):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.expert_d_ff or cfg.d_ff
    k0, k1, k2, k3 = jax.random.split(key, 4)
    return {
        "w_router": (jax.random.normal(k0, (d, e)) * d ** -0.5).astype(dt),
        "w_gate": (jax.random.normal(k1, (e, d, f)) * d ** -0.5).astype(dt),
        "w_up": (jax.random.normal(k2, (e, d, f)) * d ** -0.5).astype(dt),
        "w_down": (jax.random.normal(k3, (e, f, d)) * f ** -0.5).astype(dt),
    }


def _init_mlstm(key, cfg: ArchConfig, dt):
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    s = d ** -0.5
    return {
        "wq": (jax.random.normal(ks[0], (d, d)) * s).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, d)) * s).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, d)) * s).astype(dt),
        "wi": (jax.random.normal(ks[3], (d, cfg.n_heads)) * s).astype(dt),
        "wf": (jax.random.normal(ks[4], (d, cfg.n_heads)) * s).astype(dt),
        "gn": jnp.zeros((d,), dt),
        "wo": (jax.random.normal(ks[5], (d, d)) * s).astype(dt),
    }


def _init_slstm(key, cfg: ArchConfig, dt):
    d = cfg.d_model
    ks = jax.random.split(key, 9)
    s = d ** -0.5
    p = {}
    for i, nm in enumerate(["wz", "wi_g", "wf_g", "wo_g"]):
        p[nm] = (jax.random.normal(ks[i], (d, d)) * s).astype(dt)
    for i, nm in enumerate(["rz", "ri", "rf", "ro"]):
        p[nm] = (jax.random.normal(ks[4 + i], (d, d)) * s * 0.5).astype(dt)
    p["wo"] = (jax.random.normal(ks[8], (d, d)) * s).astype(dt)
    return p


def _init_rglru_block(key, cfg: ArchConfig, dt):
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "w_branch_gate": (jax.random.normal(ks[0], (d, w)) * s).astype(dt),
        "w_branch_lin": (jax.random.normal(ks[1], (d, w)) * s).astype(dt),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, w)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((w,), dt),
        "w_rec_gate": (jax.random.normal(ks[3], (w, w)) * w ** -0.5).astype(dt),
        "w_in_gate": (jax.random.normal(ks[4], (w, w)) * w ** -0.5).astype(dt),
        "lambda": jnp.full((w,), 0.6, dt),
        "w_out": (jax.random.normal(ks[5], (w, d)) * w ** -0.5).astype(dt),
    }


def _init_layer(key, cfg: ArchConfig, kind: BlockKind, dt, cross: bool):
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: Dict[str, Any] = {"ln1": jnp.zeros((d,), dt)}
    if kind in (BlockKind.ATTN, BlockKind.LOCAL_ATTN, BlockKind.MOE):
        p["attn"] = _init_attn(ks[0], cfg, dt)
        p["ln2"] = jnp.zeros((d,), dt)
        if kind == BlockKind.MOE:
            p["moe"] = _init_moe(ks[1], cfg, dt)
        elif cfg.d_ff:
            p["mlp"] = _init_mlp(ks[1], d, cfg.d_ff, dt)
    elif kind == BlockKind.MLSTM:
        p["mlstm"] = _init_mlstm(ks[0], cfg, dt)
        if cfg.d_ff:
            p["ln2"] = jnp.zeros((d,), dt)
            p["mlp"] = _init_mlp(ks[1], d, cfg.d_ff, dt)
    elif kind == BlockKind.SLSTM:
        p["slstm"] = _init_slstm(ks[0], cfg, dt)
        if cfg.d_ff:
            p["ln2"] = jnp.zeros((d,), dt)
            p["mlp"] = _init_mlp(ks[1], d, cfg.d_ff, dt)
    elif kind == BlockKind.RGLRU:
        p["rec"] = _init_rglru_block(ks[0], cfg, dt)
        if cfg.d_ff:
            p["ln2"] = jnp.zeros((d,), dt)
            p["mlp"] = _init_mlp(ks[1], d, cfg.d_ff, dt)
    if cross:
        p["ln_x"] = jnp.zeros((d,), dt)
        p["xattn"] = _init_attn(ks[2], cfg, dt)
    return p


def init_params(cfg: ArchConfig, key: jax.Array) -> Dict[str, Any]:
    dt = _dtype(cfg)
    keys = jax.random.split(key, cfg.n_layers + cfg.encoder_layers + 3)
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab, cfg.d_model))
                  * cfg.d_model ** -0.5).astype(dt),
        "final_norm": jnp.zeros((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(keys[1], (cfg.d_model, cfg.vocab))
            * cfg.d_model ** -0.5).astype(dt)
    kinds = cfg.blocks()
    params["layers"] = [
        _init_layer(keys[2 + i], cfg, kinds[i], dt, cross=cfg.is_enc_dec)
        for i in range(cfg.n_layers)
    ]
    if cfg.is_enc_dec:
        params["enc_layers"] = [
            _init_layer(keys[2 + cfg.n_layers + i], cfg, BlockKind.ATTN, dt,
                        cross=False)
            for i in range(cfg.encoder_layers)
        ]
        params["enc_norm"] = jnp.zeros((cfg.d_model,), dt)
    if cfg.n_vision_tokens:
        # Frontend STUB projection for precomputed patch embeddings.
        params["vision_proj"] = (
            jax.random.normal(keys[-1], (cfg.d_model, cfg.d_model))
            * cfg.d_model ** -0.5).astype(dt)
    if cfg.audio_frames:
        params["audio_proj"] = (
            jax.random.normal(keys[-1], (cfg.d_model, cfg.d_model))
            * cfg.d_model ** -0.5).astype(dt)
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


# --------------------------------------------------------------------------
# Forward (training / prefill)
# --------------------------------------------------------------------------

def _layer_apply(cfg: ArchConfig, kind: BlockKind, p, x, positions,
                 mesh_axes, enc_out=None, enc_mask=None):
    aux = jnp.float32(0.0)
    h = L.rms_norm(x, p["ln1"])
    if kind in (BlockKind.ATTN, BlockKind.LOCAL_ATTN, BlockKind.MOE):
        window = cfg.sliding_window if kind == BlockKind.LOCAL_ATTN else None
        attn_out, _ = L.attention(cfg, p["attn"], h, positions,
                                  sliding_window=window)
        x = x + attn_out
        if enc_out is not None:
            hx = L.rms_norm(x, p["ln_x"])
            b, s_enc, d = enc_out.shape
            hkv, hd = cfg.n_kv_heads, cfg.hd
            ek = (enc_out @ p["xattn"]["wk"]).reshape(b, s_enc, hkv, hd)
            ev = (enc_out @ p["xattn"]["wv"]).reshape(b, s_enc, hkv, hd)
            cross_out, _ = L.attention(
                cfg, p["xattn"], hx, positions,
                cross_kv=(ek.transpose(0, 2, 1, 3), ev.transpose(0, 2, 1, 3)),
                cross_mask=enc_mask)
            x = x + cross_out
        h2 = L.rms_norm(x, p["ln2"])
        if kind == BlockKind.MOE:
            ffn_out, aux = L.moe_ffn(cfg, p["moe"], h2, mesh_axes)
        elif "mlp" in p:
            ffn_out = L.mlp(p["mlp"], h2)
        else:
            ffn_out = jnp.zeros_like(x)
        x = x + ffn_out
    elif kind == BlockKind.MLSTM:
        x = x + R.mlstm_train(p["mlstm"], h, cfg.n_heads)
        if "mlp" in p:
            x = x + L.mlp(p["mlp"], L.rms_norm(x, p["ln2"]))
    elif kind == BlockKind.SLSTM:
        x = x + R.slstm_train(p["slstm"], h)
        if "mlp" in p:
            x = x + L.mlp(p["mlp"], L.rms_norm(x, p["ln2"]))
    elif kind == BlockKind.RGLRU:
        rp = p["rec"]
        gate = jax.nn.gelu(h @ rp["w_branch_gate"])
        lin = h @ rp["w_branch_lin"]
        lin = R.temporal_conv_train(rp, lin, cfg.conv_width)
        rec = R.rglru_train(rp, lin)
        x = x + (gate * rec) @ rp["w_out"]
        if "mlp" in p:
            x = x + L.mlp(p["mlp"], L.rms_norm(x, p["ln2"]))
    x = _shard(x, mesh_axes, ("data", None, None))
    return x, aux


def _build_positions(cfg: ArchConfig, b: int, s: int):
    pos = jnp.arange(s, dtype=jnp.int32)[None, :].repeat(b, axis=0)
    if cfg.mrope_sections is None:
        return pos
    # M-RoPE: first n_vision_tokens form a (t=0, h, w) grid; text continues
    # with equal t/h/w ids (exactly standard RoPE for text).
    nv = cfg.n_vision_tokens
    grid_w = max(1, int(nv ** 0.5))
    vis_h = (jnp.arange(nv) // grid_w).astype(jnp.int32)
    vis_w = (jnp.arange(nv) % grid_w).astype(jnp.int32)
    t_ids = jnp.concatenate([jnp.zeros((nv,), jnp.int32),
                             jnp.arange(s - nv, dtype=jnp.int32) + 1])
    h_ids = jnp.concatenate([vis_h, jnp.arange(s - nv, dtype=jnp.int32) + 1])
    w_ids = jnp.concatenate([vis_w, jnp.arange(s - nv, dtype=jnp.int32) + 1])
    return jnp.stack([t_ids, h_ids, w_ids])[:, None, :].repeat(b, axis=1)


def encode(cfg: ArchConfig, params, audio_embeds: jnp.ndarray,
           mesh_axes=None) -> jnp.ndarray:
    """Bidirectional encoder over precomputed frontend embeddings
    (seamless-m4t). Memoize the result for decode."""
    b = audio_embeds.shape[0]
    e = (audio_embeds @ params["audio_proj"]).astype(audio_embeds.dtype)
    e = _shard(e, mesh_axes, ("data", None, None))
    epos = jnp.arange(e.shape[1], dtype=jnp.int32)[None, :].repeat(b, 0)

    def enc_fn(e_, p_):
        h = L.rms_norm(e_, p_["ln1"])
        # Bidirectional attention: route through cross_kv against itself
        # (no causal mask).
        hkv, hd = cfg.n_kv_heads, cfg.hd
        ek = (h @ p_["attn"]["wk"]).reshape(b, -1, hkv, hd).transpose(0, 2, 1, 3)
        ev = (h @ p_["attn"]["wv"]).reshape(b, -1, hkv, hd).transpose(0, 2, 1, 3)
        o, _ = L.attention(cfg, p_["attn"], h, epos, cross_kv=(ek, ev))
        e_ = e_ + o
        if "mlp" in p_:
            e_ = e_ + L.mlp(p_["mlp"], L.rms_norm(e_, p_["ln2"]))
        return e_

    for p in params["enc_layers"]:
        e = (jax.checkpoint(enc_fn)(e, p) if cfg.remat else enc_fn(e, p))
    return L.rms_norm(e, params["enc_norm"])


def forward(cfg: ArchConfig, params, tokens: jnp.ndarray,
            vision_embeds: Optional[jnp.ndarray] = None,
            audio_embeds: Optional[jnp.ndarray] = None,
            mesh_axes: Optional[bool] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B, S) → (logits (B, S, V), aux_loss)."""
    b, s = tokens.shape
    x = params["embed"][tokens]
    x = _shard(x, mesh_axes, ("data", None, None))
    if cfg.n_vision_tokens and vision_embeds is not None:
        vis = (vision_embeds @ params["vision_proj"]).astype(x.dtype)
        x = jnp.concatenate([vis, x[:, cfg.n_vision_tokens:]], axis=1)

    enc_out = enc_mask = None
    if cfg.is_enc_dec:
        assert audio_embeds is not None, "enc-dec needs encoder frames"
        enc_out = encode(cfg, params, audio_embeds, mesh_axes)

    positions = _build_positions(cfg, b, s)
    kinds = cfg.blocks()
    aux_total = jnp.float32(0.0)
    for li, p in enumerate(params["layers"]):
        fn = functools.partial(_layer_apply, cfg, kinds[li])
        if cfg.remat:
            fn = jax.checkpoint(fn, static_argnums=())
        x, aux = fn(p, x, positions, mesh_axes, enc_out, enc_mask)
        aux_total = aux_total + aux

    x = L.rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = L.matmul(x, head)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    logits = _shard(logits, mesh_axes, ("data", None, "model"))
    return logits, aux_total


def lm_loss(cfg: ArchConfig, params, tokens, labels,
            vision_embeds=None, audio_embeds=None, mesh_axes=None):
    logits, aux = forward(cfg, params, tokens, vision_embeds, audio_embeds,
                          mesh_axes)
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = jnp.mean(logz - gold)
    return nll + 0.01 * aux


# --------------------------------------------------------------------------
# Decode (serve_step): one new token against cached/recurrent state
# --------------------------------------------------------------------------

def init_decode_state(cfg: ArchConfig, batch: int, max_len: int,
                      dtype=None) -> Dict[str, Any]:
    """Allocate per-layer decode state. Attention layers hold KV caches
    (full length for global, `sliding_window` ring for local); recurrent
    layers hold O(1) state."""
    dt = dtype or _dtype(cfg)
    hkv, hd = cfg.n_kv_heads, cfg.hd
    states: List[Dict[str, Any]] = []
    for kind in cfg.blocks():
        if kind in (BlockKind.ATTN, BlockKind.MOE):
            states.append({
                "k": jnp.zeros((batch, hkv, max_len, hd), dt),
                "v": jnp.zeros((batch, hkv, max_len, hd), dt),
            })
        elif kind == BlockKind.LOCAL_ATTN:
            w = cfg.sliding_window or max_len
            w = min(w, max_len)
            states.append({
                "k": jnp.zeros((batch, hkv, w, hd), dt),
                "v": jnp.zeros((batch, hkv, w, hd), dt),
                "slot_pos": jnp.full((w,), -1, jnp.int32),
            })
        elif kind == BlockKind.MLSTM:
            states.append(R.mlstm_init_state(
                batch, cfg.n_heads, cfg.d_model // cfg.n_heads))
        elif kind == BlockKind.SLSTM:
            states.append(R.slstm_init_state(batch, cfg.d_model))
        elif kind == BlockKind.RGLRU:
            w = cfg.lru_width or cfg.d_model
            states.append({
                "h": R.rglru_init_state(batch, w),
                "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dt),
            })
    return {"pos": jnp.int32(0), "layers": states}


def _decode_attn(cfg, p, h, state, pos, window=None, ring=False):
    """One-token attention against a cache (ring=False) or a fixed-size
    ring buffer (ring=True, sliding-window layers)."""
    b = h.shape[0]
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (h @ p["wq"]).reshape(b, 1, hq, hd).transpose(0, 2, 1, 3)
    k_new = (h @ p["wk"]).reshape(b, 1, hkv, hd).transpose(0, 2, 1, 3)
    v_new = (h @ p["wv"]).reshape(b, 1, hkv, hd).transpose(0, 2, 1, 3)
    posb = jnp.full((b, 1), pos, jnp.int32)
    q = L.apply_rope(q, posb, cfg.rope_theta)
    k_new = L.apply_rope(k_new, posb, cfg.rope_theta)

    if not ring:
        k = jax.lax.dynamic_update_slice(
            state["k"], k_new.astype(state["k"].dtype), (0, 0, pos, 0))
        v = jax.lax.dynamic_update_slice(
            state["v"], v_new.astype(state["v"].dtype), (0, 0, pos, 0))
        kv_pos = jnp.arange(k.shape[2])
        valid = kv_pos <= pos
        new_state = {"k": k, "v": v}
    else:  # ring buffer
        w = state["k"].shape[2]
        slot = pos % w
        k = jax.lax.dynamic_update_slice(
            state["k"], k_new.astype(state["k"].dtype), (0, 0, slot, 0))
        v = jax.lax.dynamic_update_slice(
            state["v"], v_new.astype(state["v"].dtype), (0, 0, slot, 0))
        slot_pos = jax.lax.dynamic_update_slice(
            state["slot_pos"], jnp.array([pos], jnp.int32), (slot,))
        valid = (slot_pos >= 0) & (slot_pos <= pos)
        if window is not None:
            valid = valid & (slot_pos > pos - window)
        new_state = {"k": k, "v": v, "slot_pos": slot_pos}

    group = hq // hkv
    qg = q.reshape(b, hkv, group, hd)
    logits = jnp.einsum("bhgd,bhtd->bhgt", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / (hd ** 0.5)
    logits = L._softcap(logits, cfg.attn_softcap)
    logits = jnp.where(valid[None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgt,bhtd->bhgd", probs, v.astype(jnp.float32))
    out = out.reshape(b, 1, hq * hd).astype(h.dtype)
    return out @ p["wo"], new_state


def decode_step(cfg: ArchConfig, params, token: jnp.ndarray,
                state: Dict[str, Any],
                enc_out: Optional[jnp.ndarray] = None,
                mesh_axes=None) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """token (B, 1) int32 → (logits (B, 1, V), new state)."""
    b = token.shape[0]
    pos = state["pos"]
    x = params["embed"][token]
    kinds = cfg.blocks()
    new_layer_states = []
    for li, p in enumerate(params["layers"]):
        st = state["layers"][li]
        h = L.rms_norm(x, p["ln1"])
        kind = kinds[li]
        if kind in (BlockKind.ATTN, BlockKind.MOE, BlockKind.LOCAL_ATTN):
            window = cfg.sliding_window if kind == BlockKind.LOCAL_ATTN else None
            attn_out, new_st = _decode_attn(
                cfg, p["attn"], h, st, pos, window,
                ring=kind == BlockKind.LOCAL_ATTN)
            x = x + attn_out
            if enc_out is not None and "xattn" in p:
                hx = L.rms_norm(x, p["ln_x"])
                hkv, hd = cfg.n_kv_heads, cfg.hd
                ek = (enc_out @ p["xattn"]["wk"]).reshape(
                    b, -1, hkv, hd).transpose(0, 2, 1, 3)
                ev = (enc_out @ p["xattn"]["wv"]).reshape(
                    b, -1, hkv, hd).transpose(0, 2, 1, 3)
                posb = jnp.full((b, 1), pos, jnp.int32)
                cross_out, _ = L.attention(cfg, p["xattn"], hx, posb,
                                           cross_kv=(ek, ev))
                x = x + cross_out
            h2 = L.rms_norm(x, p["ln2"])
            if kind == BlockKind.MOE:
                ffn_out, _ = L.moe_ffn(cfg, p["moe"], h2)
            elif "mlp" in p:
                ffn_out = L.mlp(p["mlp"], h2)
            else:
                ffn_out = jnp.zeros_like(x)
            x = x + ffn_out
        elif kind == BlockKind.MLSTM:
            y, new_st = R.mlstm_step(p["mlstm"], h, st, cfg.n_heads)
            x = x + y
            if "mlp" in p:
                x = x + L.mlp(p["mlp"], L.rms_norm(x, p["ln2"]))
        elif kind == BlockKind.SLSTM:
            y, new_st = R.slstm_step(p["slstm"], h, st)
            x = x + y
            if "mlp" in p:
                x = x + L.mlp(p["mlp"], L.rms_norm(x, p["ln2"]))
        elif kind == BlockKind.RGLRU:
            rp = p["rec"]
            gate = jax.nn.gelu(h @ rp["w_branch_gate"])
            lin = h @ rp["w_branch_lin"]
            lin, conv_st = R.temporal_conv_step(rp, lin, st["conv"],
                                                cfg.conv_width)
            rec, h_st = R.rglru_step(rp, lin, st["h"])
            new_st = {"h": h_st, "conv": conv_st}
            x = x + (gate * rec) @ rp["w_out"]
            if "mlp" in p:
                x = x + L.mlp(p["mlp"], L.rms_norm(x, p["ln2"]))
        new_layer_states.append(new_st)

    x = L.rms_norm(x, params["final_norm"])
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = L.matmul(x, head)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits, {"pos": pos + 1, "layers": new_layer_states}
