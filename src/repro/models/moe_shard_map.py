"""Expert-parallel MoE dispatch with explicit all-to-all (shard_map).

§Perf next-iteration module (EXPERIMENTS §Perf): the GSPMD dispatch in
layers.moe_ffn routes tokens through a logically-global (E·C, d) gather
that XLA materializes per device (~0.5 TiB/chip on kimi-k2 train). This
version makes the routing explicit per device:

  1. tokens live on (data, model)-sharded devices; experts are partitioned
     over the model axis (E_loc = E / |model| per rank);
  2. each device routes its local tokens, compacts them into per-destination
     buffers (n_model, cap, d) with the same histogram-rank trick;
  3. one `all_to_all` over the model axis delivers each rank the tokens for
     ITS experts; local batched FFN; a second all_to_all returns outputs;
  4. combine with the saved top-k weights.

Dispatch memory is bounded by n_model × cap_local × d per device
(~0.3 GiB/chip/layer on kimi-k2) and the wire cost is exactly two
all-to-alls of that buffer — the GShard schedule.

Requires E % |model axis| == 0 (kimi-k2: 384 % 16 ✓); callers fall back to
layers.moe_ffn otherwise (mixtral's 8 experts on 16-way TP keep the
tensor-parallel-inside-expert path).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.compat import axis_size
from repro.models.config import ArchConfig


def _rank_in_group(group_ids: jnp.ndarray, n_groups: int) -> jnp.ndarray:
    """Rank of each element within its group (histogram + sorted-order)."""
    n = group_ids.shape[0]
    order = jnp.argsort(group_ids, stable=True)
    hist = jnp.bincount(group_ids, length=n_groups)
    starts = jnp.cumsum(hist) - hist
    ranks_sorted = jnp.arange(n) - starts[group_ids[order]]
    return jnp.zeros_like(ranks_sorted).at[order].set(ranks_sorted)


def _local_moe(cfg: ArchConfig, p, xf, model_axis: str):
    """Per-device body (runs inside shard_map over the model axis).

    xf: (t_loc, d) local tokens; p: expert weights with E_loc experts local
    plus a replicated router.
    """
    t, d = xf.shape
    e, k = cfg.n_experts, cfg.top_k
    n_ranks = axis_size(model_axis)
    e_loc = e // n_ranks

    probs = jax.nn.softmax(
        (xf @ p["w_router"]).astype(jnp.float32), axis=-1)      # (t, E)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, e, dtype=jnp.float32), axis=1), axis=0)
    aux = e * jnp.sum(me * ce) / k

    flat_e = top_e.reshape(-1)                                   # (t·k,)
    flat_w = top_p.reshape(-1)
    tok_id = jnp.repeat(jnp.arange(t), k)
    dest = flat_e // e_loc                                       # model rank
    # capacity per destination rank (static): tokens*k spread over ranks
    cap = max(1, int(cfg.capacity_factor * t * k / n_ranks))
    cap = ((cap + 7) // 8) * 8

    pos = _rank_in_group(dest, n_ranks)
    keep = pos < cap
    slot = jnp.where(keep, dest * cap + pos, n_ranks * cap)

    send_x = jnp.zeros((n_ranks * cap, d), xf.dtype).at[slot].set(
        xf[tok_id], mode="drop").reshape(n_ranks, cap, d)
    send_eid = jnp.full((n_ranks * cap,), 0, jnp.int32).at[slot].set(
        (flat_e % e_loc).astype(jnp.int32), mode="drop").reshape(n_ranks, cap)
    send_valid = jnp.zeros((n_ranks * cap,), jnp.bool_).at[slot].set(
        keep, mode="drop").reshape(n_ranks, cap)

    # Exchange: rank r receives, from every peer, tokens for r's experts.
    recv_x = jax.lax.all_to_all(send_x, model_axis, 0, 0, tiled=False)
    recv_eid = jax.lax.all_to_all(send_eid, model_axis, 0, 0, tiled=False)
    recv_valid = jax.lax.all_to_all(send_valid, model_axis, 0, 0, tiled=False)

    rx = recv_x.reshape(n_ranks * cap, d)
    reid = recv_eid.reshape(-1)
    rvalid = recv_valid.reshape(-1)

    # Batched local expert FFN via per-expert gather of weights: for each
    # incoming token select its expert's weights (E_loc small per rank).
    wg = p["w_gate"]                                             # (E_loc,d,f)
    wu = p["w_up"]
    wd = p["w_down"]
    h = jax.nn.silu(jnp.einsum("td,tdf->tf", rx, wg[reid])) * \
        jnp.einsum("td,tdf->tf", rx, wu[reid])
    out_tok = jnp.einsum("tf,tfd->td", h, wd[reid])
    out_tok = jnp.where(rvalid[:, None], out_tok, 0).astype(xf.dtype)

    # Return outputs to the senders.
    back = jax.lax.all_to_all(out_tok.reshape(n_ranks, cap, d),
                              model_axis, 0, 0, tiled=False)
    back = back.reshape(n_ranks * cap, d)

    gathered = back[jnp.clip(slot, 0, n_ranks * cap - 1)] * \
        (flat_w * keep)[:, None].astype(xf.dtype)
    out = jnp.zeros((t, d), xf.dtype).at[tok_id].add(gathered)
    return out, aux


def moe_ffn_shard_map(cfg: ArchConfig, p: Dict[str, jnp.ndarray],
                      x: jnp.ndarray, mesh, data_axes: Tuple[str, ...],
                      model_axis: str = "model"):
    """x (B, S, D) → (out, aux). Expert weights must be (E, d, f) arrays;
    they are consumed model-axis-sharded on dim 0 inside shard_map."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    b, s, d = x.shape
    assert cfg.n_experts % mesh.shape[model_axis] == 0, \
        "E must divide the model axis; use layers.moe_ffn otherwise"

    def body(xl, wr, wg, wu, wd):
        t_loc = xl.shape[0] * xl.shape[1]
        out, aux = _local_moe(
            cfg, {"w_router": wr, "w_gate": wg, "w_up": wu, "w_down": wd},
            xl.reshape(t_loc, d), model_axis)
        aux = jax.lax.pmean(aux, model_axis)
        for ax in data_axes:
            aux = jax.lax.pmean(aux, ax)
        return out.reshape(xl.shape), aux

    out, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(data_axes, None, None), P(None, None),
                  P(model_axis, None, None), P(model_axis, None, None),
                  P(model_axis, None, None)),
        out_specs=(P(data_axes, None, None), P()),
        check_rep=False,
    )(x, p["w_router"], p["w_gate"], p["w_up"], p["w_down"])
    return out, aux
