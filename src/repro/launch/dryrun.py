import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first — jax locks the device count on first
backend init, and only the dry-run wants 512 placeholder devices.

Per cell this:
  1. builds the full-size config and abstract (ShapeDtypeStruct) params /
     optimizer / decode-state trees — no allocation anywhere;
  2. jits the step (train_step / prefill_step / serve_step) with the
     sharding rules from repro.launch.sharding, lowers against
     input_specs(), compiles, and prints memory_analysis + cost_analysis;
  3. compiles the scan-unit body standalone and composes exact totals
     (module + (R-1) × body — XLA counts while bodies once, trip counts are
     known statically here);
  4. parses per-device collective bytes out of the HLO for the roofline's
     third term, and writes everything to results/dryrun/<cell>.json.

Usage:
  python -m repro.launch.dryrun --arch yi_6b --shape train_4k --mesh multi
  python -m repro.launch.dryrun --all [--mesh both] [--out results/dryrun]
"""
import argparse
import functools
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, arch_ids, get_config, shape_applicable
from repro.launch.hlo_analysis import collective_bytes, collective_count
from repro.kernels.compat import use_mesh
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (
    batch_pspec, opt_state_pspecs, state_pspecs, tree_pspecs,
)
from repro.launch.specs import input_specs
from repro.models.stacked import (
    _unit_apply, forward_scan, group_split, init_decode_state_stacked,
    init_params_stacked, lm_loss_scan, decode_step_scan, unit_kinds,
)
from repro.models.transformer import MESH_AXES_MULTI, MESH_AXES_SINGLE
from repro.train.optim import make_optimizer

ADAFACTOR_THRESHOLD = 100e9  # params above this use factored moments


def _mesh_axes(multi_pod: bool):
    return MESH_AXES_MULTI if multi_pod else MESH_AXES_SINGLE


def _param_count(tree) -> int:
    return sum(int(x.size if hasattr(x, "size") else 0)
               for x in jax.tree_util.tree_leaves(tree))


def _sh(mesh, spec):
    return NamedSharding(mesh, spec)


def _tree_sh(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))


def _analyze(lowered, compiled) -> Dict[str, Any]:
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    text = compiled.as_text()
    coll_total, coll_kinds = collective_bytes(text)
    return {
        "memory": {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
        },
        "cost": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        },
        "collectives": {"bytes": int(coll_total), "by_kind": coll_kinds,
                        "count": collective_count(text)},
    }


def _body_cost(cfg, mesh, mesh_axes, shape, kind: str, abs_params,
               abs_state=None, fsdp: bool = False):
    """Compile one scan unit standalone → per-iteration cost/collectives."""
    u_kinds = unit_kinds(cfg)
    b = shape["global_batch"]
    s = shape["seq_len"] if kind != "decode" else 1
    act_dt = jnp.dtype(cfg.dtype)

    abs_unit = [jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), g)
        for g in abs_params["scan"]]
    unit_specs = [tree_pspecs(u, mesh, fsdp=fsdp) for u in abs_unit]

    x_sds = jax.ShapeDtypeStruct((b, s, cfg.d_model), act_dt)
    # Activations are replicated across the model axis between blocks
    # (§Perf iteration 2) — the body probe must match or it measures
    # spurious boundary re-sharding.
    x_spec = P(batch_pspec((b, s, cfg.d_model), mesh)[0], None, None)
    if cfg.mrope_sections is not None:
        pos_sds = jax.ShapeDtypeStruct((3, b, shape["seq_len"]), jnp.int32)
        pos_spec = P(None, batch_pspec((b,), mesh)[0], None)
    else:
        pos_sds = jax.ShapeDtypeStruct((b, s), jnp.int32)
        pos_spec = batch_pspec((b, s), mesh)

    enc_args = ()
    enc_in_sh = ()
    if cfg.is_enc_dec and kind != "decode":
        enc_sds = jax.ShapeDtypeStruct((b, cfg.audio_frames, cfg.d_model), act_dt)
        enc_spec = batch_pspec((b, cfg.audio_frames, cfg.d_model), mesh)
        enc_args = (enc_sds,)
        enc_in_sh = (_sh(mesh, enc_spec),)

    if kind == "train":
        def body(x, ct, positions, *rest):
            enc_out = rest[-1] if cfg.is_enc_dec else None
            unit = rest[: len(abs_unit)]
            f = lambda x_, unit_: _unit_apply(
                cfg, u_kinds, unit_, x_, positions, mesh_axes, enc_out)[0]
            y, pull = jax.vjp(f, x, tuple(unit))
            dx, dunit = pull(ct)
            return y, dx, dunit

        args = (x_sds, x_sds, pos_sds, *abs_unit, *enc_args)
        in_sh = (_sh(mesh, x_spec), _sh(mesh, x_spec), _sh(mesh, pos_spec),
                 *[_tree_sh(mesh, sp) for sp in unit_specs], *enc_in_sh)
    elif kind == "prefill":
        def body(x, positions, *rest):
            enc_out = rest[-1] if cfg.is_enc_dec else None
            unit = rest[: len(abs_unit)]
            return _unit_apply(cfg, u_kinds, tuple(unit), x, positions,
                               mesh_axes, enc_out)[0]

        args = (x_sds, pos_sds, *abs_unit, *enc_args)
        in_sh = (_sh(mesh, x_spec), _sh(mesh, pos_spec),
                 *[_tree_sh(mesh, sp) for sp in unit_specs], *enc_in_sh)
    else:  # decode: one unit step against stacked-state slice
        abs_unit_state = [jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), g)
            for g in abs_state["scan"]]
        state_specs = [state_pspecs(st, mesh) for st in abs_unit_state]

        def body(x, pos, *rest):
            unit = rest[: len(abs_unit)]
            states = rest[len(abs_unit):]
            from repro.models.stacked import decode_step_scan  # noqa
            # apply one unit (same code path as the scan body)
            from repro.models.stacked import BlockKind  # noqa
            x_ = x
            new_states = []
            # reuse the scan body's per-layer application
            for j, k_ in enumerate(u_kinds):
                x_, ns = _decode_apply_one(cfg, k_, unit[j], states[j], x_,
                                           pos)
                new_states.append(ns)
            return x_, tuple(new_states)

        x1 = jax.ShapeDtypeStruct((b, 1, cfg.d_model), act_dt)
        x1_spec = P(batch_pspec((b,), mesh)[0], None, None)
        pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
        args = (x1, pos_sds, *abs_unit, *abs_unit_state)
        in_sh = (_sh(mesh, x1_spec), _sh(mesh, P()),
                 *[_tree_sh(mesh, sp) for sp in unit_specs],
                 *[_tree_sh(mesh, sp) for sp in state_specs])

    with use_mesh(mesh):
        lowered = jax.jit(body, in_shardings=in_sh).lower(*args)
        compiled = lowered.compile()
    return _analyze(lowered, compiled)


def _decode_apply_one(cfg, kind, p, st, x, pos):
    """Single-layer decode application shared with decode_step_scan."""
    from repro.models.stacked import decode_step_scan  # circular-safe
    from repro.models import layers as L
    from repro.models import recurrent as R_
    from repro.models.config import BlockKind
    from repro.models.transformer import _decode_attn

    h = L.rms_norm(x, p["ln1"])
    if kind in (BlockKind.ATTN, BlockKind.MOE, BlockKind.LOCAL_ATTN):
        window = cfg.sliding_window if kind == BlockKind.LOCAL_ATTN else None
        attn_out, new_st = _decode_attn(cfg, p["attn"], h, st, pos, window,
                                        ring=kind == BlockKind.LOCAL_ATTN)
        x = x + attn_out
        h2 = L.rms_norm(x, p["ln2"])
        if kind == BlockKind.MOE:
            ffn_out, _ = L.moe_ffn(cfg, p["moe"], h2)
        elif "mlp" in p:
            ffn_out = L.mlp(p["mlp"], h2)
        else:
            ffn_out = jnp.zeros_like(x)
        x = x + ffn_out
    elif kind == BlockKind.MLSTM:
        y, new_st = R_.mlstm_step(p["mlstm"], h, st, cfg.n_heads)
        x = x + y
        if "mlp" in p:
            x = x + L.mlp(p["mlp"], L.rms_norm(x, p["ln2"]))
    elif kind == BlockKind.SLSTM:
        y, new_st = R_.slstm_step(p["slstm"], h, st)
        x = x + y
        if "mlp" in p:
            x = x + L.mlp(p["mlp"], L.rms_norm(x, p["ln2"]))
    else:  # RGLRU
        rp = p["rec"]
        gate = jax.nn.gelu(h @ rp["w_branch_gate"])
        lin = h @ rp["w_branch_lin"]
        lin, conv_st = R_.temporal_conv_step(rp, lin, st["conv"], cfg.conv_width)
        rec, h_st = R_.rglru_step(rp, lin, st["h"])
        new_st = {"h": h_st, "conv": conv_st}
        x = x + (gate * rec) @ rp["w_out"]
        if "mlp" in p:
            x = x + L.mlp(p["mlp"], L.rms_norm(x, p["ln2"]))
    return x, new_st


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             body_costs: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_axes = _mesh_axes(multi_pod)
    kind = shape["kind"]
    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": kind, "ok": False,
    }

    runs, reason = shape_applicable(arch, shape_name)
    if not runs:
        result["skipped"] = reason
        return result

    t0 = time.time()
    abs_params = jax.eval_shape(
        functools.partial(init_params_stacked, cfg), jax.random.PRNGKey(0))
    n_params = _param_count(abs_params)
    result["params"] = n_params
    # FSDP only when bf16 params can't replicate across the data axis
    # (per-device share with TP-16 would blow HBM); smaller models keep
    # params TP-only + ZeRO-1 optimizer sharding — far fewer collectives.
    fsdp = n_params > 30e9
    result["fsdp"] = fsdp
    param_specs = tree_pspecs(abs_params, mesh, fsdp=fsdp)
    specs = input_specs(cfg, shape)
    r, rem = group_split(cfg)
    result["scan_repeats"] = r

    if kind == "train":
        opt_name = "adafactor" if n_params > ADAFACTOR_THRESHOLD else "adamw"
        result["optimizer"] = opt_name
        opt_init, opt_update = make_optimizer(opt_name, lr=1e-4)
        abs_opt = jax.eval_shape(opt_init, abs_params)
        opt_specs = opt_state_pspecs(abs_opt, param_specs, mesh)

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(
                lambda p: lm_loss_scan(
                    cfg, p, batch["tokens"], batch["labels"],
                    vision_embeds=batch.get("vision_embeds"),
                    audio_embeds=batch.get("audio_embeds"),
                    mesh_axes=mesh_axes))(params)
            params, opt_state = opt_update(params, grads, opt_state)
            return loss, params, opt_state

        batch_specs = {k: batch_pspec(v.shape, mesh) for k, v in specs.items()}
        in_sh = (_tree_sh(mesh, param_specs), _tree_sh(mesh, opt_specs),
                 {k: _sh(mesh, s) for k, s in batch_specs.items()})
        out_sh = (_sh(mesh, P()), _tree_sh(mesh, param_specs),
                  _tree_sh(mesh, opt_specs))
        step = jax.jit(train_step, in_shardings=in_sh, out_shardings=out_sh)
        args = (abs_params, abs_opt, specs)

    elif kind == "prefill":
        def prefill_step(params, batch):
            logits, _ = forward_scan(
                cfg, params, batch["tokens"],
                vision_embeds=batch.get("vision_embeds"),
                audio_embeds=batch.get("audio_embeds"),
                mesh_axes=mesh_axes, last_only=True)
            return logits

        batch_specs = {k: batch_pspec(v.shape, mesh) for k, v in specs.items()}
        in_sh = (_tree_sh(mesh, param_specs),
                 {k: _sh(mesh, s) for k, s in batch_specs.items()})
        step = jax.jit(prefill_step, in_shardings=in_sh)
        args = (abs_params, specs)

    else:  # decode
        abs_state = jax.eval_shape(
            functools.partial(init_decode_state_stacked, cfg,
                              shape["global_batch"], shape["seq_len"]))
        st_specs = state_pspecs(abs_state, mesh)

        def serve_step(params, token, state, enc_out=None):
            return decode_step_scan(cfg, params, token, state,
                                    enc_out=enc_out, mesh_axes=mesh_axes)

        tok_spec = batch_pspec(specs["token"].shape, mesh)
        in_sh = [_tree_sh(mesh, param_specs), _sh(mesh, tok_spec),
                 _tree_sh(mesh, st_specs)]
        args = [abs_params, specs["token"], abs_state]
        if cfg.is_enc_dec:
            in_sh.append(_sh(mesh, batch_pspec(specs["enc_out"].shape, mesh)))
            args.append(specs["enc_out"])
        step = jax.jit(serve_step, in_shardings=tuple(in_sh))
        args = tuple(args)

    try:
        with use_mesh(mesh):
            t_l = time.time()
            lowered = step.lower(*args)
            result["lower_s"] = round(time.time() - t_l, 2)
            t_c = time.time()
            compiled = lowered.compile()
            result["compile_s"] = round(time.time() - t_c, 2)
            print(compiled.memory_analysis())   # proves it fits
            print(compiled.cost_analysis())     # FLOPs/bytes for §Roofline
        result.update(_analyze(lowered, compiled))

        if body_costs:
            abs_state = (jax.eval_shape(
                functools.partial(init_decode_state_stacked, cfg,
                                  shape["global_batch"], shape["seq_len"]))
                if kind == "decode" else None)
            body = _body_cost(cfg, mesh, mesh_axes, shape, kind,
                              abs_params, abs_state, fsdp=fsdp)
            result["body"] = body
            # exact totals: module counts each scan body once
            mult = max(r - 1, 0)
            result["total_flops"] = (result["cost"]["flops"]
                                     + mult * body["cost"]["flops"])
            result["total_bytes_accessed"] = (
                result["cost"]["bytes_accessed"]
                + mult * body["cost"]["bytes_accessed"])
            result["total_collective_bytes"] = (
                result["collectives"]["bytes"]
                + mult * body["collectives"]["bytes"])
        result["ok"] = True
    except Exception as err:  # noqa: BLE001
        result["error"] = f"{type(err).__name__}: {err}"
        result["traceback"] = traceback.format_exc()[-2000:]
    result["elapsed_s"] = round(time.time() - t0, 2)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-body", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    archs = arch_ids() if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cells.append((arch, shape, mp))

    for arch, shape, mp in cells:
        name = f"{arch}__{shape}__{'multi' if mp else 'single'}"
        path = os.path.join(args.out, name + ".json")
        if os.path.exists(path) and not args.force:
            print(f"[skip] {name} (exists)")
            continue
        print(f"[run ] {name}", flush=True)
        res = run_cell(arch, shape, mp, body_costs=not args.no_body)
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        status = ("OK" if res.get("ok")
                  else ("SKIP: " + res["skipped"]) if "skipped" in res
                  else "FAIL: " + res.get("error", "?"))
        print(f"[done] {name}: {status} ({res.get('elapsed_s', 0)}s)",
              flush=True)


if __name__ == "__main__":
    main()
