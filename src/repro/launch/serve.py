"""Serving launcher: batched decode with KV/recurrent state, or GCN serving.

`serve(cfg, params, prompts, steps)` prefRuns a prefill then `steps` decode
iterations for a batch of requests; the same serve_step is what the
dry-run lowers at decode_32k / long_500k shapes.

`--mode gcn` instead drives the out-of-core GCN serving engine
(repro.runtime.engine): registered graphs, queued requests, batched
streamed aggregation with the tiered segment cache — prints per-epoch
uploaded vs cache-hit wire bytes.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import (
    init_params, encode, init_decode_state, decode_step,
)


def serve(cfg, params, prompts: np.ndarray, steps: int = 8):
    """prompts (B, S0) int32 → generated tokens (B, steps)."""
    b, s0 = prompts.shape
    state = init_decode_state(cfg, b, max_len=s0 + steps + 1)
    enc_out = None
    if cfg.is_enc_dec:
        audio = jnp.zeros((b, cfg.audio_frames, cfg.d_model), jnp.float32)
        enc_out = encode(cfg, params, audio)

    # Prefill token-by-token through the decode path (teacher-forced) —
    # keeps one compiled step; a chunked prefill is the production variant.
    step_fn = jax.jit(lambda p, t, st: decode_step(cfg, p, t, st,
                                                   enc_out=enc_out))
    logits = None
    for t in range(s0):
        logits, state = step_fn(params, jnp.asarray(prompts[:, t:t+1]), state)
    out = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    for _ in range(steps):
        out.append(np.asarray(tok)[:, 0])
        logits, state = step_fn(params, tok, state)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return np.stack(out, axis=1)


def serve_gcn(scale: float = 1e-4, batch: int = 4, epochs: int = 2,
              cache: bool = True, feature_dim: int = 16, seed: int = 0,
              cache_shards: int = 1, workers: int = 1,
              passes: bool = False, calibrate: bool = False,
              autotune: bool = False, summary_out=None):
    """Drive the multi-graph GCN serving engine; returns per-epoch reports.

    `cache_shards > 1` partitions each worker's cache device tier across
    shards (remote hits ride ICI); `workers > 1` runs replicated engines
    against the same graphs with a shared `CacheDirectory`, so one worker's
    demoted bricks serve the others' misses. With one worker the reports
    are a flat per-epoch list (back-compat); with several, a list of
    per-epoch lists, one report per worker.

    `passes` routes every batch through the plan-rewrite pipeline
    (repro.core.passes): shard-aware brick placement, transfer coalescing
    and earliest-deadline-first batch ordering.

    `calibrate` attaches a `CostCalibrator` to every worker: each batch's
    `RequestLatency` stream refits the cost model, so later epochs price
    against the calibrated spec. `autotune` runs the schedule autotuner
    per graph after the first epoch and installs the winners. A caller
    dict in `summary_out` receives per-epoch calibrated vs uncalibrated
    mean |error| and the installed `TunedSchedule` descriptions.
    """
    from repro.data import (
        SUITESPARSE_SPECS, generate_graph, normalized_adjacency, scaled_spec,
    )
    from repro.io import CacheDirectory
    from repro.runtime import EngineConfig, InferenceRequest, ServingEngine

    from repro.core import (
        CostCalibrator, EDFOrderingPass, ShardPlacementPass,
        TransferCoalescingPass, plan_memory_dense_features,
    )

    rng = np.random.default_rng(seed)
    graphs = {
        name: normalized_adjacency(generate_graph(
            scaled_spec(SUITESPARSE_SPECS[name], scale), seed=i))
        for i, name in enumerate(("socLJ1", "rUSA"))
    }
    # Feasible for the engine's pinned plan width (64), small enough that
    # streaming still splits into several segments per graph.
    budget = max(
        int(est.m_b + est.m_c + 0.6 * a.nbytes())
        for a in graphs.values()
        for est in [plan_memory_dense_features(a, a.n_rows, 64,
                                               float("inf"))])
    directory = CacheDirectory() if workers > 1 else None
    plan_passes = ([ShardPlacementPass(), TransferCoalescingPass(),
                    EDFOrderingPass()] if passes else None)
    engines = []
    for wid in range(workers):
        eng = ServingEngine(
            EngineConfig(device_budget_bytes=budget, cache_enabled=cache,
                         cache_shards=cache_shards, worker_id=wid,
                         plan_passes=plan_passes,
                         calibrator=CostCalibrator() if calibrate else None),
            directory=directory)
        for name, a in graphs.items():
            eng.register_graph(name, a)
        engines.append(eng)

    # Fixed-spec baseline predictions for the calibration comparison: one
    # template request per graph, priced against the *uncalibrated*
    # tier_spec (spec= bypasses the calibrated memo).
    uncal_cost = {}
    if calibrate:
        for name, a in graphs.items():
            h0 = np.zeros((a.n_rows, feature_dim), np.float32)
            w0 = [np.zeros((feature_dim, feature_dim), np.float32)]
            uncal_cost[name] = engines[0].estimate_request_cost(
                InferenceRequest(name, h0, w0),
                spec=engines[0].config.tier_spec)

    epoch_errors = []  # (calibrated mean |err|, uncalibrated mean |err|)
    reports = []
    for epoch in range(epochs):
        epoch_reports = []
        for eng in engines:
            for name, a in graphs.items():
                for _ in range(batch):
                    h = rng.standard_normal(
                        (a.n_rows, feature_dim)).astype(np.float32)
                    w = [rng.standard_normal(
                        (feature_dim, feature_dim)).astype(np.float32)]
                    eng.submit(InferenceRequest(name, h, w))
            epoch_reports.append(eng.run_batch())
        if calibrate:
            lats = [l for r in epoch_reports for l in r.request_latency]
            if lats:
                epoch_errors.append((
                    sum(abs(l.error_s) for l in lats) / len(lats),
                    sum(abs(l.processing_s - uncal_cost[l.graph])
                        for l in lats) / len(lats)))
        if autotune and epoch == 0:
            for eng in engines:
                for name in graphs:
                    eng.autotune(name, install=True)
        reports.append(epoch_reports[0] if workers == 1 else epoch_reports)
    if summary_out is not None:
        summary_out["epoch_errors"] = epoch_errors
        summary_out["installed_schedules"] = {
            name: tuned.describe()
            for name, tuned in engines[0].installed_schedules.items()}
    return reports


def serve_continuous(scale: float = 1e-4, trace: str = "poisson",
                     requests: int = 24, seed: int = 0,
                     feature_dim: int = 16):
    """Replay an arrival trace through the continuous step loop.

    Builds the same two-graph engine as `serve_gcn` but on a shared
    `VirtualClock`, generates a Poisson or Gamma-modulated bursty trace
    whose rate and deadlines are quoted in units of one modeled pass,
    and streams it through a `ContinuousServer`. Returns the
    `(ServeReport, summary_dict)` pair."""
    from repro.data import (
        SUITESPARSE_SPECS, generate_graph, normalized_adjacency, scaled_spec,
    )
    from repro.runtime import (
        ContinuousServer, EngineConfig, InferenceRequest, ServingEngine,
        VirtualClock, bursty_trace, poisson_trace, replay_continuous,
        summarize,
    )
    from repro.core import EDFOrderingPass, plan_memory_dense_features

    rng = np.random.default_rng(seed)
    graphs = {
        name: normalized_adjacency(generate_graph(
            scaled_spec(SUITESPARSE_SPECS[name], scale), seed=i))
        for i, name in enumerate(("socLJ1", "rUSA"))
    }
    budget = max(
        int(est.m_b + est.m_c + 0.6 * a.nbytes())
        for a in graphs.values()
        for est in [plan_memory_dense_features(a, a.n_rows, 64,
                                               float("inf"))])
    clock = VirtualClock()
    eng = ServingEngine(EngineConfig(
        device_budget_bytes=budget, clock=clock,
        plan_passes=[EDFOrderingPass(clock=clock)]))
    for name, a in graphs.items():
        eng.register_graph(name, a)

    feats = {name: rng.standard_normal(
        (a.n_rows, feature_dim)).astype(np.float32)
        for name, a in graphs.items()}
    weights = rng.standard_normal(
        (feature_dim, feature_dim)).astype(np.float32)
    unit = eng.estimate_request_cost(
        InferenceRequest("socLJ1", feats["socLJ1"], [weights]))
    maker = poisson_trace if trace == "poisson" else bursty_trace
    rate_key = "rate_hz" if trace == "poisson" else "base_rate_hz"
    arrivals = maker(n=requests, graphs=sorted(graphs), seed=seed,
                     feature_dim=feature_dim, deadline_s=3.0 * unit,
                     **{rate_key: 1.5 / unit})

    def make_request(arr):
        return InferenceRequest(arr.graph, feats[arr.graph], [weights],
                                deadline_s=arr.deadline_s)

    report = replay_continuous(ContinuousServer(eng), arrivals, make_request)
    return report, summarize(report)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("lm", "gcn", "continuous"),
                    default="lm")
    ap.add_argument("--arch")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--no-cache", action="store_true",
                    help="gcn mode: disable the tiered segment cache")
    ap.add_argument("--cache-shards", type=int, default=1,
                    help="gcn mode: partition the cache device tier over "
                         "this many mesh shards (remote hits ride ICI)")
    ap.add_argument("--workers", type=int, default=1,
                    help="gcn mode: replicated serving workers sharing a "
                         "CacheDirectory (dedups demotion copies)")
    ap.add_argument("--passes", action="store_true",
                    help="gcn mode: route batches through the plan-rewrite "
                         "pipeline (shard placement, transfer coalescing, "
                         "EDF batch ordering)")
    ap.add_argument("--calibrate", action="store_true",
                    help="gcn mode: fit the cost model online from each "
                         "batch's latency stream and reprice against it")
    ap.add_argument("--autotune", action="store_true",
                    help="gcn mode: autotune + install the plan schedule "
                         "per graph after the first epoch")
    ap.add_argument("--trace", choices=("poisson", "bursty"),
                    default="poisson",
                    help="continuous mode: arrival process to replay")
    ap.add_argument("--requests", type=int, default=24,
                    help="continuous mode: number of arrivals in the trace")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.mode == "continuous":
        _, summary = serve_continuous(trace=args.trace,
                                      requests=args.requests,
                                      seed=args.seed)
        print(f"{args.trace} trace: {summary['served']}/{summary['offered']} "
              f"served in {summary['groups_served']} groups, "
              f"{summary['on_time']} on time "
              f"(miss rate {summary['deadline_miss_rate']:.0%}); "
              f"p50 {summary['p50_latency_s']*1e3:.2f} ms, "
              f"p99 {summary['p99_latency_s']*1e3:.2f} ms, "
              f"goodput {summary['goodput_rps']:.1f} req/s; "
              f"uploaded {summary['uploaded_bytes']} B, "
              f"cache-hit {summary['cache_hit_bytes']} B")
        return

    if args.mode == "gcn":
        summary = {}
        reports = serve_gcn(batch=args.batch, epochs=args.epochs,
                            cache=not args.no_cache,
                            cache_shards=args.cache_shards,
                            workers=args.workers, passes=args.passes,
                            calibrate=args.calibrate,
                            autotune=args.autotune, summary_out=summary)
        for e, rep in enumerate(reports):
            for wid, r in enumerate(rep if isinstance(rep, list) else [rep]):
                lat = r.request_latency
                err = (sum(abs(l.error_s) for l in lat) / len(lat)
                       if lat else 0.0)
                print(f"epoch {e} worker {wid}: {len(r.results)} requests, "
                      f"{r.aggregation_passes} streamed passes, "
                      f"uploaded {r.uploaded_bytes} B, "
                      f"cache-hit {r.cache_hit_bytes} B "
                      f"(promoted {r.promoted_bytes} B, "
                      f"ici {r.ici_bytes} B, "
                      f"peer-served {r.directory_hit_bytes} B, "
                      f"dup-avoided {r.duplicate_avoided_bytes} B, "
                      f"hit rate {r.hit_rate:.0%}) in {r.wall_seconds:.2f}s; "
                      f"mean |predicted-actual| {err*1e3:.2f} ms")
        for e, (cal_err, uncal_err) in enumerate(
                summary.get("epoch_errors", [])):
            print(f"epoch {e}: calibrated mean |err| {cal_err*1e3:.2f} ms "
                  f"vs uncalibrated {uncal_err*1e3:.2f} ms")
        for name, desc in summary.get("installed_schedules", {}).items():
            print(f"installed {desc}")
        return

    if args.arch is None:
        ap.error("--arch is required in lm mode")
    cfg = get_config(args.arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, size=(args.batch, args.prompt_len), dtype=np.int32)
    t0 = time.perf_counter()
    tokens = serve(cfg, params, prompts, steps=args.steps)
    dt = time.perf_counter() - t0
    print(f"generated {tokens.shape} in {dt:.2f}s "
          f"({args.batch * args.steps / dt:.1f} tok/s)")
    print(tokens)


if __name__ == "__main__":
    main()
