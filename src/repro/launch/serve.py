"""Serving launcher: batched decode with KV/recurrent state.

`serve(cfg, params, prompts, steps)` prefRuns a prefill then `steps` decode
iterations for a batch of requests; the same serve_step is what the
dry-run lowers at decode_32k / long_500k shapes.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import (
    init_params, forward, encode, init_decode_state, decode_step,
)


def serve(cfg, params, prompts: np.ndarray, steps: int = 8):
    """prompts (B, S0) int32 → generated tokens (B, steps)."""
    b, s0 = prompts.shape
    state = init_decode_state(cfg, b, max_len=s0 + steps + 1)
    enc_out = None
    if cfg.is_enc_dec:
        audio = jnp.zeros((b, cfg.audio_frames, cfg.d_model), jnp.float32)
        enc_out = encode(cfg, params, audio)

    # Prefill token-by-token through the decode path (teacher-forced) —
    # keeps one compiled step; a chunked prefill is the production variant.
    step_fn = jax.jit(lambda p, t, st: decode_step(cfg, p, t, st,
                                                   enc_out=enc_out))
    logits = None
    for t in range(s0):
        logits, state = step_fn(params, jnp.asarray(prompts[:, t:t+1]), state)
    out = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    for _ in range(steps):
        out.append(np.asarray(tok)[:, 0])
        logits, state = step_fn(params, tok, state)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return np.stack(out, axis=1)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--steps", type=int, default=8)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, size=(args.batch, args.prompt_len), dtype=np.int32)
    t0 = time.perf_counter()
    tokens = serve(cfg, params, prompts, steps=args.steps)
    dt = time.perf_counter() - t0
    print(f"generated {tokens.shape} in {dt:.2f}s "
          f"({args.batch * args.steps / dt:.1f} tok/s)")
    print(tokens)


if __name__ == "__main__":
    main()
