"""Collective-traffic extraction from lowered/compiled HLO text.

cost_analysis() has no collective-bytes entry, so the roofline's third term
comes from parsing the (per-device, post-SPMD-partitioning) HLO: sum the
result-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute. Async pairs (-start/-done) are counted
once via the -start op.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# `%name = <shape-or-tuple> <op>(` — shape like bf16[8,128]{1,0} or a tuple.
_OP_RE = re.compile(
    r"=\s*(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

# XLA:CPU's AllReducePromotion pass rewrites bf16/f16 all-reduces to
# convert→f32-all-reduce→convert (the reducer computation gets a
# "_promoted" suffix). XLA:TPU reduces bf16 natively, so for the TPU-target
# roofline those ops are counted at their pre-promotion width.
_PROMOTED_RE = re.compile(r"to_apply=%\S*promoted")


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str,
                     undo_cpu_promotion: bool = True) -> Tuple[int, Dict[str, int]]:
    """Total per-device collective bytes + per-op-kind breakdown."""
    by_kind: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_text, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue  # counted at -start
        nbytes = _shape_bytes(shape_text)
        if (undo_cpu_promotion and kind == "all-reduce"
                and "f32" in shape_text and _PROMOTED_RE.search(line)):
            nbytes //= 2  # bf16 on the TPU wire
        by_kind[kind] += nbytes
    return sum(by_kind.values()), dict(by_kind)


def collective_count(hlo_text: str) -> int:
    return sum(1 for m in _OP_RE.finditer(hlo_text) if m.group(3) != "-done")
