"""Production mesh factory.

Defined as functions (never module-level constants) so importing this
module does not touch jax device state — jax locks the device count on
first backend initialization, and only dryrun.py sets the 512-device flag.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 (data, model) single pod; 2×16×16 (pod, data, model) for two
    pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """The axes a batch dimension shards over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh) -> str:
    return "model"


def make_cache_mesh(n_shards: int, axis: str = "cache"):
    """1-D mesh for the sharded segment cache's device tier.

    Uses the first `n_shards` local devices; on a CPU container run with
    `XLA_FLAGS=--xla_force_host_platform_device_count=8` these are real
    distinct devices, so remote-shard hits genuinely cross device
    boundaries (tests/test_shard_cache.py exercises this).
    """
    import jax

    if n_shards > jax.device_count():
        raise ValueError(
            f"n_shards {n_shards} > available devices {jax.device_count()} "
            "(set XLA_FLAGS=--xla_force_host_platform_device_count=N on CPU)")
    return jax.make_mesh((n_shards,), (axis,),
                         devices=jax.devices()[:n_shards])
