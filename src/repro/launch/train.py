"""Training launcher: --arch <id> [--smoke] — end-to-end driver.

On the CPU container this runs reduced configs for real (examples/CI); on a
pod, the same entry point drives the full config with the production mesh
(single process per host, jax.distributed initialization left to the
scheduler environment).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer, latest_step
from repro.configs import get_config
from repro.data import TokenPipeline
from repro.models import init_params
from repro.runtime import Supervisor, SupervisorConfig
from repro.train import TrainLoopConfig, make_optimizer, train_loop


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    init_opt, _ = make_optimizer(args.optimizer, lr=args.lr)
    opt_state = init_opt(params)

    ck = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if args.resume and ck is not None and latest_step(ck.directory) is not None:
        restored, start_step = ck.restore(
            {"params": params, "opt_state": opt_state})
        params, opt_state = restored["params"], restored["opt_state"]
        print(f"resumed from step {start_step}")

    pipe = TokenPipeline(cfg.vocab, args.seq, args.batch)

    def batches():
        step = start_step
        while True:
            t, lbl = pipe.batch_at(step)
            yield {"tokens": jnp.asarray(t), "labels": jnp.asarray(lbl)}
            step += 1

    lc = TrainLoopConfig(optimizer=args.optimizer, lr=args.lr,
                         max_steps=args.steps, compress=args.compress,
                         checkpoint_every=max(args.steps // 4, 1))

    sup = Supervisor(SupervisorConfig())

    def body(start):
        nonlocal params, opt_state
        params, opt_state, info = train_loop(
            cfg, lc, params, opt_state, batches(), checkpointer=ck,
            start_step=start)
        for step, loss in info["history"]:
            print(f"step {step:>5d} loss {loss:.4f}")
        print(f"{info['seconds']:.1f}s for {args.steps} steps")
        return args.steps

    sup.run(body, restore=lambda: start_step)


if __name__ == "__main__":
    main()
