"""Sharding rules: param/activation/state PartitionSpecs for any mesh.

Generic, divisibility-checked rules — the same policy MaxText-class
frameworks use, expressed as name-pattern preferences with automatic
fallback so every assigned architecture compiles on the production mesh:

  * 2D weights: columns over "model" (TP), rows over ("pod","data") (FSDP/
    ZeRO — optimizer state shards with the params, which is what makes
    AdamW on a 72B model fit 512×16 GB).
  * MoE expert banks (E, d, f): experts over "model" (EP) when E divides,
    else tensor-parallel inside the expert; d over data axes.
  * embeddings: vocab over "model" when divisible (sharded softmax), else
    d_model.
  * norms/scalars: replicated.
  * KV caches: batch over data axes, kv-heads over "model" when divisible,
    else head_dim.

Preference order is tried first; any dim that does not divide falls back
(None) — compile success is guaranteed, performance is the hillclimb's job.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


DATA_AXES = ("pod", "data")


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        out = 1
        for a in axis:
            out *= _axis_size(mesh, a)
        return out
    return mesh.shape[axis] if axis in mesh.axis_names else 0


def _fit(mesh: Mesh, shape: Sequence[int], spec: Sequence) -> Optional[P]:
    """Return P(spec) with non-dividing axes dropped; None if axis missing."""
    out = []
    for dim, axis in zip(shape, spec):
        size = _axis_size(mesh, axis)
        if size == 0:
            # axis not in this mesh (e.g. "pod" on single-pod): drop it
            if isinstance(axis, (tuple, list)):
                kept = tuple(a for a in axis if a in mesh.axis_names)
                size = _axis_size(mesh, kept)
                axis = kept if kept else None
            else:
                axis = None
                size = 1
        if size > 1 and dim % size == 0:
            out.append(axis if not isinstance(axis, (tuple, list))
                       else tuple(axis))
        else:
            out.append(None)
    return P(*out)


# Sentinel: shard over data axes only in FSDP mode (params too big to
# replicate across the data dimension), else replicate. Optimizer state
# always resolves FSDP=True (ZeRO-1: moments shard over data even when the
# params replicate — grads reduce-scatter into the update, updated params
# all-gather once per step instead of per layer).
FSDP = "__fsdp__"

# (regex on param path, ordered spec preferences per rank) — first rule
# match wins; within a rule, the first preference whose sharded dims all
# divide wins; else the last preference is per-dim fitted.
_PARAM_RULES: List[Tuple[str, Dict[int, Sequence]]] = [
    # MoE expert banks: EP over model preferred; when E doesn't divide the
    # model axis (mixtral's 8 experts on 16-way TP), tensor-parallel inside
    # the expert instead — never shard only the contracting dim.
    (r"moe/w_(gate|up)$",   {3: [("model", FSDP, None), (None, FSDP, "model")]}),
    (r"moe/w_down$",        {3: [("model", None, FSDP), (None, "model", FSDP)]}),
    (r"moe/w_router$",      {2: [(FSDP, None)]}),
    # Attention projections: column-parallel in, row-parallel out.
    (r"(attn|xattn)/w[qkv]$", {2: [(FSDP, "model")]}),
    (r"(attn|xattn)/wo$",     {2: [("model", FSDP)]}),
    # Dense MLP.
    (r"mlp/w_(gate|up)$",   {2: [(FSDP, "model")]}),
    (r"mlp/w_down$",        {2: [("model", FSDP)]}),
    # Recurrent blocks.
    (r"mlstm/w[qkv]$",      {2: [(FSDP, "model")]}),
    (r"mlstm/w[if]$",       {2: [(FSDP, None)]}),
    (r"mlstm/wo$",          {2: [("model", FSDP)]}),
    (r"slstm/(wz|wi_g|wf_g|wo_g)$", {2: [(FSDP, "model")]}),
    (r"slstm/r[zifo]$",     {2: [(FSDP, "model")]}),
    (r"slstm/wo$",          {2: [("model", FSDP)]}),
    (r"rec/w_branch_(gate|lin)$", {2: [(FSDP, "model")]}),
    (r"rec/w_(rec|in)_gate$",     {2: [(FSDP, "model")]}),
    (r"rec/w_out$",         {2: [("model", FSDP)]}),
    (r"rec/conv_w$",        {2: [(None, "model")]}),
    (r"rec/(conv_b|lambda)$", {1: [("model",)]}),
    # Embeddings / head: vocab over model (sharded softmax) preferred.
    (r"embed$",             {2: [("model", None)]}),
    (r"lm_head$",           {2: [(None, "model")]}),
    (r"(vision|audio)_proj$", {2: [(FSDP, "model")]}),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _resolve(spec: Sequence, fsdp: bool) -> Sequence:
    return [DATA_AXES if a == FSDP and fsdp
            else (None if a == FSDP else a) for a in spec]


def _fully_fits(mesh: Mesh, shape, spec) -> bool:
    fitted = _fit(mesh, shape, spec)
    want = [a for a in spec if a is not None]
    got = [a for a in fitted if a is not None]
    return len(want) == len(got)


def param_pspec(path: str, shape: Sequence[int], mesh: Mesh,
                fsdp: bool = False) -> P:
    rank = len(shape)
    for pattern, by_rank in _PARAM_RULES:
        if re.search(pattern, path) and rank in by_rank:
            prefs = [_resolve(p, fsdp) for p in by_rank[rank]]
            for pref in prefs:
                if _fully_fits(mesh, shape, pref):
                    return _fit(mesh, shape, pref)
            return _fit(mesh, shape, prefs[-1])
    if rank >= 2:
        spec = [None] * rank
        spec[0] = DATA_AXES if fsdp else None
        spec[-1] = "model"
        fitted = _fit(mesh, shape, spec)
        if all(a is None for a in fitted):
            spec2 = [None] * rank
            spec2[0] = "model"
            return _fit(mesh, shape, spec2)
        return fitted
    return P(*([None] * rank))


def tree_pspecs(tree, mesh: Mesh, fsdp: bool = False):
    """Pytree of PartitionSpecs matching `tree` (of arrays or SDS)."""
    def fn(path, leaf):
        return param_pspec(_path_str(path), leaf.shape, mesh, fsdp=fsdp)
    return jax.tree_util.tree_map_with_path(fn, tree)


def tree_shardings(tree, mesh: Mesh, fsdp: bool = False):
    return jax.tree_util.tree_map(
        lambda spec: NamedSharding(mesh, spec),
        tree_pspecs(tree, mesh, fsdp=fsdp))


def batch_pspec(shape: Sequence[int], mesh: Mesh) -> P:
    """Batch arrays: leading dim over data axes when divisible."""
    spec = [None] * len(shape)
    spec[0] = DATA_AXES
    return _fit(mesh, shape, spec)


def opt_state_pspecs(opt_state, param_specs, mesh: Mesh):
    """Optimizer moments shard with ZeRO-1 semantics: always the FSDP
    variant of their parameter's rule (moments shard over data even when
    params replicate — GSPMD turns the update into reduce-scatter +
    one all-gather of updated params per step). Scalars replicate."""
    out = {}
    for key, sub in opt_state.items():
        if key == "step":
            out[key] = P()
            continue
        if key in ("m", "v", "stats"):
            def fn(path, leaf):
                return param_pspec(_path_str(path), leaf.shape, mesh,
                                   fsdp=True)
            out[key] = jax.tree_util.tree_map_with_path(fn, sub)
            continue
        out[key] = jax.tree_util.tree_map(lambda _: P(), sub)
    return out


def state_pspecs(state, mesh: Mesh):
    """Decode-state sharding: caches (B, hkv, S, hd) → batch over data,
    kv-heads over model when divisible else head_dim; recurrent states
    (B, ...) → batch over data, trailing dim over model."""
    def fn(path, leaf):
        shape = leaf.shape
        if len(shape) == 4:   # kv cache
            spec = [DATA_AXES, "model", None, None]
            fitted = _fit(mesh, shape, spec)
            if fitted[1] is None:
                fitted = _fit(mesh, shape, [DATA_AXES, None, None, "model"])
            return fitted
        if len(shape) == 0:
            return P()
        spec = [None] * len(shape)
        spec[0] = DATA_AXES
        if len(shape) >= 2:
            spec[-1] = "model"
        return _fit(mesh, shape, spec)
    return jax.tree_util.tree_map_with_path(fn, state)
