"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, and never allocating — the dry-run lowers
against these. Modality frontends are stubs per the assignment: audio/vlm
cells receive precomputed frame/patch embeddings here.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig


def input_specs(cfg: ArchConfig, shape: Dict[str, Any]) -> Dict[str, Any]:
    """shape: {"kind": train|prefill|decode, "seq_len": int, "global_batch": int}."""
    b = shape["global_batch"]
    s = shape["seq_len"]
    kind = shape["kind"]
    act_dt = jnp.dtype(cfg.dtype)

    if kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        if cfg.n_vision_tokens:
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_vision_tokens, cfg.d_model), act_dt)
        if cfg.is_enc_dec:
            specs["audio_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.audio_frames, cfg.d_model), act_dt)
        return specs

    if kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.n_vision_tokens:
            specs["vision_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_vision_tokens, cfg.d_model), act_dt)
        if cfg.is_enc_dec:
            specs["audio_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.audio_frames, cfg.d_model), act_dt)
        return specs

    if kind == "decode":
        # One new token against a KV/recurrent state of length seq_len.
        specs = {"token": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
        if cfg.is_enc_dec:
            specs["enc_out"] = jax.ShapeDtypeStruct(
                (b, cfg.audio_frames, cfg.d_model), act_dt)
        return specs

    raise ValueError(f"unknown shape kind {kind!r}")
