"""Double-buffered host→device streamer (Phase II of Alg. 2).

JAX's async dispatch is the TPU-native version of CUDA stream overlap: while
the device executes the segment-k kernel, `jax.device_put` of segment k+1
proceeds concurrently. `DoubleBufferedStreamer` provides prefetch-ahead
iteration, straggler re-issue, and per-segment accounting; it is shared by
the AIRES SpGEMM scheduler and the out-of-core weight provider (MoE experts,
embeddings).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterable, Iterator, List, Optional

import jax


@dataclasses.dataclass
class StreamStats:
    segments: int = 0
    put_seconds: float = 0.0       # wall time blocked on device_put dispatch
    compute_seconds: float = 0.0   # wall time blocked on result readiness
    reissues: int = 0              # straggler mitigations
    uploaded_bytes: int = 0        # wire bytes (when payload_nbytes is given)
    cache_hits: int = 0            # segments served from the segment cache
    cache_hit_bytes: int = 0       # wire bytes served from the cache
    promoted_bytes: int = 0        # of those, host-tier promotions that DID
    #                                re-cross the bus (true bus traffic is
    #                                uploaded_bytes + promoted_bytes)
    ici_bytes: int = 0             # sharded cache: bytes that crossed the
    #                                inter-chip path (remote-shard hits and
    #                                shard placements) during this stream
    directory_hit_bytes: int = 0   # wire bytes served from a peer worker's
    #                                host copy via the CacheDirectory


class DoubleBufferedStreamer:
    """Prefetch-ahead pipeline over host segments.

    produce(i) -> host payload (numpy arrays / pytrees)
    upload(payload) -> device payload (typically jax.device_put with sharding)
    consume(device_payload, i) -> result (device computation, async)

    depth=2 is classic double buffering (paper Phase II); larger depths
    pipeline deeper when segments are small. A deadline (seconds) per
    segment triggers re-issue of the upload — the straggler mitigation used
    in multi-host deployments where a slow host NIC stalls one pipeline.

    Optional cache hooks (the tiered segment cache, io/segment_cache.py):
    `cache_lookup(payload)` returning non-None short-circuits the upload —
    the segment is already device-resident, so its wire bytes land in
    `cache_hit_bytes` instead of `uploaded_bytes`; after a miss's upload,
    `cache_store(payload, device_payload)` retains it for the next epoch.
    """

    def __init__(
        self,
        upload: Callable[[Any], Any],
        consume: Callable[[Any, int], Any],
        depth: int = 2,
        deadline_s: Optional[float] = None,
        max_reissue: int = 1,
        payload_nbytes: Optional[Callable[[Any], int]] = None,
        cache_lookup: Optional[Callable[[Any], Optional[Any]]] = None,
        cache_store: Optional[Callable[[Any, Any], None]] = None,
    ):
        if depth < 1:
            raise ValueError("depth must be >= 1")
        self.upload = upload
        self.consume = consume
        self.depth = depth
        self.deadline_s = deadline_s
        self.max_reissue = max_reissue
        self.payload_nbytes = payload_nbytes
        self.cache_lookup = cache_lookup
        self.cache_store = cache_store
        self.stats = StreamStats()

    def _upload_with_deadline(self, payload: Any) -> Any:
        nbytes = (int(self.payload_nbytes(payload))
                  if self.payload_nbytes is not None else 0)
        if self.cache_lookup is not None:
            t0 = time.perf_counter()
            cached = self.cache_lookup(payload)
            if cached is not None:
                # Lookup cost includes any host->device promotion the cache
                # performed — that is real transfer time, count it.
                self.stats.put_seconds += time.perf_counter() - t0
                self.stats.cache_hits += 1
                self.stats.cache_hit_bytes += nbytes
                return cached
        self.stats.uploaded_bytes += nbytes
        t0 = time.perf_counter()
        dev = self.upload(payload)
        if self.deadline_s is not None:
            for _ in range(self.max_reissue):
                if time.perf_counter() - t0 <= self.deadline_s:
                    break
                # Straggler: re-issue the transfer (idempotent device_put);
                # the retransmit is real wire traffic, so count it.
                self.stats.reissues += 1
                self.stats.uploaded_bytes += nbytes
                t0 = time.perf_counter()
                dev = self.upload(payload)
        self.stats.put_seconds += time.perf_counter() - t0
        if self.cache_store is not None:
            self.cache_store(payload, dev)
        return dev

    def run(self, payloads: Iterable[Any]) -> Iterator[Any]:
        """Yield consume() results in order, depth-deep pipelined."""
        it = iter(payloads)
        inflight: List[Any] = []
        # Prime the pipeline.
        for payload in it:
            inflight.append(self._upload_with_deadline(payload))
            if len(inflight) >= self.depth:
                break
        i = 0
        while inflight:
            dev = inflight.pop(0)
            t0 = time.perf_counter()
            result = self.consume(dev, i)
            self.stats.compute_seconds += time.perf_counter() - t0
            self.stats.segments += 1
            # Refill the pipeline before blocking on the result.
            try:
                nxt = next(it)
                inflight.append(self._upload_with_deadline(nxt))
            except StopIteration:
                pass
            yield result
            i += 1

    def run_all(self, payloads: Iterable[Any]) -> List[Any]:
        out = list(self.run(payloads))
        # Block once at the end (paper Phase III store) rather than per segment.
        jax.block_until_ready([o for o in out if o is not None])
        return out
