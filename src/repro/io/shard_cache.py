"""Mesh-sharded device tier for the segment cache.

`TieredSegmentCache` models one chip's tiered memory. Production serving
runs a *mesh* of chips (launch/mesh.py), and replicating the cache per chip
wastes the aggregate HBM: every chip retains — and re-demotes — its own
copy of every brick. `ShardedSegmentCache` instead partitions the device
tier across a named mesh axis, in the spirit of batched/partitioned SpGEMM
scheduling (arXiv:1903.11409) and Accel-GCN's workload-balanced block
mapping (arXiv:2308.11825):

  * every `SegmentKey` has one deterministic **owner shard**
    (`shard_of(key)`, a stable CRC over the key — NOT Python's randomized
    `hash`), so a brick is retained exactly once across the mesh; a
    partition-derived **owner map** (`install_owner_map`, fed by
    `repro.sparse.partition`) replaces the CRC default per namespace so
    connectivity-clustered row blocks co-locate on the shard that
    streams them;
  * per-shard device budgets and LRU state are **independent** — one hot
    graph cannot evict another graph's bricks from a different shard;
  * a hit whose owner is a **remote** shard ships the brick over the ICI
    path (`Path.ICI`, cheaper than the PCIe-class `dma`/`sio` paths,
    dearer than local HBM) — charged through the `TieredMemorySystem` so
    simulate-mode `bytes_by_path` stays honest, and executed for real
    (`jax.device_put` onto the local chip) when the cache is built from a
    mesh with >1 actual devices;
  * host spill, promotion, and the cross-worker `CacheDirectory` all ride
    the per-shard `TieredSegmentCache`s unchanged.

A 1-shard cache is byte-identical to a bare `TieredSegmentCache` (asserted
in tests/test_shard_cache.py): shard 0 is local, so no ICI transfer is ever
charged and every call delegates straight through.
"""
from __future__ import annotations

import zlib
from typing import Any, Callable, Dict, Hashable, List, Optional, Sequence

from repro.io.segment_cache import (
    CacheDirectory,
    CacheStats,
    SegmentKey,
    TieredSegmentCache,
    demote_to_host,
    prefix_matches,
    promote_to_device,
)
from repro.io.tiers import (
    ICI_ALL_TO_ALL,
    ICITopology,
    MemoryTier,
    Path,
    TieredMemorySystem,
)


def _shard_blob(key: SegmentKey) -> bytes:
    """Explicit field serialization of a key's four identity fields.

    Byte-identical to ``repr((graph_id, segment_id, wire_format, shape))``
    for canonical keys (str namespace, int segment id, str wire format,
    tuple-of-int shape) — including the 1-tuple trailing comma — but built
    field by field, so a `SegmentKey` dataclass-repr change (a new field,
    a renamed one) can never silently reshuffle every owner. The CRC of a
    known key is pinned in tests/test_shard_cache.py.
    """
    dims = [repr(int(d)) for d in key.shape]
    shape = "(" + ", ".join(dims) + ("," if len(dims) == 1 else "") + ")"
    return (f"({key.graph_id!r}, {int(key.segment_id)!r}, "
            f"{key.wire_format!r}, {shape})").encode()


def shard_of(key: SegmentKey, n_shards: int) -> int:
    """Deterministic owner shard of a segment key.

    CRC32 over an explicit serialization of the key's identity fields
    (`_shard_blob`): stable within a process (unlike `hash()`, which is
    salted per interpreter for str fields), uniform enough to balance
    bricks across shards, and identical for replicated workers looking at
    the same key.

    Hashes exactly the four identity fields — `SegmentKey.fingerprint` is
    deliberately excluded, so a segment keeps its owner shard across edge
    deltas (only its content identity changes) and pre-fingerprint goldens
    keep their placement bit-exactly.
    """
    if n_shards <= 1:
        return 0
    return zlib.crc32(_shard_blob(key)) % n_shards


def _place(value: Any, device) -> Any:
    """Commit a cached value's jax arrays to `device` (the ICI hop made
    real); non-array leaves (metadata, host mirrors) pass through."""
    if device is None:
        return value
    import jax

    return jax.tree_util.tree_map(
        lambda leaf: jax.device_put(leaf, device)
        if isinstance(leaf, jax.Array) else leaf, value)


class ShardedSegmentCache:
    """Device tier partitioned over a mesh axis; drop-in for
    `TieredSegmentCache` behind the `cache_lookup`/`cache_store` hooks.

    `device_budget_bytes` is the *aggregate* device budget; each of the
    `n_shards` shards gets an independent `device_budget_bytes // n_shards`
    slice (same for the host budget). `local_shard` is the shard this
    worker's streaming pipeline runs on: hits owned by any other shard are
    charged `nbytes` over `Path.ICI` (tag ``cache/ici``), and a remote put
    ships the fresh brick to its owner (tag ``cache/shard-place``).

    Build from a mesh with `from_mesh(mesh, axis=...)` to derive `n_shards`
    from the axis size and pin each shard's entries to a real device along
    that axis — with `XLA_FLAGS=--xla_force_host_platform_device_count=8`
    the bricks genuinely live on distinct (CPU) devices and remote hits
    really cross device boundaries.
    """

    def __init__(
        self,
        device_budget_bytes: int,
        host_budget_bytes: Optional[int] = None,
        tms: Optional[TieredMemorySystem] = None,
        n_shards: int = 1,
        local_shard: int = 0,
        devices: Optional[Sequence] = None,
        directory: Optional[CacheDirectory] = None,
        worker_id: Hashable = 0,
        demote: Callable[[Any], Any] = demote_to_host,
        promote: Callable[[Any], Any] = promote_to_device,
        topology: ICITopology = ICI_ALL_TO_ALL,
    ):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if not 0 <= local_shard < n_shards:
            raise ValueError(f"local_shard {local_shard} outside "
                             f"[0, {n_shards})")
        if device_budget_bytes < n_shards:
            raise ValueError(
                f"device_budget_bytes {device_budget_bytes} < n_shards "
                f"{n_shards}: every shard needs a positive budget")
        if devices is not None and len(devices) != n_shards:
            raise ValueError(f"devices ({len(devices)}) must match "
                             f"n_shards ({n_shards})")
        self.n_shards = int(n_shards)
        self.local_shard = int(local_shard)
        self.devices = list(devices) if devices is not None else None
        self.device_budget_bytes = int(device_budget_bytes)
        self.host_budget_bytes = (None if host_budget_bytes is None
                                  else int(host_budget_bytes))
        self.tms = tms
        self.directory = directory
        self.worker_id = worker_id
        self.topology = topology
        per_dev = self.device_budget_bytes // self.n_shards
        self._per_shard_device = per_dev
        per_host = self.host_budget_bytes
        if per_host is not None and self.n_shards > 1:
            per_host = max(1, per_host // self.n_shards)
        self._per_shard_host = per_host
        self.shards: List[TieredSegmentCache] = []
        for s in range(self.n_shards):
            dev = self.devices[s] if self.devices is not None else None
            shard_promote = (promote if dev is None
                             else (lambda v, d=dev: _place(promote(v), d)))
            self.shards.append(TieredSegmentCache(
                per_dev, per_host, tms=tms, demote=demote,
                promote=shard_promote, directory=directory,
                worker_id=worker_id))
        # Remote-hit accounting lives here (the shards know nothing about
        # the mesh); the aggregate `stats` property folds it in.
        self._remote_hits = 0
        self._ici_bytes = 0
        self.last_get_transfer_s: float = 0.0
        # Placement overrides (the owner map): keys whose owner differs
        # from the default owner because a put() carried an explicit shard
        # — the shard-placement rewrite pass pins a graph's hot bricks to
        # the shard that consumes them. Queried via `owner_of`.
        self._locations: Dict[SegmentKey, int] = {}
        # Partition-derived owner maps, keyed by cache namespace
        # (SegmentKey.graph_id): owners[segment_id] replaces the CRC
        # default for that namespace's keys (`install_owner_map`), with an
        # optional parallel cluster-id map the ShardPlacementPass groups
        # co-placements by. Dropped with the namespace on prefix/graph
        # invalidation; deliberately NOT dropped by `clear()` or
        # `invalidate_keys` — the map is placement *policy* derived from
        # the graph's topology, not cached content, so re-streamed and
        # warm-started bricks land back on their partition owners.
        self._owner_maps: Dict[str, List[int]] = {}
        self._cluster_maps: Dict[str, List[int]] = {}

    @classmethod
    def from_mesh(cls, mesh, device_budget_bytes: int, axis: str = "cache",
                  local_index: int = 0, **kw) -> "ShardedSegmentCache":
        """Partition over `mesh`'s `axis`: one shard per index, each pinned
        to the first device at that index (the owner chip)."""
        import numpy as np

        names = list(mesh.axis_names)
        if axis not in names:
            raise ValueError(f"mesh has no axis {axis!r} (has {names})")
        ax = names.index(axis)
        n_shards = mesh.devices.shape[ax]
        # Owner chip per shard index: first device of each slice along axis.
        dev_grid = np.moveaxis(np.asarray(mesh.devices), ax, 0)
        dev_grid = dev_grid.reshape(n_shards, -1)
        devices = [dev_grid[s, 0] for s in range(n_shards)]
        return cls(device_budget_bytes, n_shards=n_shards,
                   local_shard=local_index, devices=devices, **kw)

    # ---- introspection ---------------------------------------------------

    @property
    def stats(self) -> CacheStats:
        """Aggregate across shards (recomputed per access — read deltas of
        this, do not mutate it)."""
        agg = CacheStats()
        for shard in self.shards:
            agg.add(shard.stats)
        agg.remote_hits += self._remote_hits
        agg.ici_bytes += self._ici_bytes
        return agg

    @property
    def device_used_bytes(self) -> int:
        return sum(s.device_used_bytes for s in self.shards)

    @property
    def host_used_bytes(self) -> int:
        return sum(s.host_used_bytes for s in self.shards)

    def __len__(self) -> int:
        return sum(len(s) for s in self.shards)

    def __contains__(self, key: SegmentKey) -> bool:
        return key in self._owner(key)

    def tier_of(self, key: SegmentKey) -> Optional[MemoryTier]:
        return self._owner(key).tier_of(key)

    def owner_of(self, key: SegmentKey) -> int:
        """The shard that owns (or would own) `key`. Resolution order:
        a placement override recorded by `put(..., shard=...)`, then the
        namespace's installed partition owner map, then the deterministic
        CRC owner. This is the owner-map query the shard-placement
        rewrite pass builds on."""
        loc = self._locations.get(key)
        if loc is not None:
            return loc
        return self._default_owner(key)

    def _default_owner(self, key: SegmentKey) -> int:
        """`key`'s owner before any per-key placement override: the
        installed partition owner map when one covers it, else CRC."""
        owners = self._owner_maps.get(key.graph_id)
        if owners is not None and 0 <= key.segment_id < len(owners):
            return owners[key.segment_id]
        return shard_of(key, self.n_shards)

    def install_owner_map(self, namespace: str, owners: Sequence[int],
                          clusters: Optional[Sequence[int]] = None) -> None:
        """Install a partition-derived owner map for one cache namespace:
        `owners[i]` owns segment i of `namespace` (overriding the CRC
        default; per-key `put(shard=)` overrides still win). `clusters`
        is the parallel majority-cluster id per segment — what
        `cluster_of_key` serves to the ShardPlacementPass so co-clustered
        bricks are co-placed. Reinstalling replaces the previous map."""
        owners = [int(s) for s in owners]
        for s in owners:
            if not 0 <= s < self.n_shards:
                raise ValueError(
                    f"owner map shard {s} outside [0, {self.n_shards})")
        if clusters is not None and len(clusters) != len(owners):
            raise ValueError(
                f"cluster map length {len(clusters)} != owner map "
                f"length {len(owners)}")
        self._owner_maps[str(namespace)] = owners
        if clusters is not None:
            self._cluster_maps[str(namespace)] = [int(c) for c in clusters]
        else:
            self._cluster_maps.pop(str(namespace), None)

    def drop_owner_map(self, namespace: str) -> bool:
        """Remove one namespace's installed owner (and cluster) map;
        returns whether a map was installed."""
        had = self._owner_maps.pop(str(namespace), None) is not None
        self._cluster_maps.pop(str(namespace), None)
        return had

    def owner_map(self, namespace: str) -> Optional[List[int]]:
        """The installed owner map for `namespace` (a copy), or None."""
        owners = self._owner_maps.get(str(namespace))
        return list(owners) if owners is not None else None

    def cluster_of_key(self, key: SegmentKey) -> Optional[int]:
        """`key`'s majority-cluster id under its namespace's installed
        cluster map, or None — the grouping handle the
        ShardPlacementPass co-places whole clusters by."""
        clusters = self._cluster_maps.get(key.graph_id)
        if clusters is not None and 0 <= key.segment_id < len(clusters):
            return clusters[key.segment_id]
        return None

    def shard_index_of(self, key: SegmentKey) -> int:
        return self.owner_of(key)

    @property
    def shard_budget_bytes(self) -> int:
        """Device budget of each independent shard."""
        return self._per_shard_device

    def shard_headroom(self, shard: int) -> int:
        """Unused device-tier bytes on `shard` — what the placement pass
        may still pin there for free warm hits."""
        return self._per_shard_device - self.shards[shard].device_used_bytes

    def shard_host_headroom(self, shard: int) -> float:
        """Unused host-tier bytes on `shard` (inf when unbounded). A
        brick's owner shard matters even on the host tier — a
        remote-owner host hit pays promotion *plus* the ICI ship — but
        host placement is the placement pass's last resort: a device-
        resident brick anywhere beats a host promotion."""
        if self._per_shard_host is None:
            return float("inf")
        return self._per_shard_host - self.shards[shard].host_used_bytes

    def ici_hops(self, shard: int) -> int:
        """Links between `shard` and the local shard under the cache's
        `ICITopology` (0 for the local shard itself)."""
        return self.topology.hops(shard, self.local_shard, self.n_shards)

    def _owner(self, key: SegmentKey) -> TieredSegmentCache:
        return self.shards[self.owner_of(key)]

    # ---- maintenance -----------------------------------------------------

    def pin(self, graph_id: Hashable, obj: Any) -> None:
        for shard in self.shards:
            shard.pin(graph_id, obj)

    def invalidate_graph(self, graph_id: Hashable) -> int:
        self._drop_locations(str(graph_id), exact=graph_id)
        return sum(s.invalidate_graph(graph_id) for s in self.shards)

    def invalidate_prefix(self, prefix: str, exact: Hashable = None) -> int:
        self._drop_locations(prefix, exact=exact)
        return sum(s.invalidate_prefix(prefix, exact=exact)
                   for s in self.shards)

    def invalidate_keys(self, keys) -> int:
        """Drop exactly the given keys (delta-update invalidation), each at
        its owner shard, clearing any placement override too."""
        dropped = 0
        for key in keys:
            dropped += self._owner(key).invalidate_keys([key])
            self._locations.pop(key, None)
        return dropped

    def _drop_locations(self, prefix: str, exact: Hashable = None) -> None:
        for key in [k for k in self._locations
                    if prefix_matches(k.graph_id, prefix, exact)]:
            del self._locations[key]
        for ns in [ns for ns in self._owner_maps
                   if prefix_matches(ns, prefix, exact)]:
            del self._owner_maps[ns]
            self._cluster_maps.pop(ns, None)

    def clear(self) -> None:
        self._locations.clear()
        for shard in self.shards:
            shard.clear()

    def export_entries(self) -> list:
        """Snapshot of every shard's entries (see
        `TieredSegmentCache.export_entries`); shard order, so a re-import
        lands each brick back on its deterministic owner."""
        out = []
        for shard in self.shards:
            out.extend(shard.export_entries())
        return out

    # ---- the cache protocol ----------------------------------------------

    def get(self, key: SegmentKey, nbytes: int = 0,
            tms: Optional[TieredMemorySystem] = None) -> Optional[Any]:
        return self.get_with_cost(key, nbytes=nbytes, tms=tms)[0]

    def get_with_cost(self, key: SegmentKey, nbytes: int = 0,
                      tms: Optional[TieredMemorySystem] = None):
        """(value, transfer_seconds). A remote-shard hit adds the ICI hop(s)
        to the owner shard's own promotion cost (if any)."""
        s = self.owner_of(key)
        value, cost = self.shards[s].get_with_cost(key, nbytes=nbytes,
                                                   tms=tms)
        if value is not None and s != self.local_shard:
            hops = self.ici_hops(s)
            self._remote_hits += 1
            self._ici_bytes += nbytes * hops
            cost += self._charge_ici(tms, nbytes, "cache/ici", hops=hops)
            if self.devices is not None:
                value = _place(value, self.devices[self.local_shard])
        self.last_get_transfer_s = cost
        return value, cost

    def peek_cost(self, key: SegmentKey, nbytes: int = 0,
                  tms: Optional[TieredMemorySystem] = None,
                  shard: Optional[int] = None):
        """Price a get WITHOUT performing it (see
        `TieredSegmentCache.peek_cost`). A remote-owned key adds the ICI
        hop(s) a hit would ride — or, on a miss, the shard-place ship the
        subsequent put() would pay; `shard` is the placement override that
        put would carry (`CacheProbeOp.place_shard`), so an estimate prices
        the rewritten plan, not the CRC default."""
        s = self.owner_of(key)
        hit, cost = self.shards[s].peek_cost(key, nbytes=nbytes, tms=tms)
        if hit:
            if s != self.local_shard:
                cost += self._charge_ici(tms, nbytes, "cache/ici",
                                         hops=self.ici_hops(s))
        else:
            dst = s if shard is None else int(shard)
            if dst != self.local_shard:
                cost += self._charge_ici(tms, nbytes, "cache/shard-place",
                                         hops=self.ici_hops(dst))
        return hit, cost

    def put(self, key: SegmentKey, value: Any, nbytes: int,
            tms: Optional[TieredMemorySystem] = None,
            pin: Any = None, shard: Optional[int] = None) -> None:
        """Insert at the owner shard; a remote owner costs one ICI ship of
        the fresh brick (the upload landed on the local chip first).

        `shard` overrides the CRC owner — the shard-placement pass pins a
        plan's bricks to the shard that streams them. The override is
        recorded in the owner map so later get/peek calls resolve to the
        real location, and any stale copy at the previous owner is dropped.
        """
        cur = self.owner_of(key)
        dst = cur if shard is None else int(shard)
        if not 0 <= dst < self.n_shards:
            raise ValueError(f"placement shard {dst} outside "
                             f"[0, {self.n_shards})")
        if dst != cur:
            self.shards[cur].discard(key)
        # Record the override only when it differs from the *default*
        # owner — which is the installed partition owner map when one
        # covers this key, not the raw CRC: a put landing exactly on the
        # partition owner needs no per-key entry (and must not pin one,
        # or a later owner-map reinstall could not move it).
        if dst != self._default_owner(key):
            self._locations[key] = dst
        else:
            self._locations.pop(key, None)
        if dst != self.local_shard:
            hops = self.ici_hops(dst)
            self._ici_bytes += nbytes * hops
            self._charge_ici(tms, nbytes, "cache/shard-place", hops=hops)
            if self.devices is not None:
                value = _place(value, self.devices[dst])
        self.shards[dst].put(key, value, nbytes, tms=tms, pin=pin)

    def _charge_ici(self, tms: Optional[TieredMemorySystem], nbytes: int,
                    tag: str, hops: int = 1) -> float:
        tms = tms if tms is not None else self.tms
        if tms is None or nbytes <= 0:
            return 0.0
        return tms.transfer(Path.ICI, MemoryTier.DEVICE, MemoryTier.DEVICE,
                            int(nbytes), tag=tag, hops=hops)
