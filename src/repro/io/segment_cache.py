"""Tiered LRU segment cache — device-resident BlockELL bricks with host spill.

AIRES's Phase III keeps the output C on device for layer chaining, but the
execute path still re-streamed every BlockELL segment each layer and each
epoch. This cache closes that gap: uploaded device payloads are retained
under a device byte budget; LRU eviction *demotes* bricks device→host
instead of discarding them, and a later hit *promotes* them back. Both moves
are charged through a `TieredMemorySystem` (DMA path, tagged
``cache/demote`` / ``cache/promote``) so the simulate-mode `bytes_by_path`
stays honest: a device-tier hit is free wire traffic, a host-tier hit pays
one HtoD transfer, a miss pays the full upload.

Keys are `(graph_id, segment_id, wire_format, shape)` — graph identity plus
the segment's position in its RoBW plan plus the wire layout, so two plans
over the same graph (e.g. different planning widths) never alias. Callers
may `pin` the source graph object per graph_id: id()-derived graph ids then
cannot be recycled into stale hits while the cache lives (the same
immutability contract as `AiresSpGEMM`'s prepared cache).
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Any, Callable, Dict, Hashable, Optional, Tuple

from repro.io.tiers import MemoryTier, Path, TieredMemorySystem


@dataclasses.dataclass(frozen=True)
class SegmentKey:
    """Identity of one cached wire segment."""

    graph_id: Hashable
    segment_id: Hashable     # (plan token, index-in-plan)
    wire_format: str         # "bricks" | "csr"
    shape: Tuple[int, ...]   # wire-payload shape (disambiguates re-plans)
    # Per-segment content fingerprint (`segment_fingerprint` of the rows the
    # brick encodes). For evolving graphs, `graph_id` names the *lineage*
    # (stable across edge deltas) and this field carries content identity:
    # a delta changes only the touched segments' fingerprints, so untouched
    # bricks keep hitting. "" = legacy/content-agnostic key. Deliberately
    # EXCLUDED from `shard_of` owner hashing (io/shard_cache.py), so adding
    # it did not reshuffle shard placement.
    fingerprint: str = ""


def prefix_matches(graph_id: Hashable, prefix: str,
                   exact: Hashable = None) -> bool:
    """Does `graph_id` belong to the namespace family named by `prefix`?

    Delimiter-aware: matches the id itself or any `:`-separated extension
    of it (`g12:fwd:w64` under prefix `g12`), but never a sibling whose id
    merely shares leading characters (`g123:…` under `g12` — the
    invalidation-collision bug). `exact` additionally matches a
    non-string id by equality."""
    if exact is not None and graph_id == exact:
        return True
    gid = str(graph_id)
    return gid == prefix or gid.startswith(prefix + ":")


@dataclasses.dataclass
class CacheStats:
    device_hits: int = 0
    host_hits: int = 0       # promoted device<-host
    misses: int = 0
    hit_bytes: int = 0       # wire bytes served from either tier
    miss_bytes: int = 0      # wire bytes the caller had to upload
    demoted_bytes: int = 0   # device->host spills
    promoted_bytes: int = 0  # host->device refills
    evicted_bytes: int = 0   # dropped from the host tier entirely
    # Sharded device tier (io/shard_cache.py): hits whose brick lives on a
    # remote shard and the bytes that therefore crossed the ICI path.
    remote_hits: int = 0
    ici_bytes: int = 0
    # Cross-worker directory (CacheDirectory): hits served from a peer
    # worker's host copy, and demotion copies we skipped because a peer
    # already holds the brick.
    directory_hits: int = 0
    directory_hit_bytes: int = 0
    duplicate_avoided_bytes: int = 0

    @property
    def hits(self) -> int:
        return self.device_hits + self.host_hits + self.directory_hits

    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def add(self, other: "CacheStats") -> "CacheStats":
        """Field-wise sum (aggregating per-shard stats)."""
        for f in dataclasses.fields(CacheStats):
            setattr(self, f.name,
                    getattr(self, f.name) + getattr(other, f.name))
        return self


@dataclasses.dataclass
class _Entry:
    value: Any
    nbytes: int


def demote_to_host(value: Any):
    """Default demotion: device arrays → host numpy (bit-identical copy)."""
    import jax
    import numpy as np

    return jax.tree_util.tree_map(
        lambda leaf: np.asarray(leaf) if isinstance(leaf, jax.Array) else leaf,
        value)


def promote_to_device(value: Any):
    """Default promotion: host numpy arrays → device buffers."""
    import jax
    import numpy as np

    return jax.tree_util.tree_map(
        lambda leaf: jax.device_put(leaf) if isinstance(leaf, np.ndarray)
        else leaf, value)


class CacheDirectory:
    """Cross-worker registry of demoted host copies.

    Replicated `ServingEngine` workers each run their own segment cache over
    the same graphs, so without coordination every worker demotes — and
    stores — its own host copy of every evicted brick. A shared directory
    fixes both halves of that waste:

      * **dedup on demote** — a worker about to spill a brick first asks who
        already holds its host copy; if a *peer* does, the local copy is
        dropped without the DtoH transfer (counted in the worker's
        `stats.duplicate_avoided_bytes`).
      * **fetch on miss** — a worker that misses both its tiers asks the
        directory; a peer's host copy is promoted straight into the local
        device tier (one HtoD transfer, tag ``cache/peer-promote``) instead
        of a fresh wire upload (`stats.directory_hits` /
        `stats.directory_hit_bytes`).

    One holder per key (first demoter wins); the holder unpublishes when its
    host copy is promoted away, evicted, or invalidated. Thread-safe; cache
    locks are never held while a peer cache's lock is taken (the directory
    stores the host value itself), so workers cannot deadlock.
    """

    def __init__(self):
        self._entries: Dict[SegmentKey, Tuple[Hashable, Any, int]] = {}
        self._claimed: set = set()
        self._lock = threading.Lock()
        self.lookups = 0
        self.hits = 0
        self.hit_bytes = 0
        self.duplicates_avoided = 0
        self.duplicate_avoided_bytes = 0

    def __len__(self) -> int:
        return len(self._entries)

    def claim_worker(self, worker_id: Hashable) -> None:
        """Register one *worker* identity (a ServingEngine replica; the
        shards of one worker's cache legitimately share its id). Two
        workers claiming the same id would silently neutralize the
        directory — fetch excludes the caller's own id and demote-dedup
        only trusts *other* holders — so a duplicate claim is an error."""
        with self._lock:
            if worker_id in self._claimed:
                raise ValueError(
                    f"worker_id {worker_id!r} already claimed on this "
                    "CacheDirectory — replicated workers need distinct "
                    "EngineConfig.worker_id values, or the directory "
                    "silently never dedups or peer-serves")
            self._claimed.add(worker_id)

    def holder(self, key: SegmentKey) -> Optional[Hashable]:
        with self._lock:
            entry = self._entries.get(key)
            return entry[0] if entry is not None else None

    def publish(self, key: SegmentKey, worker_id: Hashable, value: Any,
                nbytes: int) -> None:
        """Record `worker_id` as the holder of `key`'s host copy."""
        with self._lock:
            self._entries[key] = (worker_id, value, int(nbytes))

    def unpublish(self, key: SegmentKey, worker_id: Hashable) -> None:
        """Drop the record — only if `worker_id` is still the holder."""
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None and entry[0] == worker_id:
                del self._entries[key]

    def drop(self, key: SegmentKey) -> bool:
        """Drop the record for `key` regardless of who holds it.

        The delta-update invalidation path: when a graph update makes a
        segment key stale, *every* worker's published copy of it is stale —
        including peers' — and `unpublish` (holder-checked) cannot reach
        those. Returns whether a record existed."""
        with self._lock:
            return self._entries.pop(key, None) is not None

    def drop_prefix(self, prefix: str, worker_id: Hashable = None) -> int:
        """Drop every record whose graph_id falls under `prefix`
        (delimiter-aware, see `prefix_matches`); with `worker_id`, only
        that worker's holdings. This is what `evict_graph` calls so peers
        are never routed a peer-promote for entries the evicting worker no
        longer backs. Returns the number of records dropped."""
        with self._lock:
            victims = [k for k, (holder, _, _) in self._entries.items()
                       if prefix_matches(k.graph_id, prefix)
                       and (worker_id is None or holder == worker_id)]
            for k in victims:
                del self._entries[k]
            return len(victims)

    def fetch(self, key: SegmentKey,
              exclude: Hashable = None) -> Optional[Tuple[Any, Hashable, int]]:
        """(host value, holder, nbytes) if a worker ≠ `exclude` holds it."""
        with self._lock:
            self.lookups += 1
            entry = self._entries.get(key)
            if entry is None or entry[0] == exclude:
                return None
            self.hits += 1
            self.hit_bytes += entry[2]
            return entry[1], entry[0], entry[2]


class TieredSegmentCache:
    """Device-budget-aware LRU over wire segments, with a host spill tier.

    * device tier — entries live in upload form (e.g. jax device buffers);
      `device_budget_bytes` is a hard cap, eviction demotes LRU-first.
    * host tier — demoted entries (converted by `demote`, default: numpy
      copies); `host_budget_bytes` caps it (None = unbounded); overflow is
      dropped for good and counted in `stats.evicted_bytes`.

    `tms` (constructor or per-call) receives the DMA transfer for every
    demotion/promotion; `get_with_cost` additionally returns the modeled
    seconds of the promotion so schedulers can put host-tier hits on the
    pipeline critical path.

    Semantics of the device budget: it models *spare* device memory the
    operator dedicates to brick retention, beyond the streaming working set
    (M_B + M_C + M_A) — the cache does not subtract from the scheduler's
    Eq. 5-7 budget. Sizing it larger than the actually-spare HBM is the
    operator's (unchecked) claim.
    """

    def __init__(
        self,
        device_budget_bytes: int,
        host_budget_bytes: Optional[int] = None,
        tms: Optional[TieredMemorySystem] = None,
        demote: Callable[[Any], Any] = demote_to_host,
        promote: Callable[[Any], Any] = promote_to_device,
        directory: Optional[CacheDirectory] = None,
        worker_id: Hashable = 0,
    ):
        if device_budget_bytes <= 0:
            raise ValueError("device_budget_bytes must be > 0")
        self.device_budget_bytes = int(device_budget_bytes)
        self.host_budget_bytes = (None if host_budget_bytes is None
                                  else int(host_budget_bytes))
        self.tms = tms
        # Optional cross-worker directory (replicated serving): dedups
        # demotion copies and serves misses from a peer's host tier.
        self.directory = directory
        self.worker_id = worker_id
        self._demote = demote
        self._promote = promote
        self._device: "OrderedDict[SegmentKey, _Entry]" = OrderedDict()
        self._host: "OrderedDict[SegmentKey, _Entry]" = OrderedDict()
        self._device_used = 0
        self._host_used = 0
        self._pins: Dict[Hashable, Any] = {}
        self._lock = threading.Lock()
        self.stats = CacheStats()
        # Convenience mirror of the last get()'s promotion seconds. NOT
        # race-free across threads — concurrent callers should use
        # get_with_cost() instead.
        self.last_get_transfer_s: float = 0.0

    # ---- introspection ---------------------------------------------------

    @property
    def device_used_bytes(self) -> int:
        return self._device_used

    @property
    def host_used_bytes(self) -> int:
        return self._host_used

    def __len__(self) -> int:
        return len(self._device) + len(self._host)

    def __contains__(self, key: SegmentKey) -> bool:
        return key in self._device or key in self._host

    def tier_of(self, key: SegmentKey) -> Optional[MemoryTier]:
        if key in self._device:
            return MemoryTier.DEVICE
        if key in self._host:
            return MemoryTier.HOST
        return None

    # ---- maintenance -----------------------------------------------------

    def pin(self, graph_id: Hashable, obj: Any) -> None:
        """Hold a strong reference to the graph behind `graph_id` so an
        id()-derived graph id cannot be recycled while entries live."""
        self._pins[graph_id] = obj

    def invalidate_graph(self, graph_id: Hashable) -> int:
        """Drop every entry (both tiers) and the pin for one graph."""
        return self.invalidate_prefix(str(graph_id), exact=graph_id)

    def invalidate_prefix(self, prefix: str, exact: Hashable = None) -> int:
        """Drop entries whose graph_id is `exact` or a `:`-delimited
        extension of `prefix` — one graph spans several namespaces
        (direction × plan width), all sharing the graph-identity prefix.
        Matching is delimiter-aware (`prefix_matches`): a graph whose
        fingerprint happens to be a leading substring of another's can no
        longer invalidate the bystander's entries."""
        with self._lock:
            dropped = 0
            for store in (self._device, self._host):
                for key in [k for k in store
                            if prefix_matches(k.graph_id, prefix, exact)]:
                    dropped += 1
                    self._account(store, -store.pop(key).nbytes)
                    if store is self._host and self.directory is not None:
                        self.directory.unpublish(key, self.worker_id)
            for gid in [g for g in self._pins
                        if prefix_matches(g, prefix, exact)]:
                del self._pins[gid]
            return dropped

    def invalidate_keys(self, keys) -> int:
        """Drop exactly the given keys from both tiers (the delta-update
        path: a graph update invalidates the touched segments' stale keys
        and nothing else). Returns the number of entries dropped."""
        with self._lock:
            dropped = 0
            for key in keys:
                for store in (self._device, self._host):
                    entry = store.pop(key, None)
                    if entry is not None:
                        dropped += 1
                        self._account(store, -entry.nbytes)
                        if store is self._host and self.directory is not None:
                            self.directory.unpublish(key, self.worker_id)
            return dropped

    def clear(self) -> None:
        with self._lock:
            if self.directory is not None:
                for key in self._host:
                    self.directory.unpublish(key, self.worker_id)
            self._device.clear()
            self._host.clear()
            self._device_used = 0
            self._host_used = 0
            self._pins.clear()

    def export_entries(self) -> list:
        """Snapshot every live entry as (key, host-form value, wire bytes).

        Device-tier entries are demoted to host form (bit-identical numpy
        copies) *without* being evicted — this is the read path for brick
        checkpointing (`ServingEngine.checkpoint_cache`), so a serving
        process can persist its warm cache and a successor can
        `warm_start()` from it.
        """
        with self._lock:
            out = [(key, self._demote(e.value), e.nbytes)
                   for key, e in self._device.items()]
            out.extend((key, e.value, e.nbytes)
                       for key, e in self._host.items())
            return out

    # ---- the cache protocol ----------------------------------------------

    def get(self, key: SegmentKey, nbytes: int = 0,
            tms: Optional[TieredMemorySystem] = None) -> Optional[Any]:
        """Lookup; `nbytes` (the wire size the caller would otherwise
        upload) feeds hit/miss byte accounting. Returns the device-form
        value, or None on miss."""
        return self.get_with_cost(key, nbytes=nbytes, tms=tms)[0]

    def get_with_cost(self, key: SegmentKey, nbytes: int = 0,
                      tms: Optional[TieredMemorySystem] = None):
        """Like get(), but returns (value, transfer_seconds): the modeled
        cost of the promotion this lookup triggered (0.0 for a device-tier
        hit or a miss). Race-free, unlike reading last_get_transfer_s."""
        with self._lock:
            self.last_get_transfer_s = 0.0
            entry = self._device.get(key)
            if entry is not None:
                self._device.move_to_end(key)
                self.stats.device_hits += 1
                self.stats.hit_bytes += nbytes
                return entry.value, 0.0
            entry = self._host.pop(key, None)
            if entry is not None:
                self._host_used -= entry.nbytes
                if self.directory is not None:
                    # Our host copy is consumed by the promotion.
                    self.directory.unpublish(key, self.worker_id)
                value = self._promote(entry.value)
                cost = self._charge(
                    tms, MemoryTier.HOST, MemoryTier.DEVICE, entry.nbytes,
                    "cache/promote")
                self.last_get_transfer_s = cost
                self.stats.promoted_bytes += entry.nbytes
                self.stats.host_hits += 1
                self.stats.hit_bytes += nbytes
                self._insert_device(key, _Entry(value, entry.nbytes), tms)
                return value, cost
            if self.directory is not None:
                fetched = self.directory.fetch(key, exclude=self.worker_id)
                if fetched is not None:
                    # A peer worker's host tier holds the brick: promote its
                    # copy into our device tier — one HtoD transfer instead
                    # of a fresh wire upload. The peer keeps its host copy
                    # (and stays the directory holder).
                    host_value, _, host_nbytes = fetched
                    value = self._promote(host_value)
                    cost = self._charge(
                        tms, MemoryTier.HOST, MemoryTier.DEVICE, host_nbytes,
                        "cache/peer-promote")
                    self.last_get_transfer_s = cost
                    self.stats.promoted_bytes += host_nbytes
                    self.stats.directory_hits += 1
                    self.stats.directory_hit_bytes += nbytes
                    self.stats.hit_bytes += nbytes
                    self._insert_device(key, _Entry(value, host_nbytes), tms)
                    return value, cost
            self.stats.misses += 1
            self.stats.miss_bytes += nbytes
            return None, 0.0

    def peek_cost(self, key: SegmentKey, nbytes: int = 0,
                  tms: Optional[TieredMemorySystem] = None,
                  shard: Optional[int] = None) -> Tuple[bool, float]:
        """Price a `get_with_cost` WITHOUT performing it: no promotion, no
        LRU reorder, no stats. Returns (would_hit, modeled_seconds); the
        promotion a host-tier or directory-peer hit would pay is charged to
        `tms` (pass the estimate's own fresh tms — the default `self.tms`
        is this cache's live accounting). This is the cache's half of
        `PipelinePlan.estimate()`: the pricing stays next to the code that
        really charges it (`get_with_cost`), so the two cannot drift.
        `shard` (a placement override the miss's put would carry) is
        protocol parity with `ShardedSegmentCache` — a single-chip cache
        has one shard, so it is ignored here."""
        tier = self.tier_of(key)
        if tier is MemoryTier.DEVICE:
            return True, 0.0
        if tier is MemoryTier.HOST:
            return True, self._charge(tms, MemoryTier.HOST,
                                      MemoryTier.DEVICE, nbytes,
                                      "cache/promote")
        if self.directory is not None:
            holder = self.directory.holder(key)
            if holder is not None and holder != self.worker_id:
                return True, self._charge(tms, MemoryTier.HOST,
                                          MemoryTier.DEVICE, nbytes,
                                          "cache/peer-promote")
        return False, 0.0

    def put(self, key: SegmentKey, value: Any, nbytes: int,
            tms: Optional[TieredMemorySystem] = None,
            pin: Any = None, shard: Optional[int] = None) -> None:
        """Insert/refresh a device-form value of `nbytes` wire bytes.
        `shard` (a placement override) is protocol parity with
        `ShardedSegmentCache`; a single-chip cache ignores it."""
        with self._lock:
            if pin is not None:
                self._pins[key.graph_id] = pin
            stale = self._device.pop(key, None)
            if stale is not None:
                self._device_used -= stale.nbytes
            stale = self._host.pop(key, None)
            if stale is not None:
                self._host_used -= stale.nbytes
                if self.directory is not None:
                    self.directory.unpublish(key, self.worker_id)
            self._insert_device(key, _Entry(value, int(nbytes)), tms)

    def discard(self, key: SegmentKey) -> bool:
        """Silently drop `key` from both tiers — no stats, no modeled
        transfers. Used by the sharded wrapper when a placement override
        moves a key off its previous owner shard (the move itself is
        charged by the caller)."""
        with self._lock:
            entry = self._device.pop(key, None)
            if entry is not None:
                self._device_used -= entry.nbytes
                return True
            entry = self._host.pop(key, None)
            if entry is not None:
                self._host_used -= entry.nbytes
                if self.directory is not None:
                    self.directory.unpublish(key, self.worker_id)
                return True
            return False

    def _account(self, store, delta: int) -> None:
        if store is self._device:
            self._device_used += delta
        else:
            self._host_used += delta

    # ---- internals (lock held) -------------------------------------------

    def _charge(self, tms: Optional[TieredMemorySystem], src: MemoryTier,
                dst: MemoryTier, nbytes: int, tag: str) -> float:
        tms = tms if tms is not None else self.tms
        if tms is None or nbytes <= 0:
            return 0.0
        return tms.transfer(Path.DMA, src, dst, int(nbytes), tag=tag)

    def _insert_device(self, key: SegmentKey, entry: _Entry,
                       tms: Optional[TieredMemorySystem]) -> None:
        if entry.nbytes > self.device_budget_bytes:
            # Never holds on device: spill the fresh upload straight down.
            self._demote_entry(key, entry, tms)
            return
        while self._device_used + entry.nbytes > self.device_budget_bytes:
            victim_key, victim = self._device.popitem(last=False)
            self._device_used -= victim.nbytes
            self._demote_entry(victim_key, victim, tms)
        self._device[key] = entry
        self._device_used += entry.nbytes

    def _demote_entry(self, key: SegmentKey, entry: _Entry,
                      tms: Optional[TieredMemorySystem]) -> None:
        """Move a device-form entry down a tier (or drop it if it can't fit)."""
        if self.directory is not None:
            holder = self.directory.holder(key)
            if holder is not None and holder != self.worker_id:
                # A peer already keeps this brick's host copy: drop ours
                # without the DtoH transfer — the brick stays recoverable
                # via the directory (fetch-on-miss path).
                self.stats.duplicate_avoided_bytes += entry.nbytes
                self.directory.duplicates_avoided += 1
                self.directory.duplicate_avoided_bytes += entry.nbytes
                return
        if self.host_budget_bytes is not None \
                and entry.nbytes > self.host_budget_bytes:
            self.stats.evicted_bytes += entry.nbytes
            return
        self._charge(tms, MemoryTier.DEVICE, MemoryTier.HOST,
                     entry.nbytes, "cache/demote")
        self.stats.demoted_bytes += entry.nbytes
        entry = _Entry(self._demote(entry.value), entry.nbytes)
        if self.host_budget_bytes is not None:
            while self._host_used + entry.nbytes > self.host_budget_bytes:
                victim_key, dropped = self._host.popitem(last=False)
                self._host_used -= dropped.nbytes
                self.stats.evicted_bytes += dropped.nbytes
                if self.directory is not None:
                    self.directory.unpublish(victim_key, self.worker_id)
        self._host[key] = entry
        self._host_used += entry.nbytes
        if self.directory is not None:
            self.directory.publish(key, self.worker_id, entry.value,
                                   entry.nbytes)
