"""Out-of-core weight streaming — the AIRES engine applied to parameters.

The paper's dual-way schedule generalizes beyond SpGEMM operands: for a
384-expert MoE whose expert bank exceeds HBM, expert weight bricks play the
role of CSR-A segments (aligned, complete-expert blocks — the RoBW
invariant "never split a row" becomes "never split an expert"), while the
router/attention weights stay resident like CSC-B. Phase II double-buffers
expert uploads against the previous layer's compute.

This module provides the host-side registry + prefetch iterator; the
launcher uses it when `config.stream_weights=True` (kimi-k2). On the real
pod the upload path is host DRAM → HBM DMA; here it is exercised with
jax.device_put (CPU) for tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.io.streamer import DoubleBufferedStreamer


@dataclasses.dataclass
class ExpertBank:
    """Host-resident expert parameters for one layer: dict of (E, ...) arrays."""

    layer: int
    arrays: Dict[str, np.ndarray]   # e.g. w_gate (E, d, f), w_up, w_down

    @property
    def n_experts(self) -> int:
        return next(iter(self.arrays.values())).shape[0]

    def expert_bytes(self) -> int:
        return sum(a[0].nbytes for a in self.arrays.values())

    def slice_experts(self, ids: Sequence[int]) -> Dict[str, np.ndarray]:
        idx = np.asarray(ids)
        return {k: a[idx] for k, a in self.arrays.items()}


class StreamedWeightProvider:
    """RoBW-for-experts: group experts into aligned blocks that fit the
    per-step HBM budget, stream them double-buffered across layers."""

    def __init__(self, banks: List[ExpertBank], hbm_budget_bytes: int,
                 align: int = 8, depth: int = 2,
                 deadline_s: Optional[float] = None):
        self.banks = banks
        self.align = align
        per_expert = banks[0].expert_bytes() if banks else 1
        per_block = max(1, hbm_budget_bytes // max(per_expert, 1))
        # Complete, aligned expert blocks (the RoBW invariant).
        self.block_size = max(align, (per_block // align) * align)
        self.depth = depth
        self.deadline_s = deadline_s

    def blocks_for(self, bank: ExpertBank) -> List[Tuple[int, int]]:
        e = bank.n_experts
        return [(s, min(s + self.block_size, e))
                for s in range(0, e, self.block_size)]

    def stream_layer(self, bank: ExpertBank) -> Iterator:
        """Yield device-resident expert blocks for one layer, prefetched."""
        blocks = self.blocks_for(bank)

        def produce():
            for (s, e) in blocks:
                yield (s, e), bank.slice_experts(range(s, e))

        def upload(payload):
            (s, e), arrays = payload
            return (s, e), {k: jax.device_put(v) for k, v in arrays.items()}

        def consume(dev_payload, i):
            return dev_payload

        streamer = DoubleBufferedStreamer(upload, consume, depth=self.depth,
                                          deadline_s=self.deadline_s)
        yield from streamer.run(produce())
