from repro.io.tiers import (
    MemoryTier,
    TierSpec,
    TieredMemorySystem,
    TransferRecord,
    PAPER_GPU_SYSTEM,
    TPU_V5E_SYSTEM,
)
from repro.io.streamer import DoubleBufferedStreamer, StreamStats

__all__ = [
    "MemoryTier", "TierSpec", "TieredMemorySystem", "TransferRecord",
    "PAPER_GPU_SYSTEM", "TPU_V5E_SYSTEM", "DoubleBufferedStreamer",
    "StreamStats",
]
