from repro.io.tiers import (
    ICI_ALL_TO_ALL,
    ICI_RING,
    ICITopology,
    MemoryTier,
    TierSpec,
    TieredMemorySystem,
    TransferRecord,
    PAPER_GPU_SYSTEM,
    TPU_V5E_SYSTEM,
)
from repro.io.streamer import DoubleBufferedStreamer, StreamStats
from repro.io.segment_cache import (
    CacheDirectory,
    CacheStats,
    SegmentKey,
    TieredSegmentCache,
    prefix_matches,
)
from repro.io.shard_cache import ShardedSegmentCache, shard_of

__all__ = [
    "ICI_ALL_TO_ALL", "ICI_RING", "ICITopology",
    "MemoryTier", "TierSpec", "TieredMemorySystem", "TransferRecord",
    "PAPER_GPU_SYSTEM", "TPU_V5E_SYSTEM", "DoubleBufferedStreamer",
    "StreamStats", "CacheDirectory", "CacheStats", "SegmentKey",
    "TieredSegmentCache", "ShardedSegmentCache", "prefix_matches",
    "shard_of",
]
