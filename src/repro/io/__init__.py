from repro.io.tiers import (
    MemoryTier,
    TierSpec,
    TieredMemorySystem,
    TransferRecord,
    PAPER_GPU_SYSTEM,
    TPU_V5E_SYSTEM,
)
from repro.io.streamer import DoubleBufferedStreamer, StreamStats
from repro.io.segment_cache import CacheStats, SegmentKey, TieredSegmentCache

__all__ = [
    "MemoryTier", "TierSpec", "TieredMemorySystem", "TransferRecord",
    "PAPER_GPU_SYSTEM", "TPU_V5E_SYSTEM", "DoubleBufferedStreamer",
    "StreamStats", "CacheStats", "SegmentKey", "TieredSegmentCache",
]
