"""Tiered memory system: device HBM / host DRAM / secondary storage.

The paper's three tiers are GPU HBM, host memory and NVMe (+GDS path). We
model the same topology with two parameterizations:

  * PAPER_GPU_SYSTEM — RTX 4090-class constants used by the reproduction
    benchmarks (fig6/7/8, tableIII), matching the paper's own simulation
    methodology (§V-A: "We model the I/O transfer operations ... with
    simulations").
  * TPU_V5E_SYSTEM — the deployment target used by the roofline analysis:
    197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI, PCIe-attached host.

Every transfer is accounted (bytes, path, modeled seconds) so benchmarks can
produce the Fig. 7/8 breakdowns; *real* wall-clock host preprocessing (RoBW
partitioning, merging) is measured, not modeled, mirroring the paper's split
between measured CPU work and profiled I/O.
"""
from __future__ import annotations

import dataclasses
import enum
from collections import defaultdict
from typing import Dict, List, Tuple


class MemoryTier(enum.Enum):
    DEVICE = "device"    # GPU HBM / TPU HBM
    HOST = "host"        # CPU DRAM
    STORAGE = "storage"  # NVMe SSD


class Path(enum.Enum):
    """Transfer path; bandwidth differs per path (paper Fig. 8)."""

    DMA = "dma"              # host <-> device over PCIe (cudaMemcpy HtoD/DtoH)
    GDS = "gds"              # storage <-> device direct (GPU Direct Storage)
    STORAGE_HOST = "sio"     # storage <-> host over PCIe
    UM = "um"                # unified-memory page faults (UCG baseline)
    ICI = "ici"              # inter-chip interconnect (TPU only)


@dataclasses.dataclass(frozen=True)
class ICITopology:
    """Inter-chip link topology: how many links a chip-to-chip transfer
    crosses.

    The flat-link model every ICI charge used before is exactly the
    ``all_to_all`` case (every pair of chips one hop apart). ``ring`` is the
    TPU-slice reality for a 1-D mesh axis: chip i reaches chip j over
    min(|i-j|, n-|i-j|) links. `TieredMemorySystem.transfer(..., hops=h)`
    prices an h-hop transfer as h per-link setup latencies plus one
    bandwidth term (the payload is pipelined link to link, but every link
    it crosses carries — and accounts — the bytes).

    Shared by the cost model (`ShardedSegmentCache` charges remote hits and
    shard placements at the owner's hop distance) and the shard-placement
    rewrite pass (`repro.core.passes.ShardPlacementPass` uses the same hop
    counts to prefer near shards when the local one is full).
    """

    kind: str = "all_to_all"   # "all_to_all" | "ring"

    def __post_init__(self):
        if self.kind not in ("all_to_all", "ring"):
            raise ValueError(f"unknown ICI topology kind {self.kind!r} "
                             "(expected 'all_to_all' or 'ring')")

    def hops(self, src: int, dst: int, n_chips: int) -> int:
        """Links crossed from chip `src` to chip `dst` on an `n_chips` axis."""
        if src == dst:
            return 0
        if self.kind == "all_to_all" or n_chips <= 2:
            return 1
        d = abs(int(src) - int(dst)) % n_chips
        return min(d, n_chips - d)


ICI_ALL_TO_ALL = ICITopology("all_to_all")
ICI_RING = ICITopology("ring")


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """Capacities in bytes, bandwidths in bytes/second."""

    device_capacity: int
    host_capacity: int
    storage_capacity: int
    bw: Dict[Path, float]
    latency_s: Dict[Path, float]  # fixed per-transfer setup cost
    hbm_bw: float = 1.0e12        # device memory bandwidth (SpGEMM is bound by it)
    host_memcpy_bw: float = 12e9  # effective single-stream DRAM copy bandwidth
    host_op_latency_s: float = 2e-6  # per host staging/merge event
    # Peak device compute (FLOP/s): the roofline compute term. Single
    # source of truth for benchmarks/roofline.py and the autotuner's
    # roofline cross-check.
    peak_flops: float = 0.0


def _mk(caps, bw_gbs, lat_us, hbm_bw, host_bw=12e9,
        peak_flops=0.0) -> TierSpec:
    return TierSpec(
        device_capacity=caps[0], host_capacity=caps[1], storage_capacity=caps[2],
        bw={p: g * 1e9 for p, g in bw_gbs.items()},
        latency_s={p: u * 1e-6 for p, u in lat_us.items()},
        hbm_bw=hbm_bw, host_memcpy_bw=host_bw, peak_flops=peak_flops,
    )


# RTX 4090 (24 GB, 1008 GB/s) + i9-13900KF (128 GB DDR5) + M.2 NVMe, PCIe gen4.
# ICI here models an NVLink-class peer path for the sharded segment cache:
# cheaper than the PCIe-class DMA/host paths, dearer than local HBM.
PAPER_GPU_SYSTEM = _mk(
    (24 << 30, 128 << 30, 2 << 40),
    {Path.DMA: 22.0, Path.GDS: 6.0, Path.STORAGE_HOST: 6.5, Path.UM: 9.0,
     Path.ICI: 100.0},
    {Path.DMA: 8.0, Path.GDS: 25.0, Path.STORAGE_HOST: 20.0, Path.UM: 4.0,
     Path.ICI: 2.0},
    hbm_bw=1008e9, peak_flops=82.6e12,
)

# TPU v5e chip: 16 GB HBM @ 819 GB/s; host over PCIe; ICI ~50 GB/s/link.
TPU_V5E_SYSTEM = _mk(
    (16 << 30, 512 << 30, 16 << 40),
    {Path.DMA: 32.0, Path.GDS: 8.0, Path.STORAGE_HOST: 8.0, Path.UM: 8.0,
     Path.ICI: 50.0},
    {Path.DMA: 5.0, Path.GDS: 20.0, Path.STORAGE_HOST: 20.0, Path.UM: 4.0,
     Path.ICI: 1.0},
    hbm_bw=819e9, peak_flops=197e12,
)


@dataclasses.dataclass
class TransferRecord:
    path: Path
    src: MemoryTier
    dst: MemoryTier
    nbytes: int               # wire bytes: payload × hops
    seconds: float
    tag: str = ""
    hops: int = 1             # links crossed (payload = nbytes // hops)


class OutOfMemory(RuntimeError):
    """Raised when a tier allocation exceeds capacity (Table III '-')."""


class TieredMemorySystem:
    """Accounting simulator for the three-tier hierarchy.

    Allocations are tracked per tier; transfers append TransferRecords with
    modeled latency = setup + bytes/bw. Channels are independent (dual-way:
    a GDS transfer and a DMA transfer overlap — busy-time is kept per path so
    schedulers can compute overlapped makespans, Fig. 5).
    """

    def __init__(self, spec: TierSpec, keep_records: bool = True):
        self.spec = spec
        self.used: Dict[MemoryTier, int] = {t: 0 for t in MemoryTier}
        self.allocs: Dict[Tuple[MemoryTier, str], int] = {}
        # Per-transfer records power the schedulers' fine-grained breakdowns
        # (one fresh tms per run). Long-lived accounting (a ServingEngine's
        # lifetime tms) sets keep_records=False: only the bounded per-path
        # aggregates below grow, never an unbounded record list.
        self.keep_records = keep_records
        self.transfers: List[TransferRecord] = []
        self.busy_s: Dict[Path, float] = defaultdict(float)
        self._bytes_by_path: Dict[Path, int] = defaultdict(int)
        self._seconds_by_path: Dict[Path, float] = defaultdict(float)
        self._total_bytes = 0

    # ---- allocation -----------------------------------------------------
    def _capacity(self, tier: MemoryTier) -> int:
        return {
            MemoryTier.DEVICE: self.spec.device_capacity,
            MemoryTier.HOST: self.spec.host_capacity,
            MemoryTier.STORAGE: self.spec.storage_capacity,
        }[tier]

    def alloc(self, tier: MemoryTier, name: str, nbytes: int) -> None:
        key = (tier, name)
        new_used = self.used[tier] - self.allocs.get(key, 0) + nbytes
        if new_used > self._capacity(tier):
            raise OutOfMemory(
                f"{tier.value}: need {new_used/2**30:.2f} GiB "
                f"> capacity {self._capacity(tier)/2**30:.2f} GiB ({name})")
        self.used[tier] = new_used
        self.allocs[key] = nbytes

    def free(self, tier: MemoryTier, name: str) -> None:
        key = (tier, name)
        self.used[tier] -= self.allocs.pop(key, 0)

    def headroom(self, tier: MemoryTier) -> int:
        return self._capacity(tier) - self.used[tier]

    # ---- transfer -------------------------------------------------------
    def transfer(self, path: Path, src: MemoryTier, dst: MemoryTier,
                 nbytes: int, tag: str = "", hops: int = 1) -> float:
        """Charge one transfer; returns its modeled seconds.

        `hops` > 1 models a multi-link topology hop (see `ICITopology`):
        the payload pays the per-link setup latency once per link and one
        bandwidth term (links are pipelined), while the byte accounting
        counts the payload on every link it crossed — that is the wire
        traffic the interconnect really carried.
        """
        hops = max(int(hops), 1)
        bw = self.spec.bw[path]
        secs = self.spec.latency_s[path] * hops + nbytes / bw
        wire = int(nbytes) * hops
        if self.keep_records:
            self.transfers.append(
                TransferRecord(path, src, dst, wire, secs, tag, hops=hops))
        self.busy_s[path] += secs
        self._bytes_by_path[path] += wire
        self._seconds_by_path[path] += secs
        self._total_bytes += wire
        return secs

    # ---- reporting (Fig. 7 / Fig. 8) ------------------------------------
    def bytes_by_path(self) -> Dict[Path, int]:
        return dict(self._bytes_by_path)

    def seconds_by_path(self) -> Dict[Path, float]:
        return dict(self._seconds_by_path)

    def total_bytes(self) -> int:
        return self._total_bytes

    def makespan_overlapped(self) -> float:
        """Dual-way makespan: independent channels run concurrently."""
        return max(self.busy_s.values(), default=0.0)

    def makespan_serial(self) -> float:
        """Single-path makespan (baselines without dual-way transfer)."""
        return sum(self.busy_s.values())

    def reset_accounting(self) -> None:
        self.transfers.clear()
        self.busy_s.clear()
        self._bytes_by_path.clear()
        self._seconds_by_path.clear()
        self._total_bytes = 0
