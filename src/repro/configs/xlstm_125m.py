"""xLSTM-125M — sLSTM + mLSTM blocks [arXiv:2405.04517].

12L d_model=768 4H d_ff=0 vocab=50304. Block pattern 1:3 sLSTM:mLSTM
(xLSTM[1:3] per the paper family naming); d_ff=0 — xLSTM blocks carry
their own up-projection, no separate FFN.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    head_dim=192,
    block_pattern=("slstm", "mlstm", "mlstm", "mlstm"),
    dtype="bfloat16",
)

SMOKE = CONFIG.scaled_down(vocab=256, block_pattern=("slstm", "mlstm"),
                           dtype="float32", head_dim=16)
