"""Qwen2-VL-72B — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064; head_dim=128.
M-RoPE sections (t, h, w) = (16, 24, 24) over hd/2=64 slots. The vision
tower is a STUB: input_specs() provides precomputed patch embeddings.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab=152064, head_dim=128,
    mrope_sections=(16, 24, 24), n_vision_tokens=256,
    dtype="bfloat16",
)

SMOKE = CONFIG.scaled_down(dtype="float32", head_dim=16,
                           mrope_sections=(2, 3, 3), n_vision_tokens=8)
