"""Gemma 2 27B — local+global alternating, logit softcaps [arXiv:2408.00118].

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000; head_dim=128;
sliding window 4096 on local layers; attn softcap 50, final logit softcap 30.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab=256000,
    head_dim=128,
    sliding_window=4096,
    local_global_pattern=2,     # local, global, local, global, ...
    attn_softcap=50.0,
    logit_softcap=30.0,
    dtype="bfloat16",
)

SMOKE = CONFIG.scaled_down(dtype="float32")
