"""RecurrentGemma-2B — RG-LRU + local attention, 2:1 [arXiv:2402.19427; hf].

26L d_model=2560 10H (GQA kv=1, i.e. MQA) d_ff=7680 vocab=256000;
block pattern (rglru, rglru, local) per Griffin; lru_width=2560;
local window 2048.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab=256000, head_dim=256,
    sliding_window=2048, lru_width=2560, conv_width=4,
    block_pattern=("rglru", "rglru", "local"),
    dtype="bfloat16",
)

SMOKE = CONFIG.scaled_down(dtype="float32", head_dim=16,
                           block_pattern=("rglru", "local"))
