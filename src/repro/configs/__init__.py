"""Architecture registry: --arch <id> resolves here.

Each module defines CONFIG (full-size, from public literature) — exercised
ONLY via the dry-run (abstract lowering) — and SMOKE (reduced same-family
config) used by CPU tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

_ARCH_IDS: List[str] = [
    "xlstm_125m",
    "kimi_k2_1t_a32b",
    "mixtral_8x22b",
    "gemma2_27b",
    "yi_9b",
    "deepseek_7b",
    "yi_6b",
    "seamless_m4t_medium",
    "recurrentgemma_2b",
    "qwen2_vl_72b",
]

ALIAS = {i.replace("_", "-"): i for i in _ARCH_IDS}


def arch_ids() -> List[str]:
    return list(_ARCH_IDS)


def get_config(arch: str, smoke: bool = False):
    arch = ALIAS.get(arch, arch)
    if arch not in _ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {_ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.SMOKE if smoke else mod.CONFIG


# Input-shape sets shared by all LM archs (assignment spec).
SHAPES: Dict[str, dict] = {
    "train_4k":    dict(kind="train",  seq_len=4_096,   global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32_768, global_batch=32),
    "decode_32k":  dict(kind="decode", seq_len=32_768,  global_batch=128),
    "long_500k":   dict(kind="decode", seq_len=524_288, global_batch=1),
}


def shape_applicable(arch: str, shape: str) -> tuple:
    """(runs: bool, reason: str) — the skip rules from the assignment."""
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch cannot decode at 500k context"
    return True, ""
