"""Mixtral 8x22B — 8 experts top-2, SWA [arXiv:2401.04088; hf].

56L d_model=6144 48H (GQA kv=8) expert d_ff=16384 vocab=32768.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    head_dim=128,
    sliding_window=4096,
    n_experts=8,
    top_k=2,
    expert_d_ff=16384,
    dtype="bfloat16",
)

SMOKE = CONFIG.scaled_down(n_experts=4, top_k=2, dtype="float32")
