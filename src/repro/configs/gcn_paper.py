"""The paper's own architecture: GCN with F=256 features, 99 %-sparse
feature matrix, trained with out-of-core AIRES SpGEMM (§V-A).

Not part of the assigned LM-arch registry (no train_4k/decode shapes);
exercised by the GCN benchmarks (fig3/6/7/8/9, tableIII) and
examples/gcn_train_e2e.py.
"""
from repro.models.gcn import GCNConfig

CONFIG = GCNConfig(
    name="gcn_paper",
    feature_dim=256,
    hidden_dims=(256, 256),
    n_classes=64,
    out_of_core=True,
)

SMOKE = GCNConfig(
    name="gcn_paper_smoke",
    feature_dim=32,
    hidden_dims=(32,),
    n_classes=8,
    out_of_core=True,
)
