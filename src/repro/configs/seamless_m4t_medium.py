"""SeamlessM4T-medium — enc-dec, multimodal [arXiv:2308.11596; hf].

12L enc + 12L dec, d_model=1024 16H (MHA kv=16) d_ff=4096 vocab=256206.
The speech frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, frames, d_model).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="audio",
    n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=256206, head_dim=64,
    encoder_layers=12, audio_frames=1024,
    dtype="bfloat16",
)

SMOKE = CONFIG.scaled_down(dtype="float32")
