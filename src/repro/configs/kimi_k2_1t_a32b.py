"""Kimi K2 — trillion-param MoE, 32B active [arXiv:2501.kimi2 paper-table].

61L d_model=7168 64H (GQA kv=8) vocab=163840, MoE 384 experts top-8,
expert d_ff=2048. Assignment specifies GQA (the production model uses MLA;
the assignment's config is authoritative here).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab=163840,
    head_dim=112,
    n_experts=384,
    top_k=8,
    expert_d_ff=2048,
    dtype="bfloat16",
    stream_weights=True,   # AIRES expert streaming applies (DESIGN §6)
)

SMOKE = CONFIG.scaled_down(n_experts=4, top_k=2, dtype="float32")
