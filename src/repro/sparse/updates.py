"""Edge-delta updates for evolving graphs (ROADMAP "Dynamic graphs").

The paper's motivating workloads (recommendation, PPI) mutate their graphs
continuously. CSRs here are frozen at construction — in-place mutation
raises — so the only mutation path is `apply_edge_updates`, which returns a
*fresh* CSR plus an `EdgeDelta` describing exactly which rows and columns
changed. Downstream, the delta drives the incremental re-tile
(`repro.core.robw.robw_delta_partition`) and segment-key invalidation
(`ServingEngine.update_graph`): update cost scales with the delta, not the
graph.

Untouched rows are preserved **bit-exactly** — the new arrays splice the
old row spans verbatim around rebuilt touched rows — so untouched segments
keep their `segment_fingerprint` and their cached bricks stay valid.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.sparse.formats import CSR, graph_cache_prefix


@dataclasses.dataclass(frozen=True)
class EdgeDelta:
    """What one `apply_edge_updates` call changed.

    `touched_rows` / `touched_cols` are sorted unique index arrays: the
    rows of A whose CSR content changed, and the columns — i.e. the rows of
    Aᵀ — that changed (backward/transposed plans re-tile by column).
    """

    touched_rows: np.ndarray
    touched_cols: np.ndarray
    n_inserted: int
    n_updated: int    # inserts that overwrote an existing edge's value
    n_deleted: int

    def __post_init__(self):
        for arr in (self.touched_rows, self.touched_cols):
            arr.setflags(write=False)

    @property
    def n_changed(self) -> int:
        return self.n_inserted + self.n_updated + self.n_deleted


def _check_bounds(r: int, c: int, shape: Tuple[int, int], what: str) -> None:
    if not (0 <= r < shape[0] and 0 <= c < shape[1]):
        raise IndexError(
            f"{what} ({r}, {c}) outside graph shape {shape[0]}x{shape[1]}")


def apply_edge_updates(
    a: CSR,
    inserts: Optional[Sequence[Tuple[int, int, float]]] = None,
    deletes: Optional[Sequence[Tuple[int, int]]] = None,
) -> Tuple[CSR, EdgeDelta]:
    """Apply edge inserts/deletes to `a`, returning (new CSR, EdgeDelta).

    * `inserts` — (row, col, value) triples. Inserting over an existing
      edge overwrites its value in place (counted in `n_updated`, not
      `n_inserted`). Duplicate (row, col) within one call is an error.
    * `deletes` — (row, col) pairs; deleting an absent edge is an error
      (`KeyError`), as is deleting an edge also being inserted.

    Work is proportional to the touched rows, not the graph: untouched row
    spans are spliced into the output verbatim (bit-exact, including any
    unsorted column order they had), so their segment fingerprints — and
    cached bricks — survive. Rebuilt rows keep surviving entries in their
    original order with overwrites applied; strictly-new edges are merged
    in ascending column order (appended in column order if the row was not
    sorted to begin with). The new CSR inherits `a`'s cache-namespace
    lineage via `graph_key`.
    """
    inserts = list(inserts or ())
    deletes = list(deletes or ())
    if not inserts and not deletes:
        empty = np.zeros(0, dtype=np.int64)
        return a, EdgeDelta(empty, empty.copy(), 0, 0, 0)

    ins_by_pos: Dict[Tuple[int, int], float] = {}
    for r, c, v in inserts:
        r, c = int(r), int(c)
        _check_bounds(r, c, a.shape, "insert")
        if (r, c) in ins_by_pos:
            raise ValueError(f"duplicate insert of edge ({r}, {c})")
        ins_by_pos[(r, c)] = v
    del_set: set = set()
    for r, c in deletes:
        r, c = int(r), int(c)
        _check_bounds(r, c, a.shape, "delete")
        if (r, c) in del_set:
            raise ValueError(f"duplicate delete of edge ({r}, {c})")
        if (r, c) in ins_by_pos:
            raise ValueError(
                f"edge ({r}, {c}) both inserted and deleted in one update")
        del_set.add((r, c))

    by_row: Dict[int, List[Tuple[str, int, float]]] = {}
    for (r, c), v in ins_by_pos.items():
        by_row.setdefault(r, []).append(("ins", c, v))
    for r, c in del_set:
        by_row.setdefault(r, []).append(("del", c, 0.0))

    indptr, indices, data = a.indptr, a.indices, a.data
    row_lengths = np.diff(indptr)
    touched_rows = sorted(by_row)
    touched_cols: set = set()
    n_inserted = n_updated = n_deleted = 0

    # Rebuild each touched row; untouched spans between them are spliced
    # from the old arrays verbatim.
    new_rows: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
    for r in touched_rows:
        lo, hi = int(indptr[r]), int(indptr[r + 1])
        cols = indices[lo:hi].copy()
        vals = data[lo:hi].copy()
        was_sorted = bool(np.all(np.diff(cols) > 0)) if cols.size > 1 else True
        col_pos = {int(c): i for i, c in enumerate(cols)}
        keep = np.ones(cols.shape[0], dtype=bool)
        fresh: List[Tuple[int, float]] = []
        for op, c, v in by_row[r]:
            if op == "del":
                pos = col_pos.get(c)
                if pos is None:
                    raise KeyError(
                        f"delete of absent edge ({r}, {c})")
                keep[pos] = False
                n_deleted += 1
            else:
                pos = col_pos.get(c)
                if pos is not None:
                    vals[pos] = v
                    n_updated += 1
                else:
                    fresh.append((c, v))
                    n_inserted += 1
            touched_cols.add(c)
        cols, vals = cols[keep], vals[keep]
        if fresh:
            fresh.sort()
            f_cols = np.array([c for c, _ in fresh], dtype=indices.dtype)
            f_vals = np.array([v for _, v in fresh], dtype=data.dtype)
            cols = np.concatenate([cols, f_cols])
            vals = np.concatenate([vals, f_vals])
            if was_sorted:
                order = np.argsort(cols, kind="stable")
                cols, vals = cols[order], vals[order]
        new_rows[r] = (cols, vals)

    # Splice: alternate untouched spans (old-array views) and rebuilt rows.
    idx_parts: List[np.ndarray] = []
    val_parts: List[np.ndarray] = []
    prev = 0
    new_lengths = row_lengths.copy()
    for r in touched_rows:
        lo, hi = int(indptr[r]), int(indptr[r + 1])
        if prev < lo:
            idx_parts.append(indices[prev:lo])
            val_parts.append(data[prev:lo])
        cols, vals = new_rows[r]
        idx_parts.append(cols)
        val_parts.append(vals)
        new_lengths[r] = cols.shape[0]
        prev = hi
    if prev < int(indptr[-1]):
        idx_parts.append(indices[prev:])
        val_parts.append(data[prev:])

    new_indptr = np.zeros(a.n_rows + 1, dtype=indptr.dtype)
    np.cumsum(new_lengths, out=new_indptr[1:])
    new_indices = (np.concatenate(idx_parts) if idx_parts
                   else np.zeros(0, dtype=indices.dtype))
    new_data = (np.concatenate(val_parts) if val_parts
                else np.zeros(0, dtype=data.dtype))

    new = CSR(indptr=new_indptr, indices=new_indices, data=new_data,
              shape=a.shape, graph_key=graph_cache_prefix(a))
    delta = EdgeDelta(
        touched_rows=np.asarray(touched_rows, dtype=np.int64),
        touched_cols=np.asarray(sorted(touched_cols), dtype=np.int64),
        n_inserted=n_inserted, n_updated=n_updated, n_deleted=n_deleted)
    return new, delta
