"""RoBW-128 tile densification: CSR row blocks → BlockELL bricks.

This is the Phase-I CPU preprocessing of the paper (Fig. 5) adapted to TPU:
instead of shipping ragged CSR triples, the host scatters each row block's
nonzeros into dense (bm, bk) column-tile bricks that the MXU can consume
directly, and records the tile topology (col_tile ids) for scalar prefetch.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.sparse.formats import CSR, BlockELL


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def tile_csr_to_block_ell(
    a: CSR,
    bm: int = 128,
    bk: int = 128,
    ell_width: Optional[int] = None,
    dtype: np.dtype = np.float32,
) -> BlockELL:
    """Densify CSR into MXU-aligned block-ELL.

    ell_width: max nonzero column tiles kept per row block. None → the true
    max over this segment (exact). If a row block has more populated tiles
    than ell_width, the *least-populated* tiles are dropped — callers that
    need exactness must pass ell_width=None or a verified bucket capacity
    (the memory model guarantees this for AIRES schedules; tests assert it).
    """
    n_rows, n_cols = a.shape
    n_row_blocks = max(1, (n_rows + bm - 1) // bm)
    n_col_tiles = (n_cols + bk - 1) // bk

    # Pass 1: per-row-block tile occupancy (host-side, vectorized numpy).
    per_block_tiles: List[np.ndarray] = []
    per_block_counts: List[np.ndarray] = []
    for rb in range(n_row_blocks):
        lo = a.indptr[min(rb * bm, n_rows)]
        hi = a.indptr[min((rb + 1) * bm, n_rows)]
        tiles = a.indices[lo:hi] // bk
        uniq, counts = np.unique(tiles, return_counts=True)
        per_block_tiles.append(uniq)
        per_block_counts.append(counts)

    true_width = max((t.shape[0] for t in per_block_tiles), default=0)
    if ell_width is None:
        ell_width = max(1, true_width)
    ell_width = max(1, min(ell_width, n_col_tiles))

    blocks = np.zeros((n_row_blocks, ell_width, bm, bk), dtype=dtype)
    col_tile = np.full((n_row_blocks, ell_width), -1, dtype=np.int32)
    n_tiles = np.zeros((n_row_blocks,), dtype=np.int32)

    for rb in range(n_row_blocks):
        uniq, counts = per_block_tiles[rb], per_block_counts[rb]
        if uniq.shape[0] > ell_width:
            # Keep the most-populated tiles (drop the tail). AIRES schedules
            # never hit this branch (bucket capacity ≥ true width).
            keep = np.argsort(-counts, kind="stable")[:ell_width]
            uniq = np.sort(uniq[keep])
        col_tile[rb, : uniq.shape[0]] = uniq
        n_tiles[rb] = uniq.shape[0]

        r0, r1 = rb * bm, min((rb + 1) * bm, n_rows)
        for i in range(r0, r1):
            lo, hi = a.indptr[i], a.indptr[i + 1]
            cols = a.indices[lo:hi]
            vals = a.data[lo:hi]
            t = cols // bk
            # vectorized scatter per kept tile
            for s, tile_id in enumerate(uniq):
                m = t == tile_id
                if m.any():
                    blocks[rb, s, i - r0, cols[m] - tile_id * bk] = vals[m]

    return BlockELL(blocks=blocks, col_tile=col_tile, n_tiles=n_tiles,
                    bm=bm, bk=bk, n_rows=n_rows, n_cols=n_cols)


def block_ell_to_dense(e: BlockELL) -> np.ndarray:
    """Inverse of tile_csr_to_block_ell (for oracles/tests)."""
    n_rows_pad = e.n_row_blocks * e.bm
    n_cols_pad = round_up(e.n_cols, e.bk)
    out = np.zeros((n_rows_pad, n_cols_pad), dtype=e.blocks.dtype)
    for rb in range(e.n_row_blocks):
        for s in range(int(e.n_tiles[rb])):
            t = int(e.col_tile[rb, s])
            out[rb * e.bm : (rb + 1) * e.bm, t * e.bk : (t + 1) * e.bk] += \
                e.blocks[rb, s]
    return out[: e.n_rows, : e.n_cols]
