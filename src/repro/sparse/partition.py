"""Connectivity-clustered graph partitioning for partition-aware sharding.

The sharded segment cache's default owner map is a CRC hash per
`SegmentKey` (`repro.io.shard_cache.shard_of`): uniform over shards, which
is ideal for aggregate capacity and terrible for locality — neighboring
row blocks land on arbitrary shards, so every warm epoch pays ICI ships
that pure topology could avoid. This module is the Cluster-GCN-style cure
(see `/root/related/hacors__Drug/DGL/examples/pytorch/cluster_gcn/` and
Accel-GCN's block-level partitioning, arXiv:2308.11825):

  1. `partition_graph` clusters the CSR adjacency's rows by connectivity
     with a streaming Linear Deterministic Greedy (LDG) pass — pure
     NumPy, no METIS dependency, deterministic (no RNG);
  2. `map_clusters_to_shards` assigns clusters to cache shards by nnz
     under a *bounded-imbalance* nearest-first rule: the local shard (and
     then the topologically nearest shards) fill first, each capped at
     ``balance ×`` the mean per-shard nnz. Exact balance would make every
     owner map ICI-equivalent for a worker that streams the whole plan;
     the bounded local surplus — kept under the analyzer's 2× mean
     `lint/shard-imbalance` threshold — is precisely where the warm-epoch
     ICI win comes from;
  3. the resulting `Partition` derives per-RoBW-segment owner maps
     (`owners_for_plan`) that `ShardedSegmentCache.install_owner_map`
     consumes, cluster ids (`clusters_for_plan`) that
     `ShardPlacementPass` co-places, and row `boundaries()` that
     `robw_partition` tiles over so segments stop straddling cluster
     boundaries.

Edge deltas re-cluster touched rows only (`Partition.refine`): untouched
rows keep their labels and the cluster → shard map is preserved verbatim,
so partition-derived owners survive `apply_edge_update` instead of
snapping back to CRC.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import List, Optional, Sequence

import numpy as np

from repro.io.tiers import ICI_ALL_TO_ALL, ICITopology
from repro.sparse.formats import CSR, graph_cache_prefix

__all__ = [
    "Partition",
    "map_clusters_to_shards",
    "partition_graph",
]


@dataclasses.dataclass(frozen=True)
class Partition:
    """A connectivity clustering of one graph's rows, mapped onto shards.

    `cluster_of[i]` is row i's cluster id; `cluster_to_shard[c]` the cache
    shard that owns cluster c's bricks. `row_nnz` (the source CSR's row
    lengths) makes the per-segment majority votes self-contained — a
    `Partition` prices plans without holding its graph alive.
    """

    cluster_of: np.ndarray          # (n_rows,) int64 cluster id per row
    cluster_to_shard: np.ndarray    # (n_clusters,) int64 shard per cluster
    n_shards: int
    row_nnz: np.ndarray             # (n_rows,) int64 nnz per row
    graph_prefix: str = ""          # graph lineage (graph_cache_prefix)
    token: int = dataclasses.field(default=0)

    def __post_init__(self):
        if self.token == 0:
            blob = (np.ascontiguousarray(self.cluster_of).tobytes()
                    + np.ascontiguousarray(self.cluster_to_shard).tobytes())
            object.__setattr__(self, "token",
                               zlib.crc32(blob) or 1)

    # ---- shape -----------------------------------------------------------

    @property
    def n_rows(self) -> int:
        return int(self.cluster_of.shape[0])

    @property
    def n_clusters(self) -> int:
        return int(self.cluster_to_shard.shape[0])

    @property
    def cluster_nnz(self) -> np.ndarray:
        """Total nnz per cluster — the balance metric shards are packed by."""
        return np.bincount(self.cluster_of, weights=self.row_nnz,
                           minlength=self.n_clusters).astype(np.int64)

    @property
    def shard_nnz(self) -> np.ndarray:
        """Total nnz owned per shard under the cluster → shard map."""
        return np.bincount(self.cluster_to_shard, weights=self.cluster_nnz,
                           minlength=self.n_shards).astype(np.int64)

    # ---- what the placement stack consumes -------------------------------

    def boundaries(self) -> np.ndarray:
        """Row indices where the cluster label changes — the tiling grid
        `robw_partition(boundaries=...)` clamps segment ends to, so no
        RoBW segment straddles a cluster boundary."""
        if self.n_rows == 0:
            return np.empty(0, dtype=np.int64)
        return (np.nonzero(np.diff(self.cluster_of))[0] + 1).astype(np.int64)

    def row_permutation(self) -> np.ndarray:
        """Optional bandwidth-reducing permutation: rows stably sorted by
        cluster id. Relabeling a scattered graph with this makes clusters
        contiguous (fewer, coarser `boundaries()`); the permuted graph is
        a *different* graph (new fingerprint, new cache namespaces)."""
        return np.argsort(self.cluster_of, kind="stable").astype(np.int64)

    def clusters_for_plan(self, plan,
                          row_nnz: Optional[np.ndarray] = None) -> List[int]:
        """Majority-nnz cluster of every RoBW segment in `plan` (row-count
        vote when a segment's rows are all empty). Pass `row_nnz` of the
        actually-streamed matrix for a transposed plan."""
        rn = self.row_nnz if row_nnz is None else np.asarray(row_nnz)
        k = self.n_clusters
        out: List[int] = []
        for seg in plan.segments:
            labs = self.cluster_of[seg.row_start:seg.row_end]
            counts = np.bincount(labs, weights=rn[seg.row_start:seg.row_end],
                                 minlength=k)
            if counts.max(initial=0.0) <= 0.0:
                counts = np.bincount(labs, minlength=k)
            out.append(int(counts.argmax()))
        return out

    def owners_for_plan(self, plan,
                        row_nnz: Optional[np.ndarray] = None) -> List[int]:
        """Owner shard of every RoBW segment in `plan`: its majority
        cluster's shard — the owner map `ShardedSegmentCache.
        install_owner_map` takes, indexed by segment id."""
        return [int(self.cluster_to_shard[c])
                for c in self.clusters_for_plan(plan, row_nnz=row_nnz)]

    # ---- evolving graphs -------------------------------------------------

    def refine(self, a_new: CSR, touched_rows) -> "Partition":
        """Delta re-clustering: re-label only `touched_rows` (majority
        label of their current neighbors; unassignable rows keep their
        label), keeping every other row's cluster AND the cluster → shard
        map verbatim — partition-derived owners survive edge deltas with
        work proportional to the delta, not the graph."""
        if a_new.n_rows != self.n_rows:
            raise ValueError(
                f"refine: graph has {a_new.n_rows} rows, partition covers "
                f"{self.n_rows}")
        labels = self.cluster_of.copy()
        touched = np.unique(np.asarray(touched_rows, dtype=np.int64).ravel())
        if touched.size and (touched[0] < 0 or touched[-1] >= self.n_rows):
            raise IndexError(f"touched rows outside [0, {self.n_rows})")
        k = self.n_clusters
        for i in touched:
            lo, hi = int(a_new.indptr[i]), int(a_new.indptr[i + 1])
            nbrs = a_new.indices[lo:hi]
            nbrs = nbrs[nbrs < self.n_rows]
            if nbrs.size == 0:
                continue
            counts = np.bincount(labels[nbrs], minlength=k)
            labels[i] = int(counts.argmax())
        return Partition(
            cluster_of=labels,
            cluster_to_shard=self.cluster_to_shard.copy(),
            n_shards=self.n_shards,
            row_nnz=np.diff(a_new.indptr).astype(np.int64),
            graph_prefix=self.graph_prefix)

    def describe(self) -> str:
        nnz = self.cluster_nnz
        return (f"Partition({self.n_rows} rows -> {self.n_clusters} "
                f"clusters -> {self.n_shards} shards; cluster nnz "
                f"[{int(nnz.min(initial=0))}, {int(nnz.max(initial=0))}], "
                f"shard nnz {self.shard_nnz.tolist()})")


def map_clusters_to_shards(
    cluster_nnz: Sequence[int],
    n_shards: int,
    topology: ICITopology = ICI_ALL_TO_ALL,
    local_shard: int = 0,
    balance: float = 1.75,
) -> np.ndarray:
    """Pack clusters onto shards: nearest shard first, bounded imbalance.

    Clusters (heaviest nnz first, ties toward the lower id) go to the
    topologically nearest shard — `topology.hops` from `local_shard`, ties
    toward the lower index — that still has room under ``cap = balance ×
    total_nnz / n_shards``; a cluster no shard can take under the cap
    falls back to the least-loaded shard. ``balance`` must stay below the
    analyzer's 2× `lint/shard-imbalance` threshold; the default 1.75
    gives the local shard a 75% surplus over exact balance — the surplus
    is the warm-epoch ICI win — without tripping the lint, and with
    enough slack that near-equal clusters (e.g. ``2 × n_shards`` LDG
    clusters of ~total/2s nnz each) don't sit on the cap's knife edge:
    at 1.5 exactly, ±1% cluster-size jitter decides whether the local
    shard takes its third cluster or bounces it one hop out.
    """
    nnz = np.asarray(cluster_nnz, dtype=np.float64)
    k = int(nnz.shape[0])
    if n_shards <= 1:
        return np.zeros(k, dtype=np.int64)
    if not 0 <= local_shard < n_shards:
        raise ValueError(f"local_shard {local_shard} outside [0, {n_shards})")
    if balance < 1.0:
        raise ValueError(f"balance {balance} < 1: total nnz cannot fit")
    cap = balance * float(nnz.sum()) / n_shards
    by_distance = sorted(
        range(n_shards),
        key=lambda s: (topology.hops(s, local_shard, n_shards), s))
    load = np.zeros(n_shards, dtype=np.float64)
    out = np.zeros(k, dtype=np.int64)
    for c in sorted(range(k), key=lambda c: (-nnz[c], c)):
        w = float(nnz[c])
        dst = next((s for s in by_distance if load[s] + w <= cap), None)
        if dst is None:
            dst = min(range(n_shards),
                      key=lambda s: (load[s],
                                     topology.hops(s, local_shard, n_shards),
                                     s))
        load[dst] += w
        out[c] = dst
    return out


def partition_graph(
    a: CSR,
    n_clusters: int,
    n_shards: int = 1,
    topology: ICITopology = ICI_ALL_TO_ALL,
    local_shard: int = 0,
    balance: float = 1.75,
) -> Partition:
    """Cluster `a`'s rows by connectivity and map clusters onto shards.

    Streaming LDG (Linear Deterministic Greedy) over the rows in order:
    row i scores every cluster by ``(# already-assigned neighbors in it) ×
    (1 − size/capacity)`` and joins the argmax (ties toward the lower
    cluster id); rows with no scored cluster stay with the previous row's
    cluster while it has room (bandable row order is the one prior every
    graph family here satisfies), else seed the least-loaded one.
    Capacity is ``ceil(n_rows / n_clusters)``, so cluster sizes stay
    near-uniform while connected runs of rows co-cluster — one pass,
    O(nnz), deterministic.
    """
    if n_clusters < 1:
        raise ValueError(f"n_clusters must be >= 1, got {n_clusters}")
    n = a.n_rows
    k = max(1, min(int(n_clusters), n)) if n else 1
    capacity = max(1, -(-n // k)) if n else 1
    labels = np.full(n, -1, dtype=np.int64)
    sizes = np.zeros(k, dtype=np.int64)
    indptr, indices = a.indptr, a.indices
    for i in range(n):
        nbrs = indices[indptr[i]:indptr[i + 1]]
        nbr_labels = labels[nbrs[nbrs < n]]
        nbr_labels = nbr_labels[nbr_labels >= 0]
        c = -1
        if nbr_labels.size:
            counts = np.bincount(nbr_labels, minlength=k)
            score = counts * (1.0 - sizes / capacity)
            best = int(score.argmax())
            if score[best] > 0.0:
                c = best
        if c < 0 and i > 0 and sizes[labels[i - 1]] < capacity:
            # Locality prior, NOT least-loaded seeding: a row whose
            # neighbors are all unlabeled (or whose scored clusters are
            # full) stays with its predecessor while that cluster has
            # room. CSR row order is bandable for every family we model
            # (road/kmer locality, SBM blocks, RoBW-friendly orderings),
            # and least-loaded seeding would round-robin the first k
            # rows into k different clusters — smearing every community
            # across all clusters before connectivity has any votes.
            c = int(labels[i - 1])
        if c < 0:
            c = int(sizes.argmin())
        labels[i] = c
        sizes[c] += 1
    row_nnz = np.diff(indptr).astype(np.int64)
    cluster_nnz = np.bincount(labels, weights=row_nnz, minlength=k)
    return Partition(
        cluster_of=labels,
        cluster_to_shard=map_clusters_to_shards(
            cluster_nnz, n_shards, topology=topology,
            local_shard=local_shard, balance=balance),
        n_shards=max(1, int(n_shards)),
        row_nnz=row_nnz,
        graph_prefix=graph_cache_prefix(a))
