"""Sparse matrix substrate: formats, converters, reference SpGEMM.

Host-side structures are numpy (they live on the CPU tier of the memory
hierarchy, exactly like the paper's CSR-A host staging); device-side
structures are JAX arrays with static shapes (BlockELL).
"""
from repro.sparse.formats import (
    CSR,
    CSC,
    COO,
    BlockELL,
    csr_from_dense,
    csc_from_dense,
    csr_to_dense,
    csc_to_dense,
    csr_to_csc,
    csr_transpose,
    csr_row_slice,
    csr_fingerprint,
    segment_fingerprint,
    graph_cache_prefix,
)
from repro.sparse.updates import (
    EdgeDelta,
    apply_edge_updates,
)
from repro.sparse.partition import (
    Partition,
    map_clusters_to_shards,
    partition_graph,
)
from repro.sparse.blocking import (
    tile_csr_to_block_ell,
    block_ell_to_dense,
    round_up,
)
from repro.sparse.ref_spgemm import (
    spgemm_csr_dense,
    spgemm_csr_csc,
    spmm_dense_ref,
)

__all__ = [
    "CSR", "CSC", "COO", "BlockELL",
    "csr_from_dense", "csc_from_dense", "csr_to_dense", "csc_to_dense",
    "csr_to_csc", "csr_transpose", "csr_row_slice",
    "csr_fingerprint", "segment_fingerprint", "graph_cache_prefix",
    "EdgeDelta", "apply_edge_updates",
    "Partition", "map_clusters_to_shards", "partition_graph",
    "tile_csr_to_block_ell", "block_ell_to_dense", "round_up",
    "spgemm_csr_dense", "spgemm_csr_csc", "spmm_dense_ref",
]
