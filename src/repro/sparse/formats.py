"""Compressed sparse formats (paper §II-B, Fig. 2).

CSR/CSC/COO are host-tier containers (numpy) — they model the paper's
host-memory staging of compressed data. BlockELL (see blocking.py) is the
device-tier, MXU-aligned format produced by RoBW preprocessing.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Tuple

import numpy as np


@dataclasses.dataclass
class CSR:
    """Compressed sparse row: A[i, indices[indptr[i]:indptr[i+1]]] = data[...]."""

    indptr: np.ndarray   # (n_rows + 1,) int
    indices: np.ndarray  # (nnz,) int — column ids
    data: np.ndarray     # (nnz,) value dtype
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    def nbytes(self, index_bytes: int = 4) -> int:
        """Host/device footprint of the compressed representation."""
        return int(
            self.indptr.shape[0] * index_bytes
            + self.indices.shape[0] * index_bytes
            + self.data.shape[0] * self.data.dtype.itemsize
        )

    def row_nnz(self) -> np.ndarray:
        return np.diff(self.indptr)

    def validate(self) -> None:
        assert self.indptr.ndim == 1 and self.indptr.shape[0] == self.shape[0] + 1
        assert self.indptr[0] == 0 and self.indptr[-1] == self.nnz
        assert np.all(np.diff(self.indptr) >= 0), "indptr must be monotone"
        if self.nnz:
            assert self.indices.min() >= 0 and self.indices.max() < self.shape[1]


@dataclasses.dataclass
class CSC:
    """Compressed sparse column (the paper's format for matrix B / features)."""

    indptr: np.ndarray   # (n_cols + 1,)
    indices: np.ndarray  # (nnz,) row ids
    data: np.ndarray     # (nnz,)
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    def nbytes(self, index_bytes: int = 4) -> int:
        return int(
            self.indptr.shape[0] * index_bytes
            + self.indices.shape[0] * index_bytes
            + self.data.shape[0] * self.data.dtype.itemsize
        )


@dataclasses.dataclass
class COO:
    rows: np.ndarray
    cols: np.ndarray
    data: np.ndarray
    shape: Tuple[int, int]

    def to_csr(self) -> CSR:
        order = np.lexsort((self.cols, self.rows))
        rows, cols, data = self.rows[order], self.cols[order], self.data[order]
        indptr = np.zeros(self.shape[0] + 1, dtype=np.int64)
        np.add.at(indptr, rows + 1, 1)
        indptr = np.cumsum(indptr)
        return CSR(indptr=indptr, indices=cols.astype(np.int64), data=data,
                   shape=self.shape)


@dataclasses.dataclass
class BlockELL:
    """Device-tier block-ELL: the RoBW-128 tile-densified format (DESIGN §2).

    A row-block segment holds, for each of its `n_row_blocks` row blocks of
    `bm` rows, a fixed budget of `ell_width` column tiles of `bk` columns:

      blocks:   (n_row_blocks, ell_width, bm, bk)  dense value bricks
      col_tile: (n_row_blocks, ell_width) int32    column-tile index (-1 = pad)
      n_tiles:  (n_row_blocks,) int32              valid tiles per row block

    Static shapes → XLA-friendly; padding bricks are zero so the matmul is
    exact. ell_width is the "bucket capacity" chosen by the memory model —
    the TPU adaptation of the paper's dynamic output allocation.
    """

    blocks: np.ndarray
    col_tile: np.ndarray
    n_tiles: np.ndarray
    bm: int
    bk: int
    n_rows: int   # un-padded logical rows covered by this segment
    n_cols: int   # logical column count of A

    @property
    def n_row_blocks(self) -> int:
        return int(self.blocks.shape[0])

    @property
    def ell_width(self) -> int:
        return int(self.blocks.shape[1])

    def nbytes(self) -> int:
        return int(self.blocks.nbytes + self.col_tile.nbytes + self.n_tiles.nbytes)


def csr_fingerprint(a: CSR) -> str:
    """Content fingerprint of a CSR: shape, nnz, and a CRC over the row
    pointers, column ids, AND values.

    Cache namespaces used to key on ``id(a)``, which CPython recycles after
    GC — two different graphs could alias one namespace across runs. The
    fingerprint is content-addressed, so it is also stable across processes
    (checkpointed bricks from one serving process hit in the next) and
    deterministic for sharded-cache placement (`shard_of` CRCs the key).
    Values are part of the hash because cached BlockELL bricks embed them:
    a re-weighted graph with identical sparsity must never hit the old
    graph's bricks. Memoized on the instance; CSRs are contractually
    immutable once cached (mutating one after the first call would serve a
    stale fingerprint).
    """
    memo = getattr(a, "_fingerprint", None)
    if memo is not None:
        return memo
    crc = zlib.crc32(np.ascontiguousarray(a.indptr).tobytes())
    crc = zlib.crc32(np.ascontiguousarray(a.indices).tobytes(), crc)
    crc = zlib.crc32(np.ascontiguousarray(a.data).tobytes(), crc)
    fp = f"{a.shape[0]}x{a.shape[1]}n{a.nnz}c{crc:08x}"
    a._fingerprint = fp
    return fp


def csr_from_dense(dense: np.ndarray) -> CSR:
    rows, cols = np.nonzero(dense)
    data = dense[rows, cols]
    indptr = np.zeros(dense.shape[0] + 1, dtype=np.int64)
    np.add.at(indptr, rows + 1, 1)
    indptr = np.cumsum(indptr)
    return CSR(indptr=indptr, indices=cols.astype(np.int64), data=data,
               shape=dense.shape)


def csc_from_dense(dense: np.ndarray) -> CSC:
    csr_t = csr_from_dense(dense.T)
    return CSC(indptr=csr_t.indptr, indices=csr_t.indices, data=csr_t.data,
               shape=dense.shape)


def csr_to_dense(a: CSR) -> np.ndarray:
    out = np.zeros(a.shape, dtype=a.data.dtype)
    for i in range(a.shape[0]):
        lo, hi = a.indptr[i], a.indptr[i + 1]
        out[i, a.indices[lo:hi]] = a.data[lo:hi]
    return out


def csc_to_dense(b: CSC) -> np.ndarray:
    out = np.zeros(b.shape, dtype=b.data.dtype)
    for j in range(b.shape[1]):
        lo, hi = b.indptr[j], b.indptr[j + 1]
        out[b.indices[lo:hi], j] = b.data[lo:hi]
    return out


def csr_transpose(a: CSR) -> CSR:
    """CSR of Aᵀ — the backward-pass adjacency (dH = Aᵀ dX).

    Vectorized counting sort by column: a stable argsort of the column ids
    groups each output row's entries in source-row order, so the result is
    canonical CSR (column ids strictly grouped, rows sorted). O(nnz log nnz)
    host work, no Python-per-nnz loop — this runs once per training graph in
    the backward planning path.
    """
    counts = np.bincount(a.indices, minlength=a.n_cols)
    indptr = np.zeros(a.n_cols + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    order = np.argsort(a.indices, kind="stable")
    row_of = np.repeat(
        np.arange(a.n_rows, dtype=np.int64), np.diff(a.indptr))
    return CSR(indptr=indptr, indices=row_of[order],
               data=a.data[order], shape=(a.n_cols, a.n_rows))


def csr_to_csc(a: CSR) -> CSC:
    """CSR→CSC re-index. CSC of A stores exactly the arrays of CSR of Aᵀ."""
    t = csr_transpose(a)
    return CSC(indptr=t.indptr, indices=t.indices, data=t.data, shape=a.shape)


def csr_row_slice(a: CSR, start: int, stop: int) -> CSR:
    """Complete-row slice a[start:stop, :] — the RoBW segment extractor.

    By construction this never splits a row: the returned segment is exactly
    the paper's 'complete and unfragmented' block (Fig. 4 bottom).
    """
    stop = min(stop, a.n_rows)
    lo, hi = a.indptr[start], a.indptr[stop]
    indptr = (a.indptr[start : stop + 1] - lo).astype(a.indptr.dtype)
    return CSR(indptr=indptr, indices=a.indices[lo:hi].copy(),
               data=a.data[lo:hi].copy(), shape=(stop - start, a.n_cols))
