"""Compressed sparse formats (paper §II-B, Fig. 2).

CSR/CSC/COO are host-tier containers (numpy) — they model the paper's
host-memory staging of compressed data. BlockELL (see blocking.py) is the
device-tier, MXU-aligned format produced by RoBW preprocessing.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class CSR:
    """Compressed sparse row: A[i, indices[indptr[i]:indptr[i+1]]] = data[...]."""

    indptr: np.ndarray   # (n_rows + 1,) int
    indices: np.ndarray  # (nnz,) int — column ids
    data: np.ndarray     # (nnz,) value dtype
    shape: Tuple[int, int]
    # Lineage token for evolving graphs: `apply_edge_updates` stamps the
    # updated CSR with its ancestor's cache-namespace prefix so untouched
    # segment-cache keys keep matching across edge deltas. None (static
    # graphs) → `graph_cache_prefix` derives the content-addressed prefix.
    graph_key: Optional[str] = None

    def __post_init__(self):
        # CSRs are immutable once constructed: every cache layer
        # (csr_fingerprint's memo, AiresSpGEMM's prepared LRU, the segment
        # cache) keys on content captured at first sight, so an in-place
        # mutation would silently serve stale bricks. Freezing the arrays
        # makes that path fail loudly; edge changes must go through
        # `apply_edge_updates`, which returns a fresh CSR.
        for arr in (self.indptr, self.indices, self.data):
            if isinstance(arr, np.ndarray):
                arr.setflags(write=False)

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    def nbytes(self, index_bytes: int = 4) -> int:
        """Host/device footprint of the compressed representation."""
        return int(
            self.indptr.shape[0] * index_bytes
            + self.indices.shape[0] * index_bytes
            + self.data.shape[0] * self.data.dtype.itemsize
        )

    def row_nnz(self) -> np.ndarray:
        return np.diff(self.indptr)

    def validate(self) -> None:
        assert self.indptr.ndim == 1 and self.indptr.shape[0] == self.shape[0] + 1
        assert self.indptr[0] == 0 and self.indptr[-1] == self.nnz
        assert np.all(np.diff(self.indptr) >= 0), "indptr must be monotone"
        if self.nnz:
            assert self.indices.min() >= 0 and self.indices.max() < self.shape[1]


@dataclasses.dataclass
class CSC:
    """Compressed sparse column (the paper's format for matrix B / features)."""

    indptr: np.ndarray   # (n_cols + 1,)
    indices: np.ndarray  # (nnz,) row ids
    data: np.ndarray     # (nnz,)
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(self.indices.shape[0])

    def nbytes(self, index_bytes: int = 4) -> int:
        return int(
            self.indptr.shape[0] * index_bytes
            + self.indices.shape[0] * index_bytes
            + self.data.shape[0] * self.data.dtype.itemsize
        )


@dataclasses.dataclass
class COO:
    rows: np.ndarray
    cols: np.ndarray
    data: np.ndarray
    shape: Tuple[int, int]

    def to_csr(self) -> CSR:
        order = np.lexsort((self.cols, self.rows))
        rows, cols, data = self.rows[order], self.cols[order], self.data[order]
        indptr = np.zeros(self.shape[0] + 1, dtype=np.int64)
        np.add.at(indptr, rows + 1, 1)
        indptr = np.cumsum(indptr)
        return CSR(indptr=indptr, indices=cols.astype(np.int64), data=data,
                   shape=self.shape)


@dataclasses.dataclass
class BlockELL:
    """Device-tier block-ELL: the RoBW-128 tile-densified format (DESIGN §2).

    A row-block segment holds, for each of its `n_row_blocks` row blocks of
    `bm` rows, a fixed budget of `ell_width` column tiles of `bk` columns:

      blocks:   (n_row_blocks, ell_width, bm, bk)  dense value bricks
      col_tile: (n_row_blocks, ell_width) int32    column-tile index (-1 = pad)
      n_tiles:  (n_row_blocks,) int32              valid tiles per row block

    Static shapes → XLA-friendly; padding bricks are zero so the matmul is
    exact. ell_width is the "bucket capacity" chosen by the memory model —
    the TPU adaptation of the paper's dynamic output allocation.
    """

    blocks: np.ndarray
    col_tile: np.ndarray
    n_tiles: np.ndarray
    bm: int
    bk: int
    n_rows: int   # un-padded logical rows covered by this segment
    n_cols: int   # logical column count of A

    @property
    def n_row_blocks(self) -> int:
        return int(self.blocks.shape[0])

    @property
    def ell_width(self) -> int:
        return int(self.blocks.shape[1])

    def nbytes(self) -> int:
        return int(self.blocks.nbytes + self.col_tile.nbytes + self.n_tiles.nbytes)


def csr_fingerprint(a: CSR) -> str:
    """Content fingerprint of a CSR: shape, nnz, and a CRC over the row
    pointers, column ids, AND values.

    Cache namespaces used to key on ``id(a)``, which CPython recycles after
    GC — two different graphs could alias one namespace across runs. The
    fingerprint is content-addressed, so it is also stable across processes
    (checkpointed bricks from one serving process hit in the next) and
    deterministic for sharded-cache placement (`shard_of` CRCs the key).
    Values are part of the hash because cached BlockELL bricks embed them:
    a re-weighted graph with identical sparsity must never hit the old
    graph's bricks. Memoized on the instance; safe because CSR freezes its
    arrays at construction (``__post_init__``), so the memo cannot go stale
    — in-place mutation raises instead of silently serving old bricks.
    """
    memo = getattr(a, "_fingerprint", None)
    if memo is not None:
        return memo
    crc = zlib.crc32(np.ascontiguousarray(a.indptr).tobytes())
    crc = zlib.crc32(np.ascontiguousarray(a.indices).tobytes(), crc)
    crc = zlib.crc32(np.ascontiguousarray(a.data).tobytes(), crc)
    fp = f"{a.shape[0]}x{a.shape[1]}n{a.nnz}c{crc:08x}"
    a._fingerprint = fp
    return fp


def segment_fingerprint(a: CSR, row_start: int, row_end: int) -> str:
    """Content fingerprint of rows [row_start, row_end) of `a`.

    Position-independent: the row pointers are hashed *relative* to the
    segment start, so the same row content at a different nnz offset (rows
    shifted by an edit elsewhere in the graph) fingerprints identically.
    This is what lets `SegmentKey.fingerprint` keep untouched bricks valid
    across edge deltas — a brick is stale exactly when the rows it encodes
    changed, not when anything anywhere in the CSR changed.
    """
    lo = int(a.indptr[row_start])
    hi = int(a.indptr[row_end])
    rel = np.ascontiguousarray(a.indptr[row_start:row_end + 1] - lo)
    crc = zlib.crc32(rel.tobytes())
    crc = zlib.crc32(np.ascontiguousarray(a.indices[lo:hi]).tobytes(), crc)
    crc = zlib.crc32(np.ascontiguousarray(a.data[lo:hi]).tobytes(), crc)
    return f"s{row_end - row_start}n{hi - lo}c{crc:08x}"


def graph_cache_prefix(a: CSR) -> str:
    """Identity prefix shared by every segment-cache namespace derived for
    `a` (any direction, plan width, or budget).

    Static graphs (graph_key=None) get the content-addressed form
    ``g{fingerprint}:{nnz}:{rows}x{cols}`` — stable across processes, so
    checkpointed bricks warm-start a fresh serving process. Updated graphs
    carry their ancestor's prefix in `graph_key` (stamped by
    `apply_edge_updates`): the prefix then names the *lineage*, and
    per-segment content identity moves into `SegmentKey.fingerprint`, so
    untouched bricks keep hitting after an edge delta.
    """
    if a.graph_key:
        return a.graph_key
    return f"g{csr_fingerprint(a)}:{a.nnz}:{a.shape[0]}x{a.shape[1]}"


def csr_from_dense(dense: np.ndarray) -> CSR:
    rows, cols = np.nonzero(dense)
    data = dense[rows, cols]
    indptr = np.zeros(dense.shape[0] + 1, dtype=np.int64)
    np.add.at(indptr, rows + 1, 1)
    indptr = np.cumsum(indptr)
    return CSR(indptr=indptr, indices=cols.astype(np.int64), data=data,
               shape=dense.shape)


def csc_from_dense(dense: np.ndarray) -> CSC:
    csr_t = csr_from_dense(dense.T)
    return CSC(indptr=csr_t.indptr, indices=csr_t.indices, data=csr_t.data,
               shape=dense.shape)


def csr_to_dense(a: CSR) -> np.ndarray:
    out = np.zeros(a.shape, dtype=a.data.dtype)
    for i in range(a.shape[0]):
        lo, hi = a.indptr[i], a.indptr[i + 1]
        out[i, a.indices[lo:hi]] = a.data[lo:hi]
    return out


def csc_to_dense(b: CSC) -> np.ndarray:
    out = np.zeros(b.shape, dtype=b.data.dtype)
    for j in range(b.shape[1]):
        lo, hi = b.indptr[j], b.indptr[j + 1]
        out[b.indices[lo:hi], j] = b.data[lo:hi]
    return out


def csr_transpose(a: CSR) -> CSR:
    """CSR of Aᵀ — the backward-pass adjacency (dH = Aᵀ dX).

    Vectorized counting sort by column: a stable argsort of the column ids
    groups each output row's entries in source-row order, so the result is
    canonical CSR (column ids strictly grouped, rows sorted). O(nnz log nnz)
    host work, no Python-per-nnz loop — this runs once per training graph in
    the backward planning path.
    """
    counts = np.bincount(a.indices, minlength=a.n_cols)
    indptr = np.zeros(a.n_cols + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    order = np.argsort(a.indices, kind="stable")
    row_of = np.repeat(
        np.arange(a.n_rows, dtype=np.int64), np.diff(a.indptr))
    return CSR(indptr=indptr, indices=row_of[order],
               data=a.data[order], shape=(a.n_cols, a.n_rows))


def csr_to_csc(a: CSR) -> CSC:
    """CSR→CSC re-index. CSC of A stores exactly the arrays of CSR of Aᵀ."""
    t = csr_transpose(a)
    return CSC(indptr=t.indptr, indices=t.indices, data=t.data, shape=a.shape)


def csr_row_slice(a: CSR, start: int, stop: int) -> CSR:
    """Complete-row slice a[start:stop, :] — the RoBW segment extractor.

    By construction this never splits a row: the returned segment is exactly
    the paper's 'complete and unfragmented' block (Fig. 4 bottom).
    """
    stop = min(stop, a.n_rows)
    lo, hi = a.indptr[start], a.indptr[stop]
    indptr = (a.indptr[start : stop + 1] - lo).astype(a.indptr.dtype)
    return CSR(indptr=indptr, indices=a.indices[lo:hi].copy(),
               data=a.data[lo:hi].copy(), shape=(stop - start, a.n_cols))
