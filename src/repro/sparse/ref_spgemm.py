"""Reference SpGEMM / SpMM oracles (pure numpy / jnp).

These are the ground truth every scheduler and kernel is tested against.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.sparse.formats import CSR, CSC, csr_to_dense, csc_to_dense


def spgemm_csr_dense(a: CSR, h: np.ndarray) -> np.ndarray:
    """X = A @ H with CSR A, dense H — row-by-row gather-accumulate.

    This is the semantic the paper's SpGEMM computes for aggregation (Eq. 1).
    """
    n_rows = a.shape[0]
    out = np.zeros((n_rows, h.shape[1]), dtype=np.result_type(a.data.dtype, h.dtype))
    for i in range(n_rows):
        lo, hi = a.indptr[i], a.indptr[i + 1]
        if hi > lo:
            out[i] = a.data[lo:hi] @ h[a.indices[lo:hi]]
    return out


def spgemm_csr_csc(a: CSR, b: CSC) -> np.ndarray:
    """C = A @ B with both operands compressed (paper's general case)."""
    return csr_to_dense(a) @ csc_to_dense(b)


def spmm_dense_ref(a_dense: jnp.ndarray, h: jnp.ndarray) -> jnp.ndarray:
    """jnp oracle used by kernel ref.py and jit paths."""
    return jnp.dot(a_dense, h, preferred_element_type=jnp.float32)
