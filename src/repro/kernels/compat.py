"""JAX / Pallas API-drift shim.

The kernels target both JAX 0.4.x (the container's 0.4.37) and current
releases, whose Pallas TPU surface renamed several entry points:

  =====================  ==========================  =======================
  concept                JAX 0.4.x name              current name
  =====================  ==========================  =======================
  Mosaic compile params  pltpu.TPUCompilerParams     pltpu.CompilerParams
  scalar-prefetch grid   pltpu.PrefetchScalarGridSpec (unchanged, re-exported)
  named-axis size        lax.psum(1, name)           lax.axis_size(name)
  mesh context           `with mesh:`                jax.sharding.use_mesh /
                                                     set_mesh
  AbstractMesh ctor      AbstractMesh(((n, s), ...)) AbstractMesh(sizes, names)
  =====================  ==========================  =======================

Every kernel imports from here instead of touching `pltpu` attributes
directly, so a JAX upgrade is a one-file audit.
"""
from __future__ import annotations

import contextlib
from typing import Any, Sequence

import jax
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "compiler_params",
    "prefetch_scalar_grid_spec",
    "axis_size",
    "use_mesh",
    "make_abstract_mesh",
    "VMEM",
]

# Dense scratch allocations have kept their name; re-export for symmetry so
# kernels can import everything version-sensitive from one module.
VMEM = pltpu.VMEM

_COMPILER_PARAMS_CLS = getattr(
    pltpu, "CompilerParams", None) or getattr(pltpu, "TPUCompilerParams")

_PREFETCH_GRID_CLS = getattr(pltpu, "PrefetchScalarGridSpec")


def compiler_params(*, dimension_semantics: Sequence[str], **kwargs: Any):
    """Mosaic compiler params under whichever class this JAX exposes."""
    return _COMPILER_PARAMS_CLS(
        dimension_semantics=tuple(dimension_semantics), **kwargs)


def prefetch_scalar_grid_spec(*, num_scalar_prefetch: int, grid, in_specs,
                              out_specs, scratch_shapes=()):
    """Scalar-prefetch grid spec (stable name today, shimmed for the next
    rename — grid-spec construction funnels through this one call site)."""
    return _PREFETCH_GRID_CLS(
        num_scalar_prefetch=num_scalar_prefetch,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=list(scratch_shapes),
    )


def axis_size(axis_name: str) -> int:
    """Static size of a named mesh axis from inside shard_map/pmap.

    `lax.axis_size` first appeared after 0.4.x; `lax.psum(1, name)`
    constant-folds to a Python int on every version.
    """
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)


def use_mesh(mesh):
    """Context manager activating `mesh` for jit/GSPMD sharding resolution.

    Current JAX: jax.sharding.use_mesh (or its earlier spelling set_mesh).
    JAX 0.4.x: concrete Mesh objects are themselves context managers;
    AbstractMesh is not and needs no activation there.
    """
    for name in ("use_mesh", "set_mesh"):
        fn = getattr(jax.sharding, name, None)
        if fn is not None:
            return fn(mesh)
    if hasattr(mesh, "__enter__"):
        return mesh
    return contextlib.nullcontext(mesh)


def make_abstract_mesh(axis_sizes: Sequence[int], axis_names: Sequence[str]):
    """AbstractMesh across the ctor signature change.

    Current: AbstractMesh(axis_sizes, axis_names).
    0.4.x:   AbstractMesh(shape_tuple) with (name, size) pairs.
    """
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))
