"""Flash-attention prefill kernel (causal, optional sliding window).

The post-hillclimb roofline shows train/prefill cells memory-bound, with
the S×S score materialization the largest HBM stream (EXPERIMENTS §Perf
iter 5). This kernel keeps scores in VMEM: grid (B, H, S/bq, S/bk) with the
KV-block loop innermost, online-softmax running stats in scratch — the
standard TPU flash schedule. Causal blocks above the diagonal are skipped
via @pl.when (no DMA waste thanks to block-index masking in the index map
being monotone).

Used by the LM stack in place of the lax.map chunked path on real TPUs;
validated in interpret mode against ref.flash_attention_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, bq: int, bk: int, scale: float, causal: bool,
                  window: int):
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    n_k = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = kj * bk

    # Causal/window block culling: process only blocks that intersect the
    # allowed region q_pos >= k_pos (> q_pos - window).
    run = True
    if causal:
        run = k_start <= q_start + bq - 1
    if window > 0:
        run = run & (k_start + bk - 1 > q_start - window)

    @pl.when(run)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = mask & (k_pos <= q_pos)
        if window > 0:
            mask = mask & (k_pos > q_pos - window)
        s = jnp.where(mask, s, -jnp.inf)

        m_prev = m_ref[...]                          # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        # Rows with no valid entries keep m = -inf; exp(-inf - -inf) guard:
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        alpha = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        p = jnp.where(mask, jnp.exp(s - m_safe), 0.0)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_q", "block_k", "causal", "window", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,   # (B, H, S, d)
    k: jax.Array,
    v: jax.Array,
    *,
    block_q: int = 512,
    block_k: int = 512,
    causal: bool = True,
    window: int = 0,       # 0 = no sliding window
    interpret: bool = False,
) -> jax.Array:
    b, h, s, d = q.shape
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    grid = (b, h, s // block_q, s // block_k)
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_flash_kernel, bq=block_q, bk=block_k,
                               scale=scale, causal=causal, window=window)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, qi, kj: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, qi, kj: (b_, h_, kj, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, qi, kj: (b_, h_, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, qi, kj: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            compat.VMEM((block_q, 1), jnp.float32),
            compat.VMEM((block_q, 1), jnp.float32),
            compat.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
