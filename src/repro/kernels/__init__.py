"""Pallas TPU kernels for the compute hot spots the paper optimizes.

The paper's C3 contribution is a specialized tiled compressed matmul (CUDA
in the original); here it is a TPU-native block-ELL SpMM with scalar-prefetch
tile indices (DESIGN §2). Each kernel has a pl.pallas_call implementation
(TPU target, validated with interpret=True on CPU), a jit'd wrapper in
ops.py, and a pure-jnp oracle in ref.py.
"""
from repro.kernels.ops import (
    bcsr_spmm,
    fused_gcn_layer,
    decode_attention,
    flash_attention,
)
from repro.kernels import ref

__all__ = ["bcsr_spmm", "fused_gcn_layer", "decode_attention",
           "flash_attention", "ref"]
