"""Block-ELL × dense SpMM — the paper's tiled compressed matmul on TPU.

X[rb*bm:(rb+1)*bm, ft*bn:(ft+1)*bn] = Σ_s blocks[rb, s] @ H[col_tile[rb, s]]

Grid (n_row_blocks, n_feat_tiles, ell_width); the reduction dim s is
innermost so the output block is revisited and accumulated in place (TPU
'arbitrary' dimension semantics compatible). Tile indices are scalar-
prefetched so the H BlockSpec can route each grid step's HBM→VMEM DMA to the
right column tile — this is the TPU replacement for the CUDA gather loop.

Padded ELL slots (col_tile == -1) are skipped with @pl.when; their DMA is
routed to tile 0 (harmless read) and contributes nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat


def _spmm_kernel(n_tiles_ref, col_tile_ref, a_ref, h_ref, o_ref):
    rb = pl.program_id(0)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(s < n_tiles_ref[rb])
    def _acc():
        o_ref[...] += jnp.dot(
            a_ref[0, 0], h_ref[...], preferred_element_type=jnp.float32
        ).astype(o_ref.dtype)


def _h_index_map(rb, ft, s, n_tiles_ref, col_tile_ref):
    # Route the DMA to the referenced column tile; padded slots read tile 0.
    tile = col_tile_ref[rb, s]
    return (jnp.maximum(tile, 0), ft)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bk", "bn", "interpret", "out_dtype"),
)
def bcsr_spmm_pallas(
    blocks: jax.Array,     # (n_rb, ell_w, bm, bk)
    col_tile: jax.Array,   # (n_rb, ell_w) int32
    n_tiles: jax.Array,    # (n_rb,) int32
    h: jax.Array,          # (K_pad, F_pad) — K_pad % bk == 0, F_pad % bn == 0
    *,
    bm: int,
    bk: int,
    bn: int,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    n_rb, ell_w = blocks.shape[0], blocks.shape[1]
    f_pad = h.shape[1]
    n_ft = f_pad // bn
    grid = (n_rb, n_ft, ell_w)

    out = pl.pallas_call(
        _spmm_kernel,
        grid_spec=compat.prefetch_scalar_grid_spec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (1, 1, bm, bk),
                    lambda rb, ft, s, n_tiles_ref, col_tile_ref: (rb, s, 0, 0),
                ),
                pl.BlockSpec((bk, bn), _h_index_map),
            ],
            out_specs=pl.BlockSpec(
                (bm, bn),
                lambda rb, ft, s, n_tiles_ref, col_tile_ref: (rb, ft),
            ),
        ),
        out_shape=jax.ShapeDtypeStruct((n_rb * bm, f_pad), out_dtype),
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(n_tiles, col_tile, blocks, h)
    return out


def _fused_gcn_kernel(n_tiles_ref, col_tile_ref, a_ref, h_ref, w_ref, b_ref,
                      o_ref, x_scratch):
    """Fused σ((Σ_s A_s H_s) W + b) per row block (chain fusion, Fig. 1).

    Grid (n_rb, ell_w): accumulate the aggregation X tile in VMEM scratch,
    apply the combination matmul + bias + ReLU on the last reduction step —
    X never round-trips to HBM.
    """
    rb = pl.program_id(0)
    s = pl.program_id(1)
    ell_w = pl.num_programs(1)

    @pl.when(s == 0)
    def _init():
        x_scratch[...] = jnp.zeros_like(x_scratch)

    @pl.when(s < n_tiles_ref[rb])
    def _acc():
        x_scratch[...] += jnp.dot(
            a_ref[0, 0], h_ref[...], preferred_element_type=jnp.float32
        )

    @pl.when(s == ell_w - 1)
    def _combine():
        x = x_scratch[...]
        y = jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
        y = y + b_ref[...]
        o_ref[...] = jnp.maximum(y, 0.0).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bk", "interpret", "out_dtype"),
)
def fused_gcn_layer_pallas(
    blocks: jax.Array,    # (n_rb, ell_w, bm, bk)
    col_tile: jax.Array,  # (n_rb, ell_w)
    n_tiles: jax.Array,   # (n_rb,)
    h: jax.Array,         # (K_pad, F)
    w: jax.Array,         # (F, F_out)
    b: jax.Array,         # (F_out,)
    *,
    bm: int,
    bk: int,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jax.Array:
    n_rb, ell_w = blocks.shape[0], blocks.shape[1]
    f = h.shape[1]
    f_out = w.shape[1]
    grid = (n_rb, ell_w)

    out = pl.pallas_call(
        _fused_gcn_kernel,
        grid_spec=compat.prefetch_scalar_grid_spec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (1, 1, bm, bk),
                    lambda rb, s, n_tiles_ref, col_tile_ref: (rb, s, 0, 0),
                ),
                pl.BlockSpec(
                    (bk, f),
                    lambda rb, s, n_tiles_ref, col_tile_ref: (
                        jnp.maximum(col_tile_ref[rb, s], 0), 0),
                ),
                pl.BlockSpec((f, f_out),
                             lambda rb, s, *_: (0, 0)),
                pl.BlockSpec((1, f_out),
                             lambda rb, s, *_: (0, 0)),
            ],
            out_specs=pl.BlockSpec(
                (bm, f_out),
                lambda rb, s, n_tiles_ref, col_tile_ref: (rb, 0),
            ),
            scratch_shapes=[compat.VMEM((bm, f), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((n_rb * bm, f_out), out_dtype),
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(n_tiles, col_tile, blocks, h, w, b.reshape(1, -1))
    return out
