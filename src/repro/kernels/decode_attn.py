"""Flash-decode GQA attention kernel (serve_step hot spot).

One new query token attends to a long KV cache. Grid (batch, kv_head,
kv_blocks) with the KV-block reduction innermost; online-softmax running
max/denominator live in VMEM scratch, so the (S × d) cache streams through
VMEM exactly once — memory-bound roofline behaviour, which is what decode_*
shapes measure.

KV layout (B, n_kv_heads, S, d): head-dim minor, sequence second-minor —
the collective-friendly layout used across the framework.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import compat


def _decode_kernel(lens_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, bs: int, scale: float):
    b = pl.program_id(0)
    s = pl.program_id(2)
    n_s = pl.num_programs(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = lens_ref[b]
    base = s * bs

    @pl.when(base < kv_len)
    def _block():
        q = q_ref[0, 0]          # (group, d)
        k = k_ref[0, 0]          # (bs, d)
        v = v_ref[0, 0]          # (bs, d)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (group, bs)
        pos = base + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        logits = jnp.where(pos < kv_len, logits, -jnp.inf)

        m_prev = m_ref[...]                       # (group, 1)
        m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new)               # (group, bs)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p, v.astype(jnp.float32), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(s == n_s - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_s", "interpret"),
)
def decode_attention_pallas(
    q: jax.Array,        # (B, n_kv, group, d) — GQA-grouped query
    k: jax.Array,        # (B, n_kv, S_pad, d)
    v: jax.Array,        # (B, n_kv, S_pad, d)
    lens: jax.Array,     # (B,) int32 valid KV length per sequence
    *,
    block_s: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b_sz, n_kv, group, d = q.shape
    s_pad = k.shape[2]
    assert s_pad % block_s == 0, (s_pad, block_s)
    n_s = s_pad // block_s
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_decode_kernel, bs=block_s, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid_spec=compat.prefetch_scalar_grid_spec(
            num_scalar_prefetch=1,
            grid=(b_sz, n_kv, n_s),
            in_specs=[
                pl.BlockSpec((1, 1, group, d),
                             lambda b, h, s, lens_ref: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, block_s, d),
                             lambda b, h, s, lens_ref: (b, h, s, 0)),
                pl.BlockSpec((1, 1, block_s, d),
                             lambda b, h, s, lens_ref: (b, h, s, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, group, d),
                                   lambda b, h, s, lens_ref: (b, h, 0, 0)),
            scratch_shapes=[
                compat.VMEM((group, 1), jnp.float32),
                compat.VMEM((group, 1), jnp.float32),
                compat.VMEM((group, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b_sz, n_kv, group, d), q.dtype),
        compiler_params=compat.compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(lens, q, k, v)
    return out
