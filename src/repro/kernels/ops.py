"""jit'd public wrappers around the Pallas kernels.

Handle padding/layout so callers pass natural shapes; pick interpret mode
automatically on CPU (the container target) while lowering to real Mosaic
on TPU.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import bcsr_spmm as _bcsr
from repro.kernels import decode_attn as _dec
from repro.kernels import flash_attn as _flash
from repro.sparse.formats import BlockELL


def _on_cpu() -> bool:
    return jax.devices()[0].platform == "cpu"


def _pad_to(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    size = x.shape[axis]
    target = ((size + multiple - 1) // multiple) * multiple
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads)


def bcsr_spmm(
    ell: BlockELL,
    h: jax.Array,
    *,
    bn: int = 128,
    interpret: Optional[bool] = None,
    out_dtype=jnp.float32,
) -> jax.Array:
    """X = A @ H for a BlockELL segment of A and dense H (n_cols, F).

    Returns (ell.n_rows, F) — padding rows/cols are stripped.
    """
    if interpret is None:
        interpret = _on_cpu()
    f = h.shape[1]
    bn = min(bn, ((f + 127) // 128) * 128)
    h_pad = _pad_to(_pad_to(jnp.asarray(h), 0, ell.bk), 1, bn)
    # Segment column coverage may exceed h rows when A is wider than H rows
    # (never in GCN aggregation: A is n×n, H is n×f).
    need_k = int(np.max(ell.col_tile, initial=0) + 1) * ell.bk
    if h_pad.shape[0] < need_k:
        h_pad = jnp.pad(h_pad, ((0, need_k - h_pad.shape[0]), (0, 0)))
    out = _bcsr.bcsr_spmm_pallas(
        jnp.asarray(ell.blocks),
        jnp.asarray(ell.col_tile),
        jnp.asarray(ell.n_tiles),
        h_pad,
        bm=ell.bm,
        bk=ell.bk,
        bn=bn,
        interpret=interpret,
        out_dtype=out_dtype,
    )
    return out[: ell.n_rows, :f]


def fused_gcn_layer(
    ell: BlockELL,
    h: jax.Array,
    w: jax.Array,
    b: jax.Array,
    *,
    interpret: Optional[bool] = None,
    out_dtype=jnp.float32,
) -> jax.Array:
    """σ((A @ H) @ W + b) fused per row block — Fig. 1 chain without
    materializing X in HBM."""
    if interpret is None:
        interpret = _on_cpu()
    h_pad = _pad_to(jnp.asarray(h), 0, ell.bk)
    need_k = int(np.max(ell.col_tile, initial=0) + 1) * ell.bk
    if h_pad.shape[0] < need_k:
        h_pad = jnp.pad(h_pad, ((0, need_k - h_pad.shape[0]), (0, 0)))
    out = _bcsr.fused_gcn_layer_pallas(
        jnp.asarray(ell.blocks),
        jnp.asarray(ell.col_tile),
        jnp.asarray(ell.n_tiles),
        h_pad,
        jnp.asarray(w),
        jnp.asarray(b),
        bm=ell.bm,
        bk=ell.bk,
        interpret=interpret,
        out_dtype=out_dtype,
    )
    return out[: ell.n_rows]


def decode_attention(
    q: jax.Array,       # (B, n_q_heads, d)
    k: jax.Array,       # (B, n_kv_heads, S, d)
    v: jax.Array,       # (B, n_kv_heads, S, d)
    lens: jax.Array,    # (B,) int32
    *,
    block_s: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """GQA flash-decode. Returns (B, n_q_heads, d)."""
    if interpret is None:
        interpret = _on_cpu()
    b_sz, n_q, d = q.shape
    n_kv = k.shape[1]
    group = n_q // n_kv
    qg = q.reshape(b_sz, n_kv, group, d)
    s = k.shape[2]
    block_s = min(block_s, s)
    k_pad = _pad_to(k, 2, block_s)
    v_pad = _pad_to(v, 2, block_s)
    out = _dec.decode_attention_pallas(
        qg, k_pad, v_pad, lens.astype(jnp.int32),
        block_s=block_s, interpret=interpret)
    return out.reshape(b_sz, n_q, d)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 512, block_k: int = 512,
                    interpret: Optional[bool] = None):
    """Causal/windowed flash attention (B, H, S, d) — prefill hot spot."""
    if interpret is None:
        interpret = _on_cpu()
    s_len = q.shape[2]
    block_q = min(block_q, s_len)
    block_k = min(block_k, s_len)
    return _flash.flash_attention_pallas(
        q, k, v, block_q=block_q, block_k=block_k, causal=causal,
        window=window, interpret=interpret)
