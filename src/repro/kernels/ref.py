"""Pure-jnp oracles for every Pallas kernel (ground truth for tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def bcsr_spmm_ref(blocks, col_tile, n_tiles, h, *, bm: int, bk: int):
    """Densify block-ELL then matmul — exact semantics of the kernel."""
    n_rb, ell_w = blocks.shape[0], blocks.shape[1]
    k_pad, f_pad = h.shape
    n_ct = k_pad // bk
    a_dense = jnp.zeros((n_rb * bm, k_pad), dtype=jnp.float32)
    for rb in range(n_rb):
        for s in range(ell_w):
            t = col_tile[rb, s]
            valid = (s < n_tiles[rb]) & (t >= 0)
            tile = jnp.where(valid, blocks[rb, s].astype(jnp.float32), 0.0)
            t_safe = jnp.clip(t, 0, n_ct - 1)
            a_dense = jax.lax.dynamic_update_slice(
                a_dense,
                jax.lax.dynamic_slice(
                    a_dense, (rb * bm, t_safe * bk), (bm, bk)) + tile,
                (rb * bm, t_safe * bk),
            )
    return jnp.dot(a_dense, h.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def fused_gcn_layer_ref(blocks, col_tile, n_tiles, h, w, b, *, bm: int, bk: int):
    x = bcsr_spmm_ref(blocks, col_tile, n_tiles, h, bm=bm, bk=bk)
    return jnp.maximum(x @ w.astype(jnp.float32) + b.astype(jnp.float32), 0.0)


def decode_attention_ref(q, k, v, lens):
    """(B, n_kv, group, d) GQA decode attention with per-seq valid lengths."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    logits = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    s_pad = k.shape[2]
    pos = jnp.arange(s_pad)[None, None, None, :]
    mask = pos < lens[:, None, None, None]
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhgs,bhsd->bhgd", p, v.astype(jnp.float32)).astype(q.dtype)


def flash_attention_ref(q, k, v, causal=True, window=0):
    """(B, H, S, d) causal/windowed attention oracle."""
    s_len = q.shape[2]
    scale = 1.0 / (q.shape[-1] ** 0.5)
    logits = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(s_len)[:, None]
    k_pos = jnp.arange(s_len)[None, :]
    mask = jnp.ones((s_len, s_len), bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window > 0:
        mask = mask & (k_pos > q_pos - window)
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
