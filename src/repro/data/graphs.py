"""Graph dataset generators matched to paper Table II statistics.

SuiteSparse is unavailable offline, so we synthesize graphs with the same
(vertices, edges, degree-distribution family) per dataset:
  * road/kmer (rUSA, k*) — near-uniform low degree (road & GenBank de Bruijn
    graphs have bounded degree) → uniform random regular-ish.
  * soc-LiveJournal1 — power-law (RMAT).
Benchmarks scale N down by `scale` (CPU container) and print the factor.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Literal

import numpy as np

from repro.sparse.formats import COO, CSR


@dataclasses.dataclass(frozen=True)
class GraphSpec:
    name: str
    n_vertices: int
    n_edges: int
    family: Literal["uniform", "powerlaw"]
    mem_req_gb: float      # Table II "Memory Req."
    mem_constraint_gb: float  # Table II "Memory Constraint"


# Paper Table II, verbatim statistics.
SUITESPARSE_SPECS: Dict[str, GraphSpec] = {
    "rUSA":   GraphSpec("rUSA",   23_940_000, 57_700_000,  "uniform",  3.31, 3.0),
    "kV2a":   GraphSpec("kV2a",   55_040_000, 117_210_000, "uniform",  6.87, 6.0),
    "kU1a":   GraphSpec("kU1a",   67_710_000, 138_770_000, "uniform",  8.20, 8.0),
    "socLJ1": GraphSpec("socLJ1",  4_840_000, 68_990_000,  "powerlaw", 12.14, 11.0),
    "kP1a":   GraphSpec("kP1a",  139_350_000, 297_820_000, "uniform", 17.45, 16.0),
    "kA2a":   GraphSpec("kA2a",  170_720_000, 360_580_000, "uniform", 21.18, 18.0),
    "kV1r":   GraphSpec("kV1r",  214_000_000, 465_410_000, "uniform", 27.18, 23.0),
}


def scaled_spec(spec: GraphSpec, scale: float) -> GraphSpec:
    """Scale vertices/edges down by `scale`, keeping degree structure."""
    return dataclasses.replace(
        spec,
        n_vertices=max(64, int(spec.n_vertices * scale)),
        n_edges=max(128, int(spec.n_edges * scale)),
        mem_req_gb=spec.mem_req_gb * scale,
        mem_constraint_gb=spec.mem_constraint_gb * scale,
    )


def _uniform_edges(n: int, m: int, rng: np.random.Generator):
    rows = rng.integers(0, n, size=m, dtype=np.int64)
    # Road/kmer locality: most edges connect nearby ids (bandable matrix).
    span = max(1, n // 64)
    offs = rng.integers(-span, span + 1, size=m, dtype=np.int64)
    cols = np.clip(rows + offs, 0, n - 1)
    return rows, cols


def _rmat_edges(n: int, m: int, rng: np.random.Generator,
                a=0.57, b=0.19, c=0.19):
    """RMAT power-law generator (socLJ1-like)."""
    scale = int(np.ceil(np.log2(max(n, 2))))
    rows = np.zeros(m, dtype=np.int64)
    cols = np.zeros(m, dtype=np.int64)
    for bit in range(scale):
        r = rng.random(m)
        quad_b = (r >= a) & (r < a + b)
        quad_c = (r >= a + b) & (r < a + b + c)
        quad_d = r >= a + b + c
        rows = rows * 2 + (quad_c | quad_d)
        cols = cols * 2 + (quad_b | quad_d)
    return rows % n, cols % n


def generate_graph(spec: GraphSpec, seed: int = 0,
                   dtype=np.float32) -> CSR:
    """Adjacency CSR with spec's vertex/edge counts and degree family."""
    rng = np.random.default_rng(seed)
    n, m = spec.n_vertices, spec.n_edges
    if spec.family == "powerlaw":
        rows, cols = _rmat_edges(n, m, rng)
    else:
        rows, cols = _uniform_edges(n, m, rng)
    data = np.ones(m, dtype=dtype)
    coo = COO(rows=rows, cols=cols, data=data, shape=(n, n))
    # Deduplicate parallel edges (keep structure simple & exact).
    return _dedup_csr(coo.to_csr(), dtype)


def generate_sbm_graph(n_vertices: int, n_edges: int, n_blocks: int = 4,
                       p_in: float = 0.9, seed: int = 0,
                       dtype=np.float32) -> CSR:
    """Stochastic-block-model adjacency: `n_blocks` contiguous vertex
    blocks, a `p_in` fraction of edges endpoint-confined to one block and
    the rest crossing blocks uniformly.

    This is the clustered-community structure partition-aware sharding
    exploits (see `repro.sparse.partition` and benchmarks/bench_partition):
    connectivity clustering recovers the blocks, so a cluster-aligned
    owner map keeps each block's bricks on one shard. Parallel edges are
    deduplicated exactly like `generate_graph`.
    """
    if n_blocks < 1:
        raise ValueError(f"n_blocks must be >= 1, got {n_blocks}")
    if not 0.0 <= p_in <= 1.0:
        raise ValueError(f"p_in must be in [0, 1], got {p_in}")
    rng = np.random.default_rng(seed)
    n, m = int(n_vertices), int(n_edges)
    block = max(1, n // int(n_blocks))
    rows = rng.integers(0, n, size=m, dtype=np.int64)
    # In-block endpoints: a uniform column inside the row's own block.
    b_lo = (rows // block) * block
    b_hi = np.minimum(b_lo + block, n)
    in_cols = b_lo + (rng.integers(0, block, size=m, dtype=np.int64)
                      % (b_hi - b_lo))
    out_cols = rng.integers(0, n, size=m, dtype=np.int64)
    cols = np.where(rng.random(m) < p_in, in_cols, out_cols)
    coo = COO(rows=rows, cols=cols, data=np.ones(m, dtype=dtype),
              shape=(n, n))
    return _dedup_csr(coo.to_csr(), dtype)


def _dedup_csr(a: CSR, dtype) -> CSR:
    """Drop parallel edges, unit weights (shared by the generators)."""
    n = a.n_rows
    dedup_indices = []
    dedup_data = []
    indptr = [0]
    for i in range(n):
        lo, hi = a.indptr[i], a.indptr[i + 1]
        cols_i = np.unique(a.indices[lo:hi])
        dedup_indices.append(cols_i)
        dedup_data.append(np.ones(cols_i.shape[0], dtype=dtype))
        indptr.append(indptr[-1] + cols_i.shape[0])
    return CSR(
        indptr=np.asarray(indptr, dtype=np.int64),
        indices=(np.concatenate(dedup_indices) if dedup_indices
                 else np.empty(0, np.int64)),
        data=(np.concatenate(dedup_data) if dedup_data
              else np.empty(0, dtype)),
        shape=a.shape,
    )


def normalized_adjacency(a: CSR) -> CSR:
    """Ã = D̂^{-1/2} (A + I) D̂^{-1/2} — paper Eq. (2), kept in CSR."""
    n = a.n_rows
    # A + I
    rows = []
    for i in range(n):
        lo, hi = a.indptr[i], a.indptr[i + 1]
        cols = a.indices[lo:hi]
        if i not in cols:
            cols = np.sort(np.append(cols, i))
        rows.append(cols)
    indptr = np.zeros(n + 1, dtype=np.int64)
    indptr[1:] = np.cumsum([r.shape[0] for r in rows])
    indices = np.concatenate(rows)
    deg = np.diff(indptr).astype(np.float64)
    dinv = 1.0 / np.sqrt(np.maximum(deg, 1.0))
    data = np.empty(indices.shape[0], dtype=a.data.dtype)
    for i in range(n):
        lo, hi = indptr[i], indptr[i + 1]
        data[lo:hi] = (dinv[i] * dinv[indices[lo:hi]]).astype(a.data.dtype)
    return CSR(indptr=indptr, indices=indices, data=data, shape=a.shape)
