"""Synthetic token pipeline for LM-arch training/serving.

Deterministic, seekable, shardable — the properties a production input
pipeline needs for fault-tolerant restart (resume from step k reproduces
the same batch k) and for multi-host sharding (each data-parallel group
reads its own slice).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    shard_index: int = 0      # data-parallel shard
    shard_count: int = 1

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.shard_count == 0
        return self.global_batch // self.shard_count

    def batch_at(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        """Deterministic batch for `step` — restart-safe by construction."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 131 + self.shard_index)
        tokens = rng.integers(
            0, self.vocab_size,
            size=(self.local_batch, self.seq_len), dtype=np.int32)
        labels = np.roll(tokens, -1, axis=1)
        return tokens, labels

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def synthetic_token_batches(vocab: int, seq: int, batch: int, steps: int,
                            seed: int = 0):
    pipe = TokenPipeline(vocab, seq, batch, seed)
    for s in range(steps):
        yield pipe.batch_at(s)
