from repro.data.graphs import (
    GraphSpec,
    SUITESPARSE_SPECS,
    generate_graph,
    generate_sbm_graph,
    normalized_adjacency,
    scaled_spec,
)
from repro.data.tokens import TokenPipeline, synthetic_token_batches

__all__ = [
    "GraphSpec", "SUITESPARSE_SPECS", "generate_graph",
    "generate_sbm_graph", "normalized_adjacency", "scaled_spec",
    "TokenPipeline", "synthetic_token_batches",
]
