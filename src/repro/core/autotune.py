"""Schedule autotuner: search plan knobs by calibrated predicted makespan.

AIRES's schedule has knobs the static defaults cannot pick per graph:

  * `TransferCoalescingPass.min_bytes` — the merge threshold below which
    per-transfer setup latency dominates depends on the (calibrated)
    path's ``bw·latency`` product, not a universal ``1<<18``;
  * the **ELL bucket set** — power-of-two buckets bound compiled-kernel
    count but can pad a narrow-spread graph's bricks far past its true
    tile widths (rUSA-style near-planar graphs pad ~2×); an explicit
    bucket set fitted to the width distribution streams fewer bytes;
  * **pass order** — shard placement before coalescing sees per-brick
    probes; after, it sees merged DMAs.

`autotune_schedule` prices candidates over the plan IR itself: rebuild
the raw stream plan (`AiresSpGEMM.stream_plan(..., apply_passes=False)` —
rewrite passes mutate ops in place, so every trial gets a fresh plan),
apply the candidate `PassPipeline`, and read
`PipelinePlan.estimate(spec)` under the **calibrated** spec the caller
passes (`ServingEngine.cost_spec()`), cold-cache like admission control.
Bucket sets are pre-screened analytically — per-segment true tile widths
(`segment_ell_widths`, no densification) price each candidate set's
exact BlockELL bytes — and only the byte-minimizing set is densified for
a full plan trial. The default arm (power-of-two buckets, documented
``1<<18`` threshold, default pass order) is always in the candidate set,
so the returned `TunedSchedule` is never predicted worse than default.

The engine installs the result via `ServingEngine.install_schedule`.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Sequence, Tuple

from repro.core.passes import (
    PassPipeline,
    PlanPass,
    ShardPlacementPass,
    TransferCoalescingPass,
)
from repro.core.memory_model import ell_bucket_capacity
from repro.core.robw import segment_ell_widths
from repro.io.tiers import TierSpec
from repro.sparse.formats import CSR

__all__ = ["TunedSchedule", "autotune_schedule", "candidate_bucket_sets",
           "bucket_set_bytes"]

DEFAULT_MIN_BYTES = 1 << 18
DEFAULT_PASS_ORDER: Tuple[str, ...] = ("shard-placement",
                                       "transfer-coalescing")
# min_bytes grid: the documented default, a decade around it, and None —
# the spec-derived bw·latency threshold (calibration moves it).
MIN_BYTES_GRID: Tuple[Optional[int], ...] = (
    DEFAULT_MIN_BYTES, None, 1 << 14, 1 << 16, 1 << 20)


@dataclasses.dataclass(frozen=True)
class TunedSchedule:
    """One (graph, system) tuning verdict — what the engine installs.

    `min_bytes=None` means the spec-derived coalescing threshold;
    `ell_buckets=None` keeps the power-of-two bucket ladder (the
    bit-exact default)."""

    graph: str
    min_bytes: Optional[int]
    pass_order: Tuple[str, ...]
    ell_buckets: Optional[Tuple[int, ...]]
    predicted_makespan_s: float
    default_makespan_s: float
    # Exact BlockELL bytes the plan streams under the chosen vs the
    # power-of-two bucket set (equal when ell_buckets is None).
    ell_bytes: int = 0
    default_ell_bytes: int = 0
    # Partition-aware sharding (repro.sparse.partition): cluster count for
    # connectivity-clustered owner maps, None = CRC owners (the bit-exact
    # default). Priced by modeled warm-epoch ICI bytes, not makespan — a
    # cold plan cannot see owner placement.
    partition_clusters: Optional[int] = None
    warm_ici_bytes: int = 0
    default_warm_ici_bytes: int = 0

    @property
    def predicted_speedup(self) -> float:
        return self.default_makespan_s / max(self.predicted_makespan_s,
                                             1e-300)

    @property
    def is_default(self) -> bool:
        return (self.min_bytes == DEFAULT_MIN_BYTES
                and self.pass_order == DEFAULT_PASS_ORDER
                and self.ell_buckets is None
                and self.partition_clusters is None)

    def build_passes(self) -> List[PlanPass]:
        """Instantiate the tuned plan-rewrite passes, in tuned order."""
        made: List[PlanPass] = []
        for name in self.pass_order:
            if name == "shard-placement":
                made.append(ShardPlacementPass())
            elif name == "transfer-coalescing":
                made.append(TransferCoalescingPass(min_bytes=self.min_bytes))
            else:
                raise ValueError(f"unknown tuned pass {name!r}")
        return made

    def describe(self) -> str:
        mb = ("spec-derived" if self.min_bytes is None
              else str(self.min_bytes))
        buckets = ("pow2" if self.ell_buckets is None
                   else list(self.ell_buckets))
        part = ("crc" if self.partition_clusters is None
                else f"{self.partition_clusters} clusters "
                     f"({self.warm_ici_bytes}B warm-ICI vs "
                     f"{self.default_warm_ici_bytes}B)")
        return (f"TunedSchedule({self.graph}: min_bytes={mb}, "
                f"order={'>'.join(self.pass_order)}, buckets={buckets}, "
                f"owners={part}, "
                f"predicted {self.predicted_makespan_s:.3e}s vs default "
                f"{self.default_makespan_s:.3e}s, "
                f"x{self.predicted_speedup:.3f})")


# ---- ELL bucket-set pricing (analytical, no densification) -----------------


def bucket_set_bytes(widths: Sequence[int], seg_rows: Sequence[int],
                     buckets: Optional[Sequence[int]],
                     bm: int, bk: int, dtype_bytes: int = 4) -> int:
    """Exact bytes of every segment's BlockELL brick under a bucket set.

    Mirrors `repro.sparse.formats.BlockELL.nbytes()` exactly: blocks
    ``(n_row_blocks, cap, bm, bk)`` at `dtype_bytes` + int32 col_tile
    ``(n_row_blocks, cap)`` + int32 n_tiles ``(n_row_blocks,)``, with
    ``cap = ell_bucket_capacity(true_width, buckets)``. Raises
    ValueError when a segment's true width exceeds every bucket (the
    set would truncate nonzeros — `ell_bucket_capacity` refuses)."""
    total = 0
    for w, rows in zip(widths, seg_rows):
        cap = ell_bucket_capacity(int(w), list(buckets) if buckets else None)
        nrb = max(1, (int(rows) + bm - 1) // bm)
        total += nrb * cap * bm * bk * dtype_bytes   # blocks
        total += nrb * cap * 4                       # col_tile (int32)
        total += nrb * 4                             # n_tiles (int32)
    return total


def candidate_bucket_sets(widths: Sequence[int], max_buckets: int = 4
                          ) -> List[Optional[Tuple[int, ...]]]:
    """Candidate ELL bucket sets for a graph's true-width distribution:
    always None (the power-of-two default), plus the exact distinct-width
    set when small enough, else a quantile ladder capped at
    `max_buckets` buckets (always including the max width — a set that
    cannot hold the widest segment is invalid)."""
    cands: List[Optional[Tuple[int, ...]]] = [None]
    uniq = sorted(set(int(w) for w in widths))
    if not uniq:
        return cands
    if len(uniq) <= max_buckets:
        cands.append(tuple(uniq))
    else:
        qs = {uniq[int(q * (len(uniq) - 1))]
              for q in (0.25, 0.5, 0.75)} | {uniq[-1]}
        cands.append(tuple(sorted(qs)))
    return cands


# ---- the search ------------------------------------------------------------


def _trial_makespan(engine, a: CSR, shape, spec: TierSpec,
                    passes: List[PlanPass], segment_cache) -> float:
    """Price one candidate: fresh raw plan → candidate pipeline →
    cold-cache estimate (the same reading admission control uses)."""
    plan = engine.stream_plan(a, shape, spec=spec, apply_passes=False)
    pipe = PassPipeline(passes, spec=spec, track_costs=False)
    plan, _ = pipe.apply(plan, spec=spec, segment_cache=segment_cache)
    return plan.estimate(spec).makespan_s


def autotune_schedule(engine, a: CSR, graph: str, width: int,
                      spec: TierSpec, segment_cache=None,
                      min_bytes_grid: Sequence[Optional[int]] = MIN_BYTES_GRID,
                      bucket_sets: Optional[Sequence[Optional[Sequence[int]]]]
                      = None, max_buckets: int = 4,
                      cluster_grid: Optional[Sequence[int]] = None
                      ) -> TunedSchedule:
    """Search (min_bytes × pass order × ELL bucket set) for one graph on
    one (calibrated) system spec; returns the best `TunedSchedule`.

    `engine` is the graph's `AiresSpGEMM`; `spec` the spec to price
    against — pass `ServingEngine.cost_spec()` for the calibrated view.
    The default configuration is always a candidate, so the result's
    `predicted_makespan_s` is ≤ `default_makespan_s` by construction.
    """
    shape = (a.shape[0], int(width))
    cfg = engine.config

    # Arm 1: (min_bytes, pass order) over the current bucket config.
    orders = [DEFAULT_PASS_ORDER] + [
        o for o in itertools.permutations(DEFAULT_PASS_ORDER)
        if tuple(o) != DEFAULT_PASS_ORDER]
    best: Optional[Tuple[float, Optional[int], Tuple[str, ...]]] = None
    default_makespan = None
    for order in orders:
        for mb in min_bytes_grid:
            passes: List[PlanPass] = []
            for name in order:
                passes.append(ShardPlacementPass()
                              if name == "shard-placement"
                              else TransferCoalescingPass(min_bytes=mb))
            makespan = _trial_makespan(engine, a, shape, spec, passes,
                                       segment_cache)
            if (tuple(order) == DEFAULT_PASS_ORDER
                    and mb == DEFAULT_MIN_BYTES):
                default_makespan = makespan
            # Strict < : ties keep the earlier (more default) candidate.
            if best is None or makespan < best[0]:
                best = (makespan, mb, tuple(order))
    assert best is not None and default_makespan is not None
    best_makespan, best_mb, best_order = best

    # Arm 2: ELL bucket sets, pre-screened by exact brick bytes. Only the
    # byte-minimizing non-default set is densified for a full plan trial.
    plan = engine._prepare(a, shape, transpose=False).plan
    widths = segment_ell_widths(a, plan, bm=cfg.bm, bk=cfg.bk)
    seg_rows = [s.row_end - s.row_start for s in plan.segments]
    default_bytes = bucket_set_bytes(widths, seg_rows, None, cfg.bm, cfg.bk)
    cands = (list(bucket_sets) if bucket_sets is not None
             else candidate_bucket_sets(widths, max_buckets=max_buckets))
    best_buckets: Optional[Tuple[int, ...]] = None
    best_bytes = default_bytes
    for cand in cands:
        if cand is None:
            continue
        try:
            nbytes = bucket_set_bytes(widths, seg_rows, cand,
                                      cfg.bm, cfg.bk)
        except ValueError:
            continue  # set cannot hold the widest segment
        if nbytes < best_bytes:
            best_bytes, best_buckets = nbytes, tuple(int(b) for b in cand)

    if best_buckets is not None:
        # Full-plan trial under the candidate bucket set: a throwaway
        # AiresSpGEMM (its cache namespaces carry a bucket tag, so the
        # live engine's keys are untouched) densifies once.
        from repro.core.spgemm import AiresSpGEMM
        cfg2 = dataclasses.replace(cfg, ell_buckets=list(best_buckets))
        eng2 = AiresSpGEMM(cfg2, segment_cache=segment_cache)
        passes = []
        for name in best_order:
            passes.append(ShardPlacementPass()
                          if name == "shard-placement"
                          else TransferCoalescingPass(min_bytes=best_mb))
        bucket_makespan = _trial_makespan(eng2, a, shape, spec, passes,
                                          segment_cache)
        if bucket_makespan < best_makespan:
            best_makespan = bucket_makespan
        else:
            best_buckets = None
    if best_buckets is None:
        best_bytes = default_bytes

    # Arm 3: partition cluster count, priced by modeled warm-epoch ICI
    # bytes (Σ brick bytes × hops to its owner) — the quantity
    # connectivity-clustered owner maps exist to cut. Cold makespan
    # cannot see it: a cold plan streams every brick from host no matter
    # who owns it. Trials run on throwaway engines with NO cache
    # attached, so the live cache's namespaces, pins, and owner maps are
    # untouched (and the `:p{k}` namespace tag isolates them even if a
    # caller wires a cache in later). Strict <, so a uniform graph — or
    # an unsharded cache — keeps the bit-exact CRC default.
    partition_clusters: Optional[int] = None
    warm_ici = default_ici = 0
    n_shards = int(getattr(segment_cache, "n_shards", 1) or 1)
    if n_shards > 1 and hasattr(segment_cache, "ici_hops"):
        from repro.core.spgemm import AiresSpGEMM
        from repro.io.shard_cache import shard_of
        from repro.sparse.partition import partition_graph
        prep0 = engine._prepare(a, shape, transpose=False)
        default_ici = sum(
            ell.nbytes() * segment_cache.ici_hops(shard_of(k, n_shards))
            for ell, k in zip(prep0.ells, engine._segment_keys(prep0)))
        warm_ici = default_ici
        grid = (tuple(cluster_grid) if cluster_grid is not None
                else (n_shards, 2 * n_shards, 4 * n_shards))
        cfg3 = (dataclasses.replace(cfg, ell_buckets=list(best_buckets))
                if best_buckets is not None else cfg)
        for k in grid:
            if not 1 < int(k) <= a.shape[0]:
                continue
            part = partition_graph(
                a, int(k), n_shards=n_shards,
                topology=segment_cache.topology,
                local_shard=segment_cache.local_shard)
            eng3 = AiresSpGEMM(cfg3, partition=part)
            prep3 = eng3._prepare(a, shape, transpose=False)
            owners = part.owners_for_plan(prep3.plan)
            trial = sum(ell.nbytes() * segment_cache.ici_hops(o)
                        for ell, o in zip(prep3.ells, owners))
            if trial < warm_ici:  # ties keep fewer clusters / the default
                warm_ici, partition_clusters = trial, int(k)

    return TunedSchedule(
        graph=graph, min_bytes=best_mb, pass_order=best_order,
        ell_buckets=best_buckets, predicted_makespan_s=best_makespan,
        default_makespan_s=default_makespan,
        ell_bytes=best_bytes, default_ell_bytes=default_bytes,
        partition_clusters=partition_clusters,
        warm_ici_bytes=int(warm_ici),
        default_warm_ici_bytes=int(default_ici))
