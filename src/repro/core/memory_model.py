"""AIRES analytical memory model — paper Eq. (5), (6), (7).

The model answers, *before any data is loaded* (paper §III-B last paragraph):
given device memory M, how much must be reserved for the resident matrix B
(M_B, Eq. 6) and the output C (M_C, Eq. 5), and what per-segment budget p
remains for streaming CSR A (Eq. 7)?

On TPU the same model additionally chooses the BlockELL *bucket capacity*
(ell_width): XLA's static shapes turn the paper's `cudaMalloc`-style dynamic
allocation into capacity planning (DESIGN §2).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.sparse.formats import CSR


@dataclasses.dataclass(frozen=True)
class FeatureSpec:
    """Shape/sparsity proxy for the feature matrix H (paper's CSC B).

    The paper trains with F=256 at 99% *uniform* sparsity (§V-A), stored
    compressed — simulate-mode schedulers only need this proxy, never the
    values. sparsity_pct=0 models the dense-resident TPU adaptation.
    """

    n_rows: int
    n_cols: int
    dtype_bytes: int = 4
    sparsity_pct: float = 0.0
    index_bytes: int = 4

    @property
    def dense_bytes(self) -> int:
        return self.n_rows * self.n_cols * self.dtype_bytes

    @property
    def nnz(self) -> int:
        return int(self.dense_bytes / self.dtype_bytes
                   * (100.0 - self.sparsity_pct) / 100.0)

    @property
    def value_bytes(self) -> int:
        """α_B of Eq. (5)/(6)."""
        return self.nnz * self.dtype_bytes

    @property
    def compressed_bytes(self) -> int:
        """M_B of Eq. (6): values + column ids + row pointers."""
        if self.sparsity_pct <= 0.0:
            return self.dense_bytes
        return (self.value_bytes + self.nnz * self.index_bytes
                + (self.n_cols + 1) * self.index_bytes)

    @classmethod
    def of(cls, h) -> "FeatureSpec":
        """Accept a FeatureSpec, a numpy array, or (n, f) tuple."""
        if isinstance(h, cls):
            return h
        if hasattr(h, "shape") and hasattr(h, "dtype"):
            return cls(h.shape[0], h.shape[1], h.dtype.itemsize, 0.0)
        n, f = h
        return cls(n, f)


@dataclasses.dataclass(frozen=True)
class MemoryEstimate:
    m_b: float          # bytes reserved for resident matrix B (Eq. 6)
    m_c: float          # bytes reserved for output C (Eq. 5)
    p: float            # per-segment byte budget for streamed CSR A (Eq. 7)
    m_total: float      # device budget
    feasible: bool      # p > 0 — can the schedule run at all?

    @property
    def m_a(self) -> float:
        return self.p * 3.0  # Eq. 7 inverted: segment budget covers 3 arrays


def estimate_output_bytes(
    alpha_a: float,
    alpha_b: float,
    sparsity_a_pct: float,
    sparsity_b_pct: float,
) -> float:
    """Eq. (5): M_C = 3·α_A·(100−s_A)/100 · (1 + α_B/α_A + (100−s_B)/100).

    α = value-array byte size of the compressed matrix, s = sparsity %.
    The leading 3 models CSR C's three arrays (values/indices/indptr).
    """
    dens_a = (100.0 - sparsity_a_pct) / 100.0
    dens_b = (100.0 - sparsity_b_pct) / 100.0
    return 3.0 * alpha_a * dens_a * (1.0 + alpha_b / max(alpha_a, 1.0) + dens_b)


def estimate_resident_bytes(alpha_b: float, beta_b: float, theta_b: float) -> float:
    """Eq. (6): M_B = α_B + β_B + θ_B (values + column ids + row ids)."""
    return alpha_b + beta_b + theta_b


def segment_budget(m_total: float, m_c: float, m_b: float) -> float:
    """Eq. (7): p = (M − M_C − M_B) / 3."""
    return (m_total - m_c - m_b) / 3.0


def plan_memory(
    a: CSR,
    b_nbytes_values: float,
    b_nbytes_colid: float,
    b_nbytes_rowid: float,
    m_total: float,
    sparsity_b_pct: float = 99.0,
    index_bytes: int = 4,
) -> MemoryEstimate:
    """Run Eq. 5–7 for a concrete (A, B, budget) triple."""
    alpha_a = float(a.nnz * a.data.dtype.itemsize)
    n_total = float(a.shape[0]) * float(a.shape[1])
    sparsity_a_pct = 100.0 * (1.0 - a.nnz / max(n_total, 1.0))
    alpha_b = float(b_nbytes_values)
    m_c = estimate_output_bytes(alpha_a, alpha_b, sparsity_a_pct, sparsity_b_pct)
    m_b = estimate_resident_bytes(alpha_b, b_nbytes_colid, b_nbytes_rowid)
    p = segment_budget(m_total, m_c, m_b)
    return MemoryEstimate(m_b=m_b, m_c=m_c, p=p, m_total=m_total,
                          feasible=p > 0.0)


def plan_memory_unified(
    a: CSR,
    feat,
    m_total: float,
    index_bytes: int = 4,
) -> MemoryEstimate:
    """THE Eq. 5-7 planner — single reading for compressed AND dense features.

    `feat` is anything `FeatureSpec.of` accepts. α_A/α_B enter Eq. 5 as the
    DENSE value-array sizes, so α_A·(100−s_A)/100 recovers the compressed
    nnz-bytes. This reading is self-consistent for hypersparse graph
    adjacencies (s_A → 100%), where interpreting α as the compressed size
    would make M_C vanish. The resulting estimate,
    M_C ≈ 3·nnz_A·itemsize·(1 + α_B/α_A + dens_B), matches the expected
    output fill E[matches per A-nonzero] ≈ F·dens_B for uniform B.

    With sparsity_pct=0 (dense-resident TPU mode, DESIGN §2 dual-path) the
    output C = X is dense (N, F), so M_C is additionally capped at the dense
    footprint — Eq. 5 is an upper bound for compressed C.

    Both historical entry points (`plan_memory_spec` for compressed feature
    matrices, `plan_memory_dense_features` for the dense GCN aggregation)
    are thin wrappers over this function, so they agree by construction —
    in particular they produce the same M_C for dense features, which lets
    the simulate↔execute cross-check hand both planners the same budget.
    """
    feat = FeatureSpec.of(feat)
    itemsize = float(a.data.dtype.itemsize)
    n_total = float(a.shape[0]) * float(a.shape[1])
    alpha_a_dense = n_total * itemsize
    alpha_b_dense = float(feat.dense_bytes)
    sparsity_a_pct = 100.0 * (1.0 - a.nnz / max(n_total, 1.0))
    m_c = estimate_output_bytes(alpha_a_dense, alpha_b_dense,
                                sparsity_a_pct, feat.sparsity_pct)
    if feat.sparsity_pct <= 0.0:
        m_c = min(m_c, float(a.shape[0]) * feat.n_cols * feat.dtype_bytes)
    m_b = float(feat.compressed_bytes)
    p = segment_budget(m_total, m_c, m_b)
    return MemoryEstimate(m_b=m_b, m_c=m_c, p=p, m_total=m_total,
                          feasible=p > 0.0)


def plan_memory_spec(
    a: CSR,
    feat: "FeatureSpec",
    m_total: float,
    index_bytes: int = 4,
) -> MemoryEstimate:
    """Eq. 5-7 with compressed (or dense) feature accounting.

    Thin wrapper over `plan_memory_unified` (the paper-faithful reading),
    kept for its established name.
    """
    return plan_memory_unified(a, feat, m_total, index_bytes=index_bytes)


def required_bytes(a: CSR, feat: "FeatureSpec") -> float:
    """Table II 'Memory Req.': combined size of A, B and C."""
    est = plan_memory_unified(a, feat, m_total=float("inf"))
    return float(a.nbytes()) + est.m_b + est.m_c


def plan_memory_dense_features(
    a: CSR,
    n_nodes: int,
    feature_dim: int,
    m_total: float,
    feature_bytes: int = 4,
    index_bytes: int = 4,
) -> MemoryEstimate:
    """Memory plan for GCN aggregation X = Ã·H with *dense* device features.

    On TPU the feature matrix H is dense-resident (DESIGN §2 dual-path):
    M_B = N·F·bytes, and M_C is Eq. 5 capped at the dense X footprint. Thin
    wrapper over `plan_memory_unified` with a sparsity_pct=0 FeatureSpec —
    identical, by construction, to `plan_memory_spec` on the same dense
    spec (the two used to read Eq. 5 differently; see ROADMAP history).
    """
    return plan_memory_unified(
        a, FeatureSpec(n_nodes, feature_dim, feature_bytes, 0.0,
                       index_bytes=index_bytes),
        m_total, index_bytes=index_bytes)


def calc_mem(k_rows: int, q_nnz: int, value_bytes: int = 4,
             index_bytes: int = 4) -> int:
    """`calcMem(k, q)` from Algorithm 1: bytes for a k-row, q-nnz CSR block.

    (k+1) row pointers + q column ids + q values.
    """
    return (k_rows + 1) * index_bytes + q_nnz * (index_bytes + value_bytes)


def ell_bucket_capacity(true_width: int, buckets: Optional[list] = None) -> int:
    """Pick the BlockELL bucket ≥ true tile width (powers of two).

    TPU adaptation of dynamic allocation: segments are padded to the chosen
    bucket so recompiles only happen across buckets, not per segment.

    With an explicit bucket list, a `true_width` larger than every bucket is
    an error: silently returning `max(buckets)` would pad the segment to a
    capacity *smaller* than its true tile width, truncating nonzeros.
    """
    if true_width <= 0:
        return 1
    if buckets:
        for b in sorted(buckets):
            if b >= true_width:
                return b
        raise ValueError(
            f"ell_bucket_capacity: true_width {true_width} exceeds every "
            f"explicit bucket {sorted(buckets)} — a segment padded to "
            f"{max(buckets)} would silently truncate; add a larger bucket "
            "or omit `buckets` for the power-of-two path")
    return 1 << max(0, math.ceil(math.log2(true_width)))
