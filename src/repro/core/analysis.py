"""Static plan analyzer: semantic verification of `PipelinePlan`s.

`PipelinePlan.validate()` catches *structural* malformation (dangling,
self-, forward deps; undeclared phases). Semantic bugs — a rewrite pass
that oversubscribes a tier, drops bytes, or leaves a cache retain racing
its consumer — used to surface only as wrong interpreter output or a
runtime `OutOfMemory`. This module is the semantic layer: it runs over
any plan *without interpreting it* and returns an :class:`AnalysisReport`
of coded :class:`Finding`s, the way TVM/Halide verify schedules before
lowering. Three analyses:

1. **Tier-budget liveness** (``mem/*``) — replay the plan's `AllocOp`s
   symbolically against the `TierSpec` capacities, with the same
   same-name-realloc-replaces semantics as `TieredMemorySystem.alloc`,
   and flag point-in-time oversubscription. A plan with no
   ``mem/oversubscription`` finding is guaranteed to interpret without
   `OutOfMemory` at those capacities (allocs are the interpreters' only
   OOM source) — property-tested in tests/test_analysis.py.

2. **Lane-hazard race detection** (``race/*``) — build the
   happens-before relation the cost model defines (explicit `deps`;
   lane serialization within a ``lanes`` phase; total order within a
   ``serial`` phase; declared phase order as a barrier, since the
   makespan sums phase spans in that order) and flag pairs of ops that
   touch the same resource — a cache `SegmentKey`, an alloc ``(tier,
   name)`` slot, a pin — while unordered. Unordered same-resource ops
   mean list order is carrying semantics the dep graph does not, so a
   legal rewrite pass could reorder them and change behavior.

3. **Byte conservation + semantic lints** (``bytes/*``, ``lint/*``) —
   :func:`path_byte_totals` reads a plan's cold per-path byte totals
   statically; `PassPipeline(strict=True)` diffs them across every
   rewrite (centralizing what the `TransferCoalescingPass` tests used
   to assert ad hoc), plus rules for zero/negative-byte transfers, a
   probe's miss transfer not landing in the device tier, allocs whose
   tier no later op touches, out-of-range placement overrides,
   duplicate `SegmentKey` retains with conflicting fingerprints, and
   pins/payloads dangling after `release_payloads`.

Wiring: the interpreters take ``analyze=`` (None → module default,
flipped on under tests by tests/conftest.py); `PassPipeline(strict=True)`
analyzes after every pass and attaches findings to its `PassReport`s;
`EngineConfig.analyze_plans` forces it per serving engine; and
scripts/lint_plans.py runs the analyzer over every benchmark-built plan
in CI.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.core.pipeline import (
    AllocOp,
    CacheProbeOp,
    ComputeOp,
    HostPreprocessOp,
    PipelinePlan,
    TransferOp,
)
from repro.io.tiers import MemoryTier, TierSpec

__all__ = [
    "AnalysisReport",
    "Finding",
    "PlanAnalysisError",
    "RULES",
    "analyze_plan",
    "default_analyze",
    "diff_path_totals",
    "path_byte_totals",
    "set_default_analyze",
]

SEVERITY_ERROR = "error"
SEVERITY_WARNING = "warning"
SEVERITY_INFO = "info"

# The rule catalog. Codes are stable API: tests, CI lint output and the
# README table reference them by name — never renumber, only append.
RULES: Dict[str, str] = {
    "mem/oversubscription":
        "Replaying the plan's AllocOps exceeds a TierSpec capacity — the "
        "interpreters would raise OutOfMemory at this op.",
    "race/segment-key":
        "Two cache probes of the same SegmentKey are unordered in "
        "happens-before: a rewrite pass could legally reorder a retain "
        "past the probe that expects it resident.",
    "race/alloc-name":
        "Two AllocOps of the same (tier, name) slot are unordered: the "
        "surviving reservation depends on list order alone.",
    "race/pin":
        "Two probes pin the same graph's working set with different pin "
        "objects while unordered: which pin the cache ends up holding "
        "depends on list order alone.",
    "race/unconsumed-payload":
        "A payload-bearing stream op has no ComputeOp ordered after it: "
        "the upload's consumer is not tied down, so a rewrite could "
        "consume the double-buffer slot before the upload is ordered.",
    "bytes/path-delta":
        "A rewrite pass changed a plan's per-path byte totals (emitted "
        "by PassPipeline(strict=True), not by analyze_plan).",
    "lint/negative-bytes":
        "A transfer, alloc or probe declares negative bytes.",
    "lint/zero-byte-transfer":
        "A transfer moves zero bytes: it pays full path setup latency "
        "for no traffic.",
    "lint/miss-dst-tier":
        "A cache probe's miss transfer does not land in the device tier, "
        "but the probe's retain puts the value in the cache's device "
        "tier — the two accountings disagree.",
    "lint/alloc-unreferenced":
        "An AllocOp reserves a tier that no later op transfers through, "
        "computes on, or probes into.",
    "lint/bad-placement":
        "A probe's place_shard override is outside the segment cache's "
        "shard range.",
    "lint/dangling-pin":
        "A released plan (release_payloads ran) still holds a pin, "
        "payload or kernel closure — it would pin the working set the "
        "release exists to drop.",
    "lint/duplicate-key-conflict":
        "Two probes retain the same logical segment (graph, segment, "
        "wire format, shape) under conflicting content fingerprints — "
        "one of them is serving a stale generation.",
    "lint/shard-imbalance":
        "One shard owns more than 2x the mean per-shard wire bytes of "
        "the plan's cache probes — the owner map (CRC, partition, or "
        "placement overrides) is concentrating the working set on one "
        "shard. Emitted only for plans with at least 8 probes per shard; "
        "smaller plans cannot spread evenly by pigeonhole.",
}

# Module default for the interpreters' `analyze=None`: off in production
# (analysis costs O(ops²/64) per interpretation), flipped on for the whole
# suite by an autouse fixture in tests/conftest.py.
_DEFAULT_ANALYZE = False


def default_analyze() -> bool:
    return _DEFAULT_ANALYZE


def set_default_analyze(value: bool) -> bool:
    """Set the module default; returns the previous value (for restore)."""
    global _DEFAULT_ANALYZE
    previous = _DEFAULT_ANALYZE
    _DEFAULT_ANALYZE = bool(value)
    return previous


@dataclasses.dataclass(frozen=True)
class Finding:
    """One coded analyzer finding. `ops` are indices into `plan.ops`."""

    rule: str
    severity: str
    message: str
    ops: Tuple[int, ...] = ()

    def __str__(self) -> str:
        where = f" @ ops {list(self.ops)}" if self.ops else ""
        return f"[{self.severity}] {self.rule}{where}: {self.message}"


class PlanAnalysisError(ValueError):
    """A plan carries error-severity findings. Raised by
    `AnalysisReport.raise_for_errors()` — i.e. by the interpreters under
    ``analyze=True`` and by `PassPipeline(strict=True)` after a pass."""

    def __init__(self, report: "AnalysisReport"):
        self.report = report
        lines = "\n  ".join(str(f) for f in report.errors)
        super().__init__(
            f"plan {report.scheduler!r} failed static analysis with "
            f"{len(report.errors)} error(s):\n  {lines}")


@dataclasses.dataclass
class AnalysisReport:
    """All findings of one `analyze_plan` run, most severe first."""

    scheduler: str
    findings: List[Finding] = dataclasses.field(default_factory=list)

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == SEVERITY_ERROR]

    @property
    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == SEVERITY_WARNING]

    @property
    def ok(self) -> bool:
        return not self.errors

    def by_rule(self, rule: str) -> List[Finding]:
        return [f for f in self.findings if f.rule == rule]

    def raise_for_errors(self) -> "AnalysisReport":
        if self.errors:
            raise PlanAnalysisError(self)
        return self


# ---- byte accounting (shared with PassPipeline strict mode + pass tests) ---


def path_byte_totals(plan: PipelinePlan) -> Dict[str, int]:
    """A plan's cold per-path byte totals, read statically.

    Every `TransferOp` counts its declared bytes; every `CacheProbeOp`
    counts its miss transfer (the cold, cache-empty reading — what the
    plan *moves* independent of live cache state). Rewrite passes
    re-arrange the same bytes, so this reading must be invariant across
    `PassPipeline.apply` — the `bytes/path-delta` rule."""
    totals: Dict[str, int] = {}
    for bound in plan.ops:
        op = bound.op
        if isinstance(op, TransferOp):
            t = op
        elif isinstance(op, CacheProbeOp):
            t = op.miss
        else:
            continue
        totals[t.path.value] = totals.get(t.path.value, 0) + int(t.nbytes)
    return totals


def diff_path_totals(before: Dict[str, int],
                     after: Dict[str, int]) -> Dict[str, int]:
    """Nonzero per-path deltas (after − before); {} iff bytes conserved."""
    return {p: after.get(p, 0) - before.get(p, 0)
            for p in set(before) | set(after)
            if after.get(p, 0) != before.get(p, 0)}


# ---- happens-before ---------------------------------------------------------


def _ancestor_masks(plan: PipelinePlan) -> List[int]:
    """Per-op bitmask of transitive happens-before predecessors.

    Edges mirror the cost model exactly: explicit `deps`; same-lane list
    order within a ``lanes`` phase (lane availability serializes); full
    list order within a ``serial`` phase (no overlap at all); and every
    op of an earlier-declared phase precedes every op of a later one
    (the makespan sums phase spans in declared order — a barrier)."""
    n = len(plan.ops)
    overlap = {ph.name: ph.overlap for ph in plan.phases}
    phase_mask: Dict[str, int] = {ph.name: 0 for ph in plan.phases}
    for i, bound in enumerate(plan.ops):
        phase_mask[bound.phase] |= 1 << i
    earlier: Dict[str, int] = {}
    acc = 0
    for ph in plan.phases:
        earlier[ph.name] = acc
        acc |= phase_mask[ph.name]

    anc = [0] * n
    last_serial: Dict[str, int] = {}
    last_lane: Dict[Tuple[str, str], int] = {}
    for i, bound in enumerate(plan.ops):
        mask = earlier.get(bound.phase, 0)
        if overlap.get(bound.phase, "lanes") == "serial":
            p = last_serial.get(bound.phase)
            if p is not None:
                mask |= anc[p] | (1 << p)
            last_serial[bound.phase] = i
        elif bound.lane:
            key = (bound.phase, bound.lane)
            p = last_lane.get(key)
            if p is not None:
                mask |= anc[p] | (1 << p)
            last_lane[key] = i
        for d in bound.deps:
            mask |= anc[d] | (1 << d)
        anc[i] = mask
    return anc


def _ordered(anc: List[int], i: int, j: int) -> bool:
    return bool((anc[j] >> i) & 1 or (anc[i] >> j) & 1)


# ---- the analyzer -----------------------------------------------------------


def analyze_plan(plan: PipelinePlan,
                 spec: Optional[TierSpec] = None,
                 segment_cache: Any = None,
                 released: bool = False) -> AnalysisReport:
    """Statically analyze `plan`; never interprets, charges or mutates.

    `spec` enables the tier-budget liveness rules (without capacities
    there is nothing to oversubscribe). `segment_cache` bounds placement
    overrides. `released=True` additionally checks the post-
    `release_payloads` contract (`lint/dangling-pin`). Structural
    problems still raise `PlanValidationError` — analysis assumes a
    structurally valid plan (deps backward, phases declared).
    """
    plan.validate()
    report = AnalysisReport(scheduler=plan.scheduler)
    if plan.oom:
        # The builder already declared this plan infeasible; interpreters
        # return an OOM result without touching the op list, so there is
        # nothing to analyze.
        return report
    findings = report.findings
    anc = _ancestor_masks(plan)

    _check_liveness(plan, spec, findings)
    _check_races(plan, anc, findings)
    _check_lints(plan, segment_cache, findings)
    if released:
        _check_released(plan, findings)

    order = {SEVERITY_ERROR: 0, SEVERITY_WARNING: 1, SEVERITY_INFO: 2}
    findings.sort(key=lambda f: (order.get(f.severity, 3), f.rule, f.ops))
    return report


def _check_liveness(plan: PipelinePlan, spec: Optional[TierSpec],
                    findings: List[Finding]) -> None:
    """mem/*: symbolic AllocOp replay against the TierSpec capacities."""
    for i, bound in enumerate(plan.ops):
        op = bound.op
        if isinstance(op, AllocOp) and int(op.nbytes) < 0:
            findings.append(Finding(
                "lint/negative-bytes", SEVERITY_ERROR,
                f"alloc {op.name!r} reserves {op.nbytes} bytes", (i,)))
    if spec is None:
        return
    caps = {
        MemoryTier.DEVICE: spec.device_capacity,
        MemoryTier.HOST: spec.host_capacity,
        MemoryTier.STORAGE: spec.storage_capacity,
    }
    used: Dict[MemoryTier, int] = {t: 0 for t in caps}
    held: Dict[Tuple[MemoryTier, str], int] = {}
    blown: set = set()
    for i, bound in enumerate(plan.ops):
        op = bound.op
        if not isinstance(op, AllocOp) or int(op.nbytes) < 0:
            continue
        slot = (op.tier, op.name)
        # Same-name realloc replaces — mirror TieredMemorySystem.alloc.
        used[op.tier] += int(op.nbytes) - held.get(slot, 0)
        held[slot] = int(op.nbytes)
        if used[op.tier] > caps[op.tier] and op.tier not in blown:
            blown.add(op.tier)
            findings.append(Finding(
                "mem/oversubscription", SEVERITY_ERROR,
                f"alloc {op.name!r} brings {op.tier.value} residency to "
                f"{used[op.tier]} bytes, over the {caps[op.tier]}-byte "
                "capacity — interpretation would raise OutOfMemory here",
                (i,)))


def _check_races(plan: PipelinePlan, anc: List[int],
                 findings: List[Finding]) -> None:
    """race/*: same-resource op pairs unordered in happens-before."""
    by_key: Dict[Any, List[int]] = {}
    by_slot: Dict[Tuple[MemoryTier, str], List[int]] = {}
    by_pin: Dict[Any, List[int]] = {}
    payload_ops: List[int] = []
    consumed = 0
    for i, bound in enumerate(plan.ops):
        op = bound.op
        if isinstance(op, CacheProbeOp):
            by_key.setdefault(op.key, []).append(i)
            if op.pin is not None:
                gid = getattr(op.key, "graph_id", op.key)
                by_pin.setdefault(gid, []).append(i)
            if op.payload is not None:
                payload_ops.append(i)
        elif isinstance(op, AllocOp):
            by_slot.setdefault((op.tier, op.name), []).append(i)
        elif isinstance(op, TransferOp) and op.payload is not None:
            payload_ops.append(i)
        elif isinstance(op, ComputeOp):
            consumed |= anc[i]

    def flag_unordered(groups: Dict[Any, List[int]], rule: str,
                       severity: str, what: str) -> None:
        for res, members in groups.items():
            for a_pos, i in enumerate(members):
                for j in members[a_pos + 1:]:
                    if not _ordered(anc, i, j):
                        findings.append(Finding(
                            rule, severity,
                            f"ops {i} and {j} both touch {what} {res!r} "
                            "but neither happens-before the other",
                            (i, j)))

    flag_unordered(by_key, "race/segment-key", SEVERITY_ERROR,
                   "cache key")
    flag_unordered(by_slot, "race/alloc-name", SEVERITY_ERROR,
                   "alloc slot")
    # Pins race only when the pinned objects differ — re-pinning the same
    # working set from two unordered probes is idempotent.
    distinct_pins = {
        gid: members for gid, members in by_pin.items()
        if len({id(plan.ops[i].op.pin) for i in members}) > 1}
    flag_unordered(distinct_pins, "race/pin", SEVERITY_WARNING, "pin for")

    for i in payload_ops:
        if not (consumed >> i) & 1:
            findings.append(Finding(
                "race/unconsumed-payload", SEVERITY_WARNING,
                f"payload-bearing op {i} has no ComputeOp ordered after "
                "it — its double-buffer slot is consumed at an order the "
                "plan does not pin down", (i,)))


def _check_lints(plan: PipelinePlan, segment_cache: Any,
                 findings: List[Finding]) -> None:
    """lint/*: per-op semantic rules."""
    n_shards = getattr(segment_cache, "n_shards", None)
    tiers_after: List[set] = [set() for _ in plan.ops]
    touched: set = set()
    by_identity: Dict[Tuple, Dict[str, int]] = {}
    owner_bytes: Dict[int, int] = {}
    owned_probes = 0
    for i in range(len(plan.ops) - 1, -1, -1):
        tiers_after[i] = set(touched)
        touched |= _touched_tiers(plan.ops[i].op)

    for i, bound in enumerate(plan.ops):
        op = bound.op
        if isinstance(op, TransferOp):
            if int(op.nbytes) < 0:
                findings.append(Finding(
                    "lint/negative-bytes", SEVERITY_ERROR,
                    f"transfer {op.tag!r} moves {op.nbytes} bytes", (i,)))
            elif int(op.nbytes) == 0:
                findings.append(Finding(
                    "lint/zero-byte-transfer", SEVERITY_WARNING,
                    f"transfer {op.tag!r} on {op.path.value} moves zero "
                    "bytes but pays full setup latency", (i,)))
        elif isinstance(op, CacheProbeOp):
            if int(op.wire_bytes) < 0 or int(op.miss.nbytes) < 0:
                findings.append(Finding(
                    "lint/negative-bytes", SEVERITY_ERROR,
                    f"probe of {op.key!r} declares negative bytes", (i,)))
            if op.miss.dst is not MemoryTier.DEVICE:
                findings.append(Finding(
                    "lint/miss-dst-tier", SEVERITY_ERROR,
                    f"probe miss transfer lands in {op.miss.dst.value}, "
                    "but the retain puts the value in the cache's device "
                    "tier", (i,)))
            if op.place_shard is not None:
                bad = op.place_shard < 0 or (
                    n_shards is not None and op.place_shard >= n_shards)
                if bad:
                    findings.append(Finding(
                        "lint/bad-placement", SEVERITY_ERROR,
                        f"place_shard={op.place_shard} is outside the "
                        f"cache's shard range [0, {n_shards})", (i,)))
            ident = (getattr(op.key, "graph_id", None),
                     getattr(op.key, "segment_id", None),
                     getattr(op.key, "wire_format", None),
                     getattr(op.key, "shape", None))
            fp = getattr(op.key, "fingerprint", None)
            if None not in ident and fp is not None:
                by_identity.setdefault(ident, {}).setdefault(fp, i)
            # Owner-balance accounting: a place_shard override wins, else
            # the cache's owner map (partition or CRC) resolves the key.
            if n_shards is not None and n_shards > 1:
                s = op.place_shard
                if s is None and hasattr(segment_cache, "owner_of"):
                    s = segment_cache.owner_of(op.key)
                if s is not None and 0 <= int(s) < n_shards:
                    owner_bytes[int(s)] = (owner_bytes.get(int(s), 0)
                                           + int(op.wire_bytes))
                    owned_probes += 1
        elif isinstance(op, AllocOp):
            if op.tier not in tiers_after[i]:
                findings.append(Finding(
                    "lint/alloc-unreferenced", SEVERITY_WARNING,
                    f"alloc {op.name!r} reserves {op.tier.value} but no "
                    "later op transfers through, computes on, or probes "
                    "into that tier", (i,)))

    for ident, fps in by_identity.items():
        if len(fps) > 1:
            findings.append(Finding(
                "lint/duplicate-key-conflict", SEVERITY_ERROR,
                f"segment {ident!r} is retained under "
                f"{len(fps)} conflicting fingerprints "
                f"{sorted(fps)!r} — one generation is stale",
                tuple(sorted(fps.values()))))

    # Probe-count gate: below 8 probes per shard, one big segment can
    # exceed 2x the mean by pigeonhole alone — only enough probes make
    # imbalance a property of the owner map rather than of granularity.
    if (n_shards is not None and n_shards > 1
            and owned_probes >= 8 * n_shards):
        total = sum(owner_bytes.values())
        mean = total / n_shards
        worst = max(owner_bytes, key=lambda s: (owner_bytes[s], -s))
        if mean > 0 and owner_bytes[worst] > 2 * mean:
            findings.append(Finding(
                "lint/shard-imbalance", SEVERITY_WARNING,
                f"shard {worst} owns {owner_bytes[worst]} of the plan's "
                f"{total} probe wire bytes — more than 2x the "
                f"{mean:.0f}-byte per-shard mean across {n_shards} "
                "shards", ()))


def _touched_tiers(op: Any) -> set:
    """Which memory tiers an op reads or writes (for alloc-unreferenced)."""
    if isinstance(op, TransferOp):
        return {op.src, op.dst}
    if isinstance(op, CacheProbeOp):
        return {op.miss.src, op.miss.dst, MemoryTier.DEVICE}
    if isinstance(op, ComputeOp):
        return {MemoryTier.DEVICE}
    if isinstance(op, HostPreprocessOp):
        return {MemoryTier.HOST}
    return set()


def _check_released(plan: PipelinePlan, findings: List[Finding]) -> None:
    """lint/dangling-pin: the post-release_payloads contract."""
    for i, bound in enumerate(plan.ops):
        op = bound.op
        leftovers = []
        if isinstance(op, CacheProbeOp):
            if op.pin is not None:
                leftovers.append("pin")
            if op.payload is not None or op.miss.payload is not None:
                leftovers.append("payload")
        elif isinstance(op, TransferOp) and op.payload is not None:
            leftovers.append("payload")
        elif isinstance(op, ComputeOp) and op.kernel is not None:
            leftovers.append("kernel")
        if leftovers:
            findings.append(Finding(
                "lint/dangling-pin", SEVERITY_ERROR,
                f"released plan still holds {'+'.join(leftovers)} on op "
                f"{i} — release_payloads exists to drop exactly these",
                (i,)))
    if plan.reference_kernel is not None:
        findings.append(Finding(
            "lint/dangling-pin", SEVERITY_ERROR,
            "released plan still holds its reference kernel", ()))
