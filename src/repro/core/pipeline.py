"""Pipeline-plan IR — one typed op-graph for every scheduler.

AIRES's three-phase schedule (dual-way Phase I loads, double-buffered
Phase II streaming, device-resident Phase III) used to live four times over
as ~100-line `run()` monoliths in `core/scheduler.py`, each interleaving
Eq. 5-7 planning, DMA cost charging, cache probing and real kernel
execution — so simulate and execute modes could silently diverge, and every
new feature had to be hand-threaded through four copies. Following the
schedule-description / execution-backend split of batched SpGEMM
(arXiv:1903.11409) and GE-SpMM (arXiv:2007.03179), this module separates
the two:

  * **plan builders** (the schedulers, `AiresSpGEMM`) emit a
    :class:`PipelinePlan` — a typed list of ops (:class:`TransferOp`,
    :class:`ComputeOp`, :class:`CacheProbeOp`, :class:`HostPreprocessOp`,
    :class:`AllocOp`) grouped into phases, each op on a declared resource
    lane (DMA channel, GDS path, host CPU, compute unit) with explicit
    dependencies;
  * **two interpreters** consume the same plan:

      - :class:`CostInterpreter` charges every transfer through a
        `TieredMemorySystem` and computes the overlap-aware makespan from
        per-lane availability — this *is* simulate mode;
      - :class:`ExecuteInterpreter` additionally runs the plan's kernel
        thunks (scheduler execute mode) and, for the real engine path,
        drives a `DoubleBufferedStreamer` over the plan's stream ops
        (:meth:`ExecuteInterpreter.stream`).

Simulate-vs-execute agreement is therefore true by construction — one
plan, two interpreters — instead of cross-checked by test scaffolding.
`PipelinePlan.estimate()` exposes a side-effect-free cost reading (cache
probes peek, never mutate) that the serving engine uses for admission
control.

Makespan semantics per phase (`PhaseSpec.overlap`):

  * ``"lanes"`` — ops on the same lane serialize on that lane's
    availability; an op additionally waits for its `deps`. The phase span
    is the latest completion. This reproduces the paper's Fig. 5 overlap:
    Phase I's GDS load rides its own lane against the A-load + RoBW chain,
    and Phase II's double buffering falls out of DMA-lane serialization
    plus compute→transfer dependencies.
  * ``"serial"`` — no overlap: the span is (transfer seconds) + (host
    seconds) + (compute seconds), the accounting the MaxMemory/UCG
    baselines use.

The plan-level makespan is the sum of phase spans, in declared phase order.
"""
from __future__ import annotations

import dataclasses
from typing import (
    Any, Callable, Dict, List, Literal, Optional, Sequence, Tuple, Union,
)

import numpy as np

from repro.io.tiers import (
    MemoryTier,
    OutOfMemory,
    Path,
    TieredMemorySystem,
    TierSpec,
)

# Resource lanes. Lanes are per-phase serial resources: two ops on the same
# lane of the same phase never overlap; ops on different lanes do (unless
# tied by deps). Names match the transfer paths they model where relevant.
LANE_DMA = "dma"
LANE_GDS = "gds"
LANE_SIO = "sio"
LANE_UM = "um"
LANE_HOST = "host"
LANE_COMPUTE = "compute"


@dataclasses.dataclass
class ScheduleMetrics:
    """Everything the paper's figures read off a run.

    Produced by the interpreters; kept importable from
    `repro.core.scheduler` (its historical home) for compatibility.
    """

    scheduler: str
    dataset: str = ""
    # Latency components (seconds)
    host_preprocess_s: float = 0.0   # modeled: RoBW / densify / merge / pack
    host_measured_s: float = 0.0     # wall-clock of the real host work (diagnostic)
    io_modeled_s: float = 0.0        # modeled: sum of transfer seconds
    compute_modeled_s: float = 0.0   # modeled: device kernel seconds
    makespan_s: float = 0.0          # overlapped end-to-end estimate
    # I/O accounting (Fig. 7/8)
    bytes_by_path: Dict[str, int] = dataclasses.field(default_factory=dict)
    seconds_by_path: Dict[str, float] = dataclasses.field(default_factory=dict)
    total_transfer_bytes: int = 0
    cache_hit_bytes: int = 0         # wire bytes served by the segment cache
    merge_events: int = 0
    merge_io_s: float = 0.0          # modeled DtoH/HtoD seconds for merges
    segments: int = 0
    oom: bool = False

    def merge_overhead_frac(self) -> float:
        """Fig. 3 metric: 'merging the partial segments, and data transfer
        time between the GPU and host memory ... measured over the
        computation latency'."""
        denom = max(self.compute_modeled_s, 1e-12)
        return (self.host_preprocess_s + self.merge_io_s) / denom


def modeled_spgemm_seconds(nnz: int, feat, spec: TierSpec,
                           compute_efficiency: float = 0.20) -> float:
    """Device time for a compressed-×-compressed partial product.

    Hypersparse SpGEMM is HBM-bound, not FLOP-bound: per A-nonzero the
    kernel reads the A entry, gathers the matching B row segment
    (dens_B·F values+ids) and writes ~E[matches] C entries. Effective
    bandwidth is a fraction of peak (irregular access). Shared by the
    scheduler plan builders and `AiresSpGEMM.stream_plan` so cost
    estimates agree wherever a plan is built.
    """
    dens_b = (100.0 - feat.sparsity_pct) / 100.0
    val = feat.dtype_bytes
    idx = feat.index_bytes
    per_nnz = (val + idx) + dens_b * feat.n_cols * (val + idx) \
        + max(dens_b * feat.n_cols, 1.0) * (val + idx)
    bytes_touched = nnz * per_nnz
    return bytes_touched / (spec.hbm_bw * compute_efficiency)


# ---- ops -------------------------------------------------------------------


@dataclasses.dataclass
class AllocOp:
    """Reserve `nbytes` of `tier` under `name` (raises OutOfMemory at
    interpret time if the tier's capacity is exceeded — Table III '-')."""

    tier: MemoryTier
    name: str
    nbytes: int


@dataclasses.dataclass
class TransferOp:
    """One modeled transfer over `path`. `merge` marks partial-row merge
    traffic (feeds `ScheduleMetrics.merge_io_s`, the Fig. 3 numerator).
    `payload` optionally carries the real host payload `(index, data)` for
    the execute interpreter's streaming backend."""

    path: Path
    src: MemoryTier
    dst: MemoryTier
    nbytes: int
    tag: str = ""
    merge: bool = False
    payload: Any = None


@dataclasses.dataclass
class ComputeOp:
    """One device-kernel slot: `seconds` of modeled time, optionally a
    real `kernel(out)` thunk the execute interpreter runs (writes its
    row-slice of the plan's output buffer)."""

    seconds: float
    flops: float = 0.0
    kernel: Optional[Callable[[np.ndarray], None]] = None


@dataclasses.dataclass
class CacheProbeOp:
    """Probe the segment cache for `key`; on miss, perform the fallback
    `miss` transfer and retain `value` under the key. A device-tier hit is
    free wire traffic; a host-tier hit costs the promotion DMA (charged by
    the cache itself). `payload` as on TransferOp.

    `place_shard` is a placement override written by the shard-placement
    rewrite pass (`repro.core.passes.ShardPlacementPass`): the miss's
    retain lands on that cache shard instead of the key's CRC owner, so a
    graph's hot bricks live where they are consumed. None = default owner.
    """

    key: Any                 # io.segment_cache.SegmentKey
    wire_bytes: int
    miss: TransferOp
    value: Any = True
    pin: Any = None
    payload: Any = None
    place_shard: Optional[int] = None


@dataclasses.dataclass
class HostPreprocessOp:
    """Host CPU work (RoBW pass, staging memcpy, partial-row merge):
    `modeled_s` enters the makespan, `measured_s` is the wall-clock of the
    real work the plan builder performed (diagnostic only)."""

    modeled_s: float
    measured_s: float = 0.0


OpKind = Union[AllocOp, TransferOp, ComputeOp, CacheProbeOp, HostPreprocessOp]


class PlanValidationError(ValueError):
    """A structurally malformed `PipelinePlan`: dangling, self-, forward or
    cyclic dependencies, or ops in undeclared phases. Raised by
    `PipelinePlan.validate()` — and by the interpreters before running —
    instead of letting a bad dep silently read a completion time of 0.0
    and mis-order the lane-availability makespan."""


@dataclasses.dataclass
class PlanOp:
    """An op bound into the plan: its phase, its resource lane, and the
    indices of ops it must wait for (beyond lane availability)."""

    op: OpKind
    phase: str
    lane: str = ""
    deps: Tuple[int, ...] = ()


@dataclasses.dataclass
class PhaseSpec:
    name: str
    overlap: Literal["lanes", "serial"] = "lanes"


@dataclasses.dataclass
class PipelinePlan:
    """A scheduler's entire I/O + compute schedule as data.

    Built once by a plan builder; consumed by either interpreter. `oom`
    marks a plan the builder already knows is infeasible (Eq. 7 p ≤ 0,
    static split cannot fit B, ...): interpreters return an OOM result
    without touching the op list.
    """

    scheduler: str
    dataset: str = ""
    phases: List[PhaseSpec] = dataclasses.field(default_factory=list)
    ops: List[PlanOp] = dataclasses.field(default_factory=list)
    segments: int = 0
    merge_events: int = 0
    oom: bool = False
    mem: Any = None                  # MemoryEstimate (Eq. 5-7), when planned
    robw: Any = None                 # RoBWPlan, when RoBW-partitioned
    out_shape: Optional[Tuple[int, int]] = None   # execute: output buffer
    out_dtype: Any = np.float32
    # Baselines execute a single reference kernel instead of per-segment
    # thunks (their correctness path is not the streamed pipeline).
    reference_kernel: Optional[Callable[[], np.ndarray]] = None

    def add(self, op: OpKind, phase: str, lane: str = "",
            deps: Sequence[int] = ()) -> int:
        """Append an op; returns its index (for later `deps`)."""
        self.ops.append(PlanOp(op, phase, lane, tuple(deps)))
        return len(self.ops) - 1

    def validate(self) -> "PipelinePlan":
        """Structural validation; returns self, raises PlanValidationError.

        The interpreters evaluate ops in list order, reading each dep's
        completion time from earlier iterations — so list order must be a
        topological order of the dep graph. A dangling index, a self-dep,
        or a forward reference (which every dependency cycle necessarily
        contains) would read a completion time of 0.0 and silently
        mis-order the lane-availability makespan. `PassPipeline`
        revalidates after every rewrite pass; builder plans are checked on
        interpretation.
        """
        names = [ph.name for ph in self.phases]
        if len(set(names)) != len(names):
            raise PlanValidationError(
                f"duplicate phase declarations: {names}")
        declared = set(names)
        if self.ops and not declared:
            # An op-bearing plan with no declared phases used to slip
            # through (the per-op check was guarded on `declared` being
            # non-empty) — and then every op landed in an undeclared
            # phase whose span never entered the makespan.
            raise PlanValidationError(
                f"plan {self.scheduler!r} carries {len(self.ops)} ops but "
                "declares no phases: every op would sit in an undeclared "
                "phase and its span would never enter the makespan")
        n = len(self.ops)
        for idx, bound in enumerate(self.ops):
            if bound.phase not in declared:
                raise PlanValidationError(
                    f"op {idx} ({type(bound.op).__name__}) sits in "
                    f"undeclared phase {bound.phase!r} "
                    f"(declared: {sorted(declared)})")
            for d in bound.deps:
                d = int(d)
                if not 0 <= d < n:
                    raise PlanValidationError(
                        f"op {idx} ({type(bound.op).__name__}) has a "
                        f"dangling dependency on op {d} "
                        f"(plan has {n} ops)")
                if d == idx:
                    raise PlanValidationError(
                        f"op {idx} ({type(bound.op).__name__}) depends on "
                        "itself (dependency cycle)")
                if d > idx:
                    raise PlanValidationError(
                        f"op {idx} ({type(bound.op).__name__}) depends on "
                        f"later op {d}: list order must be a topological "
                        "order (forward references — including every "
                        "dependency cycle — would silently mis-order the "
                        "makespan)")
        return self

    def phase_ops(self, phase: str) -> List[OpKind]:
        return [p.op for p in self.ops if p.phase == phase]

    def stream_payloads(self) -> List[Any]:
        """The real host payloads of the plan's stream ops, in order."""
        return [p.op.payload for p in self.ops
                if isinstance(p.op, (TransferOp, CacheProbeOp))
                and p.op.payload is not None]

    def wire_bytes(self) -> int:
        """Total Phase II wire bytes (the cache-relevant traffic)."""
        total = 0
        for p in self.ops:
            if isinstance(p.op, CacheProbeOp):
                total += p.op.wire_bytes
            elif isinstance(p.op, TransferOp) and p.op.payload is not None:
                total += p.op.nbytes
        return total

    def release_payloads(self) -> None:
        """Drop the heavy references interpretation needed: brick payloads,
        cache-probe values, kernel thunks (which close over bricks and the
        feature matrix), and the baseline reference kernel.

        Called by the schedulers after `run()` so a retained
        `ScheduleResult.pipeline` costs op metadata, not the densified
        working set — this is an out-of-core library; results must not pin
        every graph's bricks. The plan stays fully cost-interpretable.
        """
        for bound in self.ops:
            op = bound.op
            if isinstance(op, TransferOp):
                op.payload = None
            elif isinstance(op, CacheProbeOp):
                op.payload = None
                op.value = True
                op.pin = None       # pin=a would keep the whole CSR alive
                op.miss.payload = None
            elif isinstance(op, ComputeOp):
                op.kernel = None
        self.reference_kernel = None

    def estimate(self, spec: TierSpec,
                 segment_cache: Any = None) -> ScheduleMetrics:
        """Side-effect-free cost reading of this plan.

        Cache probes *peek* (`tier_of`) instead of get/put, so estimating a
        request never promotes, demotes, or inserts — the serving engine
        calls this on live shared caches for admission control.
        """
        interp = CostInterpreter(spec, segment_cache=segment_cache,
                                 peek_only=True, analyze=False)
        metrics, _ = interp.run(self)
        return metrics


# ---- interpreters ----------------------------------------------------------


class CostInterpreter:
    """Charge a plan through a `TieredMemorySystem`; derive the makespan
    from lane availability. This is simulate mode for every scheduler."""

    execute = False

    def __init__(self, spec: TierSpec, segment_cache: Any = None,
                 peek_only: bool = False, analyze: Optional[bool] = None):
        self.spec = spec
        self.segment_cache = segment_cache
        self.peek_only = peek_only
        # Static analysis before interpreting (repro.core.analysis):
        # None defers to the module default — off in production, on for
        # the whole suite via tests/conftest.py. `estimate()` always
        # passes False: admission control prices plans constantly and
        # analysis there would only re-check an already-checked plan.
        self.analyze = analyze

    def _analyze_enabled(self) -> bool:
        if self.analyze is not None:
            return self.analyze
        from repro.core.analysis import default_analyze
        return default_analyze()

    def _analyze(self, plan: "PipelinePlan") -> None:
        from repro.core.analysis import analyze_plan
        analyze_plan(plan, spec=self.spec,
                     segment_cache=self.segment_cache).raise_for_errors()

    def run(self, plan: PipelinePlan,
            tms: Optional[TieredMemorySystem] = None
            ) -> Tuple[ScheduleMetrics, Optional[np.ndarray]]:
        """Interpret `plan`; returns (metrics, output-or-None)."""
        tms = tms if tms is not None else TieredMemorySystem(self.spec)
        m = ScheduleMetrics(scheduler=plan.scheduler, dataset=plan.dataset)
        if plan.oom:
            m.oom = True
            return m, None
        plan.validate()
        if self._analyze_enabled():
            self._analyze(plan)
        out = (np.zeros(plan.out_shape, dtype=plan.out_dtype)
               if self.execute and plan.out_shape is not None else None)

        overlap = {ph.name: ph.overlap for ph in plan.phases}
        completion = [0.0] * len(plan.ops)
        lane_free: Dict[Tuple[str, str], float] = {}
        lane_span: Dict[str, float] = {}
        serial_io: Dict[str, float] = {}
        serial_host: Dict[str, float] = {}
        serial_cmp: Dict[str, float] = {}

        for idx, bound in enumerate(plan.ops):
            op = bound.op
            secs = 0.0
            kind = ""
            if isinstance(op, AllocOp):
                try:
                    tms.alloc(op.tier, op.name, op.nbytes)
                except OutOfMemory:
                    m.oom = True
                    return m, None
            elif isinstance(op, TransferOp):
                secs = tms.transfer(op.path, op.src, op.dst, op.nbytes,
                                    tag=op.tag)
                if op.merge:
                    m.merge_io_s += secs
                kind = "io"
            elif isinstance(op, CacheProbeOp):
                secs = self._probe(op, tms, m)
                kind = "io"
            elif isinstance(op, HostPreprocessOp):
                m.host_preprocess_s += op.modeled_s
                m.host_measured_s += op.measured_s
                secs = op.modeled_s
                kind = "host"
            elif isinstance(op, ComputeOp):
                secs = op.seconds
                m.compute_modeled_s += secs
                kind = "compute"
                if self.execute and op.kernel is not None and out is not None:
                    op.kernel(out)
            else:  # pragma: no cover - new op kinds must be handled here
                raise TypeError(f"unknown plan op {type(op).__name__}")

            if overlap.get(bound.phase, "lanes") == "serial":
                if kind == "io":
                    serial_io[bound.phase] = \
                        serial_io.get(bound.phase, 0.0) + secs
                elif kind == "host":
                    serial_host[bound.phase] = \
                        serial_host.get(bound.phase, 0.0) + secs
                elif kind == "compute":
                    serial_cmp[bound.phase] = \
                        serial_cmp.get(bound.phase, 0.0) + secs
            else:
                start = lane_free.get((bound.phase, bound.lane), 0.0)
                for d in bound.deps:
                    start = max(start, completion[d])
                completion[idx] = start + secs
                if bound.lane:
                    lane_free[(bound.phase, bound.lane)] = completion[idx]
                lane_span[bound.phase] = max(
                    lane_span.get(bound.phase, 0.0), completion[idx])

        makespan = 0.0
        for ph in plan.phases:
            if ph.overlap == "serial":
                span = (serial_io.get(ph.name, 0.0)
                        + serial_host.get(ph.name, 0.0)
                        + serial_cmp.get(ph.name, 0.0))
            else:
                span = lane_span.get(ph.name, 0.0)
            makespan = makespan + span

        if self.execute and plan.reference_kernel is not None:
            out = plan.reference_kernel()

        m.io_modeled_s = sum(t.seconds for t in tms.transfers)
        m.makespan_s = makespan
        m.bytes_by_path = {p.value: b for p, b in tms.bytes_by_path().items()}
        m.seconds_by_path = {p.value: s
                             for p, s in tms.seconds_by_path().items()}
        m.total_transfer_bytes = tms.total_bytes()
        m.segments = plan.segments
        m.merge_events = plan.merge_events
        return m, out

    # -- cache probe ---------------------------------------------------------

    def _probe(self, op: CacheProbeOp, tms: TieredMemorySystem,
               m: ScheduleMetrics) -> float:
        cache = self.segment_cache
        if cache is None:
            t = op.miss
            return tms.transfer(t.path, t.src, t.dst, t.nbytes, tag=t.tag)
        if self.peek_only:
            return self._peek(op, cache, tms, m)
        hit, promote_s = cache.get_with_cost(op.key, nbytes=op.wire_bytes,
                                             tms=tms)
        if hit is not None:
            m.cache_hit_bytes += op.wire_bytes
            # Device-tier hit: free. Host-tier hit: the promotion DMA
            # (already charged into tms by the cache) is this segment's
            # pipeline I/O slot.
            return promote_s
        t = op.miss
        secs = tms.transfer(t.path, t.src, t.dst, t.nbytes, tag=t.tag)
        cache.put(op.key, op.value, op.wire_bytes, tms=tms, pin=op.pin,
                  shard=op.place_shard)
        return secs

    @staticmethod
    def _peek(op: CacheProbeOp, cache: Any, tms: TieredMemorySystem,
              m: ScheduleMetrics) -> float:
        """Estimate-mode probe: the cache prices its own would-be hit
        (`peek_cost` — tier promotion, remote-shard ICI, directory
        peer-promote — the pricing lives next to `get_with_cost`, so the
        two readings cannot drift); a would-be miss adds the fallback
        wire transfer. Nothing is mutated."""
        hit, cost = cache.peek_cost(op.key, nbytes=op.wire_bytes, tms=tms,
                                    shard=op.place_shard)
        if hit:
            m.cache_hit_bytes += op.wire_bytes
            return cost
        t = op.miss
        return cost + tms.transfer(t.path, t.src, t.dst, t.nbytes, tag=t.tag)


class ExecuteInterpreter(CostInterpreter):
    """Cost interpretation + real execution.

    For scheduler plans, `run()` additionally invokes kernel thunks
    (AIRES per-segment Pallas kernels into the plan's output buffer, or a
    baseline's single reference kernel) — the metrics side is identical to
    `CostInterpreter` by inheritance, which is the whole point.

    For the real engine path, :meth:`stream` drives the plan's stream ops
    through a `DoubleBufferedStreamer`: `jax.device_put` uploads overlap
    kernel dispatch via JAX async dispatch, cache probes become the
    streamer's lookup/store hooks, and the plan's wire-byte declarations
    feed `StreamStats` — one plan, the same keys and byte counts the cost
    interpreter models.
    """

    execute = True

    def __init__(self, spec: Optional[TierSpec] = None,
                 segment_cache: Any = None, peek_only: bool = False,
                 analyze: Optional[bool] = None):
        # `spec` is only needed by run(); stream() is pure execution.
        super().__init__(spec, segment_cache=segment_cache,
                         peek_only=peek_only, analyze=analyze)

    def stream(self, plan: PipelinePlan,
               upload: Callable[[Any], Any],
               consume: Callable[[Any, int], Any],
               depth: int = 2,
               deadline_s: Optional[float] = None,
               max_reissue: int = 1) -> Tuple[List[Any], Any]:
        """Run the plan's stream ops for real; returns (results, StreamStats).

        Payloads are the `(index, data)` pairs the plan builder attached to
        its stream ops; cache keys and wire bytes come from the same ops the
        cost interpreter charges, so the two accountings cannot drift.
        """
        from repro.io.streamer import DoubleBufferedStreamer

        if self._analyze_enabled():
            # run() validates before interpreting; stream() is the real
            # engine path and deserves the same gate when analysis is on
            # (spec may be None here — the budget rules then skip).
            plan.validate()
            self._analyze(plan)

        payloads: List[Any] = []
        meta: Dict[Any, Tuple[Any, int, Optional[int]]] = {}
        probed = False
        for bound in plan.ops:
            op = bound.op
            if isinstance(op, CacheProbeOp) and op.payload is not None:
                payloads.append(op.payload)
                meta[op.payload[0]] = (op.key, op.wire_bytes, op.place_shard)
                probed = True
            elif isinstance(op, TransferOp) and op.payload is not None:
                payloads.append(op.payload)
                meta[op.payload[0]] = (None, op.nbytes, None)

        cache = self.segment_cache
        cache_lookup = cache_store = None
        if cache is not None and probed:
            def cache_lookup(payload):
                key, nbytes, _ = meta[payload[0]]
                return cache.get(key, nbytes=nbytes)

            def cache_store(payload, dev):
                key, nbytes, place = meta[payload[0]]
                cache.put(key, dev, nbytes, shard=place)

        streamer = DoubleBufferedStreamer(
            upload, consume, depth=depth, deadline_s=deadline_s,
            max_reissue=max_reissue,
            payload_nbytes=lambda payload: meta[payload[0]][1],
            cache_lookup=cache_lookup, cache_store=cache_store)
        results = streamer.run_all(payloads)
        return results, streamer.stats
