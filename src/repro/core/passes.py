"""Plan-rewrite pass framework: optimizer passes over the pipeline IR.

PR 4 made every scheduler's I/O + compute schedule first-class data — a
typed :class:`~repro.core.pipeline.PipelinePlan` consumed by two
interpreters. AIRES's remaining wins (shard-aware RoBW placement,
transfer batching, deadline-aware serving order) re-arrange the *same
bytes*, so they are plan **transformations**, not new schedulers — the
same post-hoc schedule-rewriting that pays off for HC-SpMM's hybrid-core
kernel selection and the batched-SpGEMM reordering of arXiv:1903.11409.
This module is the pass manager between plan builders and interpreters:

  * :class:`PlanPass` — one rewrite, pure ``PipelinePlan -> PipelinePlan``
    (a pass may *annotate* ops — e.g. placement overrides — or rebuild the
    op list, but never executes anything);
  * :class:`PassPipeline` — runs passes in order, **revalidates the plan
    after every pass** (`PipelinePlan.validate()`: deps stay a topological
    order, phases stay declared) and, when a `TierSpec` is available,
    records a per-pass before/after cost delta via the `CostInterpreter`
    (`PipelinePlan.estimate()` — cache probes peek, nothing mutates);
  * three production passes:

      - :class:`ShardPlacementPass` — pin a plan's cache-probed bricks to
        the shard that streams them (closing the ROADMAP shard-aware RoBW
        placement item): remote CRC owners become `place_shard` overrides,
        bounded by per-shard device headroom, falling back to the
        fewest-ICI-hop shard with room (`ShardedSegmentCache.ici_hops`,
        ring vs all-to-all). Placement never *increases* ICI traffic: a
        key either moves strictly nearer or keeps its owner.
      - :class:`TransferCoalescingPass` — merge adjacent small same-lane,
        same-path transfers into one DMA: total bytes per path are
        conserved, per-transfer setup latency is paid once per merged
        group, and on the real streamer the merged group becomes a single
        upload issue (`CoalescedPayload`).
      - :class:`EDFOrderingPass` — deadline-aware batch ordering for
        `ServingEngine.run_batch`, priced by the same
        `PipelinePlan.estimate()` cost admission control uses. The order
        is earliest-deadline-first refined by Moore–Hodgson tardy
        demotion (`deadline_order`), which is optimal in on-time count —
        so it never misses more deadlines than the submission order.

The identity pipeline (``PassPipeline([])``) is behavior-preserving by
construction: it validates and returns the plan untouched, so simulate
metrics stay float-equal to the PR-4 goldens and execute outputs stay
bit-exact (asserted in tests/test_passes.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.analysis import (
    SEVERITY_ERROR,
    Finding,
    analyze_plan,
    diff_path_totals,
    path_byte_totals,
)
from repro.core.pipeline import (
    CacheProbeOp,
    PipelinePlan,
    PlanOp,
    ScheduleMetrics,
    TransferOp,
)
from repro.io.tiers import TierSpec

__all__ = [
    "CoalescedPayload", "EDFOrderingPass", "PassContext", "PassPipeline",
    "PassReport", "PlanPass", "ShardPlacementPass", "TransferCoalescingPass",
    "deadline_order", "edf_sort", "remaining_deadline",
]


@dataclasses.dataclass
class PassContext:
    """What a pass may *read* while rewriting: the cost model and the live
    segment cache (owner map, budgets, hop counts). Passes never mutate
    either — cache state changes only when the rewritten plan is
    interpreted."""

    spec: Optional[TierSpec] = None
    segment_cache: Any = None


class PlanPass:
    """One plan rewrite. Subclasses override `__call__` (return the
    rewritten plan — annotating ops in place or rebuilding the op list)
    and/or `order_requests` (batch-level work ordering for the serving
    engine). The base class is the identity on both."""

    name = "identity"
    # Passes re-arrange the same bytes; `PassPipeline(strict=True)`
    # enforces it via `analysis.path_byte_totals` after every rewrite.
    # A future pass that legitimately changes traffic (layer fusion
    # dropping a round trip, say) opts out by setting this False.
    conserves_bytes = True

    def __call__(self, plan: PipelinePlan,
                 ctx: Optional[PassContext] = None) -> PipelinePlan:
        return plan

    def order_requests(self, requests: List[Any]) -> List[Any]:
        return requests


@dataclasses.dataclass
class PassReport:
    """Before/after cost reading of one pass (both via
    `PipelinePlan.estimate()` under the pipeline's TierSpec).

    Under `PassPipeline(strict=True)`, `findings` carries the static
    analyzer's verdict on the pass's output (repro.core.analysis) —
    empty means the rewrite analyzed clean."""

    pass_name: str
    # None when the pipeline runs strict-only (no TierSpec to estimate
    # under); the cost-delta properties assume a tracked run.
    before: Optional[ScheduleMetrics]
    after: Optional[ScheduleMetrics]
    findings: Tuple[Any, ...] = ()

    @property
    def makespan_delta_s(self) -> float:
        """Negative = the pass made the modeled plan faster."""
        return self.after.makespan_s - self.before.makespan_s

    def bytes_delta(self, path: str) -> int:
        return (self.after.bytes_by_path.get(path, 0)
                - self.before.bytes_by_path.get(path, 0))


class PassPipeline:
    """Ordered passes + revalidation + per-pass cost deltas.

    `apply(plan)` validates the incoming plan, runs each pass, revalidates
    after every rewrite, and (when a `TierSpec` is known and `track_costs`
    is on) estimates the plan before and after each pass so callers can
    see exactly what each rewrite bought. The last run's reports are kept
    on `last_reports`.

    An empty pipeline is the identity: validate, touch nothing — the
    refactor's behavior-preservation anchor.
    """

    def __init__(self, passes: Sequence[PlanPass] = (),
                 spec: Optional[TierSpec] = None,
                 track_costs: bool = True, strict: bool = False):
        self.passes: List[PlanPass] = list(passes)
        self.spec = spec
        self.track_costs = track_costs
        # strict: statically analyze the plan after every pass
        # (repro.core.analysis), attach the findings to the PassReports,
        # enforce per-path byte conservation for every pass that does not
        # declare `conserves_bytes = False`, and raise PlanAnalysisError
        # on any error-severity finding — so a byte-dropping or
        # hazard-introducing rewrite dies at the pass boundary instead of
        # surfacing as wrong interpreter output.
        self.strict = strict
        self.last_reports: List[PassReport] = []

    def __len__(self) -> int:
        return len(self.passes)

    def __iter__(self):
        return iter(self.passes)

    @property
    def orders_requests(self) -> bool:
        """True if any pass reorders batch work (the engine only re-groups
        its queue when one does, keeping the default path byte-identical)."""
        return any(type(p).order_requests is not PlanPass.order_requests
                   for p in self.passes)

    def order_requests(self, requests: List[Any]) -> List[Any]:
        for p in self.passes:
            requests = p.order_requests(requests)
        return requests

    def apply(self, plan: PipelinePlan, spec: Optional[TierSpec] = None,
              segment_cache: Any = None
              ) -> Tuple[PipelinePlan, List[PassReport]]:
        plan.validate()
        if not self.passes or plan.oom:
            self.last_reports = []
            return plan, []
        spec = spec if spec is not None else self.spec
        ctx = PassContext(spec=spec, segment_cache=segment_cache)
        track = self.track_costs and spec is not None
        reports: List[PassReport] = []
        before = plan.estimate(spec, segment_cache) if track else None
        totals = path_byte_totals(plan) if self.strict else None
        for p in self.passes:
            plan = p(plan, ctx)
            plan.validate()
            findings: Tuple[Any, ...] = ()
            verdict = None
            if self.strict:
                verdict = analyze_plan(plan, spec=spec,
                                       segment_cache=segment_cache)
                after_totals = path_byte_totals(plan)
                delta = diff_path_totals(totals, after_totals)
                if delta and getattr(p, "conserves_bytes", True):
                    verdict.findings.append(Finding(
                        "bytes/path-delta", SEVERITY_ERROR,
                        f"pass {p.name!r} changed per-path byte totals "
                        f"by {delta} (set conserves_bytes=False if the "
                        "pass legitimately re-routes traffic)"))
                totals = after_totals
                findings = tuple(verdict.findings)
            if track or self.strict:
                after = plan.estimate(spec, segment_cache) if track \
                    else None
                reports.append(PassReport(p.name, before, after,
                                          findings=findings))
                before = after
            if verdict is not None:
                self.last_reports = reports
                verdict.raise_for_errors()
        self.last_reports = reports
        return plan, reports


# ---- pass 1: shard-aware RoBW placement ------------------------------------


class ShardPlacementPass(PlanPass):
    """Pin a plan's cache-probed bricks to the shard that consumes them.

    The CRC owner map spreads bricks uniformly over the mesh — good for
    aggregate capacity, but every brick this worker streams from a remote
    owner pays ICI twice (shard-place on insert, cache/ici on every warm
    hit). This pass walks the plan's `CacheProbeOp`s in stream order (the
    RoBW plan's hot order — every pass streams all of them) and decides,
    for each not-yet-resident key owned remotely, where the miss's insert
    should land — by the tier the brick is expected to settle in, since
    that is what a warm hit will cost:

      1. **local device** headroom left → pin local (`place_shard =
         local`): warm hits become free, no ICI ever again;
      2. else **owner device** headroom left → keep the CRC owner: a
         remote *device* hit costs only the ICI hop, which is cheaper
         than converting it into a local host-tier promotion over the
         PCIe-class DMA path;
      3. else another shard has device headroom at no more `ici_hops`
         than the owner → place there (device residency at
         equal-or-fewer hops);
      4. else the brick will settle on a host tier wherever it lands —
         prefer the **local** host tier (promotion without the ICI
         add-on), then the nearest host tier strictly closer than the
         owner.

    Per-shard device/host headrooms are budgeted down as the walk assigns
    bricks, so the pass never plans past capacity. Keys already resident
    somewhere are left alone (migrating warm bricks would charge the move
    against this batch). Monotonicity — placement never increases modeled
    `ici_bytes` — holds by construction (every override sits at
    equal-or-fewer hops than the CRC owner) and is property-tested.

    **Cluster co-placement** (partition-aware sharding): when the cache
    carries a partition-derived cluster map (`ShardedSegmentCache.
    cluster_of_key`, installed by `install_owner_map(..., clusters=...)`),
    probes of the same cluster are placed as ONE unit through device
    rules 1–3 — co-clustered bricks share neighbors, so splitting a
    cluster across shards forfeits exactly the locality the partitioner
    bought. A cluster that fits nowhere as a unit falls back to the
    per-brick walk (host tiers included); probes with no cluster id take
    the per-brick path bit-exactly as before.
    """

    name = "shard-placement"

    def __call__(self, plan: PipelinePlan,
                 ctx: Optional[PassContext] = None) -> PipelinePlan:
        cache = getattr(ctx, "segment_cache", None)
        if cache is None or getattr(cache, "n_shards", 1) <= 1:
            return plan
        local = cache.local_shard
        shards = range(cache.n_shards)
        dev = {s: max(cache.shard_headroom(s), 0) for s in shards}
        host = {s: max(cache.shard_host_headroom(s), 0) for s in shards}

        def nearest(budgets, nbytes, max_hops):
            """Closest non-local shard with room, at most `max_hops` away
            (ties broken toward the lowest shard index, deterministic)."""
            best, best_hops = None, max_hops + 1
            for s in shards:
                if s == local or nbytes > budgets[s]:
                    continue
                h = cache.ici_hops(s)
                if h < best_hops:
                    best, best_hops = s, h
            return best

        def place_one(op, owner):
            nbytes = int(op.wire_bytes)
            owner_hops = cache.ici_hops(owner)
            if nbytes <= dev[local]:
                op.place_shard = local
                dev[local] -= nbytes
                return
            if nbytes <= dev[owner]:
                dev[owner] -= nbytes        # reserve; keep the owner
                return
            s = nearest(dev, nbytes, owner_hops)
            if s is not None:
                op.place_shard = s
                dev[s] -= nbytes
                return
            if nbytes <= host[local]:
                op.place_shard = local
                host[local] -= nbytes
                return
            s = nearest(host, nbytes, owner_hops - 1)
            if s is not None:
                op.place_shard = s
                host[s] -= nbytes
            elif nbytes <= host[owner]:
                host[owner] -= nbytes       # settles at the owner's host

        def needs_placement(op):
            return (cache.owner_of(op.key) != local
                    and cache.tier_of(op.key) is None)

        # Cluster groups among the probes that need placement: the
        # members move as one unit through device rules 1-3. Grouping
        # reads only static cache state (owner maps, residency), so the
        # precomputed groups match the walk's own filter.
        clustered = hasattr(cache, "cluster_of_key")
        groups: dict = {}
        if clustered:
            for bound in plan.ops:
                op = bound.op
                if not isinstance(op, CacheProbeOp):
                    continue
                c = cache.cluster_of_key(op.key)
                if c is not None and needs_placement(op):
                    groups.setdefault(c, []).append(op)

        placed_clusters: set = set()
        for bound in plan.ops:
            op = bound.op
            if not isinstance(op, CacheProbeOp):
                continue
            if not needs_placement(op):
                continue
            owner = cache.owner_of(op.key)
            c = cache.cluster_of_key(op.key) if clustered else None
            if c is None:
                place_one(op, owner)
                continue
            if c in placed_clusters:
                continue
            placed_clusters.add(c)
            members = groups.get(c, [op])
            total = sum(int(m.wire_bytes) for m in members)
            owner_hops = cache.ici_hops(owner)
            if total <= dev[local]:
                for m in members:
                    m.place_shard = local
                dev[local] -= total
                continue
            if total <= dev[owner]:
                dev[owner] -= total         # co-resident at the owner
                continue
            s = nearest(dev, total, owner_hops)
            if s is not None:
                for m in members:
                    m.place_shard = s
                dev[s] -= total
                continue
            # The cluster fits nowhere as a unit: per-brick rescue, in
            # stream order, host tiers included.
            for m in members:
                place_one(m, cache.owner_of(m.key))
        return plan


# ---- pass 2: transfer coalescing -------------------------------------------


@dataclasses.dataclass
class CoalescedPayload:
    """Stream payloads of a merged transfer, in original segment order.

    `AiresSpGEMM` uploads all member bricks in one streamer issue and
    consumes them back-to-back; per-segment results are flattened back
    into plan order, so outputs are bit-identical to the unmerged stream.
    """

    payloads: List[Any]


class TransferCoalescingPass(PlanPass):
    """Merge adjacent small same-lane, same-path transfers into one DMA.

    Per-transfer setup latency (`TierSpec.latency_s`) dominates transfers
    below ~bw·latency bytes; RoBW segmentation and the baselines' merge
    bounces produce long runs of them. Two transfers coalesce when they
    share (phase, lane, path, src/dst tier, merge flag, payload-ness),
    each is below `min_bytes`, and merging cannot break the dep order:

      * a dependent of any member now waits for the whole merged DMA —
        exactly the semantics of a real coalesced transfer;
      * a candidate whose deps do not all resolve *before* the open run's
        position starts a fresh run instead (list order must remain a
        topological order — revalidated by the PassPipeline);
      * in a ``lanes`` phase, a non-mergeable op on the same lane closes
        the run (lane traffic order is preserved); ``serial`` phases sum
        regardless, so only dep order gates there.

    Total bytes per path are conserved (property-tested); only the
    per-transfer latency count — and, for payload-bearing stream plans,
    the real streamer's issue count — drops. `CacheProbeOp`s are never
    merged: each brick must stay individually addressable in the cache.

    ``min_bytes=None`` derives the threshold per path from the
    (calibrated) spec in the `PassContext` as ``bw·latency`` — the byte
    count at which setup cost equals streaming cost, which is exactly
    where merging stops paying. With no spec in context it falls back to
    the documented ``1<<18`` default.
    """

    name = "transfer-coalescing"

    DEFAULT_MIN_BYTES = 1 << 18

    def __init__(self, min_bytes: Optional[int] = DEFAULT_MIN_BYTES):
        if min_bytes is not None and min_bytes <= 0:
            raise ValueError("min_bytes must be > 0")
        self.min_bytes = int(min_bytes) if min_bytes is not None else None

    def threshold(self, spec: Optional[TierSpec], path) -> int:
        """Coalescing threshold for one path: the explicit `min_bytes`,
        or the spec-derived ``bw·latency`` crossover when None."""
        if self.min_bytes is not None:
            return self.min_bytes
        if spec is None or path not in spec.bw:
            return self.DEFAULT_MIN_BYTES
        return max(1, int(spec.bw[path] * spec.latency_s.get(path, 0.0)))

    def __call__(self, plan: PipelinePlan,
                 ctx: Optional[PassContext] = None) -> PipelinePlan:
        spec = ctx.spec if ctx is not None else None
        overlap = {ph.name: ph.overlap for ph in plan.phases}
        groups: List[List[int]] = []     # member op indices, consecutive
        group_of: Dict[int, int] = {}
        open_runs: Dict[tuple, int] = {}  # run key -> group id

        for idx, bound in enumerate(plan.ops):
            op = bound.op
            run_key = None
            if (isinstance(op, TransferOp)
                    and op.nbytes < self.threshold(spec, op.path)):
                run_key = (bound.phase, bound.lane, op.path, op.src, op.dst,
                           op.merge, op.payload is None)
            if run_key is None:
                if overlap.get(bound.phase, "lanes") == "lanes":
                    for k in [k for k in open_runs
                              if k[0] == bound.phase and k[1] == bound.lane]:
                        del open_runs[k]
                group_of[idx] = len(groups)
                groups.append([idx])
                continue
            gid = open_runs.get(run_key)
            if gid is not None:
                run_first = groups[gid][0]
                if all(group_of[d] == gid
                       or groups[group_of[d]][0] < run_first
                       for d in bound.deps):
                    group_of[idx] = gid
                    groups[gid].append(idx)
                    continue
            gid = len(groups)
            group_of[idx] = gid
            groups.append([idx])
            open_runs[run_key] = gid

        if all(len(g) == 1 for g in groups):
            return plan

        # Rebuild: groups were created in first-member order, so group id
        # IS the new op index — deps remap straight through group_of.
        out_ops: List[PlanOp] = []
        for gid, members in enumerate(groups):
            bound0 = plan.ops[members[0]]
            deps = tuple(sorted({group_of[int(d)]
                                 for m in members
                                 for d in plan.ops[m].deps
                                 if group_of[int(d)] != gid}))
            if len(members) == 1:
                out_ops.append(PlanOp(bound0.op, bound0.phase, bound0.lane,
                                      deps))
                continue
            op0 = bound0.op
            payload = None
            if op0.payload is not None:
                member_payloads = [plan.ops[m].op.payload for m in members]
                payload = (member_payloads[0][0],
                           CoalescedPayload(member_payloads))
            merged = TransferOp(
                op0.path, op0.src, op0.dst,
                sum(int(plan.ops[m].op.nbytes) for m in members),
                tag=op0.tag, merge=op0.merge, payload=payload)
            out_ops.append(PlanOp(merged, bound0.phase, bound0.lane, deps))
        return dataclasses.replace(plan, ops=out_ops)


# ---- pass 3: deadline-aware (EDF) batch ordering ---------------------------


def _edf_order(deadlines: Sequence[float]) -> List[int]:
    """Index permutation: stable earliest-deadline-first (deadlines are
    already None→inf normalized). The single EDF primary order shared by
    `edf_sort` and `deadline_order`, so the two cannot drift."""
    return sorted(range(len(deadlines)), key=lambda i: (deadlines[i], i))


def _normalized(items, deadline_of) -> List[float]:
    inf = float("inf")
    return [deadline_of(it) if deadline_of(it) is not None else inf
            for it in items]


def edf_sort(items: Sequence[Any],
             deadline_of: Callable[[Any], Optional[float]]) -> List[Any]:
    """Stable earliest-deadline-first order; deadline-free items keep their
    relative order at the tail. Optimal for *maximum lateness* (Jackson's
    rule) — the guarantee pure EDF actually carries."""
    return [items[i] for i in _edf_order(_normalized(items, deadline_of))]


def deadline_order(items: Sequence[Any],
                   cost_of: Callable[[Any], float],
                   deadline_of: Callable[[Any], Optional[float]]
                   ) -> List[Any]:
    """EDF refined by Moore–Hodgson tardy demotion.

    Process items in EDF order, tracking the running completion time under
    `cost_of`; whenever the current item would finish past its deadline,
    demote the *most expensive* scheduled item to the tardy tail. The
    on-time set this yields is maximum (Moore–Hodgson is optimal for
    1‖ΣUⱼ), so the returned order never misses more deadlines than the
    submission order — pure EDF alone does not guarantee that (it is
    optimal for max lateness, not miss count). Tardy items run last, in
    submission order; deadline-free items never miss and sort after all
    deadlines. Returns a permutation of `items`.
    """
    dl = _normalized(items, deadline_of)
    order = _edf_order(dl)
    scheduled: List[int] = []
    tardy: List[int] = []
    t = 0.0
    for i in order:
        scheduled.append(i)
        t += max(float(cost_of(items[i])), 0.0)
        if t > dl[i]:
            k = max(range(len(scheduled)),
                    key=lambda j: (cost_of(items[scheduled[j]]),
                                   scheduled[j]))
            dropped = scheduled.pop(k)
            t -= max(float(cost_of(items[dropped])), 0.0)
            tardy.append(dropped)
    tardy.sort()
    return [items[i] for i in scheduled + tardy]


def remaining_deadline(r: Any, now: float) -> Optional[float]:
    """Seconds a request has left on its relative deadline, on one clock:
    `InferenceRequest.deadline_s` counts from submit time, so two requests
    submitted at different moments compare via `submitted_s + deadline_s −
    now`. Unstamped requests (never passed `submit()`) fall back to the
    raw relative field — their deadline starts counting now."""
    d = getattr(r, "deadline_s", None)
    if d is None:
        return None
    submitted = getattr(r, "submitted_s", -1.0)
    return d if submitted < 0 else submitted + d - now


class EDFOrderingPass(PlanPass):
    """Deadline-aware `run_batch` ordering.

    Plans pass through untouched — the rewrite is the *work list*: the
    serving engine hands its drained queue to `order_requests`, which
    orders by `deadline_order` over each request's
    `PipelinePlan.estimate()` cost (the same prediction admission control
    prices with, filled in by `run_batch` before ordering). The engine
    then serves graph groups in first-appearance order of the reordered
    queue, so the earliest deadlines stream first.

    Deadlines are compared on one clock: `InferenceRequest.deadline_s` is
    *relative to submit time*, so two requests submitted at different
    moments cannot be ordered by the raw field — the pass converts each
    to the seconds **remaining** now (`remaining_deadline`), which is also
    the unit the Moore–Hodgson completion clock (cumulative cost from
    batch start) is checked against.

    `clock` defaults to `time.monotonic`; the continuous serving loop
    passes its `VirtualClock` so remaining-time math runs on the replay
    timeline. `order_groups` is the continuous loop's *queue-position*
    variant: the schedulable unit is a whole column-concat group, priced
    by `ServingEngine.estimate_group_cost`, so Moore–Hodgson's completion
    clock accumulates whole-group costs — each group's deadline is checked
    against its time-to-front (the modeled cost of every group ahead of
    it), not just its within-round rank.
    """

    name = "edf-ordering"

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        self.clock: Callable[[], float] = clock or time.monotonic

    def order_requests(self, requests: List[Any]) -> List[Any]:
        now = self.clock()
        return deadline_order(
            requests,
            cost_of=lambda r: getattr(r, "estimated_cost_s", 0.0),
            deadline_of=lambda r: remaining_deadline(r, now))

    def order_groups(self, groups: Sequence[Any],
                     cost_of: Callable[[Any], float]) -> List[Any]:
        """Queue-position EDF over request groups. Each group's deadline is
        the *tightest* remaining deadline among its members (the group
        completes as a unit — column-concat passes finish together), its
        cost the caller-supplied per-group `PipelinePlan.estimate()`
        rollup. `deadline_order`'s running completion clock then *is* the
        time-to-front of each group."""
        now = self.clock()

        def tightest(group) -> Optional[float]:
            ds = [remaining_deadline(r, now) for r in _members(group)]
            ds = [d for d in ds if d is not None]
            return min(ds) if ds else None

        return deadline_order(list(groups), cost_of, tightest)


def _members(group: Any) -> Sequence[Any]:
    """A group is either a bare request sequence or a (name, requests)
    pair (the serving loop's shape); normalize to the request list."""
    if (isinstance(group, tuple) and len(group) == 2
            and isinstance(group[0], str)):
        return group[1]
    return group
