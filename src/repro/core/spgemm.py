"""AiresSpGEMM — the paper's technique as a first-class composable API.

`AiresSpGEMM` wraps the full pipeline: Eq.5-7 planning → RoBW partitioning →
tile densification → double-buffered streaming → Pallas block-ELL kernel.
It is **differentiable**: a `jax.custom_vjp` computes dH = Aᵀ dX by
streaming the transposed RoBW plan (`robw_transpose_plan`) through the same
`DoubleBufferedStreamer`, so `jax.grad` through a GCN layer triggers real
backward I/O instead of a modeled multiplier.

`gcn_epoch` chains it through the Fig. 1 aggregation/combination chain for
per-epoch latency accounting. In execute mode the epoch runs a true
forward+backward pass (jax.vjp over the layer chain) and reports separate
forward/backward `StreamStats`; simulate mode keeps the paper's
`backward_factor` accounting for large-scale modeling.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Literal, Optional, Union

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.memory_model import FeatureSpec, plan_memory_unified
from repro.core.pipeline import (
    LANE_COMPUTE,
    LANE_DMA,
    CacheProbeOp,
    ComputeOp,
    ExecuteInterpreter,
    PhaseSpec,
    PipelinePlan,
    ScheduleMetrics,
    TransferOp,
    modeled_spgemm_seconds,
)
from repro.core.robw import (
    densify_segment,
    robw_delta_partition,
    robw_partition,
    robw_transpose_plan,
    segments_to_block_ell,
)
from repro.core.scheduler import (
    SCHEDULERS,
)
from repro.io.segment_cache import SegmentKey, TieredSegmentCache
from repro.io.shard_cache import ShardedSegmentCache
from repro.io.streamer import StreamStats
from repro.io.tiers import MemoryTier, Path, TierSpec, TPU_V5E_SYSTEM
from repro.sparse.formats import (
    CSR,
    csr_fingerprint,
    graph_cache_prefix,
    segment_fingerprint,
)
from repro.sparse.partition import Partition
from repro.sparse.updates import EdgeDelta

# Both tiered caches speak the same get/put protocol; the engine and the
# epoch runner accept either (mesh-sharded device tier included).
SegmentCacheLike = Union[TieredSegmentCache, ShardedSegmentCache]


@dataclasses.dataclass
class AiresConfig:
    device_budget_bytes: int
    bm: int = 128
    bk: int = 128
    align: int = 8
    stream_depth: int = 2            # double buffering (Phase II)
    straggler_deadline_s: Optional[float] = None
    wire_format: Literal["csr", "bricks"] = "bricks"
    interpret: Optional[bool] = None  # None → auto (CPU container)
    # Plan (and densify) as if the feature matrix were this wide, regardless
    # of the H actually passed — one RoBW plan then serves every layer width
    # and every batched request width ≤ plan_features, so the segment cache
    # hits across layers/epochs/requests instead of re-planning per shape.
    # Widths beyond plan_features still get their own (conservative) plan.
    plan_features: Optional[int] = None
    # Explicit ELL bucket ladder for tile densification (see
    # `ell_bucket_capacity` and the autotuner, repro.core.autotune).
    # None (default) keeps the power-of-two buckets bit-exactly.
    ell_buckets: Optional[List[int]] = None


@dataclasses.dataclass
class _Prepared:
    """Host-side artifacts of one streaming direction for one graph."""

    a: CSR                    # the matrix actually streamed (A or Aᵀ)
    mem: object               # MemoryEstimate
    plan: object              # RoBWPlan
    segs: List[object]
    ells: List[object]
    cache_ns: str = ""        # segment-cache namespace (graph+direction+plan)
    # Per-segment content fingerprints (segment_fingerprint of each
    # segment's rows) — the content half of every SegmentKey this plan
    # emits; the delta-update path preserves them for reused segments.
    fps: List[str] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class UpdateStats:
    """What one `AiresSpGEMM.apply_edge_update` changed, summed over every
    prepared plan (direction × width) of the updated graph."""

    plans_updated: int = 0
    segments_retiled: int = 0
    segments_reused: int = 0
    retiled_bytes: int = 0        # wire bytes of the re-densified bricks
    # Cache keys the update made stale (old keys absent from the updated
    # plans) — exactly what the runtime must invalidate, nothing more.
    stale_keys: List[SegmentKey] = dataclasses.field(default_factory=list)


class AiresSpGEMM:
    """Out-of-core X = A @ H with the AIRES schedule, executing for real.

    The simulate-mode scheduler (`repro.core.scheduler.AiresScheduler`)
    models large-scale latency; this class *runs* the streaming pipeline —
    `jax.device_put` uploads overlap kernel dispatch via JAX async dispatch,
    with the same RoBW plan and memory model.

    Differentiation: `__call__` carries a custom VJP whose backward streams
    the transposed plan (dH = Aᵀ dX), so autodiff through a GCN layer incurs
    the paper's backward I/O for real. Per-call `StreamStats` accumulate in
    `forward_stats_log` / `backward_stats_log` (cleared by
    `reset_stats_logs`), with the most recent also on `last_stream_stats` /
    `last_backward_stream_stats`.
    """

    # Per-engine cap on cached (graph × shape × direction) preparations.
    # Densified BlockELL tiles outweigh the source CSR, so the cache is a
    # small LRU rather than unbounded — epoch loops reuse a handful of
    # entries (one per layer width per direction) and multi-graph training
    # evicts instead of growing without bound.
    PREPARED_CACHE_MAX = 8

    def __init__(self, config: AiresConfig,
                 segment_cache: Optional[SegmentCacheLike] = None,
                 plan_passes=None, analyze: Optional[bool] = None,
                 partition: Optional[Partition] = None):
        self.config = config
        # Partition-aware sharding (repro.sparse.partition): when set, RoBW
        # plans tile over the partition's cluster boundaries, cache
        # namespaces carry a `:p{n_clusters}` tag, and every prepared plan
        # installs its partition-derived owner map on a sharded segment
        # cache — warm-epoch ICI drops from topology, not retention
        # heuristics. None keeps every byte of the unpartitioned behavior.
        self.partition = partition
        # Optional tiered LRU over uploaded BlockELL payloads (shared across
        # engines by the serving layer): repeat streams of the same plan skip
        # the device_put entirely — see StreamStats.cache_hit_bytes.
        self.segment_cache = segment_cache
        # Optional repro.core.passes.PassPipeline applied to every stream
        # plan before it is estimated or executed (build → rewrite →
        # interpret, same seam as the schedulers). None = identity.
        self.plan_passes = plan_passes
        # Static plan analysis before every real stream (repro.core
        # .analysis): None defers to the module default (tests flip it
        # on); the serving engine forwards EngineConfig.analyze_plans.
        self.analyze = analyze
        self._prepared: Dict[tuple, _Prepared] = {}
        self._transposes: Dict[tuple, CSR] = {}
        self.forward_stats_log: List[StreamStats] = []
        self.backward_stats_log: List[StreamStats] = []
        self.last_stream_stats: Optional[StreamStats] = None
        self.last_backward_stream_stats: Optional[StreamStats] = None

    def plan(self, a: CSR, h_shape, boundaries=None) -> tuple:
        mem = plan_memory_unified(
            a, FeatureSpec(h_shape[0], h_shape[1], 4, 0.0),
            m_total=self.config.device_budget_bytes)
        if not mem.feasible:
            raise MemoryError(
                f"AIRES plan infeasible: budget {self.config.device_budget_bytes}"
                f" < M_B+M_C = {mem.m_b + mem.m_c:.0f}")
        plan = robw_partition(a, int(mem.m_a), align=self.config.align,
                              boundaries=boundaries)
        return mem, plan

    def reset_stats_logs(self) -> None:
        self.forward_stats_log = []
        self.backward_stats_log = []

    def clear_cache(self) -> None:
        """Drop all cached plans/densified tiles (and memoized transposes).

        Does NOT touch the shared segment cache — use
        `segment_cache.invalidate_prefix(graph_cache_prefix(a))` for that.
        """
        self._prepared.clear()
        self._transposes.clear()

    @staticmethod
    def graph_cache_prefix(a: CSR) -> str:
        """Identity prefix shared by every segment-cache namespace this
        engine derives for `a` (any direction, plan width, or budget).

        Content-addressed (`csr_fingerprint`), not ``id(a)``: ids are
        recycled after GC, and a stable prefix is what lets checkpointed
        bricks warm-start a *fresh* process's cache (the keys survive).
        Updated graphs keep their ancestor's prefix (`CSR.graph_key`
        lineage) so untouched segment keys survive edge deltas — see
        `repro.sparse.formats.graph_cache_prefix`."""
        return graph_cache_prefix(a)

    # ---- host-side preparation (cached per graph × feature shape) --------
    #
    # CSR inputs are treated as IMMUTABLE: the cache keys are content
    # fingerprints (structure AND values), but the fingerprint itself is
    # memoized on the instance, so mutating a CSR in place between calls
    # would serve stale densified tiles. Re-weighted graphs must be new CSR
    # objects (they then fingerprint — and cache — separately).

    def transpose_of(self, a: CSR) -> CSR:
        """Memoized Aᵀ — shared by backward streaming and epoch accounting.

        Content-addressed (`csr_fingerprint`, values included), never
        id(a): ids are recycled after GC, and this memo holds no reference
        to its source graph that would keep the id alive. The memo is
        LRU-bounded like `_prepared`.
        """
        key = (csr_fingerprint(a), a.nnz, a.shape)
        hit = self._transposes.pop(key, None)
        if hit is not None:
            self._transposes[key] = hit  # re-insert: most-recently-used
            return hit
        from repro.sparse.formats import csr_transpose
        a_t = csr_transpose(a)
        self._transposes[key] = a_t
        while len(self._transposes) > self.PREPARED_CACHE_MAX:
            self._transposes.pop(next(iter(self._transposes)))
        return a_t

    def _prepare(self, a: CSR, dense_shape, transpose: bool) -> _Prepared:
        """Plan + densify one streaming direction; LRU-cached for epoch
        reuse (see the immutability note above)."""
        cfg = self.config
        # Plan at the pinned width when configured (conservative for any
        # narrower H): one plan — and one set of cacheable bricks — serves
        # every width up to plan_features.
        plan_shape = (dense_shape[0],
                      max(cfg.plan_features or 0, dense_shape[1]))
        part = self.partition
        key = (csr_fingerprint(a), a.nnz, a.shape, plan_shape, transpose,
               tuple(cfg.ell_buckets or ()),
               0 if part is None else part.token)
        hit = self._prepared.pop(key, None)
        if hit is not None:
            self._prepared[key] = hit  # re-insert: most-recently-used
            return hit
        # The partition tiles the *streamed* orientation: forward streams
        # A's rows directly; the transposed (backward) direction only lines
        # up for square graphs, where Aᵀ's rows are the same vertex set.
        part_rows = a.shape[1] if transpose else a.shape[0]
        if part is not None and part.n_rows != part_rows:
            part = None
        bounds = None if part is None else part.boundaries()
        if transpose:
            # Plan on Aᵀ: the backward output dH is (n_cols, F), so M_C and
            # the Eq. 7 segment budget must be sized for the transposed
            # orientation (they differ whenever A is non-square).
            a_t = self.transpose_of(a)
            mem = plan_memory_unified(
                a_t, FeatureSpec(plan_shape[0], plan_shape[1], 4, 0.0),
                m_total=cfg.device_budget_bytes)
            if not mem.feasible:
                raise MemoryError(
                    "AIRES backward plan infeasible: budget "
                    f"{cfg.device_budget_bytes} < M_B+M_C = "
                    f"{mem.m_b + mem.m_c:.0f}")
            _, plan = robw_transpose_plan(a, int(mem.m_a), align=cfg.align,
                                          a_t=a_t, boundaries=bounds)
            stream_a = a_t
        else:
            mem, plan = self.plan(a, plan_shape, boundaries=bounds)
            stream_a = a
        # Explicit bucket ladders tag the namespace: their bricks pad
        # differently, so they must never collide with (or warm-start
        # from) the default power-of-two entries. No buckets = the
        # pre-autotune namespace, byte-for-byte. Partitioned plans tag the
        # cluster count (`:p{k}`) the same way: their segment boundaries
        # differ, so bricks from different cluster counts must never
        # collide — and autotune's cluster-count trials each probe their
        # own namespace instead of clobbering the live one. The tag is
        # count-only on purpose: `Partition.refine` after an edge delta
        # keeps the count, so the namespace — and every untouched brick in
        # it — survives, exactly like the unpartitioned delta path.
        bucket_tag = ("" if not cfg.ell_buckets else
                      ":e" + "x".join(str(b) for b in cfg.ell_buckets))
        part_tag = "" if part is None else f":p{part.n_clusters}"
        cache_ns = (f"{self.graph_cache_prefix(a)}"
                    f":{'bwd' if transpose else 'fwd'}"
                    f":w{plan_shape[1]}:b{cfg.device_budget_bytes}"
                    f"{bucket_tag}{part_tag}")
        prepared = _Prepared(
            a=stream_a, mem=mem, plan=plan, segs=list(plan.segments),
            ells=list(segments_to_block_ell(stream_a, plan,
                                            bm=cfg.bm, bk=cfg.bk,
                                            buckets=cfg.ell_buckets)),
            cache_ns=cache_ns,
            fps=[segment_fingerprint(stream_a, s.row_start, s.row_end)
                 for s in plan.segments])
        if self.segment_cache is not None:
            # Pin the source graph so the id()-derived namespace can't be
            # recycled into stale hits while cached bricks live.
            self.segment_cache.pin(cache_ns, a)
        if part is not None:
            self._install_owner_map(part, prepared, transpose)
        self._prepared[key] = prepared
        while len(self._prepared) > self.PREPARED_CACHE_MAX:
            self._prepared.pop(next(iter(self._prepared)))
        return prepared

    def _install_owner_map(self, part: Partition, prepared: _Prepared,
                           transpose: bool) -> None:
        """Project `part` onto one prepared plan's segments and install the
        resulting owner map on the sharded segment cache.

        No-op for unsharded caches, caches without owner-map support, or
        shard-count mismatches (a partition packed for 4 shards says
        nothing about an 8-shard cache). The transposed orientation votes
        with Aᵀ's row nnz — `part.row_nnz` counts A's rows, which are Aᵀ's
        *columns*.
        """
        cache = self.segment_cache
        if (cache is None or part.n_shards <= 1
                or not hasattr(cache, "install_owner_map")
                or part.n_shards != getattr(cache, "n_shards", 1)):
            return
        row_nnz = (np.diff(prepared.a.indptr).astype(np.int64)
                   if transpose else None)
        clusters = part.clusters_for_plan(prepared.plan, row_nnz=row_nnz)
        owners = [int(part.cluster_to_shard[c]) for c in clusters]
        cache.install_owner_map(prepared.cache_ns, owners, clusters)

    # ---- incremental updates (evolving graphs) ---------------------------

    def _segment_keys(self, prepared: _Prepared) -> List[SegmentKey]:
        """Every SegmentKey one prepared plan emits (mirrors
        `_build_stream_plan`'s key construction exactly)."""
        cfg = self.config
        return [SegmentKey(prepared.cache_ns, i, cfg.wire_format,
                           tuple(ell.blocks.shape), fingerprint=fp)
                for i, (ell, fp) in enumerate(zip(prepared.ells,
                                                  prepared.fps))]

    def apply_edge_update(self, old: CSR, new: CSR,
                          delta: EdgeDelta) -> UpdateStats:
        """Migrate every prepared plan of `old` to `new` incrementally.

        For each cached preparation (forward plans re-tile by
        `delta.touched_rows`, transposed plans by `delta.touched_cols`):
        untouched segments keep their bricks and fingerprints verbatim;
        touched spans re-partition under the old budget
        (`robw_delta_partition`) and re-densify only their rows
        (`densify_segment` — bit-identical to a from-scratch re-tile of the
        same rows). The cache namespace carries over unchanged (`new`
        inherits `old`'s `graph_key` lineage), so the untouched segments'
        cache entries keep hitting; re-placed bricks flow through
        `ShardPlacementPass` on the next stream like any not-yet-resident
        segment. Returns the stale keys the caller must invalidate.
        """
        old_fp = csr_fingerprint(old)
        cfg = self.config
        stats = UpdateStats()
        if (self.partition is not None
                and self.partition.n_rows == new.shape[0]):
            # Delta re-clustering: only the touched rows re-vote their
            # cluster label (majority neighbor); the cluster→shard map —
            # and therefore the `:p{k}` namespace and every untouched
            # brick's owner — carries over verbatim.
            self.partition = self.partition.refine(new, delta.touched_rows)
        token = 0 if self.partition is None else self.partition.token
        for key in [k for k in self._prepared if k[0] == old_fp]:
            prep = self._prepared.pop(key)
            _, _, _, plan_shape, transpose, buckets, _ = key
            if transpose:
                stream_new = self.transpose_of(new)
                touched = delta.touched_cols
            else:
                stream_new = new
                touched = delta.touched_rows
            new_plan, reuse = robw_delta_partition(stream_new, prep.plan,
                                                   touched)
            segs, ells, fps = [], [], []
            for seg, src in zip(new_plan.segments, reuse):
                segs.append(seg)
                if src is not None:
                    ells.append(prep.ells[src])
                    fps.append(prep.fps[src])
                    stats.segments_reused += 1
                else:
                    ell = densify_segment(stream_new, seg,
                                          bm=cfg.bm, bk=cfg.bk,
                                          buckets=cfg.ell_buckets)
                    ells.append(ell)
                    fps.append(segment_fingerprint(
                        stream_new, seg.row_start, seg.row_end))
                    stats.segments_retiled += 1
                    stats.retiled_bytes += ell.nbytes()
            old_keys = self._segment_keys(prep)
            # mem is reused: the budget (and Eq. 5 split) depends on shape
            # and width, both unchanged by an edge delta; the re-packed
            # spans were re-partitioned under the same m_a.
            new_prep = _Prepared(a=stream_new, mem=prep.mem, plan=new_plan,
                                 segs=segs, ells=ells,
                                 cache_ns=prep.cache_ns, fps=fps)
            self._prepared[(csr_fingerprint(new), new.nnz, new.shape,
                            plan_shape, transpose, buckets,
                            token)] = new_prep
            if self.segment_cache is not None:
                # Re-pin: the namespace now answers for the updated graph.
                self.segment_cache.pin(prep.cache_ns, new)
            part = self.partition
            if part is not None and part.n_rows == stream_new.shape[0]:
                # Refresh the namespace's owner map from the refined
                # labels: migrated rows may now live in a different
                # cluster, and the re-tiled plan's segments need owners.
                self._install_owner_map(part, new_prep, transpose)
            fresh = set(self._segment_keys(new_prep))
            stats.stale_keys.extend(k for k in old_keys if k not in fresh)
            stats.plans_updated += 1
        self._transposes.pop((old_fp, old.nnz, old.shape), None)
        return stats

    # ---- pipeline-plan building + streaming executors --------------------

    @staticmethod
    def device_payload(ell):
        """Upload one BlockELL brick — the device-resident payload format
        shared by the streamer, the segment cache, and engine warm-start."""
        return (
            jax.device_put(jnp.asarray(ell.blocks)),
            jax.device_put(jnp.asarray(ell.col_tile)),
            jax.device_put(jnp.asarray(ell.n_tiles)),
            ell,
        )

    def _build_stream_plan(self, prepared: _Prepared,
                           feat: Optional[FeatureSpec] = None,
                           spec: Optional[TierSpec] = None) -> PipelinePlan:
        """Phase II of one streamed pass as a `PipelinePlan`.

        The same plan serves both interpreters: `ExecuteInterpreter.stream`
        drives the attached `(i, ell)` payloads through the double-buffered
        streamer for real, and `PipelinePlan.estimate()` reads the modeled
        cost (cache probes peek, never mutate) — that is what the serving
        engine's admission control prices a request with.
        """
        cfg = self.config
        spec = spec if spec is not None else TPU_V5E_SYSTEM
        if feat is None:
            feat = FeatureSpec(prepared.a.shape[0],
                               cfg.plan_features or 1, 4, 0.0)
        plan = PipelinePlan(scheduler="aires-stream")
        plan.phases = [PhaseSpec("stream")]
        plan.mem = prepared.mem
        plan.robw = prepared.plan
        plan.segments = len(prepared.ells)
        cached = self.segment_cache is not None
        for i, (seg, ell) in enumerate(zip(prepared.segs, prepared.ells)):
            nbytes = ell.nbytes()
            miss = TransferOp(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE,
                              nbytes, tag="phaseII/seg", payload=(i, ell))
            if cached:
                fp = prepared.fps[i] if i < len(prepared.fps) else ""
                key = SegmentKey(prepared.cache_ns, i, cfg.wire_format,
                                 tuple(ell.blocks.shape), fingerprint=fp)
                i_io = plan.add(CacheProbeOp(key, nbytes, miss,
                                             payload=(i, ell)),
                                "stream", LANE_DMA)
            else:
                i_io = plan.add(miss, "stream", LANE_DMA)
            plan.add(ComputeOp(modeled_spgemm_seconds(seg.nnz, feat, spec)),
                     "stream", LANE_COMPUTE, deps=(i_io,))
        return plan

    def stream_plan(self, a: CSR, h_shape, spec: Optional[TierSpec] = None,
                    transpose: bool = False,
                    apply_passes: bool = True) -> PipelinePlan:
        """Plan (and prepare) one streamed pass of `a` at `h_shape`.

        The configured `plan_passes` are applied, so estimates price the
        plan the stream will actually run. ``apply_passes=False`` returns
        the raw pre-rewrite plan — the autotuner's trial input (rewrite
        passes mutate ops in place, so each candidate pipeline needs a
        fresh build)."""
        h_shape = tuple(int(s) for s in h_shape)
        feat = FeatureSpec(h_shape[0], h_shape[1], 4, 0.0)
        prepared = self._prepare(a, h_shape, transpose)
        plan = self._build_stream_plan(prepared, feat=feat, spec=spec)
        if apply_passes and self.plan_passes is not None:
            plan, _ = self.plan_passes.apply(
                plan, spec=spec, segment_cache=self.segment_cache)
        return plan

    def _stream(self, prepared: _Prepared, consume_one: Callable,
                feat: Optional[FeatureSpec] = None) -> tuple:
        """Run one double-buffered pass over `prepared`'s segments via the
        execute interpreter.

        consume_one(ell_dev, i) -> per-segment device result. Returns
        (row-concatenated output, StreamStats).
        """
        from repro.core.passes import CoalescedPayload

        cfg = self.config
        plan = self._build_stream_plan(prepared, feat=feat)
        if self.plan_passes is not None:
            plan, _ = self.plan_passes.apply(
                plan, segment_cache=self.segment_cache)

        def upload(payload):
            _, ell = payload
            if isinstance(ell, CoalescedPayload):
                # One streamer issue uploads every member brick of a
                # coalesced transfer (the pass merged adjacent small DMAs).
                return CoalescedPayload(
                    [(i, self.device_payload(e)) for i, e in ell.payloads])
            return self.device_payload(ell)

        def consume_device(dev_payload, i):
            blocks, col_tile, n_tiles, ell = dev_payload
            ell_dev = dataclasses.replace(
                ell, blocks=blocks, col_tile=col_tile, n_tiles=n_tiles)
            return consume_one(ell_dev, i)

        def consume(dev_payload, i):
            if isinstance(dev_payload, CoalescedPayload):
                return [consume_device(dp, j)
                        for j, dp in dev_payload.payloads]
            return consume_device(dev_payload, i)

        cache = self.segment_cache
        # Copy, not alias: TieredSegmentCache.stats mutates in place.
        before = (dataclasses.replace(cache.stats)
                  if cache is not None else None)
        interp = ExecuteInterpreter(segment_cache=cache,
                                    analyze=self.analyze)
        parts, stats = interp.stream(
            plan, upload, consume, depth=cfg.stream_depth,
            deadline_s=cfg.straggler_deadline_s)
        if cache is not None:
            # Host-tier hits re-crossed the bus via device_put promotions;
            # surface them so uploaded_bytes=0 can't misread as zero traffic.
            # Likewise inter-chip traffic (sharded cache) and peer-host
            # serves (cache directory). `cache.stats` may be a recomputed
            # aggregate (ShardedSegmentCache), so snapshot-and-diff.
            after = cache.stats
            stats.promoted_bytes = (
                after.promoted_bytes - before.promoted_bytes)
            stats.ici_bytes = after.ici_bytes - before.ici_bytes
            stats.directory_hit_bytes = (
                after.directory_hit_bytes - before.directory_hit_bytes)
        # Flatten coalesced-group results back into per-segment plan order.
        flat = []
        for p in parts:
            if isinstance(p, list):
                flat.extend(p)
            else:
                flat.append(p)
        out = jnp.concatenate(
            [p[: s.n_rows] for p, s in zip(flat, prepared.segs)], axis=0)
        return out, stats

    def _stream_spmm(self, prepared: _Prepared, dense) -> tuple:
        """X = stream(A) @ dense — shared by forward and transposed passes."""
        from repro.kernels import bcsr_spmm

        cfg = self.config
        dense_dev = jax.device_put(dense)  # Phase I: resident feature matrix
        feat = FeatureSpec(int(dense.shape[0]), int(dense.shape[1]), 4, 0.0)
        return self._stream(
            prepared,
            lambda ell_dev, i: bcsr_spmm(ell_dev, dense_dev,
                                         interpret=cfg.interpret),
            feat=feat)

    # ---- differentiable public API --------------------------------------

    def __call__(self, a: CSR, h: jax.Array) -> jax.Array:
        """X = A @ H, differentiable w.r.t. H (dH streams Aᵀ)."""
        h = jnp.asarray(h)
        fwd = self._prepare(a, h.shape, transpose=False)
        h_dtype = h.dtype

        def run_forward(h_in):
            x, stats = self._stream_spmm(fwd, h_in)
            self.last_stream_stats = stats
            self.forward_stats_log.append(stats)
            return x

        @jax.custom_vjp
        def spgemm(h_in):
            return run_forward(h_in)

        def spgemm_fwd(h_in):
            return run_forward(h_in), None

        def spgemm_bwd(_, g):
            dh = self._backward_stream(a, g)
            return (dh.astype(h_dtype),)

        spgemm.defvjp(spgemm_fwd, spgemm_bwd)
        return spgemm(h)

    def _backward_stream(self, a: CSR, g) -> jax.Array:
        """dH = Aᵀ @ g via the transposed RoBW plan, with stats recorded."""
        g = jnp.asarray(g)
        bwd = self._prepare(a, g.shape, transpose=True)
        dh, stats = self._stream_spmm(bwd, g)
        self.last_backward_stream_stats = stats
        self.backward_stats_log.append(stats)
        return dh

    def gcn_layer(self, a: CSR, h: jax.Array, w: jax.Array,
                  b: jax.Array) -> jax.Array:
        """Differentiable fused layer Y = σ((A H) W + b), Fig. 1 chain.

        Forward streams the fused Pallas kernel — the aggregation X never
        round-trips through HBM. Backward therefore *recomputes* X with one
        forward stream (activation recomputation), then:
            dXW = dY ⊙ 1[Y>0];  dW = Xᵀ dXW;  db = Σ dXW;
            dH  = Aᵀ (dXW Wᵀ)   — one transposed stream.
        """
        from repro.kernels import fused_gcn_layer

        cfg = self.config
        h = jnp.asarray(h)
        w = jnp.asarray(w)
        b = jnp.asarray(b)
        fwd = self._prepare(a, h.shape, transpose=False)
        dtypes = (h.dtype, w.dtype, b.dtype)

        def run_fused(h_in, w_in, b_in):
            h_dev = jax.device_put(h_in)
            y, stats = self._stream(
                fwd,
                lambda ell_dev, i: fused_gcn_layer(
                    ell_dev, h_dev, w_in, b_in, interpret=cfg.interpret))
            self.last_stream_stats = stats
            self.forward_stats_log.append(stats)
            return y

        @jax.custom_vjp
        def layer(h_in, w_in, b_in):
            return run_fused(h_in, w_in, b_in)

        def layer_fwd(h_in, w_in, b_in):
            y = run_fused(h_in, w_in, b_in)
            return y, (h_in, w_in, y)

        def layer_bwd(res, dy):
            h_in, w_in, y = res
            # Recompute X = A H with one forward stream (counted in the
            # backward log: it is backward-phase I/O).
            x, stats = self._stream_spmm(fwd, h_in)
            self.backward_stats_log.append(stats)
            dxw = dy * (y > 0).astype(dy.dtype)
            dw = x.T.astype(jnp.float32) @ dxw.astype(jnp.float32)
            db = jnp.sum(dxw, axis=0)
            dx = dxw.astype(jnp.float32) @ w_in.T.astype(jnp.float32)
            dh = self._backward_stream(a, dx)
            return (dh.astype(dtypes[0]), dw.astype(dtypes[1]),
                    db.astype(dtypes[2]))

        layer.defvjp(layer_fwd, layer_bwd)
        return layer(h, w, b)


@dataclasses.dataclass
class EpochMetrics:
    per_layer: List[ScheduleMetrics]
    epoch_makespan_s: float
    total_transfer_bytes: int
    # execute mode: modeled backward metrics (transposed stream) per layer
    per_layer_backward: List[ScheduleMetrics] = dataclasses.field(
        default_factory=list)
    # execute mode: real streaming stats, one entry per layer, layer order
    forward_stream: List[StreamStats] = dataclasses.field(default_factory=list)
    backward_stream: List[StreamStats] = dataclasses.field(default_factory=list)
    wall_seconds: float = 0.0

    def speedup_over(self, other: "EpochMetrics") -> float:
        return other.epoch_makespan_s / max(self.epoch_makespan_s, 1e-12)


def gcn_epoch(
    a: CSR,
    h0,
    weights: List[np.ndarray],
    scheduler_name: str,
    spec: TierSpec,
    device_budget: int,
    mode: Literal["simulate", "execute"] = "simulate",
    dataset: str = "",
    backward_factor: float = 2.0,
    engine_config: Optional[AiresConfig] = None,
    segment_cache: Optional[SegmentCacheLike] = None,
) -> EpochMetrics:
    """One training epoch of the Fig. 1 chain under a given scheduler.

    Per layer: X = Ã H (out-of-core SpGEMM, scheduled), H' = σ(X W) (dense,
    on-device).

    simulate — backward is modeled as `backward_factor`× the forward cost
    with the same streaming pattern, matching the paper's per-epoch
    accounting (§V-A) at scales where execution is impractical.

    execute — a true forward+backward pass runs through the differentiable
    `AiresSpGEMM` engine (`jax.vjp` over the layer chain): the backward
    really streams the transposed RoBW plan, and `EpochMetrics` carries the
    per-layer forward/backward `StreamStats` plus modeled per-layer metrics
    for the chosen scheduler over A (forward) and Aᵀ (backward).
    `backward_factor` is ignored in execute mode.
    """
    if mode == "execute":
        return _execute_epoch(a, h0, weights, scheduler_name, spec,
                              device_budget, dataset, engine_config,
                              segment_cache)
    return _simulate_epoch(a, h0, weights, scheduler_name, spec,
                           device_budget, dataset, backward_factor,
                           segment_cache)


def _simulate_epoch(a, h0, weights, scheduler_name, spec, device_budget,
                    dataset, backward_factor,
                    segment_cache=None) -> EpochMetrics:
    from repro.core.memory_model import FeatureSpec

    kw = ({"segment_cache": segment_cache}
          if segment_cache is not None and scheduler_name == "aires" else {})
    sched = SCHEDULERS[scheduler_name](spec, device_budget=device_budget, **kw)
    per_layer: List[ScheduleMetrics] = []
    makespan = 0.0
    total_bytes = 0
    h = h0
    for li, w in enumerate(weights):
        res = sched.run(a, h, mode="simulate", dataset=dataset)
        m = res.metrics
        per_layer.append(m)
        if m.oom:
            return EpochMetrics(per_layer, float("inf"), 0)
        # forward + modeled backward streaming cycles
        makespan += m.makespan_s * (1.0 + backward_factor)
        total_bytes += int(m.total_transfer_bytes * (1.0 + backward_factor))
        if isinstance(h, FeatureSpec):
            h = FeatureSpec(h.n_rows, w.shape[1], h.dtype_bytes,
                            h.sparsity_pct)
        else:
            h = np.zeros((h.shape[0], w.shape[1]), dtype=np.float32)
    return EpochMetrics(per_layer, makespan, total_bytes)


def _execute_epoch(a, h0, weights, scheduler_name, spec, device_budget,
                   dataset, engine_config, segment_cache=None) -> EpochMetrics:
    from repro.core.memory_model import FeatureSpec

    cfg = engine_config or AiresConfig(device_budget_bytes=device_budget)
    engine = AiresSpGEMM(cfg, segment_cache=segment_cache)
    engine.reset_stats_logs()
    sched = SCHEDULERS[scheduler_name](spec, device_budget=device_budget)
    # One transpose, shared with the engine's backward streaming plans.
    a_t = engine.transpose_of(a)

    # ---- modeled per-layer accounting: forward over A, backward over Aᵀ.
    per_layer: List[ScheduleMetrics] = []
    per_layer_bwd: List[ScheduleMetrics] = []
    makespan = 0.0
    total_bytes = 0
    n, f = h0.shape
    width = f
    for w in weights:
        feat_f = FeatureSpec(n, width, 4, 0.0)
        res_f = sched.run(a, feat_f, mode="simulate", dataset=dataset)
        # dX arriving at this layer's aggregation has the layer's own width.
        res_b = sched.run(a_t, FeatureSpec(n, width, 4, 0.0),
                          mode="simulate", dataset=dataset)
        per_layer.append(res_f.metrics)
        per_layer_bwd.append(res_b.metrics)
        if res_f.metrics.oom or res_b.metrics.oom:
            return EpochMetrics(per_layer, float("inf"), 0,
                                per_layer_backward=per_layer_bwd)
        makespan += res_f.metrics.makespan_s + res_b.metrics.makespan_s
        total_bytes += (res_f.metrics.total_transfer_bytes
                        + res_b.metrics.total_transfer_bytes)
        width = w.shape[1]

    # ---- real forward+backward through the differentiable engine.
    h0_j = jnp.asarray(np.asarray(h0, dtype=np.float32))
    ws = [jnp.asarray(np.asarray(w, dtype=np.float32)) for w in weights]

    def chain(h, ws_):
        for w_ in ws_:
            x = engine(a, h)
            h = jax.nn.relu(x @ w_)
        return h

    t0 = time.perf_counter()
    out, vjp_fn = jax.vjp(chain, h0_j, ws)
    grads = vjp_fn(jnp.ones_like(out) / out.size)
    jax.block_until_ready((out, grads))
    wall = time.perf_counter() - t0

    return EpochMetrics(
        per_layer=per_layer,
        epoch_makespan_s=makespan,
        total_transfer_bytes=total_bytes,
        per_layer_backward=per_layer_bwd,
        forward_stream=list(engine.forward_stats_log),
        backward_stream=list(reversed(engine.backward_stats_log)),
        wall_seconds=wall,
    )
