"""AiresSpGEMM — the paper's technique as a first-class composable API.

`AiresSpGEMM` wraps the full pipeline: Eq.5-7 planning → RoBW partitioning →
tile densification → double-buffered streaming → Pallas block-ELL kernel.
`gcn_epoch` chains it through the Fig. 1 aggregation/combination chain for
per-epoch latency accounting (forward + backward), which is what the paper's
end-to-end figures measure.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Literal, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.memory_model import plan_memory_dense_features
from repro.core.robw import robw_partition, segments_to_block_ell
from repro.core.scheduler import (
    AiresScheduler,
    ScheduleMetrics,
    ScheduleResult,
    SCHEDULERS,
)
from repro.io.streamer import DoubleBufferedStreamer
from repro.io.tiers import TierSpec, TPU_V5E_SYSTEM
from repro.sparse.formats import CSR


@dataclasses.dataclass
class AiresConfig:
    device_budget_bytes: int
    bm: int = 128
    bk: int = 128
    align: int = 8
    stream_depth: int = 2            # double buffering (Phase II)
    straggler_deadline_s: Optional[float] = None
    wire_format: Literal["csr", "bricks"] = "bricks"
    interpret: Optional[bool] = None  # None → auto (CPU container)


class AiresSpGEMM:
    """Out-of-core X = A @ H with the AIRES schedule, executing for real.

    The simulate-mode scheduler (`repro.core.scheduler.AiresScheduler`)
    models large-scale latency; this class *runs* the streaming pipeline —
    `jax.device_put` uploads overlap kernel dispatch via JAX async dispatch,
    with the same RoBW plan and memory model.
    """

    def __init__(self, config: AiresConfig):
        self.config = config

    def plan(self, a: CSR, h_shape) -> tuple:
        mem = plan_memory_dense_features(
            a, n_nodes=h_shape[0], feature_dim=h_shape[1],
            m_total=self.config.device_budget_bytes)
        if not mem.feasible:
            raise MemoryError(
                f"AIRES plan infeasible: budget {self.config.device_budget_bytes}"
                f" < M_B+M_C = {mem.m_b + mem.m_c:.0f}")
        plan = robw_partition(a, int(mem.m_a), align=self.config.align)
        return mem, plan

    def __call__(self, a: CSR, h: jax.Array) -> jax.Array:
        from repro.kernels import bcsr_spmm

        cfg = self.config
        mem, plan = self.plan(a, h.shape)
        h_dev = jax.device_put(h)  # Phase I: resident feature matrix

        segs = list(plan.segments)
        ells = segments_to_block_ell(a, plan, bm=cfg.bm, bk=cfg.bk)

        def upload(ell):
            return (
                jax.device_put(jnp.asarray(ell.blocks)),
                jax.device_put(jnp.asarray(ell.col_tile)),
                jax.device_put(jnp.asarray(ell.n_tiles)),
                ell,
            )

        def consume(dev_payload, i):
            blocks, col_tile, n_tiles, ell = dev_payload
            ell_dev = dataclasses.replace(
                ell, blocks=blocks, col_tile=col_tile, n_tiles=n_tiles)
            return bcsr_spmm(ell_dev, h_dev, interpret=cfg.interpret)

        streamer = DoubleBufferedStreamer(
            upload, consume, depth=cfg.stream_depth,
            deadline_s=cfg.straggler_deadline_s)
        parts = streamer.run_all(ells)
        x = jnp.concatenate([p[: s.n_rows] for p, s in zip(parts, segs)], axis=0)
        self.last_stream_stats = streamer.stats
        return x


@dataclasses.dataclass
class EpochMetrics:
    per_layer: List[ScheduleMetrics]
    epoch_makespan_s: float
    total_transfer_bytes: int

    def speedup_over(self, other: "EpochMetrics") -> float:
        return other.epoch_makespan_s / max(self.epoch_makespan_s, 1e-12)


def gcn_epoch(
    a: CSR,
    h0: np.ndarray,
    weights: List[np.ndarray],
    scheduler_name: str,
    spec: TierSpec,
    device_budget: int,
    mode: Literal["simulate", "execute"] = "simulate",
    dataset: str = "",
    backward_factor: float = 2.0,
) -> EpochMetrics:
    """One training epoch of the Fig. 1 chain under a given scheduler.

    Per layer: X = Ã H (out-of-core SpGEMM, scheduled), H' = σ(X W) (dense,
    on-device). Backward is modeled as `backward_factor`× the forward cost
    with the same streaming pattern (dÃᵀ-side SpGEMM re-streams A), matching
    the paper's per-epoch accounting (§V-A: "one training epoch entails
    multiple cycles of SpGEMM, activation, and backward gradient descent").
    """
    from repro.core.memory_model import FeatureSpec

    sched = SCHEDULERS[scheduler_name](spec, device_budget=device_budget)
    per_layer: List[ScheduleMetrics] = []
    makespan = 0.0
    total_bytes = 0
    h = h0
    for li, w in enumerate(weights):
        res = sched.run(a, h, mode=mode, dataset=dataset)
        m = res.metrics
        per_layer.append(m)
        if m.oom:
            return EpochMetrics(per_layer, float("inf"), 0)
        # forward + backward streaming cycles
        makespan += m.makespan_s * (1.0 + backward_factor)
        total_bytes += int(m.total_transfer_bytes * (1.0 + backward_factor))
        if mode == "execute" and res.x is not None:
            h = np.maximum(res.x @ w, 0.0).astype(np.float32)
        elif isinstance(h, FeatureSpec):
            # simulate: layer output keeps the spec with the new width
            h = FeatureSpec(h.n_rows, w.shape[1], h.dtype_bytes,
                            h.sparsity_pct)
        else:
            h = np.zeros((h.shape[0], w.shape[1]), dtype=np.float32)
    return EpochMetrics(per_layer, makespan, total_bytes)
