"""AIRES three-phase dynamic scheduling (paper Alg. 2, Fig. 5) + baselines.

Faithful reproduction of the paper's methodology: host-side preprocessing
(RoBW partitioning, tile densification, partial-row merging for baselines)
is **executed and wall-clock measured**; I/O transfers and device kernel
latency are **modeled** with the calibrated tiered-memory cost model —
exactly the split the paper uses (§V-A: "We model the I/O transfer
operations and kernel-level computation latency with simulations").

Since the pipeline-plan IR refactor, every scheduler is a pure **plan
builder**: `build_plan()` emits a typed `repro.core.pipeline.PipelinePlan`
(ops on declared resource lanes, grouped into phases), and `run()` hands
that one plan to an interpreter — `CostInterpreter` for ``simulate`` (the
paper's large-scale accounting), `ExecuteInterpreter` for ``execute``
(real Pallas kernels on the streamed segments). Simulate and execute can
no longer diverge on I/O accounting: they interpret the same op list.

Schedulers:
  AiresScheduler     — C1+C2+C4+C5: RoBW alignment, Eq.5-7 planning,
                       dual-way Phase I, double-buffered Phase II,
                       on-device C for chaining (Phase III).
  MaxMemoryScheduler — naive max-rows static split; partial-row merge cost.
  UCGScheduler       — unified-memory reads, CPU-GPU split, no alignment.
  ETCScheduler       — batched DMA with dedup + pipeline, output allocated
                       at the larger-input size (paper §III-B), no alignment.

Policy flags mirror paper Table I (Alignment / DMA / UM / Dual-way).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Literal, Optional

import numpy as np

from repro.core.memory_model import (
    FeatureSpec,
    MemoryEstimate,
    plan_memory_unified,
    required_bytes,
)
from repro.core.pipeline import (
    LANE_COMPUTE,
    LANE_DMA,
    LANE_GDS,
    LANE_HOST,
    LANE_SIO,
    LANE_UM,
    AllocOp,
    CacheProbeOp,
    ComputeOp,
    CostInterpreter,
    ExecuteInterpreter,
    HostPreprocessOp,
    PhaseSpec,
    PipelinePlan,
    ScheduleMetrics,
    TransferOp,
    modeled_spgemm_seconds,
)
from repro.core.robw import (
    RoBWPlan,
    merge_partial_rows,
    naive_partition,
    robw_partition,
    segments_to_block_ell,
)
from repro.io.segment_cache import SegmentKey, TieredSegmentCache
from repro.io.shard_cache import ShardedSegmentCache
from repro.io.tiers import (
    MemoryTier,
    Path,
    TierSpec,
)
from repro.sparse.formats import CSR, csr_fingerprint

__all__ = [
    "SCHEDULERS", "AiresScheduler", "ETCScheduler", "MaxMemoryScheduler",
    "ScheduleMetrics", "ScheduleResult", "UCGScheduler",
]


@dataclasses.dataclass
class ScheduleResult:
    x: Optional[np.ndarray]          # output (execute mode) or None (simulate)
    metrics: ScheduleMetrics
    plan: Optional[RoBWPlan] = None
    mem: Optional[MemoryEstimate] = None
    pipeline: Optional[PipelinePlan] = None   # the IR both interpreters read
    # Per-pass before/after cost deltas when a PassPipeline rewrote the
    # plan (repro.core.passes.PassReport); empty without passes.
    pass_reports: list = dataclasses.field(default_factory=list)


def _spgemm_flops(a: CSR, f: int) -> float:
    return 2.0 * a.nnz * f


class _BaseScheduler:
    """Shared accounting + the build→interpret `run()` driver.

    Feasibility calibration (`oom_fraction`): Table III shows each baseline's
    minimum viable budget as a fraction of Table II's memory requirement —
    MaxMemory/UCG need ≳85 % of (A+B+C), ETC ≳72 % (output allocated at the
    larger input's size), AIRES is bounded only by Eq. 7's p>0. We encode
    those observed thresholds as policy constants; the *latency* model below
    them is mechanistic (transfers, merges, overlap), not curve-fit.
    """

    name = "base"
    oom_fraction = 0.0  # min budget / required_bytes; 0 → model-driven only
    segment_cache: Optional[
        "TieredSegmentCache | ShardedSegmentCache"] = None

    def __init__(
        self,
        spec: TierSpec,
        device_budget: Optional[int] = None,
        peak_flops: float = 82.6e12,       # RTX4090-class fp32 for paper benches
        compute_efficiency: float = 0.20,  # fraction of HBM bw sparse kernels achieve
        passes=None,                       # Optional[repro.core.passes.PassPipeline]
    ):
        self.spec = spec
        self.device_budget = device_budget or spec.device_capacity
        self.peak_flops = peak_flops
        self.compute_efficiency = compute_efficiency
        # Plan-rewrite passes applied between build_plan() and the
        # interpreter (run() = build → rewrite → interpret). None — and
        # the empty PassPipeline — are the identity: bit-exact with the
        # pass-free pipeline.
        self.passes = passes

    def _kernel_seconds(self, flops: float) -> float:
        return flops / (self.peak_flops * self.compute_efficiency)

    def _spgemm_seconds(self, nnz: int, feat: FeatureSpec) -> float:
        return modeled_spgemm_seconds(nnz, feat, self.spec,
                                      self.compute_efficiency)

    def _host_seconds(self, nbytes: float, events: int = 1) -> float:
        """Modeled host staging/merge cost: DRAM memcpy + per-event latency.

        Host costs are modeled (not wall-clock measured) so that scaled-down
        benchmark graphs keep the full-scale cost *ratios*: at 1/1000 scale a
        measured Python-loop overhead would swamp µs-scale modeled
        transfers. Execute-mode still runs the real work; tests compare its
        outputs, not its timing.
        """
        return nbytes / self.spec.host_memcpy_bw \
            + events * self.spec.host_op_latency_s

    @staticmethod
    def _feat(h) -> FeatureSpec:
        return FeatureSpec.of(h)

    def _budget_infeasible(self, a: CSR, feat: FeatureSpec) -> bool:
        if self.oom_fraction <= 0.0:
            return False
        return self.device_budget < self.oom_fraction * required_bytes(a, feat)

    def build_plan(self, a: CSR, h,
                   mode: Literal["simulate", "execute"] = "simulate",
                   dataset: str = "") -> PipelinePlan:
        raise NotImplementedError

    def run(self, a: CSR, h,
            mode: Literal["simulate", "execute"] = "simulate",
            dataset: str = "") -> ScheduleResult:
        """Build the plan, rewrite it, interpret it.

        One plan — rewritten once by the optional `passes` PassPipeline
        (validated after every pass, per-pass cost deltas in
        `ScheduleResult.pass_reports`) — then handed to either interpreter.
        """
        plan = self.build_plan(a, h, mode=mode, dataset=dataset)
        pass_reports = []
        if self.passes is not None:
            plan, pass_reports = self.passes.apply(
                plan, spec=self.spec, segment_cache=self.segment_cache)
        cls = ExecuteInterpreter if mode == "execute" else CostInterpreter
        interp = cls(self.spec, segment_cache=self.segment_cache)
        metrics, x = interp.run(plan)
        # The returned plan keeps op metadata (re-estimable) but not the
        # densified bricks / kernel closures it was executed with.
        plan.release_payloads()
        return ScheduleResult(x=x, metrics=metrics, plan=plan.robw,
                              mem=plan.mem, pipeline=plan,
                              pass_reports=pass_reports)


class AiresScheduler(_BaseScheduler):
    """C1+C2+C4+C5 — the paper's contribution, TPU-adapted (DESIGN §2)."""

    name = "aires"

    def __init__(self, *args, bm: int = 128, bk: int = 128, align: int = 8,
                 wire_format: Literal["csr", "bricks"] = "csr",
                 segment_cache: Optional[
                     "TieredSegmentCache | ShardedSegmentCache"] = None,
                 partition=None, **kw):
        super().__init__(*args, **kw)
        self.bm = bm
        self.bk = bk
        self.align = align
        # Optional repro.sparse.partition.Partition: RoBW tiles over its
        # cluster boundaries, the cache namespace carries a `:p{k}` tag,
        # and the partition-derived owner map is installed on a sharded
        # segment cache before probes are priced. None = legacy behavior.
        self.partition = partition
        # "csr": stream raw compressed segments (paper-faithful wire format,
        #        densification happens device-side on GPU); "bricks": stream
        #        densified BlockELL bricks (TPU wire format).
        self.wire_format = wire_format
        # Optional TieredSegmentCache shared across runs: cache-hit segments
        # skip the Phase II DMA transfer (device-tier hit) or pay only the
        # promotion (host-tier hit), both visible in bytes_by_path; skipped
        # wire bytes are reported in metrics.cache_hit_bytes.
        self.segment_cache = segment_cache

    def build_plan(self, a: CSR, h, mode="simulate",
                   dataset="") -> PipelinePlan:
        feat = self._feat(h)
        f = feat.n_cols
        plan = PipelinePlan(scheduler=self.name, dataset=dataset)

        # ---- Phase 0: analytical planning (Eq. 5-7), no data touched.
        mem = plan_memory_unified(a, feat, m_total=self.device_budget)
        plan.mem = mem
        if not mem.feasible:
            plan.oom = True
            return plan
        plan.phases = [PhaseSpec("load"), PhaseSpec("stream"),
                       PhaseSpec("store")]

        # ---- Phase I: dual-way loads. B/H ride the direct storage→device
        # path (GDS analogue) on their own lane; A crosses storage→host and
        # feeds the RoBW pass — the two chains overlap (Fig. 5).
        plan.add(AllocOp(MemoryTier.DEVICE, "H", int(mem.m_b)), "load")
        plan.add(AllocOp(MemoryTier.DEVICE, "C", int(mem.m_c)), "load")
        plan.add(TransferOp(Path.GDS, MemoryTier.STORAGE, MemoryTier.DEVICE,
                            int(mem.m_b), tag="phaseI/H"), "load", LANE_GDS)
        a_bytes = a.nbytes()
        plan.add(AllocOp(MemoryTier.HOST, "A", a_bytes), "load")
        i_load_a = plan.add(
            TransferOp(Path.STORAGE_HOST, MemoryTier.STORAGE, MemoryTier.HOST,
                       a_bytes, tag="phaseI/A"), "load", LANE_SIO)

        # RoBW partitioning on the CPU: executed for real at build time; its
        # makespan contribution is modeled as one indptr scan + per-segment
        # events (see _host_seconds for why).
        part = self.partition
        if part is not None and part.n_rows != a.shape[0]:
            part = None  # built for a different graph: ignore, don't crash
        t0 = time.perf_counter()
        robw = robw_partition(
            a, int(mem.m_a), align=self.align,
            boundaries=None if part is None else part.boundaries())
        measured = time.perf_counter() - t0
        plan.robw = robw
        plan.segments = robw.n_segments
        plan.add(HostPreprocessOp(
            self._host_seconds(a.indptr.nbytes, events=robw.n_segments),
            measured_s=measured), "load", LANE_HOST, deps=(i_load_a,))

        # ---- Phase II: double-buffered streaming + per-segment compute.
        # DMA-lane serialization + compute→transfer deps reproduce the
        # double-buffer recurrence (segment k+1's transfer overlaps segment
        # k's compute; each resource is serial).
        execute = mode == "execute"
        ell_iter = (segments_to_block_ell(a, robw, bm=self.bm, bk=self.bk)
                    if execute or self.wire_format == "bricks" else None)
        ells = (list(ell_iter) if ell_iter is not None
                else [None] * robw.n_segments)
        if execute:
            plan.out_shape = (a.n_rows, f)

        cache = self.segment_cache
        # "sim:" prefix keeps simulate-mode token entries from ever aliasing
        # an execute-mode device payload in a shared cache. The graph id is
        # a content fingerprint, never id(a): CPython reuses ids after GC,
        # which could alias two different graphs into one namespace.
        graph_ns = (f"sim:g{csr_fingerprint(a)}:{a.nnz}"
                    f":{a.shape[0]}x{a.shape[1]}:w{f}:b{self.device_budget}"
                    f"{'' if part is None else f':p{part.n_clusters}'}")
        if (cache is not None and part is not None and part.n_shards > 1
                and hasattr(cache, "install_owner_map")
                and part.n_shards == getattr(cache, "n_shards", 1)):
            clusters = part.clusters_for_plan(robw)
            cache.install_owner_map(
                graph_ns,
                [int(part.cluster_to_shard[c]) for c in clusters],
                clusters)
        for i, (seg, ell) in enumerate(zip(robw.segments, ells)):
            if self.wire_format == "bricks" and ell is not None:
                wire_bytes = ell.nbytes()
                wire_shape = tuple(ell.blocks.shape)
            else:
                wire_bytes = seg.nbytes
                wire_shape = (seg.n_rows, seg.nnz)
            miss = TransferOp(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE,
                              wire_bytes, tag="phaseII/seg")
            if cache is not None:
                key = SegmentKey(graph_ns, i, self.wire_format, wire_shape)
                i_io = plan.add(
                    CacheProbeOp(key, wire_bytes, miss,
                                 value=ell if ell is not None else True,
                                 pin=a), "stream", LANE_DMA)
            else:
                i_io = plan.add(miss, "stream", LANE_DMA)
            kernel = (self._segment_kernel(ell, seg, h)
                      if execute and ell is not None else None)
            plan.add(ComputeOp(self._spgemm_seconds(seg.nnz, feat),
                               kernel=kernel),
                     "stream", LANE_COMPUTE, deps=(i_io,))

        # ---- Phase III: C stays on device for chaining; final store of the
        # compressed output via the direct storage path.
        plan.add(TransferOp(Path.GDS, MemoryTier.DEVICE, MemoryTier.STORAGE,
                            int(mem.m_c), tag="phaseIII/C"), "store", LANE_GDS)
        return plan

    @staticmethod
    def _segment_kernel(ell, seg, h):
        """Execute-mode thunk: stream this segment through the Pallas
        block-ELL kernel, writing its row slice of the output buffer."""
        def kernel(out: np.ndarray) -> None:
            from repro.kernels import bcsr_spmm as _spmm_op
            import jax.numpy as jnp
            x_seg = np.asarray(_spmm_op(ell, jnp.asarray(h)))
            out[seg.row_start:seg.row_end] = x_seg[: seg.n_rows]
        return kernel


def _reference_kernel(a: CSR, h):
    """Baseline execute mode: exact output via the dense reference path
    (the baselines' correctness story is not the streamed pipeline)."""
    def kernel() -> np.ndarray:
        from repro.sparse.ref_spgemm import spgemm_csr_dense
        return spgemm_csr_dense(a, np.asarray(h))
    return kernel


class MaxMemoryScheduler(_BaseScheduler):
    """Naive static split: maximize rows per segment, merge partial rows.

    Models the paper's MaxMemory baseline: equal static allocation for A and
    B on device; segments cut at byte budget regardless of row boundaries;
    partial rows bounce back to host for merging (measured numpy work) and
    are re-transferred (modeled DMA) — the Fig. 3 overhead. The plan is one
    fully **serial** phase: the baseline has no overlap.
    """

    name = "maxmemory"
    oom_fraction = 0.84  # Table III: dies one notch below Memory Req.

    def build_plan(self, a: CSR, h, mode="simulate",
                   dataset="") -> PipelinePlan:
        feat = self._feat(h)
        f = feat.n_cols
        plan = PipelinePlan(scheduler=self.name, dataset=dataset)
        plan.phases = [PhaseSpec("all", overlap="serial")]
        h_bytes = feat.compressed_bytes
        half = self.device_budget // 2
        if h_bytes > half or self._budget_infeasible(a, feat):
            plan.oom = True  # static split cannot fit B / minimum set absent
            return plan
        plan.add(AllocOp(MemoryTier.DEVICE, "H", h_bytes), "all")
        plan.add(AllocOp(MemoryTier.DEVICE, "A_seg",
                         min(half, self.spec.device_capacity - h_bytes)),
                 "all")

        # B over PCIe through host (no GDS in baseline), serial with A.
        plan.add(TransferOp(Path.STORAGE_HOST, MemoryTier.STORAGE,
                            MemoryTier.HOST, h_bytes, tag="phaseI/H"), "all")
        plan.add(TransferOp(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE,
                            h_bytes, tag="phaseI/H"), "all")
        plan.add(TransferOp(Path.STORAGE_HOST, MemoryTier.STORAGE,
                            MemoryTier.HOST, a.nbytes(), tag="phaseI/A"),
                 "all")

        cuts = naive_partition(a, half)
        plan.segments = len(cuts)
        value_bytes = a.data.dtype.itemsize
        per_nnz = 4 + value_bytes
        row_of = np.searchsorted(a.indptr, np.arange(a.nnz + 1),
                                 side="right") - 1
        carry_vals = np.empty(0, dtype=a.data.dtype)
        for (lo, hi, first_partial, last_partial) in cuts:
            # Unaligned cut ⇒ every segment must be re-packed ("staged") into
            # a contiguous pinned buffer before HtoD: the stored layout does
            # not match the transfer window. Measured host memcpy — this is
            # the bulk of the Fig. 3 overhead; AIRES's aligned segments skip
            # it entirely (segments ARE the stored layout).
            t0 = time.perf_counter()
            staged_vals = np.ascontiguousarray(a.data[lo:hi])
            staged_idx = np.ascontiguousarray(a.indices[lo:hi])
            measured = time.perf_counter() - t0
            plan.add(HostPreprocessOp(
                self._host_seconds(staged_vals.nbytes + staged_idx.nbytes,
                                   events=1), measured_s=measured), "all")
            if first_partial and carry_vals.size:
                # Merge the previous segment's partial row with its
                # continuation on the host (measured), re-send.
                row = row_of[lo]
                row_end = int(a.indptr[row + 1])
                t0 = time.perf_counter()
                merged = merge_partial_rows(carry_vals,
                                            np.asarray(a.data[lo:row_end]))
                np.ascontiguousarray(merged)  # pinned-buffer re-pack
                measured = time.perf_counter() - t0
                plan.add(HostPreprocessOp(
                    self._host_seconds(2 * merged.nbytes, events=2),
                    measured_s=measured), "all")
                plan.add(TransferOp(Path.DMA, MemoryTier.HOST,
                                    MemoryTier.DEVICE,
                                    merged.size * per_nnz + f * 4,
                                    tag="merge/HtoD", merge=True), "all")
                plan.merge_events += 1
            nbytes = (hi - lo) * per_nnz
            plan.add(TransferOp(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE,
                                nbytes, tag="seg"), "all")
            plan.add(ComputeOp(self._spgemm_seconds(hi - lo, feat)), "all")
            del staged_vals, staged_idx
            if last_partial:
                # Incomplete row returns to host (values + partial result).
                row = row_of[hi]
                row_lo = int(a.indptr[row])
                carry_vals = np.asarray(a.data[row_lo:hi])
                tail_bytes = carry_vals.size * per_nnz + f * 4
                plan.add(TransferOp(Path.DMA, MemoryTier.DEVICE,
                                    MemoryTier.HOST, tail_bytes,
                                    tag="merge/DtoH", merge=True), "all")
            else:
                carry_vals = np.empty(0, dtype=a.data.dtype)

        # Dynamic-size output vs static allocation (§III-B): C shares the
        # non-A half with B. Every time the C slot fills, the partial output
        # spills DtoH; because a hypersparse A spreads each C row's updates
        # across many segments, spilled C blocks are re-fetched when later
        # segments touch them again (thrash ∝ spill count, capped).
        mem_full = plan_memory_unified(a, feat, m_total=float("inf"))
        c_slot = max(half - h_bytes, 1)
        n_spills = max(1, int(np.ceil(mem_full.m_c / c_slot)))
        thrash = min(n_spills, 3)
        plan.add(TransferOp(Path.DMA, MemoryTier.DEVICE, MemoryTier.HOST,
                            int(mem_full.m_c) * thrash, tag="spill/C"), "all")
        if n_spills > 1:
            # Re-uploaded C partials that later segments accumulate into.
            reup = int(mem_full.m_c * 0.35 * (thrash - 1))
            plan.add(TransferOp(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE,
                                reup, tag="spill/reup", merge=True), "all")
            # Capacity pressure also evicts resident B pages; they re-read.
            b_evict = int(h_bytes * min(
                1.0, 0.4 * max(0.0, (mem_full.m_c - c_slot)) / max(h_bytes, 1)))
            if b_evict:
                plan.add(TransferOp(Path.STORAGE_HOST, MemoryTier.STORAGE,
                                    MemoryTier.HOST, b_evict, tag="evict/B"),
                         "all")
                plan.add(TransferOp(Path.DMA, MemoryTier.HOST,
                                    MemoryTier.DEVICE, b_evict,
                                    tag="evict/B"), "all")
        if mode == "execute":
            plan.reference_kernel = _reference_kernel(a, h)
        plan.add(TransferOp(Path.STORAGE_HOST, MemoryTier.HOST,
                            MemoryTier.STORAGE, int(mem_full.m_c),
                            tag="phaseIII/C"), "all")
        return plan


class UCGScheduler(_BaseScheduler):
    """UCG [22] policy model: unified-memory reads + CPU/GPU work split.

    Table I: no alignment, no DMA batching, UM reads, no dual-way. UM
    page-fault traffic re-reads hot pages; a fraction of work runs on CPU
    (dynamic balance) at CPU throughput. Serial plan: UM serializes with
    compute.
    """

    name = "ucg"
    oom_fraction = 0.84  # Table III: same threshold as MaxMemory

    def __init__(self, *args, cpu_flops: float = 1.2e12,
                 cpu_fraction: float = 0.15, um_refetch: float = 1.15, **kw):
        super().__init__(*args, **kw)
        self.cpu_flops = cpu_flops
        self.cpu_fraction = cpu_fraction
        self.um_refetch = um_refetch  # page-granularity over-fetch factor

    def build_plan(self, a: CSR, h, mode="simulate",
                   dataset="") -> PipelinePlan:
        feat = self._feat(h)
        f = feat.n_cols
        plan = PipelinePlan(scheduler=self.name, dataset=dataset)
        plan.phases = [PhaseSpec("all", overlap="serial")]
        h_bytes = feat.compressed_bytes
        if self._budget_infeasible(a, feat):
            # UM spills, but a minimum resident set must fit (Table III '-').
            plan.oom = True
            return plan
        plan.segments = 1

        plan.add(TransferOp(Path.STORAGE_HOST, MemoryTier.STORAGE,
                            MemoryTier.HOST, a.nbytes() + h_bytes,
                            tag="load"), "all")
        # UM moves A, H and C on demand. Page-granularity refetch grows as
        # the resident share shrinks: fewer pages stay cached, so evicted
        # pages refault — refetch ∝ working-set / budget.
        mem_full = plan_memory_unified(a, feat, m_total=float("inf"))
        working_set = a.nbytes() + h_bytes + mem_full.m_c
        refetch = self.um_refetch * max(
            1.0, 0.6 * working_set / max(self.device_budget, 1))
        um_bytes = int((a.nbytes() + h_bytes) * refetch)
        plan.add(TransferOp(Path.UM, MemoryTier.HOST, MemoryTier.DEVICE,
                            um_bytes, tag="um"), "all", LANE_UM)
        dens_b = (100.0 - feat.sparsity_pct) / 100.0
        flops = max(_spgemm_flops(a, f) * dens_b, 2.0 * a.nnz)
        gpu_s = self._kernel_seconds(flops * (1 - self.cpu_fraction))
        cpu_s = flops * self.cpu_fraction / self.cpu_flops
        # CPU/GPU run concurrently: one compute slot at the slower side.
        plan.add(ComputeOp(max(gpu_s, cpu_s), flops=flops), "all")
        plan.add(TransferOp(Path.UM, MemoryTier.DEVICE, MemoryTier.HOST,
                            int(mem_full.m_c * refetch / self.um_refetch),
                            tag="out"), "all", LANE_UM)
        plan.add(TransferOp(Path.STORAGE_HOST, MemoryTier.HOST,
                            MemoryTier.STORAGE, int(mem_full.m_c),
                            tag="out"), "all")
        if mode == "execute":
            plan.reference_kernel = _reference_kernel(a, h)
        return plan


class ETCScheduler(_BaseScheduler):
    """ETC [16] policy model: batched DMA + dedup + inter-batch pipeline.

    Table I: DMA yes, no UM, no alignment, no dual-way. Output buffer is
    allocated at the larger compressed input's size (paper §III-B), which
    shrinks the effective streaming budget; batch boundaries still split
    rows (merge cost remains, amortized by batching ~4x fewer events).

    Plan shape: a serial "load" phase (Phase I loads, merge bounces, output
    paging — ETC has no dual-way overlap for those) plus a "stream" phase
    whose transfer ops depend on the *previous* compute op — the inter-batch
    pipeline can only prefetch one batch ahead.
    """

    name = "etc"
    oom_fraction = 0.72  # Table III: survives one notch lower than UCG

    def __init__(self, *args, dedup: float = 0.80, batch_amortize: int = 4, **kw):
        super().__init__(*args, **kw)
        self.dedup = dedup              # fraction of redundant transfer removed
        self.batch_amortize = batch_amortize

    def build_plan(self, a: CSR, h, mode="simulate",
                   dataset="") -> PipelinePlan:
        feat = self._feat(h)
        f = feat.n_cols
        plan = PipelinePlan(scheduler=self.name, dataset=dataset)
        plan.phases = [PhaseSpec("load", overlap="serial"),
                       PhaseSpec("stream")]
        h_bytes = feat.compressed_bytes
        out_alloc = max(a.nbytes(), h_bytes)  # sized to larger input (§III-B)
        a_budget = self.device_budget - h_bytes - out_alloc
        if a_budget <= 0:
            # Output under-allocation: C pages through a smaller window
            # (extra spills below) and the stream budget shrinks to a floor.
            a_budget = max(int(0.05 * self.device_budget), 1 << 16)
        if self._budget_infeasible(a, feat):
            plan.oom = True
            return plan
        plan.add(TransferOp(Path.STORAGE_HOST, MemoryTier.STORAGE,
                            MemoryTier.HOST, a.nbytes() + h_bytes,
                            tag="load"), "load")
        plan.add(TransferOp(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE,
                            h_bytes, tag="phaseI/H"), "load")

        cuts = naive_partition(a, int(a_budget))
        plan.segments = len(cuts)
        value_bytes = a.data.dtype.itemsize
        per_nnz = 4 + value_bytes
        prev_cmp: Optional[int] = None
        for idx, (lo, hi, first_partial, last_partial) in enumerate(cuts):
            if idx % self.batch_amortize == 0:
                # Batching amortizes the re-staging memcpy across
                # `batch_amortize` segments (ETC's 3-step access policy), but
                # cannot remove it: batch boundaries are still unaligned.
                t0 = time.perf_counter()
                sv = np.ascontiguousarray(a.data[lo:hi])
                si = np.ascontiguousarray(a.indices[lo:hi])
                measured = time.perf_counter() - t0
                plan.add(HostPreprocessOp(
                    self._host_seconds(sv.nbytes + si.nbytes, events=1),
                    measured_s=measured), "load")
            nbytes = int((hi - lo) * per_nnz * (1 - self.dedup * 0.25))
            i_io = plan.add(
                TransferOp(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE,
                           nbytes, tag="seg"), "stream", LANE_DMA,
                deps=(() if prev_cmp is None else (prev_cmp,)))
            prev_cmp = plan.add(
                ComputeOp(self._spgemm_seconds(hi - lo, feat)),
                "stream", LANE_COMPUTE, deps=(i_io,))
            if last_partial and idx % self.batch_amortize == 0:
                plan.add(TransferOp(Path.DMA, MemoryTier.DEVICE,
                                    MemoryTier.HOST, f * 4 + 64 * per_nnz,
                                    tag="merge/DtoH", merge=True), "load")
                plan.merge_events += 1

        # Output paging: C exits via DMA; if the reserved out_alloc is under
        # M_C, the overflow pages out mid-stream as well (no GDS in ETC).
        mem_full = plan_memory_unified(a, feat, m_total=float("inf"))
        plan.add(TransferOp(Path.DMA, MemoryTier.DEVICE, MemoryTier.HOST,
                            int(mem_full.m_c), tag="out"), "load")
        plan.add(TransferOp(Path.STORAGE_HOST, MemoryTier.HOST,
                            MemoryTier.STORAGE, int(mem_full.m_c),
                            tag="out"), "load")
        if mode == "execute":
            plan.reference_kernel = _reference_kernel(a, h)
        return plan


SCHEDULERS = {
    "aires": AiresScheduler,
    "maxmemory": MaxMemoryScheduler,
    "ucg": UCGScheduler,
    "etc": ETCScheduler,
}
