"""AIRES three-phase dynamic scheduling (paper Alg. 2, Fig. 5) + baselines.

Faithful reproduction of the paper's methodology: host-side preprocessing
(RoBW partitioning, tile densification, partial-row merging for baselines)
is **executed and wall-clock measured**; I/O transfers and device kernel
latency are **modeled** with the calibrated tiered-memory cost model —
exactly the split the paper uses (§V-A: "We model the I/O transfer
operations and kernel-level computation latency with simulations").

Schedulers:
  AiresScheduler     — C1+C2+C4+C5: RoBW alignment, Eq.5-7 planning,
                       dual-way Phase I, double-buffered Phase II,
                       on-device C for chaining (Phase III).
  MaxMemoryScheduler — naive max-rows static split; partial-row merge cost.
  UCGScheduler       — unified-memory reads, CPU-GPU split, no alignment.
  ETCScheduler       — batched DMA with dedup + pipeline, output allocated
                       at the larger-input size (paper §III-B), no alignment.

Policy flags mirror paper Table I (Alignment / DMA / UM / Dual-way).
The `execute` mode streams real segments through the Pallas kernel
(interpret on CPU) and returns the exact output — used by tests; the
`simulate` mode models kernel time analytically — used by the large-scale
benchmarks, like the paper.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Literal, Optional

import numpy as np

from repro.core.memory_model import (
    FeatureSpec,
    MemoryEstimate,
    plan_memory_unified,
    required_bytes,
)
from repro.core.robw import (
    RoBWPlan,
    merge_partial_rows,
    naive_partition,
    robw_partition,
    segments_to_block_ell,
)
from repro.io.segment_cache import SegmentKey, TieredSegmentCache
from repro.io.shard_cache import ShardedSegmentCache
from repro.io.tiers import (
    MemoryTier,
    OutOfMemory,
    Path,
    TieredMemorySystem,
    TierSpec,
)
from repro.sparse.formats import CSR, csr_row_slice


@dataclasses.dataclass
class ScheduleMetrics:
    """Everything the paper's figures read off a run."""

    scheduler: str
    dataset: str = ""
    # Latency components (seconds)
    host_preprocess_s: float = 0.0   # modeled: RoBW / densify / merge / pack
    host_measured_s: float = 0.0     # wall-clock of the real host work (diagnostic)
    io_modeled_s: float = 0.0        # modeled: sum of transfer seconds
    compute_modeled_s: float = 0.0   # modeled: device kernel seconds
    makespan_s: float = 0.0          # overlapped end-to-end estimate
    # I/O accounting (Fig. 7/8)
    bytes_by_path: Dict[str, int] = dataclasses.field(default_factory=dict)
    seconds_by_path: Dict[str, float] = dataclasses.field(default_factory=dict)
    total_transfer_bytes: int = 0
    cache_hit_bytes: int = 0         # wire bytes served by the segment cache
    merge_events: int = 0
    merge_io_s: float = 0.0          # modeled DtoH/HtoD seconds for merges
    segments: int = 0
    oom: bool = False

    def merge_overhead_frac(self) -> float:
        """Fig. 3 metric: 'merging the partial segments, and data transfer
        time between the GPU and host memory ... measured over the
        computation latency'."""
        denom = max(self.compute_modeled_s, 1e-12)
        return (self.host_preprocess_s + self.merge_io_s) / denom


@dataclasses.dataclass
class ScheduleResult:
    x: Optional[np.ndarray]          # output (execute mode) or None (simulate)
    metrics: ScheduleMetrics
    plan: Optional[RoBWPlan] = None
    mem: Optional[MemoryEstimate] = None


def _spgemm_flops(a: CSR, f: int) -> float:
    return 2.0 * a.nnz * f


class _BaseScheduler:
    """Shared accounting.

    Feasibility calibration (`oom_fraction`): Table III shows each baseline's
    minimum viable budget as a fraction of Table II's memory requirement —
    MaxMemory/UCG need ≳85 % of (A+B+C), ETC ≳72 % (output allocated at the
    larger input's size), AIRES is bounded only by Eq. 7's p>0. We encode
    those observed thresholds as policy constants; the *latency* model below
    them is mechanistic (transfers, merges, overlap), not curve-fit.
    """

    name = "base"
    oom_fraction = 0.0  # min budget / required_bytes; 0 → model-driven only

    def __init__(
        self,
        spec: TierSpec,
        device_budget: Optional[int] = None,
        peak_flops: float = 82.6e12,       # RTX4090-class fp32 for paper benches
        compute_efficiency: float = 0.20,  # fraction of HBM bw sparse kernels achieve
    ):
        self.spec = spec
        self.device_budget = device_budget or spec.device_capacity
        self.peak_flops = peak_flops
        self.compute_efficiency = compute_efficiency

    def _kernel_seconds(self, flops: float) -> float:
        return flops / (self.peak_flops * self.compute_efficiency)

    def _spgemm_seconds(self, nnz: int, feat: FeatureSpec) -> float:
        """Device time for a compressed-×-compressed partial product.

        Hypersparse SpGEMM is HBM-bound, not FLOP-bound: per A-nonzero the
        kernel reads the A entry, gathers the matching B row segment
        (dens_B·F values+ids) and writes ~E[matches] C entries. Effective
        bandwidth is a fraction of peak (irregular access).
        """
        dens_b = (100.0 - feat.sparsity_pct) / 100.0
        val = feat.dtype_bytes
        idx = feat.index_bytes
        per_nnz = (val + idx) + dens_b * feat.n_cols * (val + idx) \
            + max(dens_b * feat.n_cols, 1.0) * (val + idx)
        bytes_touched = nnz * per_nnz
        return bytes_touched / (self.spec.hbm_bw * self.compute_efficiency)

    def _host_seconds(self, nbytes: float, events: int = 1) -> float:
        """Modeled host staging/merge cost: DRAM memcpy + per-event latency.

        Host costs are modeled (not wall-clock measured) so that scaled-down
        benchmark graphs keep the full-scale cost *ratios*: at 1/1000 scale a
        measured Python-loop overhead would swamp µs-scale modeled
        transfers. Execute-mode still runs the real work; tests compare its
        outputs, not its timing.
        """
        return nbytes / self.spec.host_memcpy_bw \
            + events * self.spec.host_op_latency_s

    @staticmethod
    def _feat(h) -> FeatureSpec:
        return FeatureSpec.of(h)

    def _budget_infeasible(self, a: CSR, feat: FeatureSpec) -> bool:
        if self.oom_fraction <= 0.0:
            return False
        return self.device_budget < self.oom_fraction * required_bytes(a, feat)

    def run(self, a: CSR, h,
            mode: Literal["simulate", "execute"] = "simulate",
            dataset: str = "") -> ScheduleResult:
        raise NotImplementedError


class AiresScheduler(_BaseScheduler):
    """C1+C2+C4+C5 — the paper's contribution, TPU-adapted (DESIGN §2)."""

    name = "aires"

    def __init__(self, *args, bm: int = 128, bk: int = 128, align: int = 8,
                 wire_format: Literal["csr", "bricks"] = "csr",
                 segment_cache: Optional[
                     "TieredSegmentCache | ShardedSegmentCache"] = None, **kw):
        super().__init__(*args, **kw)
        self.bm = bm
        self.bk = bk
        self.align = align
        # "csr": stream raw compressed segments (paper-faithful wire format,
        #        densification happens device-side on GPU); "bricks": stream
        #        densified BlockELL bricks (TPU wire format).
        self.wire_format = wire_format
        # Optional TieredSegmentCache shared across runs: cache-hit segments
        # skip the Phase II DMA transfer (device-tier hit) or pay only the
        # promotion (host-tier hit), both visible in bytes_by_path; skipped
        # wire bytes are reported in metrics.cache_hit_bytes.
        self.segment_cache = segment_cache

    def run(self, a: CSR, h, mode="simulate", dataset="") -> ScheduleResult:
        tms = TieredMemorySystem(self.spec)
        feat = self._feat(h)
        f = feat.n_cols
        m = ScheduleMetrics(scheduler=self.name, dataset=dataset)

        # ---- Phase 0: analytical planning (Eq. 5-7), no data touched.
        mem = plan_memory_unified(a, feat, m_total=self.device_budget)
        if not mem.feasible:
            m.oom = True
            return ScheduleResult(x=None, metrics=m, mem=mem)

        # ---- Phase I: dual-way loads.
        # B/H: storage -> device directly (GDS path analogue).
        tms.alloc(MemoryTier.DEVICE, "H", int(mem.m_b))
        tms.alloc(MemoryTier.DEVICE, "C", int(mem.m_c))
        t_b = tms.transfer(Path.GDS, MemoryTier.STORAGE, MemoryTier.DEVICE,
                           int(mem.m_b), tag="phaseI/H")
        # A: storage -> host for preprocessing.
        a_bytes = a.nbytes()
        tms.alloc(MemoryTier.HOST, "A", a_bytes)
        t_a = tms.transfer(Path.STORAGE_HOST, MemoryTier.STORAGE,
                           MemoryTier.HOST, a_bytes, tag="phaseI/A")
        phase1_io = max(t_b, t_a)  # dual-way: paths overlap (Fig. 5)

        # RoBW partitioning on the CPU: executed for real; its makespan
        # contribution is modeled as one indptr scan + per-segment events
        # (see _host_seconds for why).
        t0 = time.perf_counter()
        plan = robw_partition(a, int(mem.m_a), align=self.align)
        m.host_measured_s += time.perf_counter() - t0
        m.host_preprocess_s += self._host_seconds(
            a.indptr.nbytes, events=plan.n_segments)
        m.segments = plan.n_segments

        # ---- Phase II: double-buffered streaming + per-segment compute.
        seg_io: List[float] = []
        seg_cmp: List[float] = []
        out = np.zeros((a.n_rows, f), dtype=np.float32) if mode == "execute" else None
        ell_iter = (segments_to_block_ell(a, plan, bm=self.bm, bk=self.bk)
                    if mode == "execute" or self.wire_format == "bricks" else None)
        ells = list(ell_iter) if ell_iter is not None else [None] * plan.n_segments

        cache = self.segment_cache
        # "sim:" prefix keeps simulate-mode token entries from ever aliasing
        # an execute-mode device payload in a shared cache.
        graph_ns = (f"sim:g{id(a)}:{a.nnz}:{a.shape[0]}x{a.shape[1]}"
                    f":w{f}:b{self.device_budget}")
        for i, (seg, ell) in enumerate(zip(plan.segments, ells)):
            if self.wire_format == "bricks" and ell is not None:
                wire_bytes = ell.nbytes()
                wire_shape = tuple(ell.blocks.shape)
            else:
                wire_bytes = seg.nbytes
                wire_shape = (seg.n_rows, seg.nnz)
            if cache is not None:
                key = SegmentKey(graph_ns, i, self.wire_format, wire_shape)
                hit, promote_s = cache.get_with_cost(
                    key, nbytes=wire_bytes, tms=tms)
                if hit is not None:
                    m.cache_hit_bytes += wire_bytes
                    # Device-tier hit: free. Host-tier hit: the promotion DMA
                    # (already in tms) is this segment's pipeline I/O slot.
                    seg_io.append(promote_s)
                else:
                    seg_io.append(tms.transfer(
                        Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE,
                        wire_bytes, tag="phaseII/seg"))
                    cache.put(key, ell if ell is not None else True,
                              wire_bytes, tms=tms, pin=a)
            else:
                seg_io.append(tms.transfer(
                    Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE,
                    wire_bytes, tag="phaseII/seg"))
            seg_cmp.append(self._spgemm_seconds(seg.nnz, feat))
            if mode == "execute" and ell is not None:
                from repro.kernels import bcsr_spmm as _spmm_op
                import jax.numpy as jnp
                x_seg = np.asarray(_spmm_op(ell, jnp.asarray(h)))
                out[seg.row_start:seg.row_end] = x_seg[: seg.n_rows]

        # Double buffering: segment-k+1 transfer overlaps segment-k compute;
        # the DMA channel and the compute unit are each serial resources.
        pipeline = 0.0
        io_free = 0.0
        for io_s, cmp_s in zip(seg_io, seg_cmp):
            io_done = io_free + io_s          # DMA channel availability
            pipeline = max(pipeline, io_done) + cmp_s
            io_free = io_done
        phase2 = pipeline

        # ---- Phase III: C stays on device for chaining; final store of the
        # compressed output via the direct storage path.
        t_store = tms.transfer(Path.GDS, MemoryTier.DEVICE, MemoryTier.STORAGE,
                               int(mem.m_c), tag="phaseIII/C")

        m.io_modeled_s = sum(t.seconds for t in tms.transfers)
        m.compute_modeled_s = sum(seg_cmp)
        # Dual-way Phase I: the GDS load of B overlaps both the A load and
        # the CPU-side RoBW pass (independent resources, Fig. 5).
        phase1 = max(t_b, t_a + m.host_preprocess_s)
        m.makespan_s = phase1 + phase2 + t_store
        m.bytes_by_path = {p.value: b for p, b in tms.bytes_by_path().items()}
        m.seconds_by_path = {p.value: s for p, s in tms.seconds_by_path().items()}
        m.total_transfer_bytes = tms.total_bytes()
        return ScheduleResult(x=out, metrics=m, plan=plan, mem=mem)


class MaxMemoryScheduler(_BaseScheduler):
    """Naive static split: maximize rows per segment, merge partial rows.

    Models the paper's MaxMemory baseline: equal static allocation for A and
    B on device; segments cut at byte budget regardless of row boundaries;
    partial rows bounce back to host for merging (measured numpy work) and
    are re-transferred (modeled DMA) — the Fig. 3 overhead.
    """

    name = "maxmemory"
    oom_fraction = 0.84  # Table III: dies one notch below Memory Req.

    def run(self, a: CSR, h, mode="simulate", dataset="") -> ScheduleResult:
        tms = TieredMemorySystem(self.spec)
        feat = self._feat(h)
        f = feat.n_cols
        m = ScheduleMetrics(scheduler=self.name, dataset=dataset)
        h_bytes = feat.compressed_bytes
        half = self.device_budget // 2
        if h_bytes > half or self._budget_infeasible(a, feat):
            m.oom = True  # static split cannot fit B / minimum set absent
            return ScheduleResult(x=None, metrics=m)
        try:
            tms.alloc(MemoryTier.DEVICE, "H", h_bytes)
            tms.alloc(MemoryTier.DEVICE, "A_seg", min(half, self.spec.device_capacity - h_bytes))
        except OutOfMemory:
            m.oom = True
            return ScheduleResult(x=None, metrics=m)

        # B over PCIe through host (no GDS in baseline), serial with A.
        tms.transfer(Path.STORAGE_HOST, MemoryTier.STORAGE, MemoryTier.HOST,
                     h_bytes, tag="phaseI/H")
        tms.transfer(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE, h_bytes,
                     tag="phaseI/H")
        tms.transfer(Path.STORAGE_HOST, MemoryTier.STORAGE, MemoryTier.HOST,
                     a.nbytes(), tag="phaseI/A")

        cuts = naive_partition(a, half)
        m.segments = len(cuts)
        total_cmp = 0.0
        value_bytes = a.data.dtype.itemsize
        per_nnz = 4 + value_bytes
        row_of = np.searchsorted(a.indptr, np.arange(a.nnz + 1), side="right") - 1
        carry_vals = np.empty(0, dtype=a.data.dtype)
        for (lo, hi, first_partial, last_partial) in cuts:
            # Unaligned cut ⇒ every segment must be re-packed ("staged") into
            # a contiguous pinned buffer before HtoD: the stored layout does
            # not match the transfer window. Measured host memcpy — this is
            # the bulk of the Fig. 3 overhead; AIRES's aligned segments skip
            # it entirely (segments ARE the stored layout).
            t0 = time.perf_counter()
            staged_vals = np.ascontiguousarray(a.data[lo:hi])
            staged_idx = np.ascontiguousarray(a.indices[lo:hi])
            m.host_measured_s += time.perf_counter() - t0
            m.host_preprocess_s += self._host_seconds(
                staged_vals.nbytes + staged_idx.nbytes, events=1)
            if first_partial and carry_vals.size:
                # Merge the previous segment's partial row with its
                # continuation on the host (measured), re-send.
                row = row_of[lo]
                row_end = int(a.indptr[row + 1])
                t0 = time.perf_counter()
                merged = merge_partial_rows(carry_vals,
                                            np.asarray(a.data[lo:row_end]))
                np.ascontiguousarray(merged)  # pinned-buffer re-pack
                m.host_measured_s += time.perf_counter() - t0
                m.host_preprocess_s += self._host_seconds(
                    2 * merged.nbytes, events=2)
                m.merge_io_s += tms.transfer(
                    Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE,
                    merged.size * per_nnz + f * 4, tag="merge/HtoD")
                m.merge_events += 1
            nbytes = (hi - lo) * per_nnz
            tms.transfer(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE, nbytes,
                         tag="seg")
            total_cmp += self._spgemm_seconds(hi - lo, feat)
            del staged_vals, staged_idx
            if last_partial:
                # Incomplete row returns to host (values + partial result).
                row = row_of[hi]
                row_lo = int(a.indptr[row])
                carry_vals = np.asarray(a.data[row_lo:hi])
                tail_bytes = carry_vals.size * per_nnz + f * 4
                m.merge_io_s += tms.transfer(
                    Path.DMA, MemoryTier.DEVICE, MemoryTier.HOST,
                    tail_bytes, tag="merge/DtoH")
            else:
                carry_vals = np.empty(0, dtype=a.data.dtype)

        # Dynamic-size output vs static allocation (§III-B): C shares the
        # non-A half with B. Every time the C slot fills, the partial output
        # spills DtoH; because a hypersparse A spreads each C row's updates
        # across many segments, spilled C blocks are re-fetched when later
        # segments touch them again (thrash ∝ spill count, capped).
        mem_full = plan_memory_unified(a, feat, m_total=float("inf"))
        c_slot = max(half - h_bytes, 1)
        n_spills = max(1, int(np.ceil(mem_full.m_c / c_slot)))
        thrash = min(n_spills, 3)
        tms.transfer(Path.DMA, MemoryTier.DEVICE, MemoryTier.HOST,
                     int(mem_full.m_c) * thrash, tag="spill/C")
        if n_spills > 1:
            # Re-uploaded C partials that later segments accumulate into.
            reup = int(mem_full.m_c * 0.35 * (thrash - 1))
            m.merge_io_s += tms.transfer(Path.DMA, MemoryTier.HOST,
                                         MemoryTier.DEVICE, reup,
                                         tag="spill/reup")
            # Capacity pressure also evicts resident B pages; they re-read.
            b_evict = int(h_bytes * min(
                1.0, 0.4 * max(0.0, (mem_full.m_c - c_slot)) / max(h_bytes, 1)))
            if b_evict:
                tms.transfer(Path.STORAGE_HOST, MemoryTier.STORAGE,
                             MemoryTier.HOST, b_evict, tag="evict/B")
                tms.transfer(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE,
                             b_evict, tag="evict/B")
        out = None
        if mode == "execute":
            from repro.sparse.ref_spgemm import spgemm_csr_dense
            out = spgemm_csr_dense(a, np.asarray(h))  # baseline correctness path
        tms.transfer(Path.STORAGE_HOST, MemoryTier.HOST, MemoryTier.STORAGE,
                     int(mem_full.m_c), tag="phaseIII/C")

        m.io_modeled_s = sum(t.seconds for t in tms.transfers)
        m.compute_modeled_s = total_cmp
        # No overlap in the naive baseline: serial makespan.
        m.makespan_s = m.io_modeled_s + m.host_preprocess_s + total_cmp
        m.bytes_by_path = {p.value: b for p, b in tms.bytes_by_path().items()}
        m.seconds_by_path = {p.value: s for p, s in tms.seconds_by_path().items()}
        m.total_transfer_bytes = tms.total_bytes()
        return ScheduleResult(x=out, metrics=m)


class UCGScheduler(_BaseScheduler):
    """UCG [22] policy model: unified-memory reads + CPU/GPU work split.

    Table I: no alignment, no DMA batching, UM reads, no dual-way. UM
    page-fault traffic re-reads hot pages; a fraction of work runs on CPU
    (dynamic balance) at CPU throughput.
    """

    name = "ucg"
    oom_fraction = 0.84  # Table III: same threshold as MaxMemory

    def __init__(self, *args, cpu_flops: float = 1.2e12,
                 cpu_fraction: float = 0.15, um_refetch: float = 1.15, **kw):
        super().__init__(*args, **kw)
        self.cpu_flops = cpu_flops
        self.cpu_fraction = cpu_fraction
        self.um_refetch = um_refetch  # page-granularity over-fetch factor

    def run(self, a: CSR, h, mode="simulate", dataset="") -> ScheduleResult:
        tms = TieredMemorySystem(self.spec)
        feat = self._feat(h)
        f = feat.n_cols
        m = ScheduleMetrics(scheduler=self.name, dataset=dataset)
        h_bytes = feat.compressed_bytes
        if self._budget_infeasible(a, feat):
            # UM spills, but a minimum resident set must fit (Table III '-').
            m.oom = True
            return ScheduleResult(x=None, metrics=m)

        tms.transfer(Path.STORAGE_HOST, MemoryTier.STORAGE, MemoryTier.HOST,
                     a.nbytes() + h_bytes, tag="load")
        # UM moves A, H and C on demand. Page-granularity refetch grows as
        # the resident share shrinks: fewer pages stay cached, so evicted
        # pages refault — refetch ∝ working-set / budget.
        mem_full = plan_memory_unified(a, feat, m_total=float("inf"))
        working_set = a.nbytes() + h_bytes + mem_full.m_c
        refetch = self.um_refetch * max(
            1.0, 0.6 * working_set / max(self.device_budget, 1))
        um_bytes = int((a.nbytes() + h_bytes) * refetch)
        tms.transfer(Path.UM, MemoryTier.HOST, MemoryTier.DEVICE, um_bytes,
                     tag="um")
        dens_b = (100.0 - feat.sparsity_pct) / 100.0
        flops = max(_spgemm_flops(a, f) * dens_b, 2.0 * a.nnz)
        gpu_s = self._kernel_seconds(flops * (1 - self.cpu_fraction))
        cpu_s = flops * self.cpu_fraction / self.cpu_flops
        total_cmp = max(gpu_s, cpu_s)  # CPU/GPU run concurrently
        tms.transfer(Path.UM, MemoryTier.DEVICE, MemoryTier.HOST,
                     int(mem_full.m_c * refetch / self.um_refetch), tag="out")
        tms.transfer(Path.STORAGE_HOST, MemoryTier.HOST, MemoryTier.STORAGE,
                     int(mem_full.m_c), tag="out")

        out = None
        if mode == "execute":
            from repro.sparse.ref_spgemm import spgemm_csr_dense
            out = spgemm_csr_dense(a, np.asarray(h))
        m.io_modeled_s = sum(t.seconds for t in tms.transfers)
        m.compute_modeled_s = total_cmp
        m.makespan_s = m.io_modeled_s + total_cmp  # UM serializes with compute
        m.bytes_by_path = {p.value: b for p, b in tms.bytes_by_path().items()}
        m.seconds_by_path = {p.value: s for p, s in tms.seconds_by_path().items()}
        m.total_transfer_bytes = tms.total_bytes()
        m.segments = 1
        return ScheduleResult(x=out, metrics=m)


class ETCScheduler(_BaseScheduler):
    """ETC [16] policy model: batched DMA + dedup + inter-batch pipeline.

    Table I: DMA yes, no UM, no alignment, no dual-way. Output buffer is
    allocated at the larger compressed input's size (paper §III-B), which
    shrinks the effective streaming budget; batch boundaries still split
    rows (merge cost remains, amortized by batching ~4x fewer events).
    """

    name = "etc"
    oom_fraction = 0.72  # Table III: survives one notch lower than UCG

    def __init__(self, *args, dedup: float = 0.80, batch_amortize: int = 4, **kw):
        super().__init__(*args, **kw)
        self.dedup = dedup              # fraction of redundant transfer removed
        self.batch_amortize = batch_amortize

    def run(self, a: CSR, h, mode="simulate", dataset="") -> ScheduleResult:
        tms = TieredMemorySystem(self.spec)
        feat = self._feat(h)
        f = feat.n_cols
        m = ScheduleMetrics(scheduler=self.name, dataset=dataset)
        h_bytes = feat.compressed_bytes
        out_alloc = max(a.nbytes(), h_bytes)  # sized to larger input (§III-B)
        a_budget = self.device_budget - h_bytes - out_alloc
        if a_budget <= 0:
            # Output under-allocation: C pages through a smaller window
            # (extra spills below) and the stream budget shrinks to a floor.
            a_budget = max(int(0.05 * self.device_budget), 1 << 16)
        if self._budget_infeasible(a, feat):
            m.oom = True
            return ScheduleResult(x=None, metrics=m)
        tms.transfer(Path.STORAGE_HOST, MemoryTier.STORAGE, MemoryTier.HOST,
                     a.nbytes() + h_bytes, tag="load")
        tms.transfer(Path.DMA, MemoryTier.HOST, MemoryTier.DEVICE, h_bytes,
                     tag="phaseI/H")

        cuts = naive_partition(a, int(a_budget))
        m.segments = len(cuts)
        value_bytes = a.data.dtype.itemsize
        per_nnz = 4 + value_bytes
        seg_io, seg_cmp = [], []
        merge_seg = 0
        for idx, (lo, hi, first_partial, last_partial) in enumerate(cuts):
            if idx % self.batch_amortize == 0:
                # Batching amortizes the re-staging memcpy across
                # `batch_amortize` segments (ETC's 3-step access policy), but
                # cannot remove it: batch boundaries are still unaligned.
                t0 = time.perf_counter()
                sv = np.ascontiguousarray(a.data[lo:hi])
                si = np.ascontiguousarray(a.indices[lo:hi])
                m.host_measured_s += time.perf_counter() - t0
                m.host_preprocess_s += self._host_seconds(
                    sv.nbytes + si.nbytes, events=1)
            nbytes = int((hi - lo) * per_nnz * (1 - self.dedup * 0.25))
            seg_io.append(tms.transfer(Path.DMA, MemoryTier.HOST,
                                       MemoryTier.DEVICE, nbytes, tag="seg"))
            seg_cmp.append(self._spgemm_seconds(hi - lo, feat))
            if last_partial and idx % self.batch_amortize == 0:
                m.merge_io_s += tms.transfer(
                    Path.DMA, MemoryTier.DEVICE, MemoryTier.HOST,
                    f * 4 + 64 * per_nnz, tag="merge/DtoH")
                m.merge_events += 1

        # Inter-batch pipeline: IO overlaps compute (like AIRES Phase II).
        pipeline, io_free = 0.0, 0.0
        for io_s, cmp_s in zip(seg_io, seg_cmp):
            start = max(io_free, pipeline)
            io_done = start + io_s
            pipeline = max(pipeline, io_done) + cmp_s
            io_free = io_done
        # Output paging: C exits via DMA; if the reserved out_alloc is under
        # M_C, the overflow pages out mid-stream as well (no GDS in ETC).
        mem_full = plan_memory_unified(a, feat, m_total=float("inf"))
        tms.transfer(Path.DMA, MemoryTier.DEVICE, MemoryTier.HOST,
                     int(mem_full.m_c), tag="out")
        tms.transfer(Path.STORAGE_HOST, MemoryTier.HOST, MemoryTier.STORAGE,
                     int(mem_full.m_c), tag="out")

        out = None
        if mode == "execute":
            from repro.sparse.ref_spgemm import spgemm_csr_dense
            out = spgemm_csr_dense(a, np.asarray(h))
        m.io_modeled_s = sum(t.seconds for t in tms.transfers)
        m.compute_modeled_s = sum(seg_cmp)
        load_s = sum(t.seconds for t in tms.transfers if t.tag != "seg")
        m.makespan_s = load_s + m.host_preprocess_s + pipeline
        m.bytes_by_path = {p.value: b for p, b in tms.bytes_by_path().items()}
        m.seconds_by_path = {p.value: s for p, s in tms.seconds_by_path().items()}
        m.total_transfer_bytes = tms.total_bytes()
        return ScheduleResult(x=out, metrics=m)


SCHEDULERS = {
    "aires": AiresScheduler,
    "maxmemory": MaxMemoryScheduler,
    "ucg": UCGScheduler,
    "etc": ETCScheduler,
}
