"""AIRES core — the paper's primary contribution in JAX.

  memory_model : Eq. (5)-(7) analytical planning
  robw         : Algorithm 1 row block-wise alignment (+ RoBW-128)
  pipeline     : typed pipeline-plan IR + cost/execute interpreters
  analysis     : static plan analyzer (liveness, races, byte lints)
  scheduler    : Algorithm 2 plan builders (AIRES + baselines)
  spgemm       : AiresSpGEMM public API + chained GCN epoch runner
  calibration  : online per-path bandwidth/latency fitting (cost loop)
  autotune     : schedule knob search over the plan IR
"""
from repro.core.analysis import (
    AnalysisReport,
    Finding,
    PlanAnalysisError,
    RULES,
    analyze_plan,
    diff_path_totals,
    path_byte_totals,
)
from repro.core.memory_model import (
    FeatureSpec,
    MemoryEstimate,
    calc_mem,
    ell_bucket_capacity,
    estimate_output_bytes,
    estimate_resident_bytes,
    plan_memory,
    plan_memory_dense_features,
    plan_memory_spec,
    plan_memory_unified,
    required_bytes,
    segment_budget,
)
from repro.core.pipeline import (
    AllocOp,
    CacheProbeOp,
    ComputeOp,
    CostInterpreter,
    ExecuteInterpreter,
    HostPreprocessOp,
    PhaseSpec,
    PipelinePlan,
    PlanOp,
    PlanValidationError,
    TransferOp,
    modeled_spgemm_seconds,
)
from repro.core.passes import (
    CoalescedPayload,
    EDFOrderingPass,
    PassContext,
    PassPipeline,
    PassReport,
    PlanPass,
    ShardPlacementPass,
    TransferCoalescingPass,
    deadline_order,
    edf_sort,
)
from repro.core.autotune import (
    TunedSchedule,
    autotune_schedule,
    bucket_set_bytes,
    candidate_bucket_sets,
)
from repro.core.calibration import (
    CostCalibrator,
    PathEstimate,
)
from repro.core.robw import (
    RoBWPlan,
    RoBWSegment,
    densify_segment,
    merge_partial_rows,
    naive_partition,
    robw_delta_partition,
    robw_partition,
    robw_transpose_plan,
    segment_ell_widths,
    segments_to_block_ell,
)
from repro.core.scheduler import (
    SCHEDULERS,
    AiresScheduler,
    ETCScheduler,
    MaxMemoryScheduler,
    ScheduleMetrics,
    ScheduleResult,
    UCGScheduler,
)
from repro.core.spgemm import (
    AiresConfig, AiresSpGEMM, EpochMetrics, UpdateStats, gcn_epoch,
)

__all__ = [
    "AnalysisReport", "Finding", "PlanAnalysisError", "RULES",
    "analyze_plan", "diff_path_totals", "path_byte_totals",
    "FeatureSpec", "MemoryEstimate", "calc_mem", "ell_bucket_capacity",
    "estimate_output_bytes", "estimate_resident_bytes", "plan_memory",
    "plan_memory_dense_features", "plan_memory_spec", "plan_memory_unified",
    "required_bytes",
    "segment_budget",
    "RoBWPlan", "RoBWSegment", "densify_segment", "merge_partial_rows",
    "naive_partition", "robw_delta_partition", "robw_partition",
    "robw_transpose_plan", "segment_ell_widths", "segments_to_block_ell",
    "CostCalibrator", "PathEstimate",
    "TunedSchedule", "autotune_schedule", "bucket_set_bytes",
    "candidate_bucket_sets",
    "SCHEDULERS", "AiresScheduler", "ETCScheduler", "MaxMemoryScheduler",
    "ScheduleMetrics", "ScheduleResult", "UCGScheduler",
    "AllocOp", "CacheProbeOp", "ComputeOp", "CostInterpreter",
    "ExecuteInterpreter", "HostPreprocessOp", "PhaseSpec", "PipelinePlan",
    "PlanOp", "PlanValidationError", "TransferOp", "modeled_spgemm_seconds",
    "CoalescedPayload", "EDFOrderingPass", "PassContext", "PassPipeline",
    "PassReport", "PlanPass", "ShardPlacementPass", "TransferCoalescingPass",
    "deadline_order", "edf_sort",
    "AiresConfig", "AiresSpGEMM", "EpochMetrics", "UpdateStats", "gcn_epoch",
]
