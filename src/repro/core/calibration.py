"""Online cost-model calibration: fit TierSpec coefficients from traffic.

Every scheduling decision the runtime makes — admission control, EDF
ordering, backpressure, every pass's cost delta — prices plans through
`PipelinePlan.estimate()` against *static* `TierSpec` bandwidth/latency
constants. Real systems drift: link contention, host paging, thermal
throttling. This module closes the ROADMAP "cost-model calibration loop":

  * :class:`CostCalibrator` consumes two observation streams —

      - **per-path transfer timings** (`observe_transfer` /
        `observe_records` over `TieredMemorySystem.TransferRecord`s,
        tagged by `Path` and hop count) and fits, per path, the linear
        model ``seconds = latency_s·hops + bytes/bw`` by accumulated
        least squares over ``(hops, bytes) → seconds``;
      - **request-level prediction error** (`observe_error` /
        `observe_batch` over `RequestLatency`-shaped objects): an EWMA of
        the ``processing_s / predicted_s`` ratio — the only online signal
        a long-lived serving engine has (its tms runs
        ``keep_records=False``), applied as a scale to paths that have no
        direct transfer observations.

  * `calibrated(base)` exposes the fits as a **view**: a new `TierSpec`
    via `dataclasses.replace` with only `bw` / `latency_s` rewritten —
    capacities and the byte-accounting semantics are untouched, so the
    calibrated spec drops into `CostInterpreter`/`estimate()` anywhere
    the static one did. With zero observations it returns `base` itself
    (identity), which is what keeps calibration **off by default**
    bit-exact.

  * Fits are **trust-blended**, not swapped in: after ``n`` observation
    rounds a path's coefficients are ``(1-w)·base + w·fitted`` with
    ``w = 1-(1-blend)^n``, so predictions converge geometrically onto the
    fitted model — prediction error shrinks strictly window over window
    (the property `benchmarks/bench_autotune.py` persists) instead of
    jumping on the first noisy sample.

  * `generation` increments on every state change; the serving engine
    compares it to invalidate stale `_pass_costs` memos and reprice
    queued requests (see `ServingEngine.cost_spec`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.io.tiers import Path, TierSpec, TransferRecord

__all__ = ["CostCalibrator", "PathEstimate"]


@dataclasses.dataclass
class _PathModel:
    """Accumulated least-squares moments for one path's linear model
    ``seconds = θ₀·hops + θ₁·bytes`` (θ₀ = setup latency per link,
    θ₁ = 1/effective bandwidth). Moments, not samples: O(1) state no
    matter how long the engine serves."""

    n_obs: int = 0        # individual transfers folded in
    rounds: int = 0       # observation rounds (trust grows per round)
    s_hh: float = 0.0
    s_hb: float = 0.0
    s_bb: float = 0.0
    s_hs: float = 0.0
    s_bs: float = 0.0

    def observe(self, hops: float, nbytes: float, seconds: float) -> None:
        h, b, s = float(hops), float(nbytes), float(seconds)
        self.n_obs += 1
        self.s_hh += h * h
        self.s_hb += h * b
        self.s_bb += b * b
        self.s_hs += h * s
        self.s_bs += b * s

    def fit(self, base_latency_s: float) -> Optional[Tuple[float, float]]:
        """Solve the 2×2 normal equations; returns ``(latency_s, inv_bw)``
        or None with no observations. Degenerate designs (every sample at
        the same bytes-per-hop ratio cannot separate setup from bandwidth)
        keep the base latency and fit only the bandwidth term — which
        still reproduces the observed seconds at the observed sizes."""
        if self.n_obs == 0:
            return None
        det = self.s_hh * self.s_bb - self.s_hb * self.s_hb
        if det > 1e-9 * max(self.s_hh * self.s_bb, 1e-300):
            lat = (self.s_bb * self.s_hs - self.s_hb * self.s_bs) / det
            inv_bw = (self.s_hh * self.s_bs - self.s_hb * self.s_hs) / det
        else:
            lat = base_latency_s
            inv_bw = ((self.s_bs - lat * self.s_hb) / self.s_bb
                      if self.s_bb > 0.0 else 0.0)
        return max(lat, 0.0), max(inv_bw, 1e-300)


@dataclasses.dataclass(frozen=True)
class PathEstimate:
    """One path's calibration reading: the raw fit and the trust weight
    the calibrated view blends it in with."""

    path: Path
    n_obs: int
    rounds: int
    bw: float           # fitted effective bandwidth, bytes/s
    latency_s: float    # fitted per-link setup latency
    trust: float        # blend weight w = 1-(1-blend)^rounds


class CostCalibrator:
    """Online per-path bandwidth/latency fits + request-error EWMA,
    exposed as a calibrated `TierSpec` view (see module docstring)."""

    def __init__(self, blend: float = 0.5, error_alpha: float = 0.25):
        if not 0.0 < blend <= 1.0:
            raise ValueError(f"blend must be in (0, 1], got {blend}")
        if not 0.0 < error_alpha <= 1.0:
            raise ValueError(
                f"error_alpha must be in (0, 1], got {error_alpha}")
        self.blend = float(blend)
        self.error_alpha = float(error_alpha)
        self._models: Dict[Path, _PathModel] = {}
        # Request-error channel: EWMA of processing_s / predicted_s.
        self._error_ratio = 1.0
        self._error_rounds = 0
        self._error_n = 0
        # Bumped on every state change; the engine invalidates its
        # `_pass_costs` memos (and reprices its queue) when it moves.
        self.generation = 0

    # ---- observation: per-path transfer timings --------------------------

    def observe_transfer(self, path: Path, nbytes: int, seconds: float,
                         hops: int = 1) -> None:
        """Fold one observed transfer into `path`'s fit (one trust round)."""
        if nbytes <= 0 or seconds <= 0.0:
            return
        m = self._models.setdefault(path, _PathModel())
        m.observe(max(int(hops), 1), int(nbytes), float(seconds))
        m.rounds += 1
        self.generation += 1

    def observe_records(self, records: Iterable[TransferRecord]) -> int:
        """Fold a batch of `TransferRecord`s (one trust round per path
        that received any). Records store *wire* bytes (payload × hops);
        the fit is over payload bytes, recovered from the hop count.
        Returns the number of records consumed."""
        touched: Dict[Path, int] = {}
        for rec in records:
            hops = max(int(getattr(rec, "hops", 1)), 1)
            payload = rec.nbytes // hops
            if payload <= 0 or rec.seconds <= 0.0:
                continue
            m = self._models.setdefault(rec.path, _PathModel())
            m.observe(hops, payload, rec.seconds)
            touched[rec.path] = touched.get(rec.path, 0) + 1
        for path in touched:
            self._models[path].rounds += 1
        if touched:
            self.generation += 1
        return sum(touched.values())

    # ---- observation: request-level prediction error ---------------------

    def observe_error(self, latency: Any) -> bool:
        """Fold one `RequestLatency`-shaped sample (``predicted_s`` +
        ``processing_s`` attributes) into the error-ratio EWMA. Samples
        with a non-positive prediction carry no ratio and are skipped."""
        predicted = float(getattr(latency, "predicted_s", 0.0))
        processing = float(getattr(latency, "processing_s", 0.0))
        if predicted <= 0.0 or processing <= 0.0:
            return False
        a = self.error_alpha
        self._error_ratio = ((1.0 - a) * self._error_ratio
                             + a * (processing / predicted))
        self._error_n += 1
        self.generation += 1
        return True

    def observe_batch(self, latencies: Iterable[Any]) -> int:
        """Fold a batch of request latencies (one error trust round)."""
        n = sum(1 for lat in latencies if self.observe_error(lat))
        if n:
            self._error_rounds += 1
        return n

    # ---- readings --------------------------------------------------------

    def _trust(self, rounds: int) -> float:
        return 1.0 - (1.0 - self.blend) ** rounds

    def fitted(self, path: Path,
               base: Optional[TierSpec] = None) -> Optional[Tuple[float, float]]:
        """Raw (unblended) fit for `path`: ``(bw, latency_s)`` or None."""
        m = self._models.get(path)
        if m is None:
            return None
        base_lat = base.latency_s.get(path, 0.0) if base is not None else 0.0
        fit = m.fit(base_lat)
        if fit is None:
            return None
        lat, inv_bw = fit
        return 1.0 / inv_bw, lat

    def estimates(self, base: TierSpec) -> List[PathEstimate]:
        out = []
        for path, m in sorted(self._models.items(), key=lambda kv: kv[0].value):
            fit = self.fitted(path, base)
            if fit is None:
                continue
            bw, lat = fit
            out.append(PathEstimate(path, m.n_obs, m.rounds, bw, lat,
                                    self._trust(m.rounds)))
        return out

    @property
    def error_scale(self) -> float:
        """Trust-weighted processing/predicted ratio — the scale applied
        to paths without direct transfer observations."""
        w = self._trust(self._error_rounds)
        return 1.0 + w * (self._error_ratio - 1.0)

    def calibrated(self, base: TierSpec) -> TierSpec:
        """Calibrated view of `base`: per-path `bw`/`latency_s` replaced
        by trust-blended fits (blending in inverse-bandwidth space, so
        modeled seconds interpolate linearly); paths with no direct
        observations scaled by the request-error channel. Capacities,
        `hbm_bw` and every byte-accounting field pass through untouched.
        With zero observations this returns `base` itself — the
        calibration-off identity the golden tests pin."""
        if self.generation == 0:
            return base
        scale = self.error_scale
        bw = dict(base.bw)
        lat = dict(base.latency_s)
        for path in bw:
            m = self._models.get(path)
            fit = m.fit(base.latency_s.get(path, 0.0)) if m is not None \
                else None
            if fit is not None:
                fit_lat, fit_inv = fit
                w = self._trust(m.rounds)
                inv = (1.0 - w) / bw[path] + w * fit_inv
                bw[path] = 1.0 / inv
                lat[path] = (1.0 - w) * lat[path] + w * fit_lat
            elif scale != 1.0:
                bw[path] = bw[path] / scale
                lat[path] = lat[path] * scale
        return dataclasses.replace(base, bw=bw, latency_s=lat)
