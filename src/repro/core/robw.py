"""RoBW — Row Block-Wise partitioning (paper Algorithm 1 + Fig. 4).

Given CSR A and a per-segment device budget M_A, greedily pack *complete
rows* into segments such that calcMem(k, q) ≤ M_A. The invariant (tested by
hypothesis): segment boundaries never split a row, and concatenating the
segments reproduces A exactly — this is what eliminates the merge overhead
of Fig. 3.

TPU extension (RoBW-128): segment boundaries are additionally aligned to a
row-block multiple `align` (default 8, the f32 sublane; 128 for full MXU
tiles) so every streamed segment densifies into whole BlockELL bricks.
Alignment can only *shrink* a segment, so calcMem budget still holds.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional

import numpy as np

from repro.core.memory_model import calc_mem, ell_bucket_capacity
from repro.sparse.blocking import tile_csr_to_block_ell
from repro.sparse.formats import CSR, BlockELL, csr_row_slice, csr_transpose


@dataclasses.dataclass
class RoBWSegment:
    """One aligned segment: complete rows [row_start, row_end)."""

    row_start: int
    row_end: int
    nnz: int
    nbytes: int

    @property
    def n_rows(self) -> int:
        return self.row_end - self.row_start


@dataclasses.dataclass
class RoBWPlan:
    segments: List[RoBWSegment]
    align: int
    budget_bytes: int

    @property
    def n_segments(self) -> int:
        return len(self.segments)

    def max_rows(self) -> int:
        return max((s.n_rows for s in self.segments), default=0)

    def max_nnz(self) -> int:
        return max((s.nnz for s in self.segments), default=0)


def robw_partition(
    a: CSR,
    m_a_bytes: int,
    align: int = 1,
    value_bytes: Optional[int] = None,
    index_bytes: int = 4,
    boundaries=None,
) -> RoBWPlan:
    """Algorithm 1, vectorized where possible.

    Walks rows, extending the block while calcMem(k, q) ≤ M_A; emits the
    block, then continues from the next row (never mid-row). With align>1,
    the emitted boundary is rounded *down* to the alignment grid unless that
    would make the block empty.

    `boundaries` is an optional row-index tiling grid (e.g.
    `repro.sparse.partition.Partition.boundaries()` — the rows where the
    cluster label changes): a segment's end is clamped down to the first
    boundary strictly inside it, so no segment straddles a cluster
    boundary and every segment maps to exactly one owner shard. Clamping
    only shrinks segments (the calcMem budget and the complete-row
    invariant both still hold); ``boundaries=None`` is byte-identical to
    the unclamped plan.
    """
    if value_bytes is None:
        value_bytes = int(a.data.dtype.itemsize)
    n = a.n_rows
    cuts = None
    if boundaries is not None:
        cuts = np.unique(np.asarray(boundaries, dtype=np.int64).ravel())
        cuts = cuts[(cuts > 0) & (cuts < n)]
    segments: List[RoBWSegment] = []
    start = 0
    indptr = a.indptr
    while start < n:
        # Greedy expansion (Alg. 1 lines 5-8). Vectorized: find the largest
        # end such that calcMem(end-start, indptr[end]-indptr[start]) <= M_A.
        k = np.arange(1, n - start + 1, dtype=np.int64)
        q = indptr[start + 1 : n + 1] - indptr[start]
        mem = (k + 1) * index_bytes + q * (index_bytes + value_bytes)
        fits = np.nonzero(mem <= m_a_bytes)[0]
        if fits.shape[0] == 0:
            # Single row exceeds budget: emit it alone (the paper's blocks
            # are at least one row; callers check plan feasibility upstream).
            end = start + 1
        else:
            end = start + int(fits[-1]) + 1
            if align > 1 and end < n:
                aligned = start + ((end - start) // align) * align
                if aligned > start:
                    end = aligned
            if cuts is not None and cuts.size:
                # Clamp to the first tiling boundary strictly inside
                # (start, end): cuts[j] > start implies end > start holds.
                j = int(np.searchsorted(cuts, start, side="right"))
                if j < cuts.size and int(cuts[j]) < end:
                    end = int(cuts[j])
        nnz = int(indptr[end] - indptr[start])
        segments.append(
            RoBWSegment(
                row_start=start,
                row_end=end,
                nnz=nnz,
                nbytes=calc_mem(end - start, nnz, value_bytes, index_bytes),
            )
        )
        start = end
    return RoBWPlan(segments=segments, align=align, budget_bytes=m_a_bytes)


def robw_transpose_plan(
    a: CSR,
    m_a_bytes: int,
    align: int = 1,
    value_bytes: Optional[int] = None,
    index_bytes: int = 4,
    a_t: Optional[CSR] = None,
    boundaries=None,
) -> tuple:
    """RoBW plan over Aᵀ — the backward-pass streaming schedule.

    A GCN epoch's backward gradient dH = Aᵀ dX re-streams the adjacency in
    transposed orientation. Materializing CSC of A as CSR of Aᵀ (one
    counting sort) lets Algorithm 1 run unchanged: complete *columns* of A
    become complete rows of Aᵀ, so the no-merge invariant carries over to
    the backward stream. Returns (a_t, plan) where plan partitions a_t.
    Pass a precomputed `a_t` to skip the transpose (callers that already
    materialized it for planning or accounting).
    """
    if a_t is None:
        a_t = csr_transpose(a)
    plan = robw_partition(a_t, m_a_bytes, align=align,
                          value_bytes=value_bytes, index_bytes=index_bytes,
                          boundaries=boundaries)
    return a_t, plan


def naive_partition(a: CSR, m_a_bytes: int, value_bytes: Optional[int] = None,
                    index_bytes: int = 4) -> List[tuple]:
    """The MaxMemory baseline split: cut at *nnz* budget ignoring row
    boundaries. Returns [(nnz_start, nnz_end, first_partial, last_partial)].

    Segments generally begin/end mid-row; the scheduler must merge partial
    rows on the host (the Fig. 3 overhead AIRES removes).
    """
    if value_bytes is None:
        value_bytes = int(a.data.dtype.itemsize)
    per_nnz = index_bytes + value_bytes
    budget_nnz = max(1, (m_a_bytes - 2 * index_bytes) // per_nnz)
    cuts = []
    pos = 0
    row_of = np.searchsorted(a.indptr, np.arange(a.nnz + 1), side="right") - 1
    while pos < a.nnz:
        end = min(pos + budget_nnz, a.nnz)
        first_partial = pos != a.indptr[row_of[min(pos, a.nnz - 1)]]
        last_partial = end < a.nnz and end != a.indptr[row_of[end]]
        cuts.append((int(pos), int(end), bool(first_partial), bool(last_partial)))
        pos = end
    return cuts


def densify_segment(
    a: CSR,
    seg: RoBWSegment,
    bm: int = 128,
    bk: int = 128,
    dtype: np.dtype = np.float32,
    bucketed: bool = True,
    buckets: Optional[List[int]] = None,
) -> BlockELL:
    """Tile-densify one RoBW segment of `a` into a BlockELL brick.

    The single re-tile primitive shared by the full pass
    (`segments_to_block_ell`) and the delta path (`AiresSpGEMM.
    apply_edge_update`): both produce bit-identical bricks for the same
    rows, which is what makes delta-updated bricks interchangeable with a
    from-scratch re-tile.

    `buckets` is an explicit ELL bucket ladder (see `ell_bucket_capacity`
    and the autotuner, `repro.core.autotune`); None keeps the default
    power-of-two buckets bit-exactly.
    """
    sub = csr_row_slice(a, seg.row_start, seg.row_end)
    ell = tile_csr_to_block_ell(sub, bm=bm, bk=bk, ell_width=None, dtype=dtype)
    if bucketed:
        cap = ell_bucket_capacity(ell.ell_width, buckets)
        if cap != ell.ell_width:
            pad = cap - ell.ell_width
            ell.blocks = np.pad(ell.blocks, ((0, 0), (0, pad), (0, 0), (0, 0)))
            ell.col_tile = np.pad(ell.col_tile, ((0, 0), (0, pad)),
                                  constant_values=-1)
    return ell


def segments_to_block_ell(
    a: CSR,
    plan: RoBWPlan,
    bm: int = 128,
    bk: int = 128,
    dtype: np.dtype = np.float32,
    bucketed: bool = True,
    buckets: Optional[List[int]] = None,
) -> Iterator[BlockELL]:
    """Phase-I host preprocessing: stream of tile-densified segments.

    With bucketed=True, ell_width is padded to the power-of-two bucket so all
    segments in the same bucket share a compiled kernel (DESIGN §2); an
    explicit `buckets` ladder replaces the power-of-two one.
    """
    for seg in plan.segments:
        yield densify_segment(a, seg, bm=bm, bk=bk, dtype=dtype,
                              bucketed=bucketed, buckets=buckets)


def segment_ell_widths(a: CSR, plan: RoBWPlan, bm: int = 128,
                       bk: int = 128) -> List[int]:
    """True (pre-padding) BlockELL tile width of every segment in `plan`.

    The width `tile_csr_to_block_ell(..., ell_width=None)` would compute
    — max over the segment's row blocks of distinct populated column
    tiles — read straight off the CSR index structure, with no
    densification. This is what lets the autotuner price candidate ELL
    bucket sets analytically (`repro.core.autotune.bucket_set_bytes`)
    before committing to a re-tile.
    """
    widths: List[int] = []
    for seg in plan.segments:
        w = 0
        for rb_start in range(seg.row_start, seg.row_end, bm):
            lo = int(a.indptr[rb_start])
            hi = int(a.indptr[min(rb_start + bm, seg.row_end)])
            if hi > lo:
                w = max(w, int(np.unique(a.indices[lo:hi] // bk).size))
        widths.append(max(1, w))
    return widths


def robw_delta_partition(
    a_new: CSR,
    old_plan: RoBWPlan,
    touched_rows,
    value_bytes: Optional[int] = None,
    index_bytes: int = 4,
) -> tuple:
    """Incremental RoBW re-partition after an edge delta.

    `a_new` is the updated CSR (same row count as the graph `old_plan`
    partitioned); `touched_rows` are the rows whose content changed
    (`EdgeDelta.touched_rows`, or `.touched_cols` for a transposed plan).
    Returns ``(plan, reuse)`` where ``reuse[i]`` is the old segment index
    whose rows — and bricks — new segment ``i`` reuses verbatim, or None if
    the segment covers touched rows and must re-tile.

    Untouched segments are copied boundary-for-boundary (their content is
    bit-identical, so their bricks and fingerprints stay valid). Maximal
    runs of touched segments are merged into one span and re-partitioned by
    `robw_partition` under the *old* plan's budget and alignment — work
    proportional to the touched span, not the graph. Because each span is
    re-packed greedily in isolation, a delta plan's boundaries inside a
    span may differ from a from-scratch global re-plan; the bricks it
    yields are still exactly `densify_segment` of their rows, and every
    segment still respects the budget.
    """
    if value_bytes is None:
        value_bytes = int(a_new.data.dtype.itemsize)
    segs_old = old_plan.segments
    touched = np.unique(np.asarray(touched_rows, dtype=np.int64).ravel())
    if touched.size and (touched[0] < 0 or touched[-1] >= a_new.n_rows):
        raise IndexError(f"touched rows outside [0, {a_new.n_rows})")
    row_starts = np.array([s.row_start for s in segs_old], dtype=np.int64)
    touched_mask = np.zeros(len(segs_old), dtype=bool)
    if touched.size:
        hit = np.searchsorted(row_starts, touched, side="right") - 1
        touched_mask[np.unique(hit)] = True
    segments: List[RoBWSegment] = []
    reuse: List[Optional[int]] = []
    i = 0
    while i < len(segs_old):
        if not touched_mask[i]:
            segments.append(dataclasses.replace(segs_old[i]))
            reuse.append(i)
            i += 1
            continue
        j = i
        while j < len(segs_old) and touched_mask[j]:
            j += 1
        span_start = segs_old[i].row_start
        span_end = segs_old[j - 1].row_end
        sub = csr_row_slice(a_new, span_start, span_end)
        sub_plan = robw_partition(sub, old_plan.budget_bytes,
                                  align=old_plan.align,
                                  value_bytes=value_bytes,
                                  index_bytes=index_bytes)
        for s in sub_plan.segments:
            segments.append(RoBWSegment(
                row_start=s.row_start + span_start,
                row_end=s.row_end + span_start,
                nnz=s.nnz, nbytes=s.nbytes))
            reuse.append(None)
        i = j
    return (RoBWPlan(segments=segments, align=old_plan.align,
                     budget_bytes=old_plan.budget_bytes), reuse)


def merge_partial_rows(prev_tail: np.ndarray, head: np.ndarray) -> np.ndarray:
    """Host-side merge of a split row (baseline schedulers only).

    Models the paper's 'packed with the last portion of data already
    transferred ... for merging and staging in the host memory'. Returns the
    merged row values; the cost of this call is what Fig. 3 measures.
    """
    return np.concatenate([prev_tail, head])
