"""Train-step builder + fault-tolerant training loop.

`make_train_step` returns a jit-able (params, opt_state, batch) → (loss,
params, opt_state) closure with optional gradient accumulation and int8
error-feedback gradient compression (applied before the DP reduction when
running under shard_map; under plain pjit/GSPMD the quantize/dequantize
pair still bounds the wire format of the reduce).

`train_loop` drives steps with checkpoint/restart via repro.checkpoint and
the runtime supervisor's retry policy.

`make_gcn_train_step` / `gcn_train_loop` are the out-of-core counterparts
for the paper's GCN workload: gradients flow through `AiresSpGEMM`'s custom
VJP, so every optimizer step really streams A forward and Aᵀ backward.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.transformer import lm_loss
from repro.train.compression import compress_grads, decompress_grads, ef_init
from repro.train.optim import make_optimizer


@dataclasses.dataclass
class TrainLoopConfig:
    optimizer: str = "adamw"
    lr: float = 3e-4
    grad_accum: int = 1
    compress: bool = False         # int8 EF gradient compression
    checkpoint_every: int = 50
    max_steps: int = 200
    mesh_axes: Optional[bool] = None


def make_train_step(cfg: ArchConfig, loop_cfg: TrainLoopConfig,
                    loss_fn: Optional[Callable] = None):
    loss_fn = loss_fn or (
        lambda params, batch: lm_loss(
            cfg, params, batch["tokens"], batch["labels"],
            vision_embeds=batch.get("vision_embeds"),
            audio_embeds=batch.get("audio_embeds"),
            mesh_axes=loop_cfg.mesh_axes))
    _, opt_update = make_optimizer(loop_cfg.optimizer, lr=loop_cfg.lr)

    def micro_grads(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def train_step(params, opt_state, batch, ef=None):
        if loop_cfg.grad_accum > 1:
            # Microbatch over the leading axis: batch arrays are
            # (accum, local_batch, ...). lax.scan keeps the HLO compact.
            def body(carry, micro):
                acc_loss, acc_grads = carry
                loss, grads = micro_grads(params, micro)
                acc_grads = jax.tree_util.tree_map(
                    jnp.add, acc_grads, grads)
                return (acc_loss + loss, acc_grads), ()

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.float32(0.0), zeros), batch)
            loss = loss / loop_cfg.grad_accum
            grads = jax.tree_util.tree_map(
                lambda g: g / loop_cfg.grad_accum, grads)
        else:
            loss, grads = micro_grads(params, batch)

        new_ef = ef
        if loop_cfg.compress and ef is not None:
            q, scales, new_ef = compress_grads(grads, ef)
            grads = decompress_grads(q, scales)

        params, opt_state = opt_update(params, grads, opt_state)
        return loss, params, opt_state, new_ef

    return train_step


def make_gcn_train_step(cfg, engine, a, h0, labels,
                        optimizer: str = "adamw", lr: float = 1e-2,
                        **opt_kwargs):
    """Out-of-core GCN train step (the paper's actual workload).

    cfg is a `repro.models.gcn.GCNConfig` with out_of_core=True, `engine` an
    `AiresSpGEMM`, `a` host CSR. The returned step is NOT wrapped in jit:
    the streaming pipeline runs host-side (device_put + per-segment Pallas
    dispatch), and jit would freeze its per-epoch accounting. Returns
    (init_opt, step) with step(params, opt_state) -> (loss, params,
    opt_state).
    """
    from repro.models.gcn import gcn_loss
    from repro.train.optim import make_optimizer as _mk

    init_opt, opt_update = _mk(optimizer, lr=lr, **opt_kwargs)

    def step(params, opt_state):
        loss, grads = jax.value_and_grad(
            lambda p: gcn_loss(cfg, p, a, h0, labels, engine=engine))(params)
        params, opt_state = opt_update(params, grads, opt_state)
        return loss, params, opt_state

    return init_opt, step


def gcn_train_loop(cfg, engine, a, h0, labels, params, n_epochs: int,
                   optimizer: str = "adamw", lr: float = 1e-2,
                   log_every: int = 1):
    """Drive true out-of-core GCN epochs; returns (params, info).

    info carries the loss history and the per-epoch forward/backward
    `StreamStats` logs from the engine — the real counterpart of
    `gcn_epoch(mode="execute")` accounting, here under an actual optimizer.
    """
    init_opt, step = make_gcn_train_step(cfg, engine, a, h0, labels,
                                         optimizer=optimizer, lr=lr)
    opt_state = init_opt(params)
    history = []
    epochs = []
    t0 = time.perf_counter()
    for epoch in range(n_epochs):
        engine.reset_stats_logs()
        loss, params, opt_state = step(params, opt_state)
        epochs.append({
            "forward_stream": list(engine.forward_stats_log),
            "backward_stream": list(reversed(engine.backward_stats_log)),
        })
        if epoch % log_every == 0:
            history.append((epoch, float(loss)))
    jax.block_until_ready(loss)
    return params, {"history": history, "epochs": epochs,
                    "seconds": time.perf_counter() - t0}


def train_loop(cfg: ArchConfig, loop_cfg: TrainLoopConfig, params, opt_state,
               batches, checkpointer=None, start_step: int = 0,
               log_every: int = 10, ef=None):
    """Simple driver: checkpoint every N steps, resumable from start_step."""
    step_fn = jax.jit(make_train_step(cfg, loop_cfg))
    if loop_cfg.compress and ef is None:
        ef = ef_init(params)
    history = []
    t0 = time.perf_counter()
    for step, batch in enumerate(batches, start=start_step):
        if step >= loop_cfg.max_steps:
            break
        loss, params, opt_state, ef = step_fn(params, opt_state, batch, ef)
        if step % log_every == 0:
            history.append((step, float(loss)))
        if checkpointer is not None and step and \
                step % loop_cfg.checkpoint_every == 0:
            checkpointer.save(step, params, opt_state, ef=ef)
    jax.block_until_ready(loss)
    elapsed = time.perf_counter() - t0
    return params, opt_state, {"history": history, "seconds": elapsed,
                               "ef": ef}
