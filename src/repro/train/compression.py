"""int8 error-feedback gradient compression for the DP all-reduce.

At 512 chips the data-parallel gradient all-reduce moves |params| bytes per
step per device; int8 quantization cuts it 4× (vs f32) / 2× (vs bf16).
Error feedback keeps the quantization *unbiased over time*: the residual
from step t is added back before quantizing at t+1, so SGD/Adam see a
telescoping sum whose error stays bounded — the standard EF-SGD argument.

Usage inside a train step:
    q, scales, ef_new = compress_grads(grads, ef)
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis)   # int32 accumulate
    grads = decompress_grads(q_sum, scale_sum, n_replicas)
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def ef_init(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads(grads, ef):
    """Returns (int8 tree, scale tree, new error-feedback tree)."""
    def comp(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = _quantize(corrected)
        recon = q.astype(jnp.float32) * scale
        return q, scale, corrected - recon

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = tdef.flatten_up_to(ef)
    out = [comp(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in out]),
            tdef.unflatten([o[1] for o in out]),
            tdef.unflatten([o[2] for o in out]))


def decompress_grads(q_tree, scale_tree, dtype=jnp.float32):
    return jax.tree_util.tree_map(
        lambda q, s: q.astype(jnp.float32) * s, q_tree, scale_tree)
